"""Generic Pallas TPU kernel for SIMD² matrix-matrix operations.

This is the TPU-native embodiment of the paper's SIMD² unit (§3.1): one
datapath (HBM→VMEM block pipeline + fp32 block accumulator resident in VMEM
across the K grid dimension) whose ⊗/⊕ "ALU" is selected per instruction.

  * mma           → the block contraction is a real MXU ``jnp.dot``.
  * addnorm       → fused MXU rewrite in-kernel: −2·a@b plus row/col norm
                    rank-1 corrections (O(K·M·N) work on the MXU).
  * min/max rings → VPU rank-u updates: the (bm, u, bn) ⊗-broadcast is
                    ⊕-reduced over u, looping u-sized K slivers (u=8 matches
                    the VPU sublane count).
  * orand         → runs in the float {0,1} domain with (max, min); the
                    wrapper restores bool.

Block sizes default to (bm, bn, bk) = (128, 128, 128): MXU-aligned, and the
three resident blocks + fp32 accumulator use 128·128·(2+2+4+4) B ≈ 192 KiB of
VMEM — small enough for Mosaic's double buffering (~0.4 MiB total) with room
to grow bk.  K-tail padding uses per-ring pad values chosen so that
⊗(pad_a, pad_b) equals the ⊕-identity (see ``_PADS``), making padded lanes
algebraic no-ops.
"""
from __future__ import annotations

import functools
from typing import Optional

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl
from jax.experimental.pallas import tpu as pltpu

from repro.core import semiring as sr_mod

Array = jax.Array

# jax renamed TPUCompilerParams → CompilerParams across 0.4.x/0.5.x releases.
_CompilerParams = getattr(pltpu, "CompilerParams", None) or getattr(
    pltpu, "TPUCompilerParams")

# (pad_a, pad_b) per op with ⊗(pad_a, pad_b) == ⊕-identity (K-tail padding);
# the table lives in core/semiring.py so the serving layer's shape bucketing
# shares the exact same padding algebra.
_PADS = sr_mod._CONTRACTION_PADS

_SUBLANES = 8  # VPU sublane count — rank-u update width.


def _float_ring(sr: sr_mod.Semiring):
  """or-and executes on the VPU in the float {0,1} domain as (max, min)."""
  if sr.boolean:
    return jnp.maximum, jnp.minimum
  return sr.oplus, sr.otimes


def _block_contract(sr: sr_mod.Semiring, a: Array, b: Array,
                    acc_dtype, faithful: bool = False) -> Array:
  """One (bm, bk) × (bk, bn) block contraction — the 'ALU' dispatch.

  ``faithful=True`` forces the paper's ⊗-ALU semantics (VPU rank-u loop)
  even for ops with an MXU rewrite — the paper-faithful baseline arm in
  EXPERIMENTS.md §Perf.
  """
  if sr.name == "mma" and not faithful:
    return jnp.dot(a, b, preferred_element_type=jnp.float32)
  if sr.name == "addnorm" and not faithful:
    # Σ(a−b)² = ‖a‖²·1ᵀ + 1·‖b‖²ᵀ − 2ab: MXU dot + rank-1 VPU corrections.
    ab = jnp.dot(a, b, preferred_element_type=jnp.float32)
    a2 = jnp.sum(jnp.square(a.astype(jnp.float32)), axis=1, keepdims=True)
    b2 = jnp.sum(jnp.square(b.astype(jnp.float32)), axis=0, keepdims=True)
    return a2 - 2.0 * ab + b2

  oplus, otimes = _float_ring(sr)
  bm, bk = a.shape
  bn = b.shape[1]
  u = min(_SUBLANES, bk)
  nsub = bk // u

  def body(j, acc):
    a_s = jax.lax.dynamic_slice(a, (0, j * u), (bm, u)).astype(acc_dtype)
    b_s = jax.lax.dynamic_slice(b, (j * u, 0), (u, bn)).astype(acc_dtype)
    prod = otimes(a_s[:, :, None], b_s[None, :, :])  # (bm, u, bn)
    part = prod[:, 0, :]
    for t in range(1, u):  # u is tiny & static: unrolled ⊕-tree
      part = oplus(part, prod[:, t, :])
    return oplus(acc, part)

  a0 = jax.lax.dynamic_slice(a, (0, 0), (bm, u)).astype(acc_dtype)
  b0 = jax.lax.dynamic_slice(b, (0, 0), (u, bn)).astype(acc_dtype)
  prod0 = otimes(a0[:, :, None], b0[None, :, :])
  acc = prod0[:, 0, :]
  for t in range(1, u):
    acc = oplus(acc, prod0[:, t, :])
  return jax.lax.fori_loop(1, nsub, body, acc) if nsub > 1 else acc


def _make_kernel(sr: sr_mod.Semiring, acc_dtype, has_c: bool, has_kv: bool,
                 bk: int, faithful: bool = False):
  oplus, _ = _float_ring(sr)

  def kernel(*refs):
    refs = list(refs)
    a_ref, b_ref = refs[0], refs[1]
    pos = 2
    c_ref = None
    if has_c:
      c_ref, pos = refs[pos], pos + 1
    kv_ref = None
    if has_kv:
      kv_ref, pos = refs[pos], pos + 1
    o_ref = refs[pos]
    k = pl.program_id(2)

    @pl.when(k == 0)
    def _init():
      # K-block 0 always runs: it both initializes o_ref and covers the
      # k_valid==0 case (a frozen request whose output the caller discards).
      part = _block_contract(sr, a_ref[...], b_ref[...], acc_dtype, faithful)
      if c_ref is not None:
        o_ref[...] = oplus(part, c_ref[...].astype(acc_dtype))
      else:
        o_ref[...] = part

    # Ragged masked-K skipping: a K-block whose first lane is at or beyond
    # this request's k_valid holds only algebraic-no-op pad lanes, so the
    # whole block contraction is dead work and is skipped.
    live = (k != 0) if kv_ref is None else ((k != 0) & (k * bk < kv_ref[0, 0]))

    @pl.when(live)
    def _acc():
      part = _block_contract(sr, a_ref[...], b_ref[...], acc_dtype, faithful)
      o_ref[...] = oplus(o_ref[...], part)

  return kernel


def _pad_to(x: Array, m: int, n: int, val: float) -> Array:
  pm, pn = m - x.shape[0], n - x.shape[1]
  if pm == 0 and pn == 0:
    return x
  return jnp.pad(x, ((0, pm), (0, pn)), constant_values=val)


@functools.partial(
    jax.jit,
    static_argnames=("op", "bm", "bn", "bk", "interpret", "faithful"))
def semiring_mmo(a: Array,
                 b: Array,
                 c: Optional[Array] = None,
                 *,
                 op: str = "mma",
                 bm: int = 128,
                 bn: int = 128,
                 bk: int = 128,
                 interpret: bool = False,
                 faithful: bool = False,
                 k_valid: Optional[Array] = None) -> Array:
  """Tiled Pallas D = C ⊕ (A ⊗ B) for 2-D operands (vmap for batching).

  ``k_valid`` (int32 scalar) marks how many leading K lanes are live; K
  blocks at or beyond it are skipped entirely (the caller guarantees those
  lanes are algebraic no-ops — contraction pads or isolated-vertex padding).
  """
  sr = sr_mod.get(op)
  was_bool = sr.boolean
  in_dtype = a.dtype
  if was_bool:
    a = a.astype(jnp.float32)
    b = b.astype(jnp.float32)
    if c is not None:
      c = c.astype(jnp.float32)
    in_dtype = jnp.dtype(jnp.float32)

  m, k = a.shape
  n = b.shape[1]
  bm_, bn_, bk_ = min(bm, _rup(m, 8)), min(bn, _rup(n, 128)), min(
      bk, _rup(k, _SUBLANES))
  mp, np_, kp = _rup(m, bm_), _rup(n, bn_), _rup(k, bk_)

  pa, pb = _PADS[sr.name]
  a_p = _pad_to(a, mp, kp, pa)
  b_p = _pad_to(b, kp, np_, pb)

  acc_dtype = jnp.float32 if sr.name in ("mma", "addnorm") else (
      jnp.float32 if was_bool else sr.acc_dtype(in_dtype))
  has_c = c is not None
  if has_c:
    c_p = _pad_to(c.astype(acc_dtype), mp, np_, 0.0)

  has_kv = k_valid is not None
  grid = (mp // bm_, np_ // bn_, kp // bk_)
  kernel = _make_kernel(sr, acc_dtype, has_c, has_kv, bk_, faithful)

  in_specs = [
      pl.BlockSpec((bm_, bk_), lambda i, j, kk: (i, kk)),
      pl.BlockSpec((bk_, bn_), lambda i, j, kk: (kk, j)),
  ]
  operands = [a_p, b_p]
  if has_c:
    in_specs.append(pl.BlockSpec((bm_, bn_), lambda i, j, kk: (i, j)))
    operands.append(c_p)
  if has_kv:
    # one live-K scalar, shipped as a (1, 1) int32 block every grid step
    in_specs.append(pl.BlockSpec((1, 1), lambda i, j, kk: (0, 0)))
    operands.append(jnp.asarray(k_valid, jnp.int32).reshape(1, 1))

  out = pl.pallas_call(
      kernel,
      grid=grid,
      in_specs=in_specs,
      out_specs=pl.BlockSpec((bm_, bn_), lambda i, j, kk: (i, j)),
      out_shape=jax.ShapeDtypeStruct((mp, np_), acc_dtype),
      compiler_params=_CompilerParams(
          dimension_semantics=("parallel", "parallel", "arbitrary")),
      interpret=interpret,
      name=f"simd2_{sr.name}",
  )(*operands)

  out = out[:m, :n]
  if was_bool:
    out = out > 0.5
  return out


def _rup(x: int, mult: int) -> int:
  return ((x + mult - 1) // mult) * mult

"""Pallas TPU kernel for the Mamba2/SSD intra-chunk contraction.

The SSD "dual form" intra-chunk term is literally a masked semiring-like
matrix operation (DESIGN.md §4):

    Y[q, p] = Σ_k  (C_q · B_k)  ·  exp(cum_q − cum_k) · 1[k ≤ q]  ·  dt_k · X[k, p]
              └── MXU dot ──┘   └──── decay mask L (VPU) ────┘     └─ MXU dot ─┘

One grid cell = one (batch·chunk, head) tile: C/B (Q,N), X (Q,P), dt/cum (Q)
all resident in VMEM; two MXU matmuls bracket a VPU mask — the same
dataflow as the SIMD² unit with a fused ⊗-stage decay.  Q=256, N=128, P=64
⇒ ~0.6 MiB VMEM/cell.  Validated in interpret mode against the einsum
oracle (tests/test_kernels_ssd.py); models/ssm.py keeps the XLA einsum as
the dry-run path (Mosaic is TPU-only).
"""
from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl
from jax.experimental.pallas import tpu as pltpu

# jax renamed TPUCompilerParams → CompilerParams across 0.4.x/0.5.x releases.
_CompilerParams = getattr(pltpu, "CompilerParams", None) or getattr(
    pltpu, "TPUCompilerParams")

Array = jax.Array


def _kernel(c_ref, b_ref, x_ref, dt_ref, cum_ref, o_ref):
  f32 = jnp.float32
  c = c_ref[0, 0].astype(f32)          # (Q, N)
  b = b_ref[0, 0].astype(f32)          # (Q, N)
  x = x_ref[0, 0].astype(f32)          # (Q, P)
  dt = dt_ref[0, 0].astype(f32)        # (Q, 1)
  cum = cum_ref[0, 0].astype(f32)      # (Q, 1)

  scores = jax.lax.dot_general(c, b, (((1,), (1,)), ((), ())),
                               preferred_element_type=f32)       # (Q, Q) MXU
  q = scores.shape[0]
  seg = cum[:, 0][:, None] - cum[:, 0][None, :]                  # cum_q−cum_k
  iq = jax.lax.broadcasted_iota(jnp.int32, (q, q), 0)
  ik = jax.lax.broadcasted_iota(jnp.int32, (q, q), 1)
  decay = jnp.where(ik <= iq, jnp.exp(seg), 0.0)                 # L mask VPU
  p_mat = scores * decay * dt[:, 0][None, :]                     # (Q, Q)
  y = jax.lax.dot_general(p_mat, x, (((1,), (0,)), ((), ())),
                          preferred_element_type=f32)            # (Q, P) MXU
  o_ref[0, 0] = y.astype(o_ref.dtype)


@functools.partial(jax.jit, static_argnames=("interpret",))
def ssd_intra_chunk(c: Array, b: Array, xh: Array, dt: Array, cum: Array,
                    *, interpret: bool = False) -> Array:
  """Intra-chunk SSD output.

  c, b: (BZ, H, Q, N) per-head (group-expanded) projections;
  xh:   (BZ, H, Q, P); dt, cum: (BZ, H, Q).  Returns y (BZ, H, Q, P).
  (BZ = batch·n_chunks; the inter-chunk recurrence stays in JAX.)
  """
  bz, h, q, n = c.shape
  p = xh.shape[-1]
  dt2 = dt[..., None]                                   # (BZ,H,Q,1)
  cum2 = cum[..., None]

  spec_qn = pl.BlockSpec((1, 1, q, n), lambda i, j: (i, j, 0, 0))
  spec_qp = pl.BlockSpec((1, 1, q, p), lambda i, j: (i, j, 0, 0))
  spec_q1 = pl.BlockSpec((1, 1, q, 1), lambda i, j: (i, j, 0, 0))

  return pl.pallas_call(
      _kernel,
      grid=(bz, h),
      in_specs=[spec_qn, spec_qn, spec_qp, spec_q1, spec_q1],
      out_specs=spec_qp,
      out_shape=jax.ShapeDtypeStruct((bz, h, q, p), jnp.float32),
      compiler_params=_CompilerParams(
          dimension_semantics=("parallel", "parallel")),
      interpret=interpret,
      name="ssd_intra_chunk",
  )(c, b, xh, dt2, cum2)


def ssd_intra_chunk_ref(c, b, xh, dt, cum):
  """einsum oracle (identical math to models/ssm.ssd_chunked's y_diag)."""
  f32 = jnp.float32
  scores = jnp.einsum("zhqn,zhkn->zhqk", c.astype(f32), b.astype(f32))
  seg = cum.astype(f32)[..., :, None] - cum.astype(f32)[..., None, :]
  qlen = c.shape[-2]
  mask = jnp.tril(jnp.ones((qlen, qlen), bool))
  decay = jnp.where(mask, jnp.exp(seg), 0.0)
  return jnp.einsum("zhqk,zhk,zhkp->zhqp", scores * decay, dt.astype(f32),
                    xh.astype(f32))

"""jit'd public wrappers for the Pallas kernels.

``semiring_mmo`` / ``flash_attention`` here are the entry points the rest of
the framework uses; on a CPU host they run in interpret mode automatically
(the kernels themselves target TPU Mosaic).
"""
from __future__ import annotations

import functools
from typing import Optional

import jax
import jax.numpy as jnp

from repro.kernels import semiring_mmo as _sm
from repro.kernels import flash_attention as _fa

Array = jax.Array


def _on_tpu() -> bool:
  return jax.default_backend() == "tpu"


def semiring_mmo(a: Array, b: Array, c: Optional[Array] = None, *,
                 op: str = "mma", bm: int = 128, bn: int = 128, bk: int = 128,
                 interpret: Optional[bool] = None, faithful: bool = False,
                 k_valid: Optional[Array] = None) -> Array:
  """Batched-aware Pallas MMO; vmaps leading batch dims onto the 2-D kernel.

  ``k_valid`` broadcasts over the batch dims (one live-K scalar per kernel
  instance), so a (R, M, K) batch takes an (R,) vector of per-request K
  counts — the ragged masked-K serving path.
  """
  interp = (not _on_tpu()) if interpret is None else interpret
  kw = dict(op=op, bm=bm, bn=bn, bk=bk, interpret=interp, faithful=faithful)
  has_c, has_kv = c is not None, k_valid is not None

  def base(*ops_):
    pos = 2
    cc = ops_[pos] if has_c else None
    pos += has_c
    kv = ops_[pos] if has_kv else None
    return _sm.semiring_mmo(ops_[0], ops_[1], cc, k_valid=kv, **kw)

  operands = [a, b]
  if has_c:
    operands.append(c)
  if has_kv:
    operands.append(jnp.broadcast_to(jnp.asarray(k_valid, jnp.int32),
                                     a.shape[:-2]))
  fn = base
  for _ in range(a.ndim - 2):
    fn = jax.vmap(fn)
  return fn(*operands)


def flash_attention(q: Array, k: Array, v: Array, *, causal: bool = True,
                    window: Optional[int] = None,
                    scale: Optional[float] = None,
                    bq: int = 128, bkv: int = 128,
                    interpret: Optional[bool] = None) -> Array:
  interp = (not _on_tpu()) if interpret is None else interpret
  return _fa.flash_attention(q, k, v, causal=causal, window=window,
                             scale=scale, bq=bq, bkv=bkv, interpret=interp)

"""jit'd public wrappers for the Pallas kernels.

``semiring_mmo`` / ``flash_attention`` here are the entry points the rest of
the framework uses; on a CPU host they run in interpret mode automatically
(the kernels themselves target TPU Mosaic).
"""
from __future__ import annotations

import functools
from typing import Optional

import jax
import jax.numpy as jnp

from repro.kernels import semiring_mmo as _sm
from repro.kernels import flash_attention as _fa

Array = jax.Array


def _on_tpu() -> bool:
  return jax.default_backend() == "tpu"


def semiring_mmo(a: Array, b: Array, c: Optional[Array] = None, *,
                 op: str = "mma", bm: int = 128, bn: int = 128, bk: int = 128,
                 interpret: Optional[bool] = None,
                 faithful: bool = False) -> Array:
  """Batched-aware Pallas MMO; vmaps leading batch dims onto the 2-D kernel."""
  interp = (not _on_tpu()) if interpret is None else interpret
  fn = functools.partial(_sm.semiring_mmo, op=op, bm=bm, bn=bn, bk=bk,
                         interpret=interp, faithful=faithful)
  nbatch = a.ndim - 2
  for _ in range(nbatch):
    fn = jax.vmap(fn)
  if c is None:
    return fn(a, b) if nbatch == 0 else fn(a, b)
  return fn(a, b, c)


def flash_attention(q: Array, k: Array, v: Array, *, causal: bool = True,
                    window: Optional[int] = None,
                    scale: Optional[float] = None,
                    bq: int = 128, bkv: int = 128,
                    interpret: Optional[bool] = None) -> Array:
  interp = (not _on_tpu()) if interpret is None else interpret
  return _fa.flash_attention(q, k, v, causal=causal, window=window,
                             scale=scale, bq=bq, bkv=bkv, interpret=interp)

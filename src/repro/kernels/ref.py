"""Pure-jnp oracles for every Pallas kernel in this package.

Each kernel in kernels/ is validated against these references over
shape/dtype sweeps (tests/test_kernels.py) — the same role the paper's
cuASR/CUTLASS "correctness validation backend" plays (§5.1.2).
"""
from __future__ import annotations

from typing import Optional

import jax
import jax.numpy as jnp

from repro.core import semiring as sr_mod

Array = jax.Array


def semiring_mmo_ref(a: Array, b: Array, c: Optional[Array] = None, *,
                     op: str = "mma") -> Array:
  """Unblocked D = C ⊕ (A ⊗ B) oracle."""
  sr = sr_mod.get(op)
  acc = sr.acc_dtype(a.dtype)
  if sr.boolean:
    a, b = a.astype(jnp.bool_), b.astype(jnp.bool_)
    prod = sr.otimes(a[..., :, :, None], b[..., None, :, :])
  else:
    prod = sr.otimes(a[..., :, :, None].astype(acc),
                     b[..., None, :, :].astype(acc))
  out = sr_mod.oplus_reduce(sr, prod, axis=-2)
  if c is not None:
    out = sr.oplus(out, c.astype(out.dtype))
  return out


def addnorm_ref(a: Array, b: Array, c: Optional[Array] = None) -> Array:
  """Pairwise squared-L2: D[i,j] = Σ_k (a[i,k] − b[k,j])² (+ C)."""
  return semiring_mmo_ref(a, b, c, op="addnorm")


def attention_ref(q: Array, k: Array, v: Array, *, causal: bool = True,
                  window: Optional[int] = None,
                  scale: Optional[float] = None) -> Array:
  """Dense softmax attention oracle.

  q: (B, H, Sq, D); k, v: (B, H, Skv, D) — head-group expansion (GQA) is the
  wrapper's job.  Supports causal masking and sliding-window (SWA).
  """
  *_, sq, d = q.shape
  skv = k.shape[-2]
  scale = (d ** -0.5) if scale is None else scale
  logits = jnp.einsum("bhqd,bhkd->bhqk", q.astype(jnp.float32),
                      k.astype(jnp.float32)) * scale
  qpos = jnp.arange(sq)[:, None] + (skv - sq)  # align ends (decode-friendly)
  kpos = jnp.arange(skv)[None, :]
  mask = jnp.ones((sq, skv), dtype=bool)
  if causal:
    mask &= kpos <= qpos
  if window is not None:
    mask &= kpos > qpos - window
  logits = jnp.where(mask, logits, -jnp.inf)
  probs = jax.nn.softmax(logits, axis=-1)
  out = jnp.einsum("bhqk,bhkd->bhqd", probs, v.astype(jnp.float32))
  return out.astype(q.dtype)

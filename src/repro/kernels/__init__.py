"""Pallas TPU kernels: generic SIMD² semiring MMO + flash attention."""
from repro.kernels.ops import flash_attention, semiring_mmo

__all__ = ["flash_attention", "semiring_mmo"]

"""Pallas TPU kernels: generic SIMD² semiring MMO, the fused closure
fixpoint megakernel, and flash attention."""
from repro.kernels.closure_megakernel import megakernel_fixpoint
from repro.kernels.ops import flash_attention, semiring_mmo

__all__ = ["flash_attention", "megakernel_fixpoint", "semiring_mmo"]

"""Fused Pallas closure megakernel — G fixpoint iterations per launch.

``_batched_fixpoint`` (core/closure.py) runs one device program per squaring
step: every iteration round-trips the whole (R, n, n) iterate through HBM and
re-reads it for the next contraction.  The TCU computational model
(arXiv:1908.06649) says exactly this off-chip traffic — not FLOPs — bounds
iterative matrix algorithms, so this kernel keeps each request's iterate
resident in VMEM and runs **G whole iterations per grid visit**:

  * grid = (requests, G, output row-blocks); the request dim is parallel
    (Megacore splits it), the iteration and block dims are sequential.
  * the output ref doubles as the on-chip iterate: initialized from the
    incoming stack at (g == 0, i == 0), updated in place each iteration, and
    flushed to HBM once per request — HBM traffic is paid once per G
    iterations instead of once per iteration.
  * per-request ``k_valid``/live-n, the incoming active flags, iteration
    counters, and the chunk's live-step budget are **scalar-prefetched**
    (the ragged-attention idiom): available before the body runs, so a
    frozen request's grid steps skip all contraction work via ``pl.when``
    without any host observation.
  * the per-request convergence reduction — ``_changed``'s inf-aware (and
    NaN-aware) compare — runs in-kernel on the last block of each iteration
    and lands in an output flag vector the host driver folds back into the
    surrounding ``lax.while_loop``.
  * a ``pl.CostEstimate`` tells XLA the launch covers R·G contractions'
    worth of flops over one chunk's worth of HBM bytes, so it schedules the
    fused program sanely instead of assuming one-matmul cost.

Why G-iteration chunks instead of unrolling the whole fixpoint on-chip: the
iterate must stay fully VMEM-resident (each iteration reads every row of the
previous one), which caps n, and worst-case trip counts (n−1 for
Bellman-Ford) would force a worst-case-sized launch even though most batches
converge early.  Chunking keeps the early-exit: the host ``while_loop`` asks
for at most G more iterations, re-checks ``any(active)``, and stops — frozen
requests inside a chunk cost one scalar test per grid step.

Bit-parity contract: outputs *and* per-request iteration counts match
``_batched_fixpoint`` exactly for every ring with a ⊗-identity (the parity
suite in tests/test_closure_megakernel.py pins this in interpret mode).
"""
from __future__ import annotations

import functools
from typing import Any, NamedTuple, Optional

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl
from jax.experimental.pallas import tpu as pltpu

from repro.core import closure as cl_mod
from repro.core import semiring as sr_mod
from repro.kernels.semiring_mmo import (_CompilerParams, _float_ring, _rup,
                                        _SUBLANES)

Array = jax.Array

DEFAULT_G = 8  # chunk length: fixpoint iterations fused per kernel launch


def _slab_contract(sr: sr_mod.Semiring, a_slab: Array, b_full: Array,
                   kv, acc_dtype) -> Array:
  """One (bm, K) × (K, N) row-slab contraction against the full resident
  iterate.  K is never split across grid steps (the whole matrix is already
  in VMEM), so mma keeps the reference's single-dot summation order — the
  bit-parity contract with the per-iteration path.

  ``kv`` (traced int32) bounds the VPU rank-u sliver loop: lanes at or
  beyond a request's live-n are isolated-vertex padding whose ⊗ terms are
  ⊕-identity no-ops, so min/max rings skip them (exact algebra — dropping
  exact no-ops cannot move a min/max).  The MXU path ignores the hint, like
  the per-contraction kernel: full padded K on the MXU is already cheap.
  """
  if sr.name == "mma":
    return jnp.dot(a_slab, b_full, preferred_element_type=jnp.float32)

  oplus, otimes = _float_ring(sr)
  bm, kp = a_slab.shape
  bn = b_full.shape[1]
  u = min(_SUBLANES, kp)

  def sliver(j):
    a_s = jax.lax.dynamic_slice(a_slab, (0, j * u), (bm, u)).astype(acc_dtype)
    b_s = jax.lax.dynamic_slice(b_full, (j * u, 0), (u, bn)).astype(acc_dtype)
    prod = otimes(a_s[:, :, None], b_s[None, :, :])  # (bm, u, bn)
    part = prod[:, 0, :]
    for t in range(1, u):  # u is tiny & static: unrolled ⊕-tree
      part = oplus(part, prod[:, t, :])
    return part

  # sliver 0 always runs: every live request has kv >= 1
  acc = sliver(0)
  nlive = (kv + u - 1) // u  # live slivers — the ragged masked-K trip count

  def body(j, acc):
    return oplus(acc, sliver(j))

  return jax.lax.fori_loop(1, nlive, body, acc)


def _make_fixpoint_kernel(sr: sr_mod.Semiring, acc_dtype, nblk: int, bm: int,
                          has_adj: bool):
  """Kernel factory; ``has_adj`` selects Bellman-Ford (D ← D ⊕ (D ⊗ A),
  constant second operand) vs repeated squaring (C ← C ⊕ (C ⊗ C))."""
  oplus, _ = _float_ring(sr)
  boolean = sr.boolean

  def fixpoint_kernel(kv_ref, act0_ref, it0_ref, glim_ref, *refs):
    # scalar-prefetch refs first (SMEM, whole vectors, indexable by request)
    if has_adj:
      c_ref, adj_ref = refs[0], refs[1]
      o_ref, it_ref, act_ref, new_ref = refs[2], refs[3], refs[4], refs[5]
    else:
      c_ref, adj_ref = refs[0], None
      o_ref, it_ref, act_ref, new_ref = refs[1], refs[2], refs[3], refs[4]

    r = pl.program_id(0)
    g = pl.program_id(1)
    i = pl.program_id(2)

    @pl.when((g == 0) & (i == 0))
    def _init():
      # seed the VMEM-resident iterate + per-request flags for this request
      o_ref[0] = c_ref[0].astype(acc_dtype)
      it_ref[0, 0] = it0_ref[r]
      act_ref[0, 0] = act0_ref[r]

    # frozen requests (and steps past the request's live budget) skip every
    # contraction — one scalar test per grid step, no host round-trip.  The
    # budget is a per-request vector: the batched driver broadcasts one
    # chunk-wide value, the arena hands every slot its own remaining cap so
    # slots admitted at different times share a launch without over-running.
    live = (act_ref[0, 0] != 0) & (g < glim_ref[r])

    @pl.when(live)
    def _compute():
      old_slab = o_ref[0, pl.ds(i * bm, bm), :]
      b_full = adj_ref[0] if has_adj else o_ref[0]
      part = _slab_contract(sr, old_slab, b_full, kv_ref[r], acc_dtype)
      new_ref[pl.ds(i * bm, bm), :] = oplus(part, old_slab)

    @pl.when(live & (i == nblk - 1))
    def _commit():
      # all row slabs of this iteration are in scratch; run the convergence
      # reduction (inf- and NaN-aware, matching core.closure._changed) and
      # advance the iterate + flags in place
      old = o_ref[0]
      new = new_ref[...]
      if boolean:
        same = new == old  # float {0,1} domain — plain equality is exact
      else:
        same = ((new == old)
                | (jnp.isinf(new) & jnp.isinf(old)
                   & (jnp.sign(new) == jnp.sign(old)))
                | (jnp.isnan(new) & jnp.isnan(old)))
      ndiff = jnp.sum(jnp.logical_not(same).astype(jnp.int32))
      o_ref[0] = new
      it_ref[0, 0] = it_ref[0, 0] + 1
      act_ref[0, 0] = (ndiff > 0).astype(jnp.int32)

  return fixpoint_kernel


def _chunk_call(c: Array, adj: Optional[Array], kv: Array, act: Array,
                it: Array, glim: Array, *, op: str, g_steps: int, bm: int,
                interpret: bool):
  """One megakernel launch: up to ``g_steps`` fixpoint iterations on-chip.

  ``glim`` is an (R,) int32 vector of per-request live-step budgets —
  request ``r`` runs ``min(glim[r], g_steps)`` iterations (fewer if it
  converges first).  Returns (iterate, iteration counters, active flags) —
  the pieces the host ``while_loop`` carries between chunks.
  """
  sr = sr_mod.get(op)
  acc_dtype = c.dtype
  r, np_ = c.shape[0], c.shape[-1]
  nblk = np_ // bm
  has_adj = adj is not None
  kernel = _make_fixpoint_kernel(sr, acc_dtype, nblk, bm, has_adj)

  def mat_spec():
    return pl.BlockSpec((1, np_, np_), lambda rr, gg, ii, *_: (rr, 0, 0))

  def flag_spec():
    return pl.BlockSpec((1, 1), lambda rr, gg, ii, *_: (rr, 0))

  in_specs = [mat_spec()]
  operands = [c]
  if has_adj:
    in_specs.append(mat_spec())
    operands.append(adj)

  itemsize = jnp.dtype(acc_dtype).itemsize
  # the whole point of the fusion: HBM traffic is one chunk's worth (read
  # the stack once, write it once, plus the constant A for Bellman-Ford),
  # while the flops cover all R·G contractions run from VMEM
  cost = pl.CostEstimate(
      flops=2 * r * g_steps * np_ * np_ * np_,
      bytes_accessed=itemsize * r * np_ * np_ * (2 + int(has_adj)),
      transcendentals=0,
  )

  out, it_out, act_out = pl.pallas_call(
      kernel,
      grid_spec=pltpu.PrefetchScalarGridSpec(
          num_scalar_prefetch=4,
          grid=(r, g_steps, nblk),
          in_specs=in_specs,
          out_specs=[mat_spec(), flag_spec(), flag_spec()],
          scratch_shapes=[pltpu.VMEM((np_, np_), acc_dtype)],
      ),
      out_shape=[
          jax.ShapeDtypeStruct((r, np_, np_), acc_dtype),
          jax.ShapeDtypeStruct((r, 1), jnp.int32),
          jax.ShapeDtypeStruct((r, 1), jnp.int32),
      ],
      compiler_params=_CompilerParams(
          dimension_semantics=("parallel", "arbitrary", "arbitrary")),
      cost_estimate=cost,
      interpret=interpret,
      name=f"simd2_fixpoint_{sr.name}",
  )(kv, act, it, glim, *operands)
  return out, it_out[:, 0], act_out[:, 0]


class ChunkGeometry(NamedTuple):
  """Resolved kernel layout for one (ring, n, dtype) combination.

  Both megakernel callers — the batched ``megakernel_fixpoint`` driver and
  the request arena (serve_mmo/arena.py) — derive their buffers from this
  one resolver, so a slot admitted into the arena lands in a byte-identical
  layout to the same request stacked into a batch: bit-parity of the two
  paths is by construction, not by test luck.
  """
  was_bool: bool      # boolean ring: stored as float32 {0,1}, output > 0.5
  missing: float      # ⊕-identity fill for padded cells
  self_value: float   # ⊗-identity for padded diagonal (isolated vertices)
  acc_dtype: Any      # on-chip iterate dtype
  bm: int             # row-slab height (lane/sublane aligned)
  np_: int            # padded matrix dim (multiple of bm)
  interpret: bool     # Pallas interpret mode (CPU CI) vs compiled TPU


def chunk_geometry(op: str, n: int, dtype=jnp.float32, *, bm: int = 128,
                   interpret: Optional[bool] = None) -> ChunkGeometry:
  """Resolve the megakernel layout for ring ``op`` at true size ``n``.

  Raises for rings without a ⊗-identity (addnorm) — no isolated-vertex
  embedding exists, exactly like the per-iteration path refuses closure.
  """
  sr = sr_mod.get(op)
  missing, self_value = cl_mod.closure_pad_values(op)
  interp = (jax.default_backend() != "tpu") if interpret is None else (
      bool(interpret))
  was_bool = sr.boolean
  if was_bool:
    missing, self_value = float(missing), float(self_value)
  store = jnp.float32 if was_bool else jnp.dtype(dtype)
  acc_dtype = jnp.float32 if (sr.name == "mma" or was_bool) else (
      sr.acc_dtype(store))
  # lane/sublane-aligned padding; interpret mode keeps it minimal so the
  # CPU parity suite stays cheap
  bm_ = min(bm, _rup(n, 8 if interp else 128))
  np_ = _rup(n, bm_)
  return ChunkGeometry(was_bool=was_bool, missing=missing,
                       self_value=self_value, acc_dtype=acc_dtype,
                       bm=bm_, np_=np_, interpret=interp)


def fixpoint_iters(algorithm: str, n: int) -> int:
  """Default trip-count cap: the same bound both fixpoint paths use —
  Bellman-Ford needs n relaxation rounds, repeated squaring ⌈log2 n⌉."""
  if algorithm == "bellman_ford":
    return max(1, int(n))
  if algorithm == "leyzorek":
    import math
    return max(1, math.ceil(math.log2(max(n, 2))))
  raise ValueError(f"unknown algorithm {algorithm!r}")


def fixpoint_chunk(c: Array, adj: Optional[Array], kv: Array, act: Array,
                   it: Array, glim: Array, *, op: str, g_steps: int, bm: int,
                   interpret: bool):
  """Public chunk entry point — one fused launch of up to ``g_steps``
  fixpoint iterations over an (R, np_, np_) stack with per-request budgets.

  The arena jit-wraps this over its whole slot buffer each tick; operands
  must already be in ``chunk_geometry`` layout (padded, acc_dtype, bool
  rings as float32).  Returns (iterate, iteration counters, active flags).
  """
  return _chunk_call(c, adj, kv, act, it, glim, op=op, g_steps=g_steps,
                     bm=bm, interpret=interpret)


def _pad_closure(x: Array, np_: int, missing, self_value) -> Array:
  """Embed (R, n, n) into (R, np_, np_) as isolated vertices — the same
  stable-under-closure padding the serving bucketer uses, so the in-kernel
  convergence compare over the padded region never flips a flag."""
  r, n = x.shape[0], x.shape[-1]
  if np_ == n:
    return x
  out = jnp.full((r, np_, np_), jnp.asarray(missing, x.dtype), x.dtype)
  out = out.at[:, :n, :n].set(x)
  diag = jnp.arange(n, np_)
  return out.at[:, diag, diag].set(jnp.asarray(self_value, x.dtype))


@functools.partial(
    jax.jit,
    static_argnames=("op", "algorithm", "max_iters", "g", "bm", "interpret"))
def megakernel_fixpoint(adj: Array,
                        *,
                        op: str,
                        algorithm: str = "leyzorek",
                        max_iters: Optional[int] = None,
                        valid_n: Optional[Array] = None,
                        g: int = DEFAULT_G,
                        bm: int = 128,
                        interpret: Optional[bool] = None):
  """Whole-fixpoint driver: ``lax.while_loop`` over G-iteration megakernel
  chunks.  Drop-in replacement for ``core.closure._batched_fixpoint`` —
  same (closure, per-request iteration counts) contract, bit-identical
  results (the per-chunk live budget ``min(g, max_iters − i)`` keeps the
  ``max_iters`` cap exact even when G doesn't divide the trip count).
  """
  if adj.ndim != 3:
    raise ValueError(f"megakernel fixpoint needs (R, n, n) input, "
                     f"got {adj.shape}")
  if algorithm not in ("leyzorek", "bellman_ford"):
    raise ValueError(f"unknown algorithm {algorithm!r}")
  if g < 1:
    raise ValueError(f"chunk length g must be >= 1, got {g}")
  sr = sr_mod.get(op)

  r, n = adj.shape[0], adj.shape[-1]
  iters = fixpoint_iters(algorithm, n) if max_iters is None else max_iters

  # the shared layout resolver refuses rings without a ⊗-identity (addnorm)
  # — no isolated-vertex embedding exists, like the per-iteration path
  was_bool = sr.boolean
  x = adj.astype(jnp.float32) if was_bool else adj
  geom = chunk_geometry(op, n, adj.dtype, bm=bm, interpret=interpret)
  acc_dtype, bm_, np_, interp = (geom.acc_dtype, geom.bm, geom.np_,
                                 geom.interpret)
  c0 = _pad_closure(x.astype(acc_dtype), np_, geom.missing, geom.self_value)
  adj_operand = c0 if algorithm == "bellman_ford" else None

  if valid_n is None:
    kv = jnp.full((r,), n, jnp.int32)
  else:
    kv = jnp.asarray(valid_n, jnp.int32)

  g_steps = min(g, iters)

  def cond(state):
    _, active, _, i = state
    return jnp.any(active) & (i < iters)

  def body(state):
    c, active, it, i = state
    glim = jnp.minimum(jnp.asarray(g_steps, jnp.int32),
                       jnp.asarray(iters, jnp.int32) - i)
    c2, it2, act2 = _chunk_call(
        c, adj_operand, kv, active.astype(jnp.int32), it,
        jnp.full((r,), glim, jnp.int32),
        op=op, g_steps=g_steps, bm=bm_, interpret=interp)
    return c2, act2 > 0, it2, i + glim

  state0 = (c0, jnp.ones((r,), jnp.bool_), jnp.zeros((r,), jnp.int32),
            jnp.asarray(0, jnp.int32))
  out, _, iters_run, _ = jax.lax.while_loop(cond, body, state0)
  out = out[:, :n, :n]
  if was_bool:
    out = out > 0.5
  return out, iters_run

"""Pallas TPU flash-attention (forward) kernel for the LM stack.

Perf-critical compute layer for the assigned transformer architectures:
online-softmax block attention with causal and sliding-window (SWA) masking.
Grid is (batch·heads, q_blocks, kv_blocks); running max / denominator / fp32
output accumulator live in VMEM scratch across the kv dimension (the
TPU-idiomatic replacement for a GPU warp-register accumulator).  Blocks whose
entire kv range is masked out are skipped via ``pl.when`` (causal + window
early-out), so compute for a causal prefill is ~half the rectangle and SWA
prefill is O(S·window).

GQA wrapping, KV-cache paging and decode (q_len=1) stay in XLA — only the
O(S²) prefill core is a kernel (see models/attention.py).
"""
from __future__ import annotations

import functools
from typing import Optional

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl
from jax.experimental.pallas import tpu as pltpu

# jax renamed TPUCompilerParams → CompilerParams across 0.4.x/0.5.x releases.
_CompilerParams = getattr(pltpu, "CompilerParams", None) or getattr(
    pltpu, "TPUCompilerParams")

Array = jax.Array

_NEG_INF = -1e30
_LANES = 128  # scratch minor dim (VPU lane count)


def _fa_kernel(q_ref, k_ref, v_ref, o_ref, m_scr, l_scr, acc_scr, *,
               scale: float, causal: bool, window: Optional[int],
               bq: int, bkv: int, nkv: int, seq_off: int, kv_len: int):
  """One (q_block, kv_block) step of online softmax."""
  qi = pl.program_id(1)
  kj = pl.program_id(2)

  @pl.when(kj == 0)
  def _init():
    m_scr[...] = jnp.full_like(m_scr, _NEG_INF)
    l_scr[...] = jnp.zeros_like(l_scr)
    acc_scr[...] = jnp.zeros_like(acc_scr)

  # absolute positions: q rows sit at the *end* of the kv axis (decode-style
  # alignment); seq_off = skv - sq.
  q_start = qi * bq + seq_off
  k_start = kj * bkv

  # block-level reachability early-out (skips ~half the causal rectangle,
  # and everything outside the sliding window)
  conds = []
  if causal:
    conds.append(k_start <= q_start + bq - 1)
  if window is not None:
    conds.append(k_start + bkv - 1 > q_start - window)
  run = None
  for c in conds:
    run = c if run is None else jnp.logical_and(run, c)

  def _step():
    q = q_ref[0].astype(jnp.float32)  # (bq, d)
    k = k_ref[0].astype(jnp.float32)  # (bkv, d)
    s = jax.lax.dot_general(q, k, (((1,), (1,)), ((), ())),
                            preferred_element_type=jnp.float32) * scale

    qpos = q_start + jax.lax.broadcasted_iota(jnp.int32, (bq, bkv), 0)
    kpos = k_start + jax.lax.broadcasted_iota(jnp.int32, (bq, bkv), 1)
    mask = kpos < kv_len  # mask kv-tail padding
    if causal:
      mask &= kpos <= qpos
    if window is not None:
      mask &= kpos > qpos - window
    s = jnp.where(mask, s, _NEG_INF)

    m_prev = m_scr[:, 0]                      # (bq,)
    m_cur = jnp.maximum(m_prev, jnp.max(s, axis=1))
    alpha = jnp.exp(m_prev - m_cur)           # rescale factor
    p = jnp.exp(s - m_cur[:, None])           # (bq, bkv)
    l_cur = alpha * l_scr[:, 0] + jnp.sum(p, axis=1)

    v = v_ref[0].astype(jnp.float32)          # (bkv, d)
    pv = jax.lax.dot_general(p, v, (((1,), (0,)), ((), ())),
                             preferred_element_type=jnp.float32)
    acc_scr[...] = acc_scr[...] * alpha[:, None] + pv
    m_scr[...] = jnp.broadcast_to(m_cur[:, None], m_scr.shape)
    l_scr[...] = jnp.broadcast_to(l_cur[:, None], l_scr.shape)

  if run is None:
    _step()
  else:
    pl.when(run)(_step)

  @pl.when(kj == nkv - 1)
  def _finish():
    l = l_scr[:, 0]
    l = jnp.where(l == 0.0, 1.0, l)  # fully-masked rows → zeros, not NaN
    o_ref[0] = (acc_scr[...] / l[:, None]).astype(o_ref.dtype)


@functools.partial(
    jax.jit,
    static_argnames=("causal", "window", "scale", "bq", "bkv", "interpret"))
def flash_attention(q: Array, k: Array, v: Array, *,
                    causal: bool = True,
                    window: Optional[int] = None,
                    scale: Optional[float] = None,
                    bq: int = 128, bkv: int = 128,
                    interpret: bool = False) -> Array:
  """q: (B, H, Sq, D); k, v: (B, H, Skv, D); returns (B, H, Sq, D).

  Expand GQA KV heads before calling (wrapper does this lazily via
  broadcasting in index_map — no materialized copy)."""
  b, h, sq, d = q.shape
  skv = k.shape[-2]
  hkv = k.shape[1]
  assert h % hkv == 0, (h, hkv)
  grp = h // hkv
  scale_v = (d ** -0.5) if scale is None else scale

  bq_ = min(bq, sq)
  bkv_ = min(bkv, skv)
  sq_p, skv_p = _rup(sq, bq_), _rup(skv, bkv_)
  if sq_p != sq:
    q = jnp.pad(q, ((0, 0), (0, 0), (0, sq_p - sq), (0, 0)))
  if skv_p != skv:
    # padded kv rows must never win the max: rely on causal/pos mask — pad
    # positions sit beyond every real q position, masked by kpos <= qpos when
    # causal; for non-causal we mask via kpos < skv below.
    k = jnp.pad(k, ((0, 0), (0, 0), (0, skv_p - skv), (0, 0)))
    v = jnp.pad(v, ((0, 0), (0, 0), (0, skv_p - skv), (0, 0)))

  nq, nkv = sq_p // bq_, skv_p // bkv_
  bh = b * h
  q4 = q.reshape(bh, sq_p, d)
  seq_off = skv - sq

  kernel = functools.partial(
      _fa_kernel, scale=scale_v, causal=causal, window=window,
      bq=bq_, bkv=bkv_, nkv=nkv, seq_off=seq_off, kv_len=skv)

  # map flattened (b*h) → kv head index without materializing GQA expansion
  def kv_index(bh_i, qi, kj):
    return (bh_i // (grp * hkv) * hkv + (bh_i % (grp * hkv)) // grp, kj, 0)

  k3 = k.reshape(b * hkv, skv_p, d)
  v3 = v.reshape(b * hkv, skv_p, d)

  out = pl.pallas_call(
      kernel,
      grid=(bh, nq, nkv),
      in_specs=[
          pl.BlockSpec((1, bq_, d), lambda bh_i, qi, kj: (bh_i, qi, 0)),
          pl.BlockSpec((1, bkv_, d), kv_index),
          pl.BlockSpec((1, bkv_, d), kv_index),
      ],
      out_specs=pl.BlockSpec((1, bq_, d), lambda bh_i, qi, kj: (bh_i, qi, 0)),
      out_shape=jax.ShapeDtypeStruct((bh, sq_p, d), q.dtype),
      scratch_shapes=[
          pltpu.VMEM((bq_, _LANES), jnp.float32),  # running max
          pltpu.VMEM((bq_, _LANES), jnp.float32),  # running denom
          pltpu.VMEM((bq_, d), jnp.float32),       # fp32 out accumulator
      ],
      compiler_params=_CompilerParams(
          dimension_semantics=("parallel", "parallel", "arbitrary")),
      interpret=interpret,
      name="flash_attention_fwd",
  )(q4, k3, v3)

  return out.reshape(b, h, sq_p, d)[:, :, :sq, :]


def _rup(x: int, mult: int) -> int:
  return ((x + mult - 1) // mult) * mult

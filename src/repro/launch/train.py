"""Training driver: config → mesh → sharded train loop with checkpointing,
fault tolerance, and deterministic resume.

    PYTHONPATH=src python -m repro.launch.train --arch tinyllama-1.1b \
        --steps 200 --batch 8 --seq 256 --smoke --ckpt-dir /tmp/run1

On the CPU host this runs the reduced (smoke) configs on a host mesh; on a
real pod the same driver runs the full config on make_production_mesh().
Fault tolerance: every --ckpt-every steps the full train state is committed
atomically; on restart the driver resumes from LATEST and the stateless data
pipeline replays the exact stream.  A simulated failure mode (--fail-at)
kills the process mid-run so tests can exercise the restart path.
"""
from __future__ import annotations

import argparse
import os
import sys
import time

import jax
import jax.numpy as jnp
from jax.sharding import NamedSharding, PartitionSpec as P

from repro import configs
from repro.data import DataConfig, make_source
from repro.launch import mesh as mesh_mod
from repro.models import common as cm
from repro.models import zoo
from repro.train import (AdamWConfig, checkpoint as ckpt, init_opt_state,
                         make_train_step)


def main(argv=None):
  ap = argparse.ArgumentParser()
  ap.add_argument("--arch", required=True)
  ap.add_argument("--smoke", action="store_true")
  ap.add_argument("--steps", type=int, default=100)
  ap.add_argument("--batch", type=int, default=8)
  ap.add_argument("--seq", type=int, default=256)
  ap.add_argument("--lr", type=float, default=3e-3)
  ap.add_argument("--accum", type=int, default=1)
  ap.add_argument("--ckpt-dir", default=None)
  ap.add_argument("--ckpt-every", type=int, default=50)
  ap.add_argument("--fail-at", type=int, default=None,
                  help="simulate a node failure at this step (tests)")
  ap.add_argument("--corpus", default=None)
  ap.add_argument("--async-ckpt", action="store_true",
                  help="commit checkpoints on a background thread")
  ap.add_argument("--prefetch", type=int, default=2)
  ap.add_argument("--log-every", type=int, default=10)
  ap.add_argument("--seed", type=int, default=0)
  args = ap.parse_args(argv)

  cfg = configs.get_config(args.arch, smoke=args.smoke)
  oc = AdamWConfig(lr=args.lr, warmup_steps=max(2, args.steps // 20),
                   total_steps=args.steps)
  data = make_source(DataConfig(vocab=cfg.vocab, seq_len=args.seq,
                                global_batch=args.batch, seed=args.seed,
                                corpus_path=args.corpus),
                     prefetch=args.prefetch)

  n_dev = len(jax.devices())
  mesh = mesh_mod.make_host_mesh(model=2 if n_dev > 1 else 1)
  par = cm.Parallelism(data_axes=("data",), tp_size=mesh.shape["model"])

  start = 0
  params = zoo.init(cfg, jax.random.PRNGKey(args.seed))
  opt = init_opt_state(params)
  if args.ckpt_dir and ckpt.latest_step(args.ckpt_dir) is not None:
    restored, start = ckpt.restore(args.ckpt_dir,
                                   template={"params": params, "opt": opt})
    params, opt = restored["params"], restored["opt"]
    print(f"[train] resumed from step {start}")

  specs = cm.specs_like(params, cfg, par)
  shard = lambda t, s: jax.device_put(
      t, jax.tree.map(lambda sp: NamedSharding(mesh, sp), s,
                      is_leaf=lambda x: isinstance(x, P)))
  with mesh:
    params = shard(params, specs)
    opt = shard(opt, {"m": specs, "v": specs, "step": P()})
    step_fn = jax.jit(make_train_step(cfg, oc, accum=args.accum),
                      donate_argnums=0)

    state = (params, opt)
    t0 = time.time()
    for step in range(start, args.steps):
      if args.fail_at is not None and step == args.fail_at:
        print(f"[train] simulating node failure at step {step}", flush=True)
        os._exit(42)
      batch = data.batch_at(step)
      state, metrics = step_fn(state, batch)
      if (step + 1) % args.log_every == 0 or step == start:
        loss = float(metrics["loss"])
        dt = time.time() - t0
        tok_s = args.batch * args.seq * (step + 1 - start) / max(dt, 1e-9)
        print(f"[train] step={step + 1} loss={loss:.4f} "
              f"lr={float(metrics['lr']):.2e} "
              f"gnorm={float(metrics['grad_norm']):.2f} tok/s={tok_s:,.0f}",
              flush=True)
      if args.ckpt_dir and (step + 1) % args.ckpt_every == 0:
        payload = {"params": state[0], "opt": state[1]}
        if args.async_ckpt:
          if not hasattr(main, "_ac") or main._ac.ckpt_dir != args.ckpt_dir:
            main._ac = ckpt.AsyncCheckpointer(args.ckpt_dir)
          main._ac.save(step + 1, payload)
        else:
          ckpt.save(args.ckpt_dir, step + 1, payload)
  if args.ckpt_dir and args.async_ckpt and hasattr(main, "_ac"):
    main._ac.wait()
  print("[train] done")
  return 0


if __name__ == "__main__":
  sys.exit(main())

import os
os.environ["XLA_FLAGS"] = "--xla_force_host_platform_device_count=512"

# Multi-pod dry-run: lower + compile every (architecture × input-shape × mesh)
# cell against the production mesh, with 512 placeholder host devices (set
# above, BEFORE any other import — jax locks the device count on first init).
#
#   PYTHONPATH=src python -m repro.launch.dryrun --arch granite-8b \
#       --shape train_4k --mesh single
#   PYTHONPATH=src python -m repro.launch.dryrun --all --out results/
#
# Per cell it prints/records compiled.memory_analysis() (proves fit),
# cost_analysis() (FLOPs/bytes for §Roofline) and the parsed collective
# traffic (for the collective roofline term).

import argparse        # noqa: E402
import json            # noqa: E402
import math            # noqa: E402
import sys             # noqa: E402
import time            # noqa: E402

import jax             # noqa: E402
import jax.numpy as jnp  # noqa: E402
from jax.sharding import PartitionSpec as P  # noqa: E402

from repro import configs                     # noqa: E402
from repro.launch import mesh as mesh_mod     # noqa: E402
from repro.launch import specs as sp          # noqa: E402
from repro.models import common as cm         # noqa: E402
from repro.models import zoo                  # noqa: E402
from repro.roofline import analysis, collectives, hlo_walk  # noqa: E402
from repro.train import optimizer as opt_mod  # noqa: E402
from repro.train import steps as steps_mod    # noqa: E402


def active_params(cfg) -> float:
  """Non-embedding active params (MoE: topk/E of expert weights)."""
  import functools
  shapes = jax.eval_shape(functools.partial(zoo.init, cfg),
                          jax.random.PRNGKey(0))
  flat = cm.tree_paths(shapes)
  total = 0.0
  for path, leaf in flat.items():
    n = math.prod(leaf.shape)
    if "embed" in path or "lm_head" in path:
      continue
    if "experts" in path and cfg.n_experts:
      n = n * cfg.topk / cfg.n_experts
    total += n
  return total


def _ns(mesh, tree):
  """PartitionSpec pytree → NamedSharding pytree (P is a tuple: is_leaf)."""
  return jax.tree.map(
      lambda s: jax.sharding.NamedSharding(mesh, s),
      tree, is_leaf=lambda x: isinstance(x, P))


# Gradient microbatching per train cell: fixed global batch, sequential
# accumulation — the standard memory lever when activations exceed HBM at
# accum=1 (recorded in EXPERIMENTS.md §Dry-run).
ACCUM_OVERRIDES = {
    ("mixtral-8x7b", "train_4k"): 4,
    ("phi3.5-moe-42b-a6.6b", "train_4k"): 4,
    ("chameleon-34b", "train_4k"): 8,
    ("zamba2-7b", "train_4k"): 4,
    ("seamless-m4t-large-v2", "train_4k"): 4,
}


def build_cell(arch: str, shape_name: str, multi_pod: bool, *,
               remat: str = "full", accum: int = 0,
               seq_shard_decode: bool = True, fsdp: bool = True,
               act_seq_shard: bool = True, cfg_overrides: dict = None,
               zero2: bool = False, grad_comm_bf16: bool = False):
  if accum == 0:  # auto: per-cell override table, default 1
    accum = ACCUM_OVERRIDES.get((arch, shape_name), 1)
  cfg = configs.get_config(arch)
  if cfg_overrides:
    cfg = cfg.replace(**cfg_overrides)
  shape = configs.SHAPES[shape_name]
  par = mesh_mod.make_parallelism(multi_pod=multi_pod, fsdp=fsdp,
                                  seq_shard_decode=seq_shard_decode,
                                  remat=remat)
  mesh = mesh_mod.make_production_mesh(multi_pod=multi_pod)

  b_shapes = sp.batch_shapes(cfg, shape)
  b_specs = sp.batch_specs(cfg, shape, par)
  act_spec = P(par.dp, par.tp, None) if act_seq_shard else None

  if shape.kind == "train":
    oc = opt_mod.AdamWConfig()
    step = steps_mod.make_train_step(cfg, oc, accum=accum, remat=remat,
                                     grad_specs=sp.param_specs(cfg, par),
                                     zero2=zero2,
                                     grad_comm_bf16=grad_comm_bf16)
    st_shapes = sp.train_state_shapes(cfg)
    st_specs = _ns(mesh, sp.train_state_specs(cfg, par))
    jitted = jax.jit(step, in_shardings=(st_specs, _ns(mesh, b_specs)),
                     out_shardings=(st_specs, None), donate_argnums=0)
    args = (st_shapes, b_shapes)
  elif shape.kind == "prefill":
    step = steps_mod.make_prefill_step(cfg)
    p_specs = _ns(mesh, sp.param_specs(cfg, par))
    c_specs = _ns(mesh, sp.cache_specs(cfg, par, shape))
    out_specs = (_ns(mesh, P(par.dp_for(shape.global_batch), par.tp)), c_specs)
    jitted = jax.jit(step, in_shardings=(p_specs, _ns(mesh, b_specs)),
                     out_shardings=out_specs)
    args = (sp.param_shapes(cfg), b_shapes)
  else:  # decode
    step = steps_mod.make_decode_step(cfg)
    p_specs = _ns(mesh, sp.param_specs(cfg, par))
    c_shapes = sp.cache_shapes(cfg, shape)
    c_specs = _ns(mesh, sp.cache_specs(cfg, par, shape))
    jitted = jax.jit(step, in_shardings=(p_specs, c_specs, _ns(mesh, b_specs)),
                     out_shardings=(_ns(mesh, P(par.dp_for(shape.global_batch), None)), c_specs),
                     donate_argnums=1)
    args = (sp.param_shapes(cfg), c_shapes, b_shapes)

  return cfg, shape, mesh, par, jitted, args, act_spec


def run_cell(arch: str, shape_name: str, mesh_kind: str, **kw) -> dict:
  skip = configs.skip_reason(arch, shape_name)
  if skip:
    return {"arch": arch, "shape": shape_name, "mesh": mesh_kind,
            "status": "skipped", "reason": skip}
  multi_pod = mesh_kind == "multi"
  t0 = time.time()
  cfg, shape, mesh, par, jitted, args, act_spec = build_cell(
      arch, shape_name, multi_pod, **kw)
  with mesh:
    with cm.activation_sharding(act_spec):
      lowered = jitted.lower(*args)
    t_lower = time.time() - t0
    compiled = lowered.compile()
  t_compile = time.time() - t0 - t_lower

  mem = compiled.memory_analysis()
  cost = compiled.cost_analysis()
  # older jax returns a per-device list of cost dicts, newer a single dict
  if isinstance(cost, (list, tuple)):
    cost = cost[0] if cost else {}
  hlo = compiled.as_text()
  # Loop-corrected per-device costs from the compiled artifact (XLA's own
  # cost_analysis counts while bodies once — see roofline/hlo_walk.py).
  walked = hlo_walk.module_cost(hlo)
  chips = math.prod(mesh.devices.shape)

  flops = walked.flops
  nbytes = walked.bytes
  tokens = shape.global_batch * (shape.seq_len if shape.kind != "decode"
                                 else 1)
  mf = analysis.model_flops_estimate(active_params(cfg), shape.kind, tokens)

  peak = None
  argb = outb = tmpb = genb = None
  if mem is not None:
    try:
      argb = mem.argument_size_in_bytes
      outb = mem.output_size_in_bytes
      tmpb = mem.temp_size_in_bytes
      genb = mem.generated_code_size_in_bytes
      alias = getattr(mem, "alias_size_in_bytes", 0)
      peak = argb + outb + tmpb - alias
    except AttributeError:
      pass

  roof = analysis.Roofline(
      arch=arch, shape=shape_name, mesh=mesh_kind, chips=chips,
      hlo_flops=flops * chips,   # walker reports the per-device program
      hlo_bytes=nbytes * chips,
      coll_bytes=walked.coll_bytes,
      coll_breakdown=dict(walked.coll_breakdown),
      model_flops=mf,
      peak_memory_per_dev=peak,
  )
  row = roof.row()
  row.update({
      "status": "ok",
      "lower_s": round(t_lower, 1),
      "compile_s": round(t_compile, 1),
      "arg_bytes": argb, "out_bytes": outb, "temp_bytes": tmpb,
      "code_bytes": genb,
      # raw XLA numbers (loop bodies counted once) kept as a cross-check
      "xla_flops_raw": float(cost.get("flops", 0.0)),
      "xla_bytes_raw": float(cost.get("bytes accessed", 0.0)),
  })
  return row


def main(argv=None):
  ap = argparse.ArgumentParser()
  ap.add_argument("--arch", default=None)
  ap.add_argument("--shape", default=None)
  ap.add_argument("--mesh", default="single", choices=("single", "multi"))
  ap.add_argument("--all", action="store_true")
  ap.add_argument("--out", default=None, help="directory for per-cell JSON")
  ap.add_argument("--remat", default="full")
  ap.add_argument("--accum", type=int, default=0)
  ap.add_argument("--no-fsdp", action="store_true")
  ap.add_argument("--no-seq-shard-decode", action="store_true")
  ap.add_argument("--no-act-seq-shard", action="store_true")
  ap.add_argument("--zero2", action="store_true",
                  help="ZeRO-2: gather compute params once per step")
  ap.add_argument("--grad-comm-bf16", action="store_true",
                  help="bf16 gradient reduction (DDP-style compression)")
  ap.add_argument("--flash-chunk", type=int, default=0)
  ap.add_argument("--set", action="append", default=[],
                  help="config override k=v (e.g. --set ssm_chunk=128)")
  args = ap.parse_args(argv)

  cells = []
  if args.all:
    for a, s, _ in configs.cells():
      cells.append((a, s, args.mesh))
  else:
    cells.append((args.arch, args.shape, args.mesh))

  ok = True
  for arch, shp, mk in cells:
    try:
      if args.flash_chunk:
        from repro.models import attention as _attn
        _attn.FLASH_CHUNK[0] = args.flash_chunk
      overrides = {}
      for kv in args.set:
        k, v = kv.split("=")
        overrides[k] = int(v) if v.lstrip("-").isdigit() else v
      row = run_cell(arch, shp, mk, remat=args.remat, accum=args.accum,
                     fsdp=not args.no_fsdp,
                     seq_shard_decode=not args.no_seq_shard_decode,
                     act_seq_shard=not args.no_act_seq_shard,
                     cfg_overrides=overrides or None, zero2=args.zero2,
                     grad_comm_bf16=args.grad_comm_bf16)
    except Exception as e:  # noqa: BLE001 — a failed cell is a bug; report it
      row = {"arch": arch, "shape": shp, "mesh": mk, "status": "FAILED",
             "error": f"{type(e).__name__}: {e}"}
      ok = False
    print(json.dumps(row, default=float))
    sys.stdout.flush()
    if args.out:
      os.makedirs(args.out, exist_ok=True)
      fn = f"{arch}__{shp}__{mk}.json".replace("/", "_")
      with open(os.path.join(args.out, fn), "w") as f:
        json.dump(row, f, indent=1, default=float)
  return 0 if ok else 1


if __name__ == "__main__":
  sys.exit(main())

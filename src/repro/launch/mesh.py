"""Production mesh construction (assignment-mandated shapes).

Single pod: (data=16, model=16) = 256 chips (TPU v5e pod).
Multi-pod:  (pod=2, data=16, model=16) = 512 chips; the batch is sharded
over (pod, data) — the pod axis is a pure data-parallel outer axis, so the
only cross-pod collective is the gradient all-reduce (DCN-friendly).

Functions, not module constants — importing this module never touches jax
device state.
"""
from __future__ import annotations

import jax

from repro.models.common import Parallelism


def make_production_mesh(*, multi_pod: bool = False):
  shape = (2, 16, 16) if multi_pod else (16, 16)
  axes = ("pod", "data", "model") if multi_pod else ("data", "model")
  return jax.make_mesh(shape, axes)


def make_parallelism(*, multi_pod: bool = False, fsdp: bool = True,
                     seq_shard_decode: bool = True,
                     remat: str = "none") -> Parallelism:
  return Parallelism(
      data_axes=("pod", "data") if multi_pod else ("data",),
      model_axis="model",
      tp_size=16,
      dp_size=32 if multi_pod else 16,
      fsdp=fsdp,
      seq_shard_decode=seq_shard_decode,
      remat=remat,
  )


def make_host_mesh(n_devices: int = 0, model: int = 2):
  """Small mesh over host devices (tests / examples)."""
  n = n_devices or len(jax.devices())
  model = min(model, n)
  return jax.make_mesh((n // model, model), ("data", "model"))

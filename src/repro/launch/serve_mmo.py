"""Serving driver for semiring workloads: open-loop traffic → MMO engine.

    PYTHONPATH=src python -m repro.launch.serve_mmo --rate 40 --duration 3 \
        --backend xla --max-batch 8

    # sharded serving: big buckets run as mesh schedules over 8 devices
    # (3e7 FLOPs ≈ the bucket-256 crossover BENCH_shard.json measures on CPU)
    XLA_FLAGS=--xla_force_host_platform_device_count=8 PYTHONPATH=src \
        python -m repro.launch.serve_mmo --mesh 2,4 --schedule dp \
        --sizes 24,96,200 --shard-flops 3e7 --rate 20

    # QoS serving: deadline policy + admission caps + live metrics every 1s
    PYTHONPATH=src python -m repro.launch.serve_mmo --policy deadline \
        --deadline-s 0.25 --max-queue 256 --tenant-quota 64 \
        --metrics-every 1 --rate 80 --duration 5

    # adaptive QoS: predictions track measured latency; bulk batches are
    # capped to ~20ms of predicted work while deadline traffic is active
    PYTHONPATH=src python -m repro.launch.serve_mmo --policy deadline \
        --deadline-s 0.25 --adaptive --max-batch-seconds 0.02 --rate 80

    # live observability: Prometheus /metrics + /healthz + /snapshot +
    # /trace on :9178 while serving; Chrome trace dumped at the end
    PYTHONPATH=src python -m repro.launch.serve_mmo --http-port 9178 \
        --rate 40 --duration 10 --trace-out /tmp/serve_trace.json
    # (curl localhost:9178/metrics from another terminal)

    # chaos: 5% of execute dispatches fail transiently; bisection + retries
    # keep every request completing (resilience line reports the recovery)
    PYTHONPATH=src python -m repro.launch.serve_mmo --rate 40 --duration 3 \
        --inject-faults "execute:rate:0.05" --transient-retries 2
    # break one arm persistently: its breaker opens and traffic re-dispatches
    PYTHONPATH=src python -m repro.launch.serve_mmo --backend xla \
        --inject-faults "execute:persistent:backend=xla" --watchdog-s 5

Generates a Poisson arrival stream of mixed SIMD² problems (APSP, KNN,
reachability, raw mmo at several sizes), submits each request at its arrival
time against the engine's background serving loop, and reports throughput
(problems/s), latency percentiles, bucket occupancy, and executable-cache
behavior.  Open-loop means arrivals do NOT wait for completions — the
process-level property that makes p99 honest under load.

``--mesh dp,mp`` builds a (data=dp, model=mp) device mesh and turns on the
engine's sharded bucket path: buckets whose per-request contraction exceeds
``--shard-flops`` execute as batched distributed schedules (dp / SUMMA /
kspan / ring per ``--schedule``), the rest stay single-device.
"""
from __future__ import annotations

import argparse
import json
import sys
import threading
import time

import numpy as np

from repro.apps import graphs
from repro.serve_mmo import (DeadlineExceededError, MMOEngine, RejectedError,
                             apsp_request, knn_request, mmo_request,
                             reachability_request)

TENANTS = ("alpha", "beta", "gamma")


def synthesize_request(rng: np.random.Generator, sizes, *,
                       deadline_s=None, deadline_frac: float = 0.0):
  """One random problem from the mixed APSP/KNN/reachability/mmo workload.

  Tenants cycle through a fixed trio; with ``deadline_s``, a
  ``deadline_frac`` share of requests is deadline-tagged at priority 1 —
  the latency-sensitive slice the deadline policy protects.
  """
  kind = rng.choice(("apsp", "knn", "reach", "mmo"))
  n = int(rng.choice(sizes))
  seed = int(rng.integers(0, 2 ** 31))
  qos = {"tenant": TENANTS[int(rng.integers(0, len(TENANTS)))]}
  if deadline_s is not None and rng.random() < deadline_frac:
    qos.update(deadline_s=float(deadline_s), priority=1)
  if kind == "apsp":
    return apsp_request(graphs.weighted_digraph(n, 0.3, seed=seed), **qos)
  if kind == "reach":
    return reachability_request(graphs.boolean_digraph(n, 0.1, seed=seed),
                                **qos)
  if kind == "knn":
    ref, qry = graphs.knn_points(4 * n, n, 16, seed=seed)
    return knn_request(qry, ref, k=min(8, 4 * n), **qos)
  a = rng.standard_normal((n, n)).astype(np.float32)
  b = rng.standard_normal((n, n)).astype(np.float32)
  return mmo_request(a, b, op="minplus", **qos)


def warmup(engine: MMOEngine, rng: np.random.Generator, sizes, n: int = 40):
  """Pre-compile the bucket executables so the measured run is steady-state.

  A sample of the synthetic workload discovers the buckets; ``prewarm`` then
  compiles every (bucket, batch) variant those buckets can produce.
  """
  engine.prewarm([synthesize_request(rng, sizes) for _ in range(n)])
  engine.reset_stats()


def main(argv=None):
  from repro.analysis.sanitize import maybe_enable_sanitize
  maybe_enable_sanitize()  # REPRO_SANITIZE=1: debug_nans + analyzer preflight
  ap = argparse.ArgumentParser()
  ap.add_argument("--rate", type=float, default=40.0,
                  help="mean arrival rate (problems/s)")
  ap.add_argument("--duration", type=float, default=3.0,
                  help="traffic window (s)")
  ap.add_argument("--backend", default="xla",
                  choices=("auto", "xla", "vector", "pallas"))
  ap.add_argument("--max-batch", type=int, default=8)
  ap.add_argument("--min-bucket", type=int, default=8)
  ap.add_argument("--sizes", default="12,24,48",
                  help="comma-separated problem sizes")
  ap.add_argument("--seed", type=int, default=0)
  ap.add_argument("--no-warmup", action="store_true")
  ap.add_argument("--mesh", default=None, metavar="DP,MP",
                  help="device mesh axis sizes, e.g. '2,4' (data=2, model=4);"
                       " enables the sharded bucket path")
  ap.add_argument("--schedule", default="auto",
                  choices=("auto", "dp", "summa", "kspan", "ring", "local"),
                  help="distributed schedule for over-threshold buckets "
                       "(auto: cost-table mesh rows / roofline prior; dp: "
                       "requests sharded over all devices)")
  ap.add_argument("--shard-flops", type=float, default=1e8,
                  help="per-request contraction FLOP cutoff above which a "
                       "bucket routes to the mesh")
  ap.add_argument("--cost-table", default=None, metavar="PATH",
                  help="JSON cost table for --backend auto (see "
                       "repro.tuning.autotune); defaults to $REPRO_COST_TABLE")
  ap.add_argument("--autotune", action="store_true",
                  help="with --backend auto: measure this workload's buckets "
                       "on the live device before serving (and persist to "
                       "--cost-table if given)")
  ap.add_argument("--policy", default="fifo",
                  choices=("fifo", "deadline", "fair"),
                  help="scheduling policy: fifo (oldest head first), "
                       "deadline (earliest feasible deadline + priority "
                       "tiers), fair (weighted round-robin across tenants)")
  ap.add_argument("--max-queue", type=int, default=None,
                  help="admission: reject once this many requests are queued")
  ap.add_argument("--tenant-quota", type=int, default=None,
                  help="admission: per-tenant in-flight request cap")
  ap.add_argument("--max-backlog-s", type=float, default=None,
                  help="admission: reject once the queue's predicted drain "
                       "time (cost-table seconds) exceeds this")
  ap.add_argument("--adaptive", action="store_true",
                  help="close the prediction loop: deadline feasibility, "
                       "backlog admission, and the batch cap read live EWMA "
                       "service latency + measured closure convergence "
                       "counts instead of the static cost table alone")
  ap.add_argument("--max-batch-seconds", type=float, default=None,
                  metavar="SECS",
                  help="service-time batch cap: while deadline traffic is "
                       "active, bound each bulk batch to ~SECS of predicted "
                       "work so an urgent arrival never waits a full "
                       "max_batch service time behind one")
  ap.add_argument("--deadline-s", type=float, default=None,
                  help="tag a --deadline-frac share of traffic with this "
                       "latency budget (priority 1); late requests expire")
  ap.add_argument("--deadline-frac", type=float, default=0.25,
                  help="share of traffic carrying --deadline-s (default .25)")
  ap.add_argument("--metrics-every", type=float, default=None, metavar="SECS",
                  help="emit a live metrics snapshot (rolling p50/p99 per "
                       "bucket, queue depth, admission state) every SECS "
                       "while serving — to stderr (or --metrics-file) so the "
                       "ticker never interleaves with stdout results")
  ap.add_argument("--metrics-file", default=None, metavar="PATH",
                  help="append --metrics-every snapshots to PATH as JSON "
                       "lines instead of stderr")
  ap.add_argument("--http-port", type=int, default=None, metavar="PORT",
                  help="serve the live observability endpoint on PORT: "
                       "/metrics (Prometheus text exposition), /healthz, "
                       "/snapshot (metrics JSON), /trace (Chrome trace-event "
                       "JSON for Perfetto).  0 picks an ephemeral port")
  ap.add_argument("--http-host", default="127.0.0.1",
                  help="bind address for --http-port (default loopback)")
  ap.add_argument("--http-linger", type=float, default=0.0, metavar="SECS",
                  help="keep the observability endpoint up SECS after the "
                       "run drains (lets a scraper collect final state)")
  ap.add_argument("--no-trace", action="store_true",
                  help="disable the request-lifecycle flight recorder "
                       "(tracing is on by default; overhead is bounded and "
                       "asserted in benchmarks/serve_bench.py)")
  ap.add_argument("--trace-out", default=None, metavar="PATH",
                  help="write the flight recorder's Chrome trace JSON to "
                       "PATH at the end of the run")
  ap.add_argument("--inject-faults", default=None, metavar="SPEC",
                  help="chaos harness: ';'-separated fault rules, each "
                       "point:mode[:arg][:k=v...][@match] — e.g. "
                       "'execute:rate:0.02' (2%% of execute checks fail), "
                       "'execute:persistent:backend=xla', "
                       "'slow:transient:1:delay=0.2' (see serve_mmo/faults.py)")
  ap.add_argument("--fault-seed", type=int, default=0,
                  help="seed for rate-mode fault rules (replayable chaos)")
  ap.add_argument("--transient-retries", type=int, default=1,
                  help="whole-sub-batch retries before bisection (default 1)")
  ap.add_argument("--retry-backoff-s", type=float, default=0.002,
                  help="base backoff before a retry, doubled per attempt")
  ap.add_argument("--no-bisect", action="store_true",
                  help="fail a whole batch once retries are spent instead of "
                       "bisecting to isolate the poisoned request")
  ap.add_argument("--breaker-threshold", type=int, default=5, metavar="N",
                  help="consecutive arm failures that open a circuit "
                       "breaker; 0 disables breakers (fail in place)")
  ap.add_argument("--breaker-probe-s", type=float, default=0.25,
                  help="cooldown before an open breaker half-opens for a "
                       "probe batch")
  ap.add_argument("--watchdog-s", type=float, default=None, metavar="SECS",
                  help="per-batch device watchdog: a batch that does not "
                       "return within SECS fails with a timeout instead of "
                       "wedging the serving loop (default: off)")
  args = ap.parse_args(argv)

  try:
    sizes = tuple(int(s) for s in args.sizes.split(","))
    if not sizes or any(s <= 0 for s in sizes):
      raise ValueError
  except ValueError:
    ap.error(f"--sizes must be comma-separated positive ints, got "
             f"{args.sizes!r}")
  rng = np.random.default_rng(args.seed)

  mesh = None
  if args.mesh:
    import jax
    try:
      dims = tuple(int(x) for x in args.mesh.split(","))
      if not 1 <= len(dims) <= 2 or any(d <= 0 for d in dims):
        raise ValueError
    except ValueError:
      ap.error(f"--mesh must be 'dp,mp' positive ints, got {args.mesh!r}")
    if len(dims) == 1:
      dims = (1, dims[0])
    need = dims[0] * dims[1]
    have = len(jax.devices())
    if need > have:
      ap.error(f"--mesh {args.mesh} needs {need} devices, host has {have} "
               f"(on CPU: XLA_FLAGS=--xla_force_host_platform_device_count="
               f"{need})")
    mesh = jax.make_mesh(dims, ("data", "model"))
    print(f"[serve_mmo] mesh data={dims[0]} × model={dims[1]} "
          f"schedule={args.schedule} shard_flops={args.shard_flops:g}")
  elif args.schedule != "auto":
    ap.error(f"--schedule {args.schedule} requires --mesh")

  cost_table = None
  if args.backend == "auto":
    import os
    from repro.tuning import CostTable, tune_for_requests
    if args.cost_table and os.path.exists(args.cost_table):
      cost_table = CostTable.load(args.cost_table)
      print(f"[serve_mmo] loaded cost table {args.cost_table}: "
            f"{len(cost_table)} entries ({cost_table.counts()})")
    elif args.cost_table and not args.autotune:
      # only --autotune may create the file; otherwise a missing table means
      # serving would silently run untuned — fail loudly instead
      ap.error(f"--cost-table {args.cost_table!r} does not exist "
               f"(pass --autotune to create it)")
    if args.autotune:
      sample_rng = np.random.default_rng(args.seed)
      sample = [synthesize_request(sample_rng, sizes) for _ in range(40)]
      t0 = time.perf_counter()
      cost_table = tune_for_requests(sample, table=cost_table)
      print(f"[serve_mmo] autotune: {len(cost_table)} entries in "
            f"{time.perf_counter() - t0:.2f}s")
      if args.cost_table:
        cost_table.save(args.cost_table)
        print(f"[serve_mmo] persisted cost table to {args.cost_table}")

  injector = None
  if args.inject_faults:
    from repro.serve_mmo import parse_fault_spec
    try:
      injector = parse_fault_spec(args.inject_faults, seed=args.fault_seed)
    except ValueError as e:
      ap.error(f"--inject-faults: {e}")
    print(f"[serve_mmo] fault injection armed: {args.inject_faults!r} "
          f"(seed={args.fault_seed})")

  engine = MMOEngine(backend=args.backend, max_batch=args.max_batch,
                     min_bucket=args.min_bucket, cost_table=cost_table,
                     mesh=mesh, schedule=args.schedule if mesh else "auto",
                     shard_flops=args.shard_flops,
                     policy=args.policy, max_queue=args.max_queue,
                     tenant_quota=args.tenant_quota,
                     max_backlog_s=args.max_backlog_s,
                     adaptive=args.adaptive,
                     max_batch_seconds=args.max_batch_seconds,
                     trace=not args.no_trace,
                     faults=injector,
                     transient_retries=args.transient_retries,
                     retry_backoff_s=args.retry_backoff_s,
                     bisect=not args.no_bisect,
                     breaker_threshold=(args.breaker_threshold
                                        if args.breaker_threshold > 0
                                        else None),
                     breaker_probe_s=args.breaker_probe_s,
                     watchdog_s=args.watchdog_s)

  http_server = None
  if args.http_port is not None:
    from repro.serve_mmo import ObservabilityServer
    http_server = ObservabilityServer(engine, host=args.http_host,
                                      port=args.http_port).start()
    print(f"[serve_mmo] observability endpoint at {http_server.url} "
          f"(/metrics /healthz /snapshot /trace)")

  if not args.no_warmup:
    t0 = time.perf_counter()
    warmup(engine, rng, sizes)
    print(f"[serve_mmo] warmup: {engine.cache.stats()} "
          f"({time.perf_counter() - t0:.2f}s)")

  # Poisson arrivals, materialized up front so generation cost is not on the
  # serving path.
  arrivals = np.cumsum(rng.exponential(1.0 / args.rate,
                                       int(args.rate * args.duration)))
  reqs = [synthesize_request(rng, sizes, deadline_s=args.deadline_s,
                             deadline_frac=args.deadline_frac)
          for _ in arrivals]
  misses_before = engine.cache.misses

  ticker_stop = threading.Event()
  if args.metrics_every:
    # the ticker writes to stderr (or --metrics-file), never stdout: the
    # driver's results go to stdout and a mid-line ticker fire would corrupt
    # both streams for anything parsing them
    def tick():
      sink = (open(args.metrics_file, "a", encoding="utf-8")
              if args.metrics_file else sys.stderr)
      try:
        while not ticker_stop.wait(args.metrics_every):
          line = json.dumps(engine.metrics_snapshot(), default=float)
          print(f"[serve_mmo][metrics] {line}", file=sink, flush=True)
      finally:
        if args.metrics_file:
          sink.close()
    threading.Thread(target=tick, name="mmo-metrics", daemon=True).start()

  engine.start()
  t0 = time.perf_counter()
  futures = []
  for t_arr, req in zip(arrivals, reqs):
    now = time.perf_counter() - t0
    if t_arr > now:
      time.sleep(t_arr - now)
    futures.append(engine.submit(req))
  outcomes = {"done": 0, "rejected": 0, "expired": 0, "failed": 0}
  for f in futures:
    try:
      f.result(timeout=600)
      outcomes["done"] += 1
    except RejectedError:
      outcomes["rejected"] += 1
    except DeadlineExceededError:
      outcomes["expired"] += 1
    except Exception:  # noqa: BLE001 — tally, keep draining
      outcomes["failed"] += 1
  wall = time.perf_counter() - t0
  engine.stop()
  ticker_stop.set()
  if args.trace_out:
    with open(args.trace_out, "w", encoding="utf-8") as f:
      json.dump(engine.export_trace(), f)
    print(f"[serve_mmo] wrote Chrome trace ({engine.tracer.stats()}) to "
          f"{args.trace_out} — load it in Perfetto / about://tracing")
  if http_server is not None:
    if args.http_linger > 0:
      print(f"[serve_mmo] endpoint lingering {args.http_linger:g}s at "
            f"{http_server.url}")
      time.sleep(args.http_linger)
    http_server.stop()

  st = engine.stats()
  misses_during = engine.cache.misses - misses_before
  print(f"[serve_mmo] backend={args.backend} policy={args.policy} "
        f"rate={args.rate}/s duration={args.duration}s "
        f"offered={len(futures)}")
  print(f"[serve_mmo] served {st.completed} problems in {wall:.2f}s "
        f"({st.completed / wall:.1f} problems/s) outcomes={outcomes}")
  if st.completed:
    print(f"[serve_mmo] latency p50={st.percentile(50) * 1e3:.1f}ms "
          f"p90={st.percentile(90) * 1e3:.1f}ms "
          f"p99={st.percentile(99) * 1e3:.1f}ms")
  print(f"[serve_mmo] batches={st.batches} mean_batch={st.mean_batch:.2f} "
        f"rejected={st.rejected} expired={st.expired} cache={st.cache}")
  if st.rejected:
    print(f"[serve_mmo] admission rejections: "
          f"{dict(engine.admission.rejections)}")
  msnap = engine.metrics_snapshot()
  retries = msnap["counters"]["retries"]
  failures_by_kind = msnap["batch_failures_by_kind"]
  breakers = engine.resilience.snapshot()
  if injector is not None or retries or failures_by_kind or breakers:
    opens = sum(c["opens"] for c in breakers)
    open_now = [f"{c['bucket']}/{c['backend']}/{c['schedule']}"
                for c in breakers if c["state"] != "closed"]
    print(f"[serve_mmo] resilience: retries={retries} "
          f"batch_failures={failures_by_kind} breaker_opens={opens} "
          f"open_now={open_now}")
    if injector is not None:
      print(f"[serve_mmo] injector: {injector.stats()}")
  if args.adaptive:
    est = engine.estimator.snapshot()
    warm = {label: f"{c['seconds'] * 1e3:.2f}ms/{c['observations']}obs"
            for label, c in est["cells"].items()}
    print(f"[serve_mmo] adaptive estimator (per-request EWMA): {warm}")
    if est["iterations"]:
      print(f"[serve_mmo] measured closure iterations: {est['iterations']}")
  if mesh is not None:
    placement: dict = {}
    for s in engine._schedules.values():
      placement[s] = placement.get(s, 0) + 1
    print(f"[serve_mmo] bucket placement (buckets per schedule): {placement}")
  if not args.no_warmup and misses_during:
    print(f"[serve_mmo] WARNING: {misses_during} compiles during the "
          f"measured window (cold buckets)")
  return 0


if __name__ == "__main__":
  sys.exit(main())

"""Serving driver: batched prefill + decode with continuous batching slots.

    PYTHONPATH=src python -m repro.launch.serve --arch tinyllama-1.1b \
        --smoke --batch 4 --prompt-len 32 --gen 16

Design (scales to the pod meshes in launch/mesh.py):
  * prefill and decode are two separately jitted programs (the assignment's
    ``prefill_*`` / ``decode_*`` shapes lower exactly these),
  * the KV cache is allocated once at max_len and donated through decode
    steps (no reallocation),
  * SWA archs get a window-sized ring-buffer cache automatically,
  * a simple slot scheduler retires finished sequences and admits queued
    prompts (continuous batching) — requests are (prompt, max_new_tokens).
"""
from __future__ import annotations

import argparse
import sys
import time

import jax
import jax.numpy as jnp
import numpy as np

from repro import configs
from repro.models import zoo
from repro.train import make_decode_step, make_prefill_step


class Engine:
  """Minimal batched serving engine over the zoo API."""

  def __init__(self, cfg, params, max_len: int = 512):
    self.cfg = cfg
    self.params = params
    if cfg.window is not None:
      max_len = min(max_len, cfg.window)
    self.max_len = max_len
    self._prefill = jax.jit(make_prefill_step(cfg))
    self._decode = jax.jit(make_decode_step(cfg), donate_argnums=1)

  def generate(self, prompts: np.ndarray, n_new: int,
               src_embeds=None) -> np.ndarray:
    """prompts: (B, S) int32 (right-aligned, already padded)."""
    b, s = prompts.shape
    batch = {"tokens": jnp.asarray(prompts, jnp.int32)}
    enc_out = None
    if self.cfg.family == "encdec":
      from repro.models import encdec as encdec_mod
      enc_out = encdec_mod.encode(self.params, self.cfg,
                                  jnp.asarray(src_embeds))
      batch["src_embeds"] = jnp.asarray(src_embeds)
    last_logits, cache = self._prefill(self.params, batch)

    # seat the prefill cache into a max_len-sized ring cache
    full = zoo.init_cache(self.cfg, b, self.max_len)
    def seat(f, g):
      if f.shape == g.shape:
        return g.astype(f.dtype)
      pad = [(0, fs - gs) for fs, gs in zip(f.shape, g.shape)]
      return jnp.pad(g, pad).astype(f.dtype)
    cache = jax.tree.map(seat, full, cache)

    tok = jnp.argmax(last_logits, axis=-1).astype(jnp.int32)[:, None]
    out = [np.asarray(tok)]
    for _ in range(n_new - 1):
      step_batch = {"tokens": tok}
      if enc_out is not None:
        step_batch["enc_out"] = enc_out
      tok, cache = self._decode(self.params, cache, step_batch)
      out.append(np.asarray(tok))
    return np.concatenate(out, axis=1)


def main(argv=None):
  ap = argparse.ArgumentParser()
  ap.add_argument("--arch", required=True)
  ap.add_argument("--smoke", action="store_true")
  ap.add_argument("--batch", type=int, default=4)
  ap.add_argument("--prompt-len", type=int, default=32)
  ap.add_argument("--gen", type=int, default=16)
  ap.add_argument("--seed", type=int, default=0)
  args = ap.parse_args(argv)

  cfg = configs.get_config(args.arch, smoke=args.smoke)
  params = zoo.init(cfg, jax.random.PRNGKey(args.seed))
  eng = Engine(cfg, params, max_len=args.prompt_len + args.gen + 8)

  rng = np.random.default_rng(args.seed)
  prompts = rng.integers(0, cfg.vocab, (args.batch, args.prompt_len),
                         dtype=np.int32)
  src = None
  if cfg.family == "encdec":
    src = rng.standard_normal(
        (args.batch, cfg.src_len, cfg.d_model)).astype(np.float32)

  t0 = time.time()
  toks = eng.generate(prompts, args.gen, src_embeds=src)
  dt = time.time() - t0
  print(f"[serve] arch={cfg.name} generated {toks.shape} in {dt:.2f}s "
        f"({args.batch * args.gen / dt:.1f} tok/s)")
  print("[serve] sample:", toks[0][:16].tolist())
  return 0


if __name__ == "__main__":
  sys.exit(main())

import os
os.environ.setdefault("XLA_FLAGS", "--xla_force_host_platform_device_count=512")

# Pod-scale dry-run of the PAPER'S OWN flagship workload: all-pairs shortest
# paths as a distributed min-plus Leyzorek closure (SUMMA squaring) at the
# paper's Table-4 sizes, lowered + compiled against the production mesh.
#
#   PYTHONPATH=src python -m repro.launch.dryrun_apsp [--v 16384] [--mesh single]

import argparse  # noqa: E402
import json      # noqa: E402
import math      # noqa: E402
import sys       # noqa: E402

import jax       # noqa: E402
import jax.numpy as jnp  # noqa: E402
from jax.sharding import NamedSharding, PartitionSpec as P  # noqa: E402

from repro.core.distributed import summa_mmo  # noqa: E402
from repro.launch import mesh as mesh_mod     # noqa: E402
from repro.roofline import analysis, hlo_walk  # noqa: E402


def closure_step_fn(mesh, op="minplus"):
  def step(c):
    return summa_mmo(c, c, c, op=op, mesh=mesh)
  return step


def run(v: int, mesh_kind: str, op: str = "minplus", iters: int = None):
  multi = mesh_kind == "multi"
  mesh = mesh_mod.make_production_mesh(multi_pod=multi)
  chips = math.prod(mesh.devices.shape)
  spec = NamedSharding(
      mesh, P("data", "model") if not multi else P(("pod", "data"), "model"))
  # one Leyzorek squaring C ← C ⊕ (C ⊗ C); lg|V| of these solve APSP
  fn = closure_step_fn(mesh, op)
  with mesh:
    lowered = jax.jit(fn, in_shardings=(spec,), out_shardings=spec,
                      donate_argnums=0).lower(
        jax.ShapeDtypeStruct((v, v), jnp.float32))
    compiled = lowered.compile()
  walked = hlo_walk.module_cost(compiled.as_text())
  mem = compiled.memory_analysis()
  lg = math.ceil(math.log2(v))
  roof = analysis.Roofline(
      arch=f"apsp-|V|={v}", shape=f"closure_step({op})", mesh=mesh_kind,
      chips=chips, hlo_flops=walked.flops * chips,
      hlo_bytes=walked.bytes * chips, coll_bytes=walked.coll_bytes,
      coll_breakdown=dict(walked.coll_breakdown),
      model_flops=2.0 * v ** 3,   # useful ⊕⊗ work of one squaring
      peak_memory_per_dev=(mem.argument_size_in_bytes
                           + mem.output_size_in_bytes
                           + mem.temp_size_in_bytes) if mem else None)
  row = roof.row()

  # --- the SIMD² hardware story at pod scale (per squaring) ---------------
  # ⊕⊗ ops of one squaring = 2·V³ elementwise (add+min).  Three arms:
  #   xla-vector   — measured above: XLA materializes the ⊗ broadcast blocks
  #                  through HBM ⇒ memory-bound (the "no SIMD² unit" arm);
  #   pallas-vpu   — the kernels/semiring_mmo.py tiling: HBM traffic drops to
  #                  A,B panel reads (V³/bk ×2 bytes·f32) and compute runs at
  #                  VPU rate (peak/16) ⇒ compute-bound;
  #   simd2-unit   — the paper's proposal: same tiling, ⊕⊗ at MXU-class rate.
  from repro.roofline import hw
  ops = 2.0 * float(v) ** 3
  bk = 128.0
  t_vpu = ops / (chips * hw.PEAK_FLOPS_BF16 * hw.VPU_RATIO)
  t_unit = ops / (chips * hw.PEAK_FLOPS_BF16)
  tiled_bytes = 2.0 * (v ** 3 / bk) * 4.0          # A+B panel re-reads, f32
  t_mem_tiled = tiled_bytes / (chips * hw.HBM_BW)
  row.update({
      "status": "ok", "lg_v_steps": lg,
      "solve_bound_s": roof.t_bound * lg,
      "t_step_xla_vector": roof.t_bound,
      "t_step_pallas_vpu": max(t_vpu, t_mem_tiled),
      "t_step_simd2_unit": max(t_unit, t_mem_tiled),
      "speedup_pallas_vs_xla": roof.t_bound / max(t_vpu, t_mem_tiled),
      "speedup_simd2_vs_pallas": max(t_vpu, t_mem_tiled) / max(t_unit,
                                                               t_mem_tiled),
  })
  return row


def main(argv=None):
  ap = argparse.ArgumentParser()
  ap.add_argument("--v", type=int, default=16384)
  ap.add_argument("--mesh", default="single", choices=("single", "multi"))
  ap.add_argument("--op", default="minplus")
  ap.add_argument("--out", default=None)
  args = ap.parse_args(argv)
  row = run(args.v, args.mesh, args.op)
  print(json.dumps(row, default=float))
  if args.out:
    os.makedirs(args.out, exist_ok=True)
    with open(os.path.join(args.out,
                           f"apsp_{args.v}_{args.mesh}.json"), "w") as f:
      json.dump(row, f, indent=1, default=float)
  return 0


if __name__ == "__main__":
  sys.exit(main())

"""Elasticity & straggler mitigation — the control-plane story at 1000+
nodes, exercised in simulation (tests/test_elastic.py).

Mechanisms (all host-level; the data-plane stays pure SPMD):

  * **Heartbeats + failure detection** — every host ticks a coordinator;
    a missed deadline marks the host suspect, two mark it dead.
  * **Checkpoint/restart re-meshing** — on membership change, the job
    restarts from LATEST with a new mesh shape chosen by ``plan_remesh``
    (largest (data × model) grid that the surviving hosts support with the
    model axis preserved — TP topology must stay intact, DP shrinks).
    Because the data pipeline is step-indexed and shard assignments are
    derived from (host_id, topology), a resize replays no data and skips
    none (see data/pipeline.py).
  * **Straggler mitigation** — per-step host durations feed an EWMA; hosts
    slower than ``threshold ×`` the fleet median for ``patience``
    consecutive steps are reported for eviction (at pod scale the scheduler
    replaces the VM; here the policy object is unit-tested against traces).
"""
from __future__ import annotations

import dataclasses
import time
from collections import defaultdict
from typing import Optional


@dataclasses.dataclass
class HostState:
  last_beat: float
  suspect: bool = False
  dead: bool = False
  ewma_ms: Optional[float] = None
  slow_streak: int = 0


class Coordinator:
  """Failure detector + straggler policy over host heartbeats."""

  def __init__(self, hosts, *, deadline_s: float = 10.0,
               straggler_threshold: float = 1.5, patience: int = 5,
               ewma_alpha: float = 0.2, clock=time.monotonic):
    self.clock = clock
    self.deadline_s = deadline_s
    self.threshold = straggler_threshold
    self.patience = patience
    self.alpha = ewma_alpha
    now = clock()
    self.hosts = {h: HostState(last_beat=now) for h in hosts}

  # -- failure detection -----------------------------------------------------
  def beat(self, host, step_ms: Optional[float] = None):
    st = self.hosts[host]
    st.last_beat = self.clock()
    st.suspect = st.dead = False
    if step_ms is not None:
      st.ewma_ms = (step_ms if st.ewma_ms is None
                    else self.alpha * step_ms + (1 - self.alpha) * st.ewma_ms)

  def sweep(self):
    """Advance failure detection; returns newly dead hosts."""
    now = self.clock()
    died = []
    for h, st in self.hosts.items():
      if st.dead:
        continue
      late = now - st.last_beat
      if late > 2 * self.deadline_s:
        st.dead = True
        died.append(h)
      elif late > self.deadline_s:
        st.suspect = True
    return died

  def alive(self):
    return [h for h, st in self.hosts.items() if not st.dead]

  # -- straggler policy --------------------------------------------------------
  def stragglers(self):
    vals = sorted(st.ewma_ms for st in self.hosts.values()
                  if st.ewma_ms is not None and not st.dead)
    if not vals:
      return []
    median = vals[len(vals) // 2]
    out = []
    for h, st in self.hosts.items():
      if st.dead or st.ewma_ms is None:
        continue
      if st.ewma_ms > self.threshold * median:
        st.slow_streak += 1
        if st.slow_streak >= self.patience:
          out.append(h)
      else:
        st.slow_streak = 0
    return out


def plan_remesh(n_hosts_alive: int, chips_per_host: int, model: int = 16):
  """Largest (data, model) mesh on the survivors with the TP axis intact.

  Returns (data, model) or None if even one TP group no longer fits."""
  chips = n_hosts_alive * chips_per_host
  if chips < model:
    return None
  data = chips // model
  # data must keep the global batch divisible; round down to a power of two
  p = 1
  while p * 2 <= data:
    p *= 2
  return (p, model)

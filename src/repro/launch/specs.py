"""ShapeDtypeStruct stand-ins + PartitionSpecs for every dry-run cell.

``input_specs(cfg, shape, par)`` returns (shapes, shardings) pytrees for the
step function the cell lowers — weak-type-correct, shardable, and never
allocating (the shannon/kernels pattern).
"""
from __future__ import annotations

import functools
from typing import Optional

import jax
import jax.numpy as jnp
from jax.sharding import PartitionSpec as P

from repro.configs import Shape
from repro.models import common as cm
from repro.models import zoo
from repro.train import optimizer as opt_mod

Sds = jax.ShapeDtypeStruct


def _sds(tree):
  return jax.tree.map(lambda x: Sds(x.shape, x.dtype), tree)


# ---------------------------------------------------------------------------
# model / optimizer state
# ---------------------------------------------------------------------------


def param_shapes(cfg: cm.ModelConfig):
  return _sds(jax.eval_shape(
      functools.partial(zoo.init, cfg), jax.random.PRNGKey(0)))


def param_specs(cfg: cm.ModelConfig, par: cm.Parallelism):
  return cm.specs_like(param_shapes(cfg), cfg, par)


def train_state_shapes(cfg: cm.ModelConfig):
  p = param_shapes(cfg)
  opt = {
      "m": p,
      "v": p,
      "step": Sds((), jnp.int32),
  }
  return (p, opt)


def train_state_specs(cfg: cm.ModelConfig, par: cm.Parallelism):
  ps = param_specs(cfg, par)
  return (ps, {"m": ps, "v": ps, "step": P()})


# ---------------------------------------------------------------------------
# batches
# ---------------------------------------------------------------------------


def batch_shapes(cfg: cm.ModelConfig, shape: Shape):
  b = shape.global_batch
  s = 1 if shape.kind == "decode" else shape.seq_len
  out = {"tokens": Sds((b, s), jnp.int32)}
  if shape.kind == "train":
    out["labels"] = Sds((b, s), jnp.int32)
  if cfg.family == "encdec":
    if shape.kind == "decode":
      out["enc_out"] = Sds((b, cfg.src_len, cfg.d_model), cfg.dtype)
    else:
      out["src_embeds"] = Sds((b, cfg.src_len, cfg.d_model), cfg.dtype)
  return out


def batch_specs(cfg: cm.ModelConfig, shape: Shape, par: cm.Parallelism):
  dp = par.dp_for(shape.global_batch)
  out = {"tokens": P(dp, None)}
  if shape.kind == "train":
    out["labels"] = P(dp, None)
  if cfg.family == "encdec":
    if shape.kind == "decode":
      out["enc_out"] = P(dp, None, None)
    else:
      out["src_embeds"] = P(dp, None, None)
  return out


# ---------------------------------------------------------------------------
# KV / state caches
# ---------------------------------------------------------------------------


def cache_max_len(cfg: cm.ModelConfig, shape: Shape) -> int:
  """SWA archs decode long contexts with a window-sized ring buffer."""
  if cfg.window is not None:
    return min(shape.seq_len, cfg.window)
  return shape.seq_len


def cache_shapes(cfg: cm.ModelConfig, shape: Shape):
  return _sds(jax.eval_shape(
      functools.partial(zoo.init_cache, cfg, shape.global_batch,
                        cache_max_len(cfg, shape))))


def cache_specs(cfg: cm.ModelConfig, par: cm.Parallelism, shape: Shape, *,
                seq_sharded: Optional[bool] = None):
  """Specs matching the init_cache pytree.  ``seq_sharded`` (decode default)
  puts the cache sequence axis on the model axis — sequence-parallel decode;
  SSM/conv states put their head/channel axis there instead."""
  dp, tp = par.dp_for(shape.global_batch), par.tp
  seq_sharded = par.seq_shard_decode if seq_sharded is None else seq_sharded
  kv_seq = tp if seq_sharded else None

  def walk(prefix, tree):
    out = {}
    for k, v in tree.items():
      if isinstance(v, dict):
        out[k] = walk(f"{prefix}/{k}", v)
        continue
      if k in ("k", "v"):
        # (L|n_apps, B, S, KV, hd).  When the batch can't shard (B=1
        # long-context cells) put the idle data axes on the KV-head dim
        # instead (divisibility permitting) — 2-D cache sharding.
        kv_heads_dp = None
        if dp is None and cfg.n_kv_heads % par.dp_size == 0:
          kv_heads_dp = par.dp
        out[k] = P(None, dp, kv_seq, kv_heads_dp, None)
      elif k == "ssm":
        # (L, B, H, N, Pdim) — heads on the model axis
        out[k] = P(None, dp, tp, None, None)
      elif k in ("conv", "bc_conv"):
        # (L, B, K-1, C) — channels on the model axis (conv is depthwise);
        # bc channels are small → replicated
        out[k] = P(None, dp, None, tp if k == "conv" else None)
      elif k == "len":
        out[k] = P()
      else:
        raise KeyError(f"unknown cache leaf {prefix}/{k}")
    return out

  shp = jax.eval_shape(functools.partial(zoo.init_cache, cfg, 8, 128))
  return walk("", shp)


def logits_spec(cfg: cm.ModelConfig, par: cm.Parallelism):
  return P(par.dp, None, par.tp)

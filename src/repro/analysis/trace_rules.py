"""Trace-safety rules: host/trace boundary hygiene + cache-key coverage.

``trace-safety`` analyzes every jit-reachable function — functions
decorated with ``jax.jit`` (bare or via ``functools.partial`` with
``static_argnames``), kernels handed to ``pl.pallas_call``, and
same-module functions that receive traced values from one of those roots
(one-module call-graph propagation) — and flags the two classic
trace-time bugs:

  * **Python control flow on a traced value** — ``if``/``while``/``for``/
    ``assert`` over an abstract tracer raises at trace time at best and
    silently specializes at worst.  Branching on *static* values is the
    whole point of ``static_argnames``, so the pass tracks which names are
    statically known: static parameters, literals, and shape/dtype
    extractions (``x.shape``, ``x.ndim``, ``x.dtype``, ``x.size``,
    ``len(x)`` are static under tracing even on traced ``x``), propagated
    through local assignments.  ``is None`` tests are always host-static.
  * **host coercions** — ``float()`` / ``int()`` / ``bool()`` / ``.item()``
    / ``.tolist()`` / ``np.*`` on a traced value forces a device sync
    (or a concretization error) inside the traced region.

``cache-key-coverage`` is the retrace-bug gate for serve_mmo/engine.py:
every knob fed to ``batching.make_batch_fn`` (the function the executable
cache compiles) must either appear in the ``_exec_key`` tuple or be one of
the engine's declared immutable attributes (set in ``__init__`` and never
reassigned — which the rule also verifies).  A knob that varies without
being keyed means two different programs share one cache slot; a knob in
neither set is exactly the bug class PRs 2–7 had to hand-audit.
"""
from __future__ import annotations

import ast
from typing import Optional

from repro.analysis.core import Context, Finding, rule

__all__ = ["jit_roots", "analyze_function"]

_STATIC_ATTRS = ("shape", "ndim", "dtype", "size")
_STATIC_CALLS = ("len", "isinstance", "range", "min", "max", "int", "tuple",
                 "list", "sorted", "enumerate", "zip", "abs", "type")
_COERCIONS = ("float", "bool")
_HOST_METHODS = ("item", "tolist")


# ---------------------------------------------------------------------------
# root discovery
# ---------------------------------------------------------------------------


def _is_jax_jit(node) -> bool:
  if isinstance(node, ast.Attribute) and node.attr == "jit":
    return True
  return isinstance(node, ast.Name) and node.id == "jit"


def _static_argnames(call: ast.Call) -> set:
  for kw in call.keywords:
    if kw.arg != "static_argnames":
      continue
    v = kw.value
    if isinstance(v, ast.Constant) and isinstance(v.value, str):
      return {v.value}
    if isinstance(v, (ast.Tuple, ast.List)):
      return {e.value for e in v.elts
              if isinstance(e, ast.Constant) and isinstance(e.value, str)}
  return set()


def jit_roots(tree) -> list:
  """(FunctionDef, static-param-name set) for every jit-decorated function
  and every kernel passed positionally to ``pl.pallas_call``."""
  roots = []
  for node in ast.walk(tree):
    if isinstance(node, (ast.FunctionDef, ast.AsyncFunctionDef)):
      for deco in node.decorator_list:
        if _is_jax_jit(deco):
          roots.append((node, set()))
        elif isinstance(deco, ast.Call):
          if _is_jax_jit(deco.func):
            roots.append((node, set()))
          elif (isinstance(deco.func, (ast.Name, ast.Attribute))
                and (deco.func.id if isinstance(deco.func, ast.Name)
                     else deco.func.attr) == "partial"
                and deco.args and _is_jax_jit(deco.args[0])):
            roots.append((node, _static_argnames(deco)))
  # kernels: pl.pallas_call(kernel_name, ...) — resolve the Name to a
  # same-scope FunctionDef; its Ref params are traced
  defs = {n.name: n for n in ast.walk(tree)
          if isinstance(n, (ast.FunctionDef, ast.AsyncFunctionDef))}
  rooted = {fn.name for fn, _ in roots}
  for node in ast.walk(tree):
    if (isinstance(node, ast.Call) and isinstance(node.func, ast.Attribute)
        and node.func.attr == "pallas_call" and node.args
        and isinstance(node.args[0], ast.Name)):
      fn = defs.get(node.args[0].id)
      if fn is not None and fn.name not in rooted:
        rooted.add(fn.name)
        roots.append((fn, set()))
  return roots


def _param_names(fn) -> list:
  a = fn.args
  return [p.arg for p in (*a.posonlyargs, *a.args, *a.kwonlyargs)]


# ---------------------------------------------------------------------------
# per-function analysis
# ---------------------------------------------------------------------------


def analyze_function(fn, traced_params: set, *, path: str) -> tuple:
  """(findings, calls) — ``calls`` maps callee name → list of per-call
  arg-traced tuples (positional) for call-graph propagation."""
  findings = []
  calls: dict = {}
  traced = set(traced_params)

  def is_traced(node) -> bool:
    if node is None:
      return False
    if isinstance(node, ast.Name):
      return node.id in traced
    if isinstance(node, ast.Attribute):
      if node.attr in _STATIC_ATTRS:
        return False  # shape/dtype extraction is static under tracing
      return is_traced(node.value)
    if isinstance(node, ast.Compare):
      if all(isinstance(op, (ast.Is, ast.IsNot)) for op in node.ops):
        return False  # `x is None` tests the Python object, not the value
      return any(is_traced(c) for c in (node.left, *node.comparators))
    if isinstance(node, ast.Call):
      fname = _call_name(node)
      if fname in _STATIC_CALLS:
        return False  # len(x)/range(...) etc. produce host values
      # method calls propagate through the receiver too: `v.any()` is
      # traced when `v` is, even with no arguments
      recv = (is_traced(node.func.value)
              if isinstance(node.func, ast.Attribute) else False)
      return (recv or any(is_traced(a) for a in node.args)
              or any(is_traced(kw.value) for kw in node.keywords))
    return any(is_traced(c) for c in ast.iter_child_nodes(node))

  def _call_name(call: ast.Call) -> Optional[str]:
    f = call.func
    if isinstance(f, ast.Name):
      return f.id
    if isinstance(f, ast.Attribute):
      return f.attr
    return None

  def bind(target, value_traced: bool):
    for name in _target_names(target):
      if value_traced:
        traced.add(name)
      else:
        traced.discard(name)

  def _target_names(target):
    if isinstance(target, ast.Name):
      yield target.id
    elif isinstance(target, (ast.Tuple, ast.List)):
      for e in target.elts:
        yield from _target_names(e)

  def flag(node, msg):
    findings.append(Finding(rule="trace-safety", path=path,
                            line=node.lineno, message=msg))

  def record_call(node: ast.Call):
    f = node.func
    if isinstance(f, ast.Name):
      calls.setdefault(f.id, []).append(
          tuple(is_traced(a) for a in node.args))

  def scan_expr(node):
    """Flag host coercions anywhere inside an expression."""
    for sub in ast.walk(node):
      if not isinstance(sub, ast.Call):
        continue
      record_call(sub)
      fname = _call_name(sub)
      args_traced = (any(is_traced(a) for a in sub.args)
                     or any(is_traced(kw.value) for kw in sub.keywords))
      if fname in _COERCIONS and args_traced:
        flag(sub, f"`{fname}()` on a traced value inside a jit-reachable "
                  f"function forces host concretization "
                  f"(`{fn.name}`)")
      elif (fname in _HOST_METHODS and isinstance(sub.func, ast.Attribute)
            and is_traced(sub.func.value)):
        flag(sub, f"`.{fname}()` on a traced value inside a jit-reachable "
                  f"function forces a device sync (`{fn.name}`)")
      elif (isinstance(sub.func, ast.Attribute)
            and isinstance(sub.func.value, ast.Name)
            and sub.func.value.id in ("np", "numpy") and args_traced):
        flag(sub, f"`np.{sub.func.attr}` on a traced value inside a "
                  f"jit-reachable function runs on the host "
                  f"(`{fn.name}`; use jnp)")

  def scan_stmt(node):
    if isinstance(node, (ast.FunctionDef, ast.AsyncFunctionDef)):
      # nested defs (lax.scan/while_loop bodies): params are traced values
      for p in _param_names(node):
        traced.add(p)
      for s in node.body:
        scan_stmt(s)
      return
    if isinstance(node, ast.Assign):
      scan_expr(node.value)
      vt = is_traced(node.value)
      for t in node.targets:
        bind(t, vt)
      return
    if isinstance(node, (ast.AugAssign, ast.AnnAssign)):
      if node.value is not None:
        scan_expr(node.value)
        bind(node.target, is_traced(node.value)
             or (isinstance(node, ast.AugAssign) and is_traced(node.target)))
      return
    if isinstance(node, (ast.If, ast.While)):
      scan_expr(node.test)
      if is_traced(node.test):
        kind = "if" if isinstance(node, ast.If) else "while"
        flag(node.test,
             f"Python `{kind}` on a traced value in jit-reachable "
             f"`{fn.name}` — use lax.cond/select (or make the operand "
             f"static)")
      for s in (*node.body, *node.orelse):
        scan_stmt(s)
      return
    if isinstance(node, ast.For):
      scan_expr(node.iter)
      if is_traced(node.iter):
        flag(node.iter,
             f"Python `for` over a traced value in jit-reachable "
             f"`{fn.name}` — use lax.fori_loop/scan")
      bind(node.target, is_traced(node.iter))
      for s in (*node.body, *node.orelse):
        scan_stmt(s)
      return
    if isinstance(node, ast.Assert):
      scan_expr(node.test)
      if is_traced(node.test):
        flag(node.test,
             f"`assert` on a traced value in jit-reachable `{fn.name}` — "
             f"asserts concretize; use checkify or move to the host")
      return
    for sub in ast.iter_child_nodes(node):
      if isinstance(sub, ast.expr):
        scan_expr(sub)
      elif isinstance(sub, ast.stmt):
        scan_stmt(sub)

  for stmt in fn.body:
    scan_stmt(stmt)
  return findings, calls


@rule("trace-safety", family="trace")
def _rule_trace_safety(ctx: Context) -> list:
  """No Python control flow or host coercions on traced values."""
  out = []
  for mod in ctx.modules:
    roots = jit_roots(mod.tree)
    if not roots:
      continue
    defs = {n.name: n for n in ast.walk(mod.tree)
            if isinstance(n, (ast.FunctionDef, ast.AsyncFunctionDef))}
    # worklist: function name → set of traced param names (unioned over
    # call sites); seeded by the jit roots, propagated one module deep
    traced_by_fn: dict = {}
    for fn, static in roots:
      traced_by_fn[fn.name] = {p for p in _param_names(fn)
                               if p not in static and p != "self"}
    findings_by_fn: dict = {}
    for _ in range(10):  # fixpoint over the same-module call graph
      changed = False
      for name, tp in sorted(traced_by_fn.items()):
        fn = defs.get(name)
        if fn is None:
          continue
        findings, calls = analyze_function(fn, tp, path=mod.relpath)
        findings_by_fn[name] = findings
        for callee, sites in calls.items():
          target = defs.get(callee)
          if target is None or callee in (r.name for r, _ in roots):
            continue
          params = [p for p in _param_names(target) if p != "self"]
          newly = {params[i]
                   for site in sites for i, t in enumerate(site)
                   if t and i < len(params)}
          if not newly:
            continue
          cur = traced_by_fn.setdefault(callee, set())
          if not newly <= cur:
            cur |= newly
            changed = True
      if not changed:
        break
    seen = set()
    for findings in findings_by_fn.values():
      for f in findings:
        key = (f.line, f.message)
        if key not in seen:
          seen.add(key)
          out.append(f)
  return out


# ---------------------------------------------------------------------------
# cache-key coverage (serve_mmo/engine.py)
# ---------------------------------------------------------------------------

# engine attributes allowed to feed make_batch_fn WITHOUT being in the
# executable-cache key: immutable after __init__ (verified below).  ``mesh``
# is covered by ``_mesh_sig`` inside the key; ``interpret`` is a
# process-lifetime debug switch.
_ENGINE_CONSTANT_ATTRS = ("interpret", "mesh", "_mesh_sig")


def _names_and_self_attrs(node):
  names, attrs = set(), set()
  for sub in ast.walk(node):
    if isinstance(sub, ast.Attribute) and isinstance(sub.value, ast.Name) \
        and sub.value.id == "self":
      attrs.add(sub.attr)
    elif isinstance(sub, ast.Name) and sub.id != "self":
      names.add(sub.id)
  return names, attrs


@rule("cache-key-coverage", family="trace")
def _rule_cache_key_coverage(ctx: Context) -> list:
  """Every make_batch_fn knob must be in _exec_key or engine-constant."""
  mod = ctx.module("serve_mmo/engine.py")
  if mod is None:
    return []
  out = []
  engine = next((n for n in ast.walk(mod.tree)
                 if isinstance(n, ast.ClassDef) and n.name == "MMOEngine"),
                None)
  if engine is None:
    return out
  exec_key = next((n for n in engine.body
                   if isinstance(n, ast.FunctionDef)
                   and n.name == "_exec_key"), None)
  if exec_key is None:
    return [Finding(rule="cache-key-coverage", path=mod.relpath,
                    line=engine.lineno,
                    message="MMOEngine has no _exec_key method — the "
                            "executable cache has no keying discipline to "
                            "check")]
  key_names: set = set()
  key_attrs: set = set()
  for node in ast.walk(exec_key):
    if isinstance(node, ast.Return) and node.value is not None:
      n, a = _names_and_self_attrs(node.value)
      key_names |= n
      key_attrs |= a

  # sub-check: the declared engine constants must really be constant —
  # assigned in __init__ only
  for item in engine.body:
    if not isinstance(item, ast.FunctionDef) or item.name == "__init__":
      continue
    for node in ast.walk(item):
      targets = []
      if isinstance(node, ast.Assign):
        targets = node.targets
      elif isinstance(node, (ast.AugAssign, ast.AnnAssign)):
        targets = [node.target]
      for t in targets:
        if isinstance(t, ast.Attribute) and isinstance(t.value, ast.Name) \
            and t.value.id == "self" and t.attr in _ENGINE_CONSTANT_ATTRS:
          out.append(Finding(
              rule="cache-key-coverage", path=mod.relpath, line=node.lineno,
              message=f"MMOEngine.{item.name} reassigns self.{t.attr}, "
                      f"which cache-key coverage declares immutable — "
                      f"either stop reassigning it or add it to _exec_key"))

  # every make_batch_fn call: each arg's free names must come from the key
  # (lambda defaults like ``lambda s=schedule:`` are resolved through)
  lambda_defaults: dict = {}
  for node in ast.walk(engine):
    if isinstance(node, ast.Lambda):
      args = node.args
      pos = (*args.posonlyargs, *args.args)
      for p, d in zip(pos[len(pos) - len(args.defaults):], args.defaults):
        if isinstance(d, ast.Name):
          lambda_defaults[p.arg] = d.id
  for node in ast.walk(engine):
    if not (isinstance(node, ast.Call)
            and isinstance(node.func, (ast.Name, ast.Attribute))
            and (node.func.id if isinstance(node.func, ast.Name)
                 else node.func.attr) == "make_batch_fn"):
      continue
    for value in (*node.args, *(kw.value for kw in node.keywords)):
      names, attrs = _names_and_self_attrs(value)
      names = {lambda_defaults.get(n, n) for n in names}
      loose_names = names - key_names
      loose_attrs = attrs - key_attrs - set(_ENGINE_CONSTANT_ATTRS)
      for n in sorted(loose_names):
        out.append(Finding(
            rule="cache-key-coverage", path=mod.relpath, line=value.lineno,
            message=f"make_batch_fn consumes `{n}`, which is not in the "
                    f"_exec_key tuple — two programs differing in `{n}` "
                    f"would share one executable-cache slot"))
      for a in sorted(loose_attrs):
        out.append(Finding(
            rule="cache-key-coverage", path=mod.relpath, line=value.lineno,
            message=f"make_batch_fn consumes `self.{a}`, which is neither "
                    f"in _exec_key nor a declared engine constant — "
                    f"retrace/stale-program hazard"))
  return out

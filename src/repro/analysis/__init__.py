"""repro.analysis — dependency-free static analysis for the repro engine.

Three rule families (see DESIGN.md §Static analysis):

  * ``semiring`` — literal pad/identity tables cross-checked against the
    live ``core.semiring`` registry, plus numeric law checking over
    adversarial floats (repro.analysis.laws);
  * ``locks``    — a declared GUARDED_BY table for serve_mmo mutable state
    enforced by an AST lock-domination pass (repro.analysis.lock_rules);
  * ``trace``    — host/trace boundary hygiene for jit/pallas-reachable
    functions and executable-cache key coverage
    (repro.analysis.trace_rules).

Run it::

    python -m repro.analysis                # human output, exit 1 on new
    python -m repro.analysis --json         # machine output (CI artifact)
    python -m repro.analysis --rules locks  # one family (or rule id)

Findings carry a line-independent fingerprint; known-accepted ones live in
``baseline.json`` next to this package, and one-off exceptions are
suppressed in source with ``# repro: ignore[rule-id]``.
"""
from repro.analysis.core import (FAMILIES, Context, Finding, Module, Report,
                                 all_rules, format_human, format_json,
                                 load_baseline, load_context, rule, run,
                                 save_baseline, select_rules)

# importing the rule modules registers their rules with the registry
from repro.analysis import laws as _laws                      # noqa: F401
from repro.analysis import lock_rules as _lock_rules          # noqa: F401
from repro.analysis import semiring_rules as _semiring_rules  # noqa: F401
from repro.analysis import trace_rules as _trace_rules        # noqa: F401

__all__ = [
    "FAMILIES", "Context", "Finding", "Module", "Report", "all_rules",
    "format_human", "format_json", "load_baseline", "load_context", "rule",
    "run", "save_baseline", "select_rules",
]

"""CLI for repro.analysis — ``python -m repro.analysis``.

Exit status: 0 when no *new* findings (suppressed and baselined findings
do not fail the run), 1 otherwise, 2 on usage errors.
"""
from __future__ import annotations

import argparse
import sys
from pathlib import Path

from repro.analysis import (all_rules, format_human, format_json,
                            load_baseline, run, save_baseline)

_PKG_DIR = Path(__file__).resolve().parent
DEFAULT_ROOT = _PKG_DIR.parent          # src/repro
DEFAULT_BASELINE = _PKG_DIR / "baseline.json"


def main(argv=None) -> int:
  parser = argparse.ArgumentParser(
      prog="python -m repro.analysis",
      description="Static analysis for the repro engine: semiring "
                  "consistency, lock discipline, trace safety.")
  parser.add_argument("--root", type=Path, default=DEFAULT_ROOT,
                      help=f"tree to analyze (default: {DEFAULT_ROOT})")
  parser.add_argument("--rules", default=None,
                      help="comma-separated rule ids and/or families "
                           "(semiring, locks, trace); default: all")
  parser.add_argument("--baseline", type=Path, default=DEFAULT_BASELINE,
                      help="grandfathered-findings file (default: "
                           "baseline.json next to the package)")
  parser.add_argument("--no-baseline", action="store_true",
                      help="ignore the baseline: report every finding")
  parser.add_argument("--update-baseline", action="store_true",
                      help="rewrite the baseline to grandfather every "
                           "current finding, then exit 0")
  parser.add_argument("--json", action="store_true",
                      help="machine-readable output (CI artifact)")
  parser.add_argument("--list-rules", action="store_true",
                      help="list registered rules and exit")
  args = parser.parse_args(argv)

  if args.list_rules:
    for r in sorted(all_rules().values(), key=lambda r: (r.family, r.name)):
      print(f"{r.name:28s} [{r.family}]  {r.doc}")
    return 0

  baseline = set() if args.no_baseline else load_baseline(args.baseline)
  try:
    report = run(args.root, rules=args.rules, baseline=baseline)
  except ValueError as e:          # bad --rules spec
    parser.error(str(e))

  if args.update_baseline:
    save_baseline(args.baseline, report.findings + report.baselined)
    print(f"baseline updated: {args.baseline} now grandfathers "
          f"{len(report.findings) + len(report.baselined)} finding(s)")
    return 0

  print(format_json(report) if args.json else format_human(report))
  return 0 if report.ok else 1


if __name__ == "__main__":
  sys.exit(main())

"""REPRO_SANITIZE=1 — opt-in hardened mode for tests and benchmarks.

When the environment variable ``REPRO_SANITIZE`` is a truthy value
(``1``/``true``/``yes``), entry points that call
:func:`maybe_enable_sanitize` get two extra safety nets:

  * ``jax_debug_nans`` — JAX re-runs any primitive that produced a NaN
    un-jitted and raises at the producing op, turning silent poison (a NaN
    that an unfortunate ``max`` later *hides*) into a loud failure at the
    source;
  * an analyzer pre-flight — ``repro.analysis`` runs over ``src/repro``
    before any workload, so a lock-discipline or pad-table regression
    aborts the run before it can produce misleading numbers.

It is opt-in (default off) because debug_nans forcibly deoptimizes and
some semirings legitimately *route around* NaN (the law checker covers
NaN propagation separately); the tier-1 suite must not change behavior
under default settings.
"""
from __future__ import annotations

import os

_TRUTHY = ("1", "true", "yes", "on")


def sanitize_requested(environ=None) -> bool:
  env = os.environ if environ is None else environ
  return str(env.get("REPRO_SANITIZE", "")).strip().lower() in _TRUTHY


def maybe_enable_sanitize(*, preflight: bool = True) -> bool:
  """Enable sanitize mode if requested; returns whether it is active.

  Raises RuntimeError when the analyzer pre-flight finds new findings —
  a dirty tree must not run workloads in sanitize mode.
  """
  if not sanitize_requested():
    return False
  import jax
  jax.config.update("jax_debug_nans", True)
  if preflight:
    from repro import analysis
    from repro.analysis.__main__ import DEFAULT_BASELINE, DEFAULT_ROOT
    report = analysis.run(DEFAULT_ROOT,
                          baseline=analysis.load_baseline(DEFAULT_BASELINE))
    if not report.ok:
      raise RuntimeError(
          "REPRO_SANITIZE pre-flight failed — repro.analysis reports "
          f"{len(report.findings)} new finding(s):\n"
          + "\n".join(str(f) for f in report.findings))
  return True

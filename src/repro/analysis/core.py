"""Static-analysis framework for the SIMD² repo (stdlib ``ast`` only).

Three pieces, mirroring what a production linter needs and nothing more:

  * a **rule registry** — rules are functions ``(Context) -> [Finding]``
    registered under a stable rule id and a family name (``semiring`` /
    ``locks`` / ``trace``), so the CLI can run one family or one rule;
  * **suppressions** — ``# repro: ignore[rule-id]`` (or a bare
    ``# repro: ignore``) on the flagged line or the line above silences a
    finding at that site, visibly and greppably;
  * a **baseline** — a checked-in JSON file of grandfathered finding
    fingerprints.  Fingerprints hash (rule, path, message) and deliberately
    exclude the line number, so unrelated edits above a baselined site do
    not resurrect it.  ``python -m repro.analysis`` exits nonzero only on
    findings that are neither suppressed nor baselined: the tree must stay
    at zero *new* findings while grandfathered ones are paid down.

Rules may run numeric checks against the live registries (the semiring law
checker does) — "static" here means *no code under test executes*, not
"no arithmetic".
"""
from __future__ import annotations

import ast
import dataclasses
import hashlib
import json
import re
import time
from pathlib import Path
from typing import Callable, Optional

__all__ = ["Finding", "Module", "Context", "Report", "rule", "all_rules",
           "run", "load_context", "load_baseline", "save_baseline",
           "format_human", "format_json", "FAMILIES"]

FAMILIES = ("semiring", "locks", "trace")

_SUPPRESS_RE = re.compile(
    r"#\s*repro:\s*ignore(?:\[(?P<rules>[A-Za-z0-9_\-, ]+)\])?")


@dataclasses.dataclass(frozen=True)
class Finding:
  """One rule violation at one site.

  ``fingerprint`` identifies the finding for baseline matching: it hashes
  the rule id, the module path, and the message — NOT the line number, so
  baselined findings survive unrelated edits elsewhere in the file.  Rules
  therefore write messages that name the symbol, not positional context.
  """

  rule: str
  path: str
  line: int
  message: str

  @property
  def fingerprint(self) -> str:
    raw = f"{self.rule}|{self.path}|{self.message}".encode()
    return hashlib.sha256(raw).hexdigest()[:16]

  def to_json(self) -> dict:
    return {"rule": self.rule, "path": self.path, "line": self.line,
            "message": self.message, "fingerprint": self.fingerprint}

  def __str__(self) -> str:
    return f"{self.path}:{self.line}: [{self.rule}] {self.message}"


@dataclasses.dataclass
class Module:
  """One parsed source file: AST + per-line suppression table."""

  path: Path
  relpath: str           # posix path relative to the repo root (stable ids)
  source: str
  tree: ast.Module
  # line → None (suppress every rule) | frozenset of suppressed rule ids
  suppressions: dict

  def suppresses(self, rule_id: str, line: int) -> bool:
    """True when ``line`` (or the line above — comment-above style) carries
    a matching suppression comment."""
    for ln in (line, line - 1):
      entry = self.suppressions.get(ln, _MISSING)
      if entry is _MISSING:
        continue
      if entry is None or rule_id in entry:
        return True
    return False


_MISSING = object()


def _parse_suppressions(source: str) -> dict:
  table: dict = {}
  for i, text in enumerate(source.splitlines(), start=1):
    m = _SUPPRESS_RE.search(text)
    if not m:
      continue
    rules = m.group("rules")
    table[i] = (None if rules is None else
                frozenset(r.strip() for r in rules.split(",") if r.strip()))
  return table


@dataclasses.dataclass
class Context:
  """Everything a rule sees: the scanned tree plus parse results."""

  root: Path
  repo_root: Path
  modules: list

  def module(self, suffix: str) -> Optional[Module]:
    """The unique module whose relpath ends with ``suffix`` (posix), or
    None — rules targeting one file (engine.py) resolve it through this so
    they degrade to no-ops on fixture trees that lack the file."""
    suffix = suffix.lstrip("/")
    hits = [m for m in self.modules
            if m.relpath == suffix or m.relpath.endswith("/" + suffix)]
    return hits[0] if len(hits) == 1 else None


def _find_repo_root(root: Path) -> Path:
  for parent in (root, *root.parents):
    if (parent / "pyproject.toml").is_file():
      return parent
  return root


def load_context(root) -> Context:
  root = Path(root).resolve()
  repo_root = _find_repo_root(root)
  modules = []
  for path in sorted(root.rglob("*.py")):
    if "__pycache__" in path.parts:
      continue
    source = path.read_text(encoding="utf-8")
    try:
      tree = ast.parse(source, filename=str(path))
    except SyntaxError as e:
      raise SyntaxError(f"cannot analyze {path}: {e}") from e
    try:
      rel = path.relative_to(repo_root).as_posix()
    except ValueError:
      rel = path.name
    modules.append(Module(path=path, relpath=rel, source=source, tree=tree,
                          suppressions=_parse_suppressions(source)))
  return Context(root=root, repo_root=repo_root, modules=modules)


# ---------------------------------------------------------------------------
# rule registry
# ---------------------------------------------------------------------------


@dataclasses.dataclass(frozen=True)
class Rule:
  name: str
  family: str
  doc: str
  fn: Callable


_RULES: dict = {}


def rule(name: str, family: str):
  """Register a rule function ``(Context) -> list[Finding]``."""
  if family not in FAMILIES:
    raise ValueError(f"unknown rule family {family!r}; one of {FAMILIES}")

  def deco(fn):
    if name in _RULES:
      raise ValueError(f"duplicate rule id {name!r}")
    _RULES[name] = Rule(name=name, family=family,
                        doc=(fn.__doc__ or "").strip().splitlines()[0]
                        if fn.__doc__ else "", fn=fn)
    return fn

  return deco


def all_rules() -> dict:
  return dict(_RULES)


def select_rules(spec: Optional[str]) -> list:
  """Resolve a CLI ``--rules`` spec (comma-separated rule ids and/or family
  names) to Rule objects; None selects everything."""
  if not spec:
    return list(_RULES.values())
  out, seen = [], set()
  for token in (t.strip() for t in spec.split(",") if t.strip()):
    if token in FAMILIES:
      picked = [r for r in _RULES.values() if r.family == token]
    elif token in _RULES:
      picked = [_RULES[token]]
    else:
      raise ValueError(
          f"unknown rule or family {token!r}; rules: {sorted(_RULES)}; "
          f"families: {FAMILIES}")
    for r in picked:
      if r.name not in seen:
        seen.add(r.name)
        out.append(r)
  return out


# ---------------------------------------------------------------------------
# baseline
# ---------------------------------------------------------------------------

BASELINE_VERSION = 1


def load_baseline(path) -> set:
  """Fingerprints grandfathered by ``path`` (missing file = empty set)."""
  path = Path(path)
  if not path.is_file():
    return set()
  doc = json.loads(path.read_text(encoding="utf-8"))
  if doc.get("version") != BASELINE_VERSION:
    raise ValueError(f"baseline {path} has unsupported version "
                     f"{doc.get('version')!r}")
  return {f["fingerprint"] for f in doc.get("findings", [])}


def save_baseline(path, findings) -> None:
  """Write ``findings`` (new + currently-baselined) as the new baseline."""
  doc = {
      "version": BASELINE_VERSION,
      "findings": sorted(
          ({"fingerprint": f.fingerprint, "rule": f.rule, "path": f.path,
            "message": f.message} for f in findings),
          key=lambda d: (d["rule"], d["path"], d["message"])),
  }
  Path(path).write_text(json.dumps(doc, indent=2) + "\n", encoding="utf-8")


# ---------------------------------------------------------------------------
# runner
# ---------------------------------------------------------------------------


@dataclasses.dataclass
class Report:
  root: str
  rules_run: list
  findings: list       # new findings — these fail the build
  baselined: list      # grandfathered findings still present
  suppressed: int
  elapsed_s: float

  @property
  def ok(self) -> bool:
    return not self.findings


def run(root, *, rules: Optional[str] = None, baseline=None) -> Report:
  """Run ``rules`` (CLI spec or None = all) over the tree at ``root``.

  ``baseline`` is a fingerprint set (see ``load_baseline``) — matching
  findings are reported separately and do not fail the run.
  """
  t0 = time.perf_counter()
  ctx = load_context(root)
  selected = select_rules(rules) if isinstance(rules, (str, type(None))) \
      else list(rules)
  baseline = baseline or set()
  by_path = {m.relpath: m for m in ctx.modules}
  new, grandfathered, suppressed = [], [], 0
  for r in selected:
    for f in r.fn(ctx):
      mod = by_path.get(f.path)
      if mod is not None and mod.suppresses(f.rule, f.line):
        suppressed += 1
      elif f.fingerprint in baseline:
        grandfathered.append(f)
      else:
        new.append(f)
  key = lambda f: (f.path, f.line, f.rule, f.message)  # noqa: E731
  new.sort(key=key)
  grandfathered.sort(key=key)
  return Report(root=str(ctx.root), rules_run=[r.name for r in selected],
                findings=new, baselined=grandfathered,
                suppressed=suppressed,
                elapsed_s=time.perf_counter() - t0)


# ---------------------------------------------------------------------------
# output
# ---------------------------------------------------------------------------


def format_human(report: Report) -> str:
  lines = []
  for f in report.findings:
    lines.append(str(f))
  if report.baselined:
    lines.append(f"({len(report.baselined)} baselined finding(s) still "
                 f"present — pay them down, don't add more)")
  verdict = "OK" if report.ok else f"{len(report.findings)} new finding(s)"
  lines.append(
      f"repro.analysis: {verdict} — {len(report.rules_run)} rule(s) over "
      f"{report.root} in {report.elapsed_s:.2f}s "
      f"({report.suppressed} suppressed, {len(report.baselined)} baselined)")
  return "\n".join(lines)


def format_json(report: Report) -> str:
  return json.dumps({
      "root": report.root,
      "rules": report.rules_run,
      "ok": report.ok,
      "elapsed_s": round(report.elapsed_s, 3),
      "suppressed": report.suppressed,
      "findings": [f.to_json() for f in report.findings],
      "baselined": [f.to_json() for f in report.baselined],
  }, indent=2)

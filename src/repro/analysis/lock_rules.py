"""Lock-discipline rule: declared GUARDED_BY table + an AST domination pass.

``GUARDED_BY`` below *declares* which mutable attributes of each serve_mmo
class are protected by which locks.  It is declared, not inferred, on
purpose: inference from observed usage would bless today's bugs as the
spec (an attribute touched unlocked in two places would "infer" as
unguarded), while a declaration is reviewed once and then machine-enforced
forever — the same reason Clang's thread-safety analysis uses GUARDED_BY
annotations rather than guessing.

The pass proves every ``self.<attr>`` read/write of a guarded attribute is
*lexically dominated* by ``with self.<lock>:`` for one of the class's
declared locks, with two escapes:

  * methods whose name ends in ``_locked`` are caller-holds-lock helpers
    (the convention this PR introduces; the analyzer enforces that the
    convention is the ONLY way to defer locking);
  * ``__init__`` / ``__del__`` run before/after the object is shared.

Conditions constructed over the same lock count as the lock itself: the
engine's ``_work`` / ``_idle`` are ``threading.Condition(self._lock)``
aliases, so ``with self._work:`` acquires the engine lock.

Nested functions and lambdas do NOT inherit the enclosing ``with`` —
a closure created under the lock may run on another thread after the lock
is released (that is exactly how the executable-cache build lambda is
used), so they are analyzed under their own name's convention only.
"""
from __future__ import annotations

import ast
import dataclasses

from repro.analysis.core import Context, Finding, rule

__all__ = ["GUARDED_BY", "LockSpec", "check_class"]


@dataclasses.dataclass(frozen=True)
class LockSpec:
  locks: tuple      # attribute names whose ``with self.<lock>`` protects
  attrs: tuple      # guarded attribute names


# (module suffix, class name) → spec.  ``scheduler`` and ``admission`` are
# whole *objects* guarded by the engine lock (their classes are documented
# as not independently thread-safe), so every touch of the reference is
# checked, not just their internals.
GUARDED_BY = {
    ("serve_mmo/engine.py", "MMOEngine"): LockSpec(
        locks=("_lock", "_work", "_idle"),
        attrs=("_decisions", "_schedules", "_static_cost",
               "_fallback_arms_memo", "_records", "_batches", "_rejected",
               "_expired", "_next_id", "_pending", "_inflight", "_running",
               "_stopped", "scheduler", "admission",
               "_arenas", "_arena_failures")),
    ("serve_mmo/arena.py", "RequestArena"): LockSpec(
        locks=("_lock",),
        # device state handles (_c/_adj/_kv/_act/_it) are guarded too: admit
        # and tick swap them wholesale, and an unlocked read could pair a
        # pre-tick iterate with post-tick flags
        attrs=("_slots", "_free", "_admit_s", "_admitted", "_evicted",
               "_ticks", "_c", "_adj", "_kv", "_act", "_it")),
    ("serve_mmo/cache.py", "ExecutableCache"): LockSpec(
        locks=("_lock",), attrs=("_entries", "_misses")),
    ("serve_mmo/metrics.py", "ServeMetrics"): LockSpec(
        locks=("_lock",),
        attrs=("_counters", "_rejected_by_reason", "_batch_failures_by_kind",
               "_buckets")),
    ("serve_mmo/estimator.py", "ServiceEstimator"): LockSpec(
        locks=("_lock",), attrs=("_cells", "_iters")),
    ("serve_mmo/resilience.py", "ResilienceManager"): LockSpec(
        locks=("_lock",), attrs=("_breakers",)),
    ("serve_mmo/observability.py", "FlightRecorder"): LockSpec(
        locks=("_lock",), attrs=("_events", "_recorded")),
}

_EXEMPT_METHODS = ("__init__", "__del__")


def _is_self_attr(node, names) -> bool:
  return (isinstance(node, ast.Attribute)
          and isinstance(node.value, ast.Name) and node.value.id == "self"
          and node.attr in names)


def check_class(cls_node: ast.ClassDef, spec: LockSpec) -> list:
  """(line, attr, method) for every unprotected guarded-attribute access."""
  violations = []

  def scan(stmts, protected: bool, method: str):
    for stmt in stmts:
      scan_node(stmt, protected, method)

  def scan_node(node, protected: bool, method: str):
    if isinstance(node, (ast.FunctionDef, ast.AsyncFunctionDef)):
      # nested def: the closure may outlive the lock scope — only the
      # _locked convention (or being a fresh __init__) protects its body
      scan(node.body, node.name.endswith("_locked"), method)
      return
    if isinstance(node, ast.Lambda):
      scan_node(node.body, False, method)
      return
    if isinstance(node, ast.With):
      holds = protected or any(
          _is_self_attr(item.context_expr, spec.locks)
          for item in node.items)
      for item in node.items:
        scan_node(item.context_expr, protected, method)
      scan(node.body, holds, method)
      return
    if _is_self_attr(node, spec.attrs):
      if not protected:
        violations.append((node.lineno, node.attr, method))
      return
    for child in ast.iter_child_nodes(node):
      scan_node(child, protected, method)

  for item in cls_node.body:
    if not isinstance(item, (ast.FunctionDef, ast.AsyncFunctionDef)):
      continue
    protected = (item.name in _EXEMPT_METHODS
                 or item.name.endswith("_locked"))
    scan(item.body, protected, item.name)
  return violations


@rule("lock-discipline", family="locks")
def _rule_lock_discipline(ctx: Context) -> list:
  """Guarded serve_mmo attributes may only be touched under their lock."""
  out = []
  for (suffix, cls_name), spec in GUARDED_BY.items():
    mod = ctx.module(suffix)
    if mod is None:
      continue
    for node in ast.walk(mod.tree):
      if isinstance(node, ast.ClassDef) and node.name == cls_name:
        for line, attr, method in check_class(node, spec):
          out.append(Finding(
              rule="lock-discipline", path=mod.relpath, line=line,
              message=f"{cls_name}.{method} touches guarded attribute "
                      f"self.{attr} outside `with self.{spec.locks[0]}` "
                      f"(declared GUARDED_BY {list(spec.locks)}; use the "
                      f"lock or a *_locked helper)"))
  return out

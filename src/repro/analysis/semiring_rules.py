"""AST rules for semiring-consistency: literal tables vs the live registry.

The codebase's convention for per-ring constants is the *op-keyed dict* —
``{"minplus": ..., "maxmul": ..., ...}`` — in core/closure.py
(_SELF_VALUES / _MISSING_VALUES), core/semiring.py (_CONTRACTION_PADS),
and wherever the next subsystem grows one.  Three things can rot:

  * a new ring lands in the registry but a table is never extended
    (``semiring-table-coverage`` — every op-keyed dict must cover ALL_OPS
    exactly, no missing mnemonics, no unknown ones);
  * a pad pair stops satisfying ⊗(pa, pb) == ⊕-identity
    (``semiring-pad-consistency`` — any op-keyed dict of 2-tuples is
    treated as a pad table and re-verified numerically against the live
    registry operators);
  * someone hardcodes an identity instead of reading the registry
    (``semiring-hardcoded-identity`` — ±inf literals in the modules that
    implement contraction/padding must come from an op-keyed table or the
    registry; a bare ``jnp.inf`` accumulator init is exactly the bug class
    that silently corrupts one ring and not the other eight).

The numeric side of the family (law checking over adversarial floats)
lives in repro.analysis.laws.
"""
from __future__ import annotations

import ast
from typing import Optional

import numpy as np

from repro.analysis.core import Context, Finding, rule
from repro.core import semiring as sr_mod

__all__ = ["const_float", "op_keyed_dicts"]

# modules whose ±inf literals must be registry-sourced — the contraction /
# padding implementations plus the sparse seed path.  core/semiring.py is
# exempt: it IS the registry, its literals are the source of truth.
_IDENTITY_SCOPED = ("core/closure.py", "core/mmo.py", "core/sparse.py",
                    "kernels/semiring_mmo.py", "serve_mmo/batching.py")

# a dict literal is "op-keyed" when it has at least this many registry
# mnemonics as keys (guards against flagging unrelated small dicts)
_MIN_OP_KEYS = 5


def const_float(node) -> Optional[float]:
  """Evaluate the constant-float spellings the repo uses, else None:
  literals, -x, float("inf"), float(np.inf), np.inf / math.inf / jnp.inf."""
  if isinstance(node, ast.Constant) and isinstance(node.value, (int, float,
                                                                bool)):
    return float(node.value)
  if isinstance(node, ast.UnaryOp) and isinstance(node.op, ast.USub):
    inner = const_float(node.operand)
    return None if inner is None else -inner
  if isinstance(node, ast.Attribute) and node.attr in ("inf", "nan"):
    return float(node.attr)
  if (isinstance(node, ast.Call) and isinstance(node.func, ast.Name)
      and node.func.id == "float" and len(node.args) == 1
      and not node.keywords):
    arg = node.args[0]
    if isinstance(arg, ast.Constant) and isinstance(arg.value, str):
      try:
        return float(arg.value)
      except ValueError:
        return None
    return const_float(arg)
  return None


def _dict_name(module_tree, dict_node) -> str:
  """Assignment-target name of a dict literal (for messages), else ''."""
  for node in ast.walk(module_tree):
    if isinstance(node, ast.Assign) and node.value is dict_node:
      targets = [t.id for t in node.targets if isinstance(t, ast.Name)]
      if targets:
        return targets[0]
    if (isinstance(node, ast.AnnAssign) and node.value is dict_node
        and isinstance(node.target, ast.Name)):
      return node.target.id
  return ""


def op_keyed_dicts(module):
  """(dict node, name, {op: value node}) for every op-keyed dict literal."""
  out = []
  for node in ast.walk(module.tree):
    if not isinstance(node, ast.Dict):
      continue
    keys = {}
    for k, v in zip(node.keys, node.values):
      if isinstance(k, ast.Constant) and isinstance(k.value, str):
        keys[k.value] = v
    if sum(1 for k in keys if k in sr_mod.ALL_OPS) >= _MIN_OP_KEYS:
      out.append((node, _dict_name(module.tree, node), keys))
  return out


@rule("semiring-table-coverage", family="semiring")
def _rule_table_coverage(ctx: Context) -> list:
  """Every op-keyed dict must cover ALL_OPS exactly."""
  out = []
  registered = set(sr_mod.ALL_OPS)
  for mod in ctx.modules:
    for node, name, keys in op_keyed_dicts(mod):
      label = f"op-keyed table {name or '<anonymous>'}"
      missing = sorted(registered - set(keys))
      unknown = sorted(set(keys) - registered)
      if missing:
        out.append(Finding(
            rule="semiring-table-coverage", path=mod.relpath,
            line=node.lineno,
            message=f"{label} is missing registered op(s) "
                    f"{missing} — every ring needs an entry"))
      if unknown:
        out.append(Finding(
            rule="semiring-table-coverage", path=mod.relpath,
            line=node.lineno,
            message=f"{label} has key(s) {unknown} that are not in the "
                    f"semiring registry"))
  return out


@rule("semiring-pad-consistency", family="semiring")
def _rule_pad_consistency(ctx: Context) -> list:
  """Op-keyed pad-pair tables must satisfy ⊗(pa, pb) == ⊕-identity."""
  from repro.analysis.laws import np_ops
  out = []
  for mod in ctx.modules:
    for node, name, keys in op_keyed_dicts(mod):
      label = name or "<anonymous>"
      for op, value in keys.items():
        if op not in sr_mod.ALL_OPS:
          continue
        if not (isinstance(value, (ast.Tuple, ast.List))
                and len(value.elts) == 2):
          continue  # not a pad-pair table entry
        pa, pb = (const_float(e) for e in value.elts)
        if pa is None or pb is None:
          continue  # non-constant pair: not a literal pad table
        sr = sr_mod.get(op)
        _, otimes = np_ops(sr)
        if sr.boolean:
          prod = float(otimes(np.bool_(pa), np.bool_(pb)))
          ident = float(np.bool_(sr.oplus_identity))
        else:
          prod = float(otimes(np.float64(pa), np.float64(pb)))
          ident = float(sr.oplus_identity)
        if np.isnan(prod) or prod != ident:
          out.append(Finding(
              rule="semiring-pad-consistency", path=mod.relpath,
              line=value.lineno,
              message=f"pad table {label}[{op!r}] == ({pa!r}, {pb!r}) but "
                      f"⊗(pa, pb) == {prod!r}, want the ⊕-identity "
                      f"{ident!r} — padded lanes would corrupt results"))
  return out


@rule("semiring-hardcoded-identity", family="semiring")
def _rule_hardcoded_identity(ctx: Context) -> list:
  """±inf literals in contraction/padding modules must be table-sourced."""
  out = []
  for mod in ctx.modules:
    if not any(mod.relpath.endswith(s) for s in _IDENTITY_SCOPED):
      continue
    table_spans = set()
    for node, _, _ in op_keyed_dicts(mod):
      table_spans.update(range(node.lineno, (node.end_lineno or node.lineno)
                               + 1))
    seen = set()
    for node in ast.walk(mod.tree):
      if isinstance(node, ast.Dict):
        continue
      value = None
      if isinstance(node, (ast.Call, ast.Attribute)):
        value = const_float(node)
      if value is None or not np.isinf(value):
        continue
      if node.lineno in table_spans or node.lineno in seen:
        continue
      seen.add(node.lineno)
      out.append(Finding(
          rule="semiring-hardcoded-identity", path=mod.relpath,
          line=node.lineno,
          message=f"hardcoded {value!r} outside an op-keyed table — "
                  f"semiring identities/pads must come from the "
                  f"core.semiring registry (one ring's identity is another "
                  f"ring's corruption)"))
  return out

"""Numeric semiring-law checker — algebra the AST cannot see.

Every registered (⊕, ⊗) pair is exercised over adversarial floats (±inf,
NaN, denormals) with *numpy mirrors* of the registry's jnp operators — no
tracing, no compilation, so the whole family runs in milliseconds:

  * ⊕ associativity and commutativity (exact for the min/max/or lattice
    reductions; tolerance-at-working-magnitude for float ``+``, which is
    only associative up to rounding — the honest IEEE statement of the law);
  * ⊕-identity (``x ⊕ id == x``) and ⊗-identity where the registry declares
    one (addnorm's squared difference has none — the paper's "beyond GEMM"
    op is deliberately not a true semiring);
  * the annihilator law ``⊗(id_⊕, x) == id_⊕`` over each ring's *value
    domain* — the domains below are the engine's data contract (e.g. the
    mul-rings carry positive reliabilities, so 0·(−inf) can never meet);
  * NaN propagation — neither operator may silently swallow a NaN;
  * K-pad invariance: ``⊗(pa, pb) == id_⊕`` pointwise AND a full padded
    contraction equals the unpadded one (the property every padded/ragged/
    bisected batch in serve_mmo rests on);
  * closure-pad invariance: ``core.closure`` pads adjacencies with
    (_SELF_VALUES, _MISSING_VALUES) sentinels; squaring the padded matrix
    must reproduce the unpadded closure on the original block and may never
    manufacture NaN (this is how those tables are cross-checked — mma's
    "self" is 0, not the ⊗-identity, so a literal-equality check would be
    wrong where this behavioral one is right).

Findings anchor at the registry entry (core/semiring.py) or the sentinel
tables (core/closure.py) so a violation points at the table to fix.
"""
from __future__ import annotations

import numpy as np

from repro.analysis.core import Context, Finding, rule
from repro.core import closure as cl_mod
from repro.core import semiring as sr_mod

__all__ = ["np_ops", "check_laws", "check_closure_pads", "LAW_DOMAINS"]

_INF = float("inf")

# Adversarial-but-valid operand sets per ring — each ring's *data contract*,
# i.e. the values the serving layer may actually contract.  Exclusions are
# deliberate and load-bearing:
#   minplus excludes -inf (inf + -inf = NaN; +inf spells "unreachable"),
#   maxplus symmetrically excludes +inf,
#   minmul/maxmul are positive-reliability rings: 0 and ±inf are excluded
#     as ⊗ operands because 0·inf = NaN and the ±inf ⊕-identities enter ⊗
#     only as K-pads (checked separately, as the (pa, pb) *pair*),
#   minmax/maxmin are pure lattice ops: the full extended line is legal.
_FINITE = [0.0, -0.0, 1.0, -1.0, 0.5, 3.0, 1e30, -1e30,
           5e-324, -5e-324, 2.2250738585072014e-308]
_POS = [5e-324, 2.2250738585072014e-308, 0.25, 0.5, 1.0, 3.0, 1e30]
LAW_DOMAINS = {
    "mma": _FINITE,
    "minplus": _FINITE + [_INF],
    "maxplus": _FINITE + [-_INF],
    "minmul": _POS + [_INF],
    "maxmul": _POS,
    "minmax": _FINITE + [_INF, -_INF],
    "maxmin": _FINITE + [_INF, -_INF],
    "orand": [False, True],
    "addnorm": _FINITE,
}


def np_ops(sr):
  """Numpy mirrors of one registry entry's (⊕, ⊗) jnp operators."""
  import jax.numpy as jnp
  table = {jnp.add: np.add, jnp.multiply: np.multiply,
           jnp.minimum: np.minimum, jnp.maximum: np.maximum,
           jnp.logical_or: np.logical_or, jnp.logical_and: np.logical_and}
  oplus = table.get(sr.oplus)
  otimes = table.get(sr.otimes)
  if otimes is None and sr.otimes is sr_mod._sq_diff:
    otimes = lambda a, b: np.square(np.subtract(a, b))  # noqa: E731
  if oplus is None or otimes is None:
    raise NotImplementedError(
        f"no numpy mirror for {sr.name}'s operators — teach "
        f"repro.analysis.laws.np_ops about them")
  return oplus, otimes


def _exact_oplus(sr) -> bool:
  """min/max/or reductions are exact on floats; ``+`` is only associative
  up to rounding."""
  import jax.numpy as jnp
  return sr.oplus is not jnp.add


def _eq(a, b, *, exact: bool, scale: float = 1.0) -> bool:
  a, b = float(a), float(b)
  if np.isnan(a) or np.isnan(b):
    return False
  if a == b:
    return True
  if exact:
    return False
  tol = 1e-9 * max(1.0, abs(scale))
  return abs(a - b) <= tol


def _anchor_line(module, needle: str) -> int:
  if module is None:
    return 1
  for i, text in enumerate(module.source.splitlines(), start=1):
    if needle in text:
      return i
  return 1


def check_laws(op: str) -> list:
  """Law-violation messages for one ring (empty = clean)."""
  sr = sr_mod.get(op)
  oplus, otimes = np_ops(sr)
  dom = [np.bool_(v) if sr.boolean else np.float64(v)
         for v in LAW_DOMAINS[op]]
  exact = _exact_oplus(sr)
  ident = np.bool_(False) if sr.boolean else np.float64(sr.oplus_identity)
  out = []

  def law(name, cond, detail):
    if not cond:
      out.append(f"{op}: {name} violated: {detail}")

  for a in dom:
    law("oplus-identity", _eq(oplus(a, ident), a, exact=True),
        f"{a!r} ⊕ id == {oplus(a, ident)!r}")
    for b in dom:
      law("oplus-commutativity",
          _eq(oplus(a, b), oplus(b, a), exact=True),
          f"{a!r} ⊕ {b!r} != {b!r} ⊕ {a!r}")
      for c in dom:
        scale = max(abs(float(a)), abs(float(b)), abs(float(c)), 1.0) \
            if not sr.boolean else 1.0
        law("oplus-associativity",
            _eq(oplus(oplus(a, b), c), oplus(a, oplus(b, c)),
                exact=exact, scale=scale),
            f"({a!r} ⊕ {b!r}) ⊕ {c!r} != {a!r} ⊕ ({b!r} ⊕ {c!r})")

  if sr.otimes_identity is not None:
    one = (np.bool_(bool(sr.otimes_identity)) if sr.boolean
           else np.float64(sr.otimes_identity))
    for a in dom:
      law("otimes-identity",
          _eq(otimes(one, a), a, exact=True)
          and _eq(otimes(a, one), a, exact=True),
          f"id_⊗ ⊗ {a!r} == {otimes(one, a)!r}")
    # annihilator only makes sense for rings with a true ⊗ (addnorm's
    # (id-x)² = x² breaks it by construction — and that is exactly why the
    # sparse layer must refuse addnorm seeds, see core/sparse.py)
    for a in dom:
      law("annihilator",
          _eq(otimes(ident, a), ident, exact=True)
          and _eq(otimes(a, ident), ident, exact=True),
          f"id_⊕ ⊗ {a!r} == {otimes(ident, a)!r}")

  if not sr.boolean:
    nan = np.float64(np.nan)
    for a in dom:
      law("nan-propagation",
          np.isnan(oplus(a, nan)) and np.isnan(oplus(nan, a))
          and np.isnan(otimes(a, nan)) and np.isnan(otimes(nan, a)),
          f"an operator swallowed NaN next to {a!r}")

  # -- K-pad invariance ------------------------------------------------------
  pa, pb = sr_mod.contraction_pads(op)
  if sr.boolean:
    pa, pb = np.bool_(pa), np.bool_(pb)
  else:
    pa, pb = np.float64(pa), np.float64(pb)
  prod = otimes(pa, pb)
  law("pad-product", not np.isnan(prod) and _eq(prod, ident, exact=True),
      f"⊗(pad_a={pa!r}, pad_b={pb!r}) == {prod!r}, want id_⊕ == {ident!r}")

  rng = np.random.default_rng(0)
  m, k, n, kpad = 3, 4, 3, 7
  a2 = _sample(rng, op, (m, k))
  b2 = _sample(rng, op, (k, n))
  ap = np.full((m, kpad), pa, dtype=a2.dtype)
  bp = np.full((kpad, n), pb, dtype=b2.dtype)
  ap[:, :k] = a2
  bp[:k, :] = b2
  base = _np_mmo(sr, a2, b2)
  padded = _np_mmo(sr, ap, bp)
  scale = 1.0 if sr.boolean else float(np.max(np.abs(
      base[np.isfinite(base)]), initial=1.0))
  law("kpad-invariance",
      all(_eq(x, y, exact=exact, scale=scale)
          for x, y in zip(base.ravel(), padded.ravel())),
      "padding K with (pad_a, pad_b) changed the contraction result")
  return out


def _sample(rng, op: str, shape):
  """Random operand block drawn from the ring's value domain."""
  sr = sr_mod.get(op)
  if sr.boolean:
    return rng.random(shape) < 0.5
  if op in ("minmul", "maxmul", "maxmin"):
    # positive-only rings: reliabilities/capacities — 0 is the maxmul/maxmin
    # no-edge sentinel, negative values have no graph meaning
    return rng.uniform(0.25, 2.0, shape)
  return rng.uniform(-1.0, 1.0, shape)


def _np_mmo(sr, a, b):
  """Reference ⊕-over-k contraction with numpy mirrors (host-side only)."""
  oplus, otimes = np_ops(sr)
  prod = otimes(a[:, :, None], b[None, :, :])  # (m, k, n)
  if sr.boolean:
    return np.logical_or.reduce(prod, axis=1)
  return {np.add: np.add, np.minimum: np.minimum,
          np.maximum: np.maximum}[oplus].reduce(prod, axis=1)


def check_closure_pads(op: str) -> list:
  """Behavioral check of closure.py's (_SELF_VALUES, _MISSING_VALUES)
  sentinels: padding an adjacency with isolated vertices must leave the
  closure of the original block unchanged and NaN-free.

  Rings without a ⊗-identity have no isolated-vertex embedding (addnorm's
  (x − missing)² = x² feeds pad vertices back into the real block), and
  ``closure_pad_values`` refuses them — verified here instead of checking
  an invariant that cannot hold."""
  sr = sr_mod.get(op)
  if sr.otimes_identity is None:
    try:
      cl_mod.closure_pad_values(op)
    except ValueError:
      return []
    return [f"{op}: has no ⊗-identity but closure_pad_values accepts it — "
            f"pad vertices would corrupt the real block after one squaring"]
  oplus, _ = np_ops(sr)
  rng = np.random.default_rng(1)
  n, npad = 5, 8
  adj = _sample(rng, op, (n, n))
  missing, self_v = cl_mod.closure_pad_values(op)
  adj[rng.random((n, n)) < 0.3] = missing
  np.fill_diagonal(adj, self_v)
  padded = cl_mod.pad_adjacency(adj, npad, op=op)
  exact = _exact_oplus(sr)
  c, cp = adj.copy(), padded.copy()
  out = []
  for it in range(3):  # per-squaring invariance — no fixpoint needed
    c = oplus(c, _np_mmo(sr, c, c))
    cp = oplus(cp, _np_mmo(sr, cp, cp))
    if not sr.boolean and np.isnan(cp).any():
      out.append(f"{op}: closure-pad sentinels manufacture NaN at "
                 f"squaring {it + 1}")
      break
    block = cp[:n, :n]
    scale = 1.0 if sr.boolean else float(np.max(np.abs(
        c[np.isfinite(c)]), initial=1.0))
    if not all(_eq(x, y, exact=exact, scale=scale)
               for x, y in zip(c.ravel(), block.ravel())):
      out.append(f"{op}: padded closure diverges from the unpadded one at "
                 f"squaring {it + 1} — (_SELF_VALUES, _MISSING_VALUES) are "
                 f"not an isolated-vertex embedding for this ring")
      break
  return out


@rule("semiring-laws", family="semiring")
def _rule_semiring_laws(ctx: Context) -> list:
  """Numerically verify ⊕/⊗ laws, pads, and NaN behavior for every ring."""
  mod = ctx.module("core/semiring.py")
  if mod is None:
    return []
  out = []
  for op in sr_mod.ALL_OPS:
    line = _anchor_line(mod, f'name="{op}"')
    out.extend(Finding(rule="semiring-laws", path=mod.relpath, line=line,
                       message=msg) for msg in check_laws(op))
  return out


@rule("semiring-closure-pads", family="semiring")
def _rule_closure_pads(ctx: Context) -> list:
  """Numerically verify closure.py's adjacency-padding sentinel tables."""
  mod = ctx.module("core/closure.py")
  if mod is None:
    return []
  line = _anchor_line(mod, "_MISSING_VALUES")
  out = []
  for op in sr_mod.ALL_OPS:
    out.extend(Finding(rule="semiring-closure-pads", path=mod.relpath,
                       line=line, message=msg)
               for msg in check_closure_pads(op))
  return out

"""Synthetic problem generators for the 8 SIMD² applications (paper §5.2).

Conventions per ring (missing-edge sentinel, self value) follow
core/closure.prepare_adjacency; reliabilities are sampled in (0, 1] so
min-mul's +inf sentinel can never meet a zero (no NaN paths).
"""
from __future__ import annotations

import numpy as np


def weighted_digraph(n: int, density: float = 0.3, *, seed: int = 0,
                     wmin: float = 1.0, wmax: float = 10.0) -> np.ndarray:
  """APSP input: weights > 0, np.inf where no edge."""
  rng = np.random.default_rng(seed)
  w = rng.uniform(wmin, wmax, (n, n)).astype(np.float32)
  w[rng.random((n, n)) >= density] = np.inf
  np.fill_diagonal(w, 0.0)
  return w


def dag(n: int, density: float = 0.3, *, seed: int = 0,
        wmin: float = 1.0, wmax: float = 10.0) -> np.ndarray:
  """APLP input: edges only i→j for i<j (acyclic); -inf where no edge."""
  rng = np.random.default_rng(seed)
  w = rng.uniform(wmin, wmax, (n, n)).astype(np.float32)
  keep = (rng.random((n, n)) < density) & np.triu(np.ones((n, n), bool), 1)
  w = np.where(keep, w, -np.inf).astype(np.float32)
  np.fill_diagonal(w, 0.0)
  return w


def reliability_graph(n: int, density: float = 0.3, *, seed: int = 0,
                      maximize: bool = True) -> np.ndarray:
  """Edge success probabilities in (0.05, 1]; sentinel 0 (max-mul) or
  +inf (min-mul) where no edge; diagonal 1.

  The min-mul instance is generated ACYCLIC (edges i→j only for i<j): with
  min-reduction over sub-1 products, cyclic graphs have no fixed point (every
  extra lap shrinks the product), so minimum-reliability paths are only
  well-defined on DAG reliability networks — matching the paper's use case."""
  rng = np.random.default_rng(seed)
  p = rng.uniform(0.05, 1.0, (n, n)).astype(np.float32)
  missing = 0.0 if maximize else np.inf
  p[rng.random((n, n)) >= density] = missing
  if not maximize:
    p[np.tril_indices(n, 0)] = missing
  np.fill_diagonal(p, 1.0)
  return p


def capacity_graph(n: int, density: float = 0.3, *, seed: int = 0) -> np.ndarray:
  """Edge capacities > 0; 0 where no edge; +inf self capacity."""
  rng = np.random.default_rng(seed)
  c = rng.uniform(1.0, 100.0, (n, n)).astype(np.float32)
  c[rng.random((n, n)) >= density] = 0.0
  np.fill_diagonal(c, np.inf)
  return c


def undirected_weighted(n: int, density: float = 0.3, *, seed: int = 0
                        ) -> np.ndarray:
  """MST input: symmetric, unique positive weights, +inf where no edge.
  A random spanning path is added so the graph is always connected."""
  rng = np.random.default_rng(seed)
  w = np.full((n, n), np.inf, dtype=np.float32)
  iu = np.triu_indices(n, 1)
  keep = rng.random(len(iu[0])) < density
  # unique weights → unique MST (makes the oracle comparison exact)
  vals = rng.permutation(len(iu[0])).astype(np.float32) + 1.0
  w[iu[0][keep], iu[1][keep]] = vals[keep]
  order = rng.permutation(n)
  for t, (a, b) in enumerate(zip(order[:-1], order[1:])):
    i, j = min(a, b), max(a, b)
    if not np.isfinite(w[i, j]):
      w[i, j] = float(len(vals) + 1 + t)  # unique, larger than sampled vals
  w = np.minimum(w, w.T)
  np.fill_diagonal(w, 0.0)
  return w


def boolean_digraph(n: int, density: float = 0.05, *, seed: int = 0
                    ) -> np.ndarray:
  rng = np.random.default_rng(seed)
  adj = rng.random((n, n)) < density
  np.fill_diagonal(adj, True)
  return adj


def knn_points(n_ref: int, n_query: int, dim: int, *, seed: int = 0):
  rng = np.random.default_rng(seed)
  ref = rng.standard_normal((n_ref, dim)).astype(np.float32)
  qry = rng.standard_normal((n_query, dim)).astype(np.float32)
  return ref, qry

"""Independent scalar/numpy baselines — the paper's "state-of-the-art GPU
baseline" arm (§5.2), reimplemented as classic algorithms so that each
SIMD²-ized solver is validated against a *different* algorithm, exactly as
the paper's correctness-validation flow demands (§5.1.2):

  APSP/APLP/MaxCP/MaxRP/MinRP → Floyd-Warshall k-pivot recurrences
  MST                         → Kruskal with union-find (+ tree path maxima)
  GTC                         → per-source BFS reachability
  KNN                         → brute-force norm expansion + argpartition
"""
from __future__ import annotations

import numpy as np


def floyd_warshall_np(adj: np.ndarray, oplus, otimes) -> np.ndarray:
  """Generic k-pivot closure. adj must already hold self values/sentinels."""
  d = adj.astype(np.float64, copy=True)
  n = d.shape[0]
  for k in range(n):
    with np.errstate(invalid="ignore", over="ignore"):
      cand = otimes(d[:, k:k + 1], d[k:k + 1, :])
    d = oplus(d, cand)
  return d


def apsp_np(w: np.ndarray) -> np.ndarray:
  return floyd_warshall_np(w, np.minimum, np.add)


def aplp_np(w: np.ndarray) -> np.ndarray:
  # longest path on a DAG: -inf sentinels never contribute (−inf + x = −inf)
  return floyd_warshall_np(w, np.maximum, np.add)


def maxcp_np(c: np.ndarray) -> np.ndarray:
  # max capacity: widest-path recurrence
  return floyd_warshall_np(c, np.maximum, np.minimum)


def maxrp_np(p: np.ndarray) -> np.ndarray:
  return floyd_warshall_np(p, np.maximum, np.multiply)


def minrp_np(p: np.ndarray) -> np.ndarray:
  return floyd_warshall_np(p, np.minimum, np.multiply)


def gtc_np(adj: np.ndarray) -> np.ndarray:
  """Reflexive-transitive closure by BFS from every source."""
  n = adj.shape[0]
  out = np.zeros((n, n), dtype=bool)
  nbrs = [np.nonzero(adj[i])[0] for i in range(n)]
  for s in range(n):
    seen = np.zeros(n, dtype=bool)
    seen[s] = True
    frontier = [s]
    while frontier:
      nxt = []
      for u in frontier:
        for v in nbrs[u]:
          if not seen[v]:
            seen[v] = True
            nxt.append(v)
      frontier = nxt
    out[s] = seen
  return out


class _UnionFind:
  def __init__(self, n):
    self.p = list(range(n))

  def find(self, x):
    while self.p[x] != x:
      self.p[x] = self.p[self.p[x]]
      x = self.p[x]
    return x

  def union(self, a, b):
    ra, rb = self.find(a), self.find(b)
    if ra == rb:
      return False
    self.p[ra] = rb
    return True


def kruskal_mst_np(w: np.ndarray):
  """Returns (edge set as sorted (i,j) tuples, total weight)."""
  n = w.shape[0]
  iu, ju = np.triu_indices(n, 1)
  finite = np.isfinite(w[iu, ju])
  edges = sorted(zip(w[iu[finite], ju[finite]], iu[finite], ju[finite]))
  uf = _UnionFind(n)
  out, total = set(), 0.0
  for wt, i, j in edges:
    if uf.union(int(i), int(j)):
      out.add((int(i), int(j)))
      total += float(wt)
  return out, total


def minimax_paths_np(w: np.ndarray) -> np.ndarray:
  """Minimax (bottleneck) path matrix — the quantity the min-max closure
  computes; derived here independently from the MST (max edge on the unique
  tree path), for cross-validation against the semiring solver."""
  n = w.shape[0]
  edges, _ = kruskal_mst_np(w)
  adj = [[] for _ in range(n)]
  for i, j in edges:
    adj[i].append((j, w[i, j]))
    adj[j].append((i, w[i, j]))
  out = np.full((n, n), np.inf)
  np.fill_diagonal(out, -np.inf)  # semiring self value (min-max identity-ish)
  for s in range(n):
    # DFS carrying the max edge weight seen
    stack = [(s, -np.inf)]
    seen = {s}
    while stack:
      u, mx = stack.pop()
      for v, wt in adj[u]:
        if v not in seen:
          seen.add(v)
          m2 = max(mx, wt)
          out[s, v] = m2
          stack.append((v, m2))
  return out


def knn_np(ref: np.ndarray, qry: np.ndarray, k: int):
  """Brute-force: returns (sq-dists (Q,k), indices (Q,k)) sorted ascending."""
  d2 = ((qry[:, None, :].astype(np.float64)
         - ref[None, :, :].astype(np.float64)) ** 2).sum(-1)
  idx = np.argsort(d2, axis=1, kind="stable")[:, :k]
  return np.take_along_axis(d2, idx, axis=1), idx

"""The paper's 8 benchmark applications, SIMD²-ized + independent baselines."""
from repro.apps import baselines, graphs
from repro.apps.solvers import (ALL_APPS, aplp, apsp, gtc, knn, maxcp, maxrp,
                                minrp, mst_edges, mst_minimax)

__all__ = ["ALL_APPS", "apsp", "aplp", "maxcp", "maxrp", "minrp",
           "mst_minimax", "mst_edges", "gtc", "knn", "baselines", "graphs"]

"""SIMD²-ized solvers for the paper's 8 applications (§5.2).

Each solver is "the Figure-7 host program" in JAX: prepare the adjacency for
its ring, run a closure built from SIMD² MMOs (Leyzorek by default, AP
Bellman-Ford / Floyd-Warshall selectable), and post-process.  ``backend``
forwards to core.mmo ('xla' = MXU-rewrites + blocked vector, 'vector' = the
SIMD²-w/-CUDA-cores arm, 'pallas' = the SIMD²-unit kernel).
"""
from __future__ import annotations

import functools
from typing import Optional

import jax
import jax.numpy as jnp
import numpy as np

from repro.core import closure as cl
from repro.core.mmo import mmo

_ALGOS = ("leyzorek", "bellman_ford", "floyd_warshall")


def _closure(adj, *, op, algorithm="leyzorek", convergence=True,
             backend="auto", max_iters=None):
  if algorithm == "leyzorek":
    out, it = cl.leyzorek_closure(adj, op=op, backend=backend,
                                  check_convergence=convergence,
                                  max_iters=max_iters)
  elif algorithm == "bellman_ford":
    out, it = cl.bellman_ford_closure(adj, op=op, backend=backend,
                                      check_convergence=convergence,
                                      max_iters=max_iters)
  elif algorithm == "floyd_warshall":
    out, it = cl.floyd_warshall(adj, op=op), adj.shape[-1]
  else:
    raise ValueError(f"algorithm must be one of {_ALGOS}")
  return out, it


def apsp(w, **kw):
  """All-pairs shortest paths — SIMD².minplus (w: +inf for missing, 0 diag)."""
  adj = cl.prepare_adjacency(jnp.asarray(w), op="minplus")
  return _closure(adj, op="minplus", **kw)


def aplp(w, **kw):
  """All-pairs longest (critical) paths on a DAG — SIMD².maxplus."""
  adj = cl.prepare_adjacency(jnp.asarray(w), op="maxplus")
  return _closure(adj, op="maxplus", **kw)


def maxcp(c, **kw):
  """Maximum capacity (widest) paths — SIMD².maxmin."""
  adj = cl.prepare_adjacency(jnp.asarray(c), op="maxmin")
  return _closure(adj, op="maxmin", **kw)


def maxrp(p, **kw):
  """Maximum reliability paths — SIMD².maxmul (p: 0 for missing, 1 diag)."""
  adj = cl.prepare_adjacency(jnp.asarray(p), op="maxmul")
  return _closure(adj, op="maxmul", **kw)


def minrp(p, **kw):
  """Minimum reliability paths — SIMD².minmul (p: +inf for missing, 1 diag)."""
  adj = cl.prepare_adjacency(jnp.asarray(p), op="minmul")
  return _closure(adj, op="minmul", **kw)


def mst_minimax(w, **kw):
  """Min-max closure: minimax (bottleneck) path matrix — SIMD².minmax."""
  adj = cl.prepare_adjacency(jnp.asarray(w), op="minmax")
  return _closure(adj, op="minmax", **kw)


def mst_edges(w, **kw):
  """Minimum spanning tree via the cycle property: for unique weights, edge
  (i,j) ∈ MST ⟺ w(i,j) equals the minimax path value between i and j."""
  mm, it = mst_minimax(w, **kw)
  w = jnp.asarray(w)
  finite = jnp.isfinite(w)
  in_mst = finite & (w <= mm) & ~jnp.eye(w.shape[0], dtype=bool)
  return in_mst, it


def gtc(adj, **kw):
  """Graph transitive (reflexive) closure — SIMD².orand."""
  a = cl.prepare_adjacency(jnp.asarray(adj), op="orand")
  return _closure(a, op="orand", **kw)


@functools.partial(jax.jit, static_argnames=("k", "backend"))
def knn(ref, qry, *, k: int, backend: str = "auto"):
  """K-nearest neighbours — SIMD².addnorm + top-k.

  Returns (sq-dists (Q,k), indices (Q,k)) ascending."""
  d2 = mmo(jnp.asarray(qry), jnp.asarray(ref).T, op="addnorm",
           backend=backend)
  neg, idx = jax.lax.top_k(-d2, k)
  return -neg, idx


ALL_APPS = {
    "apsp": apsp,
    "aplp": aplp,
    "mcp": maxcp,
    "maxrp": maxrp,
    "minrp": minrp,
    "mst": mst_minimax,
    "gtc": gtc,
    "knn": knn,
}

"""The remaining Table-1 applications: matrix inverse (plus-multiply ring)
and k-means (add-norm), completing the paper's application taxonomy.

  * ``newton_inverse`` — Newton–Schulz iteration X ← X(2I − AX): pure mma
    MMOs, quadratic convergence; the paper lists matrix inversion as a
    plus-multiply-ring workload.
  * ``kmeans`` — Lloyd's algorithm where the assignment step is the SIMD²
    ``addnorm`` instruction (pairwise squared-L2 + argmin), the same kernel
    as KNN / chameleon's VQ tokenizer.
"""
from __future__ import annotations

import functools

import jax
import jax.numpy as jnp

from repro.core.mmo import mmo

Array = jax.Array


@functools.partial(jax.jit, static_argnames=("iters", "backend"))
def newton_inverse(a: Array, *, iters: int = 32, backend: str = "auto"):
  """A⁻¹ by Newton–Schulz: X₀ = Aᵀ/(‖A‖₁‖A‖∞); Xₖ₊₁ = Xₖ(2I − A Xₖ).

  Every step is two mma MMOs. Returns (inverse, residual ‖AX−I‖∞)."""
  n = a.shape[-1]
  norm1 = jnp.max(jnp.sum(jnp.abs(a), axis=-2))
  norminf = jnp.max(jnp.sum(jnp.abs(a), axis=-1))
  x = a.T / (norm1 * norminf)
  eye2 = 2.0 * jnp.eye(n, dtype=a.dtype)

  def body(_, x):
    ax = mmo(a, x, op="mma", backend=backend)          # A @ X
    return mmo(x, eye2 - ax, op="mma", backend=backend)  # X(2I − AX)

  x = jax.lax.fori_loop(0, iters, body, x)
  resid = jnp.max(jnp.abs(mmo(a, x, op="mma", backend=backend) -
                          jnp.eye(n, dtype=a.dtype)))
  return x, resid


@functools.partial(jax.jit, static_argnames=("k", "iters", "backend"))
def kmeans(points: Array, *, k: int, iters: int = 20, seed: int = 0,
           backend: str = "auto"):
  """Lloyd's k-means; the assignment step is SIMD².addnorm + argmin.

  points: (N, D).  Returns (centroids (k, D), assignments (N,), inertia)."""
  n, d = points.shape
  key = jax.random.PRNGKey(seed)
  init_idx = jax.random.choice(key, n, (k,), replace=False)
  cents = points[init_idx]

  def step(_, cents):
    d2 = mmo(points, cents.T, op="addnorm", backend=backend)   # (N, k)
    assign = jnp.argmin(d2, axis=-1)
    onehot = jax.nn.one_hot(assign, k, dtype=points.dtype)     # (N, k)
    sums = onehot.T @ points                                    # (k, D)
    counts = jnp.sum(onehot, axis=0)[:, None]
    new = jnp.where(counts > 0, sums / jnp.maximum(counts, 1.0), cents)
    return new

  cents = jax.lax.fori_loop(0, iters, step, cents)
  d2 = mmo(points, cents.T, op="addnorm", backend=backend)
  assign = jnp.argmin(d2, axis=-1)
  inertia = jnp.sum(jnp.min(d2, axis=-1))
  return cents, assign, inertia

"""mamba2-780m [ssm] — SSD, attention-free.  [arXiv:2405.21060]"""
from repro.models.common import ModelConfig

CONFIG = ModelConfig(
    name="mamba2-780m", family="ssm",
    n_layers=48, d_model=1536, n_heads=1, n_kv_heads=1, d_ff=0,
    vocab=50280,
    ssm_state=128, ssm_expand=2, ssm_headdim=64, ssm_ngroups=1,
    ssm_chunk=256, conv_kernel=4,
)


def smoke_config():
  return CONFIG.replace(n_layers=2, d_model=64, vocab=512, ssm_state=16,
                        ssm_headdim=16, ssm_chunk=8)

"""phi3.5-moe-42b-a6.6b [moe] — 16 experts top-2.
[hf:microsoft/Phi-3.5-MoE-instruct; hf]"""
from repro.models.common import ModelConfig

CONFIG = ModelConfig(
    name="phi3.5-moe-42b-a6.6b", family="moe",
    n_layers=32, d_model=4096, n_heads=32, n_kv_heads=8, d_ff=6400,
    vocab=32064, head_dim=128,
    n_experts=16, topk=2, capacity_factor=1.25, rope_theta=10000.0,
)


def smoke_config():
  return CONFIG.replace(n_layers=2, d_model=64, n_heads=4, n_kv_heads=2,
                        d_ff=128, vocab=512, head_dim=16, n_experts=4)

"""seamless-m4t-large-v2 [audio] — enc-dec backbone; the audio frontend is a
STUB (input_specs provides precomputed frame embeddings).  The assignment's
"24L" is realized as 24 encoder + 24 decoder layers (the m4t-large text
enc/dec depths).  [arXiv:2308.11596; hf]"""
from repro.models.common import ModelConfig

CONFIG = ModelConfig(
    name="seamless-m4t-large-v2", family="encdec",
    n_layers=24, d_model=1024, n_heads=16, n_kv_heads=16, d_ff=8192,
    vocab=256206, head_dim=64,
    enc_layers=24, dec_layers=24, cross_attention=True,
    src_len=4096, modality_stub="audio",
)


def smoke_config():
  return CONFIG.replace(n_layers=2, enc_layers=2, dec_layers=2, d_model=64,
                        n_heads=4, n_kv_heads=4, d_ff=128, vocab=512,
                        head_dim=16, src_len=24)

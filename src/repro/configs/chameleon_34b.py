"""chameleon-34b [vlm] — early-fusion, VQ image tokens in a reserved vocab
range, qk-norm.  The image tokenizer frontend is a STUB; its nearest-codebook
search is the SIMD² addnorm op (models/vlm.py).  [arXiv:2405.09818]"""
from repro.models.common import ModelConfig

CONFIG = ModelConfig(
    name="chameleon-34b", family="vlm",
    n_layers=48, d_model=8192, n_heads=64, n_kv_heads=8, d_ff=22016,
    vocab=65536, head_dim=128, qk_norm=True, rope_theta=10000.0,
)


def smoke_config():
  return CONFIG.replace(n_layers=2, d_model=64, n_heads=4, n_kv_heads=2,
                        d_ff=128, vocab=512, head_dim=16)

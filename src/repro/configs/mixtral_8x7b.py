"""mixtral-8x7b [moe] — 8 experts top-2, SWA.  [arXiv:2401.04088; hf]"""
from repro.models.common import ModelConfig

CONFIG = ModelConfig(
    name="mixtral-8x7b", family="moe",
    n_layers=32, d_model=4096, n_heads=32, n_kv_heads=8, d_ff=14336,
    vocab=32000, head_dim=128, window=4096,
    n_experts=8, topk=2, capacity_factor=1.25, rope_theta=1000000.0,
)


def smoke_config():
  return CONFIG.replace(n_layers=2, d_model=64, n_heads=4, n_kv_heads=2,
                        d_ff=128, vocab=512, head_dim=16, n_experts=4,
                        window=16)

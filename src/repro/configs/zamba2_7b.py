"""zamba2-7b [hybrid] — Mamba2 blocks + one shared attention block applied
every 6 SSM blocks.  [arXiv:2411.15242]"""
from repro.models.common import ModelConfig

CONFIG = ModelConfig(
    name="zamba2-7b", family="hybrid",
    n_layers=81, d_model=3584, n_heads=32, n_kv_heads=32, d_ff=14336,
    vocab=32000, head_dim=112,
    ssm_state=64, ssm_expand=2, ssm_headdim=64, ssm_ngroups=1,
    ssm_chunk=256, conv_kernel=4, hybrid_attn_every=6,
)


def smoke_config():
  return CONFIG.replace(n_layers=5, d_model=64, n_heads=4, n_kv_heads=4,
                        d_ff=128, vocab=512, head_dim=16, ssm_state=16,
                        ssm_headdim=16, ssm_chunk=8, hybrid_attn_every=2)

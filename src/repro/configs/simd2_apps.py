"""The paper's own workloads (Table 4): 8 applications x 3 input sizes."""

APP_SIZES = {
    "apsp":  {"small": 4096, "medium": 8192, "large": 16384},
    "aplp":  {"small": 4096, "medium": 8192, "large": 16384},
    "mcp":   {"small": 4096, "medium": 8192, "large": 16384},
    "maxrp": {"small": 4096, "medium": 8192, "large": 16384},
    "minrp": {"small": 4096, "medium": 8192, "large": 16384},
    "mst":   {"small": 1024, "medium": 2048, "large": 4096},
    "gtc":   {"small": 1024, "medium": 4096, "large": 8192},
    "knn":   {"small": 4096, "medium": 8192, "large": 16384},
}

# CPU-host benchmark sizes (same ratios, scaled so the suite finishes):
BENCH_SIZES = {
    "apsp":  {"small": 256, "medium": 512, "large": 1024},
    "aplp":  {"small": 256, "medium": 512, "large": 1024},
    "mcp":   {"small": 256, "medium": 512, "large": 1024},
    "maxrp": {"small": 256, "medium": 512, "large": 1024},
    "minrp": {"small": 256, "medium": 512, "large": 1024},
    "mst":   {"small": 128, "medium": 256, "large": 512},
    "gtc":   {"small": 128, "medium": 512, "large": 1024},
    "knn":   {"small": 256, "medium": 512, "large": 1024},
}

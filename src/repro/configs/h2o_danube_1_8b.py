"""h2o-danube-1.8b [dense] — llama+mistral mix, sliding-window attention.
[arXiv:2401.16818; hf]"""
from repro.models.common import ModelConfig

CONFIG = ModelConfig(
    name="h2o-danube-1.8b", family="dense",
    n_layers=24, d_model=2560, n_heads=32, n_kv_heads=8, d_ff=6912,
    vocab=32000, head_dim=80, window=4096, rope_theta=10000.0,
)


def smoke_config():
  return CONFIG.replace(n_layers=2, d_model=64, n_heads=4, n_kv_heads=2,
                        d_ff=128, vocab=512, head_dim=16, window=16)

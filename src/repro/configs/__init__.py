"""Config registry: 10 assigned architectures + the paper's own workloads.

Each ``<arch>.py`` exports:
  CONFIG          — the exact published configuration (full scale)
  smoke_config()  — a reduced same-family config for CPU tests
Shapes (per assignment) and per-arch skip rules live here.
"""
from __future__ import annotations

import dataclasses
import importlib
from typing import Optional

_ARCHS = {
    "mamba2-780m": "mamba2_780m",
    "tinyllama-1.1b": "tinyllama_1_1b",
    "qwen2.5-3b": "qwen2_5_3b",
    "granite-8b": "granite_8b",
    "h2o-danube-1.8b": "h2o_danube_1_8b",
    "seamless-m4t-large-v2": "seamless_m4t_large_v2",
    "mixtral-8x7b": "mixtral_8x7b",
    "phi3.5-moe-42b-a6.6b": "phi3_5_moe",
    "zamba2-7b": "zamba2_7b",
    "chameleon-34b": "chameleon_34b",
}


@dataclasses.dataclass(frozen=True)
class Shape:
  name: str
  seq_len: int
  global_batch: int
  kind: str  # train | prefill | decode


SHAPES = {
    "train_4k": Shape("train_4k", 4096, 256, "train"),
    "prefill_32k": Shape("prefill_32k", 32768, 32, "prefill"),
    "decode_32k": Shape("decode_32k", 32768, 128, "decode"),
    "long_500k": Shape("long_500k", 524288, 1, "decode"),
}

# long_500k needs sub-quadratic/bounded-state attention: run for SSM/hybrid
# and SWA archs, skip for pure full-attention archs (recorded in DESIGN.md §4
# and in the dry-run/roofline tables).
LONG_OK = {"mamba2-780m", "zamba2-7b", "mixtral-8x7b", "h2o-danube-1.8b"}


def skip_reason(arch: str, shape: str) -> Optional[str]:
  if shape == "long_500k" and arch not in LONG_OK:
    return "pure full-attention arch: 524k dense-KV decode is not sub-quadratic"
  return None


def list_archs():
  return list(_ARCHS)


def cells():
  """All (arch, shape) cells incl. skipped ones (with reasons)."""
  out = []
  for a in _ARCHS:
    for s in SHAPES:
      out.append((a, s, skip_reason(a, s)))
  return out


def get_config(name: str, smoke: bool = False):
  mod = importlib.import_module(f"repro.configs.{_ARCHS[name]}")
  return mod.smoke_config() if smoke else mod.CONFIG

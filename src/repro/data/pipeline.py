"""Deterministic, stateless data pipeline.

Every batch is a pure function of (seed, step, host shard) — there is no
iterator state to checkpoint, which is what makes checkpoint/restart exact:
restoring ``step`` restores the stream.  Two sources:

  * ``SyntheticLM``  — PRNG token streams (markov-ish, so loss decreases and
    smoke training is meaningful);
  * ``PackedCorpus`` — a memory-mapped uint16/uint32 token file, sampled by
    step-indexed offsets (the production path; deterministic across restarts
    and elastic re-sharding because offsets are derived, not consumed).

Per-host sharding: host h of H draws rows [h·B/H, (h+1)·B/H) of the global
batch — after a topology change (elastic resize) the derivation keeps every
sample exactly-once per step.
"""
from __future__ import annotations

import dataclasses
from typing import Optional

import jax
import jax.numpy as jnp
import numpy as np

Array = jax.Array


@dataclasses.dataclass(frozen=True)
class DataConfig:
  vocab: int
  seq_len: int
  global_batch: int
  seed: int = 0
  corpus_path: Optional[str] = None


class SyntheticLM:
  """Deterministic synthetic LM stream with local structure (each token is a
  noisy affine function of its predecessor mod V) so models can learn."""

  def __init__(self, cfg: DataConfig, n_hosts: int = 1, host_id: int = 0):
    self.cfg = cfg
    self.n_hosts = n_hosts
    self.host_id = host_id
    assert cfg.global_batch % n_hosts == 0

  def batch_at(self, step: int) -> dict:
    c = self.cfg
    b_local = c.global_batch // self.n_hosts
    key = jax.random.fold_in(
        jax.random.fold_in(jax.random.PRNGKey(c.seed), step), self.host_id)
    k1, k2, k3 = jax.random.split(key, 3)
    first = jax.random.randint(k1, (b_local, 1), 0, c.vocab)
    steps = jax.random.randint(k2, (b_local, c.seq_len - 1), 1, 17)
    noise = (jax.random.uniform(k3, (b_local, c.seq_len - 1)) < 0.1)
    steps = jnp.where(noise, steps * 31, steps)
    toks = (first + jnp.cumsum(steps, axis=1)) % c.vocab
    tokens = jnp.concatenate([first, toks], axis=1).astype(jnp.int32)
    return {"tokens": tokens, "labels": tokens}


class PackedCorpus:
  """Memory-mapped packed-token corpus, step-indexed window sampling."""

  def __init__(self, cfg: DataConfig, n_hosts: int = 1, host_id: int = 0,
               dtype=np.uint16):
    self.cfg = cfg
    self.n_hosts = n_hosts
    self.host_id = host_id
    self.data = np.memmap(cfg.corpus_path, dtype=dtype, mode="r")
    self.n_tokens = len(self.data)
    assert self.n_tokens > cfg.seq_len + 1, "corpus too small"

  def batch_at(self, step: int) -> dict:
    c = self.cfg
    b_local = c.global_batch // self.n_hosts
    rng = np.random.default_rng(
        np.random.SeedSequence([c.seed, step, self.host_id]))
    starts = rng.integers(0, self.n_tokens - c.seq_len - 1, b_local)
    rows = np.stack([self.data[s:s + c.seq_len] for s in starts])
    tokens = jnp.asarray(rows.astype(np.int32))
    return {"tokens": tokens, "labels": tokens}


class Prefetcher:
  """Step-ahead prefetch on a worker thread — hides host-side batch
  construction behind device compute.  Still stateless: wraps any
  ``batch_at`` source, so checkpoint/restart semantics are unchanged."""

  def __init__(self, source, depth: int = 2):
    import queue
    import threading
    self.source = source
    self._q: "queue.Queue" = queue.Queue(maxsize=depth)
    self._want = None
    self._lock = threading.Lock()

  def batch_at(self, step: int) -> dict:
    # fetch requested step synchronously if not prefetched, then prefetch
    # step+1 in the background
    import threading
    batch = None
    while not self._q.empty():
      s, b = self._q.get_nowait()
      if s == step:
        batch = b
        break
    if batch is None:
      batch = self.source.batch_at(step)
    t = threading.Thread(target=self._prefetch, args=(step + 1,),
                         daemon=True)
    t.start()
    return batch

  def _prefetch(self, step: int):
    try:
      self._q.put_nowait((step, self.source.batch_at(step)))
    except Exception:   # noqa: BLE001 — full queue / shutdown races are fine
      pass


def make_source(cfg: DataConfig, n_hosts: int = 1, host_id: int = 0,
                prefetch: int = 0):
  src = (PackedCorpus(cfg, n_hosts, host_id) if cfg.corpus_path
         else SyntheticLM(cfg, n_hosts, host_id))
  return Prefetcher(src, depth=prefetch) if prefetch else src

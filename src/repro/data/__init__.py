"""Deterministic data pipeline."""
from repro.data.pipeline import DataConfig, PackedCorpus, SyntheticLM, make_source

__all__ = ["DataConfig", "PackedCorpus", "SyntheticLM", "make_source"]

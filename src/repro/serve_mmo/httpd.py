"""Live HTTP observability endpoint for a serving engine — stdlib only.

``ObservabilityServer(engine, port=...)`` runs a ``ThreadingHTTPServer`` on
a daemon thread next to the engine's serving loop:

  /metrics   — Prometheus text exposition (serve_mmo/exposition.py):
               counters, per-bucket latency/host/device histograms, queue
               and executing gauges, estimator cells + drift, cache and
               flight-recorder counters.
  /healthz   — health JSON: 200 {"status": "ok", ...} while every circuit
               breaker is closed; 503 {"status": "degraded",
               "open_breakers": [...]} naming the open (bucket, backend,
               schedule) arms when any is open — a load balancer should
               drain a degraded instance while it still answers.  Also
               reports whether the serving loop thread is up.
  /snapshot  — the full ``engine.metrics_snapshot()`` JSON (rolling-window
               percentiles, admission state, estimator cells) — the same
               document ``--metrics-every`` tickers.
  /trace     — the flight recorder's Chrome trace-event JSON; save it and
               load in Perfetto / about://tracing.

Every handler reads a point-in-time snapshot the engine assembles under its
own locks and renders *outside* them, so a slow scraper (or a curl mid
load-test) can never stall the serving path.  Requests for anything else
get 404; handler errors get 500 with the exception name rather than killing
the handler thread.
"""
from __future__ import annotations

import json
import threading
from http.server import BaseHTTPRequestHandler, ThreadingHTTPServer
from typing import Optional

__all__ = ["ObservabilityServer", "PROMETHEUS_CONTENT_TYPE"]

PROMETHEUS_CONTENT_TYPE = "text/plain; version=0.0.4; charset=utf-8"


class ObservabilityServer:
  """HTTP front door for one engine's observability surface.

  ``port=0`` binds an ephemeral port (tests); read ``server.port`` after
  construction for the real one.  ``start()`` / ``stop()`` manage the
  serving thread; the server also works as a context manager."""

  def __init__(self, engine, *, host: str = "127.0.0.1", port: int = 0):
    self.engine = engine
    handler = _make_handler(engine)
    self._httpd = ThreadingHTTPServer((host, port), handler)
    self._httpd.daemon_threads = True
    self._thread: Optional[threading.Thread] = None

  @property
  def host(self) -> str:
    return self._httpd.server_address[0]

  @property
  def port(self) -> int:
    return self._httpd.server_address[1]

  @property
  def url(self) -> str:
    return f"http://{self.host}:{self.port}"

  def start(self) -> "ObservabilityServer":
    if self._thread is None:
      self._thread = threading.Thread(target=self._httpd.serve_forever,
                                      name="mmo-observability", daemon=True)
      self._thread.start()
    return self

  def stop(self) -> None:
    if self._thread is not None:
      self._httpd.shutdown()
      self._thread.join()
      self._thread = None
    self._httpd.server_close()

  def __enter__(self) -> "ObservabilityServer":
    return self.start()

  def __exit__(self, *exc) -> None:
    self.stop()


def _make_handler(engine):
  """Handler class closed over the engine (BaseHTTPRequestHandler is
  instantiated per request by the server, so state rides the closure)."""

  class Handler(BaseHTTPRequestHandler):
    server_version = "serve-mmo-observability/1.0"

    def log_message(self, fmt, *args):  # noqa: D102 — silence per-request logs
      pass

    def _send(self, status: int, content_type: str, body: str) -> None:
      payload = body.encode("utf-8")
      self.send_response(status)
      self.send_header("Content-Type", content_type)
      self.send_header("Content-Length", str(len(payload)))
      self.end_headers()
      self.wfile.write(payload)

    def do_GET(self):  # noqa: N802 — BaseHTTPRequestHandler API
      path = self.path.split("?", 1)[0]
      try:
        if path == "/metrics":
          from repro.serve_mmo.exposition import render_prometheus
          self._send(200, PROMETHEUS_CONTENT_TYPE,
                     render_prometheus(engine.observability_state()))
        elif path == "/healthz":
          loop = engine._thread
          resilience = getattr(engine, "resilience", None)
          open_breakers = ([] if resilience is None
                           else resilience.open_arms())
          degraded = bool(open_breakers)
          body = json.dumps({
              # degraded ≠ dead: open breakers mean some arm is failing and
              # its traffic rides a fallback — a load balancer should drain
              # this instance (503) while it still answers requests
              "status": "degraded" if degraded else "ok",
              "serving_loop_alive": bool(loop is not None and loop.is_alive()),
              "pending": engine.pending(),
              "open_breakers": [
                  {"bucket": c["bucket"], "backend": c["backend"],
                   "schedule": c["schedule"], "state": c["state"]}
                  for c in open_breakers],
          })
          self._send(503 if degraded else 200, "application/json", body)
        elif path == "/snapshot":
          self._send(200, "application/json",
                     json.dumps(engine.metrics_snapshot(), default=float))
        elif path == "/trace":
          self._send(200, "application/json",
                     json.dumps(engine.export_trace()))
        else:
          self._send(404, "text/plain; charset=utf-8",
                     "not found; try /metrics /healthz /snapshot /trace\n")
      except Exception as e:  # noqa: BLE001 — a handler bug must answer 500,
        # not silently kill this handler thread mid-scrape
        self._send(500, "text/plain; charset=utf-8",
                   f"internal error: {type(e).__name__}: {e}\n")

  return Handler

"""Pad-and-stack micro-batcher: bucket → one compiled program.

Three pieces per bucket:

  ``stack_batch``    — host-side: pad every request's operands to the bucket
                       shape and stack along a new leading request axis.
                       Padding is algebra-aware so it is a semantic no-op:
                       K-axis pads use core.semiring.contraction_pads (⊗ of
                       pads == ⊕-identity), adjacency pads add isolated
                       vertices (core.closure.closure_pad_values), and KNN
                       batches carry a per-request valid-row count so padded
                       corpus rows are masked to +inf before top-k (data-
                       scale independent — no magic far-away sentinel).
                       mmo/closure batches additionally carry a per-request
                       live-K / valid-n vector: because the padding is an
                       algebraic no-op, the backends may *skip* dead K work
                       instead of computing it (ragged masked-K execution).
  ``make_batch_fn``  — the pure jax function the executable cache compiles:
                       mmo_batched / batched_*_closure (per-request
                       convergence masks) / addnorm+top-k.
  ``split_results``  — slice the padded batch output back to each request's
                       true shape.
"""
from __future__ import annotations

from typing import Optional, Sequence

import jax
import jax.numpy as jnp
import numpy as np

from repro.core import closure as cl_mod
from repro.core import semiring as sr_mod
from repro.core.mmo import mmo_batched
from repro.serve_mmo.api import MMOResult, ProblemRequest
from repro.serve_mmo.scheduler import BucketKey

def _pad2d(x: np.ndarray, rows: int, cols: int,
           row_val, col_val) -> np.ndarray:
  """Pad a 2-D array to (rows, cols); new rows get row_val, new cols col_val."""
  out = np.full((rows, cols), col_val, dtype=x.dtype)
  out[x.shape[0]:, :] = row_val
  out[:x.shape[0], :x.shape[1]] = x
  return out


def _stack_mmo(key: BucketKey, reqs: Sequence[ProblemRequest]):
  mb, kb, nb = key.shape
  pa, pb = sr_mod.contraction_pads(key.op)
  boolean = sr_mod.get(key.op).boolean
  if boolean:
    pa = pb = False
  (has_c,) = key.params
  a = np.stack([_pad2d(r.arrays["a"], mb, kb, pa, pa) for r in reqs])
  b = np.stack([_pad2d(r.arrays["b"], kb, nb, pb, pb) for r in reqs])
  # per-request live-K: lanes beyond a request's true K are contraction pads
  # (⊗(pa, pb) == ⊕-identity), so backends may skip them (ragged masked-K)
  kv = np.asarray([r.shape[1] for r in reqs], np.int32)
  if not has_c:
    return (a, b, kv)
  ident = False if boolean else sr_mod.get(key.op).oplus_identity
  c = np.stack([_pad2d(r.arrays["c"], mb, nb, ident, ident) for r in reqs])
  return (a, b, c, kv)


def _stack_closure(key: BucketKey, reqs: Sequence[ProblemRequest]):
  (nb,) = key.shape
  adj = np.stack([cl_mod.pad_adjacency(r.arrays["adj"], nb, op=key.op)
                  for r in reqs])
  # true problem sizes: rows/cols beyond valid[r] are isolated-vertex padding
  valid = np.asarray([r.shape[0] for r in reqs], np.int32)
  return (adj, valid)


def _stack_knn(key: BucketKey, reqs: Sequence[ProblemRequest]):
  qb, rb, db = key.shape
  # all pads are zeros (query pad rows' outputs are sliced away; padded dims
  # contribute (0-0)²=0 for real rows); ``valid`` carries each request's true
  # corpus size so the compiled program can mask padded rows out of top-k.
  q = np.stack([_pad2d(r.arrays["queries"], qb, db, 0.0, 0.0) for r in reqs])
  ref = np.stack([_pad2d(r.arrays["corpus"], rb, db, 0.0, 0.0) for r in reqs])
  valid = np.asarray([r.arrays["corpus"].shape[0] for r in reqs], np.int32)
  return (q, ref, valid)


def stack_batch(key: BucketKey, reqs: Sequence[ProblemRequest]):
  """Pad + stack all request operands for one bucket batch."""
  if key.kind == "mmo":
    return _stack_mmo(key, reqs)
  if key.kind == "closure":
    return _stack_closure(key, reqs)
  if key.kind == "knn":
    return _stack_knn(key, reqs)
  raise ValueError(f"unknown kind {key.kind!r}")


def stacked_nbytes(stacked) -> int:
  """Bytes one stacked batch stages host→device (pads included) — the H2D
  traffic gauge the engine's metrics and trace spans report per batch."""
  return sum(int(a.nbytes) for a in stacked)


def abstract_batch(key: BucketKey, batch: int):
  """ShapeDtypeStructs matching ``stack_batch``'s output for ``batch``
  requests — lets prewarm compile executables without materializing data."""
  if key.kind == "mmo":
    mb, kb, nb = key.shape
    (has_c,) = key.params
    shapes = [(batch, mb, kb), (batch, kb, nb)]
    if has_c:
      shapes.append((batch, mb, nb))
    return tuple(jax.ShapeDtypeStruct(s, np.dtype(dt))
                 for s, dt in zip(shapes, key.dtypes)) + (
        jax.ShapeDtypeStruct((batch,), np.dtype(np.int32)),)
  if key.kind == "closure":
    (nb,) = key.shape
    return (jax.ShapeDtypeStruct((batch, nb, nb), np.dtype(key.dtypes[0])),
            jax.ShapeDtypeStruct((batch,), np.dtype(np.int32)))
  if key.kind == "knn":
    qb, rb, db = key.shape
    return (jax.ShapeDtypeStruct((batch, qb, db), np.dtype(key.dtypes[0])),
            jax.ShapeDtypeStruct((batch, rb, db), np.dtype(key.dtypes[1])),
            jax.ShapeDtypeStruct((batch,), np.dtype(np.int32)))
  raise ValueError(f"unknown kind {key.kind!r}")


# ---------------------------------------------------------------------------
# compiled-program construction
# ---------------------------------------------------------------------------


def make_batch_fn(key: BucketKey, *, backend: str, block: tuple = (),
                  interpret: Optional[bool] = None,
                  mesh=None, schedule: str = "local"):
  """Pure jax function over the stacked operands for one bucket.

  ``backend``/``block`` are the bucket's dispatch decision (resolved once at
  batch-build time by the engine and baked into the executable-cache key), so
  a mixed-backend steady state replays stored executables and never retraces.

  ``schedule`` places the bucket: ``"local"`` runs the single-device batched
  entry points; a name from ``core.distributed.SCHEDULES`` runs the same
  contraction sharded over ``mesh`` — kspan/SUMMA/ring shard the problem
  axes, ``"dp"`` shards the request axis (independent per-device work, and
  for closures independent per-device fixpoints) — with ``backend``
  selecting each shard's local contraction path and the per-request
  ``k_valid``/``valid_n`` ragged masks carried through.
  """
  sharded = schedule != "local"
  if sharded:
    if mesh is None:
      raise ValueError(f"schedule {schedule!r} needs a mesh")
    from repro.core import distributed as dist

    def contract(a, b, c, op, kv):
      return dist.mmo_sharded_batched(a, b, c, op=op, schedule=schedule,
                                      mesh=mesh, backend=backend, block=block,
                                      interpret=interpret, k_valid=kv)
  else:

    def contract(a, b, c, op, kv):
      return mmo_batched(a, b, c, op=op, backend=backend, block=block,
                         interpret=interpret, k_valid=kv)

  if key.kind == "mmo":
    (has_c,) = key.params

    def fn(*args):
      a, b = args[0], args[1]
      c = args[2] if has_c else None
      kv = args[2 + has_c]
      return contract(a, b, c, key.op, kv)

    return fn

  if key.kind == "closure":
    (algorithm,) = key.params

    if sharded:
      # whole-solver entry point: for dp each device runs an *independent*
      # fixpoint over its own requests (straggler decoupling); for the
      # contraction schedules it swaps the squaring step for the mesh one.
      # The fused megakernel is a single-device program — a megakernel
      # decision on a mesh-routed bucket degrades to the xla shard-local
      # contraction rather than failing the batch.
      local_bk = "xla" if backend == "megakernel" else backend

      def fn(adj, valid):
        return dist.sharded_closure_batched(adj, op=key.op,
                                            algorithm=algorithm, mesh=mesh,
                                            schedule=schedule,
                                            backend=local_bk, block=block,
                                            interpret=interpret,
                                            valid_n=valid)

      return fn

    solver = (cl_mod.batched_leyzorek_closure if algorithm == "leyzorek"
              else cl_mod.batched_bellman_ford_closure)

    if backend == "megakernel":
      # fused fixpoint: the whole G-iteration chunk runs on-chip; the
      # dispatch cfg is the chunk length G (cost_table DEFAULT_CONFIGS)
      g = int(block[0]) if block else 8

      def fn(adj, valid):
        return solver(adj, op=key.op, fixpoint_backend="megakernel",
                      megakernel_g=g, interpret=interpret, valid_n=valid)

      return fn

    def mmo_fn(a, b, c, op, bk, k_valid=None):
      from repro.core.mmo import mmo as _mmo
      return _mmo(a, b, c, op=op, backend=bk, block=block,
                  interpret=interpret, k_valid=k_valid)

    def fn(adj, valid):
      return solver(adj, op=key.op, backend=backend, mmo_fn=mmo_fn,
                    valid_n=valid)

    return fn

  if key.kind == "knn":
    (k,) = key.params

    def fn(q, ref, valid):
      d2 = contract(q, jnp.swapaxes(ref, -1, -2), None, "addnorm",
                    None)  # feature dim is never padded raggedly
      # mask padded corpus rows to +inf so they lose every top-k comparison
      row_ok = jnp.arange(d2.shape[-1]) < valid[:, None]  # (R, rb)
      # repro: ignore[semiring-hardcoded-identity] — top-k mask, not a pad
      d2 = jnp.where(row_ok[:, None, :], d2, jnp.inf)
      neg, idx = jax.lax.top_k(-d2, k)
      return -neg, idx

    return fn

  raise ValueError(f"unknown kind {key.kind!r}")


def _primary_output(key: BucketKey, out):
  """The batch output array callers consume as the result value (mmo: the
  contraction itself; closure: the closed matrix; knn: the distances)."""
  return out[0] if isinstance(out, (tuple, list)) else out


def validate_finite(key: BucketKey, out, live: int):
  """NaN scan over the primary output's first ``live`` slots; returns the
  offending request-slot indices (empty = clean).

  Only NaN counts as garbage.  ±inf is a *legitimate* value in tropical
  semirings — APSP spells "unreachable" as +inf — so this is ``isnan``,
  never ``isfinite``.  Boolean/integer outputs cannot carry NaN and always
  validate clean."""
  arr = np.asarray(_primary_output(key, out))
  if not np.issubdtype(arr.dtype, np.floating) or live < 1:
    return []
  # fast path first: one NaN-propagating reduction (min carries NaN through)
  # decides clean batches — this runs on EVERY batch, so it must cost one
  # pass and no temporaries; per-slot attribution only runs on the rare hit
  if not np.isnan(np.min(arr[:live])):
    return []
  bad = np.isnan(arr[:live]).any(axis=tuple(range(1, arr.ndim)))
  return [int(i) for i in np.nonzero(bad)[0]]


def poison_output(key: BucketKey, out, slots: Sequence[int]):
  """Overwrite the primary output's ``slots`` with NaN — the fault
  injector's ``nonfinite`` point (faults.py): the engine's result
  validation must catch exactly this.  Returns a rebuilt output structure;
  non-float primaries (boolean semirings) pass through unpoisoned."""
  primary = np.asarray(_primary_output(key, out))
  if not np.issubdtype(primary.dtype, np.floating) or not len(slots):
    return out
  primary = primary.copy()
  primary[list(slots)] = np.nan
  if isinstance(out, (tuple, list)):
    return (primary,) + tuple(out[1:])
  return primary


def split_results(key: BucketKey, reqs: Sequence[ProblemRequest], out):
  """Batched program output → per-request MMOResults at true shapes."""
  results = []
  if key.kind == "mmo":
    d = np.asarray(out)
    for i, r in enumerate(reqs):
      m, _, n = r.shape
      results.append(MMOResult(value=d[i, :m, :n]))
  elif key.kind == "closure":
    closed, iters = (np.asarray(out[0]), np.asarray(out[1]))
    for i, r in enumerate(reqs):
      (n,) = r.shape
      results.append(MMOResult(value=closed[i, :n, :n],
                               extras={"iterations": int(iters[i])}))
  elif key.kind == "knn":
    d2, idx = np.asarray(out[0]), np.asarray(out[1])
    for i, r in enumerate(reqs):
      q = r.shape[0]
      results.append(MMOResult(value=d2[i, :q], extras={"indices": idx[i, :q]}))
  else:
    raise ValueError(f"unknown kind {key.kind!r}")
  return results

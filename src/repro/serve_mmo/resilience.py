"""Circuit breakers over the engine's dispatch arms — per (bucket, backend,
schedule), with cost-ranked fallback and half-open probes.

SIMD² keeps all nine semiring ops on one execution substrate, so every
bucket has *sibling arms* that compute bit-identical results: the other
local backends (xla / vector / pallas — equivalence is pinned in the core
test sweep) and, for sharded buckets, the local path itself.  When one arm
starts failing persistently — a Pallas lowering bug on one shape, a mesh
schedule wedged by a bad collective — the right response is not to fail
that bucket's traffic forever but to *re-dispatch it to the next-best arm
from the cost table* until the broken arm recovers.

Why breakers key on (bucket, backend, schedule) and not coarser:

  * per-shape fragility is real — a generated kernel can be wrong at one
    tile shape and correct everywhere else, so a breaker per backend alone
    would take down healthy buckets;
  * per-arm independence is real — the same bucket's xla and pallas
    programs share no code beyond jax itself, and its 'dp' mesh schedule
    can fail (device loss, collective timeout) while 'local' is fine.

State machine (classic three-state breaker):

  closed     → normal dispatch; ``failure_threshold`` CONSECUTIVE failures
               (any success resets the count) opens it,
  open       → the arm is skipped; picks fall through to the next arm in
               the fallback chain (ultimately the reference dense backend).
               After ``probe_after_s`` on the engine clock, the next pick
               runs ONE probe batch on the broken arm (half-open),
  half_open  → the probe batch is in flight; other picks keep using the
               fallback.  Probe success closes the breaker (traffic
               returns to the primary arm); probe failure re-opens it and
               restarts the cooldown.

The engine composes this with batch bisection (engine.py): a bisected
sub-batch's failures feed the same breakers, so a persistently-failing arm
opens *during* recovery and the retried sub-batches already land on the
fallback — innocent requests complete on the first step even when the
primary arm is dead.

Every fallback arm lives behind its own executable-cache key (the arm IS
part of the key), so breaker re-dispatch never collides with the primary's
stored programs and steady state on either arm replays without retracing.
"""
from __future__ import annotations

import threading
import time
from typing import Callable, Optional, Sequence, Tuple

from repro.serve_mmo.metrics import bucket_label

__all__ = ["CircuitBreaker", "ResilienceManager", "STATE_CLOSED",
           "STATE_OPEN", "STATE_HALF_OPEN", "STATE_GAUGE"]

STATE_CLOSED = "closed"
STATE_OPEN = "open"
STATE_HALF_OPEN = "half_open"
# serve_breaker_state gauge encoding (fixed fleet-wide, documented in HELP)
STATE_GAUGE = {STATE_CLOSED: 0, STATE_OPEN: 1, STATE_HALF_OPEN: 2}

# Arm = (backend, block cfg, schedule) — the full placement decision the
# executable-cache key carries.  Breakers ignore the block cfg (a block
# sweep is the same kernel); their identity is (bucket, backend, schedule).
Arm = Tuple[str, tuple, str]


class CircuitBreaker:
  """One arm's breaker.  Not thread-safe on its own — the manager's lock
  guards all transitions."""

  __slots__ = ("state", "consecutive_failures", "opened_at", "opens",
               "closes", "probes")

  def __init__(self):
    self.state = STATE_CLOSED
    self.consecutive_failures = 0
    self.opened_at = 0.0
    self.opens = 0
    self.closes = 0
    self.probes = 0


class ResilienceManager:
  """Breaker registry + arm picker for one engine.

  ``pick`` walks [primary] + fallbacks and returns the first usable arm
  (with ``probe=True`` when it is a half-open probe of a broken arm);
  ``on_success`` / ``on_failure`` drive the state machine and return the
  transition (if any) so the engine can trace and count it.  ``threshold``
  None disables opening entirely (failures are still counted) — the
  historical fail-in-place behavior behind one switch.
  """

  def __init__(self, *, threshold: Optional[int] = 5,
               probe_after_s: float = 0.25, clock=None):
    if threshold is not None and threshold < 1:
      raise ValueError(f"threshold must be >= 1 or None, got {threshold}")
    self.threshold = threshold
    self.probe_after_s = float(probe_after_s)
    self._clock = clock if clock is not None else time.perf_counter
    self._lock = threading.Lock()
    self._breakers: dict = {}  # (BucketKey, backend, schedule) → CircuitBreaker

  @staticmethod
  def _cell(key, arm: Arm) -> tuple:
    backend, _block, schedule = arm
    return (key, backend, schedule)

  def _get_locked(self, cell) -> CircuitBreaker:
    br = self._breakers.get(cell)
    if br is None:
      br = self._breakers[cell] = CircuitBreaker()
    return br

  # -- dispatch ---------------------------------------------------------------

  def pick(self, key, primary: Arm,
           fallbacks: Callable[[], Sequence[Arm]]) -> Tuple[Arm, bool]:
    """(arm to execute on, is_probe).  Closed arms win in chain order; an
    open arm past its cooldown converts this pick into its half-open probe;
    if every arm is broken the chain's last arm serves anyway (failing a
    probe beats failing for free, and the terminal arm is the reference
    dense backend)."""
    if self.threshold is None:
      return primary, False
    with self._lock:
      if not self._breakers:  # steady state: no arm ever failed
        return primary, False
      now = self._clock()
      chain = [primary]
      chain_built = False
      i = 0
      while True:
        if i >= len(chain):
          if chain_built:
            return chain[-1], False  # every arm broken: serve on the last
          chain.extend(a for a in fallbacks() if a not in chain)
          chain_built = True
          if i >= len(chain):
            return chain[-1], False
        arm = chain[i]
        br = self._breakers.get(self._cell(key, arm))
        if br is None or br.state == STATE_CLOSED:
          return arm, False
        if br.state == STATE_OPEN and now - br.opened_at >= self.probe_after_s:
          br.state = STATE_HALF_OPEN
          br.probes += 1
          return arm, True
        i += 1

  # -- outcomes ---------------------------------------------------------------

  def on_success(self, key, arm: Arm) -> Optional[str]:
    """A batch attempt on ``arm`` succeeded.  Returns 'close' when this was
    the probe that recovered an open breaker (else None)."""
    if self.threshold is None:
      return None
    with self._lock:
      br = self._breakers.get(self._cell(key, arm))
      if br is None:
        return None
      was_half_open = br.state == STATE_HALF_OPEN
      br.consecutive_failures = 0
      if br.state != STATE_CLOSED:
        br.state = STATE_CLOSED
        br.closes += 1
      return "close" if was_half_open else None

  def on_failure(self, key, arm: Arm) -> Optional[str]:
    """A batch attempt on ``arm`` failed.  Returns 'open' when the breaker
    newly opened (threshold reached, or a half-open probe failed)."""
    if self.threshold is None:
      return None
    with self._lock:
      br = self._get_locked(self._cell(key, arm))
      br.consecutive_failures += 1
      if br.state == STATE_HALF_OPEN:
        br.state = STATE_OPEN       # the probe failed: cooldown restarts
        br.opened_at = self._clock()
        br.opens += 1
        return "open"
      if (br.state == STATE_CLOSED
          and br.consecutive_failures >= self.threshold):
        br.state = STATE_OPEN
        br.opened_at = self._clock()
        br.opens += 1
        return "open"
      return None

  # -- reading ----------------------------------------------------------------

  def snapshot(self) -> list:
    """All breaker cells (for exposition): bucket label + arm + state +
    counters, sorted for stable output."""
    with self._lock:
      cells = [((key, backend, schedule), br.state, br.consecutive_failures,
                br.opens, br.closes, br.probes)
               for (key, backend, schedule), br in self._breakers.items()]
    out = [{
        "bucket": bucket_label(key), "backend": backend,
        "schedule": schedule, "state": state,
        "consecutive_failures": fails, "opens": opens, "closes": closes,
        "probes": probes,
    } for (key, backend, schedule), state, fails, opens, closes, probes
        in cells]
    out.sort(key=lambda c: (c["bucket"], c["backend"], c["schedule"]))
    return out

  def open_arms(self) -> list:
    """The non-closed cells — what /healthz names when it answers 503
    degraded."""
    return [c for c in self.snapshot() if c["state"] != STATE_CLOSED]

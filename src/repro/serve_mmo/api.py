"""Problem requests and result futures for the MMO serving engine.

A request carries host (numpy) arrays plus the static metadata the scheduler
buckets on; constructors normalize each of the paper's application families
onto the three executable kinds:

  'mmo'      — one raw D = C ⊕ (A ⊗ B) instruction,
  'closure'  — a semiring fixed point (APSP, reachability, reliability, MST
               bottleneck paths, …) via Leyzorek or Bellman-Ford,
  'knn'      — addnorm distance matrix + top-k.

Adjacency preparation (diagonal self values, boolean casts) happens here on
the host so the engine's compiled programs see ready, ring-correct inputs.
"""
from __future__ import annotations

import dataclasses
import threading
from typing import Optional

import numpy as np

from repro.core import closure as cl_mod
from repro.core import semiring as sr_mod

KINDS = ("mmo", "closure", "knn")
ALGORITHMS = ("leyzorek", "bellman_ford")
DEFAULT_TENANT = "default"


class RejectedError(RuntimeError):
  """The engine's admission controller refused the request at submit time
  (queue full, tenant over quota, or predicted backlog too deep); nothing
  was queued and the request will never execute."""


class DeadlineExceededError(TimeoutError):
  """The request's deadline passed while it was queued — or the scheduler
  predicted it could no longer be met and failed it fast — so the engine
  dropped it without executing."""


@dataclasses.dataclass
class ProblemRequest:
  """One serving request.  ``arrays`` are host operands; ``shape`` is the
  logical problem shape the scheduler buckets on; ``params`` are static
  extras that must match within a bucket (algorithm, k, …).

  QoS fields: ``tenant`` names the submitter for per-tenant quotas and fair
  sharing; ``priority`` is a tier (higher serves first under the deadline
  policy); ``deadline_s`` is a latency budget in seconds from submit —
  requests still queued past it fail with ``DeadlineExceededError`` instead
  of executing late.
  """

  kind: str
  op: str
  arrays: dict
  shape: tuple
  params: tuple = ()
  # QoS (set by the request constructors, read by policies + admission)
  tenant: str = DEFAULT_TENANT
  priority: int = 0
  deadline_s: Optional[float] = None
  # engine bookkeeping (assigned at submit)
  request_id: int = -1
  arrival_s: float = 0.0
  deadline_at: Optional[float] = None  # absolute engine-clock deadline
  predicted_s: float = 0.0             # admission's per-request cost charge
  # where predicted_s came from: 'static' (cost table / roofline × worst-case
  # trips), 'iterations' (static × measured convergence counts), or 'ewma'
  # (live measured service latency) — see serve_mmo/estimator.py
  predicted_source: str = "static"

  def __post_init__(self):
    if self.kind not in KINDS:
      raise ValueError(f"kind must be one of {KINDS}, got {self.kind!r}")
    if self.deadline_s is not None and not self.deadline_s > 0.0:
      raise ValueError(f"deadline_s must be positive, got {self.deadline_s}")
    sr_mod.get(self.op)  # validates the mnemonic


@dataclasses.dataclass
class MMOResult:
  """Engine output for one request: ``value`` is the primary array (D, the
  closure matrix, or the KNN distances); ``extras`` holds secondaries
  (closure iteration count, KNN indices)."""

  value: np.ndarray
  extras: dict = dataclasses.field(default_factory=dict)


class MMOFuture:
  """Async handle returned by ``MMOEngine.submit``.

  ``result()`` blocks: when the engine's background loop is running it waits
  on the completion event; otherwise it synchronously drives ``engine.step``
  until this request's bucket is flushed (lazy batched execution).

  Terminal states (``state``): 'done' (result available), 'failed'
  (execution error), 'rejected' (admission refused it at submit —
  ``RejectedError``), 'expired' (deadline passed while queued —
  ``DeadlineExceededError``); 'pending' until one of those.  ``result()``
  raises the matching error for the non-'done' terminals.
  """

  def __init__(self, engine, request: ProblemRequest):
    self._engine = engine
    self.request = request
    self._event = threading.Event()
    self._result: Optional[MMOResult] = None
    self._error: Optional[BaseException] = None
    self._state = "pending"

  # engine-side completion ---------------------------------------------------
  def _fulfill(self, result: MMOResult):
    self._result = result
    self._state = "done"
    self._event.set()

  def _fail(self, err: BaseException):
    self._error = err
    if isinstance(err, RejectedError):
      self._state = "rejected"
    elif isinstance(err, DeadlineExceededError):
      self._state = "expired"
    else:
      self._state = "failed"
    self._event.set()

  # client-side --------------------------------------------------------------
  @property
  def state(self) -> str:
    return self._state

  def done(self) -> bool:
    return self._event.is_set()

  def result(self, timeout: Optional[float] = None) -> MMOResult:
    """Engine-bug paths (a request the scheduler lost) surface as a
    RuntimeError from ``_drive``; only a genuinely elapsed ``timeout``
    raises TimeoutError."""
    if not self._event.is_set():
      self._engine._drive(self, timeout)
    if not self._event.is_set():
      within = "the allotted time" if timeout is None else f"{timeout:g}s"
      raise TimeoutError(
          f"request {self.request.request_id} not done within {within}")
    if self._error is not None:
      raise self._error
    return self._result


# ---------------------------------------------------------------------------
# request constructors
# ---------------------------------------------------------------------------


def _as2d(x, name: str) -> np.ndarray:
  x = np.asarray(x)
  if x.ndim != 2:
    raise ValueError(f"{name} must be 2-D, got shape {x.shape}")
  return x


def mmo_request(a, b, c=None, *, op: str = "mma",
                tenant: str = DEFAULT_TENANT, priority: int = 0,
                deadline_s: Optional[float] = None) -> ProblemRequest:
  """Raw D = C ⊕ (A ⊗ B) instruction request."""
  a, b = _as2d(a, "a"), _as2d(b, "b")
  if a.shape[1] != b.shape[0]:
    raise ValueError(f"contraction mismatch: {a.shape} @ {b.shape}")
  arrays = {"a": a, "b": b}
  if c is not None:
    c = _as2d(c, "c")
    if c.shape != (a.shape[0], b.shape[1]):
      raise ValueError(f"C shape {c.shape} != ({a.shape[0]},{b.shape[1]})")
    arrays["c"] = c
  return ProblemRequest(
      kind="mmo", op=op, arrays=arrays,
      shape=(a.shape[0], a.shape[1], b.shape[1]),
      params=("c" in arrays,),
      tenant=tenant, priority=priority, deadline_s=deadline_s)


def closure_request(weights, *, op: str, algorithm: str = "leyzorek",
                    prepared: bool = False,
                    tenant: str = DEFAULT_TENANT, priority: int = 0,
                    deadline_s: Optional[float] = None) -> ProblemRequest:
  """Semiring fixed-point request (APSP, reliability paths, MST, …).

  ``weights`` uses the ring's graph conventions (core/closure.py); with
  ``prepared=False`` the diagonal self values are filled in here.
  """
  if algorithm not in ALGORITHMS:
    raise ValueError(f"algorithm must be one of {ALGORITHMS}")
  w = _as2d(weights, "weights")
  if w.shape[0] != w.shape[1]:
    raise ValueError(f"adjacency must be square, got {w.shape}")
  sr = sr_mod.get(op)
  if sr.boolean:
    w = w.astype(bool)
  if not prepared:
    _, self_value = cl_mod.closure_pad_values(op)
    w = w.copy()
    np.fill_diagonal(w, True if sr.boolean else self_value)
  return ProblemRequest(kind="closure", op=op, arrays={"adj": w},
                        shape=(w.shape[0],), params=(algorithm,),
                        tenant=tenant, priority=priority,
                        deadline_s=deadline_s)


def apsp_request(weights, **kw) -> ProblemRequest:
  """All-pairs shortest paths: weights > 0, +inf where no edge."""
  return closure_request(weights, op="minplus", **kw)


def reachability_request(adj, **kw) -> ProblemRequest:
  """Transitive & reflexive closure of a boolean adjacency."""
  return closure_request(adj, op="orand", **kw)


def knn_request(queries, corpus, *, k: int,
                tenant: str = DEFAULT_TENANT, priority: int = 0,
                deadline_s: Optional[float] = None) -> ProblemRequest:
  """K-nearest corpus points per query (squared-L2, ascending)."""
  q, r = _as2d(queries, "queries"), _as2d(corpus, "corpus")
  if q.shape[1] != r.shape[1]:
    raise ValueError(f"dim mismatch: queries {q.shape} vs corpus {r.shape}")
  if not 0 < k <= r.shape[0]:
    raise ValueError(f"k={k} must be in [1, corpus rows={r.shape[0]}]")
  return ProblemRequest(kind="knn", op="addnorm",
                        arrays={"queries": q, "corpus": r},
                        shape=(q.shape[0], r.shape[0], q.shape[1]),
                        params=(k,),
                        tenant=tenant, priority=priority,
                        deadline_s=deadline_s)

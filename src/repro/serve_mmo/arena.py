"""Device-resident request arena: slot-based continuous batching for closures.

The batch path holds every closure request hostage to its bucket's full
fixpoint cycle: requests are pad-and-stacked host-side, the whole batch runs
to convergence, and an arrival during the cycle waits for the next one.  The
arena removes the cycle.  It preallocates a fixed-capacity slot buffer ON
DEVICE — a (capacity, np_, np_) iterate plus per-slot ``k_valid`` /
``active`` / ``iteration`` vectors — and serves requests by slot lifecycle:

  admit  — one ``jax.lax.dynamic_update_slice`` writes the padded adjacency
           into a free slot (no host restack of the other residents),
  tick   — ONE fused chunk launch (``kernels.closure_megakernel.
           fixpoint_chunk``) advances every live slot by up to ``g``
           iterations in place; frozen/empty slots cost one scalar test in
           the kernel's scalar-prefetched gating,
  evict  — between chunks, converged slots (active flag 0) or capped slots
           are read out, freed, and backfilled by the next admissions.

This is the ``SequenceBuffer`` continuous-batching idiom from LLM inference
runners applied to semiring fixpoints, and the same TCU-model argument the
megakernel made (operands stay resident; HBM traffic amortizes across
steps) stretched from one batch's G iterations to the engine's lifetime.

Bit-parity with the batched path is BY CONSTRUCTION, not luck:

  * layout — both paths derive padding, accumulator dtype, and slab height
    from one resolver (``chunk_geometry``), called at the BUCKET dim ``nb``
    (not the request's true n): a request admitted into the arena lands in
    a byte-identical layout to the same request stacked into a batch;
  * iteration budget — each slot carries its own remaining-trips budget
    ``clip(max_iters - it, 0, g)``, with ``max_iters`` the same
    ``fixpoint_iters(algorithm, nb)`` default the batched solver computes
    from its stack dim, so counters and caps agree exactly;
  * independence — the fused kernel never mixes data across the request
    dim, so per-slot trajectories are independent of WHEN neighboring slots
    are admitted or evicted.  Eviction happens strictly between chunk
    launches and only rewrites freed slots' host bookkeeping; live slots'
    device state is untouched.

Zero steady-state retraces: the three programs (admit / tick / read) are
AOT-compiled once per arena through the shared ``ExecutableCache`` with the
slot index and true size as *traced* int32 scalars — every admission and
eviction replays the same stored executables, countable via the cache's
miss counter (asserted in tests/test_arena.py and benchmarks/arena_bench.py).

Thread-safety: all host bookkeeping (slot table, free list, counters) and
the device-state swaps happen under the arena's own lock.  The engine's
lock order is engine → arena; the arena never calls back into the engine.
"""
from __future__ import annotations

import threading
import time
from typing import List, NamedTuple, Optional

import jax
import jax.numpy as jnp
import numpy as np

from repro.core import closure as cl_mod
from repro.kernels.closure_megakernel import (chunk_geometry, fixpoint_chunk,
                                              fixpoint_iters)
from repro.serve_mmo.api import ProblemRequest
from repro.serve_mmo.cache import ExecutableCache
from repro.serve_mmo.scheduler import BucketKey

__all__ = ["DEFAULT_CAPACITY", "DEFAULT_ARENA_G", "Eviction", "RequestArena"]

DEFAULT_CAPACITY = 8
DEFAULT_ARENA_G = 4


class Eviction(NamedTuple):
  """One request leaving its slot: the engine turns this into a result."""
  request: ProblemRequest
  slot: int
  value: np.ndarray   # true-shape (n, n) closure, bool rings decoded
  iterations: int     # measured fixpoint trip count (parity-pinned)
  admit_s: float      # when the request entered its slot (engine clock)


class RequestArena:
  """Fixed-capacity device slot buffer for ONE closure bucket.

  Every request admitted here shares the bucket's (op, algorithm, nb,
  dtype) signature; the engine keeps one arena per closure ``BucketKey``.
  ``capacity`` bounds resident requests, ``g`` is the fused chunk length
  per tick, ``max_iters`` defaults to the batched solver's own trip cap at
  the bucket dim (MUST stay nb-derived for cross-path parity).
  """

  def __init__(self, key: BucketKey, *, capacity: int = DEFAULT_CAPACITY,
               g: int = DEFAULT_ARENA_G, cache: Optional[ExecutableCache] = None,
               max_iters: Optional[int] = None,
               interpret: Optional[bool] = None, clock=None):
    if key.kind != "closure":
      raise ValueError(f"arena serves closure buckets only, got {key.kind!r}")
    if capacity < 1:
      raise ValueError(f"capacity must be >= 1, got {capacity}")
    if g < 1:
      raise ValueError(f"g must be >= 1, got {g}")
    self.key = key
    (self.nb,) = key.shape
    self.op = key.op
    (self.algorithm,) = key.params
    self.capacity = int(capacity)
    self.g = int(g)
    self.cache = cache if cache is not None else ExecutableCache()
    self._clock = clock if clock is not None else time.perf_counter
    # the bucket dim, not any request's true n: the batched reference
    # computes its default trip cap from the padded stack dim, so the arena
    # must too or capped counters diverge between the paths
    self.max_iters = (fixpoint_iters(self.algorithm, self.nb)
                      if max_iters is None else int(max_iters))
    self.geom = chunk_geometry(key.op, self.nb, key.dtypes[0],
                               interpret=interpret)
    # Bellman-Ford relaxes against the original adjacency (D ← D ⊕ D⊗A);
    # Leyzorek squares the iterate against itself and needs no second buffer
    self._has_adj = self.algorithm == "bellman_ford"

    C, np_ = self.capacity, self.geom.np_
    acc = np.dtype(self.geom.acc_dtype)
    base = np.full((np_, np_), self.geom.missing, acc)
    np.fill_diagonal(base, self.geom.self_value)
    init = jnp.asarray(np.repeat(base[None], C, axis=0))
    # device slot state — swapped wholesale under _lock by admit/tick
    self._c = init                              # (C, np_, np_) iterate
    self._adj = init if self._has_adj else None
    self._kv = jnp.zeros((C,), jnp.int32)       # per-slot true n (masked K)
    self._act = jnp.zeros((C,), jnp.int32)      # 1 = still iterating
    self._it = jnp.zeros((C,), jnp.int32)       # measured iteration counter

    # host bookkeeping — GUARDED_BY _lock (see analysis/lock_rules.py)
    self._lock = threading.RLock()
    self._slots: List[Optional[ProblemRequest]] = [None] * C
    self._admit_s: List[float] = [0.0] * C
    self._free: List[int] = list(range(C - 1, -1, -1))  # pop() → slot 0 first
    self._admitted = 0
    self._evicted = 0
    self._ticks = 0
    self._program_specs = self._build_program_specs()

  # -- AOT programs ----------------------------------------------------------

  def _build_program_specs(self) -> dict:
    """name → (make_fn, abstract args) for the three arena programs.  The
    slot index and true size are traced scalars, so one compiled executable
    serves every slot and every request size in the bucket — admissions and
    evictions never retrace."""
    C, np_ = self.capacity, self.geom.np_
    acc, i32 = self.geom.acc_dtype, jnp.int32
    has_adj = self._has_adj
    op, g, bm = self.op, self.g, self.geom.bm
    max_iters, interpret = self.max_iters, self.geom.interpret
    mat3 = jax.ShapeDtypeStruct((C, np_, np_), acc)
    vec = jax.ShapeDtypeStruct((C,), i32)
    mat2 = jax.ShapeDtypeStruct((np_, np_), acc)
    scal = jax.ShapeDtypeStruct((), i32)

    def make_admit():
      def admit(*args):
        if has_adj:
          c, adj, kv, act, it, mat, slot, n = args
        else:
          c, kv, act, it, mat, slot, n = args
          adj = None
        c = jax.lax.dynamic_update_slice(c, mat[None], (slot, 0, 0))
        if adj is not None:
          adj = jax.lax.dynamic_update_slice(adj, mat[None], (slot, 0, 0))
        kv = jax.lax.dynamic_update_slice(kv, jnp.reshape(n, (1,)), (slot,))
        act = jax.lax.dynamic_update_slice(act, jnp.ones((1,), i32), (slot,))
        it = jax.lax.dynamic_update_slice(it, jnp.zeros((1,), i32), (slot,))
        return (c, adj, kv, act, it) if adj is not None else (c, kv, act, it)
      return admit

    def make_tick():
      def tick(*args):
        if has_adj:
          c, adj, kv, act, it = args
        else:
          c, kv, act, it = args
          adj = None
        # per-slot remaining-trips budget: a slot admitted mid-stream gets
        # exactly the iterations the batched path would have given it
        glim = jnp.clip(max_iters - it, 0, g).astype(i32)
        return fixpoint_chunk(c, adj, kv, act, it, glim, op=op, g_steps=g,
                              bm=bm, interpret=interpret)
      return tick

    def make_read():
      def read(c, slot):
        return jax.lax.dynamic_slice(c, (slot, 0, 0), (1, np_, np_))[0]
      return read

    state = (mat3, mat3) if has_adj else (mat3,)
    return {
        "admit": (make_admit, state + (vec, vec, vec, mat2, scal, scal)),
        "tick": (make_tick, state + (vec, vec, vec)),
        "read": (make_read, (mat3, scal)),
    }

  def _compiled(self, name: str):
    make_fn, abstract = self._program_specs[name]
    return self.cache.get_or_compile(
        ("arena", self.key, name, self.capacity, self.g, self.max_iters),
        make_fn, abstract)

  def prewarm(self) -> None:
    """Compile all three programs; after this, arena traffic never retraces
    (the zero-recompile guarantee tests and benches assert via the shared
    cache's miss counter)."""
    for name in self._program_specs:
      self._compiled(name)

  # -- slot lifecycle --------------------------------------------------------

  def free_slots(self) -> int:
    with self._lock:
      return len(self._free)

  def live_slots(self) -> int:
    with self._lock:
      return self.capacity - len(self._free)

  def live_requests(self) -> list:
    with self._lock:
      return [r for r in self._slots if r is not None]

  def admit(self, req: ProblemRequest, *, now: Optional[float] = None) -> int:
    """Write one request into a free slot; returns the slot index.  The
    padded adjacency is built host-side (one small H2D), then a single
    dynamic_update_slice lands it — neighboring residents never restack."""
    n = int(req.shape[0])
    if n > self.nb:
      raise ValueError(f"request n={n} exceeds arena bucket nb={self.nb}")
    mat = np.asarray(cl_mod.pad_adjacency(req.arrays["adj"], self.geom.np_,
                                          op=self.op))
    if self.geom.was_bool:
      mat = mat.astype(np.float32)
    mat = np.asarray(mat, dtype=np.dtype(self.geom.acc_dtype))
    with self._lock:
      if not self._free:
        raise RuntimeError(
            f"arena full: {self.capacity} slots live — the engine must "
            f"bound admissions by free_slots()")
      slot = self._free.pop()
      fn = self._compiled("admit")
      if self._has_adj:
        self._c, self._adj, self._kv, self._act, self._it = fn(
            self._c, self._adj, self._kv, self._act, self._it,
            mat, np.int32(slot), np.int32(n))
      else:
        self._c, self._kv, self._act, self._it = fn(
            self._c, self._kv, self._act, self._it,
            mat, np.int32(slot), np.int32(n))
      self._slots[slot] = req
      self._admit_s[slot] = self._clock() if now is None else now
      self._admitted += 1
      return slot

  def tick(self) -> bool:
    """One fused chunk over the whole slot buffer (≤ g iterations per live
    slot, in place).  Returns False without launching when nothing is live.
    Dispatch is async — ``sweep`` is the synchronization point."""
    with self._lock:
      if len(self._free) == self.capacity:
        return False
      fn = self._compiled("tick")
      if self._has_adj:
        self._c, self._it, self._act = fn(self._c, self._adj, self._kv,
                                          self._act, self._it)
      else:
        self._c, self._it, self._act = fn(self._c, self._kv, self._act,
                                          self._it)
      self._ticks += 1
      return True

  def sweep(self) -> List[Eviction]:
    """Evict every occupied slot that converged (active flag 0) or hit the
    trip cap: read its closure out, free the slot for backfill.  Runs
    strictly between chunk launches, so live slots' device state is never
    touched — the bit-parity invariant.  Freed slots need no device write:
    their stale flags are inert (the next tick's budget clips to 0 compute)
    until an admission reseeds them."""
    with self._lock:
      act = np.asarray(self._act)  # blocks on the tick — the one sync point
      it = np.asarray(self._it)
      read = self._compiled("read")
      evictions = []
      for slot, req in enumerate(self._slots):
        if req is None:
          continue
        if act[slot] != 0 and it[slot] < self.max_iters:
          continue
        n = int(req.shape[0])
        value = np.asarray(read(self._c, np.int32(slot)))[:n, :n]
        if self.geom.was_bool:
          value = value > 0.5
        evictions.append(Eviction(request=req, slot=slot, value=value,
                                  iterations=int(it[slot]),
                                  admit_s=self._admit_s[slot]))
        self._slots[slot] = None
        self._free.append(slot)
        self._evicted += 1
      return evictions

  def reset(self) -> list:
    """Abandon all resident requests (tick-failure recovery): zero the
    per-slot flags, free every slot, and return the forfeited requests for
    the engine to fail.  The iterate buffer itself needs no wipe — admission
    overwrites a slot's matrix wholesale."""
    with self._lock:
      live = [r for r in self._slots if r is not None]
      self._slots = [None] * self.capacity
      self._admit_s = [0.0] * self.capacity
      self._free = list(range(self.capacity - 1, -1, -1))
      self._kv = jnp.zeros_like(self._kv)
      self._act = jnp.zeros_like(self._act)
      self._it = jnp.zeros_like(self._it)
      return live

  def stats(self) -> dict:
    with self._lock:
      live = self.capacity - len(self._free)
      return {"capacity": self.capacity, "live": live,
              "free": len(self._free), "admitted": self._admitted,
              "evicted": self._evicted, "ticks": self._ticks,
              "g": self.g, "max_iters": self.max_iters}

"""The MMO serving engine: continuous micro-batching over shape buckets.

One engine owns a policy-driven bucket scheduler (FIFO by default; deadline
and fair-share policies via ``policy=`` — see serve_mmo/policy.py), an
admission controller (``max_queue`` / ``tenant_quota`` / ``max_backlog_s``
— see serve_mmo/admission.py), a live metrics registry
(``engine.metrics_snapshot()`` works mid-run from any thread — see
serve_mmo/metrics.py), an AOT executable cache, and the request
bookkeeping.  Two ways to run it:

  * synchronous — ``submit()`` then ``step()`` / ``run_until_idle()`` (or
    just ``future.result()``, which drives steps lazily).  Deterministic;
    what the benchmarks and tests use.
  * background loop — ``start()`` spawns a serving thread that batches
    whatever is queued as fast as it drains; ``submit()`` is then fully
    async and ``future.result()`` blocks on the completion event.  What the
    open-loop traffic driver (launch/serve_mmo.py) uses.

Batches execute OUTSIDE the queue lock: a long closure batch never blocks
concurrent ``submit`` calls — the continuous-batching property that lets
arrivals pile into the next batch while the current one runs.
"""
from __future__ import annotations

import dataclasses
import math
import threading
import time
from typing import Optional

import jax
import numpy as np

from repro.serve_mmo import batching
from repro.serve_mmo.admission import AdmissionController
from repro.serve_mmo.api import (DeadlineExceededError, MMOFuture, MMOResult,
                                 ProblemRequest, RejectedError)
from repro.serve_mmo.arena import (DEFAULT_ARENA_G, DEFAULT_CAPACITY,
                                   RequestArena)
from repro.serve_mmo.cache import ExecutableCache
from repro.serve_mmo.estimator import Estimate, ServiceEstimator
from repro.serve_mmo.faults import (ARM_FAILURE_KINDS, BatchTimeoutError,
                                    InjectedFault, NonFiniteResultError,
                                    classify_failure)
from repro.serve_mmo.metrics import ServeMetrics, bucket_label
from repro.serve_mmo.observability import (DEFAULT_TRACE_CAPACITY,
                                           FlightRecorder)
from repro.serve_mmo.resilience import ResilienceManager
from repro.serve_mmo.scheduler import (BucketScheduler, MIN_BUCKET,
                                       bucket_dim, contract_shape,
                                       request_bucket)

# the arena's (backend, block, schedule) identity for breaker/estimator
# accounting: one arm per closure bucket, never re-dispatched (per-slot
# state isolates poisoned requests instead of bisection)
_ARENA_ARM = ("arena", (), "local")


@dataclasses.dataclass
class RequestRecord:
  request_id: int
  kind: str
  op: str
  bucket: tuple
  batch_size: int
  arrival_s: float
  scheduled_s: float
  completed_s: float

  @property
  def latency_s(self) -> float:
    return self.completed_s - self.arrival_s

  @property
  def queue_s(self) -> float:
    return self.scheduled_s - self.arrival_s


@dataclasses.dataclass
class EngineStats:
  completed: int
  batches: int
  mean_batch: float
  latencies_s: np.ndarray
  cache: dict
  rejected: int = 0
  expired: int = 0

  def percentile(self, q: float) -> float:
    if len(self.latencies_s) == 0:
      return float("nan")
    return float(np.percentile(self.latencies_s, q))

  def summary(self) -> str:
    # must stay printable for an engine that served nothing (zero batches,
    # zero records, all-rejected runs): percentiles report n/a, never a
    # formatting error or division by zero
    if len(self.latencies_s):
      lat = (f"p50={self.percentile(50) * 1e3:.1f}ms "
             f"p99={self.percentile(99) * 1e3:.1f}ms")
    else:
      lat = "p50=n/a p99=n/a"
    return (f"completed={self.completed} batches={self.batches} "
            f"mean_batch={self.mean_batch:.2f} {lat} "
            f"rejected={self.rejected} expired={self.expired} "
            f"cache_hits={self.cache['hits']} "
            f"cache_misses={self.cache['misses']}")


class MMOEngine:
  """Serving engine for semiring problem requests (see api.py).

  ``backend="auto"`` resolves backend *and* block config per bucket from the
  cost table (``cost_table=`` argument, else the process-global table — see
  repro.tuning.dispatch) at batch-build time.  Decisions are memoized per
  bucket and baked into the executable-cache key, so a mixed-backend steady
  state replays one stored executable per (bucket, batch) and never retraces
  even if the global table is later mutated.

  With a ``mesh``, a second routing layer places each bucket: batches whose
  per-request contraction exceeds ``shard_flops`` execute as a batched
  distributed schedule (core.distributed SUMMA / kspan / ring) across the
  mesh, smaller buckets keep the single-device path.  ``schedule="auto"``
  picks the schedule from the cost table's mesh rows (roofline-prior fallback
  when unmeasured); a schedule name pins it.  The (schedule, mesh) placement
  is part of the executable-cache key, so sharded and local executables never
  collide and sharded steady state replays stored executables too.

  QoS: ``policy`` selects the scheduling policy ('fifo' — the default and
  the historical behavior, 'deadline', 'fair', or a SchedulingPolicy
  instance); ``max_queue`` / ``tenant_quota`` / ``max_backlog_s`` configure
  admission control (all-None = admit everything, the historical behavior);
  requests carrying ``deadline_s`` that are still queued past their deadline
  fail with ``DeadlineExceededError`` under every policy.  ``clock`` injects
  a monotonic time source for the engine's arrival/deadline/metrics
  bookkeeping (tests use a synthetic clock; the default is
  ``time.perf_counter``).

  Adaptive QoS: the engine always *records* live feedback — every batch's
  measured service latency and every closure batch's measured convergence
  counts feed a per-(bucket, backend, schedule) EWMA estimator
  (serve_mmo/estimator.py).  With ``adaptive=True``,
  ``predict_request_seconds`` — the one number deadline feasibility,
  backlog admission, and the batch cap all consume — answers from that
  estimator (warm EWMA > static cost × measured iterations > static cost ×
  worst-case trips) instead of the static cost table alone, so predictions
  track the actual device under load.  ``max_batch_seconds`` arms the
  service-time batch cap: while deadline-tagged traffic is active, bulk
  batches are bounded to ~that many predicted seconds so an urgent arrival
  never waits a full max_batch service time behind one (see
  ``SchedulingPolicy.batch_cap``).  Neither knob changes dispatch decisions
  or executable-cache keys, so steady state still never retraces.

  Observability: the engine stamps request-lifecycle spans (submit,
  queued, batch pick, pad-and-stack, compile, device compute, split, done/
  expired/failed) into a bounded flight recorder
  (serve_mmo/observability.py; ``trace=False`` turns it off,
  ``export_trace()`` returns Chrome trace-event JSON), measures every
  batch's host vs device time breakdown into the metrics registry, and
  assembles ``observability_state()`` — the snapshot the Prometheus
  renderer (serve_mmo/exposition.py) and the HTTP endpoint
  (serve_mmo/httpd.py) serve.  Tracing is on by default; its steady-state
  overhead is asserted < 5% in benchmarks/serve_bench.py.

  Fault tolerance (DESIGN.md §Fault tolerance): a failed batch no longer
  fails every co-batched future.  The recovery driver retries the failed
  sub-batch under ``transient_retries`` with exponential backoff
  (``retry_backoff_s``), then bisects it (``bisect=True``) so a single
  poisoned request costs O(log B) extra launches and fails alone while
  its siblings complete.  Per-(bucket, backend, schedule) circuit breakers
  (``breaker_threshold`` consecutive failures open one; ``None`` disables;
  serve_mmo/resilience.py) re-dispatch a persistently-failing arm's
  traffic to cost-ranked sibling arms — ultimately the reference dense
  backend — behind their own executable-cache keys, with a half-open
  probe batch after ``breaker_probe_s`` to recover.  Batch outputs are
  validated for NaNs before futures fulfill (``validate_results``;
  ±inf is legitimate tropical output), ``watchdog_s`` bounds a hung
  device computation (the batch fails instead of wedging the loop), and
  ``faults`` accepts a deterministic ``FaultInjector``
  (serve_mmo/faults.py) that exercises every one of these paths on the
  real code path.  Every retry/bisection/breaker transition lands in the
  flight recorder and the Prometheus surfaces.

  Continuous batching (DESIGN.md §Request arena): ``mode="arena"`` serves
  closure buckets from a device-resident slot buffer (serve_mmo/arena.py)
  instead of bucket-cycle batches — requests are admitted into free slots
  the moment they arrive, every live slot advances ``arena_g`` fused
  iterations per tick, and converged slots evict and backfill between
  ticks without retracing.  Non-closure buckets keep the batch path.
  Outputs and iteration counts stay bit-identical to ``mode="batch"``
  (pinned on the shared parity corpus in tests/test_arena.py); what
  changes is the waiting: an urgent arrival joins the running fixpoint at
  the next tick boundary instead of queueing behind a full bucket cycle.
  """

  def __init__(self, *, backend: str = "auto", max_batch: int = 8,
               min_bucket: int = MIN_BUCKET,
               interpret: Optional[bool] = None,
               cost_table=None, mesh=None, schedule: str = "auto",
               shard_flops: float = 1e8,
               policy="fifo", max_queue: Optional[int] = None,
               tenant_quota=None, max_backlog_s: Optional[float] = None,
               admission: Optional[AdmissionController] = None,
               clock=None, metrics_window: int = 512,
               adaptive: bool = False,
               estimator: Optional[ServiceEstimator] = None,
               max_batch_seconds: Optional[float] = None,
               deadline_lookback_s: Optional[float] = None,
               trace: bool = True,
               trace_capacity: int = DEFAULT_TRACE_CAPACITY,
               tracer: Optional[FlightRecorder] = None,
               faults=None, transient_retries: int = 1,
               retry_backoff_s: float = 0.002, bisect: bool = True,
               breaker_threshold: Optional[int] = 5,
               breaker_probe_s: float = 0.25,
               watchdog_s: Optional[float] = None,
               validate_results: bool = True,
               fallback_backends=None,
               resilience: Optional[ResilienceManager] = None,
               mode: str = "batch",
               arena_capacity: int = DEFAULT_CAPACITY,
               arena_g: int = DEFAULT_ARENA_G):
    from repro.core import distributed as dist
    valid_schedules = ("auto", "local") + dist.SCHEDULES
    if schedule not in valid_schedules:
      raise ValueError(f"unknown schedule {schedule!r}; one of "
                       f"{valid_schedules}")
    if mode not in ("batch", "arena"):
      raise ValueError(f"unknown mode {mode!r}; one of ('batch', 'arena')")
    if mesh is None and schedule not in ("auto", "local"):
      raise ValueError(f"schedule {schedule!r} needs a mesh")
    self.backend = backend
    self.interpret = interpret
    self.cost_table = cost_table
    self.mesh = mesh
    self.schedule = schedule
    self.shard_flops = float(shard_flops)
    self._mesh_sig = None if mesh is None else tuple(
        (a, int(mesh.shape[a])) for a in mesh.axis_names)
    self._clock = clock if clock is not None else time.perf_counter
    self._decisions: dict = {}  # BucketKey → (backend, block cfg)
    self._schedules: dict = {}  # BucketKey → 'local' | distributed schedule
    self._static_cost: dict = {}  # BucketKey → (contraction s, worst trips)
    self.adaptive = bool(adaptive)
    self.estimator = estimator if estimator is not None else ServiceEstimator()
    self.scheduler = BucketScheduler(policy=policy, min_bucket=min_bucket,
                                     max_batch=max_batch, clock=self._clock,
                                     max_batch_seconds=max_batch_seconds,
                                     deadline_lookback_s=deadline_lookback_s)
    self.scheduler.predict_seconds = self.predict_request_seconds
    if admission is None:
      admission = AdmissionController(max_queue=max_queue,
                                      tenant_quota=tenant_quota,
                                      max_backlog_s=max_backlog_s)
    self.admission = admission
    self.metrics = ServeMetrics(clock=self._clock, window=metrics_window)
    self.tracer = tracer if tracer is not None else FlightRecorder(
        capacity=trace_capacity, clock=self._clock, enabled=trace)
    self.cache = ExecutableCache()
    # -- fault tolerance (DESIGN.md §Fault tolerance) -----------------------
    if transient_retries < 0:
      raise ValueError(f"transient_retries must be >= 0, "
                       f"got {transient_retries}")
    self.faults = faults
    self.transient_retries = int(transient_retries)
    self.retry_backoff_s = float(retry_backoff_s)
    self.bisect = bool(bisect)
    self.watchdog_s = None if watchdog_s is None else float(watchdog_s)
    self.validate_results = bool(validate_results)
    self.fallback_backends = (None if fallback_backends is None
                              else tuple(fallback_backends))
    if resilience is None:
      resilience = ResilienceManager(threshold=breaker_threshold,
                                     probe_after_s=breaker_probe_s,
                                     clock=self._clock)
    self.resilience = resilience
    self._fallback_arms_memo: dict = {}  # BucketKey → tuple of arms
    # -- continuous batching (DESIGN.md §Request arena) ---------------------
    self.mode = mode
    self.arena_capacity = int(arena_capacity)
    self.arena_g = int(arena_g)
    self._arenas: dict = {}          # BucketKey → RequestArena
    self._arena_failures: dict = {}  # BucketKey → consecutive tick failures
    self._lock = threading.RLock()
    self._work = threading.Condition(self._lock)
    self._idle = threading.Condition(self._lock)  # signaled: _pending empty
    self._records: list[RequestRecord] = []
    self._batches = 0
    self._rejected = 0
    self._expired = 0
    self._next_id = 0
    self._pending: dict[int, MMOFuture] = {}
    self._inflight: set[int] = set()  # popped from the queue, executing now
    self._thread: Optional[threading.Thread] = None
    self._running = False
    self._stopped = False  # stop() was called; submit refuses until start()

  # -- submission ------------------------------------------------------------

  @staticmethod
  def _iteration_factor(key) -> float:
    """Contractions one request in this bucket runs: 1 for mmo/knn, the
    solver's worst-case trip count for closures (Leyzorek squares ~lg(nb)
    times, Bellman-Ford relaxes up to nb−1 times).  The cost-table row is
    one contraction; service predictions must scale by this or closure
    buckets look log-to-linear-factors cheaper than they are."""
    if key.kind != "closure":
      return 1.0
    (nb,) = key.shape
    (algorithm,) = key.params
    if algorithm == "bellman_ford":
      return float(max(1, nb - 1))
    return float(max(1, math.ceil(math.log2(nb))))

  def _static_point(self, key) -> tuple:
    """(per-contraction seconds, worst-case trips) for one bucket — the
    static prior the adaptive path corrects.  The per-contraction answer is
    ``tuning.dispatch.contraction_seconds`` (measured cost-table row when
    someone benchmarked the point — for a fixed ``backend`` the table is
    consulted for that backend's rows too — else the roofline prior);
    memoized per bucket under the engine lock like the dispatch decision
    itself."""
    with self._lock:
      memo = self._static_cost.get(key)
      if memo is None:
        m, k, n = contract_shape(key)
        from repro.tuning import dispatch as _dispatch
        # arena-mode closure buckets execute on the arena arm, so their
        # static prior prices slot-seconds there (the fused-chunk roofline —
        # see tuning/cost_table.py), not whatever the batch path would pick
        backend = ("arena" if self.mode == "arena" and key.kind == "closure"
                   else self.backend)
        _, _, s = _dispatch.contraction_seconds(
            key.op, m, k, n, key.dtypes[0], backend=backend,
            table=self.cost_table)
        memo = (s, self._iteration_factor(key))
        self._static_cost[key] = memo
      return memo

  def predict_request(self, key) -> Estimate:
    """Predicted service seconds for ONE request of this bucket, with its
    provenance.  Batch compute scales linearly with occupied slots, so this
    is also the request's marginal contribution to a batch and to queue
    backlog — what the deadline policy's feasibility check (a lower bound
    on the serving batch's duration), the admission controller's backlog
    accounting, and the service-time batch cap all consume.

    Non-adaptive engines answer the static prediction (per-contraction cost
    × the bucket's worst-case trip count) — the historical behavior.
    Adaptive engines route through the EWMA estimator, which prefers warm
    measured service latency, then static cost × measured convergence
    counts, then the static prediction."""
    contraction_s, trips = self._static_point(key)
    if not self.adaptive:
      return Estimate(contraction_s * trips, "static")
    if self.mode == "arena" and key.kind == "closure":
      # the arena's estimator cell holds measured slot-seconds per request
      # (admit → evict), observed at eviction — exactly the residency the
      # admission controller charges for
      backend, schedule = _ARENA_ARM[0], _ARENA_ARM[2]
      return self.estimator.predict(key, backend, schedule, contraction_s,
                                    trips)
    with self._lock:
      backend, _ = self.resolve_backend(key)
      schedule = self.resolve_schedule(key)
    return self.estimator.predict(key, backend, schedule, contraction_s,
                                  trips)

  def predict_request_seconds(self, key) -> float:
    """``predict_request`` without the provenance — the scheduler hook."""
    return self.predict_request(key).seconds

  def submit(self, req: ProblemRequest) -> MMOFuture:
    """Queue one request; returns its future.  Admission may refuse — the
    future then arrives already failed with ``RejectedError`` (state
    'rejected') and nothing was queued.  Raises RuntimeError after
    ``stop()`` (submit-after-stop is an error, not a silent queue-forever)."""
    fut = MMOFuture(self, req)
    with self._work:
      if self._stopped:
        raise RuntimeError(
            "submit() on a stopped engine: stop() shut the serving loop "
            "down; call start() to resume accepting requests")
      req.request_id = self._next_id
      self._next_id += 1
      req.arrival_s = self._clock()
      if req.deadline_s is not None and req.deadline_at is None:
        req.deadline_at = req.arrival_s + float(req.deadline_s)
      cost = 0.0
      if self.admission.max_backlog_s is not None:
        key = request_bucket(req, self.scheduler.min_bucket)
        est = self.predict_request(key)
        cost = est.seconds
        req.predicted_source = est.source
      verdict = self.admission.try_admit(req, cost_s=cost)
      if verdict is not None:
        kind, reason = verdict
        self._rejected += 1
        self.metrics.on_reject(kind)
        self.tracer.request_rejected(req.request_id, kind, kind=req.kind,
                                     op=req.op, tenant=req.tenant,
                                     t_s=req.arrival_s)
        fut._fail(RejectedError(
            f"request {req.request_id} ({req.kind}/{req.op}) rejected: "
            f"{reason}"))
        return fut
      self.metrics.on_submit()
      self.tracer.request_begin(req.request_id, kind=req.kind, op=req.op,
                                tenant=req.tenant, t_s=req.arrival_s)
      self.scheduler.add(req)
      self._pending[req.request_id] = fut
      self._work.notify()
    return fut

  def pending(self) -> int:
    with self._lock:
      return len(self._pending)

  # -- execution -------------------------------------------------------------

  @staticmethod
  def _batch_bucket(r: int) -> int:
    """Round the batch size up to a power of two: the request axis is shape-
    bucketed exactly like the problem axes, so one bucket spawns at most
    log2(max_batch)+1 executables instead of one per arrival count."""
    return bucket_dim(r, 1)

  def resolve_backend(self, key) -> tuple:
    """(backend, block cfg) for one bucket — the dispatch decision.

    Memoized: the first resolution a bucket ever gets is the one it keeps
    for this engine's lifetime (stable executable-cache keys).  The whole
    check-resolve-memoize sequence holds the engine lock: ``prewarm`` on the
    caller thread and ``step`` on the background loop race here, and an
    unsynchronized dict could memoize two divergent decisions if the global
    cost table moved between their resolutions.
    """
    with self._lock:
      dec = self._decisions.get(key)
      if dec is None:
        if self.backend != "auto":
          dec = (self.backend, ())
        else:
          from repro.tuning import dispatch as _dispatch
          m, k, n = contract_shape(key)
          # closure buckets own a whole fixpoint, so the fused 'megakernel'
          # arm competes for them (and only them: a single-contraction
          # bucket can't run it).  The choice flows into _exec_key via the
          # (backend, block) slots, so cached executables stay distinct.
          pool = (_dispatch.CLOSURE_BACKENDS if key.kind == "closure"
                  else None)
          d = _dispatch.resolve(key.op, m, k, n, key.dtypes[0],
                                table=self.cost_table, backends=pool)
          dec = (d.backend, d.cfg)
        self._decisions[key] = dec
      return dec

  def resolve_schedule(self, key) -> str:
    """Mesh placement for one bucket: 'local' or a distributed schedule name.

    Memoized under the engine lock like ``resolve_backend`` (stable cache
    keys); without a mesh every bucket is 'local'.
    """
    with self._lock:
      sched = self._schedules.get(key)
      if sched is None:
        sched = self._route(key)
        self._schedules[key] = sched
      return sched

  def _route(self, key) -> str:
    """The size-threshold router: buckets whose per-request contraction
    exceeds ``shard_flops`` go to the mesh, the rest stay local.  Above the
    threshold, a pinned ``schedule`` is used as-is (when it divides onto the
    mesh); ``"auto"`` asks the cost table's mesh rows (roofline-prior
    fallback) whether a distributed schedule actually beats the local path.
    Closure buckets only consider dp (independent per-device fixpoints — the
    straggler-decoupling schedule) and SUMMA (the one contraction schedule
    whose iterate stays sharded in place across squarings)."""
    if self.mesh is None or self.schedule == "local":
      return "local"
    m, k, n = contract_shape(key)
    if 2.0 * m * k * n < self.shard_flops:
      return "local"
    from repro.core import distributed as dist
    fits = [s for s in dist.SCHEDULES
            if dist.schedule_fits(s, m, k, n, self.mesh)]
    if key.kind == "closure":
      fits = [s for s in fits if s in ("dp", "summa")]
    if self.schedule != "auto":
      return self.schedule if self.schedule in fits else "local"
    if not fits:
      return "local"
    from repro.tuning import dispatch as _dispatch
    mesh_dims = tuple(s for _, s in self._mesh_sig)
    d = _dispatch.resolve(key.op, m, k, n, key.dtypes[0],
                          table=self.cost_table, mesh_shape=mesh_dims,
                          schedules=tuple(fits))
    return d.backend if d.backend in fits else "local"

  def resolve_placement(self, key, rb: Optional[int] = None) -> tuple:
    """(backend, block cfg, schedule) — the full per-bucket decision.  The
    backend doubles as each shard's local contraction path when the bucket
    is routed to the mesh.  With ``rb`` (the padded batch size), dp falls
    back to 'local' for batches that don't divide over the mesh's devices —
    a per-(bucket, rb) refinement, deterministic because rb is part of the
    executable-cache key."""
    backend, block = self.resolve_backend(key)
    schedule = self.resolve_schedule(key)
    if (schedule == "dp" and rb is not None
        and rb % self.mesh.size != 0):
      schedule = "local"
    return backend, block, schedule

  def _exec_key(self, key, rb: int, backend: str, block: tuple,
                schedule: str) -> tuple:
    """Executable-cache key: placement included, so a bucket's sharded and
    local programs (or programs for two different meshes) never collide."""
    return (key, rb, backend, block, schedule,
            None if schedule == "local" else self._mesh_sig)

  def _expire_locked(self, reqs) -> None:
    """Fail requests whose deadline passed while queued (or that the policy
    failed fast as hopeless).  Engine lock held by the caller."""
    self._expired += len(reqs)
    for r in reqs:
      self.admission.on_dequeue(r)
      self.admission.on_done(r)
      self.metrics.on_expire(request_bucket(r, self.scheduler.min_bucket))
      self.tracer.request_end(r.request_id, "expired", executing=False)
      fut = self._pending.pop(r.request_id, None)
      if fut is not None:
        fut._fail(DeadlineExceededError(
            f"request {r.request_id} ({r.kind}/{r.op}) missed its "
            f"{r.deadline_s:g}s deadline while queued"))
    if not self._pending:
      self._idle.notify_all()

  def step(self) -> int:
    """Serve one engine step; returns #requests completed.  Batch mode
    schedules + executes one bucket batch.  Arena mode admits queued
    closure requests into free slots, ticks every live arena, and completes
    evictions (non-closure traffic still batches)."""
    if self.mode == "arena":
      return self._step_arena()
    return self._step_batch()

  def _step_batch(self) -> int:
    """Schedule + execute one bucket batch; returns #requests completed.
    Requests whose deadline lapsed in the queue are failed here (the
    scheduler diverts them out of the batch) without costing a batch slot."""
    with self._lock:
      picked = self.scheduler.next_batch(now=self._clock())
      expired = self.scheduler.take_expired()
      if expired:
        self._expire_locked(expired)
      if picked is None:
        return 0
      key, reqs = picked
      for r in reqs:
        self.admission.on_dequeue(r)
      self._inflight.update(r.request_id for r in reqs)
    scheduled_s = self._clock()
    try:
      return self._serve_batch(key, reqs, scheduled_s)
    except Exception as e:  # noqa: BLE001 — recovery-driver bug safety net:
      # whatever went wrong inside the driver itself, never leak in-flight
      # requests (a wedged future blocks result() forever)
      with self._lock:
        leaked = [r for r in reqs if r.request_id in self._inflight]
      self._fail_requests(key, leaked, e)
      self.tracer.instant("batch_fail", cat="batch",
                          args={"bucket": bucket_label(key),
                                "error": type(e).__name__})
      return 0

  def _fail_requests(self, key, reqs, exc) -> None:
    """Terminally fail ``reqs`` with ``exc``: the once-per-request final
    accounting (inflight, admission, metrics, future).  Trace emission is
    the caller's job — the recovery driver already closed these requests'
    execute slices with outcome 'failed'."""
    with self._lock:
      for r in reqs:
        self._inflight.discard(r.request_id)
        self.admission.on_done(r)
        self.metrics.on_fail(key)
        fut = self._pending.pop(r.request_id, None)
        if fut is not None:
          fut._fail(exc)
      if not self._pending:
        self._idle.notify_all()

  # -- arena mode (DESIGN.md §Request arena) ---------------------------------

  def _arena_for_locked(self, key) -> RequestArena:
    """One arena per closure bucket, created lazily.  Engine lock held."""
    arena = self._arenas.get(key)
    if arena is None:
      arena = RequestArena(key, capacity=self.arena_capacity, g=self.arena_g,
                           cache=self.cache, interpret=self.interpret,
                           clock=self._clock)
      self._arenas[key] = arena
      self._arena_failures[key] = 0
    return arena

  def _arena_live_locked(self) -> bool:
    """Whether any arena holds resident requests.  Engine lock held; part
    of every drain condition — scheduler-empty alone no longer means idle."""
    return any(a.live_slots() for a in self._arenas.values())

  def _step_arena(self) -> int:
    """One arena-mode step: admit → (batch fallback) → tick/evict."""
    batch_head = self._arena_admit_phase()
    completed = 0
    if batch_head:
      # the policy's chosen bucket is not closure traffic: serve it through
      # the unchanged batch path so mixed workloads keep working
      completed += self._step_batch()
    completed += self._arena_tick_phase()
    return completed

  def _arena_admit_phase(self) -> bool:
    """Move queued closure requests into free arena slots, respecting the
    policy's bucket order.  Returns True when the queue head is non-closure
    (the caller then runs one batch step).  Admission stops at a full
    arena — its slots free up at the next sweep, so progress is guaranteed
    without ever popping more requests than there are slots."""
    while True:
      with self._lock:
        now = self._clock()
        key = self.scheduler.peek_bucket(now)
        if key is None:
          return False
        if key.kind != "closure":
          return True
        arena = self._arena_for_locked(key)
        free = arena.free_slots()
        if free <= 0:
          return False
        taken = self.scheduler.take_from(key, free, now=now)
        expired = self.scheduler.take_expired()
        if expired:
          self._expire_locked(expired)
        label = bucket_label(key)
        for r in taken:
          self.admission.on_dequeue(r)
          self._inflight.add(r.request_id)
          slot = arena.admit(r, now=self._clock())
          if self.tracer.enabled:
            self.tracer.arena_admit(r.request_id, slot=slot, bucket=label)

  def _arena_tick_phase(self) -> int:
    """Tick every arena with live slots, then complete its evictions."""
    with self._lock:
      arenas = [(k, a) for k, a in self._arenas.items() if a.live_slots()]
    completed = 0
    for key, arena in arenas:
      completed += self._tick_arena(key, arena)
    return completed

  def _tick_arena(self, key, arena) -> int:
    """One tick of one arena: fault hooks, the fused chunk launch, the
    eviction sweep, and the attempt-scoped accounting (metrics, breaker,
    tracer) the batch path's ``_attempt`` does per launch."""
    label = bucket_label(key)
    rids = [r.request_id for r in arena.live_requests()]
    if not rids:
      return 0
    t0 = self._clock()
    try:
      slow_rule = None
      if self.faults is not None:
        if self.faults.check("execute", label=label, backend="arena",
                             request_ids=rids):
          raise InjectedFault("execute", label)
        slow_rule = self.faults.check("slow", label=label, backend="arena",
                                      request_ids=rids)

      def run():
        if slow_rule is not None:
          time.sleep(slow_rule.delay_s)
        arena.tick()
        return arena.sweep()  # blocks on the tick's device flags

      evictions = self._call_with_watchdog(run, label)
    except Exception as e:  # noqa: BLE001 — classified + retried below
      self._arena_tick_failed(key, arena, e)
      return 0
    t1 = self._clock()
    transition = self.resilience.on_success(key, _ARENA_ARM)
    if self.tracer.enabled and transition == "close":
      self.tracer.instant("breaker_close", cat="resilience",
                          args={"bucket": label, "backend": "arena",
                                "schedule": "local"})
    with self._lock:
      self._arena_failures[key] = 0
      self._batches += 1
      self.metrics.on_batch(key, host_s=0.0, device_s=t1 - t0, h2d_bytes=0)
    if self.tracer.enabled:
      self.tracer.arena_tick(label, live=len(rids), evicted=len(evictions),
                             g=arena.g, t0_s=t0, t1_s=t1)
    return self._finish_evictions(key, arena, evictions, label)

  def _arena_tick_failed(self, key, arena, exc) -> None:
    """Tick failure recovery: slots stay resident under the transient-retry
    budget (the next step retries the whole tick); once the budget is spent
    every resident request fails together and the arena resets.  There is
    no bisection here — per-slot state already isolates poisoned requests
    (a NaN slot fails alone at eviction), so a tick-level failure is by
    construction arm-wide, not request-specific."""
    label = bucket_label(key)
    kind = classify_failure(exc, "execute")
    self.metrics.on_batch_failure(kind)
    if kind in ARM_FAILURE_KINDS:
      transition = self.resilience.on_failure(key, _ARENA_ARM)
      if self.tracer.enabled and transition == "open":
        self.tracer.instant("breaker_open", cat="resilience",
                            args={"bucket": label, "backend": "arena",
                                  "schedule": "local", "kind": kind})
    with self._lock:
      self._arena_failures[key] = self._arena_failures.get(key, 0) + 1
      failures = self._arena_failures[key]
    if failures <= self.transient_retries:
      self.metrics.on_retry()
      backoff = self.retry_backoff_s * (2.0 ** min(failures - 1, 3))
      if backoff > 0.0:
        time.sleep(backoff)
      return
    with self._lock:
      self._arena_failures[key] = 0
    victims = arena.reset()
    if self.tracer.enabled:
      for r in victims:
        self.tracer.request_end(r.request_id, "failed", executing=True)
      self.tracer.instant("batch_fail", cat="batch",
                          args={"bucket": label, "batch": len(victims),
                                "error": type(exc).__name__})
    self._fail_requests(key, victims, exc)

  def _finish_evictions(self, key, arena, evictions, label) -> int:
    """Turn evictions into results: per-request validation, final
    accounting, and estimator feedback.  The estimator observes measured
    slot-seconds (admit → evict, rb=1) — the per-request residency QoS
    predictions price — plus the measured iteration count, mirroring the
    batch path's two feedback signals."""
    completed = 0
    for ev in evictions:
      r = ev.request
      value = ev.value
      poisoned = False
      if self.faults is not None:
        nf = self.faults.check("nonfinite", label=label, backend="arena",
                               request_ids=[r.request_id])
        if nf is not None:
          poisoned = True
          if np.issubdtype(value.dtype, np.floating):
            value = np.full_like(value, np.nan)
      bad = (self.validate_results
             and np.issubdtype(value.dtype, np.floating)
             and bool(np.isnan(value).any()))
      if poisoned or bad:
        # garbage fails THIS slot's future alone; neighbors complete —
        # the isolation the batch path needs bisection for
        self.metrics.on_batch_failure("nonfinite")
        transition = self.resilience.on_failure(key, _ARENA_ARM)
        if self.tracer.enabled:
          if transition == "open":
            self.tracer.instant("breaker_open", cat="resilience",
                                args={"bucket": label, "backend": "arena",
                                      "schedule": "local",
                                      "kind": "nonfinite"})
          self.tracer.request_end(r.request_id, "failed", executing=True,
                                  args={"slot": ev.slot})
        self._fail_requests(key, [r], NonFiniteResultError(label, [ev.slot]))
        continue
      now = self._clock()
      res = MMOResult(value=value,
                      extras={"iterations": int(ev.iterations)})
      self.estimator.observe_iterations(key, [int(ev.iterations)])
      self.estimator.observe_batch(key, _ARENA_ARM[0], _ARENA_ARM[2], 1,
                                   now - ev.admit_s)
      if self.tracer.enabled:
        self.tracer.request_end(r.request_id, "done", executing=True,
                                args={"slot": ev.slot,
                                      "iterations": int(ev.iterations)})
      with self._lock:
        self._inflight.discard(r.request_id)
        self._records.append(RequestRecord(
            request_id=r.request_id, kind=r.kind, op=r.op, bucket=tuple(key),
            batch_size=1, arrival_s=r.arrival_s, scheduled_s=ev.admit_s,
            completed_s=now))
        self.admission.on_done(r)
        self.metrics.on_complete(key, queue_s=ev.admit_s - r.arrival_s,
                                 service_s=now - ev.admit_s)
        fut = self._pending.pop(r.request_id, None)
        if fut is not None:
          try:
            fut._fulfill(res)
          except Exception as cb:  # noqa: BLE001 — see _complete_sub
            self.tracer.instant("future_callback_error", cat="engine",
                                args={"id": r.request_id,
                                      "error": type(cb).__name__})
        if not self._pending:
          self._idle.notify_all()
      completed += 1
    return completed

  def _serve_batch(self, key, reqs, scheduled_s: float) -> int:
    """The recovery driver: execute the picked batch, isolating failures by
    bounded retry + bisection so innocent co-batched requests complete.

    A LIFO stack of (sub-batch, retries left, attempt index) starts with
    the whole batch.  A failed sub-batch is retried whole under its
    ``transient_retries`` budget (exponential backoff — a transient blip
    usually clears); once the budget is spent it is *bisected* and each
    half re-enters the stack with a fresh budget.  A single poisoned
    request in a batch of B therefore costs O(log B) extra launches — it
    keeps landing in ever-smaller failing halves until it fails alone —
    and total attempts are bounded by (retries+1)·(2B−1).  Every sub-batch
    size is re-bucketed to its own power of two, so bisection launches hit
    existing executable-cache entries (prewarm compiles every pow2 batch).

    Accounting across attempts is once-per-request for final outcomes
    (``on_complete`` / ``on_fail`` / admission / futures), per-attempt for
    attempt-scoped telemetry (failure kinds, breaker transitions, batch
    phase spans), and first-fixpoint-only for iteration observations
    (``observed`` below) — a retried closure batch must not double-feed
    the estimator.  Returns #requests completed (innocents complete even
    when a poisoned sibling fails)."""
    label = bucket_label(key)
    observed: set = set()   # rids whose measured iterations were recorded
    stack = [(list(reqs), self.transient_retries, 0)]
    completed = 0
    while stack:
      sub, retries_left, attempt = stack.pop()
      if attempt > 0 and self.tracer.enabled:
        # a fresh execute slice per retried/bisected attempt — the failed
        # attempt closed the previous one with outcome 'retried'
        self.tracer.batch_attempt_begin([r.request_id for r in sub])
      try:
        results, info = self._attempt(
            key, sub, observed, scheduled_s if attempt == 0 else None)
      except Exception as e:  # noqa: BLE001 — classified + counted in _attempt
        will_retry = retries_left > 0
        will_bisect = not will_retry and self.bisect and len(sub) > 1
        if self.tracer.enabled:
          self.tracer.batch_attempt_fail(
              [r.request_id for r in sub],
              outcome="retried" if (will_retry or will_bisect) else "failed",
              picked_t_s=scheduled_s if attempt == 0 else None,
              args={"error": type(e).__name__})
        if will_retry:
          self.metrics.on_retry()
          backoff = self.retry_backoff_s * (2.0 ** min(attempt, 3))
          if backoff > 0.0:
            time.sleep(backoff)
          stack.append((sub, retries_left - 1, attempt + 1))
        elif will_bisect:
          mid = len(sub) // 2
          self.metrics.on_retry(2)
          if self.tracer.enabled:
            self.tracer.instant(
                "batch_bisect", cat="resilience",
                args={"bucket": label, "batch": len(sub),
                      "halves": [mid, len(sub) - mid],
                      "error": type(e).__name__})
          # each half gets the full transient budget (a rate-mode fault can
          # hit an innocent half; one unlucky draw must not fail it), and
          # the left half runs first (LIFO)
          stack.append((sub[mid:], self.transient_retries, attempt + 1))
          stack.append((sub[:mid], self.transient_retries, attempt + 1))
        else:
          self._fail_requests(key, sub, e)
          self.tracer.instant("batch_fail", cat="batch",
                              args={"bucket": label, "batch": len(sub),
                                    "error": type(e).__name__})
        continue
      completed += self._complete_sub(key, sub, results, info, scheduled_s,
                                      emit_pick=attempt == 0)
    return completed

  def _attempt(self, key, reqs, observed: set, start_s: Optional[float]):
    """Execute one sub-batch once on the best currently-available arm.
    Returns (results, info dict); raises the (already classified, counted,
    and breaker-fed) failure otherwise.  ``start_s`` is the batch pick time
    for the first attempt (so the fast path's spans match the historical
    trace exactly); retries stamp their own start."""
    label = bucket_label(key)
    rids = [r.request_id for r in reqs]
    rb = self._batch_bucket(len(reqs))
    primary = self.resolve_placement(key, rb)
    arm, probe = self.resilience.pick(key, primary,
                                      lambda: self._fallback_arms(key))
    backend, block, schedule = arm
    if self.tracer.enabled and probe:
      self.tracer.instant("breaker_probe", cat="resilience",
                          args={"bucket": label, "backend": backend,
                                "schedule": schedule})
    faults = self.faults
    attempt_s = self._clock() if start_s is None else start_s
    phase = "stack"
    try:
      # fill the padded batch slots with copies of the last request — wasted
      # compute bounded at 2×, in exchange for a bounded executable set
      stacked = batching.stack_batch(key, reqs + [reqs[-1]] * (rb - len(reqs)))
      h2d_bytes = batching.stacked_nbytes(stacked)
      stacked_s = self._clock()
      phase = "compile"
      if faults is not None and faults.check("compile", label=label,
                                             backend=backend,
                                             request_ids=rids):
        # raised BEFORE the cache is consulted: an injected compile failure
        # must never poison the executable cache with a broken entry
        raise InjectedFault("compile", label)
      misses_before = self.cache.misses
      compiled = self.cache.get_or_compile(
          self._exec_key(key, rb, backend, block, schedule),
          lambda: batching.make_batch_fn(key, backend=backend, block=block,
                                         interpret=self.interpret,
                                         mesh=self.mesh, schedule=schedule),
          stacked)
      cache_hit = self.cache.misses == misses_before
      # estimator observations start AFTER compilation: a cache-miss batch
      # must not feed trace+compile time (orders of magnitude above steady
      # service) into the EWMA as if it were device latency
      executed_s = self._clock()
      phase = "execute"
      exec_fault = slow_rule = None
      if faults is not None:
        exec_fault = faults.check("execute", label=label, backend=backend,
                                  request_ids=rids)
        slow_rule = faults.check("slow", label=label, backend=backend,
                                 request_ids=rids)

      def run():
        if exec_fault is not None:
          raise InjectedFault("execute", label)
        if slow_rule is not None:
          time.sleep(slow_rule.delay_s)
        out = compiled(*stacked)
        # block on the device result here so the device-compute window
        # (executed_s → device_s) is honest: jax dispatch is async, and
        # without the sync the first np.asarray below would absorb the
        # whole device time into the host-side split span
        jax.block_until_ready(out)
        return out

      out = self._call_with_watchdog(run, label)
      device_s = self._clock()
      # one D2H conversion for validation + split (np.asarray on numpy is
      # free downstream)
      out = (tuple(np.asarray(x) for x in out)
             if isinstance(out, (tuple, list)) else np.asarray(out))
      if faults is not None:
        nf = faults.check("nonfinite", label=label, backend=backend,
                          request_ids=rids)
        if nf is not None:
          out = batching.poison_output(
              key, out,
              [i for i, r in enumerate(reqs)
               if not nf.request_ids or r.request_id in nf.request_ids])
      iters_live = None
      if key.kind == "closure":
        # record measured convergence counts the moment the fixpoint has
        # run — BEFORE validation/splitting/fulfilling, so a batch that
        # fails later in this attempt still feeds the estimator what the
        # device actually measured.  Live slots only (padded slots are
        # copies of the last request), and only rids not observed by an
        # earlier attempt — a re-executed fixpoint measures the same
        # convergence and must not double-feed the EWMA.
        iters_live = np.asarray(out[1])[:len(reqs)]
        fresh = [i for i, r in enumerate(reqs)
                 if r.request_id not in observed]
        if fresh:
          self.estimator.observe_iterations(key, iters_live[fresh])
          observed.update(reqs[i].request_id for i in fresh)
      if self.validate_results:
        bad = batching.validate_finite(key, out, len(reqs))
        if bad:
          # garbage must fail the batch, not reach callers: NaN means the
          # kernel arm misbehaved (±inf is legitimate tropical output)
          raise NonFiniteResultError(label, bad)
      phase = "split"
      results = batching.split_results(key, reqs, out)
      if len(results) != len(reqs):
        # a short/long result list would silently wedge the unzipped
        # futures forever; fail the batch loudly instead
        raise RuntimeError(
            f"split_results returned {len(results)} results for "
            f"{len(reqs)} requests in {label}")
    except Exception as e:  # noqa: BLE001 — classify, count, feed the breaker
      kind = classify_failure(e, phase)
      self.metrics.on_batch_failure(kind)
      # only arm-implicating kinds feed the breaker: a host-side stack/split
      # failure would fail identically on every backend (faults.py)
      transition = (self.resilience.on_failure(key, arm)
                    if kind in ARM_FAILURE_KINDS else None)
      if self.tracer.enabled and transition == "open":
        self.tracer.instant("breaker_open", cat="resilience",
                            args={"bucket": label, "backend": backend,
                                  "schedule": schedule, "kind": kind})
      raise
    completed_s = self._clock()
    transition = self.resilience.on_success(key, arm)
    if self.tracer.enabled and transition == "close":
      self.tracer.instant("breaker_close", cat="resilience",
                          args={"bucket": label, "backend": backend,
                                "schedule": schedule})
    # live service-latency feedback: the same signal that fills the metrics
    # windows (minus compile time — see executed_s above), normalized per
    # padded slot.  Keyed by the arm that ACTUALLY executed — which the
    # breaker may have re-dispatched and resolve_placement may have
    # downgraded to 'local' for this rb — so a dp cell never averages in
    # local-path latencies and a fallback arm's cell prices itself.
    self.estimator.observe_batch(key, backend, schedule, rb,
                                 completed_s - executed_s)
    info = {"start_s": attempt_s, "stacked_s": stacked_s,
            "executed_s": executed_s, "device_s": device_s,
            "completed_s": completed_s, "rb": rb, "h2d_bytes": h2d_bytes,
            "cache_hit": cache_hit, "backend": backend,
            "schedule": schedule, "iters_live": iters_live}
    return results, info

  def _complete_sub(self, key, reqs, results, info, scheduled_s: float,
                    *, emit_pick: bool) -> int:
    """Complete one successful sub-batch attempt: trace emission, batch
    metrics, and the once-per-request final accounting.  ``scheduled_s``
    stays the ORIGINAL batch pick time — queue/service windows and request
    records measure what the caller experienced (service includes retry
    time), while the batch phase spans use the attempt's own timestamps."""
    completed_s = info["completed_s"]
    if self.tracer.enabled:
      # one call carries the whole attempt's event set (phase spans,
      # iteration slices, member picks + dones) so the steady-state tracing
      # cost is one lock acquisition per batch, not per request
      self.tracer.batch_complete(
          label=bucket_label(key), scheduled_s=info["start_s"],
          stacked_s=info["stacked_s"], executed_s=info["executed_s"],
          device_s=info["device_s"], completed_s=completed_s,
          backend=info["backend"], schedule=info["schedule"],
          batch=len(reqs), padded=info["rb"],
          h2d_bytes=info["h2d_bytes"], cache_hit=info["cache_hit"],
          request_ids=[r.request_id for r in reqs],
          arrivals_s=[r.arrival_s for r in reqs],
          iterations=info["iters_live"], emit_pick=emit_pick)
    with self._lock:
      self._batches += 1
      self.metrics.on_batch(
          key,
          host_s=((info["stacked_s"] - info["start_s"])
                  + (completed_s - info["device_s"])),
          device_s=info["device_s"] - info["executed_s"],
          h2d_bytes=info["h2d_bytes"])
      for r in reqs:
        self._inflight.discard(r.request_id)
      for r, res in zip(reqs, results):
        self._records.append(RequestRecord(
            request_id=r.request_id, kind=r.kind, op=r.op, bucket=tuple(key),
            batch_size=len(reqs), arrival_s=r.arrival_s,
            scheduled_s=scheduled_s, completed_s=completed_s))
        self.admission.on_done(r)
        self.metrics.on_complete(key, queue_s=scheduled_s - r.arrival_s,
                                 service_s=completed_s - scheduled_s)
        fut = self._pending.pop(r.request_id, None)
        if fut is not None:
          try:
            fut._fulfill(res)
          except Exception as cb:  # noqa: BLE001 — a bad future callback
            # must not take down the serving loop or its co-batched
            # siblings; the result IS delivered (state was set before the
            # callback ran), so this request still counts completed
            self.tracer.instant(
                "future_callback_error", cat="engine",
                args={"id": r.request_id, "error": type(cb).__name__})
      if not self._pending:
        self._idle.notify_all()
    return len(reqs)

  def _call_with_watchdog(self, fn, label: str):
    """Run ``fn`` under the engine watchdog (``watchdog_s``; None = inline,
    the historical zero-overhead path).  On timeout the batch fails with
    ``BatchTimeoutError`` instead of wedging the serving loop; the worker
    thread is abandoned — XLA's async dispatch cannot be cancelled, so the
    device computation may still finish later and its result is discarded
    (DESIGN.md §Fault tolerance on why this is the least-bad option)."""
    if self.watchdog_s is None:
      return fn()
    box: dict = {}
    done = threading.Event()

    def worker():
      try:
        box["out"] = fn()
      except BaseException as e:  # noqa: BLE001 — marshalled to the caller
        box["exc"] = e
      finally:
        done.set()

    t = threading.Thread(target=worker, name="mmo-batch-watchdog",
                         daemon=True)
    t.start()
    if not done.wait(self.watchdog_s):
      raise BatchTimeoutError(label, self.watchdog_s)
    if "exc" in box:
      raise box["exc"]
    return box["out"]

  def _fallback_arms(self, key) -> tuple:
    """Sibling arms for breaker re-dispatch, best first: every arm computes
    bit-identical results for this bucket (one substrate, many kernels —
    the SIMD² property), so traffic can move between them freely.

    Order: a sharded bucket's first fallback is its own backend on the
    local path (same kernel, no mesh collectives — survives schedule-level
    faults); then the other backends on the local path ranked by cost-table
    seconds, with the reference dense backend ('vector' — pure jnp, works
    everywhere) forced last as the terminal arm.  ``fallback_backends``
    overrides the backend order outright (deterministic tests, operator
    pinning).  Memoized per bucket: stable executable-cache keys."""
    with self._lock:
      memo = self._fallback_arms_memo.get(key)
      if memo is not None:
        return memo
      primary_backend, block = self.resolve_backend(key)
      schedule = self.resolve_schedule(key)
      arms = []
      if schedule != "local":
        arms.append((primary_backend, block, "local"))
      if self.fallback_backends is not None:
        order = [b for b in self.fallback_backends if b != primary_backend]
      else:
        from repro.tuning import dispatch as _dispatch
        m, k, n = contract_shape(key)
        ranked = []
        for b in ("xla", "pallas"):
          if b == primary_backend:
            continue
          try:
            _, _, s = _dispatch.contraction_seconds(
                key.op, m, k, n, key.dtypes[0], backend=b,
                table=self.cost_table)
          except Exception:  # noqa: BLE001 — an unpriceable arm is skipped
            continue
          ranked.append((s, b))
        ranked.sort()
        order = [b for _, b in ranked]
        if primary_backend != "vector":
          order.append("vector")
      arms.extend((b, (), "local") for b in order)
      memo = tuple(arms)
      self._fallback_arms_memo[key] = memo
      return memo

  def run_until_idle(self) -> int:
    """Drain the queue synchronously; returns total requests completed."""
    total = 0
    while True:
      done = self.step()
      with self._lock:
        drained = (len(self.scheduler) == 0
                   and not self._arena_live_locked())
      if done == 0 and drained:
        return total
      total += done

  def _check_dropped(self, fut: MMOFuture):
    """Raise if the scheduler lost this request: still pending, but neither
    queued (scheduler fully drained) nor inside an executing batch.  Pop +
    fulfill and pick + mark-inflight are each atomic under the engine lock,
    so this three-way state read is consistent — a positive is a real
    engine bug, never a request merely waiting behind other buckets."""
    rid = fut.request.request_id
    with self._lock:
      dropped = (rid in self._pending and rid not in self._inflight
                 and len(self.scheduler) == 0)
    if dropped:
      raise RuntimeError(
          f"request {rid} ({fut.request.kind}/{fut.request.op}) was "
          f"dropped: the queue drained without completing it — engine bug")

  def _drive(self, fut: MMOFuture, timeout: Optional[float]):
    """Future.result() plumbing: wait on the loop, or step synchronously."""
    deadline = None if timeout is None else time.perf_counter() + timeout
    while (self._thread is not None and self._thread.is_alive()
           and not fut.done()):
      # bounded waits, re-checking for a scheduler-lost request each lap —
      # result(timeout=None) must surface the engine bug as a RuntimeError,
      # not block forever on an event nobody will ever set
      self._check_dropped(fut)
      if deadline is not None and time.perf_counter() > deadline:
        return
      wait = 0.05 if deadline is None else max(
          0.0, min(0.05, deadline - time.perf_counter()))
      if fut._event.wait(wait):
        return
    # no background loop (or it died mid-wait): step synchronously
    while not fut.done():
      if deadline is not None and time.perf_counter() > deadline:
        return
      if self.step() == 0 and not fut.done():
        self._check_dropped(fut)
        # another thread's step() holds (or just finished) this request's
        # batch, or its bucket sits behind one that just failed — wait for
        # the completion event, then loop back into step()
        wait = 0.005 if deadline is None else max(
            0.0, min(0.005, deadline - time.perf_counter()))
        fut._event.wait(wait)

  # -- live metrics ----------------------------------------------------------

  def metrics_snapshot(self) -> dict:
    """Point-in-time QoS view (rolling-window per-bucket p50/p99 queue +
    service latency, counters, queue depth, admission state).  Safe to call
    from any thread while the background loop is serving — it reads the
    gauges under the engine lock for one moment, then aggregates outside the
    serving path."""
    with self._lock:
      depth = len(self.scheduler)
      executing = len(self._inflight)
      adm = self.admission.snapshot()
    return self.metrics.snapshot(queue_depth=depth, executing=executing,
                                 admission=adm,
                                 estimator=self.estimator.snapshot())

  def observability_state(self) -> dict:
    """Everything the Prometheus renderer (serve_mmo/exposition.py) emits,
    in one point-in-time document: metrics counters + histogram state,
    queue/executing gauges, admission + cache + scheduler counters, the
    estimator's cells with their drift against the static cost model
    (measured EWMA / static prediction — the model-vs-reality gauge), and
    flight-recorder stats.  Gauges are read under the engine lock; the
    per-cell drift math runs outside it."""
    with self._lock:
      depth = len(self.scheduler)
      executing = len(self._inflight)
      adm = self.admission.snapshot()
      sched = {"picks": self.scheduler.picks,
               "pick_seconds": self.scheduler.pick_seconds}
    cells = []
    for key, backend, schedule, seconds, count in self.estimator.cells_raw():
      contraction_s, trips = self._static_point(key)
      static_s = contraction_s * trips
      cells.append({
          "bucket": bucket_label(key), "backend": backend,
          "schedule": schedule, "seconds": seconds, "observations": count,
          "drift": (seconds / static_s) if static_s > 0.0 else None,
      })
    return {
        "metrics": self.metrics.exposition_state(),
        "queue_depth": depth,
        "executing": executing,
        "admission": adm,
        "cache": self.cache.stats(),
        "scheduler": sched,
        "estimator_cells": cells,
        "breakers": self.resilience.snapshot(),
        "trace": self.tracer.stats(),
    }

  def export_trace(self) -> dict:
    """The flight recorder's Chrome trace-event JSON (load in Perfetto or
    about://tracing) — per-request lifecycle spans plus per-batch
    host/device phase breakdown.  See serve_mmo/observability.py."""
    return self.tracer.export()

  def prewarm(self, sample_reqs) -> int:
    """Compile every (bucket, pow2-batch) executable the sample's buckets can
    produce, without executing anything.  Returns #programs compiled.  After
    ``prewarm``, traffic confined to those buckets causes zero recompiles —
    the steady-state guarantee benchmarks/serve_bench.py asserts.
    """
    from repro.serve_mmo.scheduler import request_bucket
    with self._lock:  # scheduler config is engine-lock guarded state
      min_bucket = self.scheduler.min_bucket
      max_batch = self.scheduler.max_batch
    seen = {request_bucket(req, min_bucket) for req in sample_reqs}
    before = self.cache.misses
    for key in seen:
      if self.mode == "arena" and key.kind == "closure":
        # arena buckets compile their three slot programs instead of the
        # pow2 batch ladder — after this, admissions/ticks/evictions replay
        # stored executables (the zero-retrace guarantee test_arena pins)
        with self._lock:
          arena = self._arena_for_locked(key)
        arena.prewarm()
        continue
      rb = 1
      while True:
        backend, block, schedule = self.resolve_placement(key, rb)
        self.cache.get_or_compile(
            self._exec_key(key, rb, backend, block, schedule),
            lambda s=schedule: batching.make_batch_fn(
                key, backend=backend, block=block, interpret=self.interpret,
                mesh=self.mesh, schedule=s),
            batching.abstract_batch(key, rb))
        if rb >= max_batch:
          break
        rb = self._batch_bucket(min(2 * rb, max_batch))
    return self.cache.misses - before

  # -- background serving loop -----------------------------------------------

  def start(self):
    """Spawn the background serving thread (idempotent; re-arms submit
    after a stop())."""
    with self._lock:
      self._stopped = False
      if self._running:
        return
      self._running = True
    self._thread = threading.Thread(target=self._loop, name="mmo-serve",
                                    daemon=True)
    self._thread.start()

  def stop(self, *, drain: bool = True):
    """Stop the loop; with ``drain`` finish everything queued first (if the
    loop is not running, drain synchronously instead of spinning).  Stopped
    is a terminal accepting state: later ``submit`` calls raise until
    ``start()`` is called again (pinned in tests/test_serve_mmo.py)."""
    with self._lock:
      self._stopped = True
    if drain:
      if self._thread is not None and self._thread.is_alive():
        # step() notifies _idle the moment _pending empties, so drain wakes
        # immediately and burns no CPU; the timeout is only a liveness
        # backstop should the serving thread die without notifying.
        with self._idle:
          while self._pending and self._thread.is_alive():
            self._idle.wait(timeout=0.5)
      else:
        self.run_until_idle()
    with self._work:
      self._running = False
      self._work.notify_all()
    if self._thread is not None:
      self._thread.join()
      self._thread = None

  def _loop(self):
    while True:
      with self._work:
        while (self._running and len(self.scheduler) == 0
               and not self._arena_live_locked()):
          self._work.wait()
        if not self._running:
          return
      self.step()

  # -- stats -----------------------------------------------------------------

  def stats(self) -> EngineStats:
    with self._lock:
      recs = list(self._records)
      batches = self._batches
      rejected, expired = self._rejected, self._expired
    lat = np.asarray([r.latency_s for r in recs], dtype=np.float64)
    return EngineStats(
        completed=len(recs),
        batches=batches,
        mean_batch=(len(recs) / batches) if batches else 0.0,
        latencies_s=lat,
        cache=self.cache.stats(),
        rejected=rejected,
        expired=expired,
    )

  def reset_stats(self):
    with self._lock:
      self._records.clear()
      self._batches = 0
      self._rejected = 0
      self._expired = 0

"""Shape-bucketed request scheduler for the MMO serving engine.

Requests land in buckets keyed by (kind, op, padded shape, dtype, static
params).  Padding each dimension up to the next power of two (with a floor)
collapses the long tail of real-world problem shapes onto a handful of
compiled programs while bounding wasted compute at <4× (2× per padded axis
in the worst case, far less on average).

*Which* bucket batches next — and in what order requests leave a bucket —
is a pluggable ``SchedulingPolicy`` (serve_mmo/policy.py): FIFO (oldest
head first, the default and the engine's historical behavior), deadline
(earliest-feasible-deadline with priority tiers and fail-fast), or fair
share (weighted round-robin across tenants).  The scheduler itself only
owns storage: one heap per bucket, ordered by the policy's request rank
with submit seq breaking ties, so the FIFO policy's heaps degenerate to
exact submit order.

Deadline bookkeeping also lives here: ``add`` stamps each request's
absolute ``deadline_at``, and ``next_batch`` diverts requests whose
deadline already passed — or that the policy declares hopeless — into an
``expired`` side channel (``take_expired``) instead of the batch, so the
engine can fail them without burning executable time.

With ``max_batch_seconds``, batches are additionally *service-time-capped*
while deadline-tagged traffic is around: the policy's ``batch_cap`` hook
bounds each batch to roughly that many predicted seconds of work
(``predict_seconds`` × batch size), so a bulk batch on device can delay an
urgent arrival by at most the cap instead of a full ``max_batch`` service
time.  The scheduler tracks whether deadline traffic is queued (a live
counter) or recent (``deadline_lookback_s`` since the last deadline-tagged
submit) so pure-bulk workloads keep full batches.  See DESIGN.md §Adaptive
prediction.
"""
from __future__ import annotations

import heapq
import time
from typing import NamedTuple, Optional

import numpy as np

from repro.serve_mmo.api import ProblemRequest
from repro.serve_mmo.policy import FifoPolicy, QueueEntry, make_policy
# Canonical bucketing lives in tuning.cost_table so the cost table's key —
# the bucket signature — is the same function of a shape everywhere.
from repro.tuning.cost_table import MIN_BUCKET, bucket_dim, bucket_shape

__all__ = ["MIN_BUCKET", "BucketKey", "bucket_dim", "bucket_shape",
           "contract_shape", "request_bucket", "BucketScheduler",
           "FifoBucketScheduler"]


class BucketKey(NamedTuple):
  kind: str
  op: str
  shape: tuple     # padded problem shape
  dtypes: tuple    # one dtype string per operand, in operand order
  params: tuple


def contract_shape(key: BucketKey) -> tuple:
  """The (M, K, N) contraction a bucket's executable runs per request — what
  the cost table is keyed on and the dispatcher resolves with."""
  if key.kind == "mmo":
    return key.shape
  if key.kind == "closure":
    (nb,) = key.shape
    return (nb, nb, nb)
  if key.kind == "knn":
    qb, rb, db = key.shape  # addnorm contracts the feature dim
    return (qb, db, rb)
  raise ValueError(f"unknown kind {key.kind!r}")


def request_bucket(req: ProblemRequest,
                   min_bucket: int = MIN_BUCKET) -> BucketKey:
  """Deterministic bucket assignment for one request.  Every operand's dtype
  goes into the key: a bucket's AOT executable is dtype-exact, so two
  requests may share it only if ALL their operands agree."""
  dtypes = tuple(str(np.dtype(a.dtype)) for a in req.arrays.values())
  return BucketKey(kind=req.kind, op=req.op,
                   shape=bucket_shape(req.shape, min_bucket),
                   dtypes=dtypes, params=req.params)


class BucketScheduler:
  """Request queue + policy-driven bucket picker (host-side).

  ``predict_seconds`` is an optional ``BucketKey → seconds`` hook (the
  engine wires it to the cost table's per-request service prediction,
  ``MMOEngine.predict_request_seconds``) that deadline-aware policies use
  for feasibility; without it, fail-fast degrades to plain already-expired
  detection.
  """

  DEADLINE_LOOKBACK_S = 1.0  # default recency window for the batch cap

  def __init__(self, *, policy="fifo", min_bucket: int = MIN_BUCKET,
               max_batch: int = 8, clock=None,
               max_batch_seconds: Optional[float] = None,
               deadline_lookback_s: Optional[float] = None):
    if max_batch < 1:
      raise ValueError("max_batch must be >= 1")
    if max_batch_seconds is not None and not max_batch_seconds > 0.0:
      raise ValueError(
          f"max_batch_seconds must be > 0, got {max_batch_seconds}")
    self.policy = make_policy(policy)
    self.min_bucket = min_bucket
    self.max_batch = max_batch
    self.max_batch_seconds = max_batch_seconds
    self.deadline_lookback_s = (self.DEADLINE_LOOKBACK_S
                                if deadline_lookback_s is None
                                else float(deadline_lookback_s))
    self.predict_seconds = None  # set by the engine (see MMOEngine)
    self._clock = clock if clock is not None else time.perf_counter
    self._buckets: dict[BucketKey, list[QueueEntry]] = {}  # heaps
    self._seq = 0
    # observability counters (read by MMOEngine.observability_state): how
    # many batches the policy picked and the wall time spent picking —
    # always real host seconds (perf_counter, not the injected clock, which
    # tests replace with synthetic time) so the exposed pick cost is the
    # actual scheduling overhead
    self.picks = 0
    self.pick_seconds = 0.0
    self._expired: list[ProblemRequest] = []
    self._deadline_queued = 0          # deadline-tagged entries not yet popped
    self._last_deadline_s: Optional[float] = None  # last deadline-tagged add

  def __len__(self) -> int:
    return sum(len(q) for q in self._buckets.values())

  def add(self, req: ProblemRequest) -> BucketKey:
    now = self._clock()
    if req.deadline_s is not None and req.deadline_at is None:
      req.deadline_at = now + float(req.deadline_s)
    key = request_bucket(req, self.min_bucket)
    entry = QueueEntry(self._seq, req, self.policy.request_rank(req, now))
    self._seq += 1
    if req.deadline_at is not None:
      self._deadline_queued += 1
      self._last_deadline_s = now
    heapq.heappush(self._buckets.setdefault(key, []), entry)
    self.policy.on_add(entry, key, self)
    return key

  def deadline_traffic_active(self, now: float) -> bool:
    """Whether the service-time batch cap should bind: deadline-tagged work
    is queued right now, or arrived within the last ``deadline_lookback_s``
    (an ongoing deadline stream keeps bulk batches short *between* urgent
    arrivals — the arrival that benefits from the cap is by definition not
    queued yet when the bulk batch is built)."""
    if self._deadline_queued > 0:
      return True
    return (self._last_deadline_s is not None
            and now - self._last_deadline_s <= self.deadline_lookback_s)

  def pending_buckets(self) -> dict:
    return {k: len(q) for k, q in self._buckets.items() if q}

  def next_batch(self, now: Optional[float] = None) -> Optional[tuple]:
    """(BucketKey, [requests]) for the policy's chosen bucket, or None.

    Requests whose deadline already passed, or that the policy fails fast,
    are diverted to the ``take_expired`` side channel rather than returned;
    a pick whose bucket expires away entirely falls through to the next
    pick, so a non-None return always carries at least one live request.
    """
    if now is None:
      now = self._clock()
    t0 = time.perf_counter()
    try:
      return self._next_batch(now)
    finally:
      self.pick_seconds += time.perf_counter() - t0

  def _next_batch(self, now: float) -> Optional[tuple]:
    while True:
      key = self.policy.pick(self, now)
      if key is None:
        return None
      cap = min(self.max_batch, self.policy.batch_cap(key, self, now))
      batch = self._take_locked(key, cap, now)
      if batch:
        return key, batch

  def _take_locked(self, key, cap: int, now: float) -> list:
    """Pop up to ``cap`` live requests from one bucket's heap — the shared
    core of ``next_batch`` and ``take_from``.  Expired / failed-fast
    entries are diverted to the ``take_expired`` side channel and do not
    count toward the cap; an emptied heap deletes its bucket."""
    heap = self._buckets.get(key)
    if not heap:  # stale pick (e.g. the bucket dict was cleared)
      self._buckets.pop(key, None)
      return []
    batch = []
    while heap and len(batch) < cap:
      entry = heapq.heappop(heap)
      if entry.taken:
        continue
      entry.taken = True
      if entry.req.deadline_at is not None:
        self._deadline_queued = max(0, self._deadline_queued - 1)
      deadline = entry.req.deadline_at
      if ((deadline is not None and deadline < now)
          or self.policy.fail_fast(entry, key, self, now)):
        self._expired.append(entry.req)
        continue
      batch.append(entry.req)
    if not heap:
      del self._buckets[key]
    if batch:
      self.policy.on_batch(key, batch, self)
      self.picks += 1
    return batch

  def peek_bucket(self, now: Optional[float] = None):
    """The policy's current bucket choice WITHOUT popping anything — the
    arena admission path peeks to decide whether the queue head is closure
    traffic (arena-eligible) or must go through the batch path.  Stale
    picks are cleaned up exactly like ``next_batch``."""
    if now is None:
      now = self._clock()
    while True:
      key = self.policy.pick(self, now)
      if key is None:
        return None
      if self._buckets.get(key):
        return key
      self._buckets.pop(key, None)

  def take_from(self, key, limit: int, now: Optional[float] = None) -> list:
    """Pop up to ``limit`` live requests from ONE specific bucket — the
    arena admission path, where the engine (not max_batch) bounds how many
    requests leave the queue: its free slot count.  Shares ``next_batch``'s
    mechanics (expiry diversion, policy bookkeeping, pick accounting)."""
    if now is None:
      now = self._clock()
    t0 = time.perf_counter()
    try:
      return self._take_locked(key, limit, now)
    finally:
      self.pick_seconds += time.perf_counter() - t0

  def take_expired(self) -> list:
    """Requests diverted by deadline expiry / fail-fast since the last call
    (drained by the engine, which fails their futures)."""
    expired, self._expired = self._expired, []
    return expired


class FifoBucketScheduler(BucketScheduler):
  """Back-compat name: the scheduler pinned to the FIFO policy (strict FIFO
  within a bucket, oldest-head-first across buckets — the engine's
  historical behavior, byte-for-byte)."""

  def __init__(self, *, min_bucket: int = MIN_BUCKET, max_batch: int = 8,
               clock=None):
    super().__init__(policy=FifoPolicy(), min_bucket=min_bucket,
                     max_batch=max_batch, clock=clock)

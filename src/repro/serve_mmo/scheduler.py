"""Shape-bucketed FIFO scheduler for the MMO serving engine.

Requests land in buckets keyed by (kind, op, padded shape, dtype, static
params).  Padding each dimension up to the next power of two (with a floor)
collapses the long tail of real-world problem shapes onto a handful of
compiled programs while bounding wasted compute at <4× (2× per padded axis
in the worst case, far less on average).

Scheduling policy: within a bucket, strict FIFO by submit order; across
buckets, the bucket whose *head* request is oldest goes first.  That is the
no-starvation choice: a hot bucket cannot shadow a cold one indefinitely,
and completion order within a bucket always matches submit order (tested).
"""
from __future__ import annotations

import collections
from typing import NamedTuple, Optional

import numpy as np

from repro.serve_mmo.api import ProblemRequest
# Canonical bucketing lives in tuning.cost_table so the cost table's key —
# the bucket signature — is the same function of a shape everywhere.
from repro.tuning.cost_table import MIN_BUCKET, bucket_dim, bucket_shape

__all__ = ["MIN_BUCKET", "BucketKey", "bucket_dim", "bucket_shape",
           "contract_shape", "request_bucket", "FifoBucketScheduler"]


class BucketKey(NamedTuple):
  kind: str
  op: str
  shape: tuple     # padded problem shape
  dtypes: tuple    # one dtype string per operand, in operand order
  params: tuple


def contract_shape(key: BucketKey) -> tuple:
  """The (M, K, N) contraction a bucket's executable runs per request — what
  the cost table is keyed on and the dispatcher resolves with."""
  if key.kind == "mmo":
    return key.shape
  if key.kind == "closure":
    (nb,) = key.shape
    return (nb, nb, nb)
  if key.kind == "knn":
    qb, rb, db = key.shape  # addnorm contracts the feature dim
    return (qb, db, rb)
  raise ValueError(f"unknown kind {key.kind!r}")


def request_bucket(req: ProblemRequest,
                   min_bucket: int = MIN_BUCKET) -> BucketKey:
  """Deterministic bucket assignment for one request.  Every operand's dtype
  goes into the key: a bucket's AOT executable is dtype-exact, so two
  requests may share it only if ALL their operands agree."""
  dtypes = tuple(str(np.dtype(a.dtype)) for a in req.arrays.values())
  return BucketKey(kind=req.kind, op=req.op,
                   shape=bucket_shape(req.shape, min_bucket),
                   dtypes=dtypes, params=req.params)


class FifoBucketScheduler:
  """Request queue + bucket picker (host-side, O(buckets) per decision)."""

  def __init__(self, *, min_bucket: int = MIN_BUCKET, max_batch: int = 8):
    if max_batch < 1:
      raise ValueError("max_batch must be >= 1")
    self.min_bucket = min_bucket
    self.max_batch = max_batch
    self._buckets: dict[BucketKey, collections.deque] = {}
    self._seq = 0

  def __len__(self) -> int:
    return sum(len(q) for q in self._buckets.values())

  def add(self, req: ProblemRequest) -> BucketKey:
    key = request_bucket(req, self.min_bucket)
    self._buckets.setdefault(key, collections.deque()).append(
        (self._seq, req))
    self._seq += 1
    return key

  def pending_buckets(self) -> dict:
    return {k: len(q) for k, q in self._buckets.items() if q}

  def next_batch(self) -> Optional[tuple]:
    """(BucketKey, [requests]) for the bucket with the oldest head, or None."""
    best_key, best_seq = None, None
    for key, q in self._buckets.items():
      if q and (best_seq is None or q[0][0] < best_seq):
        best_key, best_seq = key, q[0][0]
    if best_key is None:
      return None
    q = self._buckets[best_key]
    batch = [q.popleft()[1] for _ in range(min(self.max_batch, len(q)))]
    if not q:
      del self._buckets[best_key]
    return best_key, batch

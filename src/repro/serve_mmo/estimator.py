"""Live service-time estimation: EWMA over measured batch latencies.

The static cost table answers "what *should* this bucket cost" — a measured
microbenchmark row or a v5e roofline prior.  Both drift from reality the
moment the device is loaded, a competing tenant warms a cache, or a closure
converges faster than its worst-case trip count.  The QoS layers that
consume ``MMOEngine.predict_request_seconds`` (deadline feasibility,
predicted-backlog admission, the service-time batch cap) are exactly the
layers that should track the *actual* device, so this module closes the
loop:

  * every completed batch contributes one observation — the same service
    latency that lands in the ``ServeMetrics`` rolling windows — normalized
    to per-request seconds (batch compute scales linearly with occupied
    slots, so seconds / padded-batch-size is the request's marginal cost),
    keyed by (bucket, backend, schedule) so a bucket re-routed to the mesh
    or to a different backend never inherits stale numbers;
  * closure batches additionally contribute their *measured* convergence
    iteration counts (``_batched_fixpoint`` reports per-request counts), so
    the cold-start prediction for a closure bucket multiplies the
    per-contraction cost by how many contractions this traffic actually
    runs, not the solver's worst-case trip count (lg n squarings / n−1
    relaxations — often 2–10× pessimistic on real graphs);
  * predictions blend: a warm EWMA (``min_observations`` reached) answers
    directly; a cold cell falls back to the static per-contraction cost ×
    the measured-iterations estimate, and with no observations at all to
    the static prediction unchanged — the engine's historical behavior.

The estimator is decoupled from engine internals and independently
thread-safe (one short lock per observe/predict): ``observe_*`` runs on the
background serving loop inside ``step`` while ``predict`` runs on caller
threads inside ``submit`` and on the scheduler's pick path.

EWMA decay is per-*observation* with a configurable half-life (see
DESIGN.md §Adaptive prediction for the default's rationale): after
``half_life`` observations an old reading retains half its weight, so the
estimate tracks load shifts at batch-arrival rate without needing a clock —
which also keeps synthetic-clock tests exact.
"""
from __future__ import annotations

import math
import threading
from typing import NamedTuple, Optional

__all__ = ["Estimate", "ServiceEstimator", "DEFAULT_HALF_LIFE",
           "DEFAULT_MIN_OBSERVATIONS"]

DEFAULT_HALF_LIFE = 8.0
DEFAULT_MIN_OBSERVATIONS = 3


class Estimate(NamedTuple):
  """One prediction: ``seconds`` per request, and where it came from —
  'ewma' (warm live estimate), 'iterations' (static per-contraction cost ×
  measured convergence counts), or 'static' (cost table / roofline prior ×
  worst-case trips, the cold-start behavior)."""
  seconds: float
  source: str


class _Ewma:
  """Exponentially-weighted mean with per-observation decay."""

  __slots__ = ("value", "count", "_alpha")

  def __init__(self, alpha: float):
    self.value = 0.0
    self.count = 0
    self._alpha = alpha

  def add(self, x: float) -> None:
    x = float(x)
    if self.count == 0:
      self.value = x
    else:
      self.value += self._alpha * (x - self.value)
    self.count += 1


class ServiceEstimator:
  """Per-(bucket, backend, schedule) EWMA service-time estimator.

  ``half_life`` is in observations: ``alpha = 1 − 2^(−1/half_life)``, so a
  reading's weight halves every ``half_life`` subsequent batches.  A cell
  answers predictions only once it holds ``min_observations`` readings —
  below that the static prior is the better-conditioned estimate and one
  outlier batch (a compile hiding in the first measurement, a page fault)
  must not steer admission.
  """

  def __init__(self, *, half_life: float = DEFAULT_HALF_LIFE,
               min_observations: int = DEFAULT_MIN_OBSERVATIONS):
    if not half_life > 0.0:
      raise ValueError(f"half_life must be > 0, got {half_life}")
    if min_observations < 1:
      raise ValueError(
          f"min_observations must be >= 1, got {min_observations}")
    self.half_life = float(half_life)
    self.min_observations = int(min_observations)
    self._alpha = 1.0 - 2.0 ** (-1.0 / self.half_life)
    self._lock = threading.Lock()
    self._cells: dict[tuple, _Ewma] = {}  # (bucket, backend, schedule)
    self._iters: dict = {}                # bucket → _Ewma of measured iters

  # -- observations (serving-loop side) ---------------------------------------

  def observe_batch(self, key, backend: str, schedule: str, slots: int,
                    seconds: float) -> None:
    """One completed batch: ``seconds`` of device service over ``slots``
    padded batch slots (the executable computes every slot, so per-request
    marginal cost is seconds / slots)."""
    if slots < 1 or not (seconds >= 0.0 and math.isfinite(seconds)):
      return  # never let a bogus reading poison the estimate
    cell_key = (key, backend, schedule)
    with self._lock:
      cell = self._cells.get(cell_key)
      if cell is None:
        cell = self._cells[cell_key] = _Ewma(self._alpha)
      cell.add(seconds / slots)

  def observe_iterations(self, key, iterations) -> None:
    """Measured per-request convergence counts from one closure batch (the
    live slots only — padded copies would double-count their template).
    Recorded separately from batch seconds so a batch that fails *after*
    the fixpoint ran (the poisoned-batch path) still contributes what it
    measured."""
    its = [float(i) for i in iterations]
    if not its:
      return
    mean = sum(its) / len(its)
    if not (mean >= 0.0 and math.isfinite(mean)):
      return
    with self._lock:
      cell = self._iters.get(key)
      if cell is None:
        cell = self._iters[key] = _Ewma(self._alpha)
      cell.add(mean)

  # -- predictions (submit / pick side) ---------------------------------------

  def iteration_estimate(self, key, worst_trips: float) -> float:
    """Expected contractions per request for this bucket: the measured EWMA
    clamped to [1, worst_trips] (the worst case is a true bound — measured
    counts above it can only be noise), or ``worst_trips`` when unmeasured."""
    with self._lock:
      cell = self._iters.get(key)
      value = cell.value if cell is not None and cell.count > 0 else None
    if value is None:
      return float(worst_trips)
    return float(min(max(value, 1.0), worst_trips))

  def predict(self, key, backend: str, schedule: str,
              static_contraction_s: float, worst_trips: float) -> Estimate:
    """Per-request service seconds for one bucket.

    Precedence: warm EWMA ('ewma') > static per-contraction cost ×
    measured-iterations estimate ('iterations') > static cost × worst-case
    trips ('static' — byte-for-byte the non-adaptive prediction).

    Observations are keyed by the schedule that *actually executed*, and
    per-batch placement may downgrade a distributed bucket to 'local'
    (e.g. dp batches whose size does not divide the mesh), so when the
    distributed cell is still cold the bucket's local cell answers before
    the static prior does — measured local latency beats an idealized
    model, and the two regimes' readings are never averaged together."""
    with self._lock:
      cell = self._cells.get((key, backend, schedule))
      warm = cell is not None and cell.count >= self.min_observations
      if not warm and schedule != "local":
        cell = self._cells.get((key, backend, "local"))
        warm = cell is not None and cell.count >= self.min_observations
      value = cell.value if warm else None
    if value is not None:
      return Estimate(value, "ewma")
    trips = self.iteration_estimate(key, worst_trips)
    source = "iterations" if trips != float(worst_trips) else "static"
    return Estimate(static_contraction_s * trips, source)

  def observations(self, key, backend: str, schedule: str) -> int:
    """How many batches the (bucket, backend, schedule) cell has seen."""
    with self._lock:
      cell = self._cells.get((key, backend, schedule))
      return cell.count if cell is not None else 0

  def cells_raw(self) -> list:
    """Every live cell as (bucket key, backend, schedule, ewma seconds,
    observation count) tuples — the unformatted view the engine's
    observability state uses to compute per-cell drift against the static
    cost model (the keys stay real BucketKeys so the engine can price the
    static side; ``snapshot`` is the label-formatted JSON counterpart)."""
    with self._lock:
      return [(k, b, s, c.value, c.count)
              for (k, b, s), c in self._cells.items()]

  # -- reading ----------------------------------------------------------------

  def snapshot(self) -> dict:
    """JSON-able state: per-cell EWMA seconds + observation counts, and the
    measured-iterations estimate per closure bucket."""
    from repro.serve_mmo.metrics import bucket_label
    with self._lock:
      cells = {f"{bucket_label(k)}|{b}|{s}": {
          "seconds": c.value, "observations": c.count}
          for (k, b, s), c in self._cells.items()}
      iters = {bucket_label(k): {"iterations": c.value,
                                 "observations": c.count}
               for k, c in self._iters.items()}
    return {"half_life": self.half_life,
            "min_observations": self.min_observations,
            "cells": cells, "iterations": iters}

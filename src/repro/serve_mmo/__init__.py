"""MMO serving engine — shape-bucketed continuous batching for semiring
workloads.

The paper's eight SIMD² applications are all *small-matrix, high-rate*
problems (a routing query, a KNN lookup, a reachability probe), which makes
them serving workloads, not one-shot library calls.  This package turns the
``core.mmo`` / ``core.closure`` stack into a request-driven service:

  api.py        — problem requests (apsp / knn / reachability / raw mmo)
                  and result futures,
  scheduler.py  — FIFO request queue bucketed by (kind, op, padded shape,
                  dtype, static params),
  batching.py   — pad-and-stack micro-batcher: one compiled program per
                  bucket executes a whole request batch (per-request
                  convergence masks for closures),
  cache.py      — AOT executable cache keyed by (bucket, batch, backend) so
                  steady-state traffic never retraces,
  engine.py     — the engine: submit()/futures, synchronous step() or a
                  background serving loop, per-request latency stats.

Quickstart::

    from repro.serve_mmo import MMOEngine, apsp_request, knn_request

    eng = MMOEngine(backend="xla", max_batch=8)
    futs = [eng.submit(apsp_request(w)) for w in weight_matrices]
    eng.run_until_idle()
    dist = futs[0].result().value
"""
from repro.serve_mmo.api import (ProblemRequest, MMOFuture, MMOResult,
                                 apsp_request, closure_request, knn_request,
                                 mmo_request, reachability_request)
from repro.serve_mmo.cache import ExecutableCache
from repro.serve_mmo.engine import EngineStats, MMOEngine
from repro.serve_mmo.scheduler import BucketKey, FifoBucketScheduler

__all__ = [
    "ProblemRequest",
    "MMOFuture",
    "MMOResult",
    "MMOEngine",
    "EngineStats",
    "ExecutableCache",
    "BucketKey",
    "FifoBucketScheduler",
    "mmo_request",
    "closure_request",
    "apsp_request",
    "reachability_request",
    "knn_request",
]

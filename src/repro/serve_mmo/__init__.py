"""MMO serving engine — shape-bucketed continuous batching for semiring
workloads.

The paper's eight SIMD² applications are all *small-matrix, high-rate*
problems (a routing query, a KNN lookup, a reachability probe), which makes
them serving workloads, not one-shot library calls.  This package turns the
``core.mmo`` / ``core.closure`` stack into a request-driven service:

  api.py        — problem requests (apsp / knn / reachability / raw mmo)
                  with QoS fields (tenant, priority, deadline_s) and result
                  futures with rejected/expired terminal states,
  scheduler.py  — request queue bucketed by (kind, op, padded shape, dtype,
                  static params); bucket picking delegates to a policy,
  policy.py     — scheduling policies: FIFO (default), deadline-aware
                  (earliest feasible deadline, priority tiers, fail-fast),
                  fair share (weighted round-robin across tenants),
  admission.py  — admission control: bounded queue depth, per-tenant
                  in-flight quotas, predicted-backlog-seconds rejection,
  metrics.py    — lock-cheap rolling-window metrics (per-bucket p50/p99
                  queue + service latency), snapshotable mid-run,
  estimator.py  — adaptive QoS: per-(bucket, backend, schedule) EWMA over
                  measured batch latencies + measured closure convergence
                  counts; corrects the cost-table predictions that drive
                  deadline feasibility, backlog admission, and the
                  service-time batch cap (``adaptive=True``),
  batching.py   — pad-and-stack micro-batcher: one compiled program per
                  bucket executes a whole request batch (per-request
                  convergence masks for closures),
  cache.py      — AOT executable cache keyed by (bucket, batch, backend) so
                  steady-state traffic never retraces,
  arena.py      — device-resident request arena: slot-based continuous
                  batching for closure fixpoints (``mode="arena"``) —
                  admit/tick/evict slot lifecycle, bit-identical to the
                  batch path,
  engine.py     — the engine: submit()/futures, synchronous step() or a
                  background serving loop, per-request latency stats, and
                  the batch-recovery driver (bounded retries, bisection,
                  watchdog, result validation),
  faults.py     — deterministic, seedable fault injection (compile /
                  execute / nonfinite / slow points; persistent, transient
                  and seeded-rate schedules) threaded through engine hooks,
  resilience.py — per-(bucket, backend, schedule) circuit breakers with
                  cost-ranked fallback arms and half-open probe recovery,
  observability.py — request-lifecycle tracer: a bounded ring-buffer flight
                  recorder of per-request/per-batch spans, exportable as
                  Chrome trace-event JSON (Perfetto / about://tracing),
  exposition.py — dependency-free Prometheus text exposition over the
                  engine's counters, log-bucketed latency histograms,
                  gauges, and estimator-vs-static drift,
  httpd.py      — stdlib HTTP endpoint serving /metrics /healthz /snapshot
                  /trace next to a live engine (``--http-port`` in
                  launch/serve_mmo.py).

Quickstart::

    from repro.serve_mmo import MMOEngine, apsp_request, knn_request

    eng = MMOEngine(backend="xla", max_batch=8,
                    policy="deadline", max_queue=1024)
    futs = [eng.submit(apsp_request(w, deadline_s=0.2))
            for w in weight_matrices]
    eng.run_until_idle()
    dist = futs[0].result().value
    print(eng.metrics_snapshot())
"""
from repro.serve_mmo.admission import AdmissionController
from repro.serve_mmo.api import (DeadlineExceededError, MMOFuture, MMOResult,
                                 ProblemRequest, RejectedError, apsp_request,
                                 closure_request, knn_request, mmo_request,
                                 reachability_request)
from repro.serve_mmo.arena import Eviction, RequestArena
from repro.serve_mmo.cache import ExecutableCache
from repro.serve_mmo.engine import EngineStats, MMOEngine
from repro.serve_mmo.estimator import Estimate, ServiceEstimator
from repro.serve_mmo.faults import (BatchTimeoutError, FaultInjector,
                                    FaultRule, InjectedFault,
                                    NonFiniteResultError, parse_fault_spec)
from repro.serve_mmo.exposition import LogHistogram, render_prometheus
from repro.serve_mmo.httpd import ObservabilityServer
from repro.serve_mmo.metrics import RollingWindow, ServeMetrics, bucket_label
from repro.serve_mmo.observability import FlightRecorder
from repro.serve_mmo.policy import (DeadlinePolicy, FairSharePolicy,
                                    FifoPolicy, SchedulingPolicy, make_policy)
from repro.serve_mmo.resilience import CircuitBreaker, ResilienceManager
from repro.serve_mmo.scheduler import (BucketKey, BucketScheduler,
                                       FifoBucketScheduler)

__all__ = [
    "ProblemRequest",
    "MMOFuture",
    "MMOResult",
    "MMOEngine",
    "EngineStats",
    "RequestArena",
    "Eviction",
    "ExecutableCache",
    "BucketKey",
    "BucketScheduler",
    "FifoBucketScheduler",
    "SchedulingPolicy",
    "FifoPolicy",
    "DeadlinePolicy",
    "FairSharePolicy",
    "make_policy",
    "AdmissionController",
    "ServiceEstimator",
    "Estimate",
    "ServeMetrics",
    "RollingWindow",
    "bucket_label",
    "FlightRecorder",
    "ObservabilityServer",
    "LogHistogram",
    "render_prometheus",
    "FaultInjector",
    "FaultRule",
    "parse_fault_spec",
    "InjectedFault",
    "NonFiniteResultError",
    "BatchTimeoutError",
    "ResilienceManager",
    "CircuitBreaker",
    "RejectedError",
    "DeadlineExceededError",
    "mmo_request",
    "closure_request",
    "apsp_request",
    "reachability_request",
    "knn_request",
]

"""Live serving metrics: lock-cheap rolling windows, snapshotable mid-run.

``EngineStats`` summarizes a *finished* run from the full record list; this
module is the opposite trade — bounded memory, O(1) appends under one short
lock, and a ``snapshot()`` that is safe to call from any thread while the
background serving loop is mid-batch (no stop, no drain).  That is what a
metrics endpoint / ``launch/serve_mmo.py --metrics-every`` needs: p99 *now*,
not p99 after the run.

Per bucket, rolling windows for queue latency (submit → batch pick) and
service latency (batch pick → results ready), plus per-batch host time
(pad-and-stack + split) and device compute time — the host/device breakdown
the engine measures around each batch.  Percentiles come from the last
``window`` observations — a rolling estimate that tracks load shifts
instead of averaging them away.  A window that has seen nothing reports its
percentiles as ``None`` (never NaN: ``json.dumps`` renders NaN as the
bareword ``NaN``, which is not strict JSON — a bucket created by
``on_expire`` alone must still snapshot to parseable output).

Alongside each window sits a fixed log-bucketed cumulative histogram
(serve_mmo/exposition.py) — the form Prometheus can aggregate across
scrapes and instances; the windows answer "now" for humans, the histograms
answer "since start" for the scraper.

Global counters (submitted / completed / rejected / expired / failed /
batches / h2d_bytes) are plain monotonic ints.

The same per-batch service-latency observations that fill these windows
also feed the engine's adaptive EWMA estimator (serve_mmo/estimator.py) —
the windows answer "what happened" for humans and dashboards, the
estimator answers "what will this cost" for admission, feasibility, and
batch capping; ``snapshot`` carries both (the engine passes the
estimator's state in as a gauge).
"""
from __future__ import annotations

import threading
import time
from typing import Optional

from repro.serve_mmo.exposition import HISTOGRAM_BOUNDS_S, LogHistogram

__all__ = ["RollingWindow", "ServeMetrics", "bucket_label"]


class RollingWindow:
  """Fixed-capacity ring of float observations with percentile queries.

  Appends are O(1) (one slot write + index bump); ``percentile`` sorts the
  live slots — called only from ``snapshot``, never on the serving path.
  """

  __slots__ = ("_buf", "_size", "_n")

  def __init__(self, size: int = 512):
    if size < 1:
      raise ValueError(f"window size must be >= 1, got {size}")
    self._buf = [0.0] * size
    self._size = size
    self._n = 0  # total observations ever (live slots = min(n, size))

  def add(self, value: float) -> None:
    self._buf[self._n % self._size] = float(value)
    self._n += 1

  @property
  def count(self) -> int:
    return self._n

  def values(self) -> list:
    return list(self._buf[:min(self._n, self._size)])

  def percentile(self, q: float) -> Optional[float]:
    """Nearest-rank percentile of the live slots, or None when empty."""
    return _rank(sorted(self.values()), q)


def _rank(sorted_vals: list, q: float) -> Optional[float]:
  """Nearest-rank percentile over a pre-sorted list (no numpy on the
  metrics path).  Empty windows answer ``None`` — the JSON-safe spelling of
  "no data" (``float('nan')`` serializes as bareword ``NaN``, breaking any
  strict parser downstream of the snapshot)."""
  if not sorted_vals:
    return None
  idx = min(len(sorted_vals) - 1,
            max(0, round(q / 100.0 * (len(sorted_vals) - 1))))
  return sorted_vals[int(idx)]


def _ms(seconds: Optional[float]) -> Optional[float]:
  return None if seconds is None else seconds * 1e3


def bucket_label(key) -> str:
  """Compact human/JSON label for one BucketKey.  Uniform-dtype buckets (the
  overwhelming majority) keep the historical single-dtype spelling; mixed
  operand dtypes are all spelled out, so two buckets differing only in a
  non-leading operand dtype can never collide under one label."""
  shape = "x".join(str(d) for d in key.shape)
  if len(set(key.dtypes)) <= 1:
    dtypes = key.dtypes[0]
  else:
    dtypes = "+".join(key.dtypes)
  return f"{key.kind}/{key.op}/{shape}/{dtypes}"


class ServeMetrics:
  """The engine's live metrics registry (one per MMOEngine).

  Every hook takes the lock for a few dict/ring operations and returns —
  cheap enough to sit inside ``submit`` and ``step`` without stretching the
  engine's critical sections.  ``snapshot`` is read-only aggregation and can
  run concurrently with serving.
  """

  COUNTERS = ("submitted", "completed", "rejected", "expired", "failed",
              "batches", "h2d_bytes", "retries")
  WINDOWS = ("queue", "service", "host", "device")

  def __init__(self, *, clock=None, window: int = 512):
    self._clock = clock if clock is not None else time.perf_counter
    self._window = window
    self._lock = threading.Lock()
    self._started_s = self._clock()
    self._counters = {name: 0 for name in self.COUNTERS}
    self._rejected_by_reason: dict[str, int] = {}
    self._batch_failures_by_kind: dict[str, int] = {}
    self._buckets: dict[str, dict] = {}  # label → windows + histograms

  # -- engine hooks ------------------------------------------------------------

  def _bucket_locked(self, key) -> dict:
    # caller holds self._lock (enforced by repro.analysis lock-discipline)
    label = bucket_label(key)
    b = self._buckets.get(label)
    if b is None:
      b = self._buckets[label] = {
          "completed": 0, "expired": 0, "failed": 0,
          **{name: RollingWindow(self._window) for name in self.WINDOWS},
          **{f"{name}_hist": LogHistogram() for name in self.WINDOWS},
      }
    return b

  def on_submit(self) -> None:
    with self._lock:
      self._counters["submitted"] += 1

  def on_reject(self, kind: str) -> None:
    with self._lock:
      self._counters["rejected"] += 1
      self._rejected_by_reason[kind] = self._rejected_by_reason.get(kind, 0) + 1

  def on_expire(self, key) -> None:
    with self._lock:
      self._counters["expired"] += 1
      self._bucket_locked(key)["expired"] += 1

  def on_fail(self, key) -> None:
    with self._lock:
      self._counters["failed"] += 1
      self._bucket_locked(key)["failed"] += 1

  def on_retry(self, n: int = 1) -> None:
    """``n`` sub-batches re-dispatched by the recovery path (a transient
    retry counts 1, a bisection counts one per half).  Distinct from
    ``on_fail``: retried requests have not failed — most never will."""
    with self._lock:
      self._counters["retries"] += int(n)

  def on_batch_failure(self, kind: str) -> None:
    """One failed batch *attempt*, classified (faults.FAILURE_KINDS).
    Every failed attempt counts — including ones whose requests later
    complete via retry/bisection — so the by-kind breakdown sees transient
    noise that the request-level ``failed`` counter (final outcomes only)
    never shows."""
    with self._lock:
      self._batch_failures_by_kind[kind] = (
          self._batch_failures_by_kind.get(kind, 0) + 1)

  def on_batch(self, key=None, *, host_s: Optional[float] = None,
               device_s: Optional[float] = None,
               h2d_bytes: Optional[int] = None) -> None:
    """One executed batch.  With a bucket key, also records the batch's
    host/device time breakdown (host = pad-and-stack + split-results,
    device = compiled-program execution) and the bytes staged host→device."""
    with self._lock:
      self._counters["batches"] += 1
      if h2d_bytes:
        self._counters["h2d_bytes"] += int(h2d_bytes)
      if key is not None:
        b = self._bucket_locked(key)
        if host_s is not None:
          b["host"].add(host_s)
          b["host_hist"].add(host_s)
        if device_s is not None:
          b["device"].add(device_s)
          b["device_hist"].add(device_s)

  def on_complete(self, key, queue_s: float, service_s: float) -> None:
    with self._lock:
      self._counters["completed"] += 1
      b = self._bucket_locked(key)
      b["completed"] += 1
      b["queue"].add(queue_s)
      b["queue_hist"].add(queue_s)
      b["service"].add(service_s)
      b["service_hist"].add(service_s)

  # -- reading -----------------------------------------------------------------

  def counter(self, name: str) -> int:
    with self._lock:
      return self._counters[name]

  def snapshot(self, *, queue_depth: Optional[int] = None,
               executing: Optional[int] = None,
               admission: Optional[dict] = None,
               estimator: Optional[dict] = None) -> dict:
    """JSON-able point-in-time view.  ``queue_depth`` / ``executing`` /
    ``admission`` / ``estimator`` are gauges the engine reads under its own
    (or the estimator's) lock and passes in (the registry never reaches
    back into the engine — no lock-order coupling).  Only O(1)-per-bucket
    window *copies* happen under the metrics lock; the sorts behind the
    percentiles run after it is released, so a slow snapshot can never
    stall the serving hooks.  Strict-JSON safe: empty windows report their
    percentiles as None, never NaN."""
    with self._lock:
      raw = {label: (b["completed"], b["expired"], b["failed"],
                     {name: b[name].values() for name in self.WINDOWS})
             for label, b in self._buckets.items()}
      snap = {
          "uptime_s": self._clock() - self._started_s,
          "counters": dict(self._counters),
          "rejected_by_reason": dict(self._rejected_by_reason),
          "batch_failures_by_kind": dict(self._batch_failures_by_kind),
      }
    buckets = {}
    for label, (completed, expired, failed, windows) in raw.items():
      stanza = {"completed": completed, "expired": expired, "failed": failed}
      for name, vals in windows.items():
        vals.sort()
        stanza[f"{name}_ms"] = {"p50": _ms(_rank(vals, 50)),
                                "p99": _ms(_rank(vals, 99))}
      stanza["window"] = len(windows["queue"])
      buckets[label] = stanza
    snap["buckets"] = buckets
    if queue_depth is not None:
      snap["queue_depth"] = queue_depth
    if executing is not None:
      snap["executing"] = executing
    if admission is not None:
      snap["admission"] = admission
    if estimator is not None:
      snap["estimator"] = estimator
    return snap

  def exposition_state(self) -> dict:
    """Raw counter + histogram state for the Prometheus renderer
    (serve_mmo/exposition.py): per-bucket cumulative histogram (counts,
    sum, count) tuples copied under the lock, shared fixed boundaries."""
    with self._lock:
      buckets = {
          label: {
              "completed": b["completed"],
              "expired": b["expired"],
              "failed": b["failed"],
              "histograms": {name: b[f"{name}_hist"].state()
                             for name in self.WINDOWS
                             if b[f"{name}_hist"].count},
          }
          for label, b in self._buckets.items()
      }
      return {
          "uptime_s": self._clock() - self._started_s,
          "counters": dict(self._counters),
          "rejected_by_reason": dict(self._rejected_by_reason),
          "batch_failures_by_kind": dict(self._batch_failures_by_kind),
          "histogram_bounds_s": list(HISTOGRAM_BOUNDS_S),
          "buckets": buckets,
      }

"""AOT executable cache — steady-state traffic never retraces.

Programs are compiled ahead-of-time (``jax.jit(fn).lower(shapes).compile()``)
and keyed by (BucketKey, batch size, backend): the engine asks the cache
before every batch, so after warmup every bucket's traffic replays a stored
executable and the hit/miss counters *prove* zero recompiles (asserted in
benchmarks/serve_bench.py).  Batch sizes are part of the key; the scheduler's
max_batch bounds how many variants one bucket can create.

Thread-safety: the cache is shared between the caller thread (``prewarm``)
and the serving loop, so every ``_entries``/``_misses`` touch happens under
``_lock``.  Compilation itself runs *outside* the lock — it can take
hundreds of milliseconds and must not stall the serving loop's hits on other
keys.  Two threads missing the same key may therefore both compile; the
first insert wins, the loser's work is discarded, and the counters stay
consistent (misses counts compile *attempts*, so `misses >= executables`).
"""
from __future__ import annotations

import dataclasses
import threading
import time
from typing import Callable

import jax


@dataclasses.dataclass
class CacheEntry:
  compiled: Callable
  compile_s: float
  hits: int = 0


class ExecutableCache:
  def __init__(self):
    self._lock = threading.Lock()
    self._entries: dict = {}
    self._misses = 0

  @property
  def misses(self) -> int:
    with self._lock:
      return self._misses

  @property
  def hits(self) -> int:
    with self._lock:
      return sum(e.hits for e in self._entries.values())

  @property
  def compiles(self) -> int:
    return self.misses

  @property
  def compile_s(self) -> float:
    with self._lock:
      return sum(e.compile_s for e in self._entries.values())

  def __len__(self) -> int:
    with self._lock:
      return len(self._entries)

  def get_or_compile(self, exec_key, make_fn: Callable, args) -> Callable:
    """Return the compiled program for ``exec_key``, compiling on first use.

    ``make_fn`` builds the pure function; ``args`` are example (or abstract)
    operands fixing shapes/dtypes.
    """
    with self._lock:
      entry = self._entries.get(exec_key)
      if entry is not None:
        entry.hits += 1
        return entry.compiled
      self._misses += 1
    t0 = time.perf_counter()
    abstract = tuple(
        jax.ShapeDtypeStruct(a.shape, a.dtype) for a in args)
    compiled = jax.jit(make_fn()).lower(*abstract).compile()
    elapsed = time.perf_counter() - t0
    with self._lock:
      entry = self._entries.get(exec_key)
      if entry is not None:  # lost the compile race: first insert wins
        entry.hits += 1
        return entry.compiled
      self._entries[exec_key] = CacheEntry(compiled=compiled,
                                           compile_s=elapsed)
    return compiled

  def stats(self) -> dict:
    with self._lock:
      return {
          "executables": len(self._entries),
          "hits": sum(e.hits for e in self._entries.values()),
          "misses": self._misses,
          "compile_s": round(
              sum(e.compile_s for e in self._entries.values()), 3),
      }

"""AOT executable cache — steady-state traffic never retraces.

Programs are compiled ahead-of-time (``jax.jit(fn).lower(shapes).compile()``)
and keyed by (BucketKey, batch size, backend): the engine asks the cache
before every batch, so after warmup every bucket's traffic replays a stored
executable and the hit/miss counters *prove* zero recompiles (asserted in
benchmarks/serve_bench.py).  Batch sizes are part of the key; the scheduler's
max_batch bounds how many variants one bucket can create.
"""
from __future__ import annotations

import dataclasses
import time
from typing import Callable

import jax


@dataclasses.dataclass
class CacheEntry:
  compiled: Callable
  compile_s: float
  hits: int = 0


class ExecutableCache:
  def __init__(self):
    self._entries: dict = {}
    self.misses = 0

  @property
  def hits(self) -> int:
    return sum(e.hits for e in self._entries.values())

  @property
  def compiles(self) -> int:
    return self.misses

  @property
  def compile_s(self) -> float:
    return sum(e.compile_s for e in self._entries.values())

  def __len__(self) -> int:
    return len(self._entries)

  def get_or_compile(self, exec_key, make_fn: Callable, args) -> Callable:
    """Return the compiled program for ``exec_key``, compiling on first use.

    ``make_fn`` builds the pure function; ``args`` are example (or abstract)
    operands fixing shapes/dtypes.
    """
    entry = self._entries.get(exec_key)
    if entry is not None:
      entry.hits += 1
      return entry.compiled
    self.misses += 1
    t0 = time.perf_counter()
    abstract = tuple(
        jax.ShapeDtypeStruct(a.shape, a.dtype) for a in args)
    compiled = jax.jit(make_fn()).lower(*abstract).compile()
    self._entries[exec_key] = CacheEntry(
        compiled=compiled, compile_s=time.perf_counter() - t0)
    return compiled

  def stats(self) -> dict:
    return {
        "executables": len(self),
        "hits": self.hits,
        "misses": self.misses,
        "compile_s": round(self.compile_s, 3),
    }

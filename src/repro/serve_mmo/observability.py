"""Request-lifecycle tracing: a bounded flight recorder over the serving path.

Latency percentiles say *how much* time a request spent; they never say
*where*.  This module stamps monotonic-clock spans at every state transition
a request goes through — submit, admit/reject, queued, batch pick,
pad-and-stack, resolve+compile, device compute (with per-squaring-iteration
slices for closures), split-results, done/expired/failed — into a
``FlightRecorder``: a fixed-capacity ring buffer of Chrome trace events.

Why a ring-buffer flight recorder and not a log: the serving loop must never
block on, allocate unboundedly for, or fsync its own telemetry.  A ring of
the last N events costs one short lock + one deque extend per emission,
keeps memory constant under any load, and still answers the question an
operator actually asks ("what did the engine do *just now*?").  Old events
fall off the back; ``stats()`` reports how many were dropped so a truncated
window is visible, never silent.

The export format is Chrome trace-event JSON (``export()`` →
``{"traceEvents": [...]}``), loadable directly in Perfetto /
``about://tracing``:

  * per-request lifecycle — nestable async events (``ph`` 'b'/'e', one id
    per request): a ``queued`` slice (submit → batch pick) followed by an
    ``execute`` slice (pick → results), with kind/op/tenant on the begin
    and the terminal outcome (done / expired / failed) on the end;
  * per-batch phases — complete events (``ph`` 'X') on the executing
    thread's track: ``pad_and_stack``, ``resolve_compile`` (args say cache
    hit or miss), ``device_compute`` (args carry backend, schedule, padded
    batch, H2D bytes, measured iterations), ``split_results``.  Together
    these are the host/device time breakdown per batch;
  * closure squaring iterations — the fixpoint runs on device inside one
    ``lax.while_loop`` with **no host round-trip** (that is the point of
    it), so per-iteration boundaries are not host-observable.  The tracer
    apportions the measured device window evenly across the batch's
    measured max iteration count into ``squaring_iter k`` child slices,
    marked ``"apportioned": true`` in args — the shape of the fixpoint is
    visible in the trace without paying a host sync per iteration;
  * instants (``ph`` 'i') for admission rejections and batch failures.

Timestamps come from the engine's injected clock (microseconds), so
synthetic-clock tests produce exact, deterministic traces.

Cost discipline (benchmarks/serve_bench.py asserts the steady-state
overhead stays under its budget): the whole per-batch event set — batch
phases, iteration slices, every member request's pick + completion — is
built locally and pushed in ONE ``batch_complete`` call (one lock, one
deque extend), and ``enabled=False`` turns every hook into an attribute
check + return.
"""
from __future__ import annotations

import collections
import threading
import time
from typing import Optional, Sequence

__all__ = ["FlightRecorder", "DEFAULT_TRACE_CAPACITY",
           "MAX_ITERATION_SLICES"]

DEFAULT_TRACE_CAPACITY = 65536
# per-batch cap on apportioned squaring_iter slices: a 1024-node
# Bellman-Ford bucket measures up to 1023 relaxations; tracing them all
# would let one batch evict half the ring
MAX_ITERATION_SLICES = 32

_PID = 1  # one engine process per recorder


class FlightRecorder:
  """Bounded ring buffer of Chrome trace events, thread-safe, O(1) append.

  Hooks are grouped by call site: ``request_begin`` (submit),
  ``request_rejected`` (admission), ``batch_complete`` (the whole per-batch
  event set in one emission), ``request_picked`` / ``request_end`` (the
  expire/fail paths, where requests terminate outside a completed batch),
  ``instant``.  Every hook is a no-op when ``enabled`` is False; callers
  with non-trivial args construction should still guard with
  ``if recorder.enabled:`` to keep the disabled path free."""

  def __init__(self, *, capacity: int = DEFAULT_TRACE_CAPACITY,
               clock=None, enabled: bool = True):
    if capacity < 1:
      raise ValueError(f"capacity must be >= 1, got {capacity}")
    self.capacity = int(capacity)
    self.enabled = bool(enabled)
    self._clock = clock if clock is not None else time.perf_counter
    self._lock = threading.Lock()
    self._events: collections.deque = collections.deque(maxlen=self.capacity)
    self._recorded = 0

  # -- clock -------------------------------------------------------------------

  def _ts(self, t_s: Optional[float] = None) -> float:
    """Trace timestamp in microseconds (Chrome trace's unit)."""
    return (self._clock() if t_s is None else t_s) * 1e6

  @staticmethod
  def _tid() -> int:
    return threading.get_ident() & 0x7FFFFFFF

  # -- raw emission ------------------------------------------------------------

  def _emit(self, events) -> None:
    with self._lock:
      self._events.extend(events)
      self._recorded += len(events)

  # -- request lifecycle (nestable async, one id per request) ------------------

  def request_begin(self, rid: int, *, kind: str, op: str, tenant: str,
                    t_s: Optional[float] = None) -> None:
    """The request was admitted and queued: open its ``queued`` slice."""
    if not self.enabled:
      return
    self._emit((
        {"ph": "b", "cat": "request", "id": rid, "name": "queued",
         "pid": _PID, "tid": self._tid(), "ts": self._ts(t_s),
         "args": {"kind": kind, "op": op, "tenant": tenant}},))

  def request_picked(self, rid: int, *, t_s: Optional[float] = None) -> None:
    """Queued slice ends, execute slice begins (batch pick) — used by the
    batch-failure path; completed batches ride ``batch_complete``."""
    if not self.enabled:
      return
    ts = self._ts(t_s)
    tid = self._tid()
    self._emit((
        {"ph": "e", "cat": "request", "id": rid, "name": "queued",
         "pid": _PID, "tid": tid, "ts": ts},
        {"ph": "b", "cat": "request", "id": rid, "name": "execute",
         "pid": _PID, "tid": tid, "ts": ts}))

  def request_end(self, rid: int, outcome: str, *, executing: bool,
                  t_s: Optional[float] = None,
                  args: Optional[dict] = None) -> None:
    """Close a request's open slice with its terminal outcome ('done',
    'expired', 'failed').  ``executing`` says which slice is open: True
    closes ``execute`` (the request was in a batch), False closes
    ``queued`` (it never left the queue)."""
    if not self.enabled:
      return
    end_args = {"outcome": outcome}
    if args:
      end_args.update(args)
    self._emit((
        {"ph": "e", "cat": "request", "id": rid,
         "name": "execute" if executing else "queued",
         "pid": _PID, "tid": self._tid(), "ts": self._ts(t_s),
         "args": end_args},))

  # -- arena slot lifecycle (admit → tick×k → evict) ---------------------------

  def arena_admit(self, rid: int, *, slot: int, bucket: str,
                  t_s: Optional[float] = None) -> None:
    """The request left the queue INTO an arena slot: its ``queued`` slice
    closes and its ``execute`` slice opens, carrying the slot index.  The
    slice stays open across every tick the request resides (``arena_tick``
    X-events land inside it) until ``request_end`` closes it at eviction —
    together the admit → tick×k → evict span of one slot residency."""
    if not self.enabled:
      return
    ts = self._ts(t_s)
    tid = self._tid()
    self._emit((
        {"ph": "e", "cat": "request", "id": rid, "name": "queued",
         "pid": _PID, "tid": tid, "ts": ts},
        {"ph": "b", "cat": "request", "id": rid, "name": "execute",
         "pid": _PID, "tid": tid, "ts": ts,
         "args": {"bucket": bucket, "slot": slot}}))

  def arena_tick(self, bucket: str, *, live: int, evicted: int, g: int,
                 t0_s: float, t1_s: float) -> None:
    """One arena tick (≤ g fused iterations over every live slot): a
    complete event on the serving thread's track, with occupancy and the
    sweep's eviction count in args."""
    if not self.enabled:
      return
    self._emit((
        {"ph": "X", "cat": "arena", "name": "arena_tick", "pid": _PID,
         "tid": self._tid(), "ts": t0_s * 1e6,
         "dur": max(0.0, (t1_s - t0_s) * 1e6),
         "args": {"bucket": bucket, "live": live, "evicted": evicted,
                  "g": g}},))

  def request_rejected(self, rid: int, reason: str, *, kind: str, op: str,
                       tenant: str, t_s: Optional[float] = None) -> None:
    """Admission refused the request: one instant — a rejection has no
    duration, so it gets a point on the timeline, not an async pair."""
    if not self.enabled:
      return
    self._emit((
        {"ph": "i", "cat": "admission", "name": "reject", "pid": _PID,
         "tid": self._tid(), "ts": self._ts(t_s), "s": "t",
         "args": {"id": rid, "reason": reason, "kind": kind, "op": op,
                  "tenant": tenant}},))

  # -- the completed-batch fast path -------------------------------------------

  def batch_complete(self, *, label: str, scheduled_s: float,
                     stacked_s: float, executed_s: float, device_s: float,
                     completed_s: float, backend: str, schedule: str,
                     batch: int, padded: int, h2d_bytes: int,
                     cache_hit: bool, request_ids: Sequence[int],
                     arrivals_s: Sequence[float],
                     iterations=None, emit_pick: bool = True) -> None:
    """Emit one completed batch's whole event set in a single lock
    acquisition: the four phase spans (pad_and_stack / resolve_compile /
    device_compute / split_results), the apportioned squaring-iteration
    slices for closures, and every member request's queued→execute
    transition (at the pick instant) and ``execute`` end (outcome done,
    with its latency).  This is the serving loop's only steady-state trace
    call, so its cost IS the tracing overhead the bench budgets.

    ``emit_pick=False`` skips the per-request queued→execute transition:
    retried/bisected sub-batches already closed ``queued`` and opened a
    fresh ``execute`` slice via ``batch_attempt_fail`` /
    ``batch_attempt_begin``, so only the terminal ``execute`` end is
    emitted here — one ``e`` per ``b`` per attempt."""
    if not self.enabled:
      return
    tid = self._tid()
    ts_sched = scheduled_s * 1e6
    ts_exec = executed_s * 1e6
    ts_dev = device_s * 1e6
    ts_done = completed_s * 1e6
    dev_args = {"bucket": label, "padded": padded, "backend": backend,
                "schedule": schedule, "h2d_bytes": h2d_bytes}
    events = [
        {"ph": "X", "cat": "batch", "name": "pad_and_stack", "pid": _PID,
         "tid": tid, "ts": ts_sched,
         "dur": max(0.0, (stacked_s - scheduled_s) * 1e6),
         "args": {"bucket": label, "batch": batch, "padded": padded,
                  "h2d_bytes": h2d_bytes}},
        {"ph": "X", "cat": "batch", "name": "resolve_compile", "pid": _PID,
         "tid": tid, "ts": stacked_s * 1e6,
         "dur": max(0.0, (executed_s - stacked_s) * 1e6),
         "args": {"bucket": label, "cache": "hit" if cache_hit else "miss",
                  "backend": backend, "schedule": schedule}},
        {"ph": "X", "cat": "batch", "name": "device_compute", "pid": _PID,
         "tid": tid, "ts": ts_exec, "dur": max(0.0, ts_dev - ts_exec),
         "args": dev_args},
        {"ph": "X", "cat": "batch", "name": "split_results", "pid": _PID,
         "tid": tid, "ts": ts_dev, "dur": max(0.0, ts_done - ts_dev),
         "args": {"bucket": label}},
    ]
    if iterations is not None and len(iterations):
      its = [int(i) for i in iterations]
      dev_args["iterations"] = its
      max_it = max(its)
      if max_it >= 1 and ts_dev > ts_exec:
        # see module docstring: apportioned slices, the fixpoint itself is
        # one on-device while_loop with no host-observable step boundary
        n = min(max_it, MAX_ITERATION_SLICES)
        dur = (ts_dev - ts_exec) / n
        events.extend(
            {"ph": "X", "cat": "batch", "name": f"squaring_iter {i}",
             "pid": _PID, "tid": tid, "ts": ts_exec + i * dur, "dur": dur,
             "args": {"apportioned": True, "iterations": max_it}}
            for i in range(n))
    for rid, arrival_s in zip(request_ids, arrivals_s):
      if emit_pick:
        events.append({"ph": "e", "cat": "request", "id": rid,
                       "name": "queued", "pid": _PID, "tid": tid,
                       "ts": ts_sched})
        events.append({"ph": "b", "cat": "request", "id": rid,
                       "name": "execute", "pid": _PID, "tid": tid,
                       "ts": ts_sched})
      events.append({"ph": "e", "cat": "request", "id": rid,
                     "name": "execute", "pid": _PID, "tid": tid,
                     "ts": ts_done,
                     "args": {"outcome": "done",
                              "latency_ms": (completed_s - arrival_s) * 1e3}})
    self._emit(events)

  # -- the recovery path (retries / bisection) ---------------------------------

  def batch_attempt_begin(self, request_ids: Sequence[int], *,
                          t_s: Optional[float] = None) -> None:
    """Open a fresh ``execute`` slice for every member of a retried or
    bisected sub-batch — the previous attempt closed its slice with outcome
    'retried' (``batch_attempt_fail``), so each attempt reads as its own
    execute span under the request's async track."""
    if not self.enabled:
      return
    ts = self._ts(t_s)
    tid = self._tid()
    self._emit([{"ph": "b", "cat": "request", "id": rid, "name": "execute",
                 "pid": _PID, "tid": tid, "ts": ts}
                for rid in request_ids])

  def batch_attempt_fail(self, request_ids: Sequence[int], *, outcome: str,
                         picked_t_s: Optional[float] = None,
                         t_s: Optional[float] = None,
                         args: Optional[dict] = None) -> None:
    """Close every member's open ``execute`` slice after a failed attempt:
    ``outcome`` is 'retried' when recovery continues (retry or bisection)
    or 'failed' at the terminal attempt.  ``picked_t_s`` handles the first
    attempt, whose members never individually transitioned queued→execute
    (the success path batches that into ``batch_complete``): their
    ``queued`` end + ``execute`` begin are emitted first, at the pick
    time — keeping one ``e`` per ``b`` whichever way the attempt ends."""
    if not self.enabled:
      return
    ts = self._ts(t_s)
    tid = self._tid()
    events = []
    if picked_t_s is not None:
      ts_pick = picked_t_s * 1e6
      for rid in request_ids:
        events.append({"ph": "e", "cat": "request", "id": rid,
                       "name": "queued", "pid": _PID, "tid": tid,
                       "ts": ts_pick})
        events.append({"ph": "b", "cat": "request", "id": rid,
                       "name": "execute", "pid": _PID, "tid": tid,
                       "ts": ts_pick})
    end_args = {"outcome": outcome}
    if args:
      end_args.update(args)
    events.extend({"ph": "e", "cat": "request", "id": rid, "name": "execute",
                   "pid": _PID, "tid": tid, "ts": ts, "args": dict(end_args)}
                  for rid in request_ids)
    self._emit(events)

  def instant(self, name: str, *, cat: str = "engine",
              args: Optional[dict] = None,
              t_s: Optional[float] = None) -> None:
    if not self.enabled:
      return
    ev = {"ph": "i", "cat": cat, "name": name, "pid": _PID,
          "tid": self._tid(), "ts": self._ts(t_s), "s": "t"}
    if args:
      ev["args"] = args
    self._emit((ev,))

  # -- reading -----------------------------------------------------------------

  def events(self) -> list:
    """Snapshot of the live ring (oldest first)."""
    with self._lock:
      return list(self._events)

  def stats(self) -> dict:
    with self._lock:
      live = len(self._events)
      recorded = self._recorded
    return {"enabled": self.enabled, "capacity": self.capacity,
            "recorded": recorded, "live": live,
            "dropped": recorded - live}

  def clear(self) -> None:
    with self._lock:
      self._events.clear()
      self._recorded = 0

  def export(self, *, process_name: str = "serve_mmo engine") -> dict:
    """Chrome trace-event JSON object: load the dump in Perfetto or
    ``about://tracing``.  Metadata events name the process; async request
    slices and per-thread batch tracks come from the ring."""
    meta = [{"ph": "M", "pid": _PID, "name": "process_name",
             "args": {"name": process_name}}]
    return {"traceEvents": meta + self.events(), "displayTimeUnit": "ms"}

"""Scheduling policies: which bucket serves next, and in what order within.

The scheduler (``serve_mmo.scheduler.BucketScheduler``) owns request storage
— one heap per shape bucket — and delegates every ordering decision to a
``SchedulingPolicy``:

  * ``request_rank``  orders requests *within* a bucket (heap key prefix;
    submit seq always breaks ties, so equal-rank requests stay FIFO),
  * ``pick``          chooses which bucket's head batches next,
  * ``fail_fast``     may declare a just-popped request hopeless (its
    deadline cannot be met even if served immediately) so the engine fails
    it instead of burning a batch slot on a result nobody can use,
  * ``batch_cap``     bounds how many requests the next batch may carry —
    the service-time-aware preemption cap (``max_batch_seconds``): while
    deadline traffic is active, bulk batches are kept short enough that an
    urgent arrival never waits a full max_batch service time behind one.

Three implementations:

  FifoPolicy       — rank ``()``: strict FIFO within a bucket, oldest head
                     across buckets.  The engine default; byte-for-byte the
                     scheduling behavior the engine shipped with.
  DeadlinePolicy   — rank ``(-priority, deadline)``: higher priority tiers
                     first, then earliest absolute deadline (requests with
                     no deadline sort last, FIFO among themselves).  At pick
                     time a head whose deadline is infeasible — now plus the
                     cost table's predicted batch service seconds already
                     overshoots it — fails fast.
  FairSharePolicy  — weighted round-robin across tenants: each pick serves
                     the bucket holding the current tenant's oldest queued
                     request, and a tenant with weight w gets w consecutive
                     picks before the turn passes.  Within the picked bucket
                     the batch is still FIFO (a batch is a *shape* unit and
                     may carry other tenants' requests along — that is free
                     batching, not a fairness violation).

Cross-bucket picking for the heap-ordered policies (FIFO, deadline) is an
O(log Q) lazy heap, not an O(buckets) scan: every queued request pushes one
``(rank, seq, bucket)`` heap record at add time, and because bucket heaps
share the same (rank, seq) order, a live top record is always its bucket's
current head.  Records whose request was already batched, expired, or lost
are discarded lazily at pick time (``taken`` flag / head-seq mismatch), so
pick cost stays flat as bucket diversity grows (microbenchmarked in
``benchmarks/qos_bench.py``).
"""
from __future__ import annotations

import collections
import heapq
import math
from typing import Optional

__all__ = ["QueueEntry", "SchedulingPolicy", "FifoPolicy", "DeadlinePolicy",
           "FairSharePolicy", "POLICIES", "make_policy"]


class QueueEntry:
  """One queued request: ``rank`` is the policy's within-bucket order prefix
  (seq breaks ties), ``taken`` marks entries already removed from their
  bucket so auxiliary structures (pick heap, tenant queues) can skip them
  lazily instead of paying for eager deletion."""

  __slots__ = ("seq", "req", "rank", "taken")

  def __init__(self, seq: int, req, rank: tuple = ()):
    self.seq = seq
    self.req = req
    self.rank = rank
    self.taken = False

  def __lt__(self, other: "QueueEntry") -> bool:
    return (self.rank, self.seq) < (other.rank, other.seq)

  def __repr__(self) -> str:
    return (f"QueueEntry(seq={self.seq}, rank={self.rank}, "
            f"taken={self.taken})")


class SchedulingPolicy:
  """Base policy: heap-ordered bucket picking over ``request_rank``."""

  name = "base"

  def __init__(self):
    self._heap: list = []  # (rank, seq, BucketKey) — lazy, see module doc

  # -- ordering ----------------------------------------------------------------

  def request_rank(self, req, now: float) -> tuple:
    """Within-bucket order prefix for one request (seq breaks ties)."""
    return ()

  # -- lifecycle hooks ---------------------------------------------------------

  def on_add(self, entry: QueueEntry, key, sched) -> None:
    heapq.heappush(self._heap, (entry.rank, entry.seq, key))

  # -- picking -----------------------------------------------------------------

  def pick(self, sched, now: float) -> Optional[tuple]:
    """BucketKey whose head serves next, or None when nothing is queued.

    The top live heap record is always its bucket's current head: bucket
    heaps and this heap share the (rank, seq) order, so any record above a
    bucket's head would itself be that bucket's head.  Stale records (request
    batched/expired, or the bucket dict was externally cleared) are popped
    and dropped.
    """
    h = self._heap
    while h:
      _, seq, key = h[0]
      bucket = sched._buckets.get(key)
      if bucket and not bucket[0].taken and bucket[0].seq == seq:
        return key
      heapq.heappop(h)
    return None

  def fail_fast(self, entry: QueueEntry, key, sched, now: float) -> bool:
    """Whether a just-popped request should fail instead of execute."""
    return False

  def batch_cap(self, key, sched, now: float) -> int:
    """Most requests the next batch from ``key`` may carry — the
    service-time-aware preemption bound.

    With ``sched.max_batch_seconds`` set and deadline-tagged traffic active
    (queued, or seen within the scheduler's lookback window), the batch is
    bounded to the largest power of two whose *predicted* service time
    (``predict_seconds`` per request × batch size — live EWMA seconds when
    the engine runs adaptive) fits the cap, so a bulk batch on device can
    delay an urgent arrival by at most ~max_batch_seconds instead of a full
    max_batch service time.  Power-of-two flooring matters: the engine pads
    batches up to the next power of two and computes every padded slot, so
    an un-floored cap of e.g. 3 would execute 4 slots and overshoot the
    seconds budget it claims to honor.  Never caps below 1; without a cap
    (or predictor) the answer is ``sched.max_batch`` — the historical
    behavior, and full batching efficiency for pure-bulk workloads.
    """
    cap_s = getattr(sched, "max_batch_seconds", None)
    predict = getattr(sched, "predict_seconds", None)
    if (cap_s is None or predict is None
        or not sched.deadline_traffic_active(now)):
      return sched.max_batch
    per = predict(key)
    if not (per > 0.0 and math.isfinite(per)):
      return sched.max_batch
    allowed = int(cap_s / per)
    if allowed <= 1:
      return 1
    return min(sched.max_batch, 1 << (allowed.bit_length() - 1))

  def on_batch(self, key, batch, sched) -> None:
    """Called with every non-empty batch the scheduler built — feedback for
    policies whose pick bookkeeping depends on who actually got served."""


class FifoPolicy(SchedulingPolicy):
  """Strict FIFO within a bucket; across buckets, oldest head first — the
  no-starvation default (a hot bucket cannot shadow a cold one)."""

  name = "fifo"


class DeadlinePolicy(SchedulingPolicy):
  """Earliest-feasible-deadline first, priority tiers breaking ties.

  Rank is ``(-priority, deadline_at)`` — higher ``priority`` wins, then the
  earlier absolute deadline; requests without a deadline rank last within
  their tier and stay FIFO among themselves.  At pick time the policy asks
  the scheduler's ``predict_seconds`` hook (the engine wires it to the cost
  table's per-request service prediction — a lower bound on the serving
  batch's duration, see ``MMOEngine.predict_request_seconds``) whether the
  head can still make its deadline; a hopeless head fails fast so the batch
  slot goes to a request that can.
  """

  name = "deadline"

  def request_rank(self, req, now: float) -> tuple:
    deadline = req.deadline_at if req.deadline_at is not None else math.inf
    return (-int(req.priority), deadline)

  def fail_fast(self, entry: QueueEntry, key, sched, now: float) -> bool:
    deadline = entry.req.deadline_at
    if deadline is None:
      return False
    predict = getattr(sched, "predict_seconds", None)
    service_s = predict(key) if predict is not None else 0.0
    return now + service_s > deadline


class FairSharePolicy(SchedulingPolicy):
  """Weighted round-robin across tenants.

  Each tenant keeps a FIFO of its queued requests; a pick serves the bucket
  holding the current tenant's oldest request, and the tenant keeps the turn
  for ``weights[tenant]`` consecutive picks (default 1) before it passes.
  Tenants with nothing queued are skipped without consuming credit.  Taken
  entries (batched along with another tenant's pick, or expired) are skipped
  lazily at the queue front.
  """

  name = "fair"

  def __init__(self, weights: Optional[dict] = None):
    super().__init__()
    self.weights = dict(weights or {})
    self._queues: dict = {}  # tenant → deque[(QueueEntry, BucketKey)]
    self._order: list = []   # tenant ring, insertion order; drained → removed
    self._idx = 0            # ring position that holds the turn
    self._credit = 0         # picks the turn-holder has left
    self._last_pick: Optional[str] = None  # tenant charged for the last pick

  def on_add(self, entry: QueueEntry, key, sched) -> None:
    tenant = entry.req.tenant
    q = self._queues.get(tenant)
    if q is None:
      q = self._queues[tenant] = collections.deque()
      self._order.append(tenant)
    q.append((entry, key))

  def pick(self, sched, now: float) -> Optional[tuple]:
    while self._order:
      if self._idx >= len(self._order):
        self._idx = 0
      tenant = self._order[self._idx]
      q = self._queues[tenant]
      while q:
        entry, key = q[0]
        # skip taken entries AND orphans (an entry whose bucket vanished
        # without the scheduler popping it — e.g. the bucket dict was
        # externally cleared); returning an orphan would livelock
        # next_batch, which can only retry the pick
        if entry.taken or not sched._buckets.get(key):
          q.popleft()
          continue
        break
      if not q:
        # tenant drained — drop it from the ring entirely (it re-registers
        # on its next submit): a long-lived engine seeing unbounded tenant
        # churn must not accrete empty queues or O(ever-seen) pick scans
        del self._queues[tenant]
        self._order.pop(self._idx)
        self._credit = 0
        continue
      if self._credit <= 0:
        self._credit = max(1, int(self.weights.get(tenant, 1)))
      self._credit -= 1
      self._last_pick = tenant
      if self._credit <= 0:
        self._idx += 1  # next pick offers the turn to the next tenant
        if self._idx >= len(self._order):
          self._idx = 0
      return q[0][1]
    return None

  def on_batch(self, key, batch, sched) -> None:
    """Refund the turn when it bought the tenant nothing: the picked
    bucket's batch pops in FIFO order, so a tenant whose oldest entry sits
    behind >= max_batch other-tenant requests can be charged for batches
    that serve none of its work.  Refunding the credit (and keeping the
    turn) means each such batch still drains the bucket toward the
    tenant's entry without costing its share."""
    tenant, self._last_pick = self._last_pick, None
    if tenant is None or any(r.tenant == tenant for r in batch):
      return
    if tenant in self._queues:
      try:
        self._idx = self._order.index(tenant)
      except ValueError:  # pragma: no cover — _queues/_order stay in sync
        return
      self._credit += 1


POLICIES = {"fifo": FifoPolicy, "deadline": DeadlinePolicy,
            "fair": FairSharePolicy}


def make_policy(policy) -> SchedulingPolicy:
  """'fifo' | 'deadline' | 'fair' | a SchedulingPolicy instance (pass-through;
  a policy instance holds queue state, so it must not be shared across
  schedulers)."""
  if isinstance(policy, SchedulingPolicy):
    return policy
  cls = POLICIES.get(policy)
  if cls is None:
    raise ValueError(f"unknown policy {policy!r}; one of "
                     f"{tuple(POLICIES)} or a SchedulingPolicy instance")
  return cls()

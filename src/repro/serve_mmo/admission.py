"""Admission control: bound what the engine accepts instead of queueing it.

An unbounded queue turns overload into unbounded latency — every request is
eventually served, long after anyone wants its answer.  The controller gives
``MMOEngine.submit`` three independent reasons to return an already-failed
future (``RejectedError``) instead of queueing:

  max_queue      — global queued-request cap: the classic depth bound.
  tenant_quota   — per-tenant *in-flight* cap (queued + executing, until the
                   future resolves): one chatty tenant cannot monopolize the
                   queue however fast it submits.  An int applies to every
                   tenant; a dict maps tenant → cap (missing tenants are
                   uncapped).
  max_backlog_s  — predicted-backlog bound, in *seconds of work*: each
                   admitted request is charged its predicted service
                   seconds (``MMOEngine.predict_request_seconds`` — on a
                   static engine the cost table's per-contraction answer
                   times the bucket's worst-case contraction count; on an
                   ``adaptive=True`` engine the live EWMA over measured
                   batch latencies, with measured closure convergence
                   counts correcting the cold-start prior — see
                   serve_mmo/estimator.py), and a request that would push
                   the queue's total predicted drain time past the bound
                   is rejected.  Queue *length* is a poor overload signal
                   when buckets differ by orders of magnitude in service
                   time (a 256³ closure vs a 16³ mmo); seconds-of-work is
                   the quantity latency SLOs are actually made of.  The
                   charge is stamped on the request at admit time and
                   released verbatim when it leaves the queue, so the
                   accounting stays exact even while the live estimate
                   drifts.  See DESIGN.md §Admission / §Adaptive
                   prediction.

All counters are maintained by the engine under its lock — the controller
itself is plain state + arithmetic and is not independently thread-safe.
"""
from __future__ import annotations

import collections
from typing import Optional, Union

__all__ = ["AdmissionController"]


class AdmissionController:
  """Decides admit/reject at submit time and tracks the load counters the
  decision reads (queued count, per-tenant in-flight, predicted backlog)."""

  def __init__(self, *, max_queue: Optional[int] = None,
               tenant_quota: Union[int, dict, None] = None,
               max_backlog_s: Optional[float] = None):
    if max_queue is not None and max_queue < 1:
      raise ValueError(f"max_queue must be >= 1, got {max_queue}")
    if max_backlog_s is not None and not max_backlog_s > 0.0:
      raise ValueError(f"max_backlog_s must be > 0, got {max_backlog_s}")
    self.max_queue = max_queue
    self.tenant_quota = tenant_quota
    self.max_backlog_s = max_backlog_s
    self.queued = 0                         # admitted, not yet batched
    self.backlog_s = 0.0                    # predicted seconds to drain queue
    self.inflight = collections.Counter()   # tenant → queued + executing
    self.rejections = collections.Counter() # reason kind → count
    self.evaluations = 0                    # try_admit calls (admit + reject)

  @property
  def unbounded(self) -> bool:
    """True when no limit is configured — every request admits (the
    engine's default; also lets submit skip the cost prediction)."""
    return (self.max_queue is None and self.tenant_quota is None
            and self.max_backlog_s is None)

  def _quota_for(self, tenant: str) -> Optional[int]:
    if isinstance(self.tenant_quota, dict):
      return self.tenant_quota.get(tenant)
    return self.tenant_quota

  # -- the decision -----------------------------------------------------------

  def try_admit(self, req, cost_s: float = 0.0) -> Optional[tuple]:
    """Admit ``req`` (returns None, counters charged, ``req.predicted_s``
    stamped) or reject it (returns a ``(kind, reason)`` pair — the short
    kind for metrics, the human-readable reason for the error; nothing
    charged)."""
    self.evaluations += 1
    if self.max_queue is not None and self.queued >= self.max_queue:
      self.rejections["queue_full"] += 1
      return ("queue_full", f"queue full: {self.queued} queued >= "
                            f"max_queue={self.max_queue}")
    quota = self._quota_for(req.tenant)
    if quota is not None and self.inflight[req.tenant] >= quota:
      self.rejections["tenant_quota"] += 1
      return ("tenant_quota", f"tenant {req.tenant!r} over quota: "
                              f"{self.inflight[req.tenant]} in flight >= "
                              f"{quota}")
    if (self.max_backlog_s is not None
        and self.backlog_s + cost_s > self.max_backlog_s):
      self.rejections["backlog"] += 1
      return ("backlog", f"predicted backlog {self.backlog_s + cost_s:.3f}s"
                         f" > max_backlog_s={self.max_backlog_s:g} "
                         f"(prediction: {req.predicted_source})")
    req.predicted_s = float(cost_s)
    self.queued += 1
    self.backlog_s += req.predicted_s
    self.inflight[req.tenant] += 1
    return None

  # -- lifecycle accounting (engine-lock-held) --------------------------------

  def on_dequeue(self, req) -> None:
    """The request left the queue (batched for execution, or expired)."""
    self.queued = max(0, self.queued - 1)
    self.backlog_s = max(0.0, self.backlog_s - req.predicted_s)

  def on_done(self, req) -> None:
    """The request's future resolved (fulfilled, failed, or expired) —
    release its tenant in-flight slot."""
    left = self.inflight[req.tenant] - 1
    if left > 0:
      self.inflight[req.tenant] = left
    else:
      del self.inflight[req.tenant]

  def snapshot(self) -> dict:
    return {
        "queued": self.queued,
        "backlog_s": self.backlog_s,
        "inflight": dict(self.inflight),
        "rejections": dict(self.rejections),
        "evaluations": self.evaluations,
        "limits": {"max_queue": self.max_queue,
                   "tenant_quota": (dict(self.tenant_quota)
                                    if isinstance(self.tenant_quota, dict)
                                    else self.tenant_quota),
                   "max_backlog_s": self.max_backlog_s},
    }

"""Deterministic fault injection for the serving engine — the failure
taxonomy and the seedable harness that exercises it.

A serving engine's failure paths are the code least likely to run in
development and most likely to run at 3am in production.  This module makes
every one of them *drivable*: a ``FaultInjector`` holds named rules that
fire at the engine's injection points, deterministically (seeded RNG for
rate-mode rules, plain counters for transient ones), so a test or a chaos
run can replay the exact same failure schedule twice and assert the exact
same recovery.

Injection points (``POINTS``), matching where the engine can actually
fail:

  ``compile``    — raise before the executable cache is consulted
                   (simulates a lowering/compile failure for this
                   (bucket, batch, arm) without poisoning the cache),
  ``execute``    — raise around the compiled program's dispatch (simulates
                   a device-side execution failure),
  ``nonfinite``  — corrupt the batch output with NaNs after execution
                   (simulates a kernel producing garbage — the engine's
                   result validation must catch it, see
                   ``batching.validate_finite``),
  ``slow``       — sleep ``delay_s`` inside the execute window (simulates a
                   slow or hung device computation — with the engine's
                   watchdog armed and ``delay_s`` past it, the batch times
                   out instead of wedging the serving loop).

Schedules (``mode``):

  ``persistent``    — every matching check fires (until ``clear()``),
  ``transient``     — the first ``count`` matching checks fire, then the
                      rule is exhausted (a blip that recovery should ride
                      out),
  ``rate``          — each matching check fires with probability ``rate``
                      from the injector's seeded RNG (chaos testing; the
                      seed makes the chaos replayable).

Scoping: ``match`` filters by bucket-label substring, ``backend`` pins the
rule to one kernel arm (a Pallas lowering bug does not follow the request
to the XLA fallback — this is what lets tests drive the circuit breaker's
arm re-dispatch), and ``request_ids`` poisons specific requests (the rule
fires only for batches containing them — what batch bisection isolates).

``parse_fault_spec`` turns the ``--inject-faults`` CLI grammar into an
injector::

    execute:rate:0.02                 2% of execute checks fail
    execute:transient:3               first 3 execute checks fail
    compile:persistent@closure        every compile of a closure bucket
    execute:persistent:backend=xla    the xla arm is broken (breaker food)
    slow:transient:1:delay=0.2        one 200ms stall (watchdog food)

Rules are ';'-separated; each rule is ``point:mode[:arg][:k=v...][@match]``
where ``arg`` is the transient count or the rate probability.

Every hook is an attribute check + return when no injector is configured —
the disabled steady-state cost is asserted < 2% in
benchmarks/resilience_bench.py.
"""
from __future__ import annotations

import dataclasses
import random
import threading
from typing import FrozenSet, Optional, Sequence

__all__ = ["POINTS", "FAILURE_KINDS", "ARM_FAILURE_KINDS", "FaultRule",
           "FaultInjector",
           "InjectedFault", "NonFiniteResultError", "BatchTimeoutError",
           "classify_failure", "parse_fault_spec"]

POINTS = ("compile", "execute", "nonfinite", "slow")
MODES = ("persistent", "transient", "rate")

# failure kinds the engine classifies batch failures into (the ``kind``
# label on serve_batch_failures_total)
FAILURE_KINDS = ("stack", "compile", "execute", "nonfinite", "timeout",
                 "split", "other")

# the kinds that implicate the executing ARM (kernel/schedule) and feed its
# circuit breaker; stack/split/other are host-side and arm-independent — a
# poisoned operand would fail identically on every backend, and opening a
# breaker for it would just burn the fallback chain
ARM_FAILURE_KINDS = frozenset(("compile", "execute", "nonfinite", "timeout"))


class InjectedFault(RuntimeError):
  """An injected failure fired at ``point`` — raised by the engine's hook
  so the recovery machinery sees a real exception on the real code path."""

  def __init__(self, point: str, detail: str = ""):
    self.point = point
    super().__init__(f"injected {point} fault{': ' + detail if detail else ''}")


class NonFiniteResultError(RuntimeError):
  """Result validation found NaNs in a batch output — a first-class failure
  kind: the device produced garbage, and fulfilling the futures would hand
  that garbage to callers.  ``slots`` are the offending batch positions
  (bisection uses the whole-batch failure; the slots make the error
  actionable in logs)."""

  def __init__(self, label: str, slots: Sequence[int]):
    self.slots = tuple(int(s) for s in slots)
    super().__init__(
        f"non-finite values in batch output for {label} at request "
        f"slot(s) {list(self.slots)}")


class BatchTimeoutError(RuntimeError):
  """The watchdog expired before the device returned the batch — the batch
  fails instead of wedging the serving loop.  The abandoned computation may
  still complete on-device later (XLA dispatch cannot be cancelled — see
  DESIGN.md §Fault tolerance); its result is discarded."""

  def __init__(self, label: str, timeout_s: float):
    self.timeout_s = float(timeout_s)
    super().__init__(
        f"batch for {label} exceeded the {timeout_s:g}s watchdog")


def classify_failure(exc: BaseException, phase: str) -> str:
  """Map one batch-attempt exception to its failure kind: typed failures
  (validation, watchdog, injection) answer for themselves; anything else is
  labeled by the phase it escaped from (stack / compile / execute / split)."""
  if isinstance(exc, NonFiniteResultError):
    return "nonfinite"
  if isinstance(exc, BatchTimeoutError):
    return "timeout"
  if isinstance(exc, InjectedFault):
    return exc.point if exc.point in FAILURE_KINDS else "execute"
  return phase if phase in FAILURE_KINDS else "other"


@dataclasses.dataclass
class FaultRule:
  """One injection rule: where it fires (``point``), when (``mode`` +
  ``count``/``rate``), and what it targets (``match`` bucket substring,
  ``backend`` arm, ``request_ids`` poison set).  ``fired`` counts how many
  times it has gone off."""

  point: str
  mode: str = "persistent"
  count: int = 1                  # transient: checks that fire before clearing
  rate: float = 0.0               # rate: per-check fire probability
  match: str = ""                 # bucket-label substring ("" matches all)
  backend: str = ""               # kernel arm filter ("" matches any arm)
  request_ids: FrozenSet[int] = frozenset()  # poison set (empty = whole batch)
  delay_s: float = 0.05           # slow: stall length
  fired: int = 0

  def __post_init__(self):
    if self.point not in POINTS:
      raise ValueError(f"point must be one of {POINTS}, got {self.point!r}")
    if self.mode not in MODES:
      raise ValueError(f"mode must be one of {MODES}, got {self.mode!r}")
    if self.mode == "rate" and not 0.0 <= self.rate <= 1.0:
      raise ValueError(f"rate must be in [0, 1], got {self.rate}")
    if self.mode == "transient" and self.count < 1:
      raise ValueError(f"transient count must be >= 1, got {self.count}")
    self.request_ids = frozenset(int(r) for r in self.request_ids)


class FaultInjector:
  """Seedable, thread-safe fault decision engine.

  ``check(point, label=..., backend=..., request_ids=...)`` returns the
  first armed rule that matches and whose schedule says "fire now" (or
  None).  Decisions are deterministic: transient rules count their own
  firings, rate rules draw from one seeded ``random.Random``, and the lock
  serializes both against the background serving loop."""

  def __init__(self, rules: Sequence[FaultRule] = (), *, seed: int = 0):
    self._lock = threading.Lock()
    self._rules: list[FaultRule] = list(rules)
    self._rng = random.Random(seed)
    self._fired_by_point = {p: 0 for p in POINTS}

  def arm(self, rule: FaultRule) -> FaultRule:
    with self._lock:
      self._rules.append(rule)
    return rule

  def clear(self, point: Optional[str] = None) -> int:
    """Drop all rules (or just one point's) — "the fault cleared".  Returns
    how many rules were removed.  Used by recovery tests to let a half-open
    breaker probe succeed."""
    with self._lock:
      keep = [r for r in self._rules
              if point is not None and r.point != point]
      removed = len(self._rules) - len(keep)
      self._rules = keep
      return removed

  def rules(self) -> list:
    with self._lock:
      return list(self._rules)

  def check(self, point: str, *, label: str = "", backend: str = "",
            request_ids: Sequence[int] = ()) -> Optional[FaultRule]:
    """Should this injection point fire for this (bucket, arm, batch)?
    Returns the firing rule (its ``delay_s``/``request_ids`` parameterize
    the fault) or None."""
    with self._lock:
      for rule in self._rules:
        if rule.point != point:
          continue
        if rule.match and rule.match not in label:
          continue
        if rule.backend and rule.backend != backend:
          continue
        if rule.request_ids and not rule.request_ids.intersection(request_ids):
          continue
        if rule.mode == "transient" and rule.fired >= rule.count:
          continue
        if rule.mode == "rate" and not self._rng.random() < rule.rate:
          continue
        rule.fired += 1
        self._fired_by_point[point] += 1
        return rule
      return None

  def stats(self) -> dict:
    with self._lock:
      return {
          "rules": len(self._rules),
          "fired": dict(self._fired_by_point),
          "fired_total": sum(self._fired_by_point.values()),
      }


def parse_fault_spec(spec: str, *, seed: int = 0) -> FaultInjector:
  """``--inject-faults`` grammar → FaultInjector (see module docstring).

  ``spec`` is ';'-separated rules, each
  ``point:mode[:arg][:key=value...][@match]`` — ``arg`` is the transient
  count or the rate probability; keys are ``delay`` (seconds, for slow),
  ``backend`` (arm filter), ``rid`` (comma-separated poison request ids).
  """
  rules = []
  for part in spec.split(";"):
    part = part.strip()
    if not part:
      continue
    match = ""
    if "@" in part:
      part, match = part.rsplit("@", 1)
    tokens = part.split(":")
    if not tokens or not tokens[0]:
      raise ValueError(f"empty fault rule in spec {spec!r}")
    kw: dict = {"point": tokens[0], "match": match}
    positional = []
    for tok in tokens[1:]:
      if "=" in tok:
        k, v = tok.split("=", 1)
        if k == "delay":
          kw["delay_s"] = float(v)
        elif k == "backend":
          kw["backend"] = v
        elif k == "rid":
          kw["request_ids"] = frozenset(int(x) for x in v.split(",") if x)
        else:
          raise ValueError(f"unknown fault rule key {k!r} in {part!r}")
      else:
        positional.append(tok)
    if positional:
      kw["mode"] = positional[0]
    if len(positional) > 1:
      if kw.get("mode") == "rate":
        kw["rate"] = float(positional[1])
      else:
        kw["count"] = int(positional[1])
    if len(positional) > 2:
      raise ValueError(f"too many positional tokens in fault rule {part!r}")
    rules.append(FaultRule(**kw))
  if not rules:
    raise ValueError(f"fault spec {spec!r} contains no rules")
  return FaultInjector(rules, seed=seed)

"""Prometheus text exposition for the serving engine — dependency-free.

Renders the engine's live state (``ServeMetrics`` counters + histograms,
scheduler/admission gauges, executable-cache counters, estimator cells and
their drift against the static cost model, flight-recorder stats) as
Prometheus text exposition format 0.0.4: ``# HELP`` / ``# TYPE`` once per
family, one sample line per labeled series.  No client library — the
grammar is a dozen lines of formatting, and the serving image must not grow
a dependency for it.

Histograms here are **fixed log-bucketed**, complementing the rolling
windows in metrics.py: a window answers "p99 over the last 512
observations" (recent, bounded memory, but forgets), a cumulative histogram
answers "the full latency distribution since start" in a form Prometheus
can aggregate across scrapes and instances (``histogram_quantile`` over
``rate()``).  Buckets double from 10 µs to ~20 s (see DESIGN.md
§Observability): doubling bounds the relative quantile error at 2× with 22
buckets covering everything from a warm 16³ mmo batch to a cold sharded
1024-node Bellman-Ford fixpoint, and *fixed* boundaries mean every engine
instance emits the same ``le`` labels, so fleet-wide aggregation is a sum.
"""
from __future__ import annotations

import bisect
import math
import threading

__all__ = ["LogHistogram", "HISTOGRAM_BOUNDS_S", "render_prometheus",
           "escape_label_value"]

# 10 µs · 2^k for k = 0..21 → top finite bound ≈ 21 s
HISTOGRAM_BOUNDS_S = tuple(1e-5 * 2.0 ** k for k in range(22))


class LogHistogram:
  """Cumulative histogram over fixed log-spaced boundaries.

  ``add`` is O(log #buckets) (a bisect) under the owner's lock — the
  ``ServeMetrics`` registry embeds these next to its rolling windows and
  guards both with its one lock.  ``state()`` snapshots (counts, sum,
  total) for the renderer."""

  __slots__ = ("bounds", "_counts", "_sum", "_n")

  def __init__(self, bounds=HISTOGRAM_BOUNDS_S):
    self.bounds = tuple(float(b) for b in bounds)
    if not self.bounds or list(self.bounds) != sorted(self.bounds):
      raise ValueError("histogram bounds must be non-empty and ascending")
    self._counts = [0] * (len(self.bounds) + 1)  # last slot: > top bound
    self._sum = 0.0
    self._n = 0

  def add(self, value: float) -> None:
    value = float(value)
    if not (value >= 0.0 and math.isfinite(value)):
      return  # telemetry must never throw on a bogus reading
    self._counts[bisect.bisect_left(self.bounds, value)] += 1
    self._sum += value
    self._n += 1

  @property
  def count(self) -> int:
    return self._n

  def state(self) -> tuple:
    """(per-bucket counts incl. overflow, sum, total count) — copy."""
    return list(self._counts), self._sum, self._n


def escape_label_value(value: str) -> str:
  """Prometheus label-value escaping: backslash, double quote, newline."""
  return (str(value).replace("\\", "\\\\").replace('"', '\\"')
          .replace("\n", "\\n"))


def _labels(**kv) -> str:
  if not kv:
    return ""
  inner = ",".join(f'{k}="{escape_label_value(v)}"'
                   for k, v in sorted(kv.items()))
  return "{" + inner + "}"


def _num(v) -> str:
  """Prometheus sample value formatting (+Inf/-Inf/NaN spellings)."""
  f = float(v)
  if math.isinf(f):
    return "+Inf" if f > 0 else "-Inf"
  if math.isnan(f):
    return "NaN"
  return repr(f) if f != int(f) else str(int(f))


class _Writer:
  """Accumulates families; enforces one HELP/TYPE per metric name."""

  def __init__(self):
    self._lines = []
    self._seen = set()

  def family(self, name: str, mtype: str, help_text: str):
    if name in self._seen:
      raise ValueError(f"duplicate metric family {name!r}")
    self._seen.add(name)
    self._lines.append(f"# HELP {name} {help_text}")
    self._lines.append(f"# TYPE {name} {mtype}")

  def sample(self, name: str, value, **labels):
    self._lines.append(f"{name}{_labels(**labels)} {_num(value)}")

  def text(self) -> str:
    return "\n".join(self._lines) + "\n"


def _histogram(w: _Writer, name: str, bounds, series: dict):
  """One histogram family; ``series`` maps label-dict-tuples → state."""
  for labels, (counts, total_sum, n) in series.items():
    labels = dict(labels)
    cum = 0
    for bound, c in zip(bounds, counts):
      cum += c
      w.sample(f"{name}_bucket", cum, le=_num(bound), **labels)
    w.sample(f"{name}_bucket", n, le="+Inf", **labels)
    w.sample(f"{name}_sum", total_sum, **labels)
    w.sample(f"{name}_count", n, **labels)


def render_prometheus(state: dict) -> str:
  """Render one engine observability state (``MMOEngine.observability_state``)
  as Prometheus text exposition.  Pure function of the passed snapshot — no
  locks, callable from the HTTP handler thread without touching the serving
  path."""
  w = _Writer()
  m = state["metrics"]

  w.family("serve_uptime_seconds", "gauge",
           "Seconds since the metrics registry started.")
  w.sample("serve_uptime_seconds", m["uptime_s"])

  counter_help = {
      "submitted": "Requests submitted (pre-admission).",
      "completed": "Requests completed successfully.",
      "rejected": "Requests refused by admission control.",
      "expired": "Requests that missed their deadline while queued.",
      "failed": "Requests failed by a batch execution error.",
      "batches": "Batches executed.",
      "h2d_bytes": "Host-to-device bytes pad-and-stacked into batches.",
      "retries": "Sub-batches re-dispatched by the recovery path "
                 "(transient retries + bisection halves).",
  }
  for name, count in sorted(m["counters"].items()):
    w.family(f"serve_{name}_total", "counter",
             counter_help.get(name, f"Engine counter {name}."))
    w.sample(f"serve_{name}_total", count)

  w.family("serve_rejected_by_reason_total", "counter",
           "Admission rejections by reason kind.")
  for reason, count in sorted(m["rejected_by_reason"].items()):
    w.sample("serve_rejected_by_reason_total", count, reason=reason)

  w.family("serve_batch_failures_total", "counter",
           "Failed batch attempts by failure kind (every failed attempt "
           "counts, including ones recovered by retry/bisection).")
  for kind, count in sorted(m.get("batch_failures_by_kind", {}).items()):
    w.sample("serve_batch_failures_total", count, kind=kind)

  # per-bucket outcome counters
  w.family("serve_bucket_completed_total", "counter",
           "Completed requests per shape bucket.")
  w.family("serve_bucket_expired_total", "counter",
           "Deadline-expired requests per shape bucket.")
  w.family("serve_bucket_failed_total", "counter",
           "Failed requests per shape bucket.")
  for label, b in sorted(m["buckets"].items()):
    w.sample("serve_bucket_completed_total", b["completed"], bucket=label)
    w.sample("serve_bucket_expired_total", b["expired"], bucket=label)
    w.sample("serve_bucket_failed_total", b["failed"], bucket=label)

  # per-bucket latency histograms (fixed log buckets — see module docstring)
  hist_help = {
      "queue": ("serve_queue_seconds",
                "Queue latency (submit to batch pick) per bucket."),
      "service": ("serve_service_seconds",
                  "Service latency (batch pick to results) per bucket."),
      "host": ("serve_batch_host_seconds",
               "Per-batch host time (pad-and-stack + split) per bucket."),
      "device": ("serve_batch_device_seconds",
                 "Per-batch device compute time per bucket."),
  }
  for which, (name, help_text) in hist_help.items():
    series = {}
    for label, b in sorted(m["buckets"].items()):
      hist = b["histograms"].get(which)
      if hist is not None:
        series[(("bucket", label),)] = hist
    if series:
      bounds = m["histogram_bounds_s"]
      w.family(name, "histogram", help_text)
      _histogram(w, name, bounds, series)

  # live gauges
  w.family("serve_queue_depth", "gauge", "Requests queued right now.")
  w.sample("serve_queue_depth", state["queue_depth"])
  w.family("serve_executing", "gauge",
           "Requests inside the currently executing batch.")
  w.sample("serve_executing", state["executing"])

  adm = state["admission"]
  w.family("serve_backlog_seconds", "gauge",
           "Predicted seconds of work in the queue (admission accounting).")
  w.sample("serve_backlog_seconds", adm["backlog_s"])
  w.family("serve_admission_evaluations_total", "counter",
           "Admission decisions taken (admit + reject).")
  w.sample("serve_admission_evaluations_total", adm["evaluations"])
  w.family("serve_tenant_inflight", "gauge",
           "In-flight (queued + executing) requests per tenant.")
  for tenant, n in sorted(adm["inflight"].items()):
    w.sample("serve_tenant_inflight", n, tenant=tenant)

  cache = state["cache"]
  w.family("serve_executable_cache_hits_total", "counter",
           "Executable cache hits (batch reused a stored program).")
  w.sample("serve_executable_cache_hits_total", cache["hits"])
  w.family("serve_executable_cache_misses_total", "counter",
           "Executable cache misses (a batch traced + compiled — retraces).")
  w.sample("serve_executable_cache_misses_total", cache["misses"])
  w.family("serve_executable_cache_size", "gauge",
           "Stored executables.")
  w.sample("serve_executable_cache_size", cache["executables"])

  sched = state["scheduler"]
  w.family("serve_scheduler_picks_total", "counter",
           "Bucket picks taken by the scheduling policy.")
  w.sample("serve_scheduler_picks_total", sched["picks"])
  w.family("serve_scheduler_pick_seconds_total", "counter",
           "Wall seconds spent picking buckets (policy + harvest).")
  w.sample("serve_scheduler_pick_seconds_total", sched["pick_seconds"])

  # estimator: live EWMA cells + drift against the static cost model
  w.family("serve_estimator_seconds", "gauge",
           "Warm per-request EWMA service seconds per "
           "(bucket, backend, schedule) cell.")
  w.family("serve_estimator_observations", "gauge",
           "Observations held by each estimator cell.")
  w.family("serve_estimator_drift_ratio", "gauge",
           "Measured EWMA / static cost-model prediction per cell: how far "
           "reality has drifted from the table (1.0 = model is exact).")
  for cell in state["estimator_cells"]:
    labels = dict(bucket=cell["bucket"], backend=cell["backend"],
                  schedule=cell["schedule"])
    w.sample("serve_estimator_seconds", cell["seconds"], **labels)
    w.sample("serve_estimator_observations", cell["observations"], **labels)
    if cell.get("drift") is not None:
      w.sample("serve_estimator_drift_ratio", cell["drift"], **labels)

  # circuit breakers: one gauge per (bucket, backend, schedule) arm
  w.family("serve_breaker_state", "gauge",
           "Circuit-breaker state per (bucket, backend, schedule) arm: "
           "0=closed, 1=open, 2=half_open.")
  w.family("serve_breaker_opens_total", "counter",
           "Times each arm's breaker opened.")
  w.family("serve_breaker_probes_total", "counter",
           "Half-open probe batches sent to each arm.")
  _breaker_gauge = {"closed": 0, "open": 1, "half_open": 2}
  for cell in state.get("breakers", ()):
    labels = dict(bucket=cell["bucket"], backend=cell["backend"],
                  schedule=cell["schedule"])
    w.sample("serve_breaker_state",
             _breaker_gauge.get(cell["state"], 0), **labels)
    w.sample("serve_breaker_opens_total", cell["opens"], **labels)
    w.sample("serve_breaker_probes_total", cell["probes"], **labels)

  trace = state["trace"]
  w.family("serve_trace_events_total", "counter",
           "Trace events recorded by the flight recorder.")
  w.sample("serve_trace_events_total", trace["recorded"])
  w.family("serve_trace_events_dropped_total", "counter",
           "Trace events evicted from the flight-recorder ring.")
  w.sample("serve_trace_events_dropped_total", trace["dropped"])
  w.family("serve_trace_enabled", "gauge",
           "Whether request-lifecycle tracing is on (1) or off (0).")
  w.sample("serve_trace_enabled", 1 if trace["enabled"] else 0)

  return w.text()

"""Train / serve step factories — the functions the launcher jits and the
dry-run lowers.

``make_train_step`` builds a (state, batch) → (state, metrics) function with:
  * next-token cross-entropy (+ MoE load-balance aux, weight 0.01),
  * gradient microbatching (sequential accumulation over `accum` slices —
    the compute/memory knob at fixed global batch),
  * AdamW update with global-norm clip,
  * donated state (in-place buffers at scale).

``make_prefill_step`` / ``make_decode_step`` are the two serving lowerings
(decode_* / long_* shapes lower the decode step, per the assignment).
"""
from __future__ import annotations

import functools
from typing import Optional

import jax
import jax.numpy as jnp

from repro.models import common as cm
from repro.models import zoo
from repro.train import optimizer as opt_mod

Array = jax.Array


def xent_loss(logits: Array, labels: Array, vocab: int) -> Array:
  """Mean next-token cross-entropy; labels ≥ vocab (pad ids) are masked."""
  logits = logits.astype(jnp.float32)
  logz = jax.nn.logsumexp(logits, axis=-1)
  gold = jnp.take_along_axis(logits, labels[..., None].clip(0), axis=-1)[..., 0]
  nll = logz - gold
  mask = (labels >= 0) & (labels < vocab)
  return jnp.sum(nll * mask) / jnp.maximum(1.0, jnp.sum(mask))


def loss_fn(params, cfg: cm.ModelConfig, batch: dict, *, impl: str = "xla",
            remat: str = "none"):
  logits, _, aux = zoo.forward(params, cfg, batch, mode="train", impl=impl,
                               remat=remat)
  loss = xent_loss(logits[:, :-1], batch["labels"][:, 1:], cfg.vocab)
  return loss + 0.01 * aux, (loss, aux)


def make_train_step(cfg: cm.ModelConfig, oc: opt_mod.AdamWConfig, *,
                    accum: int = 1, impl: str = "xla", remat: str = "none",
                    grad_specs=None, zero2: bool = False,
                    grad_comm_bf16: bool = False):
  """Returns train_step((params, opt_state), batch) → (state, metrics).

  ``grad_specs`` (pytree of PartitionSpec matching params): pins gradient
  shardings to the parameter layout — without it GSPMD materializes
  replicated fp32 gradients for non-stacked (shared/tied) weights before
  reducing, which blows per-device memory at scale.

  ``zero2``: ZeRO-2 collective schedule — the fp32 master stays
  fsdp-sharded, but bf16 *compute* params are gathered ONCE per step
  (outside the microbatch loop) instead of re-gathered per microbatch
  (ZeRO-3/FSDP default).  Trades +params(bf16)/tp_size resident memory for
  an accum× reduction in parameter all-gather traffic; gradients are still
  reduce-scattered back to the master sharding every microbatch.

  ``grad_comm_bf16``: compress the per-microbatch cross-device gradient
  reduction to bf16 (standard DDP-style compression; local accumulation
  stays fp32) — halves the gradient all-reduce bytes, which dominate the
  collective term for large dense models at high accum.
  """
  from jax.sharding import PartitionSpec

  def _drop_fsdp(spec: PartitionSpec) -> PartitionSpec:
    # remove data axes from a param spec (keep pure-TP sharding)
    data_axes = set()
    for entry in spec:
      for ax in (entry if isinstance(entry, tuple) else (entry,)):
        if ax is not None and ("data" in str(ax) or "pod" in str(ax)):
          data_axes.add(ax)

    def strip(entry):
      if isinstance(entry, tuple):
        kept = tuple(a for a in entry if a not in data_axes)
        return kept if len(kept) > 1 else (kept[0] if kept else None)
      return None if entry in data_axes else entry
    return PartitionSpec(*(strip(e) for e in spec))

  grad_fn = jax.value_and_grad(
      functools.partial(loss_fn, cfg=cfg, impl=impl, remat=remat),
      has_aux=True)

  def pin(grads):
    if grad_specs is None:
      return grads
    return jax.tree.map(
        lambda s, g: jax.lax.with_sharding_constraint(g, s), grad_specs,
        grads, is_leaf=lambda x: isinstance(x, PartitionSpec))

  def gather_compute_params(params):
    """bf16 copy of the master, unsharded over the data axes (one gather)."""
    def one(s, p):
      c = p.astype(cfg.dtype) if jnp.issubdtype(p.dtype, jnp.floating) else p
      return jax.lax.with_sharding_constraint(c, _drop_fsdp(s))
    return jax.tree.map(one, grad_specs, params,
                        is_leaf=lambda x: isinstance(x, PartitionSpec))

  def microbatches(batch):
    def split(x):
      b = x.shape[0]
      return x.reshape(accum, b // accum, *x.shape[1:])
    return jax.tree.map(split, batch)

  def train_step(state, batch):
    params, opt_state = state
    fwd_params = params
    if zero2 and grad_specs is not None:
      fwd_params = gather_compute_params(params)
      # differentiate wrt the gathered bf16 copy; the master-spec pin below
      # turns the parameter-gradient psum into a reduce-scatter
      gfn = jax.value_and_grad(
          functools.partial(loss_fn, cfg=cfg, impl=impl, remat=remat),
          has_aux=True)
    else:
      gfn = grad_fn

    def to_master(g):
      if grad_comm_bf16:
        # bf16 over the wire (the pin's reshard/reduce), fp32 local accum
        g = jax.tree.map(lambda x: x.astype(jnp.bfloat16), g)
        g = pin(g)
        return jax.tree.map(lambda x: x.astype(jnp.float32), g)
      return pin(jax.tree.map(lambda x: x.astype(jnp.float32), g))

    if accum == 1:
      (tot, (loss, aux)), grads = gfn(fwd_params, batch=batch)
      grads = to_master(grads)
    else:
      mb = microbatches(batch)

      def body(carry, mb_i):
        g_acc, l_acc, a_acc = carry
        (tot, (loss, aux)), g = gfn(fwd_params, batch=mb_i)
        g = to_master(g)
        return (pin(jax.tree.map(jnp.add, g_acc, g)), l_acc + loss,
                a_acc + aux), None

      g0 = pin(jax.tree.map(lambda p: jnp.zeros(p.shape, jnp.float32),
                            params))
      (grads, loss, aux), _ = jax.lax.scan(
          body, (g0, jnp.zeros((), jnp.float32), jnp.zeros(())), mb)
      grads = jax.tree.map(lambda g: g / accum, grads)
      loss, aux = loss / accum, aux / accum

    new_params, new_opt, om = opt_mod.adamw_update(oc, params, grads,
                                                   opt_state)
    metrics = {"loss": loss, "aux_loss": aux, **om}
    return (new_params, new_opt), metrics

  return train_step


def make_prefill_step(cfg: cm.ModelConfig, *, impl: str = "xla"):
  def prefill_step(params, batch):
    logits, cache, _ = zoo.forward(params, cfg, batch, mode="prefill",
                                   impl=impl)
    return logits[:, -1, :], cache
  return prefill_step


def make_decode_step(cfg: cm.ModelConfig, *, greedy: bool = True):
  def decode_step(params, cache, batch):
    """batch: {'tokens': (B,1)} (+ 'src_embeds'/'enc_out' for enc-dec)."""
    logits, cache, _ = zoo.forward(params, cfg, batch, mode="decode",
                                   cache=cache,
                                   enc_out=batch.get("enc_out"))
    nxt = jnp.argmax(logits[:, -1, :], axis=-1).astype(jnp.int32)
    return nxt[:, None], cache
  return decode_step

"""Training substrate: optimizer, steps, checkpointing."""
from repro.train.optimizer import AdamWConfig, adamw_update, init_opt_state
from repro.train.steps import make_decode_step, make_prefill_step, make_train_step, xent_loss
from repro.train import checkpoint

__all__ = ["AdamWConfig", "adamw_update", "init_opt_state", "make_train_step",
           "make_prefill_step", "make_decode_step", "xent_loss", "checkpoint"]

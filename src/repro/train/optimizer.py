"""Sharded AdamW with global-norm clipping and decoupled weight decay.

Optimizer moments inherit the parameter PartitionSpecs (ZeRO-style: with
``Parallelism.fsdp`` the master weights *and* both moments are sharded over
the data axis, so optimizer memory scales 1/(dp·tp)).  Pure pytree — no
optax dependency.
"""
from __future__ import annotations

import dataclasses
from typing import Any, Optional

import jax
import jax.numpy as jnp

Array = jax.Array


@dataclasses.dataclass(frozen=True)
class AdamWConfig:
  lr: float = 3e-4
  b1: float = 0.9
  b2: float = 0.95
  eps: float = 1e-8
  weight_decay: float = 0.1
  grad_clip: float = 1.0
  warmup_steps: int = 100
  total_steps: int = 10000
  min_lr_ratio: float = 0.1


def lr_schedule(c: AdamWConfig, step: Array) -> Array:
  """Linear warmup → cosine decay to min_lr_ratio·lr."""
  step = step.astype(jnp.float32)
  warm = step / jnp.maximum(1.0, c.warmup_steps)
  prog = (step - c.warmup_steps) / jnp.maximum(
      1.0, c.total_steps - c.warmup_steps)
  prog = jnp.clip(prog, 0.0, 1.0)
  cos = c.min_lr_ratio + (1 - c.min_lr_ratio) * 0.5 * (
      1 + jnp.cos(jnp.pi * prog))
  return c.lr * jnp.where(step < c.warmup_steps, warm, cos)


def init_opt_state(params) -> dict:
  zeros = lambda p: jnp.zeros_like(p)
  return {
      "m": jax.tree.map(zeros, params),
      "v": jax.tree.map(zeros, params),
      "step": jnp.zeros((), jnp.int32),
  }


def global_norm(tree) -> Array:
  return jnp.sqrt(sum(jnp.sum(jnp.square(x.astype(jnp.float32)))
                      for x in jax.tree.leaves(tree)))


def _decay_mask(path: str) -> bool:
  """No weight decay on norms/biases/1-D scales (standard practice)."""
  needle = path.lower()
  return not any(s in needle for s in ("norm", "bias", "scale", "a_log",
                                       "dt_", "skip_d"))


def _paths(tree, prefix=""):
  if isinstance(tree, dict):
    out = {}
    for k, v in tree.items():
      sub = _paths(v, f"{prefix}/{k}")
      out[k] = sub
    return out
  return prefix


def adamw_update(c: AdamWConfig, params, grads, opt_state):
  """Returns (new_params, new_opt_state, metrics)."""
  gnorm = global_norm(grads)
  clip = jnp.minimum(1.0, c.grad_clip / (gnorm + 1e-9))
  step = opt_state["step"] + 1
  lr = lr_schedule(c, step)
  b1, b2 = c.b1, c.b2
  bc1 = 1 - b1 ** step.astype(jnp.float32)
  bc2 = 1 - b2 ** step.astype(jnp.float32)

  path_tree = _paths(params)

  def upd(path, p, g, m, v):
    g = g.astype(jnp.float32) * clip
    p32 = p.astype(jnp.float32)
    m = b1 * m + (1 - b1) * g
    v = b2 * v + (1 - b2) * g * g
    mhat = m / bc1
    vhat = v / bc2
    delta = mhat / (jnp.sqrt(vhat) + c.eps)
    if _decay_mask(path):
      delta = delta + c.weight_decay * p32
    return (p32 - lr * delta).astype(p.dtype), m, v

  flat_paths = jax.tree.leaves(path_tree)
  flat_p = jax.tree.leaves(params)
  flat_g = jax.tree.leaves(grads)
  flat_m = jax.tree.leaves(opt_state["m"])
  flat_v = jax.tree.leaves(opt_state["v"])
  treedef = jax.tree.structure(params)

  new_p, new_m, new_v = [], [], []
  for path, p, g, m, v in zip(flat_paths, flat_p, flat_g, flat_m, flat_v):
    a, b_, cc = upd(path, p, g, m, v)
    new_p.append(a)
    new_m.append(b_)
    new_v.append(cc)

  return (jax.tree.unflatten(treedef, new_p),
          {"m": jax.tree.unflatten(treedef, new_m),
           "v": jax.tree.unflatten(treedef, new_v),
           "step": step},
          {"grad_norm": gnorm, "lr": lr})

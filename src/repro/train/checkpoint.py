"""Fault-tolerant checkpointing: atomic-commit save, exact-resume restore.

Layout (one directory per step):

    <dir>/step_000420/
        meta.json            {step, name, tree paths, shard info}
        shard_p0.npz         flattened arrays (this host's shard)
    <dir>/LATEST             committed pointer (written last — atomicity)

Writes go to ``step_X.tmp`` and are renamed only after fsync — a crash
mid-save can never corrupt the committed checkpoint (restart reads LATEST).
On multi-host deployments each process writes ``shard_p<i>.npz`` of its
addressable shards; this build runs single-process and records the hook.
Restart correctness is guaranteed by construction elsewhere: the data
pipeline is stateless (step-indexed PRNG), so params+opt+step is the entire
world state.  tests/test_checkpoint.py kills a run mid-stream and verifies
bit-identical continuation.
"""
from __future__ import annotations

import json
import os
import shutil
from typing import Any, Optional

import jax
import jax.numpy as jnp
import numpy as np


def _flatten(tree, prefix=""):
  out = {}
  if isinstance(tree, dict):
    for k, v in tree.items():
      out.update(_flatten(v, f"{prefix}/{k}" if prefix else k))
    return out
  out[prefix] = tree
  return out


def _unflatten(flat: dict):
  root: dict = {}
  for path, v in flat.items():
    parts = path.split("/")
    cur = root
    for p in parts[:-1]:
      cur = cur.setdefault(p, {})
    cur[parts[-1]] = v
  return root


def save(ckpt_dir: str, step: int, state: Any, extra: Optional[dict] = None):
  """Atomic checkpoint commit of an arbitrary pytree-of-dicts."""
  os.makedirs(ckpt_dir, exist_ok=True)
  name = f"step_{step:08d}"
  tmp = os.path.join(ckpt_dir, name + ".tmp")
  final = os.path.join(ckpt_dir, name)
  if os.path.exists(tmp):
    shutil.rmtree(tmp)
  os.makedirs(tmp)

  flat = _flatten(state)
  arrays = {k: np.asarray(v) for k, v in flat.items()}
  pid = jax.process_index()
  np.savez(os.path.join(tmp, f"shard_p{pid}.npz"), **arrays)
  meta = {
      "step": int(step),
      "paths": sorted(arrays),
      "n_processes": jax.process_count(),
      "extra": extra or {},
  }
  with open(os.path.join(tmp, "meta.json"), "w") as f:
    json.dump(meta, f, indent=1)
    f.flush()
    os.fsync(f.fileno())
  if os.path.exists(final):
    shutil.rmtree(final)
  os.rename(tmp, final)
  # commit pointer last — readers never see a partial checkpoint
  latest_tmp = os.path.join(ckpt_dir, "LATEST.tmp")
  with open(latest_tmp, "w") as f:
    f.write(name)
    f.flush()
    os.fsync(f.fileno())
  os.replace(latest_tmp, os.path.join(ckpt_dir, "LATEST"))
  return final


def latest_step(ckpt_dir: str) -> Optional[int]:
  ptr = os.path.join(ckpt_dir, "LATEST")
  if not os.path.exists(ptr):
    return None
  with open(ptr) as f:
    return int(f.read().strip().split("_")[-1])


class AsyncCheckpointer:
  """Overlap checkpoint I/O with training: `save` snapshots the state to
  host memory synchronously (cheap) and commits to disk on a worker thread.
  `wait()` joins the in-flight write (call before exit / next save)."""

  def __init__(self, ckpt_dir: str):
    import threading
    self.ckpt_dir = ckpt_dir
    self._thread: Optional[threading.Thread] = None

  def save(self, step: int, state: Any, extra: Optional[dict] = None):
    import threading
    self.wait()
    host_state = jax.tree.map(lambda x: np.array(x, copy=True),
                              state)  # host snapshot (copy: donor-safe)
    self._thread = threading.Thread(
        target=save, args=(self.ckpt_dir, step, host_state, extra),
        daemon=True)
    self._thread.start()

  def wait(self):
    if self._thread is not None:
      self._thread.join()
      self._thread = None


def restore(ckpt_dir: str, template: Any = None, step: Optional[int] = None):
  """Returns (state, step).  ``template`` (a matching pytree) restores
  dtypes/shardings; without it, plain numpy arrays are returned."""
  if step is None:
    step = latest_step(ckpt_dir)
    if step is None:
      raise FileNotFoundError(f"no committed checkpoint under {ckpt_dir}")
  path = os.path.join(ckpt_dir, f"step_{step:08d}")
  with open(os.path.join(path, "meta.json")) as f:
    meta = json.load(f)
  pid = jax.process_index()
  with np.load(os.path.join(path, f"shard_p{pid}.npz")) as z:
    flat = {k: z[k] for k in z.files}
  state = _unflatten(flat)
  if template is not None:
    state = jax.tree.map(
        lambda t, v: jnp.asarray(v, getattr(t, "dtype", None)),
        template, state)
  return state, meta["step"]

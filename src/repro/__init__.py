"""repro — SIMD² (generalized matrix instructions) as a multi-pod JAX framework.

Layers: core (semiring mmo + closures + distribution), kernels (Pallas TPU),
apps (the paper's 8 workloads), models/configs (10 assigned architectures),
train/data/launch (distributed substrate), roofline (compiled-HLO analysis).
"""

__version__ = "1.0.0"

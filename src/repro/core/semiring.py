"""Semiring-like structure registry — the heart of SIMD².

The paper (§2.1) identifies the algebraic structure ``D = C ⊕ (A ⊗ B)``
where ⊕ is an addition-like reduction and ⊗ a multiplication-like element
op contracted over the inner (k) dimension.  Nine (⊕, ⊗) pairs are exposed
as SIMD² instructions (paper Table 2); this module is the software registry
for those nine ops plus their algebraic metadata (identities, dtype rules,
MXU-rewrite availability) used by every higher layer (mmo dispatch, Pallas
kernels, closure solvers, distributed collectives, area model).
"""
from __future__ import annotations

import dataclasses
from typing import Callable, Optional

import jax
import jax.numpy as jnp
import numpy as np

Array = jax.Array

# ---------------------------------------------------------------------------
# Element operators.  Each takes broadcastable arrays and returns an array.
# ---------------------------------------------------------------------------


def _sq_diff(a: Array, b: Array) -> Array:
  d = a - b
  return d * d


@dataclasses.dataclass(frozen=True)
class Semiring:
  """One SIMD² (⊕, ⊗) pair.

  Attributes:
    name:            instruction mnemonic (paper Table 2, e.g. ``minplus``).
    oplus:           reduction operator (addition-like, associative+commutative).
    otimes:          element operator applied before the k-contraction.
    oplus_identity:  identity element of ``oplus`` (used to pad / init tiles).
    otimes_identity: identity element of ``otimes`` (the algebraic "1"), or
                     None when the op has none (addnorm's squared difference
                     is not a semiring multiply — the paper's "beyond GEMM"
                     point).  Consumed by the static-analysis law checker
                     (repro.analysis.laws) and the sparse seed validation.
    algorithm:       representative algorithm from paper Table 1 (docs only).
    boolean:         operates on {0,1}/bool lattice (or-and).
    mxu_rewrite:     name of an exact MXU-reuse rewrite ('matmul', 'addnorm',
                     'orand') or None when the op is VPU-only (min/max family).
    accumulate_f32:  paper semantics: 16-bit in, 32-bit out.  min/max-based
                     rings keep the input dtype ordering so they may stay in
                     input precision; (+)-reductions must widen.
  """

  name: str
  oplus: Callable[[Array, Array], Array]
  otimes: Callable[[Array, Array], Array]
  oplus_identity: float
  otimes_identity: Optional[float]
  algorithm: str
  boolean: bool = False
  mxu_rewrite: Optional[str] = None
  accumulate_f32: bool = True

  # -- helpers -------------------------------------------------------------
  def identity_like(self, shape, dtype) -> Array:
    if self.boolean:
      return jnp.zeros(shape, dtype=jnp.bool_)
    return jnp.full(shape, self.oplus_identity, dtype=dtype)

  def acc_dtype(self, in_dtype) -> jnp.dtype:
    if self.boolean:
      return jnp.dtype(jnp.bool_)
    if self.accumulate_f32 and jnp.issubdtype(in_dtype, jnp.floating):
      return jnp.dtype(jnp.float32)
    return jnp.dtype(in_dtype)


_REGISTRY: dict[str, Semiring] = {}


def _register(sr: Semiring) -> Semiring:
  _REGISTRY[sr.name] = sr
  return sr


MMA = _register(
    Semiring(
        name="mma",
        oplus=jnp.add,
        otimes=jnp.multiply,
        oplus_identity=0.0,
        otimes_identity=1.0,
        algorithm="GEMM / matrix inverse",
        mxu_rewrite="matmul",
    )
)

MINPLUS = _register(
    Semiring(
        name="minplus",
        oplus=jnp.minimum,
        otimes=jnp.add,
        oplus_identity=float(np.inf),
        otimes_identity=0.0,
        algorithm="all-pairs shortest paths",
        accumulate_f32=False,
    )
)

MAXPLUS = _register(
    Semiring(
        name="maxplus",
        oplus=jnp.maximum,
        otimes=jnp.add,
        oplus_identity=float(-np.inf),
        otimes_identity=0.0,
        algorithm="maximum cost (critical path)",
        accumulate_f32=False,
    )
)

MINMUL = _register(
    Semiring(
        name="minmul",
        oplus=jnp.minimum,
        otimes=jnp.multiply,
        oplus_identity=float(np.inf),
        otimes_identity=1.0,
        algorithm="minimum reliability paths",
        accumulate_f32=False,
    )
)

MAXMUL = _register(
    Semiring(
        name="maxmul",
        oplus=jnp.maximum,
        otimes=jnp.multiply,
        oplus_identity=float(-np.inf),
        otimes_identity=1.0,
        algorithm="maximum reliability paths",
        accumulate_f32=False,
    )
)

MINMAX = _register(
    Semiring(
        name="minmax",
        oplus=jnp.minimum,
        otimes=jnp.maximum,
        oplus_identity=float(np.inf),
        otimes_identity=float(-np.inf),
        algorithm="minimum spanning tree",
        accumulate_f32=False,
    )
)

MAXMIN = _register(
    Semiring(
        name="maxmin",
        oplus=jnp.maximum,
        otimes=jnp.minimum,
        oplus_identity=float(-np.inf),
        otimes_identity=float(np.inf),
        algorithm="maximum capacity paths",
        accumulate_f32=False,
    )
)

ORAND = _register(
    Semiring(
        name="orand",
        oplus=jnp.logical_or,
        otimes=jnp.logical_and,
        oplus_identity=0.0,  # False
        otimes_identity=1.0,  # True
        algorithm="transitive & reflexive closure",
        boolean=True,
        mxu_rewrite="orand",
        accumulate_f32=False,
    )
)

ADDNORM = _register(
    Semiring(
        name="addnorm",
        oplus=jnp.add,
        otimes=_sq_diff,
        oplus_identity=0.0,
        otimes_identity=None,  # (a-b)^2 has no right/left identity: not a true semiring
        algorithm="L2 distance (KNN / k-means)",
        mxu_rewrite="addnorm",
    )
)

ALL_OPS: tuple[str, ...] = tuple(_REGISTRY)


def get(name_or_sr) -> Semiring:
  """Look up a semiring by mnemonic (or pass a Semiring through)."""
  if isinstance(name_or_sr, Semiring):
    return name_or_sr
  try:
    return _REGISTRY[str(name_or_sr)]
  except KeyError:
    raise ValueError(
        f"unknown SIMD² op {name_or_sr!r}; available: {sorted(_REGISTRY)}"
    ) from None


# ---------------------------------------------------------------------------
# ⊕ as a cross-device collective.  psum/pmin/pmax cover every SIMD² reduction
# (or == max over {0,1}), which is what lets the distributed layer run
# K-sharded contractions with a single generalized all-reduce (see
# core/distributed.py).
# ---------------------------------------------------------------------------


def oplus_allreduce(sr, x: Array, axis_name: str) -> Array:
  sr = get(sr)
  if sr.boolean:
    return jax.lax.pmax(x.astype(jnp.int8), axis_name).astype(jnp.bool_) \
        if x.dtype == jnp.bool_ else jax.lax.pmax(x, axis_name)
  if sr.oplus is jnp.add:
    return jax.lax.psum(x, axis_name)
  if sr.oplus is jnp.minimum:
    return jax.lax.pmin(x, axis_name)
  if sr.oplus is jnp.maximum:
    return jax.lax.pmax(x, axis_name)
  raise NotImplementedError(sr.name)


def oplus_reduce(sr, x: Array, axis: int) -> Array:
  """⊕-reduction along one axis of a single array."""
  sr = get(sr)
  if sr.boolean:
    return jnp.any(x, axis=axis)
  if sr.oplus is jnp.add:
    return jnp.sum(x, axis=axis)
  if sr.oplus is jnp.minimum:
    return jnp.min(x, axis=axis)
  if sr.oplus is jnp.maximum:
    return jnp.max(x, axis=axis)
  raise NotImplementedError(sr.name)


# ---------------------------------------------------------------------------
# K-padding values.  Padding the contraction dimension of A with ``pa`` and
# of B with ``pb`` is an algebraic no-op because ⊗(pa, pb) == the ⊕-identity
# (and never NaN: e.g. maxmul uses (−inf, +inf) so the product is −inf, not
# the −inf·−inf = +inf a naive identity-pad would give).  Shared by the
# Pallas kernel's K-tail handling and the serving layer's shape bucketing.
# ---------------------------------------------------------------------------

_CONTRACTION_PADS = {
    "mma": (0.0, 0.0),
    "minplus": (float("inf"), float("inf")),
    "maxplus": (float("-inf"), float("-inf")),
    "minmul": (float("inf"), float("inf")),
    "maxmul": (float("-inf"), float("inf")),
    "minmax": (float("inf"), float("inf")),
    "maxmin": (float("-inf"), float("-inf")),
    "orand": (0.0, 0.0),
    "addnorm": (0.0, 0.0),
}


def contraction_pads(sr) -> tuple:
  """(pad_a, pad_b) for K-axis padding with ⊗(pad_a, pad_b) == ⊕-identity."""
  return _CONTRACTION_PADS[get(sr).name]

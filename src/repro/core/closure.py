"""Semiring closure solvers — the paper's host-driver algorithms (§4, Fig 7).

The paper composes SIMD² MMOs into whole-problem solvers:

  * All-pairs Bellman-Ford:  D ← D ⊕ (D ⊗ A), up to |V| iterations
    (A = original adjacency; worst-case graph diameter).
  * Leyzorek / repeated squaring:  C ← C ⊕ (C ⊗ C), lg|V| iterations.
  * Optional convergence check each iteration for early exit (Fig 7's
    ``check_convergence``) — on TPU this fuses into the same XLA program
    via ``lax.while_loop`` so there is **no host round-trip**, unlike the
    paper's GPU kernel + host sync (a TPU-native improvement recorded in
    DESIGN.md).
  * Blocked Floyd-Warshall is kept as the classic O(V³) one-pass reference.

All solvers are jit-able, differentiable where the ring is (mma), and work
on sharded inputs (the distributed layer re-uses them with a SUMMA mmo).
"""
from __future__ import annotations

import functools
import math
from typing import Callable, Optional

import jax
import jax.numpy as jnp
import numpy as np

from repro.core.mmo import mmo as _mmo
from repro.core import semiring as sr_mod

Array = jax.Array


def _default_mmo(a, b, c, op, backend, k_valid=None):
  return _mmo(a, b, c, op=op, backend=backend, k_valid=k_valid)


def _changed(new: Array, old: Array) -> Array:
  if new.dtype == jnp.bool_:
    return jnp.any(new != old)
  # inf-aware compare: inf == inf counts as unchanged.  NaN-aware too:
  # NaN != NaN, so without the isnan term a single NaN-bearing request can
  # never converge and spins its whole batch to max_iters — a NaN staying
  # in place is a fixed point like any other value (the validation layer
  # rejects NaN outputs separately).  The megakernel's in-chip reduction
  # implements the identical compare.
  same = ((new == old)
          | (jnp.isinf(new) & jnp.isinf(old) & (jnp.sign(new)
                                                == jnp.sign(old)))
          | (jnp.isnan(new) & jnp.isnan(old)))
  return ~jnp.all(same)


@functools.partial(
    jax.jit,
    static_argnames=("op", "backend", "max_iters", "check_convergence",
                     "mmo_fn"))
def leyzorek_closure(adj: Array,
                     *,
                     op: str,
                     max_iters: Optional[int] = None,
                     check_convergence: bool = True,
                     backend: str = "auto",
                     mmo_fn: Optional[Callable] = None):
  """Repeated squaring C ← C ⊕ (C ⊗ C); lg|V| worst-case iterations.

  Returns (closure, iterations_run).
  """
  n = adj.shape[-1]
  iters = max_iters if max_iters is not None else max(
      1, math.ceil(math.log2(max(n, 2))))
  f = mmo_fn or _default_mmo

  if not check_convergence:
    def body(_, c):
      return f(c, c, c, op, backend)
    out = jax.lax.fori_loop(0, iters, body, adj)
    return out, jnp.asarray(iters, jnp.int32)

  def cond(state):
    _, changed, i = state
    return changed & (i < iters)

  def body(state):
    c, _, i = state
    new = f(c, c, c, op, backend)
    return new, _changed(new, c), i + 1

  out, _, i = jax.lax.while_loop(
      cond, body, (adj, jnp.asarray(True), jnp.asarray(0, jnp.int32)))
  return out, i


@functools.partial(
    jax.jit,
    static_argnames=("op", "backend", "max_iters", "check_convergence",
                     "mmo_fn"))
def bellman_ford_closure(adj: Array,
                         *,
                         op: str,
                         max_iters: Optional[int] = None,
                         check_convergence: bool = True,
                         backend: str = "auto",
                         mmo_fn: Optional[Callable] = None):
  """All-pairs Bellman-Ford D ← D ⊕ (D ⊗ A); |V| worst-case iterations."""
  n = adj.shape[-1]
  iters = max_iters if max_iters is not None else n
  f = mmo_fn or _default_mmo

  if not check_convergence:
    def body(_, d):
      return f(d, adj, d, op, backend)
    out = jax.lax.fori_loop(0, iters, body, adj)
    return out, jnp.asarray(iters, jnp.int32)

  def cond(state):
    _, changed, i = state
    return changed & (i < iters)

  def body(state):
    d, _, i = state
    new = f(d, adj, d, op, backend)
    return new, _changed(new, d), i + 1

  out, _, i = jax.lax.while_loop(
      cond, body, (adj, jnp.asarray(True), jnp.asarray(0, jnp.int32)))
  return out, i


# ---------------------------------------------------------------------------
# Batched closures — the serving engine's entry points.  One compiled program
# closes a whole (R, n, n) stack of same-bucket problems; a per-request
# convergence mask freezes finished problems (their rows stop changing and
# their iteration counters stop) while stragglers keep iterating, so the
# batch runs to max(iters_r) instead of R·mean(iters).
#
# With ``valid_n`` (one true problem size per request), each step's mmo also
# gets a per-request live-K count: rows/columns beyond a request's true n are
# isolated-vertex padding whose contraction terms are ⊕-identity no-ops, so
# the backends skip them (masked K-blocks in the Pallas kernel, a dynamic
# K-block trip count in the vector path).  Converged requests are handed
# k_valid=0 — their step output is discarded by the freeze anyway — so
# finished problems stop paying contraction work, not just the jnp.where.
# ---------------------------------------------------------------------------


def _batched_changed(new: Array, old: Array) -> Array:
  """(R, n, n) × (R, n, n) → (R,) per-request changed flags."""
  return jax.vmap(_changed)(new, old)


def _batched_fixpoint(adj: Array, step_fn, max_iters: int,
                      valid_n: Optional[Array] = None):
  """Iterate ``c ← step_fn(c, k_valid)`` per-request-masked to convergence."""
  r = adj.shape[0]
  if valid_n is not None:
    valid_n = jnp.asarray(valid_n, jnp.int32)

  def cond(state):
    _, active, _, i = state
    return jnp.any(active) & (i < max_iters)

  def body(state):
    c, active, iters, i = state
    kv = None if valid_n is None else jnp.where(active, valid_n, 0)
    new = step_fn(c, kv)
    # freeze converged requests so their results (and counters) stop moving
    new = jnp.where(active[:, None, None], new, c)
    changed = _batched_changed(new, c)
    iters = iters + active.astype(jnp.int32)
    return new, active & changed, iters, i + 1

  state0 = (adj, jnp.ones((r,), jnp.bool_), jnp.zeros((r,), jnp.int32),
            jnp.asarray(0, jnp.int32))
  out, _, iters, _ = jax.lax.while_loop(cond, body, state0)
  return out, iters


def _megakernel_fixpoint(adj, *, op, algorithm, max_iters, valid_n,
                         megakernel_g, interpret):
  """The fused-arm dispatch target — one import seam for both solvers (and
  a lazy one: kernels/ must stay importable without closure and vice versa)."""
  from repro.kernels.closure_megakernel import megakernel_fixpoint
  return megakernel_fixpoint(adj, op=op, algorithm=algorithm,
                             max_iters=max_iters, valid_n=valid_n,
                             g=megakernel_g, interpret=interpret)


@functools.partial(
    jax.jit,
    static_argnames=("op", "backend", "max_iters", "mmo_fn",
                     "fixpoint_backend", "megakernel_g", "interpret"))
def batched_leyzorek_closure(adj: Array,
                             *,
                             op: str,
                             max_iters: Optional[int] = None,
                             backend: str = "auto",
                             mmo_fn: Optional[Callable] = None,
                             valid_n: Optional[Array] = None,
                             fixpoint_backend: str = "dispatch",
                             megakernel_g: int = 8,
                             interpret: Optional[bool] = None):
  """Repeated squaring over a (R, n, n) request stack.

  ``valid_n`` (R,) carries each request's true problem size for ragged
  masked-K work skipping.  Returns (closure (R, n, n), per-request iteration
  counts (R,)).

  ``fixpoint_backend="megakernel"`` (or the cost-table spelling
  ``backend="megakernel"``) runs the whole fixpoint through the fused Pallas
  megakernel in G-iteration chunks (kernels/closure_megakernel.py) —
  bit-identical outputs and iteration counts, HBM traffic paid once per
  ``megakernel_g`` iterations instead of once per squaring.  ``interpret``
  only applies to that arm (default: interpret off-TPU).
  """
  if adj.ndim < 3:
    raise ValueError(f"batched closure needs (R, n, n) input, got {adj.shape}")
  n = adj.shape[-1]
  iters = max_iters if max_iters is not None else max(
      1, math.ceil(math.log2(max(n, 2))))
  if fixpoint_backend == "megakernel" or backend == "megakernel":
    return _megakernel_fixpoint(adj, op=op, algorithm="leyzorek",
                                max_iters=iters, valid_n=valid_n,
                                megakernel_g=megakernel_g, interpret=interpret)
  if fixpoint_backend != "dispatch":
    raise ValueError(f"unknown fixpoint_backend {fixpoint_backend!r}; "
                     f"one of ('dispatch', 'megakernel')")
  f = mmo_fn or _default_mmo
  return _batched_fixpoint(adj, lambda c, kv: f(c, c, c, op, backend, kv),
                           iters, valid_n=valid_n)


@functools.partial(
    jax.jit,
    static_argnames=("op", "backend", "max_iters", "mmo_fn",
                     "fixpoint_backend", "megakernel_g", "interpret"))
def batched_bellman_ford_closure(adj: Array,
                                 *,
                                 op: str,
                                 max_iters: Optional[int] = None,
                                 backend: str = "auto",
                                 mmo_fn: Optional[Callable] = None,
                                 valid_n: Optional[Array] = None,
                                 fixpoint_backend: str = "dispatch",
                                 megakernel_g: int = 8,
                                 interpret: Optional[bool] = None):
  """All-pairs Bellman-Ford D ← D ⊕ (D ⊗ A) over a (R, n, n) request stack.

  ``valid_n`` (R,) enables ragged masked-K work skipping, and
  ``fixpoint_backend="megakernel"`` the fused whole-fixpoint arm (see
  ``batched_leyzorek_closure``).
  """
  if adj.ndim < 3:
    raise ValueError(f"batched closure needs (R, n, n) input, got {adj.shape}")
  n = adj.shape[-1]
  iters = max_iters if max_iters is not None else n
  if fixpoint_backend == "megakernel" or backend == "megakernel":
    return _megakernel_fixpoint(adj, op=op, algorithm="bellman_ford",
                                max_iters=iters, valid_n=valid_n,
                                megakernel_g=megakernel_g, interpret=interpret)
  if fixpoint_backend != "dispatch":
    raise ValueError(f"unknown fixpoint_backend {fixpoint_backend!r}; "
                     f"one of ('dispatch', 'megakernel')")
  f = mmo_fn or _default_mmo
  return _batched_fixpoint(adj, lambda d, kv: f(d, adj, d, op, backend, kv),
                           iters, valid_n=valid_n)


@functools.partial(jax.jit, static_argnames=("op",))
def floyd_warshall(adj: Array, *, op: str) -> Array:
  """Classic k-pivot closure (rank-1 ⊕-updates); the paper's CUDA-FW baseline
  family. O(V) sequential steps of O(V²) work — used as an oracle and as the
  'state-of-the-art GPU baseline' arm in benchmarks."""
  sr = sr_mod.get(op)
  n = adj.shape[-1]

  def body(k, d):
    row = jax.lax.dynamic_slice_in_dim(d, k, 1, axis=-2)  # (1, n)
    col = jax.lax.dynamic_slice_in_dim(d, k, 1, axis=-1)  # (n, 1)
    cand = sr.otimes(col, row)  # outer ⊗
    return sr.oplus(d, cand.astype(d.dtype))

  return jax.lax.fori_loop(0, n, body, adj)


# Per-ring adjacency conventions: ``self`` is the ⊗-identity-ish self
# distance on the diagonal, ``missing`` the no-edge sentinel.  ``missing`` is
# deliberately the *graph* sentinel (0 for maxmul/maxmin capacities), not the
# ⊕-identity: identity-padding a mul-ring adjacency would put −inf next to 0
# weights and manufacture NaNs in ⊗.
_SELF_VALUES = {
    "minplus": 0.0, "maxplus": 0.0,
    "minmul": 1.0, "maxmul": 1.0,
    "minmax": float("-inf"), "maxmin": float("inf"),
    "orand": 1.0, "mma": 0.0, "addnorm": 0.0,
}

_MISSING_VALUES = {
    "minplus": float("inf"), "maxplus": float("-inf"),
    "minmul": float("inf"), "maxmul": 0.0,
    "minmax": float("inf"), "maxmin": 0.0,
    "orand": 0.0, "mma": 0.0, "addnorm": 0.0,
}


def closure_pad_values(op) -> tuple:
  """(missing, self) values for growing an adjacency matrix of ring ``op``.

  Padding a prepared adjacency to (nb, nb) with ``missing`` everywhere and
  ``self`` on the new diagonal adds isolated vertices, so the closure of the
  padded matrix restricted to the original block equals the original closure
  — the invariant the serving layer's shape bucketing relies on (and that
  repro.analysis's semiring-closure-pads rule verifies numerically).

  Rings without a ⊗-identity (addnorm) have no such embedding at all:
  ``(x − missing)² == x²`` lets pad vertices feed values back into the real
  block after one squaring, so closure requests on them are refused here —
  at request construction (api.closure_request) and again at batch stacking.
  """
  sr = sr_mod.get(op)
  if sr.otimes_identity is None:
    raise ValueError(
        f"op {sr.name!r} has no ⊗-identity, so adjacency padding cannot "
        f"embed isolated vertices — closure is undefined for this ring")
  return _MISSING_VALUES[sr.name], _SELF_VALUES[sr.name]


def pad_adjacency(adj, nb: int, *, op: str) -> np.ndarray:
  """Embed a prepared (n, n) adjacency into (nb, nb) as isolated vertices.

  Host-side (numpy) utility — the serving micro-batcher calls it per request
  on the submit path, so it must not pay jax dispatch.  Returns numpy; wrap
  in ``jnp.asarray`` for device use.
  """
  sr = sr_mod.get(op)
  adj = np.asarray(adj)
  n = adj.shape[-1]
  if nb == n:
    return adj
  if nb < n:
    raise ValueError(f"cannot pad {n}→{nb}")
  missing, self_value = closure_pad_values(op)
  if sr.boolean:
    out = np.zeros(adj.shape[:-2] + (nb, nb), dtype=bool)
    out[..., :n, :n] = adj
    diag = np.arange(n, nb)
    out[..., diag, diag] = True
    return out
  out = np.full(adj.shape[:-2] + (nb, nb), missing, dtype=adj.dtype)
  out[..., :n, :n] = adj
  diag = np.arange(n, nb)
  out[..., diag, diag] = np.asarray(self_value, adj.dtype)
  return out


def prepare_adjacency(weights: Array, *, op: str,
                      self_value: Optional[float] = None) -> Array:
  """Fill the diagonal with the ⊗-identity-ish self distance for the ring
  (0 for plus-based paths, 1 for mul-based reliabilities, True for orand,
  -inf/+inf handled by caller semantics)."""
  sr = sr_mod.get(op)
  n = weights.shape[-1]
  if self_value is None:
    self_value = _SELF_VALUES[sr.name]
  eye = jnp.eye(n, dtype=bool)
  if sr.boolean:
    return jnp.where(eye, True, weights.astype(jnp.bool_))
  return jnp.where(eye, jnp.asarray(self_value, weights.dtype), weights)

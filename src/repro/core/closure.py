"""Semiring closure solvers — the paper's host-driver algorithms (§4, Fig 7).

The paper composes SIMD² MMOs into whole-problem solvers:

  * All-pairs Bellman-Ford:  D ← D ⊕ (D ⊗ A), up to |V| iterations
    (A = original adjacency; worst-case graph diameter).
  * Leyzorek / repeated squaring:  C ← C ⊕ (C ⊗ C), lg|V| iterations.
  * Optional convergence check each iteration for early exit (Fig 7's
    ``check_convergence``) — on TPU this fuses into the same XLA program
    via ``lax.while_loop`` so there is **no host round-trip**, unlike the
    paper's GPU kernel + host sync (a TPU-native improvement recorded in
    DESIGN.md).
  * Blocked Floyd-Warshall is kept as the classic O(V³) one-pass reference.

All solvers are jit-able, differentiable where the ring is (mma), and work
on sharded inputs (the distributed layer re-uses them with a SUMMA mmo).
"""
from __future__ import annotations

import functools
import math
from typing import Callable, Optional

import jax
import jax.numpy as jnp

from repro.core.mmo import mmo as _mmo
from repro.core import semiring as sr_mod

Array = jax.Array


def _default_mmo(a, b, c, op, backend):
  return _mmo(a, b, c, op=op, backend=backend)


def _changed(new: Array, old: Array) -> Array:
  if new.dtype == jnp.bool_:
    return jnp.any(new != old)
  # inf-aware compare: inf == inf counts as unchanged.
  same = (new == old) | (jnp.isinf(new) & jnp.isinf(old) & (jnp.sign(new)
                                                            == jnp.sign(old)))
  return ~jnp.all(same)


@functools.partial(
    jax.jit,
    static_argnames=("op", "backend", "max_iters", "check_convergence",
                     "mmo_fn"))
def leyzorek_closure(adj: Array,
                     *,
                     op: str,
                     max_iters: Optional[int] = None,
                     check_convergence: bool = True,
                     backend: str = "auto",
                     mmo_fn: Optional[Callable] = None):
  """Repeated squaring C ← C ⊕ (C ⊗ C); lg|V| worst-case iterations.

  Returns (closure, iterations_run).
  """
  sr = sr_mod.get(op)
  n = adj.shape[-1]
  iters = max_iters if max_iters is not None else max(
      1, math.ceil(math.log2(max(n, 2))))
  f = mmo_fn or _default_mmo

  if not check_convergence:
    def body(_, c):
      return f(c, c, c, op, backend)
    out = jax.lax.fori_loop(0, iters, body, adj)
    return out, jnp.asarray(iters, jnp.int32)

  def cond(state):
    _, changed, i = state
    return changed & (i < iters)

  def body(state):
    c, _, i = state
    new = f(c, c, c, op, backend)
    return new, _changed(new, c), i + 1

  out, _, i = jax.lax.while_loop(
      cond, body, (adj, jnp.asarray(True), jnp.asarray(0, jnp.int32)))
  return out, i


@functools.partial(
    jax.jit,
    static_argnames=("op", "backend", "max_iters", "check_convergence",
                     "mmo_fn"))
def bellman_ford_closure(adj: Array,
                         *,
                         op: str,
                         max_iters: Optional[int] = None,
                         check_convergence: bool = True,
                         backend: str = "auto",
                         mmo_fn: Optional[Callable] = None):
  """All-pairs Bellman-Ford D ← D ⊕ (D ⊗ A); |V| worst-case iterations."""
  n = adj.shape[-1]
  iters = max_iters if max_iters is not None else n
  f = mmo_fn or _default_mmo

  if not check_convergence:
    def body(_, d):
      return f(d, adj, d, op, backend)
    out = jax.lax.fori_loop(0, iters, body, adj)
    return out, jnp.asarray(iters, jnp.int32)

  def cond(state):
    _, changed, i = state
    return changed & (i < iters)

  def body(state):
    d, _, i = state
    new = f(d, adj, d, op, backend)
    return new, _changed(new, d), i + 1

  out, _, i = jax.lax.while_loop(
      cond, body, (adj, jnp.asarray(True), jnp.asarray(0, jnp.int32)))
  return out, i


@functools.partial(jax.jit, static_argnames=("op",))
def floyd_warshall(adj: Array, *, op: str) -> Array:
  """Classic k-pivot closure (rank-1 ⊕-updates); the paper's CUDA-FW baseline
  family. O(V) sequential steps of O(V²) work — used as an oracle and as the
  'state-of-the-art GPU baseline' arm in benchmarks."""
  sr = sr_mod.get(op)
  n = adj.shape[-1]

  def body(k, d):
    row = jax.lax.dynamic_slice_in_dim(d, k, 1, axis=-2)  # (1, n)
    col = jax.lax.dynamic_slice_in_dim(d, k, 1, axis=-1)  # (n, 1)
    cand = sr.otimes(col, row)  # outer ⊗
    return sr.oplus(d, cand.astype(d.dtype))

  return jax.lax.fori_loop(0, n, body, adj)


def prepare_adjacency(weights: Array, *, op: str,
                      self_value: Optional[float] = None) -> Array:
  """Fill the diagonal with the ⊗-identity-ish self distance for the ring
  (0 for plus-based paths, 1 for mul-based reliabilities, True for orand,
  -inf/+inf handled by caller semantics)."""
  sr = sr_mod.get(op)
  n = weights.shape[-1]
  if self_value is None:
    self_value = {
        "minplus": 0.0, "maxplus": 0.0,
        "minmul": 1.0, "maxmul": 1.0,
        "minmax": float("-inf"), "maxmin": float("inf"),
        "orand": 1.0, "mma": 0.0, "addnorm": 0.0,
    }[sr.name]
  eye = jnp.eye(n, dtype=bool)
  if sr.boolean:
    return jnp.where(eye, True, weights.astype(jnp.bool_))
  return jnp.where(eye, jnp.asarray(self_value, weights.dtype), weights)

"""Distributed SIMD² — semiring matmuls and closures on a device mesh.

The paper scales SIMD² across SMs inside one GPU; at pod scale the analogous
question is how the ⊕/⊗ contraction maps onto collectives.  Because every
SIMD² ⊕ is one of {+, min, max, or}, **the cross-device reduction is always
expressible as psum/pmin/pmax** — a "generalized matmul" needs only a
generalized all-reduce.  Three schedules are provided:

  * ``mmo_kspan``      — K-sharded: local partial contraction then a single
                         ⊕-all-reduce.  Minimum collective volume when K is
                         the big axis (one M×N reduce).
  * ``summa_mmo``      — 2-D blocked SUMMA: A row-panels all-gathered along
                         the model axis, B col-panels along the data axis,
                         local contraction on (M/p, K)×(K, N/q) blocks.
                         This is the workhorse for distributed closures where
                         the *same* matrix is squared (Leyzorek), since C
                         stays 2-D-sharded in place across iterations.
  * ``ring_mmo``       — SUMMA with the all-gather replaced by K-step
                         collective_permute so each chunk's contraction
                         overlaps the transfer of the next (compute/comm
                         overlap; the beyond-paper schedule measured in
                         EXPERIMENTS.md §Perf).

All three return bit-identical results (tests assert so on a host-device
mesh) and accept every registered op.

Each schedule also has a **batched** variant (``*_batched``) over a leading
replicated request axis — the serving engine's sharded execution path: one
bucket batch too big for a single device runs the same contraction with its
problem axes sharded across the mesh, while the request axis stays whole so
per-request ``k_valid`` masks (ragged masked-K, PR 2) keep working.  K-sharded
schedules rebase ``k_valid`` per shard/step, so ragged work skipping survives
distribution.  ``sharded_closure_batched`` runs the batched Leyzorek /
Bellman-Ford fixpoint (per-request convergence masks and all) with every ⊕/⊗
step executing as a mesh schedule — SUMMA squaring being the workhorse, since
C stays 2-D-sharded in place across iterations.
"""
from __future__ import annotations

import functools
from typing import Optional

import jax
import jax.numpy as jnp
from jax.sharding import Mesh, PartitionSpec as P

from repro.core.mmo import mmo as _mmo
from repro.core import semiring as sr_mod

if hasattr(jax, "shard_map"):
  shard_map = jax.shard_map
else:  # pragma: no cover — older jax keeps it under experimental
  from jax.experimental.shard_map import shard_map

# jax.lax.pvary only exists on newer jax (varying-axis annotations for
# shard_map rep-checking); older versions don't need the annotation.
pvary = getattr(jax.lax, "pvary", lambda x, axes: x)


def _shard_map(kernel, *, mesh, in_specs, out_specs, check_rep=True):
  """shard_map with a version-tolerant ``check_rep``: the ragged masked-K
  path lowers its dynamic K-block trip count to a ``while``, which has no
  replication rule — those callers pass check_rep=False.  Newer jax versions
  renamed/dropped the kwarg, so fall back to the bare call."""
  try:
    return shard_map(kernel, mesh=mesh, in_specs=in_specs,
                     out_specs=out_specs, check_rep=check_rep)
  except TypeError:  # pragma: no cover — future jax without check_rep
    return shard_map(kernel, mesh=mesh, in_specs=in_specs,
                     out_specs=out_specs)

Array = jax.Array


def _local_contract(a, b, sr_name, backend):
  return _mmo(a, b, None, op=sr_name, backend=backend)


def mmo_kspan(a: Array, b: Array, c: Optional[Array], *, op: str, mesh: Mesh,
              axis: str = "model", backend: str = "auto") -> Array:
  """K-sharded contraction + ⊕-all-reduce along ``axis``.

  A: (M, K) sharded on K over ``axis``; B: (K, N) sharded on K; C/D
  replicated along ``axis``.
  """
  sr = sr_mod.get(op)

  def kernel(a_blk, b_blk, c_blk):
    part = _local_contract(a_blk, b_blk, sr.name, backend)
    full = sr_mod.oplus_allreduce(sr, part, axis)
    if c_blk is not None:
      full = sr.oplus(full, c_blk.astype(full.dtype))
    return full

  in_specs = (P(None, axis), P(axis, None),
              None if c is None else P(None, None))
  fn = shard_map(kernel, mesh=mesh, in_specs=in_specs,
                 out_specs=P(None, None))
  return fn(a, b, c)


def summa_mmo(a: Array, b: Array, c: Optional[Array], *, op: str, mesh: Mesh,
              row_axis: str = "data", col_axis: str = "model",
              backend: str = "auto") -> Array:
  """2-D SUMMA: operands and result all 2-D block-sharded (row_axis, col_axis).

  Per device: all-gather A's K-panels along ``col_axis`` (row broadcast) and
  B's K-panels along ``row_axis`` (column broadcast), contract locally.
  """
  sr = sr_mod.get(op)

  def kernel(a_blk, b_blk, c_blk):
    a_row = jax.lax.all_gather(a_blk, col_axis, axis=1, tiled=True)
    b_col = jax.lax.all_gather(b_blk, row_axis, axis=0, tiled=True)
    out = _local_contract(a_row, b_col, sr.name, backend)
    if c_blk is not None:
      out = sr.oplus(out, c_blk.astype(out.dtype))
    return out

  spec = P(row_axis, col_axis)
  fn = shard_map(kernel, mesh=mesh,
                 in_specs=(spec, spec, None if c is None else spec),
                 out_specs=spec)
  return fn(a, b, c)


def ring_mmo(a: Array, b: Array, c: Optional[Array], *, op: str, mesh: Mesh,
             axis: str = "model", backend: str = "auto") -> Array:
  """1-D ring schedule: B K-sharded along ``axis`` and rotating; device j owns
  output columns C[:, Nj] and ⊕-accumulates one K-chunk's contribution per
  step.  Each step's contraction overlaps the next chunk's collective-permute
  (the overlapped alternative to SUMMA's blocking all-gather; compared in
  EXPERIMENTS.md §Perf)."""
  sr = sr_mod.get(op)
  n_dev = mesh.shape[axis]

  def kernel(a_blk, b_blk, c_blk):
    # a_blk: (M, K) replicated; b_blk: (K/p, N) rotating K-chunk.
    idx = jax.lax.axis_index(axis)
    perm = [(i, (i + 1) % n_dev) for i in range(n_dev)]
    k_chunk = b_blk.shape[0]
    n_cols = b_blk.shape[1] // n_dev  # my output column block

    def step(i, state):
      b_cur, acc = state
      # after i forward rotations the chunk held here originated at device
      # (idx - i) mod p → it holds K rows [src*k_chunk, ...).
      src = (idx - i) % n_dev
      a_piece = jax.lax.dynamic_slice_in_dim(a_blk, src * k_chunk, k_chunk, 1)
      b_cols = jax.lax.dynamic_slice_in_dim(b_cur, idx * n_cols, n_cols, 1)
      part = _local_contract(a_piece, b_cols, sr.name, backend)
      acc = sr.oplus(acc, part.astype(acc.dtype))
      b_nxt = jax.lax.ppermute(b_cur, axis, perm)
      return b_nxt, acc

    m = a_blk.shape[0]
    acc0 = sr.identity_like((m, n_cols), sr.acc_dtype(a_blk.dtype))
    acc0 = pvary(acc0, (axis,))
    _, acc = jax.lax.fori_loop(0, n_dev, step, (b_blk, acc0))
    if c_blk is not None:
      acc = sr.oplus(acc, c_blk.astype(acc.dtype))
    return acc

  in_specs = (P(None, None), P(axis, None),
              None if c is None else P(None, axis))
  fn = shard_map(kernel, mesh=mesh, in_specs=in_specs,
                 out_specs=P(None, axis))
  return fn(a, b, c)


# ---------------------------------------------------------------------------
# Batched schedules — a leading request axis (the serving engine's sharded
# bucket-batch path).  kspan/summa/ring shard the *problem* axes and keep the
# request axis replicated (specs mirror the unbatched variants with a ``None``
# prepended); ``dp`` shards the *request* axis over every mesh device and
# needs no collectives at all.  ``k_valid`` is one live-K count per request.
# ---------------------------------------------------------------------------

SCHEDULES = ("dp", "kspan", "summa", "ring")


def _dp_axes(mesh: Mesh) -> tuple:
  """The composite leading-axis sharding for dp: every mesh axis at once."""
  return tuple(mesh.axis_names)


def _local_kv(kv, axis, k_chunk):
  """Rebase a per-request global live-K count onto this shard's K-chunk
  [idx·k_chunk, (idx+1)·k_chunk): lanes before the chunk are someone else's,
  lanes past the global count are dead pads either way."""
  if kv is None:
    return None
  idx = jax.lax.axis_index(axis)
  return jnp.clip(kv - idx * k_chunk, 0, k_chunk)


def mmo_dp_batched(a: Array, b: Array, c: Optional[Array] = None, *,
                   op: str, mesh: Mesh, backend: str = "xla",
                   block: Optional[tuple] = None,
                   interpret: Optional[bool] = None,
                   k_valid: Optional[Array] = None) -> Array:
  """Batched data-parallel contraction: requests sharded over all devices.

  Each device contracts its own R/P requests locally — zero collectives,
  the vLLM-style scale-out schedule for a bucket batch of *independent*
  problems.  Requires R divisible by the mesh's device count (the engine
  falls back to 'local' for partial batches).
  """
  if a.shape[0] % mesh.size:
    raise ValueError(f"dp needs the request axis ({a.shape[0]}) divisible by "
                     f"the mesh's {mesh.size} devices")
  sr = sr_mod.get(op)
  axes = _dp_axes(mesh)
  spec = P(axes, None, None)

  def kernel(a_blk, b_blk, c_blk, kv):
    return _mmo(a_blk, b_blk, c_blk, op=sr.name, backend=backend,
                block=block or None, interpret=interpret, k_valid=kv)

  in_specs = (spec, spec, None if c is None else spec,
              None if k_valid is None else P(axes))
  fn = _shard_map(kernel, mesh=mesh, in_specs=in_specs, out_specs=spec,
                  check_rep=k_valid is None)
  return fn(a, b, c, k_valid)


def mmo_kspan_batched(a: Array, b: Array, c: Optional[Array] = None, *,
                      op: str, mesh: Mesh, axis: str = "model",
                      backend: str = "xla", block: Optional[tuple] = None,
                      interpret: Optional[bool] = None,
                      k_valid: Optional[Array] = None) -> Array:
  """Batched K-sharded contraction + ⊕-all-reduce along ``axis``.

  A: (R, M, K) and B: (R, K, N) sharded on K; C/D and ``k_valid`` replicated.
  """
  sr = sr_mod.get(op)
  k_chunk = a.shape[-1] // mesh.shape[axis]

  def kernel(a_blk, b_blk, c_blk, kv):
    part = _mmo(a_blk, b_blk, None, op=sr.name, backend=backend,
                block=block or None, interpret=interpret,
                k_valid=_local_kv(kv, axis, k_chunk))
    full = sr_mod.oplus_allreduce(sr, part, axis)
    if c_blk is not None:
      full = sr.oplus(full, c_blk.astype(full.dtype))
    return full

  in_specs = (P(None, None, axis), P(None, axis, None),
              None if c is None else P(None, None, None),
              None if k_valid is None else P(None))
  fn = _shard_map(kernel, mesh=mesh, in_specs=in_specs,
                  out_specs=P(None, None, None),
                  check_rep=k_valid is None)
  return fn(a, b, c, k_valid)


def summa_mmo_batched(a: Array, b: Array, c: Optional[Array] = None, *,
                      op: str, mesh: Mesh, row_axis: str = "data",
                      col_axis: str = "model", backend: str = "xla",
                      block: Optional[tuple] = None,
                      interpret: Optional[bool] = None,
                      k_valid: Optional[Array] = None) -> Array:
  """Batched 2-D SUMMA: operands/result 2-D block-sharded per request.

  Each device all-gathers its K-panels and contracts a (M/p, K)×(K, N/q)
  block per request; K is whole after the gathers, so ``k_valid`` applies
  unrebased.
  """
  sr = sr_mod.get(op)

  def kernel(a_blk, b_blk, c_blk, kv):
    a_row = jax.lax.all_gather(a_blk, col_axis, axis=2, tiled=True)
    b_col = jax.lax.all_gather(b_blk, row_axis, axis=1, tiled=True)
    out = _mmo(a_row, b_col, None, op=sr.name, backend=backend,
               block=block or None, interpret=interpret, k_valid=kv)
    if c_blk is not None:
      out = sr.oplus(out, c_blk.astype(out.dtype))
    return out

  spec = P(None, row_axis, col_axis)
  fn = _shard_map(kernel, mesh=mesh,
                  in_specs=(spec, spec, None if c is None else spec,
                            None if k_valid is None else P(None)),
                  out_specs=spec, check_rep=k_valid is None)
  return fn(a, b, c, k_valid)


def ring_mmo_batched(a: Array, b: Array, c: Optional[Array] = None, *,
                     op: str, mesh: Mesh, axis: str = "model",
                     backend: str = "xla", block: Optional[tuple] = None,
                     interpret: Optional[bool] = None,
                     k_valid: Optional[Array] = None) -> Array:
  """Batched 1-D ring: B K-sharded and rotating, device j owns output
  columns D[:, :, Nj]; each step's contraction overlaps the next permute."""
  sr = sr_mod.get(op)
  n_dev = mesh.shape[axis]

  def kernel(a_blk, b_blk, c_blk, kv):
    # a_blk: (R, M, K) replicated; b_blk: (R, K/p, N) rotating K-chunk.
    idx = jax.lax.axis_index(axis)
    perm = [(i, (i + 1) % n_dev) for i in range(n_dev)]
    k_chunk = b_blk.shape[1]
    n_cols = b_blk.shape[2] // n_dev

    def step(i, state):
      b_cur, acc = state
      src = (idx - i) % n_dev  # chunk origin after i forward rotations
      a_piece = jax.lax.dynamic_slice_in_dim(a_blk, src * k_chunk, k_chunk, 2)
      b_cols = jax.lax.dynamic_slice_in_dim(b_cur, idx * n_cols, n_cols, 2)
      kv_step = None if kv is None else jnp.clip(kv - src * k_chunk, 0,
                                                 k_chunk)
      part = _mmo(a_piece, b_cols, None, op=sr.name, backend=backend,
                  block=block or None, interpret=interpret, k_valid=kv_step)
      acc = sr.oplus(acc, part.astype(acc.dtype))
      b_nxt = jax.lax.ppermute(b_cur, axis, perm)
      return b_nxt, acc

    r, m = a_blk.shape[0], a_blk.shape[1]
    acc0 = sr.identity_like((r, m, n_cols), sr.acc_dtype(a_blk.dtype))
    acc0 = pvary(acc0, (axis,))
    _, acc = jax.lax.fori_loop(0, n_dev, step, (b_blk, acc0))
    if c_blk is not None:
      acc = sr.oplus(acc, c_blk.astype(acc.dtype))
    return acc

  in_specs = (P(None, None, None), P(None, axis, None),
              None if c is None else P(None, None, axis),
              None if k_valid is None else P(None))
  fn = _shard_map(kernel, mesh=mesh, in_specs=in_specs,
                  out_specs=P(None, None, axis),
                  check_rep=k_valid is None)
  return fn(a, b, c, k_valid)


def mmo_sharded_batched(a: Array, b: Array, c: Optional[Array] = None, *,
                        op: str, schedule: str, mesh: Mesh,
                        backend: str = "xla", block: Optional[tuple] = None,
                        interpret: Optional[bool] = None,
                        k_valid: Optional[Array] = None) -> Array:
  """One batched mesh schedule by name — the engine's sharded entry point.

  Axis convention: the mesh's first axis is the SUMMA row axis, its last the
  SUMMA column / K-span / ring axis (a (1, p) mesh therefore runs kspan and
  ring over all p devices and SUMMA as a 1×p column split).
  """
  row_axis, col_axis = mesh.axis_names[0], mesh.axis_names[-1]
  if schedule == "dp":
    return mmo_dp_batched(a, b, c, op=op, mesh=mesh, backend=backend,
                          block=block, interpret=interpret, k_valid=k_valid)
  if schedule == "kspan":
    return mmo_kspan_batched(a, b, c, op=op, mesh=mesh, axis=col_axis,
                             backend=backend, block=block,
                             interpret=interpret, k_valid=k_valid)
  if schedule == "summa":
    return summa_mmo_batched(a, b, c, op=op, mesh=mesh, row_axis=row_axis,
                             col_axis=col_axis, backend=backend, block=block,
                             interpret=interpret, k_valid=k_valid)
  if schedule == "ring":
    return ring_mmo_batched(a, b, c, op=op, mesh=mesh, axis=col_axis,
                            backend=backend, block=block,
                            interpret=interpret, k_valid=k_valid)
  raise ValueError(f"unknown schedule {schedule!r}; pick from {SCHEDULES}")


def schedule_fits(schedule: str, m: int, k: int, n: int, mesh: Mesh) -> bool:
  """Whether a contraction's problem axes divide evenly onto the mesh for
  one schedule (shard_map requires exact partitions; bucket dims are powers
  of two, so any pow2 mesh axis ≤ the dim fits)."""
  rows, cols = mesh.shape[mesh.axis_names[0]], mesh.shape[mesh.axis_names[-1]]
  if schedule == "dp":
    return True  # no problem-axis constraint; the request axis is checked
    # at batch-build time (the engine falls back to 'local' when the padded
    # batch doesn't divide over the mesh)
  if schedule == "kspan":
    return k % cols == 0
  if schedule == "summa":
    # K is sharded over cols on A and over rows on B before the all-gathers
    return (m % rows == 0 and n % cols == 0
            and k % rows == 0 and k % cols == 0)
  if schedule == "ring":
    return k % cols == 0 and n % cols == 0
  return False


def sharded_closure_batched(adj: Array, *, op: str,
                            algorithm: str = "leyzorek",
                            mesh: Mesh, schedule: str = "summa",
                            backend: str = "xla",
                            block: Optional[tuple] = None,
                            interpret: Optional[bool] = None,
                            max_iters: Optional[int] = None,
                            valid_n: Optional[Array] = None):
  """Batched semiring fixpoint with the mesh schedule threaded through.

  For the contraction schedules (kspan/summa/ring) this reuses the batched
  closure machinery (per-request convergence masks, converged requests
  dropping to ``k_valid=0``) with the mmo step swapped for a mesh schedule.
  SUMMA is the natural choice — C stays 2-D-sharded in place between
  iterations — but any schedule name works (GSPMD reshards between steps
  for the others).

  ``"dp"`` instead shards the *request* axis and runs one independent
  fixpoint per device: each shard's ``while`` loop exits as soon as its own
  requests converge, so a straggler (a high-diameter graph that needs the
  full lg(n) squarings) no longer drags every other request through its
  extra iterations — the schedule that wins whenever a bucket batch mixes
  convergence speeds.  Returns (closure, per-request iterations).
  """
  if schedule == "dp":
    if adj.shape[0] % mesh.size:
      raise ValueError(f"dp needs the request axis ({adj.shape[0]}) "
                       f"divisible by the mesh's {mesh.size} devices")
    fn = _dp_closure_fn(op, algorithm, backend, block, interpret,
                        max_iters, valid_n is not None, mesh)
    return fn(adj, valid_n)

  solver = _closure_solver(algorithm)
  return solver(adj, op=op, backend=backend,
                mmo_fn=_sched_mmo_fn(schedule, mesh, backend, block,
                                     interpret),
                max_iters=max_iters, valid_n=valid_n)


def _closure_solver(algorithm: str):
  from repro.core import closure as cl_mod  # local import: no cycle at load
  return (cl_mod.batched_leyzorek_closure if algorithm == "leyzorek"
          else cl_mod.batched_bellman_ford_closure)


@functools.lru_cache(maxsize=None)
def _sched_mmo_fn(schedule: str, mesh: Mesh, backend: str,
                  block: Optional[tuple] = None,
                  interpret: Optional[bool] = None):
  """One mmo_fn per (schedule, mesh, backend) — the solvers jit with
  ``mmo_fn`` as a static argument (hashed by identity), so handing them a
  fresh closure per call would retrace the whole fixpoint every time."""

  def mmo_fn(a, b, c, op_, bk, k_valid=None):
    del bk  # same value as the memoized ``backend`` (the solver echoes it)
    return mmo_sharded_batched(a, b, c, op=op_, schedule=schedule, mesh=mesh,
                               backend=backend, block=block,
                               interpret=interpret, k_valid=k_valid)

  return mmo_fn


@functools.lru_cache(maxsize=None)
def _local_mmo_fn(block: Optional[tuple], interpret: Optional[bool]):
  """Shard-local mmo step honoring a tuned block config / interpret flag;
  None (default settings) lets the solver use its own default step."""
  if not block and interpret is None:
    return None

  def mmo_fn(a, b, c, op_, bk, k_valid=None):
    return _mmo(a, b, c, op=op_, backend=bk, block=block or None,
                interpret=interpret, k_valid=k_valid)

  return mmo_fn


@functools.lru_cache(maxsize=None)
def _dp_closure_fn(op: str, algorithm: str, backend: str,
                   block: Optional[tuple], interpret: Optional[bool],
                   max_iters: Optional[int], has_valid: bool, mesh: Mesh):
  """Memoized jitted dp fixpoint (stable identity → stable jit cache)."""
  solver = _closure_solver(algorithm)
  axes = _dp_axes(mesh)

  def kernel(adj_blk, vn_blk):
    return solver(adj_blk, op=op, backend=backend, mmo_fn=_local_mmo_fn(
        block, interpret), max_iters=max_iters, valid_n=vn_blk)

  return jax.jit(_shard_map(
      kernel, mesh=mesh,
      in_specs=(P(axes, None, None), P(axes) if has_valid else None),
      out_specs=(P(axes, None, None), P(axes)),
      check_rep=False))  # per-shard fixpoint lowers to `while`


# ---------------------------------------------------------------------------
# Distributed closure (Leyzorek on a 2-D-sharded matrix via SUMMA squaring).
# ---------------------------------------------------------------------------


def distributed_leyzorek(adj: Array, *, op: str, mesh: Mesh,
                         row_axis: str = "data", col_axis: str = "model",
                         max_iters: Optional[int] = None,
                         backend: str = "auto"):
  """C ← C ⊕ (C ⊗ C) with C living 2-D-sharded across the mesh the whole
  time; only K-panels move (SUMMA all-gathers) per iteration."""
  import math
  n = adj.shape[-1]
  iters = max_iters if max_iters is not None else max(
      1, math.ceil(math.log2(max(n, 2))))

  @functools.partial(jax.jit, donate_argnums=0)
  def run(c):
    def body(_, cur):
      return summa_mmo(cur, cur, cur, op=op, mesh=mesh, row_axis=row_axis,
                       col_axis=col_axis, backend=backend)
    return jax.lax.fori_loop(0, iters, body, c)

  spec = jax.sharding.NamedSharding(mesh, P(row_axis, col_axis))
  adj = jax.device_put(adj, spec)
  return run(adj)

"""Distributed SIMD² — semiring matmuls and closures on a device mesh.

The paper scales SIMD² across SMs inside one GPU; at pod scale the analogous
question is how the ⊕/⊗ contraction maps onto collectives.  Because every
SIMD² ⊕ is one of {+, min, max, or}, **the cross-device reduction is always
expressible as psum/pmin/pmax** — a "generalized matmul" needs only a
generalized all-reduce.  Three schedules are provided:

  * ``mmo_kspan``      — K-sharded: local partial contraction then a single
                         ⊕-all-reduce.  Minimum collective volume when K is
                         the big axis (one M×N reduce).
  * ``summa_mmo``      — 2-D blocked SUMMA: A row-panels all-gathered along
                         the model axis, B col-panels along the data axis,
                         local contraction on (M/p, K)×(K, N/q) blocks.
                         This is the workhorse for distributed closures where
                         the *same* matrix is squared (Leyzorek), since C
                         stays 2-D-sharded in place across iterations.
  * ``ring_mmo``       — SUMMA with the all-gather replaced by K-step
                         collective_permute so each chunk's contraction
                         overlaps the transfer of the next (compute/comm
                         overlap; the beyond-paper schedule measured in
                         EXPERIMENTS.md §Perf).

All three return bit-identical results (tests assert so on a host-device
mesh) and accept every registered op.
"""
from __future__ import annotations

import functools
from typing import Optional

import jax
import jax.numpy as jnp
from jax.sharding import Mesh, PartitionSpec as P

from repro.core.mmo import mmo as _mmo
from repro.core import semiring as sr_mod

if hasattr(jax, "shard_map"):
  shard_map = jax.shard_map
else:  # pragma: no cover — older jax keeps it under experimental
  from jax.experimental.shard_map import shard_map

# jax.lax.pvary only exists on newer jax (varying-axis annotations for
# shard_map rep-checking); older versions don't need the annotation.
pvary = getattr(jax.lax, "pvary", lambda x, axes: x)

Array = jax.Array


def _local_contract(a, b, sr_name, backend):
  return _mmo(a, b, None, op=sr_name, backend=backend)


def mmo_kspan(a: Array, b: Array, c: Optional[Array], *, op: str, mesh: Mesh,
              axis: str = "model", backend: str = "auto") -> Array:
  """K-sharded contraction + ⊕-all-reduce along ``axis``.

  A: (M, K) sharded on K over ``axis``; B: (K, N) sharded on K; C/D
  replicated along ``axis``.
  """
  sr = sr_mod.get(op)

  def kernel(a_blk, b_blk, c_blk):
    part = _local_contract(a_blk, b_blk, sr.name, backend)
    full = sr_mod.oplus_allreduce(sr, part, axis)
    if c_blk is not None:
      full = sr.oplus(full, c_blk.astype(full.dtype))
    return full

  in_specs = (P(None, axis), P(axis, None),
              None if c is None else P(None, None))
  fn = shard_map(kernel, mesh=mesh, in_specs=in_specs,
                 out_specs=P(None, None))
  return fn(a, b, c)


def summa_mmo(a: Array, b: Array, c: Optional[Array], *, op: str, mesh: Mesh,
              row_axis: str = "data", col_axis: str = "model",
              backend: str = "auto") -> Array:
  """2-D SUMMA: operands and result all 2-D block-sharded (row_axis, col_axis).

  Per device: all-gather A's K-panels along ``col_axis`` (row broadcast) and
  B's K-panels along ``row_axis`` (column broadcast), contract locally.
  """
  sr = sr_mod.get(op)

  def kernel(a_blk, b_blk, c_blk):
    a_row = jax.lax.all_gather(a_blk, col_axis, axis=1, tiled=True)
    b_col = jax.lax.all_gather(b_blk, row_axis, axis=0, tiled=True)
    out = _local_contract(a_row, b_col, sr.name, backend)
    if c_blk is not None:
      out = sr.oplus(out, c_blk.astype(out.dtype))
    return out

  spec = P(row_axis, col_axis)
  fn = shard_map(kernel, mesh=mesh,
                 in_specs=(spec, spec, None if c is None else spec),
                 out_specs=spec)
  return fn(a, b, c)


def ring_mmo(a: Array, b: Array, c: Optional[Array], *, op: str, mesh: Mesh,
             axis: str = "model", backend: str = "auto") -> Array:
  """1-D ring schedule: B K-sharded along ``axis`` and rotating; device j owns
  output columns C[:, Nj] and ⊕-accumulates one K-chunk's contribution per
  step.  Each step's contraction overlaps the next chunk's collective-permute
  (the overlapped alternative to SUMMA's blocking all-gather; compared in
  EXPERIMENTS.md §Perf)."""
  sr = sr_mod.get(op)
  n_dev = mesh.shape[axis]

  def kernel(a_blk, b_blk, c_blk):
    # a_blk: (M, K) replicated; b_blk: (K/p, N) rotating K-chunk.
    idx = jax.lax.axis_index(axis)
    perm = [(i, (i + 1) % n_dev) for i in range(n_dev)]
    k_chunk = b_blk.shape[0]
    n_cols = b_blk.shape[1] // n_dev  # my output column block

    def step(i, state):
      b_cur, acc = state
      # after i forward rotations the chunk held here originated at device
      # (idx - i) mod p → it holds K rows [src*k_chunk, ...).
      src = (idx - i) % n_dev
      a_piece = jax.lax.dynamic_slice_in_dim(a_blk, src * k_chunk, k_chunk, 1)
      b_cols = jax.lax.dynamic_slice_in_dim(b_cur, idx * n_cols, n_cols, 1)
      part = _local_contract(a_piece, b_cols, sr.name, backend)
      acc = sr.oplus(acc, part.astype(acc.dtype))
      b_nxt = jax.lax.ppermute(b_cur, axis, perm)
      return b_nxt, acc

    m = a_blk.shape[0]
    acc0 = sr.identity_like((m, n_cols), sr.acc_dtype(a_blk.dtype))
    acc0 = pvary(acc0, (axis,))
    _, acc = jax.lax.fori_loop(0, n_dev, step, (b_blk, acc0))
    if c_blk is not None:
      acc = sr.oplus(acc, c_blk.astype(acc.dtype))
    return acc

  in_specs = (P(None, None), P(axis, None),
              None if c is None else P(None, axis))
  fn = shard_map(kernel, mesh=mesh, in_specs=in_specs,
                 out_specs=P(None, axis))
  return fn(a, b, c)


# ---------------------------------------------------------------------------
# Distributed closure (Leyzorek on a 2-D-sharded matrix via SUMMA squaring).
# ---------------------------------------------------------------------------


def distributed_leyzorek(adj: Array, *, op: str, mesh: Mesh,
                         row_axis: str = "data", col_axis: str = "model",
                         max_iters: Optional[int] = None,
                         backend: str = "auto"):
  """C ← C ⊕ (C ⊗ C) with C living 2-D-sharded across the mesh the whole
  time; only K-panels move (SUMMA all-gathers) per iteration."""
  import math
  n = adj.shape[-1]
  iters = max_iters if max_iters is not None else max(
      1, math.ceil(math.log2(max(n, 2))))

  @functools.partial(jax.jit, donate_argnums=0)
  def run(c):
    def body(_, cur):
      return summa_mmo(cur, cur, cur, op=op, mesh=mesh, row_axis=row_axis,
                       col_axis=col_axis, backend=backend)
    return jax.lax.fori_loop(0, iters, body, c)

  spec = jax.sharding.NamedSharding(mesh, P(row_axis, col_axis))
  adj = jax.device_put(adj, spec)
  return run(adj)

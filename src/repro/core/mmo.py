"""``mmo`` — the SIMD² matrix-matrix-operation API (paper §3.2/§4).

``D = C ⊕ (A ⊗ B)`` with A: (..., M, K), B: (..., K, N), C/D: (..., M, N).

Backends (selected via ``backend=``):

  'vector'  — blocked broadcast-⊗ + ⊕-reduce.  This is the TPU analogue of
              the paper's "SIMD² w/ CUDA cores" arm: correct on any platform,
              no MXU, O(M·bk·N) live intermediate per K-block.
  'xla'     — MXU-reuse rewrites where an exact one exists (mma → jnp.matmul,
              addnorm → ‖a‖²+‖b‖²−2ab expansion, orand → count>0), otherwise
              falls back to 'vector'.  This is the production path on CPU and
              the non-Pallas path on TPU.
  'pallas'  — the generic Pallas semiring kernel (kernels/semiring_mmo.py),
              the TPU-native embodiment of a SIMD² unit.  ``interpret=True``
              on CPU.
  'auto'    — consult the measured cost table (repro.tuning) for the cheapest
              (backend, block config) at this call's bucket signature; 'xla'
              when no table is loaded or it has no entry — the dispatcher
              that a compiler targeting SIMD² hardware would implement.

All backends produce identical results (tests sweep ops × shapes × dtypes).

Ragged contraction: ``k_valid`` (an int32 scalar, or one per leading request
for batched operands) declares how many leading K lanes are live.  The caller
guarantees K lanes at or beyond ``k_valid`` are algebraic no-ops (contraction
pads, or a closure's isolated-vertex padding), so backends are free to *skip*
them: the Pallas kernel masks dead K-blocks per request, the vector path
contracts a dynamic number of K-blocks bounded by ``max(k_valid)``, and the
MXU rewrites ignore the hint (full padded K on the MXU is already cheap).
"""
from __future__ import annotations

import functools
from typing import Optional

import jax
import jax.numpy as jnp

from repro.core import semiring as sr_mod

Array = jax.Array

_DEFAULT_BLOCK_K = 512
# Aim for at least this many dynamic K-blocks when a k_valid hint is present,
# so skipping dead blocks has useful granularity.
_DYN_K_BLOCKS = 8


def _check_shapes(a, b, c):
  if a.ndim < 2 or b.ndim < 2:
    raise ValueError(f"mmo operands must be >=2D, got {a.shape} {b.shape}")
  if a.shape[-1] != b.shape[-2]:
    raise ValueError(f"contraction mismatch: {a.shape} @ {b.shape}")
  m, n = a.shape[-2], b.shape[-1]
  if c is not None and c.shape[-2:] != (m, n):
    raise ValueError(f"C shape {c.shape} != ({m},{n})")


# ---------------------------------------------------------------------------
# vector backend: blocked broadcast/reduce.
# ---------------------------------------------------------------------------


def _contract_vector(a: Array, b: Array, sr: sr_mod.Semiring,
                     block_k: int) -> Array:
  """⊕_k ⊗(a[..,m,k], b[..,k,n]) by scanning K blocks."""
  *batch, m, k = a.shape
  n = b.shape[-1]
  acc_dtype = sr.acc_dtype(a.dtype)
  block_k = min(block_k, k)
  nblocks, rem = divmod(k, block_k)

  def blk(a_blk, b_blk):
    # (..., m, bk, 1) ⊗ (..., 1, bk, n) → ⊕ over bk
    prod = sr.otimes(a_blk[..., :, :, None].astype(acc_dtype),
                     b_blk[..., None, :, :].astype(acc_dtype))
    return sr_mod.oplus_reduce(sr, prod, axis=-2)

  # Initialize from the first block (not the ⊕-identity) so the accumulator
  # inherits the operands' types — incl. shard_map varying-axis annotations.
  a_main = a[..., : nblocks * block_k].reshape(*batch, m, nblocks, block_k)
  b_main = b[..., : nblocks * block_k, :].reshape(*batch, nblocks, block_k, n)
  out = blk(a_main[..., :, 0, :], b_main[..., 0, :, :])

  if nblocks > 1:
    def body(i, acc):
      part = blk(a_main[..., :, i, :], b_main[..., i, :, :])
      return sr.oplus(acc, part)

    out = jax.lax.fori_loop(1, nblocks, body, out)
  if rem:
    out = sr.oplus(out, blk(a[..., nblocks * block_k:],
                            b[..., nblocks * block_k:, :]))
  return out


def _dyn_block_k(k: int, block_k: int) -> int:
  """K-block size for the ragged path: shrink toward ~_DYN_K_BLOCKS blocks so
  the dynamic trip count has granularity to skip dead work."""
  bk = min(block_k, k)
  while bk > 8 and k / bk < _DYN_K_BLOCKS:
    bk = (bk + 1) // 2
  return max(bk, 1)


def _contract_vector_dynk(a: Array, b: Array, sr: sr_mod.Semiring,
                          block_k: int, k_valid: Array) -> Array:
  """Ragged vector contraction: only ``ceil(max(k_valid)/bk)`` K-blocks run.

  Batch-max semantics — requests with a smaller ``k_valid`` still see lanes
  up to the batch max, which the k_valid contract guarantees are ⊕-identity
  no-ops, so results match the full contraction exactly while the work
  tracks the *largest live* request instead of the padded K.
  """
  *batch, m, k = a.shape
  n = b.shape[-1]
  acc_dtype = sr.acc_dtype(a.dtype)
  bk = _dyn_block_k(k, block_k)
  kp = ((k + bk - 1) // bk) * bk
  if kp != k:  # pad the K tail so every dynamic block is full-width
    pa, pb = sr_mod.contraction_pads(sr)
    if sr.boolean:
      pa = pb = False
    a = jnp.pad(a, [(0, 0)] * len(batch) + [(0, 0), (0, kp - k)],
                constant_values=pa)
    b = jnp.pad(b, [(0, 0)] * len(batch) + [(0, kp - k), (0, 0)],
                constant_values=pb)
  nblocks = kp // bk
  live = jnp.clip((jnp.max(k_valid) + bk - 1) // bk, 1, nblocks)

  def blk(i):
    a_blk = jax.lax.dynamic_slice_in_dim(a, i * bk, bk, axis=-1)
    b_blk = jax.lax.dynamic_slice_in_dim(b, i * bk, bk, axis=-2)
    prod = sr.otimes(a_blk[..., :, :, None].astype(acc_dtype),
                     b_blk[..., None, :, :].astype(acc_dtype))
    return sr_mod.oplus_reduce(sr, prod, axis=-2)

  out = blk(0)
  if nblocks > 1:
    out = jax.lax.fori_loop(1, live, lambda i, acc: sr.oplus(acc, blk(i)),
                            out)
  return out


# ---------------------------------------------------------------------------
# MXU-reuse rewrites (exact; see DESIGN.md §2).
# ---------------------------------------------------------------------------


def _contract_matmul(a: Array, b: Array, sr: sr_mod.Semiring) -> Array:
  del sr
  return jnp.matmul(a, b, preferred_element_type=jnp.float32)


def _contract_addnorm(a: Array, b: Array, sr: sr_mod.Semiring) -> Array:
  """Σ_k (a−b)² = Σa² − 2Σab + Σb² — the O(K·M·N) term rides the MXU."""
  del sr
  ab = jnp.matmul(a, b, preferred_element_type=jnp.float32)
  a2 = jnp.sum(jnp.square(a.astype(jnp.float32)), axis=-1, keepdims=True)
  b2 = jnp.sum(jnp.square(b.astype(jnp.float32)), axis=-2, keepdims=True)
  return a2 - 2.0 * ab + b2


def _contract_orand(a: Array, b: Array, sr: sr_mod.Semiring) -> Array:
  """or-and over {0,1} == (#k: a∧b) > 0 — a thresholded MXU matmul."""
  del sr
  af = a.astype(jnp.bfloat16) if a.dtype == jnp.bool_ else (a != 0).astype(
      jnp.bfloat16)
  bf = b.astype(jnp.bfloat16) if b.dtype == jnp.bool_ else (b != 0).astype(
      jnp.bfloat16)
  cnt = jnp.matmul(af, bf, preferred_element_type=jnp.float32)
  return cnt > 0.5

_REWRITES = {
    "matmul": _contract_matmul,
    "addnorm": _contract_addnorm,
    "orand": _contract_orand,
}


# ---------------------------------------------------------------------------
# public API
# ---------------------------------------------------------------------------


def _resolve_auto(op: str, a, b) -> tuple:
  """backend='auto' → (backend, cfg) from the active cost table (trace-time
  host work; shapes/dtypes are static under tracing)."""
  from repro.tuning import dispatch as _dispatch  # lazy: tuning is optional
  d = _dispatch.resolve(op, a.shape[-2], a.shape[-1], b.shape[-1], a.dtype)
  return d.backend, d.cfg


@functools.partial(
    jax.jit,
    static_argnames=("op", "backend", "block_k", "bm", "bn", "bk",
                     "interpret"))
def _mmo_impl(a, b, c, k_valid, *, op, backend, block_k, bm, bn, bk,
              interpret):
  sr = sr_mod.get(op)
  if backend == "pallas":
    from repro.kernels import ops as kops  # local import: kernels optional
    out = kops.semiring_mmo(a, b, op=sr.name, bm=bm, bn=bn, bk=bk,
                            interpret=interpret,  # auto on CPU
                            k_valid=k_valid)
  elif backend == "xla" and sr.mxu_rewrite is not None:
    # full padded K on the MXU — the k_valid hint is not worth a branch here
    out = _REWRITES[sr.mxu_rewrite](a, b, sr)
  elif backend in ("xla", "vector"):
    if k_valid is None:
      out = _contract_vector(a, b, sr, block_k)
    else:
      out = _contract_vector_dynk(a, b, sr, block_k, k_valid)
  else:
    raise ValueError(f"unknown backend {backend!r}")

  if c is not None:
    out = sr.oplus(out, c.astype(out.dtype))
  return out


def mmo(a: Array,
        b: Array,
        c: Optional[Array] = None,
        *,
        op="mma",
        backend: str = "auto",
        block_k: int = _DEFAULT_BLOCK_K,
        block: Optional[tuple] = None,
        interpret: Optional[bool] = None,
        k_valid: Optional[Array] = None) -> Array:
  """D = C ⊕ (A ⊗ B).  See module docstring for backend semantics.

  ``block`` is the tuning-table block config: ``(bm, bn, bk)`` for the
  Pallas kernel, ``(block_k,)`` for the vector path, ``()`` for "use the
  defaults".  ``backend='auto'`` fills it from the cost table when the
  caller leaves it unset.
  """
  if backend == "megakernel":
    # a cost-table arm, but a whole-fixpoint one: it prices G fused closure
    # iterations per launch, so there is no single-contraction entry point
    raise ValueError(
        "backend 'megakernel' fuses whole closure fixpoints, not single "
        "contractions — select it via batched_leyzorek_closure / "
        "batched_bellman_ford_closure(fixpoint_backend='megakernel'), or "
        "let closure-bucket auto dispatch pick it (tuning.dispatch."
        "CLOSURE_BACKENDS)")
  sr = sr_mod.get(op)
  _check_shapes(a, b, c)
  if sr.boolean:
    a = a.astype(jnp.bool_) if a.dtype != jnp.bool_ else a
    b = b.astype(jnp.bool_) if b.dtype != jnp.bool_ else b

  if backend == "auto":
    backend, cfg = _resolve_auto(op, a, b)
    if block is None:
      block = cfg

  bm = bn = bk = 128
  if block:
    if backend == "pallas":
      if len(block) != 3:
        raise ValueError(f"pallas block config must be (bm, bn, bk), "
                         f"got {block!r}")
      bm, bn, bk = (int(x) for x in block)
    elif len(block) == 1:
      block_k = int(block[0])
    else:
      raise ValueError(f"block config must be (block_k,), got {block!r}")

  if k_valid is not None:
    k_valid = jnp.asarray(k_valid, jnp.int32)
  return _mmo_impl(a, b, c, k_valid, op=sr.name, backend=backend,
                   block_k=block_k, bm=bm, bn=bn, bk=bk, interpret=interpret)


def mmo_batched(a: Array,
                b: Array,
                c: Optional[Array] = None,
                *,
                op="mma",
                backend: str = "auto",
                block_k: int = _DEFAULT_BLOCK_K,
                block: Optional[tuple] = None,
                interpret: Optional[bool] = None,
                k_valid: Optional[Array] = None) -> Array:
  """D[r] = C[r] ⊕ (A[r] ⊗ B[r]) over a leading request axis.

  The serving engine's raw-mmo entry point: one compiled program per
  (bucket_shape, op, dtype, backend) executes a whole padded request batch.
  Every backend accepts the leading axis ('vector'/'xla' natively, 'pallas'
  via the batch vmap in kernels/ops.py); this wrapper pins the contract and
  validates that all operands agree on the request count.  ``k_valid``
  optionally carries one live-K count per request (see ``mmo``).
  """
  if a.ndim < 3 or b.ndim < 3:
    raise ValueError(f"mmo_batched needs (R, M, K)/(R, K, N), got "
                     f"{a.shape} {b.shape}")
  if c is not None and c.ndim < 3:
    raise ValueError(f"mmo_batched needs (R, M, N) for c, got {c.shape}")
  if a.shape[0] != b.shape[0] or (c is not None and c.shape[0] != a.shape[0]):
    shapes = f"a={a.shape} b={b.shape}" + (
        "" if c is None else f" c={c.shape}")
    raise ValueError(f"request-axis mismatch: {shapes}")
  return mmo(a, b, c, op=op, backend=backend, block_k=block_k, block=block,
             interpret=interpret, k_valid=k_valid)


def mmo_reference(a, b, c=None, *, op="mma"):
  """Unblocked O(MKN)-memory oracle (tests only)."""
  sr = sr_mod.get(op)
  acc = sr.acc_dtype(a.dtype)
  if sr.boolean:
    a, b = a.astype(jnp.bool_), b.astype(jnp.bool_)
    prod = sr.otimes(a[..., :, :, None], b[..., None, :, :])
  else:
    prod = sr.otimes(a[..., :, :, None].astype(acc),
                     b[..., None, :, :].astype(acc))
  out = sr_mod.oplus_reduce(sr, prod, axis=-2)
  if c is not None:
    out = sr.oplus(out, c.astype(out.dtype))
  return out

"""``mmo`` — the SIMD² matrix-matrix-operation API (paper §3.2/§4).

``D = C ⊕ (A ⊗ B)`` with A: (..., M, K), B: (..., K, N), C/D: (..., M, N).

Backends (selected via ``backend=``):

  'vector'  — blocked broadcast-⊗ + ⊕-reduce.  This is the TPU analogue of
              the paper's "SIMD² w/ CUDA cores" arm: correct on any platform,
              no MXU, O(M·bk·N) live intermediate per K-block.
  'xla'     — MXU-reuse rewrites where an exact one exists (mma → jnp.matmul,
              addnorm → ‖a‖²+‖b‖²−2ab expansion, orand → count>0), otherwise
              falls back to 'vector'.  This is the production path on CPU and
              the non-Pallas path on TPU.
  'pallas'  — the generic Pallas semiring kernel (kernels/semiring_mmo.py),
              the TPU-native embodiment of a SIMD² unit.  ``interpret=True``
              on CPU.
  'auto'    — 'xla' (the dispatcher that a compiler targeting SIMD² hardware
              would implement).

All backends produce identical results (tests sweep ops × shapes × dtypes).
"""
from __future__ import annotations

import functools
from typing import Optional

import jax
import jax.numpy as jnp

from repro.core import semiring as sr_mod

Array = jax.Array

_DEFAULT_BLOCK_K = 512


def _check_shapes(a, b, c):
  if a.ndim < 2 or b.ndim < 2:
    raise ValueError(f"mmo operands must be >=2D, got {a.shape} {b.shape}")
  if a.shape[-1] != b.shape[-2]:
    raise ValueError(f"contraction mismatch: {a.shape} @ {b.shape}")
  m, n = a.shape[-2], b.shape[-1]
  if c is not None and c.shape[-2:] != (m, n):
    raise ValueError(f"C shape {c.shape} != ({m},{n})")


# ---------------------------------------------------------------------------
# vector backend: blocked broadcast/reduce.
# ---------------------------------------------------------------------------


def _contract_vector(a: Array, b: Array, sr: sr_mod.Semiring,
                     block_k: int) -> Array:
  """⊕_k ⊗(a[..,m,k], b[..,k,n]) by scanning K blocks."""
  *batch, m, k = a.shape
  n = b.shape[-1]
  acc_dtype = sr.acc_dtype(a.dtype)
  block_k = min(block_k, k)
  nblocks, rem = divmod(k, block_k)

  def blk(a_blk, b_blk):
    # (..., m, bk, 1) ⊗ (..., 1, bk, n) → ⊕ over bk
    prod = sr.otimes(a_blk[..., :, :, None].astype(acc_dtype),
                     b_blk[..., None, :, :].astype(acc_dtype))
    return sr_mod.oplus_reduce(sr, prod, axis=-2)

  # Initialize from the first block (not the ⊕-identity) so the accumulator
  # inherits the operands' types — incl. shard_map varying-axis annotations.
  a_main = a[..., : nblocks * block_k].reshape(*batch, m, nblocks, block_k)
  b_main = b[..., : nblocks * block_k, :].reshape(*batch, nblocks, block_k, n)
  out = blk(a_main[..., :, 0, :], b_main[..., 0, :, :])

  if nblocks > 1:
    def body(i, acc):
      part = blk(a_main[..., :, i, :], b_main[..., i, :, :])
      return sr.oplus(acc, part)

    out = jax.lax.fori_loop(1, nblocks, body, out)
  if rem:
    out = sr.oplus(out, blk(a[..., nblocks * block_k:],
                            b[..., nblocks * block_k:, :]))
  return out


# ---------------------------------------------------------------------------
# MXU-reuse rewrites (exact; see DESIGN.md §2).
# ---------------------------------------------------------------------------


def _contract_matmul(a: Array, b: Array, sr: sr_mod.Semiring) -> Array:
  del sr
  return jnp.matmul(a, b, preferred_element_type=jnp.float32)


def _contract_addnorm(a: Array, b: Array, sr: sr_mod.Semiring) -> Array:
  """Σ_k (a−b)² = Σa² − 2Σab + Σb² — the O(K·M·N) term rides the MXU."""
  del sr
  ab = jnp.matmul(a, b, preferred_element_type=jnp.float32)
  a2 = jnp.sum(jnp.square(a.astype(jnp.float32)), axis=-1, keepdims=True)
  b2 = jnp.sum(jnp.square(b.astype(jnp.float32)), axis=-2, keepdims=True)
  return a2 - 2.0 * ab + b2


def _contract_orand(a: Array, b: Array, sr: sr_mod.Semiring) -> Array:
  """or-and over {0,1} == (#k: a∧b) > 0 — a thresholded MXU matmul."""
  del sr
  af = a.astype(jnp.bfloat16) if a.dtype == jnp.bool_ else (a != 0).astype(
      jnp.bfloat16)
  bf = b.astype(jnp.bfloat16) if b.dtype == jnp.bool_ else (b != 0).astype(
      jnp.bfloat16)
  cnt = jnp.matmul(af, bf, preferred_element_type=jnp.float32)
  return cnt > 0.5

_REWRITES = {
    "matmul": _contract_matmul,
    "addnorm": _contract_addnorm,
    "orand": _contract_orand,
}


# ---------------------------------------------------------------------------
# public API
# ---------------------------------------------------------------------------


@functools.partial(
    jax.jit, static_argnames=("op", "backend", "block_k", "interpret"))
def mmo(a: Array,
        b: Array,
        c: Optional[Array] = None,
        *,
        op="mma",
        backend: str = "auto",
        block_k: int = _DEFAULT_BLOCK_K,
        interpret: Optional[bool] = None) -> Array:
  """D = C ⊕ (A ⊗ B).  See module docstring for backend semantics."""
  sr = sr_mod.get(op)
  _check_shapes(a, b, c)
  if sr.boolean:
    a = a.astype(jnp.bool_) if a.dtype != jnp.bool_ else a
    b = b.astype(jnp.bool_) if b.dtype != jnp.bool_ else b

  if backend == "auto":
    backend = "xla"

  if backend == "pallas":
    from repro.kernels import ops as kops  # local import: kernels optional
    out = kops.semiring_mmo(a, b, op=sr.name, interpret=interpret)  # auto on CPU
  elif backend == "xla" and sr.mxu_rewrite is not None:
    out = _REWRITES[sr.mxu_rewrite](a, b, sr)
  elif backend in ("xla", "vector"):
    out = _contract_vector(a, b, sr, block_k)
  else:
    raise ValueError(f"unknown backend {backend!r}")

  if c is not None:
    out = sr.oplus(out, c.astype(out.dtype))
  return out


@functools.partial(
    jax.jit, static_argnames=("op", "backend", "block_k", "interpret"))
def mmo_batched(a: Array,
                b: Array,
                c: Optional[Array] = None,
                *,
                op="mma",
                backend: str = "auto",
                block_k: int = _DEFAULT_BLOCK_K,
                interpret: Optional[bool] = None) -> Array:
  """D[r] = C[r] ⊕ (A[r] ⊗ B[r]) over a leading request axis.

  The serving engine's raw-mmo entry point: one compiled program per
  (bucket_shape, op, dtype, backend) executes a whole padded request batch.
  Every backend accepts the leading axis ('vector'/'xla' natively, 'pallas'
  via the batch vmap in kernels/ops.py); this wrapper pins the contract and
  validates that all operands agree on the request count.
  """
  if a.ndim < 3 or b.ndim < 3:
    raise ValueError(f"mmo_batched needs (R, M, K)/(R, K, N), got "
                     f"{a.shape} {b.shape}")
  if a.shape[0] != b.shape[0] or (c is not None and c.shape[0] != a.shape[0]):
    raise ValueError(
        f"request-axis mismatch: {a.shape} {b.shape}"
        f"{'' if c is None else f' {c.shape}'}")
  return mmo(a, b, c, op=op, backend=backend, block_k=block_k,
             interpret=interpret)


def mmo_reference(a, b, c=None, *, op="mma"):
  """Unblocked O(MKN)-memory oracle (tests only)."""
  sr = sr_mod.get(op)
  acc = sr.acc_dtype(a.dtype)
  if sr.boolean:
    a, b = a.astype(jnp.bool_), b.astype(jnp.bool_)
    prod = sr.otimes(a[..., :, :, None], b[..., None, :, :])
  else:
    prod = sr.otimes(a[..., :, :, None].astype(acc),
                     b[..., None, :, :].astype(acc))
  out = sr_mod.oplus_reduce(sr, prod, axis=-2)
  if c is not None:
    out = sr.oplus(out, c.astype(out.dtype))
  return out

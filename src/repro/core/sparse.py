"""Sparse SIMD² (paper §6.5): 2:4 structured sparsity + CSR crossover study.

Two artifacts:
  * ``prune_24`` / ``mmo_sparse24`` — structured 2:4 sparsity along K: keep
    the 2 largest-|x| of every 4 A-entries, contract only those (exactly the
    sparse-Tensor-Core execution model; on hardware this doubles ⊗-throughput
    — the benchmark reports both the measured compacted-contraction time and
    the modeled 2× roofline).
  * ``csr_spgemm_np`` — a plain CSR×dense row-gather SpMM in numpy, the
    stand-in for cuSparse in the Fig-14 density-crossover study.
"""
from __future__ import annotations

import jax
import jax.numpy as jnp
import numpy as np

from repro.core import semiring as sr_mod

Array = jax.Array


def prune_24(a: Array):
  """Keep the 2 largest-magnitude entries of each group of 4 along K.

  Returns (compact (M, K/2) values, idx (M, K/2) int32 column indices)."""
  m, k = a.shape
  assert k % 4 == 0, k
  g = a.reshape(m, k // 4, 4)
  order = jnp.argsort(-jnp.abs(g), axis=-1)[..., :2]          # (M, K/4, 2)
  order = jnp.sort(order, axis=-1)                            # keep k-order
  vals = jnp.take_along_axis(g, order, axis=-1)               # (M, K/4, 2)
  base = (jnp.arange(k // 4) * 4)[None, :, None]
  idx = (order + base).reshape(m, k // 2)
  return vals.reshape(m, k // 2), idx.astype(jnp.int32)


def densify_24(vals: Array, idx: Array, k: int) -> Array:
  m = vals.shape[0]
  out = jnp.zeros((m, k), vals.dtype)
  return out.at[jnp.arange(m)[:, None], idx].set(vals)


def mmo_sparse24(vals: Array, idx: Array, b: Array, c=None, *,
                 op: str = "mma") -> Array:
  """Contract the 2:4-compacted A against dense B: per output row i the
  needed B rows are gathered by idx[i] — half the ⊗ work of the dense op."""
  sr = sr_mod.get(op)
  acc = sr.acc_dtype(vals.dtype)
  b_rows = b[idx]                                 # (M, K/2, N) gather
  prod = sr.otimes(vals[..., None].astype(acc), b_rows.astype(acc))
  out = sr_mod.oplus_reduce(sr, prod, axis=1)
  if c is not None:
    out = sr.oplus(out, c.astype(out.dtype))
  return out


# --- CSR SpGEMM reference (numpy; the "cuSparse arm" of Fig 14) -------------

# Per-ring "absent" entry value: a stored matrix drops entries equal to this,
# and the contraction seeds its accumulator so dropped entries contribute
# nothing.  Soundness requires absent to be a ⊗-annihilator mapping to the
# ⊕-identity — ⊗(absent, x) must equal the ⊕-identity for every x in the
# ring's domain — which ``validate_csr_seed`` re-verifies numerically
# (repro.analysis runs it over adversarial floats).  For the mul/max rings
# the annihilator property holds on the engine's positive-weight domain
# (0 is the no-edge sentinel there, matching core/closure.py).  addnorm has
# NO absent value — (absent−b)² cannot be 0 for all b — so sparse storage is
# undefined for it, exactly like closure padding.
_ABSENT = {
    "mma": 0.0,
    "minplus": float(np.inf),
    "maxplus": float(-np.inf),
    "minmul": float(np.inf),
    "maxmul": 0.0,
    "minmax": float(np.inf),
    "maxmin": 0.0,
    "orand": 0.0,       # False
    "addnorm": None,    # no ⊗-annihilator: sparsity undefined
}


def csr_absent_value(op: str) -> float:
  """The entry value ``to_csr`` drops for ``op`` (its ⊗-annihilator).

  Raises ValueError for rings with no annihilator (addnorm)."""
  sr = sr_mod.get(op)
  absent = _ABSENT[sr.name]
  if absent is None:
    raise ValueError(
        f"op {sr.name!r} has no ⊗-annihilator, so absent entries cannot "
        f"drop out of the contraction — CSR storage is undefined for it")
  return absent


def validate_csr_seed(op: str, *, samples=None) -> None:
  """Check numerically that dropping ``op``'s absent value is sound: for
  domain operands x, y the absorption law ⊕(⊗(absent, x), y) == y must hold
  (and never produce NaN) — an absent entry's product contributes nothing.

  Note this is checked on the ring's *operating domain* (positive weights
  for the mul/maxmin rings, where 0 is the no-edge sentinel — the same data
  contract core/closure.py documents), not over all floats: maxmul's
  absent 0 absorbs under max only because stored products are positive.
  Raises ValueError when the table entry is unsound — this is the
  semiring-registry cross-check the analyzer's law family leans on."""
  sr = sr_mod.get(op)
  absent = csr_absent_value(op)  # raises for addnorm
  if samples is None:
    samples = ([False, True] if sr.boolean else
               [0.25, 1.0, 2.0] if sr.name in ("minmul", "maxmul", "maxmin")
               else [-3.0, -1.0, 0.0, 0.5, 2.0])
  cast = (lambda v: jnp.bool_(v)) if sr.boolean else \
      (lambda v: jnp.float64(v))
  for x in samples:
    prod = sr.otimes(cast(absent), cast(x))
    if not sr.boolean and np.isnan(np.float64(np.asarray(prod))):
      raise ValueError(
          f"CSR absent value {absent!r} for op {op!r} poisons the "
          f"contraction: ⊗({absent!r}, {x!r}) is NaN")
    for y in samples:
      got = np.float64(np.asarray(sr.oplus(prod, cast(y))))
      want = np.float64(np.asarray(cast(y)))
      if np.isnan(got) or got != want:
        raise ValueError(
            f"CSR absent value {absent!r} for op {op!r} is not absorbed: "
            f"⊕(⊗({absent!r}, {x!r}), {y!r}) == {got!r}, want {y!r} — "
            f"dropped entries would change results")


def to_csr(a: np.ndarray, *, op: str = "mma"):
  """CSR-compress ``a``, dropping entries equal to the ring's absent value
  (validated against the semiring registry; op="mma" drops zeros, matching
  the historical behavior)."""
  validate_csr_seed(op)
  absent = csr_absent_value(op)
  m, _ = a.shape
  indptr = [0]
  indices, data = [], []
  for i in range(m):
    nz = np.nonzero(a[i] != absent)[0]
    indices.append(nz)
    data.append(a[i, nz])
    indptr.append(indptr[-1] + len(nz))
  return (np.asarray(indptr), np.concatenate(indices) if indices else
          np.zeros(0, np.int64), np.concatenate(data) if data else
          np.zeros(0, a.dtype))


def csr_spmm(indptr, indices, data, b: np.ndarray, *,
             op: str = "mma") -> np.ndarray:
  """Semiring CSR×dense SpMM, result identical to the dense contraction.

  Rows are seeded with the *absorbed product* ⊗(absent, absent) — what a
  dropped entry contributes in the dense op (constant over the ring's
  domain; +inf for minplus, "no path") — so rows with no stored entries
  match the dense result, and absorption (``validate_csr_seed``) guarantees
  the seed vanishes the moment a stored product lands."""
  validate_csr_seed(op)
  sr = sr_mod.get(op)
  absent = csr_absent_value(op)
  m = len(indptr) - 1
  if sr.boolean:
    empty = bool(np.asarray(sr.otimes(jnp.bool_(absent), jnp.bool_(absent))))
    out = np.full((m, b.shape[1]), empty)
    b = b.astype(bool)
    for i in range(m):
      lo, hi = indptr[i], indptr[i + 1]
      if hi > lo:
        prod = np.asarray(sr.otimes(jnp.asarray(data[lo:hi][:, None]),
                                    jnp.asarray(b[indices[lo:hi]])))
        out[i] = np.asarray(
            sr_mod.oplus_reduce(sr, jnp.asarray(prod), axis=0))
    return out
  empty = np.float64(np.asarray(
      sr.otimes(jnp.float64(absent), jnp.float64(absent))))
  out = np.full((m, b.shape[1]), empty, np.float64)
  for i in range(m):
    lo, hi = indptr[i], indptr[i + 1]
    if hi > lo:
      prod = np.asarray(sr.otimes(
          jnp.asarray(data[lo:hi][:, None].astype(np.float64)),
          jnp.asarray(b[indices[lo:hi]].astype(np.float64))))
      out[i] = np.asarray(
          sr_mod.oplus_reduce(sr, jnp.asarray(prod), axis=0))
  return out


def csr_spmm_np(indptr, indices, data, b: np.ndarray) -> np.ndarray:
  """The historical mma fast path (plain @-based row gather) used by the
  Fig-14 density-crossover benchmark."""
  m = len(indptr) - 1
  out = np.zeros((m, b.shape[1]), np.float64)
  for i in range(m):
    lo, hi = indptr[i], indptr[i + 1]
    if hi > lo:
      out[i] = data[lo:hi] @ b[indices[lo:hi]]
  return out

"""Sparse SIMD² (paper §6.5): 2:4 structured sparsity + CSR crossover study.

Two artifacts:
  * ``prune_24`` / ``mmo_sparse24`` — structured 2:4 sparsity along K: keep
    the 2 largest-|x| of every 4 A-entries, contract only those (exactly the
    sparse-Tensor-Core execution model; on hardware this doubles ⊗-throughput
    — the benchmark reports both the measured compacted-contraction time and
    the modeled 2× roofline).
  * ``csr_spgemm_np`` — a plain CSR×dense row-gather SpMM in numpy, the
    stand-in for cuSparse in the Fig-14 density-crossover study.
"""
from __future__ import annotations

import jax
import jax.numpy as jnp
import numpy as np

from repro.core import semiring as sr_mod

Array = jax.Array


def prune_24(a: Array):
  """Keep the 2 largest-magnitude entries of each group of 4 along K.

  Returns (compact (M, K/2) values, idx (M, K/2) int32 column indices)."""
  m, k = a.shape
  assert k % 4 == 0, k
  g = a.reshape(m, k // 4, 4)
  order = jnp.argsort(-jnp.abs(g), axis=-1)[..., :2]          # (M, K/4, 2)
  order = jnp.sort(order, axis=-1)                            # keep k-order
  vals = jnp.take_along_axis(g, order, axis=-1)               # (M, K/4, 2)
  base = (jnp.arange(k // 4) * 4)[None, :, None]
  idx = (order + base).reshape(m, k // 2)
  return vals.reshape(m, k // 2), idx.astype(jnp.int32)


def densify_24(vals: Array, idx: Array, k: int) -> Array:
  m = vals.shape[0]
  out = jnp.zeros((m, k), vals.dtype)
  return out.at[jnp.arange(m)[:, None], idx].set(vals)


def mmo_sparse24(vals: Array, idx: Array, b: Array, c=None, *,
                 op: str = "mma") -> Array:
  """Contract the 2:4-compacted A against dense B: per output row i the
  needed B rows are gathered by idx[i] — half the ⊗ work of the dense op."""
  sr = sr_mod.get(op)
  acc = sr.acc_dtype(vals.dtype)
  b_rows = b[idx]                                 # (M, K/2, N) gather
  prod = sr.otimes(vals[..., None].astype(acc), b_rows.astype(acc))
  out = sr_mod.oplus_reduce(sr, prod, axis=1)
  if c is not None:
    out = sr.oplus(out, c.astype(out.dtype))
  return out


# --- CSR SpGEMM reference (numpy; the "cuSparse arm" of Fig 14) -------------


def to_csr(a: np.ndarray):
  m, _ = a.shape
  indptr = [0]
  indices, data = [], []
  for i in range(m):
    nz = np.nonzero(a[i])[0]
    indices.append(nz)
    data.append(a[i, nz])
    indptr.append(indptr[-1] + len(nz))
  return (np.asarray(indptr), np.concatenate(indices) if indices else
          np.zeros(0, np.int64), np.concatenate(data) if data else
          np.zeros(0, a.dtype))


def csr_spmm_np(indptr, indices, data, b: np.ndarray) -> np.ndarray:
  m = len(indptr) - 1
  out = np.zeros((m, b.shape[1]), np.float64)
  for i in range(m):
    lo, hi = indptr[i], indptr[i + 1]
    if hi > lo:
      out[i] = data[lo:hi] @ b[indices[lo:hi]]
  return out

"""Analytical area/power model reproducing paper Table 5 (SIMULATED).

The paper synthesizes RTL (Synopsys DC, FreePDK45) — a hardware gate on this
host — so we model it analytically and transparently: a SIMD² unit composes
primitive circuits (fp multiplier, adder, comparator, and-or array, squarer,
operand/result muxing, per-unit control).  Composition is linear in the
primitive areas, and the primitives follow standard gate-count scaling laws
with bit width (array multiplier/squarer ∝ w², linear datapaths ∝ w), so we
**fit the primitive areas by least squares against the paper's published
Table 5 rows** and report model-vs-paper fidelity per row.  The model then
generalizes to arbitrary op subsets / widths / grid sizes.

This file is the §6.1 artifact; benchmarks/area_table.py prints the tables
side-by-side with the paper's numbers and asserts aggregate fidelity.
"""
from __future__ import annotations

import numpy as np

# primitive index: mul, add, cmp, logic, sqr(+sub), mux(per extra op), ctrl
_PRIMS = ("mul", "add", "cmp", "logic", "sqr", "mux", "ctrl")
_NP = len(_PRIMS)

# circuits needed per op beyond operand latches: (⊗ stage, ⊕ stage).
# mma = mul + add (the baseline PE).  Ops reuse the baseline's mul/add where
# the semantics allow; rows list *additional* circuits when added to an MMA
# PE, and *all* circuits when built dedicated.
# repro: ignore[semiring-table-coverage] — extra-over-baseline: no mma row
_EXTRA = {   # added to an MMA PE (mul+add exist)
    "minplus": {"add": 1, "cmp": 1},   # ⊗-position adder + ⊕ comparator
    "maxplus": {"add": 1, "cmp": 1},
    "minmul":  {"cmp": 1},             # ⊗ reuses the multiplier
    "maxmul":  {"cmp": 1},
    "minmax":  {"cmp": 2},             # both stages are comparators
    "maxmin":  {"cmp": 2},
    "orand":   {"logic": 2},
    "addnorm": {"sqr": 1},             # |a−b|² datapath (sub folded in)
}
# repro: ignore[semiring-table-coverage] — dedicated units exclude the PE
_DEDICATED = {  # standalone unit (no mma circuits to reuse)
    "minplus": {"add": 2, "cmp": 1, "ctrl": 1},
    "maxplus": {"add": 2, "cmp": 1, "ctrl": 1},
    "minmul":  {"mul": 1, "cmp": 1, "add": 1, "ctrl": 1},
    "maxmul":  {"mul": 1, "cmp": 1, "add": 1, "ctrl": 1},
    "minmax":  {"cmp": 2, "ctrl": 1},
    "maxmin":  {"cmp": 2, "ctrl": 1},
    "orand":   {"logic": 2, "ctrl": 1},
    "addnorm": {"sqr": 1, "add": 1, "ctrl": 1},
}
_MMA = {"mul": 1, "add": 1}

# mirrored ops (max* given min*) share their comparator datapath: each extra
# op in an already-covered circuit class costs one mux.
_CLASSES = (("minplus", "maxplus"), ("minmul", "maxmul"),
            ("minmax", "maxmin"), ("orand",), ("addnorm",), ("mma",))


def _scale(w):
  """Per-primitive width scaling (relative to 16-bit)."""
  s = w / 16.0
  return np.array([s * s, s, s, s, s * s, s, 1.0])  # mul,add,cmp,logic,sqr,mux,ctrl


def _vec(counts: dict, w: int = 16) -> np.ndarray:
  v = np.zeros(_NP)
  for k, n in counts.items():
    v[_PRIMS.index(k)] = n
  return v * _scale(w)


def _combined_vec(ops, w: int = 16) -> np.ndarray:
  """Shared SIMD² unit: per class take the max member cost once; each extra
  member costs a mux."""
  ops = set(ops)
  v = _vec(_MMA, w)  # baseline PE always present
  for cls in _CLASSES:
    members = [o for o in cls if o in ops and o != "mma"]
    if not members:
      continue
    v = v + _vec(_EXTRA[members[0]], w)
    v[_PRIMS.index("mux")] += (len(members) - 1) * _scale(w)[_PRIMS.index(
        "mux")]
  return v


# --- calibration against published Table 5 ---------------------------------
# repro: ignore[semiring-table-coverage] — paper Table 5 has no mma row
_PAPER_5A = {"minplus": 1.21, "maxplus": 1.21, "minmul": 1.12,
             "maxmul": 1.12, "minmax": 1.01, "maxmin": 1.01, "orand": 1.04,
             "addnorm": 1.18}
_PAPER_5A_ALL = 1.69
# repro: ignore[semiring-table-coverage] — paper Table 5 has no mma row
_PAPER_5B = {"minplus": 0.26, "maxplus": 0.26, "minmul": 1.03,
             "maxmul": 1.03, "minmax": 0.06, "maxmin": 0.06, "orand": 0.08,
             "addnorm": 0.19}
_PAPER_5C = {8: (0.25, 0.69), 16: (1.0, 1.69), 32: (4.04, 6.42),
             64: (11.17, 17.01)}


def _fit() -> np.ndarray:
  rows, targets = [], []
  base = _vec(_MMA)  # normalizer: area(base)=1 enforced as a hard-ish row
  rows.append(base * 10.0)
  targets.append(1.0 * 10.0)
  for op, t in _PAPER_5A.items():
    rows.append(_combined_vec(["mma", op]))
    targets.append(t)
  rows.append(_combined_vec(["mma", *_PAPER_5A]))
  targets.append(_PAPER_5A_ALL)
  for op, t in _PAPER_5B.items():
    rows.append(_vec(_DEDICATED[op]))
    targets.append(t)
  for w, (t_mma, t_all) in _PAPER_5C.items():
    rows.append(_vec(_MMA, w))
    targets.append(t_mma)
    rows.append(_combined_vec(["mma", *_PAPER_5A], w))
    targets.append(t_all)
  A = np.asarray(rows)
  b = np.asarray(targets)
  # relative-error weighting: every published number counts equally
  wgt = 1.0 / np.maximum(np.abs(b), 0.05)
  A = A * wgt[:, None]
  b = b * wgt
  x, *_ = np.linalg.lstsq(A, b, rcond=None)
  # non-negativity: clip and re-solve on the support
  for _ in range(4):
    neg = x < 0
    if not neg.any():
      break
    x[neg] = 0.0
    keep = ~neg
    xk, *_ = np.linalg.lstsq(A[:, keep], b, rcond=None)
    x[keep] = xk
  x = np.maximum(x, 0.0)
  # renormalize so the 16-bit MMA unit is exactly 1.0
  x = x / float(base @ x)
  return x


_COEF = _fit()


def unit_area(ops, width: int = 16) -> float:
  """Area of a shared SIMD² unit (relative; 16-bit MMA-only ≡ 1.0)."""
  return float(_combined_vec(set(ops) | {"mma"}, width) @ _COEF)


def dedicated_area(op: str, width: int = 16) -> float:
  return float(_vec(_DEDICATED[op], width) @ _COEF)


ALL_OPS = ("mma",) + tuple(_PAPER_5A)
MMA_AREA_MM2 = 11.52


def table5a() -> dict:
  out = {"MMA only": (1.0, 1.0)}
  for op in _PAPER_5A:
    out[f"MMA + {op}"] = (round(unit_area(["mma", op]), 3), _PAPER_5A[op])
  out["MMA + All"] = (round(unit_area(ALL_OPS), 3), _PAPER_5A_ALL)
  return out


def table5b() -> dict:
  out = {op: (round(dedicated_area(op), 3), _PAPER_5B[op])
         for op in _PAPER_5B}
  tot = sum(dedicated_area(op) for op in _PAPER_5B)
  out["Total"] = (round(tot, 3), 2.96)
  return out


def table5c() -> dict:
  out = {}
  for w, (t_mma, t_all) in _PAPER_5C.items():
    out[f"MMA {w}b"] = (round(unit_area(["mma"], w), 3), t_mma)
    out[f"SIMD2 {w}b"] = (round(unit_area(ALL_OPS, w), 3), t_all)
  return out


def grid_scaling(grid_dim: int = 8) -> float:
  """8×8 vs 4×4 unit (paper: MMA 8×8 ≈ 7.5× the 4×4; overhead fraction
  constant).  PE area scales with PE count; the reduction tree adds
  log-depth wiring (~ +17% at 8×8 per the paper's 7.5×/4× ratio)."""
  pes = (grid_dim / 4.0) ** 2
  wiring = 1.0 + 0.17 * np.log2(grid_dim / 4.0)
  return float(pes * wiring)


def fidelity() -> dict:
  """Mean |model − paper| / paper across every published number."""
  errs = []
  for tbl in (table5a(), table5b(), table5c()):
    for model, paper in tbl.values():
      if paper:
        errs.append(abs(model - paper) / paper)
  return {"mean_rel_err": float(np.mean(errs)),
          "max_rel_err": float(np.max(errs)), "n_targets": len(errs)}


# --- power -------------------------------------------------------------------
_POWER_MMA_W = 3.74
_PAPER_EXTRA_W = 0.79


def power_w(ops) -> float:
  """Active power: switching ∝ area with lower activity on cmp/logic paths."""
  extra = unit_area(ops) - 1.0
  # calibrated single activity factor against the paper's +0.79 W
  act = _PAPER_EXTRA_W / (unit_area(ALL_OPS) - 1.0) / _POWER_MMA_W
  return _POWER_MMA_W * (1.0 + act * extra * _POWER_MMA_W) if False else \
      _POWER_MMA_W + _POWER_MMA_W * act * extra


# --- full-chip scaling (paper §6.1 method) -----------------------------------
SM_AREA_MM2 = 3.75
SM_DIE_FRACTION = 0.502
UNIT_OVERHEAD_MM2_8N = 0.378  # paper's 45nm→8N scaled overhead


def chip_overhead_fraction() -> float:
  per_sm = UNIT_OVERHEAD_MM2_8N / SM_AREA_MM2
  return per_sm * SM_DIE_FRACTION

"""SIMD² core: semiring registry, mmo API, closure solvers, distribution."""
from repro.core.semiring import ALL_OPS, Semiring, get as get_semiring
from repro.core.mmo import mmo, mmo_reference
from repro.core.closure import (
    bellman_ford_closure,
    floyd_warshall,
    leyzorek_closure,
    prepare_adjacency,
)

__all__ = [
    "ALL_OPS",
    "Semiring",
    "get_semiring",
    "mmo",
    "mmo_reference",
    "leyzorek_closure",
    "bellman_ford_closure",
    "floyd_warshall",
    "prepare_adjacency",
]

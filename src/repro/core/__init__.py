"""SIMD² core: semiring registry, mmo API, closure solvers, distribution."""
from repro.core.semiring import (ALL_OPS, Semiring, contraction_pads,
                                 get as get_semiring)
from repro.core.mmo import mmo, mmo_batched, mmo_reference
from repro.core.closure import (
    batched_bellman_ford_closure,
    batched_leyzorek_closure,
    bellman_ford_closure,
    closure_pad_values,
    floyd_warshall,
    leyzorek_closure,
    pad_adjacency,
    prepare_adjacency,
)

__all__ = [
    "ALL_OPS",
    "Semiring",
    "get_semiring",
    "contraction_pads",
    "mmo",
    "mmo_batched",
    "mmo_reference",
    "leyzorek_closure",
    "bellman_ford_closure",
    "batched_leyzorek_closure",
    "batched_bellman_ford_closure",
    "floyd_warshall",
    "prepare_adjacency",
    "pad_adjacency",
    "closure_pad_values",
]

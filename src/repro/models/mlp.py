"""Feed-forward blocks: SwiGLU (llama family) and GELU (enc-dec)."""
from __future__ import annotations

from typing import Optional

import jax
import jax.numpy as jnp

from repro.models import common as cm

Array = jax.Array


def mlp_params(key, cfg: cm.ModelConfig, n_layers: Optional[int] = None,
               gated: bool = True, d_ff: Optional[int] = None):
  d, f = cfg.d_model, d_ff or cfg.d_ff
  L = (n_layers,) if n_layers else ()
  ks = cm.split_keys(key, 3)
  p = {
      "w1": cm.dense_init(ks[0], (*L, d, f), dtype=cfg.param_dtype),
      "w2": cm.dense_init(ks[1], (*L, f, d), dtype=cfg.param_dtype),
  }
  if gated:
    p["w3"] = cm.dense_init(ks[2], (*L, d, f), dtype=cfg.param_dtype)
  return p


def mlp(p, cfg: cm.ModelConfig, x: Array) -> Array:
  dt = cfg.dtype
  h = jnp.einsum("bsd,df->bsf", x, p["w1"].astype(dt))
  if "w3" in p:
    h = jax.nn.silu(h) * jnp.einsum("bsd,df->bsf", x, p["w3"].astype(dt))
  else:
    h = jax.nn.gelu(h)
  return jnp.einsum("bsf,fd->bsd", h, p["w2"].astype(dt))

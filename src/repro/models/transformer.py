"""Decoder-only LM assembly (dense + MoE) — scan-over-layers throughout.

Covers: tinyllama, qwen2.5, granite, h2o-danube (SWA), mixtral (MoE+SWA),
phi3.5-moe (MoE), chameleon (qk-norm early-fusion VLM backbone).
"""
from __future__ import annotations

import functools
from typing import Optional

import jax
import jax.numpy as jnp

from repro.models import attention as attn_mod
from repro.models import common as cm
from repro.models import mlp as mlp_mod
from repro.models import moe as moe_mod

Array = jax.Array


def padded_vocab(cfg: cm.ModelConfig, mult: int = 256) -> int:
  return -(-cfg.vocab // mult) * mult


def init_lm_params(key, cfg: cm.ModelConfig):
  ks = cm.split_keys(key, 6)
  L = cfg.n_layers
  vp = padded_vocab(cfg)
  p = {
      "embed": (jax.random.normal(ks[0], (vp, cfg.d_model)) * 0.02).astype(
          cfg.param_dtype),
      "final_norm_scale": jnp.ones((cfg.d_model,), cfg.param_dtype),
      "blocks": {
          "ln1_norm_scale": jnp.ones((L, cfg.d_model), cfg.param_dtype),
          "ln2_norm_scale": jnp.ones((L, cfg.d_model), cfg.param_dtype),
          "attn": attn_mod.attn_params(ks[1], cfg, L),
      },
  }
  if cfg.n_experts:
    p["blocks"]["moe"] = moe_mod.moe_params(ks[2], cfg, L)
  else:
    p["blocks"]["mlp"] = mlp_mod.mlp_params(ks[2], cfg, L)
  if not cfg.tie_embeddings:
    p["lm_head"] = (jax.random.normal(ks[3], (vp, cfg.d_model)) *
                    0.02).astype(cfg.param_dtype)
  return p


def _block(lp, cfg: cm.ModelConfig, x, positions, *, mode, cache, cache_len,
           impl):
  x = cm.constrain_acts(x)
  h = cm.rms_norm(x, lp["ln1_norm_scale"], cfg.norm_eps)
  a, kv = attn_mod.attention(lp["attn"], cfg, h, positions, mode=mode,
                             layer_cache=cache, cache_len=cache_len,
                             impl=impl)
  x = x + a
  h = cm.rms_norm(x, lp["ln2_norm_scale"], cfg.norm_eps)
  if cfg.n_experts:
    m, aux = moe_mod.moe_block(lp["moe"], cfg, h)
  else:
    m, aux = mlp_mod.mlp(lp["mlp"], cfg, h), jnp.zeros((), jnp.float32)
  return x + m, kv, aux


def logits_from(p, cfg: cm.ModelConfig, x: Array) -> Array:
  head = p["embed"] if cfg.tie_embeddings else p["lm_head"]
  return jnp.einsum("bsd,vd->bsv", x, head.astype(cfg.dtype))


def forward_lm(p, cfg: cm.ModelConfig, tokens_or_embeds: Array,
               positions: Optional[Array] = None, *, mode: str = "train",
               cache=None, impl: str = "xla", remat: str = "none"):
  """Returns (logits, new_cache_or_None, aux_loss).

  tokens_or_embeds: int32 token ids (B,S) or precomputed embeddings (B,S,D)
  (modality-frontend stub path).  For decode, S == 1 and ``cache`` must be an
  ``attn_mod.init_cache`` pytree (layer-stacked).
  """
  if tokens_or_embeds.ndim == 2:
    x = jnp.take(p["embed"], tokens_or_embeds, axis=0).astype(cfg.dtype)
  else:
    x = tokens_or_embeds.astype(cfg.dtype)
  b, s = x.shape[:2]
  cache_len = cache["len"] if cache is not None else None
  if positions is None:
    base = cache_len if mode == "decode" else 0
    positions = base + jnp.arange(s)[None, :] + jnp.zeros((b, 1), jnp.int32)

  def body(carry, xs):
    x = carry
    lp, layer_cache = xs
    x, kv, aux = _block(lp, cfg, x, positions, mode=mode, cache=layer_cache,
                        cache_len=cache_len, impl=impl)
    return x, (kv, aux)

  if remat == "full":
    body = jax.checkpoint(body)
  elif remat == "dots":
    body = jax.checkpoint(
        body, policy=jax.checkpoint_policies.dots_with_no_batch_dims_saveable)

  layer_caches = ({"k": cache["k"], "v": cache["v"]}
                  if cache is not None else None)
  x, (kvs, auxs) = jax.lax.scan(body, x, (p["blocks"], layer_caches))

  if mode == "prefill":
    x = x[:, -1:]  # serving only needs next-token logits; keeps V-dim math tiny
  x = cm.rms_norm(x, p["final_norm_scale"], cfg.norm_eps)
  logits = logits_from(p, cfg, x)

  new_cache = None
  if mode == "prefill":
    new_cache = {"k": kvs["k"], "v": kvs["v"], "len": jnp.asarray(s, jnp.int32)}
  elif mode == "decode":
    new_cache = {"k": kvs["k"], "v": kvs["v"], "len": cache_len + 1}
  return logits, new_cache, jnp.mean(auxs)

"""Top-k routed mixture-of-experts with capacity-bounded scatter dispatch.

Parallelism design (DESIGN.md §3): with 8–16 experts and a 16-wide model
axis, pure expert-parallelism is impossible (E < TP) — instead experts are
**TP-sharded on their hidden width** (each expert's FFN is split over the
model axis) and tokens stay on their data shard (no all-to-all).  Dispatch is
a per-row scatter into an (E, C) capacity buffer (vmapped over batch), so the
(T, E, C) one-hot dispatch tensor of the mesh-tf formulation is never
materialized; combine is the matching gather weighted by router gates.

Capacity per batch row: C = ceil(topk · S · capacity_factor / E); overflow
tokens are dropped (standard switch behaviour) and the router aux loss
(load-balance, Switch-style) is returned for the training objective.
"""
from __future__ import annotations

import math
from typing import Optional

import jax
import jax.numpy as jnp

from repro.models import common as cm

Array = jax.Array


def moe_params(key, cfg: cm.ModelConfig, n_layers: Optional[int] = None):
  d, f, e = cfg.d_model, cfg.d_ff, cfg.n_experts
  L = (n_layers,) if n_layers else ()
  ks = cm.split_keys(key, 4)
  return {
      "router": cm.dense_init(ks[0], (*L, d, e), dtype=cfg.param_dtype),
      "experts": {
          "w1": cm.dense_init(ks[1], (*L, e, d, f), dtype=cfg.param_dtype),
          "w3": cm.dense_init(ks[2], (*L, e, d, f), dtype=cfg.param_dtype),
          "w2": cm.dense_init(ks[3], (*L, e, f, d), in_axis=-2,
                              dtype=cfg.param_dtype),
      },
  }


def capacity(cfg: cm.ModelConfig, seq: int) -> int:
  c = math.ceil(cfg.topk * seq * cfg.capacity_factor / cfg.n_experts)
  return max(8, -(-c // 8) * 8)  # round up to 8 for TPU-friendly shapes


def _route(router_w: Array, cfg: cm.ModelConfig, x: Array):
  """x: (B,S,D) → gates (B,S,k), expert ids (B,S,k), aux loss (scalar)."""
  logits = jnp.einsum("bsd,de->bse", x.astype(jnp.float32),
                      router_w.astype(jnp.float32))
  probs = jax.nn.softmax(logits, axis=-1)
  gate, idx = jax.lax.top_k(probs, cfg.topk)          # (B,S,k)
  gate = gate / jnp.clip(gate.sum(-1, keepdims=True), 1e-9)
  # Switch aux loss: E · Σ_e fraction_tokens(e) · mean_prob(e)
  e = cfg.n_experts
  onehot = jax.nn.one_hot(idx[..., 0], e)             # top-1 fraction proxy
  frac = onehot.mean(axis=(0, 1))
  mean_p = probs.mean(axis=(0, 1))
  aux = e * jnp.sum(frac * mean_p)
  return gate.astype(x.dtype), idx, aux


def _dispatch_row(x_row: Array, idx_row: Array, gate_row: Array, e: int,
                  cap: int):
  """One batch row: scatter tokens into per-expert capacity slots.

  x_row: (S, D); idx/gate_row: (S, k).  Returns
  (buf (E, C, D), slot_e (S,k), slot_p (S,k), keep (S,k))."""
  s, k = idx_row.shape
  flat_e = idx_row.reshape(-1)                               # (S·k,)
  # position of each (token, choice) within its expert queue
  onehot = jax.nn.one_hot(flat_e, e, dtype=jnp.int32)        # (S·k, E)
  pos = jnp.cumsum(onehot, axis=0) - 1                       # arrival order
  flat_p = jnp.take_along_axis(pos, flat_e[:, None], axis=1)[:, 0]
  keep = flat_p < cap
  safe_p = jnp.where(keep, flat_p, 0)
  buf = jnp.zeros((e, cap, x_row.shape[-1]), x_row.dtype)
  contrib = jnp.where(keep[:, None], 1.0, 0.0).astype(x_row.dtype)
  tokens = jnp.repeat(x_row, k, axis=0) * contrib            # (S·k, D)
  buf = buf.at[flat_e, safe_p].add(tokens, mode="drop")
  return buf, flat_e.reshape(s, k), safe_p.reshape(s, k), keep.reshape(s, k)


def moe_block(p, cfg: cm.ModelConfig, x: Array):
  """x: (B,S,D) → (y, aux_loss)."""
  from jax.sharding import PartitionSpec as P
  b, s, d = x.shape
  e, cap = cfg.n_experts, capacity(cfg, s)
  gate, idx, aux = _route(p["router"], cfg, x)

  buf, slot_e, slot_p, keep = jax.vmap(
      lambda xr, ir, gr: _dispatch_row(xr, ir, gr, e, cap))(x, idx, gate)
  # buf: (B, E, C, D) — expert FFN, TP-sharded on F via the experts specs.
  # Pin batch/model shardings explicitly: GSPMD loses the batch sharding
  # through the vmapped scatter and would otherwise materialize global-batch
  # capacity buffers on every device (observed 53 GiB/dev on mixtral train).
  dp, tp = cm.act_axes()
  buf = cm.constrain(buf, P(dp, None, None, None))
  dt = cfg.dtype
  w = p["experts"]
  h = jnp.einsum("becd,edf->becf", buf, w["w1"].astype(dt))
  h = jax.nn.silu(h) * jnp.einsum("becd,edf->becf", buf, w["w3"].astype(dt))
  h = cm.constrain(h, P(dp, None, None, tp))
  out = jnp.einsum("becf,efd->becd", h, w["w2"].astype(dt))  # (B,E,C,D)
  out = cm.constrain(out, P(dp, None, None, None))

  # combine: gather each (token, choice) slot back, weight by gate
  def gather_row(out_row, se, sp, kp, gr):
    tok = out_row[se, sp]                                    # (S,k,D)
    return jnp.sum(tok * (gr * kp)[..., None], axis=1)

  y = jax.vmap(gather_row)(out, slot_e, slot_p,
                           keep.astype(dt), gate.astype(dt))
  return y, aux

"""Mamba2 / SSD (state-space duality) blocks — arXiv:2405.21060.

SIMD² tie-in (DESIGN.md §4): the SSD chunked algorithm *is* a masked
semiring-like contraction — the intra-chunk term is a (+, ×) matrix
contraction ``Y = (L ∘ C Bᵀ) X`` with a decay mask L built from a (+)-ring
cumulative scan (``segsum``), and the inter-chunk recurrence is an
associative ⊕-scan over chunk states.  It runs on the same MXU dataflow the
paper generalizes, which is why mamba2/zamba2 are the "technique applies
structurally" architectures in the applicability matrix.

Layout: x (B,S,D) → z,xin (d_inner), B,C (G·N), dt (H) → depthwise causal
conv on (xin|B|C) → SSD(chunks) → gated RMSNorm → out_proj.  Heads are
TP-sharded (d_inner over the model axis); B/C/dt are small and replicated.
"""
from __future__ import annotations

from typing import Optional

import jax
import jax.numpy as jnp

from repro.models import common as cm

Array = jax.Array


def ssm_params(key, cfg: cm.ModelConfig, n_layers: Optional[int] = None):
  d, din = cfg.d_model, cfg.d_inner
  g, n, h = cfg.ssm_ngroups, cfg.ssm_state, cfg.ssm_heads
  k = cfg.conv_kernel
  L = (n_layers,) if n_layers else ()
  ks = cm.split_keys(key, 8)
  return {
      "in_proj_z": cm.dense_init(ks[0], (*L, d, din), dtype=cfg.param_dtype),
      "in_proj_x": cm.dense_init(ks[1], (*L, d, din), dtype=cfg.param_dtype),
      "bc_proj": cm.dense_init(ks[2], (*L, d, 2 * g * n),
                               dtype=cfg.param_dtype),
      "dt_proj": cm.dense_init(ks[3], (*L, d, h), dtype=cfg.param_dtype),
      "conv_w": (jax.random.normal(ks[4], (*L, k, din)) * 0.1).astype(
          cfg.param_dtype),
      "bc_filter_w": (jax.random.normal(ks[5], (*L, k, 2 * g * n)) *
                      0.1).astype(cfg.param_dtype),
      "A_log": jnp.zeros((*L, h), cfg.param_dtype),       # A = −exp(A_log)
      "ssd_skip_D": jnp.ones((*L, h), cfg.param_dtype),
      "dt_bias": jnp.full((*L, h), -4.6, cfg.param_dtype),  # softplus ≈ 0.01
      "ssd_norm_scale": jnp.ones((*L, din), cfg.param_dtype),
      "out_proj": cm.dense_init(ks[6], (*L, din, d), dtype=cfg.param_dtype),
  }


def _causal_conv(x: Array, w: Array, state: Optional[Array] = None):
  """Depthwise causal conv.  x: (B,S,C); w: (K,C).  Returns (y, new_state)
  where state carries the last K−1 inputs for decode."""
  k = w.shape[0]
  if state is None:
    pad = jnp.zeros((x.shape[0], k - 1, x.shape[2]), x.dtype)
  else:
    pad = state.astype(x.dtype)
  xp = jnp.concatenate([pad, x], axis=1)
  y = sum(xp[:, i:i + x.shape[1], :] * w[i][None, None, :].astype(x.dtype)
          for i in range(k))
  new_state = xp[:, -(k - 1):, :] if k > 1 else None
  return y, new_state


def _segsum(x: Array) -> Array:
  """Within-chunk segment-sum: out[..., i, j] = Σ_{t∈(j, i]} x[..., t]
  (−inf above the diagonal) — the (+)-ring cumulative scan behind the decay
  mask L = exp(segsum)."""
  q = x.shape[-1]
  cs = jnp.cumsum(x, axis=-1)
  diff = cs[..., :, None] - cs[..., None, :]          # (…, i, j) = cs_i−cs_j
  mask = jnp.tril(jnp.ones((q, q), bool), 0)
  return jnp.where(mask, diff, -jnp.inf)


def ssd_chunked(xh: Array, dt: Array, a: Array, b: Array, c: Array,
                chunk: int, init_state: Optional[Array] = None):
  """SSD scan. xh: (B,S,H,P); dt: (B,S,H); a: (H,) negative;
  b, c: (B,S,G,N).  Returns (y (B,S,H,P), final_state (B,H,N,P))."""
  bsz, s, h, p = xh.shape
  g, n = b.shape[2], b.shape[3]
  hg = h // g
  q = min(chunk, s)
  s_real = s
  if s % q:
    # pad the tail: dt=0 ⇒ decay exp(0)=1 and contribution dt·B·x=0, so the
    # final state and all real rows are unaffected (tail rows are cropped).
    pad = q * (-(-s // q)) - s
    xh = jnp.pad(xh, ((0, 0), (0, pad), (0, 0), (0, 0)))
    dt = jnp.pad(dt, ((0, 0), (0, pad), (0, 0)))
    b = jnp.pad(b, ((0, 0), (0, pad), (0, 0), (0, 0)))
    c = jnp.pad(c, ((0, 0), (0, pad), (0, 0), (0, 0)))
    s = s + pad
  nc = s // q

  f32 = jnp.float32
  xh = xh.astype(f32)
  dt = dt.astype(f32)
  dA = dt * a.astype(f32)[None, None, :]              # (B,S,H) ≤ 0
  # NOTE(§Perf H-C): explicitly pinning dA/dt to the model axis on heads was
  # tried and REFUTED — GSPMD already propagates head sharding from xh into
  # the decay chain, and the extra boundary reshard cost +5% memory /+40%
  # collective traffic on mamba2 train_4k.  Left unpinned.

  def r(t, shape):  # (B,S,…) → (B,nc,Q,…)
    return t.reshape(bsz, nc, q, *shape)

  xc = r(xh, (h, p))
  dtc = r(dt, (h,))
  dac = r(dA, (h,))
  bc = r(b.astype(f32), (g, n))
  cc = r(c.astype(f32), (g, n))

  # decay structures
  seg = _segsum(dac.transpose(0, 1, 3, 2))            # (B,nc,H,Q,Q)
  L = jnp.exp(seg)
  cum = jnp.cumsum(dac, axis=2)                        # (B,nc,Q,H)
  total = cum[:, :, -1]                                # (B,nc,H)

  # intra-chunk: Y_d[i] = Σ_j (C_i·B_j) L[i,j] dt_j x_j
  scores = jnp.einsum("bzqgn,bzkgn->bzgqk", cc, bc)    # (B,nc,G,Q,Q)
  scores = jnp.repeat(scores, hg, axis=2) * L          # (B,nc,H,Q,Q)
  y_diag = jnp.einsum("bzhqk,bzkh,bzkhp->bzqhp", scores, dtc, xc)

  # chunk states: S_z = Σ_j exp(total − cum_j) dt_j B_j ⊗ x_j
  decay_state = jnp.exp(total[:, :, None, :] - cum)    # (B,nc,Q,H)
  b_heads = jnp.repeat(bc, hg, axis=3)                 # group → heads
  states = jnp.einsum("bzqh,bzqh,bzqhn,bzqhp->bzhnp",
                      decay_state, dtc, b_heads, xc)

  # inter-chunk recurrence: state_{z+1} = exp(total_z)·state_z + S_z
  chunk_decay = jnp.exp(total)                         # (B,nc,H)

  def scan_fn(carry, xs):
    st_prev = carry
    s_z, dec = xs
    st = st_prev * dec[..., None, None] + s_z
    return st, st_prev

  s0 = jnp.zeros((bsz, h, n, p), f32) if init_state is None else (
      init_state.astype(f32))
  xs = (states.transpose(1, 0, 2, 3, 4), chunk_decay.transpose(1, 0, 2))
  final, prevs = jax.lax.scan(scan_fn, s0, xs)
  prev_states = prevs.transpose(1, 0, 2, 3, 4)         # (B,nc,H,N,P)

  # inter-chunk output: Y_off[i] = (C_i · state_prev) exp(cum_i)
  c_heads = jnp.repeat(cc, hg, axis=3)
  y_off = jnp.einsum("bzqhn,bzhnp,bzqh->bzqhp", c_heads, prev_states,
                     jnp.exp(cum))
  y = (y_diag + y_off).reshape(bsz, s, h, p)[:, :s_real]
  return y, final


def ssm_block(p, cfg: cm.ModelConfig, x: Array, *, mode: str = "train",
              state=None):
  """One mamba2 block.  state (decode): {'ssm': (B,H,N,P), 'conv': (B,K-1,C),
  'bc_conv': (B,K-1,2GN)}.  Returns (y, new_state|None)."""
  dt_ = cfg.dtype
  bsz, s, _ = x.shape
  g, n, h, pdim = cfg.ssm_ngroups, cfg.ssm_state, cfg.ssm_heads, cfg.ssm_headdim

  z = jnp.einsum("bsd,de->bse", x, p["in_proj_z"].astype(dt_))
  xin = jnp.einsum("bsd,de->bse", x, p["in_proj_x"].astype(dt_))
  bcat = jnp.einsum("bsd,de->bse", x, p["bc_proj"].astype(dt_))
  dt = jnp.einsum("bsd,dh->bsh", x, p["dt_proj"].astype(dt_))

  conv_state = state["conv"] if state is not None else None
  bc_state = state["bc_conv"] if state is not None else None
  xin, new_conv = _causal_conv(xin, p["conv_w"], conv_state)
  bcat, new_bc = _causal_conv(bcat, p["bc_filter_w"], bc_state)
  xin = jax.nn.silu(xin)
  bcat = jax.nn.silu(bcat)

  b_ssm = bcat[..., : g * n].reshape(bsz, s, g, n)
  c_ssm = bcat[..., g * n:].reshape(bsz, s, g, n)
  dt = jax.nn.softplus(dt.astype(jnp.float32) +
                       p["dt_bias"].astype(jnp.float32))
  a = -jnp.exp(p["A_log"].astype(jnp.float32))
  xh = xin.reshape(bsz, s, h, pdim)

  if mode == "decode":
    # single-step recurrence (s == 1)
    st = state["ssm"].astype(jnp.float32)
    da = jnp.exp(dt[:, 0] * a[None, :])                 # (B,H)
    hg = h // g
    b1 = jnp.repeat(b_ssm[:, 0], hg, axis=1)            # (B,H,N)
    c1 = jnp.repeat(c_ssm[:, 0], hg, axis=1)
    upd = jnp.einsum("bh,bhn,bhp->bhnp", dt[:, 0], b1,
                     xh[:, 0].astype(jnp.float32))
    st = st * da[..., None, None] + upd
    y = jnp.einsum("bhn,bhnp->bhp", c1, st)[:, None]    # (B,1,H,P)
    new_state = {"ssm": st, "conv": new_conv, "bc_conv": new_bc}
  else:
    y, final = ssd_chunked(xh, dt, a, b_ssm, c_ssm, cfg.ssm_chunk)
    new_state = ({"ssm": final, "conv": new_conv, "bc_conv": new_bc}
                 if mode == "prefill" else None)

  y = y + p["ssd_skip_D"].astype(jnp.float32)[None, None, :, None] * \
      xh.astype(jnp.float32)
  y = y.reshape(bsz, s, h * pdim).astype(dt_)
  y = cm.rms_norm(y * jax.nn.silu(z), p["ssd_norm_scale"], cfg.norm_eps)
  out = jnp.einsum("bse,ed->bsd", y, p["out_proj"].astype(dt_))
  return out, new_state


def init_ssm_state(cfg: cm.ModelConfig, n_layers: int, batch: int):
  h, n, pdim = cfg.ssm_heads, cfg.ssm_state, cfg.ssm_headdim
  k = cfg.conv_kernel
  return {
      "ssm": jnp.zeros((n_layers, batch, h, n, pdim), jnp.float32),
      "conv": jnp.zeros((n_layers, batch, k - 1, cfg.d_inner), cfg.dtype),
      "bc_conv": jnp.zeros(
          (n_layers, batch, k - 1, 2 * cfg.ssm_ngroups * cfg.ssm_state),
          cfg.dtype),
  }

"""Chameleon-style early-fusion VLM utilities.

The backbone is the dense transformer (qk_norm=True per chameleon); images
enter as VQ codebook token ids *fused into the text stream*.  The VQ
image-tokenizer front-end is a STUB per the assignment — but its core
computation, nearest-codebook search, is exactly the paper's ``addnorm``
SIMD² instruction, so `vq_tokenize` below runs on the SIMD² kernel path:
D[i,j] = Σ_k (patch_i[k] − code_j[k])², then argmin over j.

This is the "technique applies directly" row of DESIGN.md §4.
"""
from __future__ import annotations

import functools

import jax
import jax.numpy as jnp

from repro.core.mmo import mmo

Array = jax.Array


@functools.partial(jax.jit, static_argnames=("backend",))
def vq_tokenize(patch_embeds: Array, codebook: Array, *,
                backend: str = "auto") -> Array:
  """patch_embeds: (..., P, D); codebook: (K, D) → token ids (..., P).

  Uses SIMD².addnorm (MXU-rewrite backend by default; 'pallas' routes to the
  kernel; 'vector' is the no-SIMD²-unit arm)."""
  flat = patch_embeds.reshape(-1, patch_embeds.shape[-1])
  d2 = mmo(flat, codebook.T, op="addnorm", backend=backend)
  ids = jnp.argmin(d2, axis=-1).astype(jnp.int32)
  return ids.reshape(patch_embeds.shape[:-1])


def fuse_streams(text_tokens: Array, image_tokens: Array,
                 image_token_offset: int) -> Array:
  """Early fusion: image token ids are shifted into their reserved vocab
  range and concatenated ahead of the text tokens."""
  return jnp.concatenate(
      [image_tokens + image_token_offset, text_tokens], axis=-1)

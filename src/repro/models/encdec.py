"""Encoder-decoder backbone (seamless-m4t text/audio) — scan-over-layers.

The audio frontend is a STUB per the assignment: ``input_specs`` provides
precomputed frame embeddings (B, S_src, D); the encoder is non-causal
self-attention + GELU MLP, the decoder adds causal self-attention and
per-layer cross-attention over the encoder output.
"""
from __future__ import annotations

from typing import Optional

import jax
import jax.numpy as jnp

from repro.models import attention as attn_mod
from repro.models import common as cm
from repro.models import mlp as mlp_mod
from repro.models.transformer import padded_vocab

Array = jax.Array


def init_encdec_params(key, cfg: cm.ModelConfig):
  ks = cm.split_keys(key, 8)
  le, ld = cfg.enc_layers, cfg.dec_layers
  vp = padded_vocab(cfg)
  return {
      "embed": (jax.random.normal(ks[0], (vp, cfg.d_model)) * 0.02).astype(
          cfg.param_dtype),
      "enc": {
          "ln1_norm_scale": jnp.ones((le, cfg.d_model), cfg.param_dtype),
          "ln2_norm_scale": jnp.ones((le, cfg.d_model), cfg.param_dtype),
          "attn": attn_mod.attn_params(ks[1], cfg, le),
          "mlp": mlp_mod.mlp_params(ks[2], cfg, le, gated=False),
      },
      "enc_norm_scale": jnp.ones((cfg.d_model,), cfg.param_dtype),
      "dec": {
          "ln1_norm_scale": jnp.ones((ld, cfg.d_model), cfg.param_dtype),
          "ln2_norm_scale": jnp.ones((ld, cfg.d_model), cfg.param_dtype),
          "ln3_norm_scale": jnp.ones((ld, cfg.d_model), cfg.param_dtype),
          "attn": attn_mod.attn_params(ks[3], cfg, ld),
          "cross": attn_mod.attn_params(ks[4], cfg, ld),
          "mlp": mlp_mod.mlp_params(ks[5], cfg, ld, gated=False),
      },
      "final_norm_scale": jnp.ones((cfg.d_model,), cfg.param_dtype),
      "lm_head": (jax.random.normal(ks[6], (vp, cfg.d_model)) * 0.02).astype(
          cfg.param_dtype),
  }


def encode(p, cfg: cm.ModelConfig, src_embeds: Array,
           remat: str = "none") -> Array:
  """src_embeds: (B, S_src, D) from the modality stub."""
  x = src_embeds.astype(cfg.dtype)
  b, s = x.shape[:2]
  positions = jnp.broadcast_to(jnp.arange(s)[None, :], (b, s))

  def body(x, lp):
    h = cm.rms_norm(x, lp["ln1_norm_scale"], cfg.norm_eps)
    a, _ = attn_mod.attention(lp["attn"], cfg, h, positions, mode="train",
                              causal=False)
    x = x + a
    h = cm.rms_norm(x, lp["ln2_norm_scale"], cfg.norm_eps)
    return x + mlp_mod.mlp(lp["mlp"], cfg, h), None

  if remat == "full":
    body = jax.checkpoint(body)
  x, _ = jax.lax.scan(body, x, p["enc"])
  return cm.rms_norm(x, p["enc_norm_scale"], cfg.norm_eps)


def _cross_kv(lp, cfg: cm.ModelConfig, enc_out: Array):
  """Per-layer projected encoder K/V (no RoPE on cross-attention)."""
  dt = cfg.dtype
  k = jnp.einsum("bsd,dhk->bshk", enc_out, lp["wk"].astype(dt))
  v = jnp.einsum("bsd,dhk->bshk", enc_out, lp["wv"].astype(dt))
  return k, v


def decode_stack(p, cfg: cm.ModelConfig, tokens: Array, enc_out: Array, *,
                 mode: str = "train", cache=None, impl: str = "xla",
                 remat: str = "none"):
  x = jnp.take(p["embed"], tokens, axis=0).astype(cfg.dtype)
  b, s = x.shape[:2]
  cache_len = cache["len"] if cache is not None else None
  base = cache_len if mode == "decode" else 0
  positions = base + jnp.arange(s)[None, :] + jnp.zeros((b, 1), jnp.int32)

  # Cross-attention K/V for ALL layers, projected once outside the scan
  # (§Perf: inside the rematerialized body they were recomputed fwd+bwd+remat
  # per microbatch — the dominant memory-traffic term of the seamless train
  # cell).  Scanned in as xs; remat does not recompute xs.
  dt = cfg.dtype
  ck_all = jnp.einsum("bsd,ldhk->lbshk", enc_out,
                      p["dec"]["cross"]["wk"].astype(dt))
  cv_all = jnp.einsum("bsd,ldhk->lbshk", enc_out,
                      p["dec"]["cross"]["wv"].astype(dt))

  def body(x, xs):
    lp, layer_cache, ck, cv = xs
    x = cm.constrain_acts(x)
    h = cm.rms_norm(x, lp["ln1_norm_scale"], cfg.norm_eps)
    a, kv = attn_mod.attention(lp["attn"], cfg, h, positions, mode=mode,
                               layer_cache=layer_cache, cache_len=cache_len,
                               impl=impl)
    x = x + a
    h = cm.rms_norm(x, lp["ln2_norm_scale"], cfg.norm_eps)
    ca, _ = attn_mod.attention(lp["cross"], cfg, h, positions, mode=mode,
                               layer_cache=layer_cache, cache_len=cache_len,
                               impl=impl, kv_override=(ck, cv))
    x = x + ca
    h = cm.rms_norm(x, lp["ln3_norm_scale"], cfg.norm_eps)
    return x + mlp_mod.mlp(lp["mlp"], cfg, h), kv

  if remat == "full":
    body = jax.checkpoint(body)

  layer_caches = ({"k": cache["k"], "v": cache["v"]}
                  if cache is not None else None)
  x, kvs = jax.lax.scan(body, x, (p["dec"], layer_caches, ck_all, cv_all))
  if mode == "prefill":
    x = x[:, -1:]
  x = cm.rms_norm(x, p["final_norm_scale"], cfg.norm_eps)
  logits = jnp.einsum("bsd,vd->bsv", x, p["lm_head"].astype(cfg.dtype))

  new_cache = None
  if mode == "prefill":
    new_cache = {"k": kvs["k"], "v": kvs["v"],
                 "len": jnp.asarray(s, jnp.int32)}
  elif mode == "decode":
    new_cache = {"k": kvs["k"], "v": kvs["v"], "len": cache_len + 1}
  return logits, new_cache


def forward_encdec(p, cfg: cm.ModelConfig, src_embeds: Array, tokens: Array,
                   *, mode: str = "train", cache=None, enc_out=None,
                   impl: str = "xla", remat: str = "none"):
  """Returns (logits, new_cache, aux).  For decode, pass precomputed
  ``enc_out`` (the serving loop encodes once)."""
  if enc_out is None:
    enc_out = encode(p, cfg, src_embeds, remat=remat)
  logits, new_cache = decode_stack(p, cfg, tokens, enc_out, mode=mode,
                                   cache=cache, impl=impl, remat=remat)
  return logits, new_cache, jnp.zeros((), jnp.float32)

"""GQA attention: flash-style chunked XLA path (default), Pallas kernel path
(TPU), KV cache with decode, sliding-window masking.

Memory/dataflow notes:
  * The XLA path is an online-softmax scan over KV chunks — identical math to
    kernels/flash_attention.py but expressed in HLO so the multi-pod dry-run
    lowers without Mosaic.  Peak live logits are (B, KV, G, Sq, ckv) instead
    of (B, H, Sq, Skv).
  * GQA is computed in (KV, G) grouped form — expanded K/V are never
    materialized.
  * Decode keeps the whole cache resident; with ``Parallelism.
    seq_shard_decode`` the cache's sequence axis is sharded over the model
    axis and XLA turns the softmax/PV reductions into cross-chip collectives
    (sequence-parallel decode — how a 524 k-token cache fits a pod).
"""
from __future__ import annotations

import functools
from typing import Optional

import jax
import jax.numpy as jnp

from repro.models import common as cm

Array = jax.Array
_NEG = -1e30

# KV-chunk length for the online-softmax prefill scan (perf knob, §Perf H-D):
# larger chunks amortize the (m, l, acc) carry read-modify-writes; VMEM on
# real TPU bounds it at a few k.
FLASH_CHUNK = [2048]  # §Perf H-E: 2048 beats 1024 by ~4% on prefill bytes


def attn_params(key, cfg: cm.ModelConfig, n_layers: Optional[int] = None):
  """Stacked attention params; leading dim = layers (absent if n_layers None)."""
  hd, h, kv, d = cfg.hd, cfg.n_heads, cfg.n_kv_heads, cfg.d_model
  ks = cm.split_keys(key, 4)
  L = (n_layers,) if n_layers else ()
  p = {
      "wq": cm.dense_init(ks[0], (*L, d, h, hd), in_axis=-3,
                          dtype=cfg.param_dtype),
      "wk": cm.dense_init(ks[1], (*L, d, kv, hd), in_axis=-3,
                          dtype=cfg.param_dtype),
      "wv": cm.dense_init(ks[2], (*L, d, kv, hd), in_axis=-3,
                          dtype=cfg.param_dtype),
      "wo": cm.dense_init(ks[3], (*L, h, hd, d), in_axis=-2,
                          dtype=cfg.param_dtype),
  }
  if cfg.qkv_bias:
    p["bq"] = jnp.zeros((*L, h, hd), cfg.param_dtype)
    p["bk"] = jnp.zeros((*L, kv, hd), cfg.param_dtype)
    p["bv"] = jnp.zeros((*L, kv, hd), cfg.param_dtype)
  if cfg.qk_norm:
    p["q_norm_scale"] = jnp.ones((*L, hd), cfg.param_dtype)
    p["k_norm_scale"] = jnp.ones((*L, hd), cfg.param_dtype)
  return p


def _project_qkv(p, cfg: cm.ModelConfig, x: Array, positions: Array,
                 use_rope: bool = True):
  """x: (B, S, D) → q (B,S,H,hd), k/v (B,S,KV,hd), RoPE applied."""
  dt = cfg.dtype
  q = jnp.einsum("bsd,dhk->bshk", x, p["wq"].astype(dt))
  k = jnp.einsum("bsd,dhk->bshk", x, p["wk"].astype(dt))
  v = jnp.einsum("bsd,dhk->bshk", x, p["wv"].astype(dt))
  if cfg.qkv_bias:
    q = q + p["bq"].astype(dt)
    k = k + p["bk"].astype(dt)
    v = v + p["bv"].astype(dt)
  if cfg.qk_norm:
    q = cm.rms_norm(q, p["q_norm_scale"], cfg.norm_eps)
    k = cm.rms_norm(k, p["k_norm_scale"], cfg.norm_eps)
  if use_rope:
    q = cm.rope(q, positions, cfg.rope_theta)
    k = cm.rope(k, positions, cfg.rope_theta)
  return q, k, v


def _chunk_mask(c_idx, ck, skv, qpos, causal, window):
  kpos = (c_idx * ck + jnp.arange(ck))[None, :]  # (1, ck)
  mask = kpos < skv
  if causal:
    mask = mask & (kpos <= qpos)
  if window is not None:
    mask = mask & (kpos > qpos - window)
  return mask


def _kv_chunks(k, v, chunk):
  b, skv, kvh, hd = k.shape
  ck = min(chunk, skv)
  nck = -(-skv // ck)
  pad = nck * ck - skv
  kp = jnp.pad(k, ((0, 0), (0, pad), (0, 0), (0, 0)))
  vp = jnp.pad(v, ((0, 0), (0, pad), (0, 0), (0, 0)))
  ks = kp.reshape(b, nck, ck, kvh, hd).transpose(1, 0, 3, 2, 4)
  vs = vp.reshape(b, nck, ck, kvh, hd).transpose(1, 0, 3, 2, 4)
  return ks, vs, ck, nck


def _flash_fwd_impl(q, k, v, causal, window, scale, q_offset, chunk):
  """Online-softmax forward.  Returns (out(B,S,H,hd), lse(B,KV,G,Sq))."""
  b, sq, h, hd = q.shape
  skv, kvh = k.shape[1], k.shape[2]
  g = h // kvh
  qg = q.reshape(b, sq, kvh, g, hd).transpose(0, 2, 3, 1, 4)
  qg = qg.astype(jnp.float32) * scale
  ks, vs, ck, nck = _kv_chunks(k, v, chunk)
  qpos = (q_offset + jnp.arange(sq))[:, None]

  def step(carry, xs):
    m, l, acc = carry
    kc, vc, c_idx = xs
    s = jnp.einsum("bkgqd,bkcd->bkgqc", qg, kc.astype(jnp.float32))
    mask = _chunk_mask(c_idx, ck, skv, qpos, causal, window)
    s = jnp.where(mask[None, None, None], s, _NEG)
    m_new = jnp.maximum(m, s.max(axis=-1))
    alpha = jnp.exp(m - m_new)
    pexp = jnp.exp(s - m_new[..., None])
    l_new = l * alpha + pexp.sum(axis=-1)
    pv = jnp.einsum("bkgqc,bkcd->bkgqd", pexp, vc.astype(jnp.float32))
    acc_new = acc * alpha[..., None] + pv
    return (m_new, l_new, acc_new), None

  m0 = jnp.full((b, kvh, g, sq), _NEG, jnp.float32)
  l0 = jnp.zeros((b, kvh, g, sq), jnp.float32)
  a0 = jnp.zeros((b, kvh, g, sq, hd), jnp.float32)
  (m, l, acc), _ = jax.lax.scan(step, (m0, l0, a0),
                                (ks, vs, jnp.arange(nck)))
  lsafe = jnp.where(l == 0.0, 1.0, l)
  out = acc / lsafe[..., None]
  out = out.transpose(0, 3, 1, 2, 4).reshape(b, sq, h, hd).astype(q.dtype)
  lse = m + jnp.log(lsafe)
  return out, lse


@functools.partial(jax.custom_vjp, nondiff_argnums=(3, 4, 5, 6, 7))
def _flash_xla(q: Array, k: Array, v: Array, causal: bool,
               window: Optional[int], scale: float, q_offset: int = 0,
               chunk: int = 1024) -> Array:
  """Flash attention with a flash *backward* (custom VJP): the bwd pass
  recomputes per-chunk probabilities from (q, k, v, out, lse) instead of
  letting scan-autodiff stack per-chunk f32 probability residuals through
  HBM — the dominant memory/bytes term of the baseline train cells
  (EXPERIMENTS.md §Perf, optimization P1)."""
  out, _ = _flash_fwd_impl(q, k, v, causal, window, scale, q_offset, chunk)
  return out


def _flash_xla_fwd(q, k, v, causal, window, scale, q_offset, chunk):
  out, lse = _flash_fwd_impl(q, k, v, causal, window, scale, q_offset, chunk)
  return out, (q, k, v, out, lse)


def _flash_xla_bwd(causal, window, scale, q_offset, chunk, res, dout):
  q, k, v, out, lse = res
  b, sq, h, hd = q.shape
  skv, kvh = k.shape[1], k.shape[2]
  g = h // kvh
  f32 = jnp.float32

  qg = q.reshape(b, sq, kvh, g, hd).transpose(0, 2, 3, 1, 4).astype(f32)
  og = out.reshape(b, sq, kvh, g, hd).transpose(0, 2, 3, 1, 4).astype(f32)
  dg = dout.reshape(b, sq, kvh, g, hd).transpose(0, 2, 3, 1, 4).astype(f32)
  delta = jnp.sum(og * dg, axis=-1)                    # (B,KV,G,Sq)
  ks, vs, ck, nck = _kv_chunks(k, v, chunk)
  qpos = (q_offset + jnp.arange(sq))[:, None]

  def step(dq_acc, xs):
    kc, vc, c_idx = xs
    kc = kc.astype(f32)
    vc = vc.astype(f32)
    s = jnp.einsum("bkgqd,bkcd->bkgqc", qg * scale, kc)
    mask = _chunk_mask(c_idx, ck, skv, qpos, causal, window)
    p = jnp.where(mask[None, None, None],
                  jnp.exp(s - lse[..., None]), 0.0)    # (B,KV,G,Sq,ck)
    dv_c = jnp.einsum("bkgqc,bkgqd->bkcd", p, dg)
    dp = jnp.einsum("bkgqd,bkcd->bkgqc", dg, vc)
    ds = p * (dp - delta[..., None]) * scale           # dL/ds · scale chain
    dq_acc = dq_acc + jnp.einsum("bkgqc,bkcd->bkgqd", ds, kc)
    dk_c = jnp.einsum("bkgqc,bkgqd->bkcd", ds, qg)
    return dq_acc, (dk_c, dv_c)

  dq0 = jnp.zeros((b, kvh, g, sq, hd), f32)
  dq, (dks, dvs) = jax.lax.scan(step, dq0, (ks, vs, jnp.arange(nck)))
  dq = dq.transpose(0, 3, 1, 2, 4).reshape(b, sq, h, hd).astype(q.dtype)
  # (nc,B,KV,ck,hd) → (B, Skv_pad, KV, hd) → crop
  dk = dks.transpose(1, 0, 3, 2, 4).reshape(b, nck * ck, kvh, hd)
  dv = dvs.transpose(1, 0, 3, 2, 4).reshape(b, nck * ck, kvh, hd)
  return (dq, dk[:, :skv].astype(k.dtype), dv[:, :skv].astype(v.dtype))


_flash_xla.defvjp(_flash_xla_fwd, _flash_xla_bwd)


def _full_decode(q: Array, k: Array, v: Array, *, scale: float,
                 kv_len: Array, window: Optional[int],
                 chunk: int = 8192) -> Array:
  """Flash-decode: single-step attention against a (possibly partially
  filled) cache, online-softmax over cache chunks so dtype conversions and
  score tensors stay chunk-local (never a full-cache-sized temp).

  q: (B,1,H,hd); k/v: (B,Smax,KV,hd); kv_len: valid prefix length (B,) or ()."""
  b, _, h, hd = q.shape
  smax, kvh = k.shape[1], k.shape[2]
  g = h // kvh
  f32 = jnp.float32
  qg = q.reshape(b, kvh, g, hd).astype(f32) * scale
  kv_len = jnp.asarray(kv_len)
  if kv_len.ndim == 0:
    kv_len = jnp.full((b,), kv_len)

  # single fused contraction over the whole cache: SPMD-friendly for any
  # cache sharding (seq- or kv-head-sharded).  bf16 operands with f32
  # accumulation; the CPU host backend materializes chunkable f32 converts
  # (a host-compiler artifact noted in EXPERIMENTS.md — TPU keeps bf16 dots).
  qb = qg.astype(k.dtype)
  s = jnp.einsum("bkgd,bskd->bkgs", qb, k,
                 preferred_element_type=f32)
  kpos = jnp.arange(smax)[None, :]
  mask = kpos < kv_len[:, None]
  if window is not None:
    mask = mask & (kpos > kv_len[:, None] - 1 - window)
  s = jnp.where(mask[:, None, None], s, _NEG)
  p = jax.nn.softmax(s, axis=-1)
  out = jnp.einsum("bkgs,bskd->bkgd", p.astype(k.dtype), v,
                   preferred_element_type=f32)
  return out.reshape(b, 1, h, hd).astype(q.dtype)


def init_cache(cfg: cm.ModelConfig, n_layers: int, batch: int, max_len: int,
               dtype=None):
  dtype = dtype or cfg.dtype
  kv, hd = cfg.n_kv_heads, cfg.hd
  return {
      "k": jnp.zeros((n_layers, batch, max_len, kv, hd), dtype),
      "v": jnp.zeros((n_layers, batch, max_len, kv, hd), dtype),
      "len": jnp.zeros((), jnp.int32),
  }


def attention(p, cfg: cm.ModelConfig, x: Array, positions: Array, *,
              mode: str = "train",
              layer_cache=None,
              cache_len=None,
              impl: str = "xla",
              causal: bool = True,
              kv_override=None) -> tuple[Array, Optional[dict]]:
  """One attention block.

  mode:
    'train'   — full-sequence, no cache; returns (out, None)
    'prefill' — full-sequence; returns (out, {'k','v'}) for cache seeding
    'decode'  — x is (B, 1, D); layer_cache holds {'k','v'} (B,Smax,KV,hd)
                and cache_len the filled length; returns (out, updated kv)
  kv_override: (k, v) for cross-attention (keys from the encoder).
  """
  scale = cfg.hd ** -0.5
  window = cfg.window

  if mode in ("train", "prefill"):
    q, k, v = _project_qkv(p, cfg, x, positions)
    if kv_override is not None:
      k, v = kv_override
      causal = False
    if impl == "pallas":
      from repro.kernels import flash_attention as fa
      out = fa(q.transpose(0, 2, 1, 3), k.transpose(0, 2, 1, 3),
               v.transpose(0, 2, 1, 3), causal=causal, window=window,
               scale=scale).transpose(0, 2, 1, 3)
    elif impl == "xla_autodiff":
      # baseline arm (§Perf P1): scan-autodiff attention backward
      out, _ = _flash_fwd_impl(q, k, v, causal, window, scale, 0,
                               FLASH_CHUNK[0])
    else:
      out = _flash_xla(q, k, v, causal, window, scale, 0, FLASH_CHUNK[0])
    y = jnp.einsum("bshk,hkd->bsd", out, p["wo"].astype(cfg.dtype))
    new_cache = {"k": k, "v": v} if mode == "prefill" else None
    return y, new_cache

  if mode == "decode":
    q, k_new, v_new = _project_qkv(p, cfg, x, positions)
    k_cache, v_cache = layer_cache["k"], layer_cache["v"]
    if kv_override is None:
      smax = k_cache.shape[1]
      # ring-buffer write: when the cache is sized to the sliding window
      # (long-context SWA decode), cache_len wraps and the oldest row is
      # overwritten; for a full-length cache this reduces to plain append.
      # The write is a masked select rather than dynamic-update-slice: the
      # sequence axis is sharded over the model axis in sequence-parallel
      # decode, and a DUS with a traced index on a sharded dim makes GSPMD
      # materialize unsharded copies (observed: 17× memory blow-up); the
      # elementwise select shards trivially and aliases the donated buffer.
      write_idx = cache_len % smax
      seq_iota = jnp.arange(smax)[None, :, None, None]
      wmask = seq_iota == write_idx
      k_cache = jnp.where(wmask, k_new.astype(k_cache.dtype), k_cache)
      v_cache = jnp.where(wmask, v_new.astype(v_cache.dtype), v_cache)
      kv_len = jnp.minimum(cache_len + 1, smax)
      # extra window masking only when the cache is larger than the window
      eff_window = window if (window is not None and window < smax) else None
      out = _full_decode(q, k_cache, v_cache, scale=scale,
                         kv_len=kv_len, window=eff_window)
      updated = {"k": k_cache, "v": v_cache}
    else:
      ko, vo = kv_override
      out = _full_decode(q, ko, vo, scale=scale, kv_len=ko.shape[1],
                         window=None)
      updated = layer_cache
    y = jnp.einsum("bshk,hkd->bsd", out, p["wo"].astype(cfg.dtype))
    return y, updated

  raise ValueError(mode)

"""Model substrate: configs, layers, families, unified zoo API."""
from repro.models.common import ModelConfig, Parallelism, specs_like
from repro.models import zoo

__all__ = ["ModelConfig", "Parallelism", "specs_like", "zoo"]

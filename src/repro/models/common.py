"""Model substrate shared across all assigned architectures.

Design rules (they matter at 512-chip scale):

  * **Stacked layers + lax.scan** everywhere — HLO size is O(1) in depth, so
    an 81-layer hybrid compiles as fast as a 22-layer dense model, and
    FSDP-style parameter gathering happens per scan step (overlapped by XLA).
  * **Explicit PartitionSpec per parameter** via `param_specs` — TP over the
    ``model`` axis (attention heads / FFN hidden / vocab), optional ZeRO-3
    ("fsdp") sharding of the stacked-layer weights over the ``data`` axis.
  * Pure functional pytrees (dict params), no framework dependency.
"""
from __future__ import annotations

import dataclasses
from typing import Any, Optional, Sequence

import jax
import jax.numpy as jnp
import numpy as np
from jax.sharding import PartitionSpec as P

Array = jax.Array


# ---------------------------------------------------------------------------
# configuration
# ---------------------------------------------------------------------------


@dataclasses.dataclass(frozen=True)
class ModelConfig:
  name: str
  family: str                      # dense | moe | ssm | hybrid | encdec | vlm
  n_layers: int
  d_model: int
  n_heads: int
  n_kv_heads: int
  d_ff: int
  vocab: int
  head_dim: Optional[int] = None
  # attention
  window: Optional[int] = None     # sliding-window size (SWA) or None
  qkv_bias: bool = False
  qk_norm: bool = False
  rope_theta: float = 10000.0
  norm_eps: float = 1e-5
  tie_embeddings: bool = False
  # MoE
  n_experts: int = 0
  topk: int = 0
  capacity_factor: float = 1.25
  # SSM (mamba2 / SSD)
  ssm_state: int = 0
  ssm_expand: int = 2
  ssm_headdim: int = 64
  ssm_ngroups: int = 1
  ssm_chunk: int = 256
  conv_kernel: int = 4
  # hybrid (zamba2-style): one shared attention block every k SSM blocks
  hybrid_attn_every: int = 0
  # encoder-decoder
  enc_layers: int = 0
  dec_layers: int = 0
  cross_attention: bool = False
  src_len: int = 0                 # modality-frontend stub sequence length
  # modality stub: frontend emits precomputed embeddings (audio frames /
  # image patches); `None` = token ids only
  modality_stub: Optional[str] = None
  # dtypes
  dtype: Any = jnp.bfloat16        # activation / compute dtype
  param_dtype: Any = jnp.float32   # master weights

  @property
  def hd(self) -> int:
    return self.head_dim if self.head_dim else self.d_model // self.n_heads

  @property
  def d_inner(self) -> int:        # SSD inner width
    return self.ssm_expand * self.d_model

  @property
  def ssm_heads(self) -> int:
    return self.d_inner // self.ssm_headdim

  def replace(self, **kw) -> "ModelConfig":
    return dataclasses.replace(self, **kw)


@dataclasses.dataclass(frozen=True)
class Parallelism:
  """Mesh-axis assignment for shardings (see launch/mesh.py)."""
  data_axes: tuple = ("data",)     # batch axis(es); ("pod","data") multi-pod
  model_axis: str = "model"
  tp_size: int = 16                # size of the model axis (divisibility)
  dp_size: int = 16                # total size of the data axes
  fsdp: bool = True                # ZeRO-3: stacked weights sharded over data
  seq_shard_decode: bool = True    # decode KV cache sharded over model axis
  remat: str = "none"              # none | full | dots

  @property
  def dp(self):                    # spec entry for the batch dimension
    return self.data_axes if len(self.data_axes) > 1 else self.data_axes[0]

  def dp_for(self, batch_size: int):
    """dp spec entry, or None when the batch can't shard evenly (e.g. the
    global_batch=1 long-context cells — batch stays replicated, the model
    axis still shards the long dimension)."""
    return self.dp if batch_size % self.dp_size == 0 else None

  @property
  def fsdp_axis(self):
    return self.dp if self.fsdp else None

  @property
  def tp(self):
    return self.model_axis


# ---------------------------------------------------------------------------
# primitives
# ---------------------------------------------------------------------------


# ---------------------------------------------------------------------------
# activation-sharding constraint (Megatron-style sequence parallelism):
# the launcher installs a PartitionSpec for the residual stream; every block
# body calls constrain_acts so the stream stays (data, seq→model, None)
# sharded between TP regions.  No-op when unset (tests, single device).
# ---------------------------------------------------------------------------

_ACT_SPEC: list = [None]


class activation_sharding:
  """Context manager: with activation_sharding(P('data','model',None)): ..."""

  def __init__(self, spec):
    self.spec = spec

  def __enter__(self):
    self._prev = _ACT_SPEC[0]
    _ACT_SPEC[0] = self.spec
    return self

  def __exit__(self, *a):
    _ACT_SPEC[0] = self._prev
    return False


def constrain_acts(x: Array) -> Array:
  spec = _ACT_SPEC[0]
  if spec is None or x.ndim != 3:
    return x
  return jax.lax.with_sharding_constraint(x, spec)


def act_axes():
  """(dp, tp) axis names of the installed activation spec (None when unset).
  Lets inner blocks (MoE dispatch) pin their intermediates to the batch/model
  axes — GSPMD drops batch sharding through vmapped scatters otherwise."""
  spec = _ACT_SPEC[0]
  if spec is None:
    return None, None
  dp = spec[0] if len(spec) > 0 else None
  tp = spec[1] if len(spec) > 1 else None
  return dp, tp


def constrain(x: Array, spec) -> Array:
  if _ACT_SPEC[0] is None:
    return x
  return jax.lax.with_sharding_constraint(x, spec)


def rms_norm(x: Array, scale: Array, eps: float) -> Array:
  dt = x.dtype
  x = x.astype(jnp.float32)
  x = x * jax.lax.rsqrt(jnp.mean(x * x, axis=-1, keepdims=True) + eps)
  return (x * scale.astype(jnp.float32)).astype(dt)


def layer_norm(x: Array, scale: Array, bias: Array, eps: float) -> Array:
  dt = x.dtype
  x = x.astype(jnp.float32)
  mu = jnp.mean(x, axis=-1, keepdims=True)
  var = jnp.mean(jnp.square(x - mu), axis=-1, keepdims=True)
  x = (x - mu) * jax.lax.rsqrt(var + eps)
  return (x * scale.astype(jnp.float32) + bias.astype(jnp.float32)).astype(dt)


def rope(x: Array, positions: Array, theta: float) -> Array:
  """x: (B, S, H, D) with D even; positions: (B, S) or (S,)."""
  d = x.shape[-1]
  d2 = d // 2
  freqs = 1.0 / (theta ** (np.arange(0, d2, dtype=np.float32) / d2))
  if positions.ndim == 1:
    positions = positions[None, :]
  ang = positions[..., None].astype(jnp.float32) * freqs  # (B,S,d2)
  cos = jnp.cos(ang)[:, :, None, :]
  sin = jnp.sin(ang)[:, :, None, :]
  x1, x2 = x[..., :d2].astype(jnp.float32), x[..., d2:].astype(jnp.float32)
  out = jnp.concatenate([x1 * cos - x2 * sin, x2 * cos + x1 * sin], axis=-1)
  return out.astype(x.dtype)


def dense_init(key, shape, in_axis: int = -2, dtype=jnp.float32) -> Array:
  fan_in = shape[in_axis]
  return (jax.random.normal(key, shape) / np.sqrt(fan_in)).astype(dtype)


def split_keys(key, n):
  return list(jax.random.split(key, n))


# ---------------------------------------------------------------------------
# sharding-spec construction
# ---------------------------------------------------------------------------


def spec_for(path: str, shape: Sequence[int], cfg: ModelConfig,
             par: Parallelism) -> P:
  """PartitionSpec for one parameter, keyed by its tree path.

  Conventions (leading dim is the stacked layer dim for scanned blocks):
    embeddings (V, D)            → (tp, None)            vocab-sharded
    *_norm  (..., D)             → replicated
    attn q/o projections         → TP on the head dim, fsdp on d_model
    attn k/v                     → TP on the kv-head dim iff divisible
    mlp w1/w3 (L, D, F)          → (None, fsdp, tp)
    mlp w2 (L, F, D)             → (None, tp, fsdp)
    moe experts (L, E, D, F)     → TP on F (expert width), fsdp on D
    ssd in/out projections       → TP on the inner dim
  """
  tp, fs = par.tp, par.fsdp_axis
  nd = len(shape)

  if "embed" in path or path.endswith("lm_head"):
    return P(tp, None) if nd == 2 else P(None)
  if "norm" in path or path.endswith(("scale", "bias", "dt_bias", "A_log",
                                      "D")):
    return P(*([None] * nd))
  if any(s in path for s in ("wq", "wo")):
    # stacked (L, D, H, hd) / (L, H, hd, D); shared (D, H, hd) / (H, hd, D)
    if nd == 4:
      return P(None, fs, tp, None) if "wq" in path else P(None, tp, None, fs)
    if nd == 3:
      return P(fs, tp, None) if "wq" in path else P(tp, None, fs)
    return P(fs, tp) if "wq" in path else P(tp, fs)
  if any(s in path for s in ("wk", "wv")):
    # Megatron GQA rule: TP-shard kv heads only when divisible, else
    # replicate the (small) kv projections across the model axis.
    kv_tp = tp if cfg.n_kv_heads % max(par.tp_size, 1) == 0 else None
    if nd == 4:
      return P(None, fs, kv_tp, None)
    if nd == 3:
      return P(fs, kv_tp, None)
    return P(fs, kv_tp)
  if "experts" in path:
    # (L, E, D, F) or (L, E, F, D)
    if path.endswith("w2"):
      return P(None, None, tp, fs)
    return P(None, None, fs, tp)
  if "router" in path:
    return P(None, fs, None)
  if any(s in path for s in ("w1", "w3", "in_proj", "up")):
    return P(*([None] * (nd - 2)), fs, tp)
  if any(s in path for s in ("w2", "out_proj", "down")):
    return P(*([None] * (nd - 2)), tp, fs)
  if "conv" in path:
    return P(*([None] * (nd - 1)), tp)
  return P(*([None] * nd))


def tree_paths(tree, prefix=""):
  out = {}
  for k, v in tree.items():
    p = f"{prefix}/{k}" if prefix else k
    if isinstance(v, dict):
      out.update(tree_paths(v, p))
    else:
      out[p] = v
  return out


def specs_like(params, cfg: ModelConfig, par: Parallelism):
  """Pytree of PartitionSpec matching ``params``."""
  def walk(tree, prefix=""):
    out = {}
    for k, v in tree.items():
      p = f"{prefix}/{k}" if prefix else k
      if isinstance(v, dict):
        out[k] = walk(v, p)
      else:
        out[k] = spec_for(p, v.shape, cfg, par)
    return out
  return walk(params)

"""Zamba2-style hybrid: a stack of Mamba2 (SSD) blocks with one *shared*
attention+MLP block applied every ``hybrid_attn_every`` SSM blocks
(arXiv:2411.15242 — the shared block amortizes attention params over depth).

Scan layout: the L SSM blocks are split into ⌈L/k⌉ segments; each segment is
an inner scan over its stacked params, followed by the shared block (whose
params are closed over — one copy, every application).  HLO stays O(1) in
depth; the remainder segment (L mod k) is scanned separately.
"""
from __future__ import annotations

from typing import Optional

import jax
import jax.numpy as jnp

from repro.models import attention as attn_mod
from repro.models import common as cm
from repro.models import mlp as mlp_mod
from repro.models import ssm as ssm_mod
from repro.models.transformer import logits_from, padded_vocab

Array = jax.Array


def init_hybrid_params(key, cfg: cm.ModelConfig):
  ks = cm.split_keys(key, 8)
  L = cfg.n_layers
  vp = padded_vocab(cfg)
  return {
      "embed": (jax.random.normal(ks[0], (vp, cfg.d_model)) * 0.02).astype(
          cfg.param_dtype),
      "final_norm_scale": jnp.ones((cfg.d_model,), cfg.param_dtype),
      "blocks": {
          "ln_norm_scale": jnp.ones((L, cfg.d_model), cfg.param_dtype),
          "ssm": ssm_mod.ssm_params(ks[1], cfg, L),
      },
      "shared": {
          "ln1_norm_scale": jnp.ones((cfg.d_model,), cfg.param_dtype),
          "ln2_norm_scale": jnp.ones((cfg.d_model,), cfg.param_dtype),
          "attn": attn_mod.attn_params(ks[2], cfg, None),
          "mlp": mlp_mod.mlp_params(ks[3], cfg, None),
      },
      "lm_head": (jax.random.normal(ks[4], (vp, cfg.d_model)) * 0.02).astype(
          cfg.param_dtype),
  }


def _shared_block(sp, cfg, x, positions, *, mode, layer_cache, cache_len,
                  impl):
  """The shared attention block; its KV cache is per-application (stacked on
  a leading 'application' axis in the cache pytree, scanned with the group)."""
  h = cm.rms_norm(x, sp["ln1_norm_scale"], cfg.norm_eps)
  a, kv = attn_mod.attention(sp["attn"], cfg, h, positions, mode=mode,
                             layer_cache=layer_cache, cache_len=cache_len,
                             impl=impl)
  x = x + a
  h = cm.rms_norm(x, sp["ln2_norm_scale"], cfg.norm_eps)
  return x + mlp_mod.mlp(sp["mlp"], cfg, h), kv


def forward_hybrid(p, cfg: cm.ModelConfig, tokens: Array,
                   positions: Optional[Array] = None, *, mode: str = "train",
                   cache=None, impl: str = "xla", remat: str = "none"):
  """cache (prefill/decode): {'ssm': stacked ssm states (L,…),
  'attn': {'k','v': (n_apps, B, Smax, KV, hd)}, 'len': ()}.

  Returns (logits, new_cache_or_None, aux(=0))."""
  x = jnp.take(p["embed"], tokens, axis=0).astype(cfg.dtype)
  b, s = x.shape[:2]
  every = cfg.hybrid_attn_every or cfg.n_layers + 1
  L = cfg.n_layers
  n_apps = L // every
  cache_len = cache["len"] if cache is not None else None
  if positions is None:
    base = cache_len if mode == "decode" else 0
    positions = base + jnp.arange(s)[None, :] + jnp.zeros((b, 1), jnp.int32)

  ssm_state = cache["ssm"] if cache is not None else None
  main = n_apps * every

  def ssm_body(carry, xs):
    x = carry
    lp, st = xs
    x = cm.constrain_acts(x)
    h = cm.rms_norm(x, lp["ln_norm_scale"], cfg.norm_eps)
    y, new_st = ssm_mod.ssm_block(lp["ssm"], cfg, h, mode=mode, state=st)
    return x + y, new_st

  # --- main body: ONE scan over ⌈L/k⌉ groups, each = inner scan over k SSM
  # blocks + the shared attention block.  Scanning the shared block (params
  # closed over) makes XLA accumulate its gradient in a single carried
  # buffer instead of materializing one full fp32 partial per application
  # (13× memory on zamba2 otherwise), and keeps HLO size O(1) in n_apps.
  def regroup(t):
    return t[:main].reshape(n_apps, every, *t.shape[1:])

  blocks_main = jax.tree.map(regroup, p["blocks"])
  blocks_tail = jax.tree.map(lambda t: t[main:], p["blocks"])
  st_main = (jax.tree.map(regroup, ssm_state)
             if ssm_state is not None else None)
  st_tail = (jax.tree.map(lambda t: t[main:], ssm_state)
             if ssm_state is not None else None)
  attn_cache = cache["attn"] if cache is not None else None

  def group_body(x, xs):
    grp, grp_state, app_cache = xs
    x, new_st = jax.lax.scan(ssm_body, x, (grp, grp_state))
    x, kv = _shared_block(p["shared"], cfg, x, positions, mode=mode,
                          layer_cache=app_cache, cache_len=cache_len,
                          impl=impl)
    return x, (new_st, kv)

  if remat == "full":
    group_body = jax.checkpoint(group_body)
    ssm_tail_body = jax.checkpoint(ssm_body)
  else:
    ssm_tail_body = ssm_body

  if mode == "decode" and attn_cache is not None:
    # decode: python loop + static-index in-place cache writes — the scanned
    # form would carry the whole attention cache through ys and double its
    # footprint (input xs + fresh output buffer live simultaneously).
    main_states_l, new_attn = [], attn_cache
    for app in range(n_apps):
      grp = jax.tree.map(lambda t, a=app: t[a], blocks_main)
      st = jax.tree.map(lambda t, a=app: t[a], st_main)
      x, new_st = jax.lax.scan(ssm_body, x, (grp, st))
      lc = {"k": new_attn["k"][app], "v": new_attn["v"][app]}
      x, kv = _shared_block(p["shared"], cfg, x, positions, mode=mode,
                            layer_cache=lc, cache_len=cache_len, impl=impl)
      new_attn = {
          "k": new_attn["k"].at[app].set(kv["k"].astype(new_attn["k"].dtype)),
          "v": new_attn["v"].at[app].set(kv["v"].astype(new_attn["v"].dtype)),
      }
      main_states_l.append(jax.tree.map(lambda t: t[None], new_st))
    main_states = jax.tree.map(lambda *xs: jnp.concatenate(xs, 0),
                               *main_states_l)
    attn_kvs = new_attn
  else:
    x, (main_states, attn_kvs) = jax.lax.scan(
        group_body, x, (blocks_main, st_main, attn_cache))
  tail_states = None
  if main < L:
    x, tail_states = jax.lax.scan(ssm_tail_body, x,
                                  (blocks_tail, st_tail))

  if mode == "prefill":
    x = x[:, -1:]
  x = cm.rms_norm(x, p["final_norm_scale"], cfg.norm_eps)
  logits = logits_from(p, cfg, x)

  new_cache = None
  if mode in ("prefill", "decode"):
    def degroup(t):
      return t.reshape(n_apps * every, *t.shape[2:])
    ssm_new = jax.tree.map(degroup, main_states)
    if tail_states is not None:
      ssm_new = jax.tree.map(lambda a, b: jnp.concatenate([a, b], axis=0),
                             ssm_new, tail_states)
    new_len = (jnp.asarray(s, jnp.int32) if mode == "prefill"
               else cache_len + 1)
    new_cache = {"ssm": ssm_new, "attn": attn_kvs, "len": new_len}
  return logits, new_cache, jnp.zeros((), jnp.float32)


def init_hybrid_cache(cfg: cm.ModelConfig, batch: int, max_len: int):
  every = cfg.hybrid_attn_every or cfg.n_layers + 1
  n_apps = cfg.n_layers // every
  ssm = ssm_mod.init_ssm_state(cfg, cfg.n_layers, batch)
  kv, hd = cfg.n_kv_heads, cfg.hd
  return {
      "ssm": ssm,
      "attn": {
          "k": jnp.zeros((n_apps, batch, max_len, kv, hd), cfg.dtype),
          "v": jnp.zeros((n_apps, batch, max_len, kv, hd), cfg.dtype),
      },
      "len": jnp.zeros((), jnp.int32),
  }

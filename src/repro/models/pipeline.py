"""Pipeline parallelism (GPipe schedule) via shard_map + collective_permute.

Completes the parallelism matrix (DP/TP/SP/expert-TP/FSDP + **PP**): the
layer stack is split into S stages sharded over a ``stage`` mesh axis; M
microbatches flow through the ring with one `ppermute` per tick
(T = M + S − 1 ticks; bubble fraction (S−1)/T).  Autodiff works through the
schedule (the transpose of ppermute is the reverse ppermute), so the same
function serves forward and training.

This composes with the other axes — e.g. mesh ("stage", "data", "model") —
because the stage axis only appears in the stacked-layer leading dim and the
activation ring.  Used standalone by tests/test_pipeline.py and available to
the launcher for depth-dominated models where TP×FSDP hits its collective
knee (a 1000+-node scaling option recorded in DESIGN.md)."""
from __future__ import annotations

import functools
from typing import Callable

import jax
import jax.numpy as jnp
from jax.sharding import Mesh, PartitionSpec as P

if hasattr(jax, "shard_map"):
  shard_map = jax.shard_map
else:  # pragma: no cover — older jax keeps it under experimental
  from jax.experimental.shard_map import shard_map

# jax.lax.pvary only exists on newer jax (varying-axis annotations for
# shard_map rep-checking); older versions don't need the annotation.
pvary = getattr(jax.lax, "pvary", lambda x, axes: x)

Array = jax.Array


def split_stages(stacked_params, n_stages: int):
  """(L, …) stacked layer params → (S, L/S, …)."""
  def re(t):
    l = t.shape[0]
    assert l % n_stages == 0, (l, n_stages)
    return t.reshape(n_stages, l // n_stages, *t.shape[1:])
  return jax.tree.map(re, stacked_params)


def pipeline(stage_fn: Callable, mesh: Mesh, *, axis: str = "stage",
             in_spec: P = None, x_spec: P = None):
  """Build pipelined_apply(stage_params, x_micro) → y_micro.

  stage_fn(params_one_stage, x) → y   (same shape; e.g. a scan over the
  stage's layer slice).  stage_params: (S, L/S, …) sharded on ``axis``;
  x_micro: (M, mb, …) replicated along ``axis`` (sharding over other axes is
  free to compose).
  """
  n_stage = mesh.shape[axis]
  perm = [(i, i + 1) for i in range(n_stage - 1)]

  def spmd(params_local, xs):
    # params_local: (1, L/S, …) — this stage's slice; xs: (M, mb, …)
    params_local = jax.tree.map(lambda t: t[0], params_local)
    sid = jax.lax.axis_index(axis)
    m = xs.shape[0]
    t_total = m + n_stage - 1
    zero = jnp.zeros_like(xs[0])
    outs0 = pvary(jnp.zeros_like(xs), (axis,))
    buf0 = pvary(zero, (axis,))

    def tick(t, carry):
      buf, outs = carry
      # stage 0 injects microbatch t (clamped; masked out when t ≥ M)
      inject = xs[jnp.clip(t, 0, m - 1)]
      inject = jnp.where(t < m, inject, jnp.zeros_like(inject))
      cur = jnp.where(sid == 0, inject, buf)
      y = stage_fn(params_local, cur)
      # last stage emits microbatch t-(S-1)
      oidx = t - (n_stage - 1)
      valid = (sid == n_stage - 1) & (oidx >= 0)
      safe = jnp.clip(oidx, 0, m - 1)
      upd = jnp.where(valid, y, outs[safe])
      outs = outs.at[safe].set(upd)
      buf_next = jax.lax.ppermute(y, axis, perm)
      return buf_next, outs

    _, outs = jax.lax.fori_loop(0, t_total, tick, (buf0, outs0))
    # outputs live on the last stage (zeros elsewhere) → ⊕-collect
    return jax.lax.psum(outs, axis)

  in_spec = in_spec if in_spec is not None else P(axis)
  x_spec = x_spec if x_spec is not None else P()
  return shard_map(spmd, mesh=mesh, in_specs=(in_spec, x_spec),
                   out_specs=x_spec)


def bubble_fraction(n_stages: int, n_micro: int) -> float:
  return (n_stages - 1) / (n_micro + n_stages - 1)

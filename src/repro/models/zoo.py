"""Unified model API: one (init, forward, init_cache) triple per family.

    params = zoo.init(cfg, rng)
    logits, cache, aux = zoo.forward(params, cfg, batch, mode=..., ...)

``batch`` is a dict: {'tokens': (B,S) int32} for LMs, plus
{'src_embeds': (B,S_src,D)} for enc-dec / modality-stub archs.
"""
from __future__ import annotations

from typing import Optional

import jax
import jax.numpy as jnp

from repro.models import attention as attn_mod
from repro.models import common as cm
from repro.models import encdec as encdec_mod
from repro.models import hybrid as hybrid_mod
from repro.models import ssm as ssm_mod
from repro.models import transformer as tf_mod

Array = jax.Array

_TF_FAMILIES = ("dense", "moe", "vlm")


def init(cfg: cm.ModelConfig, key) -> dict:
  if cfg.family in _TF_FAMILIES:
    return tf_mod.init_lm_params(key, cfg)
  if cfg.family == "ssm":
    return _init_ssm_lm(key, cfg)
  if cfg.family == "hybrid":
    return hybrid_mod.init_hybrid_params(key, cfg)
  if cfg.family == "encdec":
    return encdec_mod.init_encdec_params(key, cfg)
  raise ValueError(cfg.family)


def _init_ssm_lm(key, cfg: cm.ModelConfig) -> dict:
  ks = cm.split_keys(key, 4)
  vp = tf_mod.padded_vocab(cfg)
  return {
      "embed": (jax.random.normal(ks[0], (vp, cfg.d_model)) * 0.02).astype(
          cfg.param_dtype),
      "final_norm_scale": jnp.ones((cfg.d_model,), cfg.param_dtype),
      "blocks": {
          "ln_norm_scale": jnp.ones((cfg.n_layers, cfg.d_model),
                                    cfg.param_dtype),
          "ssm": ssm_mod.ssm_params(ks[1], cfg, cfg.n_layers),
      },
      "lm_head": (jax.random.normal(ks[2], (vp, cfg.d_model)) * 0.02).astype(
          cfg.param_dtype),
  }


def _forward_ssm_lm(p, cfg, tokens, *, mode="train", cache=None,
                    remat="none"):
  x = jnp.take(p["embed"], tokens, axis=0).astype(cfg.dtype)
  state = cache["ssm"] if cache is not None else None

  def body(x, xs):
    lp, st = xs
    x = cm.constrain_acts(x)
    h = cm.rms_norm(x, lp["ln_norm_scale"], cfg.norm_eps)
    y, new_st = ssm_mod.ssm_block(lp["ssm"], cfg, h, mode=mode, state=st)
    return x + y, new_st

  if remat == "full":
    body = jax.checkpoint(body)
  x, new_states = jax.lax.scan(body, x, (p["blocks"], state))
  if mode == "prefill":
    x = x[:, -1:]
  x = cm.rms_norm(x, p["final_norm_scale"], cfg.norm_eps)
  logits = tf_mod.logits_from(p, cfg, x)
  new_cache = None
  if mode in ("prefill", "decode"):
    s = tokens.shape[1]
    new_len = (jnp.asarray(s, jnp.int32) if mode == "prefill"
               else cache["len"] + 1)
    new_cache = {"ssm": new_states, "len": new_len}
  return logits, new_cache, jnp.zeros((), jnp.float32)


def forward(p, cfg: cm.ModelConfig, batch: dict, *, mode: str = "train",
            cache=None, enc_out=None, impl: str = "xla",
            remat: str = "none"):
  """Returns (logits, new_cache_or_None, aux_loss)."""
  if cfg.family in _TF_FAMILIES:
    inputs = batch.get("src_embeds", batch.get("tokens"))
    return tf_mod.forward_lm(p, cfg, inputs, mode=mode, cache=cache,
                             impl=impl, remat=remat)
  if cfg.family == "ssm":
    return _forward_ssm_lm(p, cfg, batch["tokens"], mode=mode, cache=cache,
                           remat=remat)
  if cfg.family == "hybrid":
    return hybrid_mod.forward_hybrid(p, cfg, batch["tokens"], mode=mode,
                                     cache=cache, impl=impl, remat=remat)
  if cfg.family == "encdec":
    # decode passes precomputed enc_out (in batch or kwarg) — no src needed
    enc_out = batch.get("enc_out", enc_out)
    return encdec_mod.forward_encdec(p, cfg, batch.get("src_embeds"),
                                     batch["tokens"], mode=mode, cache=cache,
                                     enc_out=enc_out, impl=impl, remat=remat)
  raise ValueError(cfg.family)


def init_cache(cfg: cm.ModelConfig, batch: int, max_len: int):
  if cfg.family in _TF_FAMILIES or cfg.family == "encdec":
    n_layers = cfg.dec_layers if cfg.family == "encdec" else cfg.n_layers
    return attn_mod.init_cache(cfg, n_layers, batch, max_len)
  if cfg.family == "ssm":
    st = ssm_mod.init_ssm_state(cfg, cfg.n_layers, batch)
    return {"ssm": st, "len": jnp.zeros((), jnp.int32)}
  if cfg.family == "hybrid":
    return hybrid_mod.init_hybrid_cache(cfg, batch, max_len)
  raise ValueError(cfg.family)


def param_count(params) -> int:
  return sum(int(jnp.size(x)) for x in jax.tree.leaves(params))

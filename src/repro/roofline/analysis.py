"""Three-term roofline derivation from a compiled dry-run cell.

    compute    = HLO_FLOPs / (chips · 197e12)
    memory     = HLO_bytes / (chips · 819e9)
    collective = collective_bytes_per_device / (ICI links · 50e9)

HLO_FLOPs/bytes come from ``compiled.cost_analysis()``; collective bytes are
the ring-model per-device traffic from ``collectives.collective_bytes``
(already per-device, so no further division by chips).  MODEL_FLOPS uses the
6·N·D (train) / 2·N·D (decode-token) convention with N = active params.
"""
from __future__ import annotations

import dataclasses
import math
from typing import Optional

from repro.roofline import hw


@dataclasses.dataclass
class Roofline:
  arch: str
  shape: str
  mesh: str
  chips: int
  hlo_flops: float
  hlo_bytes: float
  coll_bytes: float          # per device
  coll_breakdown: dict
  model_flops: float
  peak_memory_per_dev: Optional[float] = None

  @property
  def t_compute(self) -> float:
    return self.hlo_flops / (self.chips * hw.PEAK_FLOPS_BF16)

  @property
  def t_memory(self) -> float:
    return self.hlo_bytes / (self.chips * hw.HBM_BW)

  @property
  def t_collective(self) -> float:
    return self.coll_bytes / (hw.ICI_LINKS * hw.ICI_BW_PER_LINK)

  @property
  def bottleneck(self) -> str:
    terms = {"compute": self.t_compute, "memory": self.t_memory,
             "collective": self.t_collective}
    return max(terms, key=terms.get)

  @property
  def t_bound(self) -> float:
    return max(self.t_compute, self.t_memory, self.t_collective)

  @property
  def useful_ratio(self) -> float:
    """MODEL_FLOPS / HLO_FLOPs — how much compiled compute is 'useful'."""
    return self.model_flops / self.hlo_flops if self.hlo_flops else 0.0

  @property
  def mfu_bound(self) -> float:
    """Roofline-implied MFU upper bound: useful FLOPs per chip-second at the
    bound time vs peak."""
    if self.t_bound == 0:
      return 0.0
    return (self.model_flops / (self.chips * self.t_bound)) / \
        hw.PEAK_FLOPS_BF16

  def row(self) -> dict:
    return {
        "arch": self.arch, "shape": self.shape, "mesh": self.mesh,
        "chips": self.chips,
        "hlo_flops": self.hlo_flops, "hlo_bytes": self.hlo_bytes,
        "coll_bytes_per_dev": self.coll_bytes,
        "t_compute_s": self.t_compute, "t_memory_s": self.t_memory,
        "t_collective_s": self.t_collective,
        "bottleneck": self.bottleneck,
        "model_flops": self.model_flops,
        "useful_ratio": self.useful_ratio,
        "mfu_bound": self.mfu_bound,
        "peak_mem_per_dev": self.peak_memory_per_dev,
        "coll_breakdown": self.coll_breakdown,
    }


def model_flops_estimate(n_params_active: float, shape_kind: str,
                         tokens: float) -> float:
  """6·N·D for a train step; 2·N per generated token for decode; 2·N·D for
  prefill (forward only)."""
  if shape_kind == "train":
    return 6.0 * n_params_active * tokens
  return 2.0 * n_params_active * tokens

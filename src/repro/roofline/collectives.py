"""Collective-bytes extraction from post-SPMD optimized HLO.

``cost_analysis()`` does not report collective traffic, so we parse
``compiled.as_text()``: every all-gather / all-reduce / reduce-scatter /
all-to-all / collective-permute op's tensor bytes are accumulated under a
ring-model per-device traffic estimate:

    all-reduce        2·(n−1)/n · bytes     (reduce-scatter + all-gather)
    all-gather        (n−1)/n · out_bytes
    reduce-scatter    (n−1)/n · in_bytes
    all-to-all        (n−1)/n · bytes
    collective-permute  bytes               (single hop)

where n = replica-group size parsed from the op.  Shapes like
``bf16[16,4096,128]`` are parsed for element counts; tuple shapes sum.
"""
from __future__ import annotations

import re
from collections import defaultdict

_DTYPE_BYTES = {
    "pred": 1, "s8": 1, "u8": 1, "s16": 2, "u16": 2, "bf16": 2, "f16": 2,
    "s32": 4, "u32": 4, "f32": 4, "s64": 8, "u64": 8, "f64": 8, "c64": 8,
    "token": 0, "s4": 1, "u4": 1, "f8e4m3fn": 1, "f8e5m2": 1,
}

_SHAPE_RE = re.compile(r"(\w+)\[([\d,]*)\]")
_GROUPS_RE = re.compile(r"replica_groups=\{?\{([^}]*)\}")
_GROUPS_DIMS_RE = re.compile(r"replica_groups=\[(\d+),(\d+)\]")

_COLL_KINDS = ("all-reduce", "all-gather", "reduce-scatter", "all-to-all",
               "collective-permute")


def ring_traffic_bytes(kind: str, nbytes: float, group_size: int) -> float:
  """Per-device ring-model traffic for one collective moving ``nbytes``.

  The single source of the formulas in the module docstring — shared by the
  HLO walker below and the tuning layer's sharded roofline prior
  (tuning.cost_table.sharded_prior_seconds), so the measured-HLO and analytic
  collective models cannot drift apart.
  """
  n = max(group_size, 1)
  if kind == "all-reduce":
    return 2.0 * (n - 1) / n * nbytes
  if kind in ("all-gather", "reduce-scatter", "all-to-all"):
    return (n - 1) / n * nbytes
  if kind == "collective-permute":
    return float(nbytes)
  raise ValueError(f"unknown collective kind {kind!r}; one of {_COLL_KINDS}")


def _shape_bytes(text: str) -> int:
  """Sum tensor bytes over every dtype[shape] group in a type string."""
  total = 0
  for dt, dims in _SHAPE_RE.findall(text):
    if dt not in _DTYPE_BYTES:
      continue
    n = 1
    if dims:
      for d in dims.split(","):
        if d:
          n *= int(d)
    total += n * _DTYPE_BYTES[dt]
  return total


def _group_size(line: str) -> int:
  m = _GROUPS_DIMS_RE.search(line)
  if m:  # iota form [ngroups,group_size]
    return int(m.group(2))
  m = _GROUPS_RE.search(line)
  if m:
    return len([x for x in m.group(1).split(",") if x.strip() != ""])
  return 2


def collective_bytes(hlo_text: str) -> dict:
  """Returns {kind: per_device_bytes} + {'total': ...} (ring model)."""
  out = defaultdict(float)
  for line in hlo_text.splitlines():
    s = line.lstrip()
    # match "  %x = TYPE all-gather(...)" / "x = TYPE all-reduce-start(..."
    m = re.match(r"%?[\w\.\-]+\s*=\s*(\S+)\s+([a-z\-]+)", s)
    if not m:
      continue
    optype = m.group(2)
    kind = next((k for k in _COLL_KINDS if optype.startswith(k)), None)
    if kind is None or optype.endswith("-done"):
      continue
    ty = m.group(1)
    out[kind] += ring_traffic_bytes(kind, _shape_bytes(ty), _group_size(line))
    out[f"count:{kind}"] += 1
  out["total"] = sum(v for k, v in out.items()
                     if not k.startswith("count:") and k != "total")
  return dict(out)

"""Loop-corrected cost extraction from post-SPMD optimized HLO text.

XLA's ``compiled.cost_analysis()`` counts a ``while`` body **once**, so for
scan-over-layers programs it under-reports FLOPs/bytes/collectives by the
trip count (×L layers, ×KV chunks, ×grad-accum).  This walker parses the HLO
module into computations and recursively multiplies per-computation costs by
the loop trip counts XLA records in ``backend_config={"known_trip_count":
{"n":"L"}}``.

Per computation it accumulates:
  * ``flops``      — dot ops: 2 · |output| · contraction_size (dots dominate
                     transformer compute; elementwise flops are ignored and
                     the method is recorded in EXPERIMENTS.md),
  * ``bytes``      — per-op HBM traffic: operand + output tensor bytes of
                     top-level (post-fusion) ops in a traffic allowlist —
                     fusion internals are on-chip by construction,
  * ``coll_bytes`` — ring-model collective traffic (see collectives.py).

Validated against unrolled-scan programs (tests/test_roofline.py): the
walker and XLA agree when no loops are present, and the walker alone is
consistent across rolled/unrolled variants.
"""
from __future__ import annotations

import dataclasses
import json
import re
from collections import defaultdict
from typing import Optional

from repro.roofline.collectives import (_COLL_KINDS, _DTYPE_BYTES, _SHAPE_RE,
                                        _group_size, _shape_bytes)

# ops whose operand/output tensors move through HBM (post-fusion HLO)
_TRAFFIC_OPS = {
    "fusion", "dot", "copy", "convert", "reduce", "gather", "scatter",
    "dynamic-slice", "dynamic-update-slice", "slice", "concatenate", "pad",
    "broadcast", "iota", "transpose", "reverse", "sort", "select-and-scatter",
    "reduce-window", "rng", "exponential", "log", "cholesky",
    "triangular-solve", "convolution", "rng-bit-generator", "compare",
    "add", "multiply", "subtract", "divide", "maximum", "minimum", "select",
    "tanh", "negate", "abs", "rsqrt", "sqrt", "power",
}

_OP_RE = re.compile(
    r"^\s*(?:ROOT\s+)?%?([\w\.\-]+)\s*=\s*(\([^)]*\)|\S+)\s+([\w\-]+)"
    r"(?:\.\d+)?\(([^)]*)\)(.*)$")
_COMP_RE = re.compile(r"^(?:ENTRY\s+)?%?([\w\.\-]+)\s*\(.*\)\s*->.*\{")
_TRIP_RE = re.compile(r'"known_trip_count"\s*:\s*\{"n"\s*:\s*"(\d+)"')
_CALLS_RE = re.compile(r"calls=%?([\w\.\-]+)")
_BODY_RE = re.compile(r"body=%?([\w\.\-]+)")
_COND_RE = re.compile(r"condition=%?([\w\.\-]+)")
_BRANCHES_RE = re.compile(r"branch_computations=\{([^}]*)\}")
_CONTRACT_RE = re.compile(r"lhs_contracting_dims=\{([\d,]*)\}")


@dataclasses.dataclass
class Cost:
  flops: float = 0.0
  bytes: float = 0.0
  coll_bytes: float = 0.0
  coll_breakdown: dict = dataclasses.field(default_factory=dict)

  def add(self, other: "Cost", mult: float = 1.0):
    self.flops += other.flops * mult
    self.bytes += other.bytes * mult
    self.coll_bytes += other.coll_bytes * mult
    for k, v in other.coll_breakdown.items():
      self.coll_breakdown[k] = self.coll_breakdown.get(k, 0.0) + v * mult


@dataclasses.dataclass
class _Op:
  name: str
  out_type: str
  kind: str
  operands: list
  tail: str


class HloModule:
  def __init__(self, text: str):
    self.comps: dict[str, list[_Op]] = {}
    self._parse(text)
    self._memo: dict[str, Cost] = {}

  def _parse(self, text: str):
    cur = None
    for raw in text.splitlines():
      line = raw.rstrip()
      s = line.strip()
      if not s or s.startswith("//"):
        continue
      mc = _COMP_RE.match(s)
      if mc and "=" not in s.split("(")[0]:
        cur = mc.group(1)
        self.comps[cur] = []
        continue
      if s == "}" or cur is None:
        continue
      mo = _OP_RE.match(line)
      if not mo:
        continue
      name, out_type, kind, operand_str, tail = mo.groups()
      # Operands print either bare (`dot(%a, %b)`) or typed
      # (`dot(f32[8,8]{1,0} %a, …)`) depending on the XLA version; pull the
      # %names out directly so both forms parse.
      operands = re.findall(r"%([\w\.\-]+)", operand_str)
      self.comps[cur].append(_Op(name, out_type, kind, operands, tail))

  # -- per-op costing --------------------------------------------------------

  def _dot_flops(self, op: _Op, types: dict) -> float:
    out_b = _shape_elems(op.out_type)
    lhs_type = types.get(op.operands[0]) if op.operands else None
    if lhs_type is None:
      return 0.0
    m = _CONTRACT_RE.search(op.tail)
    contract = 1
    lhs_dims = _shape_dims(lhs_type)
    if m and lhs_dims:
      for d in m.group(1).split(","):
        if d:
          contract *= lhs_dims[int(d)]
    return 2.0 * out_b * contract

  def comp_cost(self, name: str) -> Cost:
    if name in self._memo:
      return self._memo[name]
    c = Cost()
    types: dict[str, str] = {}
    for op in self.comps.get(name, []):
      types[op.name] = op.out_type
    for op in self.comps.get(name, []):
      kind = op.kind
      if kind == "while":
        trip = 1
        mt = _TRIP_RE.search(op.tail)
        if mt:
          trip = int(mt.group(1))
        mb = _BODY_RE.search(op.tail)
        if mb:
          c.add(self.comp_cost(mb.group(1)), trip)
        continue
      if kind == "conditional":
        mbr = _BRANCHES_RE.search(op.tail)
        if mbr:
          names = [x.strip().lstrip("%") for x in mbr.group(1).split(",")]
          for n in names:
            c.add(self.comp_cost(n), 1.0 / max(1, len(names)))
        continue
      if kind in ("call", "async-start"):
        mc2 = _CALLS_RE.search(op.tail)
        if mc2:
          c.add(self.comp_cost(mc2.group(1)))
        continue

      coll = next((k for k in _COLL_KINDS if kind.startswith(k)), None)
      if coll is not None and not kind.endswith("-done"):
        n = _group_size(op.tail)
        b = _shape_bytes(op.out_type)
        if coll == "all-reduce":
          traffic = 2.0 * (n - 1) / max(n, 1) * b
        elif coll == "collective-permute":
          traffic = float(b)
        else:
          traffic = (n - 1) / max(n, 1) * b
        c.coll_bytes += traffic
        c.coll_breakdown[coll] = c.coll_breakdown.get(coll, 0.0) + traffic
        c.bytes += 2.0 * b  # read + write through HBM
        continue

      if kind.startswith("dot"):
        c.flops += self._dot_flops(op, types)
        c.bytes += _shape_bytes(op.out_type) + sum(
            _shape_bytes(types.get(o, "")) for o in op.operands)
        continue

      if kind == "fusion":
        # fused dots still execute — descend for flops; bytes use
        # slice-aware effective reads (a fused dynamic-slice of a stacked
        # weight reads one layer, not the whole stack).
        mf = _CALLS_RE.search(op.tail)
        if mf:
          sub = self.comp_cost(mf.group(1))
          c.flops += sub.flops
          c.bytes += self._fusion_bytes(op, mf.group(1), types)
        else:
          c.bytes += _shape_bytes(op.out_type) + sum(
              _shape_bytes(types.get(o, "")) for o in op.operands)
        continue

      if kind in ("dynamic-slice", "slice", "gather"):
        c.bytes += 2.0 * _shape_bytes(op.out_type)  # read slice + write
        continue
      if kind == "dynamic-update-slice":
        upd = types.get(op.operands[1], "") if len(op.operands) > 1 else ""
        c.bytes += 2.0 * _shape_bytes(upd)  # read update + write slice region
        continue

      if kind in _TRAFFIC_OPS:
        c.bytes += _shape_bytes(op.out_type) + sum(
            _shape_bytes(types.get(o, "")) for o in op.operands)

    self._memo[name] = c
    return c

  def _fusion_bytes(self, op: _Op, callee: str, caller_types: dict) -> float:
    """HBM traffic of one fusion: slice-aware reads + alias-aware writes."""
    body = self.comps.get(callee, [])
    # parameter name → full bytes (from its declaration inside the callee)
    param_full: dict[str, float] = {}
    for fop in body:
      if fop.kind == "parameter":
        param_full[fop.name] = _shape_bytes(fop.out_type)
    reads: dict[str, float] = {k: 0.0 for k in param_full}
    root = body[-1] if body else None
    dus_alias_param = None
    if root is not None and root.kind == "dynamic-update-slice":
      # in-place cache update: the pass-through buffer is aliased, the write
      # is only the update region
      if root.operands and root.operands[0] in param_full:
        dus_alias_param = root.operands[0]
    for fop in body:
      if fop.kind == "parameter":
        continue
      for o in fop.operands:
        if o not in param_full:
          continue
        if fop.kind in ("dynamic-slice", "slice", "gather"):
          reads[o] += _shape_bytes(fop.out_type)
        elif fop.kind == "dynamic-update-slice" and o == fop.operands[0]:
          continue  # aliased pass-through, not a read
        else:
          reads[o] += param_full[o]
    total_read = sum(min(param_full[k], reads[k]) for k in param_full
                     if k != dus_alias_param)
    if dus_alias_param is not None:
      total_read += min(param_full[dus_alias_param],
                        reads[dus_alias_param])
      upd_bytes = 0.0
      if root is not None and len(root.operands) > 1:
        # update operand may be a param or an internal op — look in both
        upd_name = root.operands[1]
        upd_bytes = param_full.get(upd_name, 0.0)
        if not upd_bytes:
          for fop in body:
            if fop.name == upd_name:
              upd_bytes = _shape_bytes(fop.out_type)
              break
      write = upd_bytes
    else:
      write = _shape_bytes(op.out_type)
    return total_read + write

  def entry_cost(self) -> Cost:
    # entry is the computation named main* or the last parsed
    entry = None
    for n in self.comps:
      if n.startswith("main"):
        entry = n
    if entry is None:
      entry = list(self.comps)[-1]
    return self.comp_cost(entry)


def _shape_dims(t: str):
  m = _SHAPE_RE.search(t or "")
  if not m:
    return []
  dims = m.group(2)
  return [int(d) for d in dims.split(",") if d] if dims else []


def _shape_elems(t: str) -> float:
  total = 0
  for dt, dims in _SHAPE_RE.findall(t or ""):
    if dt not in _DTYPE_BYTES:
      continue
    n = 1
    if dims:
      for d in dims.split(","):
        if d:
          n *= int(d)
    total += n
  return float(total)


def module_cost(hlo_text: str) -> Cost:
  return HloModule(hlo_text).entry_cost()

"""TPU v5e hardware constants (assignment-specified)."""

PEAK_FLOPS_BF16 = 197e12      # per chip, bf16
HBM_BW = 819e9                # bytes/s per chip
ICI_BW_PER_LINK = 50e9        # bytes/s per link (~50 GB/s)
ICI_LINKS = 4                 # v5e: 4 ICI links per chip (2D torus x,y × 2)
VMEM_BYTES = 128 * 2**20      # ~128 MiB vector memory
HBM_BYTES = 16 * 2**30        # 16 GiB per chip

# VPU throughput: 8 lanes×128 sublanes... effective vector FLOPs ≈ peak/16
# at bf16 (the MXU:VPU ratio that mirrors the paper's TensorCore:CUDA-core
# gap; used by the microbenchmark speedup model).
VPU_RATIO = 1.0 / 16.0

# Structural port hazard: these (⊕, ⊗) pairs issue two same-port VPU ops per
# element (the paper's observed factor for fused min/max / or-and pairs).
# Shared by the benchmark speedup model and the dispatch cost prior so the
# two analytic models cannot drift apart.
VPU_PORT_HAZARD_OPS = ("minmax", "maxmin", "orand")


def vpu_hazard(op: str) -> float:
  return 2.0 if op in VPU_PORT_HAZARD_OPS else 1.0

"""Roofline derivation from compiled dry-run artifacts."""
from repro.roofline import analysis, collectives, hw

"""Measured cost-table dispatch: autotuned backend & block-size selection.

The paper's SIMD² unit wins by picking the right datapath per instruction
(MXU rewrite vs VPU rank-u loop, §3.1/§5).  This package is the software
analogue of that choice for our three backends:

  cost_table — versioned JSON table of measured (and analytically-priored)
               seconds per (op, shape-bucket, dtype, backend, block config).
  autotune   — microbenchmarks the live device to fill the table; --dry-prior
               fills from the roofline prior only (CI schema check).
  dispatch   — the brain of ``backend="auto"``: per call signature, return
               the cheapest (backend, block config) the table knows about.
"""
from repro.tuning.cost_table import (CLOSURE_BACKENDS, CostEntry, CostTable,
                                     Decision, DEFAULT_CONFIGS, SCHEDULE_ARMS,
                                     SCHEMA_VERSION, prior_seconds,
                                     sharded_prior_seconds, signature)
from repro.tuning.autotune import tune, tune_for_requests, tune_mesh
from repro.tuning.dispatch import (clear_cost_table, contraction_seconds,
                                   get_cost_table, resolve, set_cost_table,
                                   use_cost_table)

__all__ = [
    "CLOSURE_BACKENDS",
    "CostEntry", "CostTable", "Decision", "DEFAULT_CONFIGS", "SCHEDULE_ARMS",
    "SCHEMA_VERSION", "prior_seconds", "sharded_prior_seconds", "signature",
    "tune", "tune_for_requests", "tune_mesh", "clear_cost_table",
    "contraction_seconds", "get_cost_table",
    "resolve", "set_cost_table", "use_cost_table",
]

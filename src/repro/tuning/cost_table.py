"""Versioned JSON cost table with an analytic roofline prior.

One entry per *point* — (op, contraction shape bucket, dtype, backend, block
config) — holding the best-of wall seconds observed on the live device, or a
roofline-model estimate for points nobody has measured yet.  Measured entries
always beat prior entries at the same point (``record`` enforces the
precedence); across points, ``best`` is a plain argmin over seconds.

The table key is the **bucket signature**, not the raw shape: the serving
scheduler pads every problem up to its power-of-two bucket before executing,
so two raw shapes that land in the same bucket run the *same* executable and
therefore must share one dispatch decision.  Keying on raw shapes would both
fragment the table (one entry per arrival shape) and let two requests that
share an executable disagree about which backend to run it on.  See
DESIGN.md §Dispatch.

The analytic prior reuses the roofline constants (``roofline/hw.py``): an op
contracts 2·M·K·N flops on the MXU when an exact rewrite exists for the
backend, else on the VPU at ``peak/16`` with a ×2 structural port hazard for
fused min/max / or-and pairs, bounded below by HBM traffic; the Pallas arm
adds a per-grid-step overhead so tiny problems prefer the XLA path.
"""
from __future__ import annotations

import dataclasses
import json
import math
from typing import NamedTuple, Optional, Sequence

import numpy as np

from repro.core import semiring as sr_mod
from repro.roofline import hw

SCHEMA_VERSION = 1

MIN_BUCKET = 8  # canonical bucket floor; serve_mmo.scheduler re-exports it

# Candidate block configs swept per backend: 'pallas' tunes the (bm, bn, bk)
# tile, 'vector'/'xla' tune the K block of the blocked broadcast-reduce
# (irrelevant for MXU-rewritten ops, which ignore it), 'megakernel' tunes the
# fused chunk length G (fixpoint iterations per kernel launch).
DEFAULT_CONFIGS = {
    "vector": ((128,), (512,)),
    "xla": ((512,),),
    "pallas": ((128, 128, 128), (128, 128, 256), (256, 128, 128)),
    "megakernel": ((2,), (4,), (8,)),
}

# The backend pool closure buckets dispatch over: the per-contraction arms
# plus the fused whole-fixpoint megakernel (kernels/closure_megakernel.py).
# ``best``'s default order deliberately EXCLUDES 'megakernel' — a single
# mmo call can't run a fused fixpoint, so plain contraction dispatch must
# never pick it; only callers that own a whole closure loop (the serving
# engine's closure buckets, the batched solvers) pass this pool explicitly.
CLOSURE_BACKENDS = ("xla", "vector", "pallas", "megakernel")

# Per-grid-step launch/pipeline overhead charged to the Pallas arms.
_PALLAS_STEP_OVERHEAD_S = 1e-7


def bucket_dim(n: int, min_bucket: int = MIN_BUCKET) -> int:
  """Round ``n`` up to the next power of two, with a floor."""
  if n <= 0:
    raise ValueError(f"dimension must be positive, got {n}")
  b = min_bucket
  while b < n:
    b *= 2
  return b


def bucket_shape(shape: tuple, min_bucket: int = MIN_BUCKET) -> tuple:
  return tuple(bucket_dim(int(d), min_bucket) for d in shape)


def signature(op: str, shape: Sequence[int], dtype, backend: str,
              cfg: tuple = ()) -> str:
  """Canonical string key for one table point; ``shape`` is (M, K, N) and is
  bucketed here, so raw call shapes and pre-bucketed shapes collide onto the
  same entry by construction."""
  m, k, n = bucket_shape(tuple(shape))
  cfg_s = "x".join(str(int(c)) for c in cfg) if cfg else "-"
  return f"{sr_mod.get(op).name}|{m}x{k}x{n}|{np.dtype(dtype)}|{backend}|{cfg_s}"


def _parse_cfg(cfg_s: str) -> tuple:
  return () if cfg_s == "-" else tuple(int(c) for c in cfg_s.split("x"))


class Decision(NamedTuple):
  """One dispatch outcome: which backend runs the bucket, with which blocks."""
  backend: str
  cfg: tuple
  seconds: float
  source: str  # 'measured' | 'prior' | 'default'


@dataclasses.dataclass
class CostEntry:
  seconds: float
  source: str  # 'measured' | 'prior'


# Distributed-schedule arms the table can hold rows for (core.distributed
# batched schedules); their cfg column is the mesh shape, e.g. '2x4'.
SCHEDULE_ARMS = ("dp", "kspan", "summa", "ring")

# Per-shard program launch + shard_map sync cost charged to the dp arm: dp
# moves no bytes, so without it the model would shard every batch down to
# trivially small contractions where launch overhead actually dominates.
DP_OVERHEAD_S = 50e-6


def _local_point_seconds(sr, m: int, k: int, n: int, itemsize: int,
                         backend: str, cfg: tuple) -> float:
  """Roofline seconds for one single-device (m, k, n) contraction — the
  shared core of ``prior_seconds`` and the per-shard compute term of
  ``sharded_prior_seconds`` (unbucketed: sharded shapes are already exact)."""
  flops = 2.0 * m * k * n
  bytes_ = itemsize * (m * k + k * n) + 4 * m * n  # fp32 out
  t_mem = bytes_ / hw.HBM_BW

  if backend == "xla":
    on_mxu = sr.mxu_rewrite is not None
  elif backend in ("pallas", "megakernel", "arena"):
    on_mxu = sr.name in ("mma", "addnorm")  # in-kernel MXU rewrites
  else:  # 'vector'
    on_mxu = False

  if on_mxu:
    t_comp = flops / hw.PEAK_FLOPS_BF16
  else:
    t_comp = flops * hw.vpu_hazard(sr.name) / (
        hw.PEAK_FLOPS_BF16 * hw.VPU_RATIO)

  if backend in ("megakernel", "arena"):
    # fused whole-fixpoint arm: the iterate stays VMEM-resident across the
    # chunk, so the table's one-contraction unit pays the HBM round-trip
    # only once per G iterations — compute-bound contractions price the
    # same as pallas, bandwidth-bound ones price ~G× cheaper, which is the
    # whole reason the arm exists (TCU model: off-chip traffic bounds
    # iterative matrix algorithms, not FLOPs).  The request arena
    # (serve_mmo/arena.py) runs the same fused chunk over its slot buffer,
    # so its per-contraction slot-second prior is the same roofline
    g = int(cfg[0]) if cfg else 8
    t = max(t_comp, t_mem / max(g, 1))
    # one grid step per output row-block per iteration, request dim amortized
    t += math.ceil(m / 128) * _PALLAS_STEP_OVERHEAD_S
    return t

  t = max(t_comp, t_mem)
  if backend == "pallas":
    bm, bn, bk = (cfg + (128, 128, 128))[:3] if cfg else (128, 128, 128)
    grid = math.ceil(m / bm) * math.ceil(n / bn) * math.ceil(k / bk)
    t += grid * _PALLAS_STEP_OVERHEAD_S
  return t


def prior_seconds(op: str, shape: Sequence[int], dtype, backend: str,
                  cfg: tuple = ()) -> float:
  """Analytic roofline prior for one point (v5e constants, seconds)."""
  sr = sr_mod.get(op)
  m, k, n = bucket_shape(tuple(shape))
  return _local_point_seconds(sr, m, k, n, np.dtype(dtype).itemsize,
                              backend, cfg)


def sharded_prior_seconds(op: str, shape: Sequence[int], dtype,
                          schedule: str, mesh_shape: Sequence[int], *,
                          backend: str = "xla") -> float:
  """Analytic prior for one distributed schedule on a (rows, cols) mesh:
  per-shard roofline compute + ring-model collective traffic over one ICI
  link (formulas shared with roofline.collectives.ring_traffic_bytes).

  This is the fallback ``dispatch.resolve`` compares against the local prior
  when the table has no measured mesh row — the model that decides whether
  the collective is worth it before anyone has benchmarked the mesh.
  """
  from repro.roofline.collectives import ring_traffic_bytes
  sr = sr_mod.get(op)
  m, k, n = bucket_shape(tuple(shape))
  dims = tuple(int(d) for d in mesh_shape)
  rows, cols = dims[0], dims[-1]
  itemsize = np.dtype(dtype).itemsize

  if schedule == "dp":
    # requests sharded over every device: per-device work is the whole
    # contraction over 1/P of the batch, no collectives — the arm's cost is
    # throughput-normalized like the others (whole-bucket work over P)
    ndev = 1
    for d in dims:
      ndev *= max(d, 1)
    return (_local_point_seconds(sr, m, k, n, itemsize, backend, ()) / ndev
            + DP_OVERHEAD_S)
  if schedule == "kspan":
    t = _local_point_seconds(sr, m, max(k // cols, 1), n, itemsize,
                             backend, ())
    coll = ring_traffic_bytes("all-reduce", 4.0 * m * n, cols)
  elif schedule == "summa":
    t = _local_point_seconds(sr, max(m // rows, 1), k, max(n // cols, 1),
                             itemsize, backend, ())
    coll = (ring_traffic_bytes("all-gather",
                               itemsize * (m // max(rows, 1)) * k, cols)
            + ring_traffic_bytes("all-gather",
                                 itemsize * k * (n // max(cols, 1)), rows))
  elif schedule == "ring":
    t = cols * _local_point_seconds(sr, m, max(k // cols, 1),
                                    max(n // cols, 1), itemsize, backend, ())
    coll = cols * ring_traffic_bytes(
        "collective-permute", itemsize * max(k // cols, 1) * n, cols)
  else:
    raise ValueError(f"unknown schedule {schedule!r}; one of {SCHEDULE_ARMS}")
  return t + coll / hw.ICI_BW_PER_LINK


class CostTable:
  """In-memory cost table with JSON (de)serialization."""

  def __init__(self, *, device: str = "unknown"):
    self.version = SCHEMA_VERSION
    self.device = device
    self.entries: dict[str, CostEntry] = {}
    self._best_cache: dict = {}  # memoized best() — cleared on record()

  def __len__(self) -> int:
    return len(self.entries)

  # -- writes ----------------------------------------------------------------

  def record(self, op: str, shape, dtype, backend: str, cfg: tuple,
             seconds: float, *, source: str = "measured") -> bool:
    """Insert one point.  A prior never overwrites a measurement; a
    measurement overwrites anything.  Returns whether the entry was stored."""
    if source not in ("measured", "prior"):
      raise ValueError(f"source must be 'measured' or 'prior', got {source!r}")
    if not (seconds > 0.0 and math.isfinite(seconds)):
      raise ValueError(f"seconds must be positive and finite, got {seconds}")
    sig = signature(op, shape, dtype, backend, cfg)
    old = self.entries.get(sig)
    if old is not None and old.source == "measured" and source == "prior":
      return False
    self.entries[sig] = CostEntry(seconds=float(seconds), source=source)
    self._best_cache.clear()
    return True

  # -- reads -----------------------------------------------------------------

  def lookup(self, op: str, shape, dtype, backend: str,
             cfg: tuple = ()) -> Optional[CostEntry]:
    return self.entries.get(signature(op, shape, dtype, backend, cfg))

  def best(self, op: str, shape, dtype,
           backends: Optional[Sequence[str]] = None) -> Optional[Decision]:
    """Cheapest (backend, cfg) for one bucketed call signature, or None when
    the table holds nothing for it.  Ties break toward the earlier backend in
    ``backends`` order (deterministic dispatch)."""
    order = tuple(backends) if backends else ("xla", "vector", "pallas")
    m, k, n = bucket_shape(tuple(shape))
    prefix = f"{sr_mod.get(op).name}|{m}x{k}x{n}|{np.dtype(dtype)}|"
    cache_key = (prefix, order)
    if cache_key in self._best_cache:  # hot path: mmo resolves per call
      return self._best_cache[cache_key]
    choice: Optional[Decision] = None
    for sig, entry in self.entries.items():
      if not sig.startswith(prefix):
        continue
      backend, cfg_s = sig[len(prefix):].split("|")
      if backend not in order:
        continue
      cand = Decision(backend, _parse_cfg(cfg_s), entry.seconds, entry.source)
      if choice is None or (cand.seconds, order.index(cand.backend)) < (
          choice.seconds, order.index(choice.backend)):
        choice = cand
    self._best_cache[cache_key] = choice
    return choice

  def counts(self) -> dict:
    out = {"measured": 0, "prior": 0}
    for e in self.entries.values():
      out[e.source] += 1
    return out

  # -- persistence -----------------------------------------------------------

  def to_json(self) -> str:
    return json.dumps({
        "schema_version": self.version,
        "device": self.device,
        "entries": {sig: {"seconds": e.seconds, "source": e.source}
                    for sig, e in sorted(self.entries.items())},
    }, indent=2, sort_keys=True)

  @classmethod
  def from_json(cls, text: str) -> "CostTable":
    doc = json.loads(text)
    version = doc.get("schema_version")
    if version != SCHEMA_VERSION:
      raise ValueError(
          f"cost table schema_version {version!r} != {SCHEMA_VERSION} "
          "(re-run the autotuner to regenerate the table)")
    table = cls(device=doc.get("device", "unknown"))
    for sig, e in doc.get("entries", {}).items():
      entry = CostEntry(seconds=float(e["seconds"]), source=str(e["source"]))
      if entry.source not in ("measured", "prior"):
        raise ValueError(f"bad entry source {entry.source!r} at {sig!r}")
      if not (entry.seconds > 0.0 and math.isfinite(entry.seconds)):
        raise ValueError(f"bad entry seconds {entry.seconds!r} at {sig!r}")
      table.entries[sig] = entry
    return table

  def save(self, path) -> None:
    with open(path, "w") as f:
      f.write(self.to_json() + "\n")

  @classmethod
  def load(cls, path) -> "CostTable":
    with open(path) as f:
      return cls.from_json(f.read())

"""Autotuner: microbenchmark op × shape-bucket × dtype × backend × blocks.

    PYTHONPATH=src python -m repro.tuning.autotune --out cost_table.json
    PYTHONPATH=src python -m repro.tuning.autotune --dry-prior --out t.json

    # mesh rows too: measure dp/kspan/SUMMA/ring on a (2, 4) device mesh so
    # backend="auto" sharded serving dispatches from measurements
    XLA_FLAGS=--xla_force_host_platform_device_count=8 PYTHONPATH=src \
        python -m repro.tuning.autotune --mesh 2,4 --out cost_table.json

Every point is first seeded with the analytic roofline prior, then (unless
``--dry-prior``) measured on the live device with best-of wall timing; the
table's measured-beats-prior precedence means re-running the tuner only ever
sharpens the table.  ``--dry-prior`` exists for CI: it exercises the whole
sweep → record → serialize path with zero device timing, so schema rot is
caught without needing quiet hardware.

``--mesh ROWS,COLS`` extends the sweep to the distributed-schedule arms:
each (op, shape, dtype) point is also measured as one batched sharded
contraction per schedule (same per-request single-step units as
``benchmarks/shard_bench.py --cost-table`` records, so rows from either
source are interchangeable), which is what ``dispatch.resolve(mesh_shape=…)``
compares against the local arm when routing serving buckets to the mesh.
"""
from __future__ import annotations

import argparse
import sys
import time
from typing import Optional, Sequence

import numpy as np

from repro.core import semiring as sr_mod
from repro.tuning.cost_table import (SCHEDULE_ARMS, CostTable,
                                     DEFAULT_CONFIGS, bucket_shape,
                                     prior_seconds, sharded_prior_seconds)

DEFAULT_OPS = ("mma", "minplus", "maxmin", "maxmul", "orand", "addnorm")
DEFAULT_SHAPES = ((64, 64, 64), (128, 128, 128), (64, 256, 64))
DEFAULT_BACKENDS = ("xla", "vector", "pallas", "megakernel")


def _megakernel_point_ok(op: str, shape) -> bool:
  """The fused-fixpoint arm only exists for closure-shaped points: square
  contractions on rings with a ⊗-identity (closure is refused elsewhere)."""
  m, k, n = bucket_shape(shape)
  return m == k == n and sr_mod.get(op).otimes_identity is not None


def _device_label() -> str:
  import jax
  try:
    dev = jax.devices()[0]
    return f"{jax.default_backend()}:{getattr(dev, 'device_kind', dev)}"
  except Exception:  # noqa: BLE001 — label only, never fail the tuner
    return "unknown"


def _operands(op: str, shape, dtype, seed: int = 0):
  """Random operands at the bucket shape (bool for boolean rings)."""
  m, k, n = bucket_shape(shape)
  rng = np.random.default_rng(seed)
  if sr_mod.get(op).boolean:
    return (rng.random((m, k)) > 0.5), (rng.random((k, n)) > 0.5)
  a = rng.standard_normal((m, k)).astype(dtype)
  b = rng.standard_normal((k, n)).astype(dtype)
  if op in ("minmul", "maxmul"):  # reliability rings want [0, 1] weights
    a, b = np.abs(np.tanh(a)).astype(dtype), np.abs(np.tanh(b)).astype(dtype)
  return a, b


def measure_point(op: str, shape, dtype, backend: str, cfg: tuple, *,
                  iters: int = 3, warmup: int = 1) -> float:
  """Best-of wall seconds for one table point on the live device."""
  import jax
  import jax.numpy as jnp
  from repro.core.mmo import mmo

  a_h, b_h = _operands(op, shape, dtype)
  a, b = jnp.asarray(a_h), jnp.asarray(b_h)
  def run():
    return mmo(a, b, op=op, backend=backend, block=cfg)
  for _ in range(warmup):
    jax.block_until_ready(run())
  best = float("inf")
  for _ in range(iters):
    t0 = time.perf_counter()
    jax.block_until_ready(run())
    best = min(best, time.perf_counter() - t0)
  return best


def measure_megakernel_point(op: str, shape, dtype, cfg: tuple, *,
                             iters: int = 3, warmup: int = 1) -> float:
  """Best-of wall seconds *per fused iteration* for one megakernel row.

  The table prices every backend in per-contraction units, so the fused
  arm is timed as one G-iteration chunk and divided by G.  The operand is
  a directed line graph — the slowest-converging closure input — with
  ``max_iters=G`` so the kernel runs exactly its chunk and never exits
  early: what we record is the steady-state fused iteration cost, not a
  lucky early convergence."""
  import jax
  import jax.numpy as jnp
  from repro.core.closure import batched_bellman_ford_closure

  m, k, n = bucket_shape(shape)
  assert m == k == n, "megakernel rows are square closure points"
  g = int(cfg[0]) if cfg else 8
  sr = sr_mod.get(op)
  rng = np.random.default_rng(0)
  if sr.boolean:
    adj_h = np.zeros((n, n), dtype=bool)
    adj_h[np.arange(n - 1), np.arange(1, n)] = True
  else:
    adj_h = np.full((n, n), sr.oplus_identity, dtype=dtype)
    np.fill_diagonal(adj_h, sr.otimes_identity)
    adj_h[np.arange(n - 1), np.arange(1, n)] = np.abs(
        np.tanh(rng.standard_normal(n - 1))).astype(dtype)
  adj = jnp.asarray(adj_h)[None]
  def run():
    out, _ = batched_bellman_ford_closure(
        adj, op=op, fixpoint_backend="megakernel", megakernel_g=g,
        max_iters=g)
    return out
  for _ in range(warmup):
    jax.block_until_ready(run())
  best = float("inf")
  for _ in range(iters):
    t0 = time.perf_counter()
    jax.block_until_ready(run())
    best = min(best, time.perf_counter() - t0)
  return best / g


def default_backends() -> tuple:
  """Measurement-worthy backends for this host: Pallas (and the megakernel,
  which is Pallas underneath) is only a serving option on TPU — on CPU it
  runs in interpret mode, orders of magnitude slower, and measuring it
  would stall warmup for no dispatchable gain.  (``--dry-prior`` sweeps
  still cover both: priors cost nothing.)"""
  import jax
  return ("xla", "vector") + (
      ("pallas", "megakernel") if jax.default_backend() == "tpu" else ())


def tune(*,
         ops: Sequence[str] = DEFAULT_OPS,
         shapes: Sequence[tuple] = DEFAULT_SHAPES,
         dtypes: Sequence[str] = ("float32",),
         backends: Optional[Sequence[str]] = None,
         configs: Optional[dict] = None,
         table: Optional[CostTable] = None,
         iters: int = 3,
         warmup: int = 1,
         dry_prior: bool = False,
         fill_prior: bool = True,
         verbose: bool = False) -> CostTable:
  """Sweep the grid, recording priors for every point and measurements for
  all of them unless ``dry_prior``.  Updates and returns ``table``."""
  if backends is None:
    # dry-prior sweeps cost nothing — cover every backend for schema
    # coverage; live measurement sticks to what this host can serve with
    backends = DEFAULT_BACKENDS if dry_prior else default_backends()
  configs = configs or DEFAULT_CONFIGS
  if table is None:
    table = CostTable(device="prior-only" if dry_prior else _device_label())
  for op in ops:
    boolean = sr_mod.get(op).boolean
    op_dtypes = ("bool",) if boolean else dtypes
    for shape in shapes:
      for dtype in op_dtypes:
        for backend in backends:
          if backend == "megakernel" and not _megakernel_point_ok(op, shape):
            continue  # closure undefined here: no row, prior or measured
          for cfg in configs.get(backend, ((),)):
            if fill_prior:
              table.record(op, shape, dtype, backend, cfg,
                           prior_seconds(op, shape, dtype, backend, cfg),
                           source="prior")
            if dry_prior:
              continue
            if backend == "megakernel":
              seconds = measure_megakernel_point(op, shape, dtype, cfg,
                                                 iters=iters, warmup=warmup)
            else:
              seconds = measure_point(op, shape, dtype, backend, cfg,
                                      iters=iters, warmup=warmup)
            table.record(op, shape, dtype, backend, cfg, seconds,
                         source="measured")
            if verbose:
              print(f"[autotune] {op} {shape} {dtype} {backend} {cfg}: "
                    f"{seconds * 1e6:.1f}us", file=sys.stderr)
  return table


def measure_sharded_point(op: str, shape, dtype, schedule: str, mesh, *,
                          requests: Optional[int] = None, iters: int = 3,
                          warmup: int = 1) -> float:
  """Best-of wall seconds *per request* for one distributed-schedule arm:
  one batched sharded contraction over ``requests`` (default: one per
  device, the smallest batch every schedule can shard).  Per-request
  single-step units match ``benchmarks/shard_bench.py``'s ``step_seconds``
  and the table's one-(m, k, n)-contraction signature."""
  import jax
  import jax.numpy as jnp
  from repro.core.distributed import mmo_sharded_batched

  r = requests if requests is not None else mesh.size
  m, k, n = bucket_shape(shape)
  ops = []
  for i in range(r):
    a_h, b_h = _operands(op, shape, dtype, seed=i)
    ops.append((a_h, b_h))
  a = jnp.asarray(np.stack([o[0] for o in ops]))
  b = jnp.asarray(np.stack([o[1] for o in ops]))
  fn = jax.jit(lambda x, y: mmo_sharded_batched(
      x, y, op=op, schedule=schedule, mesh=mesh, backend="xla"))
  for _ in range(warmup):
    jax.block_until_ready(fn(a, b))
  best = float("inf")
  for _ in range(iters):
    t0 = time.perf_counter()
    jax.block_until_ready(fn(a, b))
    best = min(best, time.perf_counter() - t0)
  return best / r


def tune_mesh(*,
              dims: Sequence[int],
              mesh=None,
              ops: Sequence[str] = DEFAULT_OPS,
              shapes: Sequence[tuple] = DEFAULT_SHAPES,
              dtypes: Sequence[str] = ("float32",),
              schedules: Sequence[str] = SCHEDULE_ARMS,
              table: Optional[CostTable] = None,
              iters: int = 3,
              warmup: int = 1,
              dry_prior: bool = False,
              verbose: bool = False) -> CostTable:
  """Sweep the distributed-schedule arms on a (rows, cols) mesh, recording
  the sharded roofline prior for every point and measurements unless
  ``dry_prior`` (which needs no mesh at all — CI schema coverage).  Points a
  schedule cannot shard (``core.distributed.schedule_fits``) are skipped.
  Updates and returns ``table``."""
  dims = tuple(int(d) for d in dims)
  if table is None:
    table = CostTable(device="prior-only" if dry_prior else _device_label())
  if not dry_prior:
    if mesh is None:
      import jax
      mesh = jax.make_mesh(dims, ("data", "model"))
    from repro.core.distributed import schedule_fits
  for op in ops:
    op_dtypes = ("bool",) if sr_mod.get(op).boolean else dtypes
    for shape in shapes:
      m, k, n = bucket_shape(shape)
      for dtype in op_dtypes:
        for sched in schedules:
          if sched not in SCHEDULE_ARMS:
            raise ValueError(f"unknown schedule {sched!r}; one of "
                             f"{SCHEDULE_ARMS}")
          table.record(op, shape, dtype, sched, dims,
                       sharded_prior_seconds(op, (m, k, n), dtype, sched,
                                             dims),
                       source="prior")
          if dry_prior:
            continue
          if not schedule_fits(sched, m, k, n, mesh):
            continue
          seconds = measure_sharded_point(op, shape, dtype, sched, mesh,
                                          iters=iters, warmup=warmup)
          table.record(op, shape, dtype, sched, dims, seconds,
                       source="measured")
          if verbose:
            print(f"[autotune] {op} {shape} {dtype} {sched}@{dims}: "
                  f"{seconds * 1e6:.1f}us", file=sys.stderr)
  return table


def tune_for_requests(reqs, **kw) -> CostTable:
  """Tune exactly the (op, contraction-shape, dtype) points a sample of
  serving requests exercises — the engine-warmup entry point."""
  from repro.serve_mmo.scheduler import contract_shape, request_bucket
  points = {}
  for req in reqs:
    key = request_bucket(req)
    points.setdefault((key.op, contract_shape(key), key.dtypes[0]), None)
  table = kw.pop("table", None)
  if table is None:  # NB not `or`: an empty CostTable is falsy but valid
    table = CostTable(device=_device_label())
  for (op, shape, dtype) in points:
    table = tune(ops=(op,), shapes=(shape,), dtypes=(dtype,), table=table,
                 **kw)
  return table


def main(argv=None) -> int:
  ap = argparse.ArgumentParser(description=__doc__.splitlines()[0])
  ap.add_argument("--out", default="cost_table.json",
                  help="JSON path to write the table to")
  ap.add_argument("--update", action="store_true",
                  help="load --out first and update it in place")
  ap.add_argument("--dry-prior", action="store_true",
                  help="analytic prior only — no device timing (CI mode)")
  ap.add_argument("--ops", default=",".join(DEFAULT_OPS))
  ap.add_argument("--shapes",
                  default=",".join("x".join(map(str, s))
                                   for s in DEFAULT_SHAPES),
                  help="comma-separated MxKxN triples, e.g. 64x64x64,128x128x128")
  ap.add_argument("--dtypes", default="float32")
  ap.add_argument("--backends", default=None,
                  help="comma-separated; default: every backend for "
                       "--dry-prior, else what this host can serve with")
  ap.add_argument("--iters", type=int, default=3)
  ap.add_argument("--warmup", type=int, default=1)
  ap.add_argument("--mesh", default=None, metavar="ROWS,COLS",
                  help="also sweep the distributed-schedule arms "
                       f"({','.join(SCHEDULE_ARMS)}) on a device mesh of "
                       "this shape, recording mesh rows the sharded serving "
                       "path dispatches from (dry-prior needs no devices)")
  ap.add_argument("--schedules", default=",".join(SCHEDULE_ARMS),
                  help="comma-separated schedule arms for --mesh")
  ap.add_argument("-v", "--verbose", action="store_true")
  args = ap.parse_args(argv)

  try:
    shapes = tuple(tuple(int(d) for d in s.split("x"))
                   for s in args.shapes.split(","))
    if any(len(s) != 3 for s in shapes):
      raise ValueError
  except ValueError:
    ap.error(f"--shapes must be comma-separated MxKxN triples, got "
             f"{args.shapes!r}")

  dims = None
  if args.mesh:
    try:
      dims = tuple(int(x) for x in args.mesh.split(","))
      if len(dims) != 2 or any(d <= 0 for d in dims):
        raise ValueError
    except ValueError:
      ap.error(f"--mesh must be 'rows,cols' positive ints, got {args.mesh!r}")
    if not args.dry_prior:
      import jax
      need, have = dims[0] * dims[1], len(jax.devices())
      if need > have:
        ap.error(f"--mesh {args.mesh} needs {need} devices, host has {have} "
                 f"(on CPU: XLA_FLAGS=--xla_force_host_platform_device_count="
                 f"{need})")

  table = CostTable.load(args.out) if args.update else None
  backends = tuple(args.backends.split(",")) if args.backends else None
  table = tune(ops=tuple(args.ops.split(",")), shapes=shapes,
               dtypes=tuple(args.dtypes.split(",")),
               backends=backends, table=table,
               iters=args.iters, warmup=args.warmup,
               dry_prior=args.dry_prior, verbose=args.verbose)
  if dims is not None:
    table = tune_mesh(dims=dims, ops=tuple(args.ops.split(",")),
                      shapes=shapes, dtypes=tuple(args.dtypes.split(",")),
                      schedules=tuple(args.schedules.split(",")),
                      table=table, iters=args.iters, warmup=args.warmup,
                      dry_prior=args.dry_prior, verbose=args.verbose)
  table.save(args.out)
  counts = table.counts()
  print(f"[autotune] wrote {args.out}: {len(table)} entries "
        f"({counts['measured']} measured, {counts['prior']} prior) "
        f"device={table.device}")
  return 0


if __name__ == "__main__":
  sys.exit(main())

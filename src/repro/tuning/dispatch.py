"""The brain of ``backend="auto"``: cost-table-driven backend selection.

``resolve(op, m, k, n, dtype)`` returns the cheapest (backend, block config)
the active cost table knows for the call's bucket signature, falling back to
the historical default ('xla') when no table is loaded or the table has no
entry for the point.  Resolution is host-side dict work — cheap enough for
the ``mmo`` wrapper to run per call, and deterministic so the serving
engine's per-bucket memoization and the executable cache agree.

The active table is process-global (``set_cost_table`` / ``use_cost_table``)
and can be seeded from the ``REPRO_COST_TABLE`` environment variable, which
is how a warmed, persisted table ships into a serving job.  Callers that
need isolation (the engine, tests) pass ``table=`` explicitly instead.
"""
from __future__ import annotations

import contextlib
import math
import os
import threading
from typing import Optional, Sequence, Union

from repro.tuning.cost_table import (CLOSURE_BACKENDS, SCHEDULE_ARMS,
                                     CostTable, Decision, prior_seconds,
                                     sharded_prior_seconds)

ENV_VAR = "REPRO_COST_TABLE"
DEFAULT_BACKEND = "xla"

# CLOSURE_BACKENDS (re-exported above) is the pool for dispatchers that own
# a whole closure fixpoint (the serving engine's closure buckets): the
# per-contraction arms plus the fused 'megakernel' arm, whose cfg is the
# chunk length G.  ``resolve`` with its default ``backends`` never returns
# 'megakernel' — a single mmo call can't run a fused fixpoint — so the arm
# only competes where a caller passes this pool explicitly.

_lock = threading.Lock()
_table: Optional[CostTable] = None
_env_checked = False


def set_cost_table(table: Union[CostTable, str, None]) -> None:
  """Install the process-global cost table (a CostTable or a JSON path).
  ``None`` means *explicitly no table* — the env-var lookup stays disarmed,
  so ``use_cost_table(None)`` really scopes to table-less dispatch even when
  ``$REPRO_COST_TABLE`` is set.  Use ``clear_cost_table`` to re-arm the env
  default instead."""
  global _table, _env_checked
  with _lock:
    if isinstance(table, (str, os.PathLike)):
      table = CostTable.load(table)
    _table = table
    _env_checked = True


def clear_cost_table() -> None:
  """Drop the installed table and re-arm the ``$REPRO_COST_TABLE`` lookup
  (process-default state)."""
  global _table, _env_checked
  with _lock:
    _table = None
    _env_checked = False


def get_cost_table() -> Optional[CostTable]:
  """Active global table; loads ``$REPRO_COST_TABLE`` once if set."""
  global _table, _env_checked
  with _lock:
    if _table is None and not _env_checked:
      _env_checked = True
      path = os.environ.get(ENV_VAR)
      if path:
        _table = CostTable.load(path)
    return _table


@contextlib.contextmanager
def use_cost_table(table: Union[CostTable, str, None]):
  """Scoped ``set_cost_table`` (restores the previous table on exit)."""
  prev = get_cost_table()
  set_cost_table(table)
  try:
    yield get_cost_table()
  finally:
    set_cost_table(prev)


def contraction_seconds(op: str, m: int, k: int, n: int, dtype, *,
                        backend: str = "auto",
                        table: Optional[CostTable] = None) -> tuple:
  """(backend, cfg, seconds) — the *static* per-contraction cost estimate
  for one bucket signature: the cost table's cheapest row (measured beats
  prior) under ``backend="auto"``, that backend's best table row for a
  fixed backend, and the analytic roofline prior when the table holds
  nothing for the point.  Seconds are always finite.

  This is the hand-off point between dispatch and the serving engine's
  adaptive estimator (serve_mmo/estimator.py): the value returned here is
  the estimator's cold-start prior, which live EWMA observations then
  correct.  Keeping it beside ``resolve`` pins the invariant that the
  prediction prior and the dispatch decision read the same table the same
  way.
  """
  if backend == "auto":
    d = resolve(op, m, k, n, dtype, table=table)
    chosen, cfg, s = d.backend, d.cfg, d.seconds
  else:
    chosen, cfg, s = backend, (), float("inf")
    table = table if table is not None else get_cost_table()
    best = table.best(op, (m, k, n), dtype,
                      backends=(backend,)) if table else None
    if best is not None:
      cfg, s = best.cfg, best.seconds
  if not math.isfinite(s):
    s = prior_seconds(op, (m, k, n), dtype, chosen, cfg)
  return chosen, cfg, s


def resolve(op: str, m: int, k: int, n: int, dtype, *,
            table: Optional[CostTable] = None,
            backends: Optional[Sequence[str]] = None,
            mesh_shape: Optional[Sequence[int]] = None,
            schedules: Optional[Sequence[str]] = None) -> Decision:
  """Dispatch decision for one call signature (raw or bucketed shape).

  With ``mesh_shape`` (a (rows, cols) device-mesh shape), distributed
  schedule arms compete too: the returned Decision's ``backend`` may then be
  a schedule name from ``SCHEDULE_ARMS`` with the mesh shape as its ``cfg``.
  Measured mesh rows in the table (backend = schedule, cfg = mesh shape) are
  compared against the local choice directly; when the mesh is unmeasured,
  the sharded roofline prior competes against the *local prior* — model vs
  model, never a v5e model against a live-device measurement — so an
  untuned mesh only wins when the collective model says it should.
  ``schedules`` restricts which arms may compete (e.g. closures pass
  ('dp', 'summa') — independent per-device fixpoints, or the one contraction
  schedule that keeps C sharded in place).
  """
  table = table if table is not None else get_cost_table()
  local = table.best(op, (m, k, n), dtype, backends=backends) \
      if table is not None else None
  if local is None:
    local = Decision(DEFAULT_BACKEND, (), float("inf"), "default")
  if mesh_shape is None:
    return local

  dims = tuple(int(d) for d in mesh_shape)
  arms = []
  for sched in (schedules if schedules is not None else SCHEDULE_ARMS):
    if sched not in SCHEDULE_ARMS:
      raise ValueError(f"unknown schedule {sched!r}; one of {SCHEDULE_ARMS}")
    entry = table.lookup(op, (m, k, n), dtype, sched, dims) \
        if table is not None else None
    if entry is not None:
      arms.append(Decision(sched, dims, entry.seconds, entry.source))
    else:
      arms.append(Decision(
          sched, dims,
          sharded_prior_seconds(op, (m, k, n), dtype, sched, dims), "prior"))
  if not arms:
    return local
  # measured-beats-prior inside the sharded pool too: an unmeasured arm's
  # (idealized-hardware) prior must not shadow a row someone benchmarked
  measured = [a for a in arms if a.source == "measured"]
  best_sharded = min(measured or arms, key=lambda a: a.seconds)

  # like-for-like comparison: a sharded prior beats the local *prior*, a
  # sharded measurement beats whatever the local arm actually holds
  local_s = local.seconds
  if best_sharded.source == "prior" and local.source != "prior":
    local_s = prior_seconds(op, (m, k, n), dtype, local.backend, local.cfg)
  if not math.isfinite(local_s):  # 'default' local: no table at all
    local_s = prior_seconds(op, (m, k, n), dtype, local.backend, local.cfg)
  return best_sharded if best_sharded.seconds < local_s else local

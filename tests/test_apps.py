"""The 8 SIMD²-ized applications vs independent classic-algorithm baselines
(the paper's §5.1.2 correctness-validation flow)."""
import jax.numpy as jnp
import numpy as np
import pytest

from repro.apps import baselines as bl
from repro.apps import graphs
from repro.apps import solvers as sv

N = 40


def _check_paths(got, ref, atol=1e-4):
  g = np.asarray(got, np.float64)
  fin = np.isfinite(ref)
  np.testing.assert_allclose(g[fin], ref[fin], atol=atol, rtol=1e-5)
  assert np.array_equal(~np.isfinite(g), ~fin)


@pytest.mark.parametrize("algorithm", ["leyzorek", "bellman_ford",
                                       "floyd_warshall"])
def test_apsp(algorithm):
  w = graphs.weighted_digraph(N, 0.25, seed=11)
  ref = bl.apsp_np(np.where(np.eye(N, dtype=bool), 0, w))
  got, _ = sv.apsp(w, algorithm=algorithm)
  _check_paths(got, ref)


def test_aplp():
  w = graphs.dag(N, 0.25, seed=12)
  ref = bl.aplp_np(w)
  got, _ = sv.aplp(w)
  g = np.asarray(got, np.float64)
  fin = np.isfinite(ref)
  np.testing.assert_allclose(g[fin], ref[fin], atol=1e-4)


def test_maxcp():
  c = graphs.capacity_graph(N, 0.25, seed=13)
  ref = bl.maxcp_np(c)
  got, _ = sv.maxcp(c)
  fin = np.isfinite(ref)
  np.testing.assert_allclose(np.asarray(got)[fin], ref[fin], atol=1e-4)


def test_maxrp():
  p = graphs.reliability_graph(N, 0.25, seed=14, maximize=True)
  got, _ = sv.maxrp(p)
  np.testing.assert_allclose(np.asarray(got), bl.maxrp_np(p), atol=1e-5)


def test_minrp():
  p = graphs.reliability_graph(N, 0.25, seed=15, maximize=False)
  ref = bl.minrp_np(p)
  got, _ = sv.minrp(p)
  _check_paths(got, ref, atol=1e-5)


def test_mst_minimax_and_edges():
  w = graphs.undirected_weighted(32, 0.3, seed=16)
  mm_ref = bl.minimax_paths_np(w)
  mm, _ = sv.mst_minimax(w)
  off = ~np.eye(32, dtype=bool)
  fin = np.isfinite(mm_ref) & off
  np.testing.assert_allclose(np.asarray(mm)[fin], mm_ref[fin], atol=1e-4)
  edges_ref, _ = bl.kruskal_mst_np(w)
  in_mst, _ = sv.mst_edges(w)
  got = {(min(i, j), max(i, j))
         for i, j in zip(*np.nonzero(np.asarray(in_mst)))}
  assert got == edges_ref


def test_gtc():
  adj = graphs.boolean_digraph(64, 0.05, seed=17)
  got, _ = sv.gtc(adj)
  assert np.array_equal(np.asarray(got), bl.gtc_np(adj))


@pytest.mark.parametrize("backend", ["xla", "vector"])
def test_knn(backend):
  ref_pts, qry = graphs.knn_points(200, 40, 24, seed=18)
  d_ref, i_ref = bl.knn_np(ref_pts, qry, 8)
  d_got, i_got = sv.knn(ref_pts, qry, k=8, backend=backend)
  assert np.array_equal(np.asarray(i_got), i_ref)
  np.testing.assert_allclose(np.asarray(d_got), d_ref, rtol=1e-3, atol=1e-3)


def test_knn_pallas_backend():
  ref_pts, qry = graphs.knn_points(128, 16, 16, seed=19)
  _, i_ref = bl.knn_np(ref_pts, qry, 4)
  from repro.core.mmo import mmo
  d2 = mmo(jnp.asarray(qry), jnp.asarray(ref_pts).T, op="addnorm",
           backend="pallas", interpret=True)
  i_got = np.argsort(np.asarray(d2), axis=1)[:, :4]
  assert np.array_equal(i_got, i_ref)


def test_convergence_check_early_exit():
  """Leyzorek with convergence check must stop well before lg|V| on a
  short-diameter graph (paper §6.4)."""
  w = graphs.weighted_digraph(64, 0.9, seed=20)  # dense → diameter ~1-2
  _, it_conv = sv.apsp(w, convergence=True)
  _, it_max = sv.apsp(w, convergence=False)
  assert int(it_conv) <= int(it_max)
  # diameter ~2 ⇒ converges in ~⌈lg diam⌉ squarings + 1 verification pass
  assert int(it_conv) <= 4

"""Hypothesis property tests on the system's algebraic invariants.

``hypothesis`` is an optional test dependency (see pyproject.toml); the
module skips cleanly when it is not installed.
"""
import jax.numpy as jnp
import numpy as np
import pytest

pytest.importorskip("hypothesis")
from hypothesis import given, settings, strategies as st  # noqa: E402

from repro.core import ALL_OPS, get_semiring, mmo, mmo_reference

_dims = st.integers(min_value=1, max_value=12)
_ops = st.sampled_from([o for o in ALL_OPS if o != "orand"])
_vals = st.integers(min_value=-4, max_value=4)  # small ints: exact float math


def _mat(draw, m, n, els):
  return np.array(draw(st.lists(st.lists(els, min_size=n, max_size=n),
                                min_size=m, max_size=m)), dtype=np.float32)


@settings(max_examples=40, deadline=None)
@given(st.data())
def test_k_split_invariance(data):
  """⊕ over a split contraction equals the full contraction:
  mmo(A,B) == mmo(A1,B1) ⊕ mmo(A2,B2) — the invariant every distributed
  schedule (kspan/SUMMA/ring) relies on."""
  op = data.draw(_ops)
  m, k, n = data.draw(_dims), data.draw(st.integers(2, 12)), data.draw(_dims)
  a = _mat(data.draw, m, k, _vals)
  b = _mat(data.draw, k, n, _vals)
  sr = get_semiring(op)
  cut = data.draw(st.integers(1, k - 1))
  full = mmo_reference(jnp.asarray(a), jnp.asarray(b), op=op)
  part = sr.oplus(
      mmo_reference(jnp.asarray(a[:, :cut]), jnp.asarray(b[:cut]), op=op),
      mmo_reference(jnp.asarray(a[:, cut:]), jnp.asarray(b[cut:]), op=op))
  np.testing.assert_allclose(np.asarray(full), np.asarray(part), atol=1e-4)


@settings(max_examples=40, deadline=None)
@given(st.data())
def test_backend_equivalence(data):
  op = data.draw(st.sampled_from(list(ALL_OPS)))
  m, k, n = data.draw(_dims), data.draw(_dims), data.draw(_dims)
  a = _mat(data.draw, m, k, _vals)
  b = _mat(data.draw, k, n, _vals)
  if op == "orand":
    a, b = a > 0, b > 0
  v = mmo(jnp.asarray(a), jnp.asarray(b), op=op, backend="vector", block_k=3)
  x = mmo(jnp.asarray(a), jnp.asarray(b), op=op, backend="xla")
  np.testing.assert_allclose(np.asarray(v, np.float64),
                             np.asarray(x, np.float64), atol=1e-4)


@settings(max_examples=30, deadline=None)
@given(st.data())
def test_oplus_monoid_laws(data):
  """⊕ associative + commutative with the declared identity (on the values
  each ring actually operates over)."""
  op = data.draw(st.sampled_from(list(ALL_OPS)))
  sr = get_semiring(op)
  els = st.booleans() if sr.boolean else _vals
  x = np.array(data.draw(st.lists(els, min_size=4, max_size=4)))
  y = np.array(data.draw(st.lists(els, min_size=4, max_size=4)))
  z = np.array(data.draw(st.lists(els, min_size=4, max_size=4)))
  if not sr.boolean:
    x, y, z = (v.astype(np.float32) for v in (x, y, z))
  xj, yj, zj = jnp.asarray(x), jnp.asarray(y), jnp.asarray(z)
  lhs = sr.oplus(sr.oplus(xj, yj), zj)
  rhs = sr.oplus(xj, sr.oplus(yj, zj))
  np.testing.assert_array_equal(np.asarray(lhs), np.asarray(rhs))
  np.testing.assert_array_equal(np.asarray(sr.oplus(xj, yj)),
                                np.asarray(sr.oplus(yj, xj)))
  ident = sr.identity_like(x.shape, xj.dtype)
  np.testing.assert_array_equal(np.asarray(sr.oplus(xj, ident)), x)


@settings(max_examples=25, deadline=None)
@given(st.data())
def test_closure_idempotent(data):
  """A closure is a fixed point: closing the closure changes nothing."""
  from repro.core import leyzorek_closure, prepare_adjacency
  op = data.draw(st.sampled_from(["minplus", "maxmin", "minmax"]))
  n = data.draw(st.integers(2, 8))
  w = _mat(data.draw, n, n, st.integers(1, 9))
  adj = prepare_adjacency(jnp.asarray(w), op=op)
  closed, _ = leyzorek_closure(adj, op=op)
  again, _ = leyzorek_closure(closed, op=op)
  np.testing.assert_allclose(np.asarray(closed), np.asarray(again),
                             atol=1e-5)


@settings(max_examples=20, deadline=None)
@given(st.data())
def test_checkpoint_roundtrip_pytree(data):
  """save→restore is the identity on arbitrary nested dict pytrees."""
  import tempfile
  from repro.train import checkpoint as ckpt
  depth = data.draw(st.integers(1, 3))

  def build(d):
    if d == 0:
      shape = data.draw(st.tuples(st.integers(1, 4), st.integers(1, 4)))
      return np.array(data.draw(st.lists(
          st.floats(-10, 10, allow_nan=False, width=32),
          min_size=shape[0] * shape[1],
          max_size=shape[0] * shape[1]))).reshape(shape).astype(np.float32)
    return {f"k{i}": build(d - 1) for i in range(data.draw(st.integers(1, 3)))}

  tree = {"root": build(depth)}
  with tempfile.TemporaryDirectory() as d:
    ckpt.save(d, 7, tree)
    out, step = ckpt.restore(d)
  assert step == 7
  flat_a = jnp.tree_util.tree_leaves(tree) if hasattr(jnp, "tree_util") else None
  import jax
  la, lb = jax.tree.leaves(tree), jax.tree.leaves(out)
  assert len(la) == len(lb)
  for x, y in zip(la, lb):
    np.testing.assert_array_equal(np.asarray(x), np.asarray(y))


@settings(max_examples=15, deadline=None)
@given(st.data())
def test_pallas_kernel_random_shapes(data):
  """Property sweep of the Pallas SIMD² kernel: random op × shape × dtype,
  interpret-mode kernel ≡ pure-jnp oracle."""
  from repro.kernels import semiring_mmo
  from repro.kernels.ref import semiring_mmo_ref
  op = data.draw(st.sampled_from(list(ALL_OPS)))
  m = data.draw(st.integers(1, 40))
  k = data.draw(st.integers(1, 40))
  n = data.draw(st.integers(1, 40))
  f32 = data.draw(st.booleans())
  rng = np.random.default_rng(data.draw(st.integers(0, 2 ** 16)))
  a = rng.standard_normal((m, k)).astype(np.float32)
  b = rng.standard_normal((k, n)).astype(np.float32)
  if op == "orand":
    a, b = a > 0.7, b > 0.7
  elif not f32:
    a, b = a.astype(jnp.bfloat16), b.astype(jnp.bfloat16)
  got = semiring_mmo(jnp.asarray(a), jnp.asarray(b), op=op, interpret=True)
  ref = semiring_mmo_ref(jnp.asarray(a), jnp.asarray(b), op=op)
  tol = 1e-4 if (f32 or op == "orand") else 5e-2
  np.testing.assert_allclose(np.asarray(got, np.float64),
                             np.asarray(ref, np.float64), rtol=tol, atol=tol)

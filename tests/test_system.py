"""End-to-end system behaviour: train a tiny LM, serve it, and run a SIMD²
application pipeline through the public API."""
import jax
import jax.numpy as jnp
import numpy as np

from repro import configs
from repro.data import DataConfig, SyntheticLM
from repro.launch.serve import Engine
from repro.models import zoo
from repro.train import AdamWConfig, init_opt_state, make_train_step


def test_train_then_serve_roundtrip():
  """Train a reduced tinyllama until loss drops, then generate greedily —
  the engine must reproduce the model's own argmax continuation."""
  cfg = configs.get_config("tinyllama-1.1b", smoke=True)
  oc = AdamWConfig(lr=5e-3, warmup_steps=5, total_steps=80)
  params = zoo.init(cfg, jax.random.PRNGKey(0))
  state = (params, init_opt_state(params))
  step = jax.jit(make_train_step(cfg, oc))
  data = SyntheticLM(DataConfig(vocab=cfg.vocab, seq_len=48, global_batch=8,
                                seed=11))
  first = last = None
  for i in range(40):
    state, m = step(state, data.batch_at(i))
    if first is None:
      first = float(m["loss"])
    last = float(m["loss"])
  assert last < first

  params = state[0]
  eng = Engine(cfg, params, max_len=64)
  rng = np.random.default_rng(0)
  prompts = rng.integers(0, cfg.vocab, (2, 16), dtype=np.int32)
  toks = eng.generate(prompts, 8)
  assert toks.shape == (2, 8)
  assert int(toks.max()) < cfg.vocab

  # engine output == manual full-context argmax rollout (greedy consistency)
  ctx = jnp.asarray(prompts, jnp.int32)
  manual = []
  for _ in range(8):
    logits, _, _ = zoo.forward(params, cfg, {"tokens": ctx}, mode="train")
    nxt = jnp.argmax(logits[:, -1], axis=-1).astype(jnp.int32)
    manual.append(np.asarray(nxt))
    ctx = jnp.concatenate([ctx, nxt[:, None]], axis=1)
  manual = np.stack(manual, axis=1)
  assert np.array_equal(toks, manual), (toks, manual)


def test_simd2_app_pipeline():
  """Closure solver → derived artifact (MST edges) → re-validate, through
  the public apps API (the paper's Fig-7 host-program shape)."""
  from repro.apps import graphs, mst_edges
  from repro.apps.baselines import kruskal_mst_np
  w = graphs.undirected_weighted(24, 0.4, seed=21)
  in_mst, iters = mst_edges(w)
  got = {(min(i, j), max(i, j))
         for i, j in zip(*np.nonzero(np.asarray(in_mst)))}
  expect, _ = kruskal_mst_np(w)
  assert got == expect
  assert int(iters) >= 1


def test_serve_swa_ring_cache():
  """Generation with a window-sized ring cache must keep producing valid
  tokens beyond the window length (SWA serving path)."""
  cfg = configs.get_config("h2o-danube-1.8b", smoke=True)  # window=16
  params = zoo.init(cfg, jax.random.PRNGKey(1))
  eng = Engine(cfg, params, max_len=64)  # clamped to window internally
  assert eng.max_len == cfg.window
  rng = np.random.default_rng(1)
  prompts = rng.integers(0, cfg.vocab, (2, 12), dtype=np.int32)
  toks = eng.generate(prompts, 24)  # 12 + 24 > window
  assert toks.shape == (2, 24)
  assert np.isfinite(toks).all()

"""Async checkpointing + data prefetch: overlap paths must be semantically
identical to their synchronous counterparts."""
import numpy as np

from repro.data import DataConfig, make_source
from repro.train import checkpoint as ckpt
from repro.train.checkpoint import AsyncCheckpointer


def test_async_checkpointer_roundtrip(tmp_path):
  d = str(tmp_path)
  ac = AsyncCheckpointer(d)
  state = {"w": np.arange(12, dtype=np.float32).reshape(3, 4)}
  ac.save(5, state)
  # mutate the live state after snapshot — the write must not see it
  state["w"] += 100.0
  ac.wait()
  out, step = ckpt.restore(d)
  assert step == 5
  np.testing.assert_array_equal(out["w"],
                                np.arange(12, dtype=np.float32).reshape(3, 4))
  ac.save(6, state)
  ac.wait()
  assert ckpt.latest_step(d) == 6


def test_prefetcher_matches_sync():
  cfg = DataConfig(vocab=64, seq_len=16, global_batch=4, seed=9)
  sync = make_source(cfg)
  pre = make_source(cfg, prefetch=2)
  for step in [0, 1, 2, 3, 7, 8, 2]:   # in-order + jumps + replay
    a = sync.batch_at(step)
    b = pre.batch_at(step)
    np.testing.assert_array_equal(np.asarray(a["tokens"]),
                                  np.asarray(b["tokens"]))

"""Distributed layer tests — run in a subprocess with 8 fake host devices so
the main test process keeps seeing 1 device (per the dry-run isolation
rule)."""
import os
import subprocess
import sys
import textwrap

import pytest

SRC = os.path.join(os.path.dirname(__file__), "..", "src")

_SCRIPT = textwrap.dedent("""
    import os
    os.environ["XLA_FLAGS"] = "--xla_force_host_platform_device_count=8"
    import numpy as np, jax, jax.numpy as jnp
    from jax.sharding import NamedSharding, PartitionSpec as P
    from repro.core.distributed import (distributed_leyzorek, mmo_kspan,
                                        ring_mmo, summa_mmo)
    from repro.core.mmo import mmo_reference
    from repro.core import prepare_adjacency
    from repro.models import zoo, common as cm
    from repro import configs
    from repro.train import AdamWConfig, init_opt_state, make_train_step
    from repro.data import DataConfig, SyntheticLM

    mesh = jax.make_mesh((2, 4), ("data", "model"))
    rng = np.random.default_rng(1)

    # --- 1. all three distributed schedules == reference, every op class ---
    M, K, N = 16, 32, 24
    A = rng.standard_normal((M, K)).astype(np.float32)
    B = rng.standard_normal((K, N)).astype(np.float32)
    C = rng.standard_normal((M, N)).astype(np.float32)
    for op in ("mma", "minplus", "maxmin", "addnorm", "orand"):
        a, b, c = (A > 0, B > 0, C > 1.0) if op == "orand" else (A, B, C)
        ref = np.asarray(mmo_reference(jnp.asarray(a), jnp.asarray(b),
                                       jnp.asarray(c), op=op), np.float64)
        with mesh:
            for fn, kw in ((mmo_kspan, {}), (summa_mmo, {}), (ring_mmo, {})):
                got = np.asarray(fn(jnp.asarray(a), jnp.asarray(b),
                                    jnp.asarray(c), op=op, mesh=mesh, **kw),
                                 np.float64)
                assert np.abs(got - ref).max() < 1e-3, (op, fn.__name__)
    print("SCHEDULES_OK")

    # --- 2. distributed closure == local closure ---
    n = 32
    W = rng.uniform(1, 10, (n, n)).astype(np.float32)
    W = np.where(rng.random((n, n)) < 0.7, np.inf, W)
    adj = prepare_adjacency(jnp.asarray(W), op="minplus")
    ref = np.asarray(adj).copy()
    for k in range(n):
        ref = np.minimum(ref, ref[:, k:k+1] + ref[k:k+1, :])
    out = np.asarray(distributed_leyzorek(adj, op="minplus", mesh=mesh))
    fin = np.isfinite(ref)
    assert np.abs(out[fin] - ref[fin]).max() < 1e-4
    assert np.array_equal(np.isinf(out), ~fin)
    print("CLOSURE_OK")

    # --- 3. sharded train step == single-device train step ---
    cfg = configs.get_config("tinyllama-1.1b", smoke=True)
    par = cm.Parallelism(data_axes=("data",), tp_size=4, dp_size=2)
    params = zoo.init(cfg, jax.random.PRNGKey(0))
    opt = init_opt_state(params)
    data = SyntheticLM(DataConfig(vocab=cfg.vocab, seq_len=32,
                                  global_batch=4, seed=2))
    batch = data.batch_at(0)
    oc = AdamWConfig(lr=1e-3, warmup_steps=1, total_steps=10)
    step = make_train_step(cfg, oc)
    (_, _), m_ref = jax.jit(step)((params, opt), batch)

    specs = cm.specs_like(params, cfg, par)
    ns = lambda t: jax.tree.map(lambda s: NamedSharding(mesh, s), t,
                                is_leaf=lambda x: isinstance(x, P))
    with mesh:
        sp = jax.device_put(params, ns(specs))
        so = jax.device_put(opt, ns({"m": specs, "v": specs, "step": P()}))
        sb = jax.device_put(batch, ns({"tokens": P("data", None),
                                       "labels": P("data", None)}))
        (_, _), m_sh = jax.jit(step)((sp, so), sb)
    # tolerance covers cross-device reduction-order drift (varies by jax/XLA)
    assert abs(float(m_ref["loss"]) - float(m_sh["loss"])) < 1e-3, (
        float(m_ref["loss"]), float(m_sh["loss"]))
    print("TRAIN_SHARD_OK")
""")


@pytest.mark.slow
def test_distributed_suite():
  env = dict(os.environ, PYTHONPATH=SRC)
  r = subprocess.run([sys.executable, "-c", _SCRIPT], capture_output=True,
                     text=True, env=env, timeout=900)
  assert r.returncode == 0, r.stderr[-3000:]
  for marker in ("SCHEDULES_OK", "CLOSURE_OK", "TRAIN_SHARD_OK"):
    assert marker in r.stdout

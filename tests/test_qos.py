"""QoS scheduling subsystem: policies, admission control, deadlines, metrics."""
import threading
import time

import numpy as np
import pytest

from repro.apps import graphs
from repro.serve_mmo import (AdmissionController, DeadlineExceededError,
                             DeadlinePolicy, FairSharePolicy, MMOEngine,
                             RejectedError, RollingWindow, apsp_request,
                             make_policy, mmo_request)
from repro.serve_mmo.scheduler import (BucketScheduler, FifoBucketScheduler,
                                       request_bucket)

from conftest import FakeClock

RNG = np.random.default_rng(0)


def _mmo(n, **qos):
  a = RNG.standard_normal((n, n)).astype(np.float32)
  b = RNG.standard_normal((n, n)).astype(np.float32)
  return mmo_request(a, b, op="mma", **qos)


# ---------------------------------------------------------------------------
# policies (scheduler-level)
# ---------------------------------------------------------------------------


def test_make_policy_rejects_unknown():
  with pytest.raises(ValueError, match="unknown policy"):
    make_policy("lifo")
  p = DeadlinePolicy()
  assert make_policy(p) is p


def test_deadline_policy_prefers_deadline_bucket_over_older_bulk():
  """A younger bucket whose head carries a deadline preempts an older
  no-deadline bulk bucket — the whole point of the policy."""
  sched = BucketScheduler(policy="deadline", max_batch=4)
  bulk = [apsp_request(graphs.weighted_digraph(12, 0.3, seed=i))
          for i in range(3)]
  for r in bulk:
    sched.add(r)
  urgent = _mmo(12, deadline_s=10.0)
  sched.add(urgent)
  key, batch = sched.next_batch()
  assert batch == [urgent]
  _, batch2 = sched.next_batch()
  assert batch2 == bulk  # then the bulk bucket, FIFO within


def test_deadline_policy_priority_tiers_break_ties():
  """Among no-deadline requests, a higher priority tier serves first even
  though it arrived later."""
  sched = BucketScheduler(policy="deadline", max_batch=4)
  low = apsp_request(graphs.weighted_digraph(12, 0.3, seed=0))
  high = _mmo(12, priority=5)
  sched.add(low)
  sched.add(high)
  _, batch = sched.next_batch()
  assert batch == [high]
  _, batch2 = sched.next_batch()
  assert batch2 == [low]


def test_deadline_policy_orders_by_deadline_within_bucket():
  clock = FakeClock()
  sched = BucketScheduler(policy="deadline", max_batch=1, clock=clock)
  late = _mmo(12, deadline_s=50.0)
  soon = _mmo(12, deadline_s=5.0)
  sched.add(late)
  sched.add(soon)  # same bucket, tighter deadline → must jump the queue
  assert sched.next_batch(now=0.0)[1] == [soon]
  assert sched.next_batch(now=0.0)[1] == [late]


def test_deadline_policy_fails_fast_hopeless_requests():
  """A head whose deadline cannot be met even if served right now is
  diverted to the expired channel, never into a batch."""
  clock = FakeClock()
  sched = BucketScheduler(policy="deadline", max_batch=4, clock=clock)
  sched.predict_seconds = lambda key: 100.0  # every batch predicts 100s
  hopeless = _mmo(12, deadline_s=1.0)
  fine = _mmo(12)  # no deadline — always feasible
  sched.add(hopeless)
  sched.add(fine)
  key, batch = sched.next_batch(now=0.0)
  assert batch == [fine]
  assert sched.take_expired() == [hopeless]
  assert len(sched) == 0


def test_already_expired_requests_diverted_under_fifo_too():
  """Deadline expiry is an engine-level guarantee, not a policy feature:
  even the FIFO scheduler refuses to batch a request whose deadline passed
  while it was queued."""
  clock = FakeClock()
  sched = FifoBucketScheduler(max_batch=4, clock=clock)
  doomed = _mmo(12, deadline_s=1.0)
  ok = _mmo(12)
  sched.add(doomed)
  sched.add(ok)
  clock.t = 2.0  # the deadline lapses in the queue
  key, batch = sched.next_batch()
  assert batch == [ok]
  assert sched.take_expired() == [doomed]


def test_fair_share_weighted_round_robin_across_tenants():
  """weight 2:1 → tenant a gets two picks per b pick while both have work;
  an idle tenant is skipped without burning the turn."""
  sched = BucketScheduler(policy=FairSharePolicy(weights={"a": 2, "b": 1}),
                          max_batch=1)
  for i in range(4):
    sched.add(_mmo(12, tenant="a"))
  for i in range(4):
    sched.add(_mmo(24, tenant="b"))  # distinct bucket per tenant
  order = []
  while True:
    picked = sched.next_batch()
    if picked is None:
      break
    order.append(picked[1][0].tenant)
  assert order == ["a", "a", "b", "a", "a", "b", "b", "b"]


def test_fair_share_batch_may_carry_other_tenants():
  """Tenants sharing a shape bucket ride each other's batches — batching is
  a shape property, and a free ride is not a fairness violation."""
  sched = BucketScheduler(policy="fair", max_batch=4)
  mine = _mmo(12, tenant="a")
  theirs = _mmo(12, tenant="b")
  sched.add(mine)
  sched.add(theirs)
  _, batch = sched.next_batch()
  assert batch == [mine, theirs]
  assert sched.next_batch() is None


def test_fair_share_refunds_turns_that_serve_the_tenant_nothing():
  """A tenant whose oldest entry sits behind >= max_batch other-tenant
  requests in a shared bucket keeps its turn (credit refunded) until a
  batch actually carries its work — the turn is for service, not for
  draining someone else's backlog."""
  sched = BucketScheduler(policy="fair", max_batch=2)
  for _ in range(4):
    sched.add(_mmo(12, tenant="a"))
  sched.add(_mmo(12, tenant="b"))   # same bucket, behind all of a's
  for _ in range(3):
    sched.add(_mmo(24, tenant="c"))  # its own bucket
  served = []
  while True:
    picked = sched.next_batch()
    if picked is None:
      break
    served.append([r.tenant for r in picked[1]])
  # b's turn at batch 2 served only a's work → refunded, b keeps the turn
  # and lands batch 3; without the refund c would cut in first
  assert served == [["a", "a"], ["a", "a"], ["b"], ["c", "c"], ["c"]]


def test_fair_share_drops_drained_tenants_from_the_ring():
  """Unbounded tenant churn must not accrete ring state: a drained tenant
  leaves _order/_queues entirely and re-registers on its next submit."""
  policy = FairSharePolicy()
  sched = BucketScheduler(policy=policy, max_batch=8)
  for i in range(5):
    sched.add(_mmo(12, tenant=f"user-{i}"))
  while sched.next_batch() is not None:
    pass
  assert sched.next_batch() is None
  assert policy._order == [] and policy._queues == {}
  sched.add(_mmo(12, tenant="user-3"))  # re-registers cleanly
  assert [r.tenant for r in sched.next_batch()[1]] == ["user-3"]


def test_fair_share_survives_externally_cleared_buckets():
  """Orphaned entries (bucket dict cleared without popping) must not
  livelock next_batch — the lost-request simulation the engine tests use."""
  sched = BucketScheduler(policy="fair", max_batch=2)
  sched.add(_mmo(12, tenant="a"))
  sched.add(_mmo(24, tenant="b"))
  sched._buckets.clear()
  assert sched.next_batch() is None and len(sched) == 0


def test_heap_pick_matches_linear_scan_reference():
  """The lazy-heap bucket picker must agree with the O(buckets) linear scan
  it replaced, across a random add/pick interleaving."""
  rng = np.random.default_rng(42)
  sched = FifoBucketScheduler(max_batch=2)

  def linear_reference():
    best_key, best_seq = None, None
    for key, q in sched._buckets.items():
      if q and (best_seq is None or q[0].seq < best_seq):
        best_key, best_seq = key, q[0].seq
    return best_key

  for _ in range(300):
    if rng.random() < 0.6 or len(sched) == 0:
      sched.add(_mmo(int(rng.integers(8, 80))))
    else:
      expect = linear_reference()
      key, _ = sched.next_batch()
      assert key == expect
  while len(sched):
    expect = linear_reference()
    key, _ = sched.next_batch()
    assert key == expect


# ---------------------------------------------------------------------------
# service-time batch cap (max_batch_seconds) — preemption across batches
# ---------------------------------------------------------------------------


def _bulk_sched(clock, max_batch_seconds, per_request_s=1.0, **kw):
  sched = BucketScheduler(policy="deadline", max_batch=8, clock=clock,
                          max_batch_seconds=max_batch_seconds, **kw)
  sched.predict_seconds = lambda key: per_request_s
  return sched


def test_batch_cap_inactive_without_deadline_traffic():
  """Pure-bulk workloads keep full batches: the cap only binds while
  deadline-tagged traffic is queued or recent."""
  clock = FakeClock()
  sched = _bulk_sched(clock, max_batch_seconds=2.0)
  for i in range(8):
    sched.add(_mmo(12))
  _, batch = sched.next_batch()
  assert len(batch) == 8


def test_batch_cap_bounds_bulk_batches_while_deadline_traffic_queued():
  """With deadline traffic queued, a bulk batch is bounded to
  ~max_batch_seconds of predicted work, floored to a power of two (the
  engine pads batches up to the next power of two and computes every
  slot, so un-floored caps would overshoot the budget they claim)."""
  clock = FakeClock()
  sched = _bulk_sched(clock, max_batch_seconds=3.0)  # 3s / 1s each → 3 → 2
  for i in range(8):
    sched.add(_mmo(12))
  sched.add(_mmo(24, deadline_s=60.0))  # deadline bucket, served first
  _, urgent_batch = sched.next_batch()
  assert [r.shape[0] for r in urgent_batch] == [24]
  _, bulk_batch = sched.next_batch()
  assert len(bulk_batch) == 2  # pow2 floor of 3
  # a sub-second budget still serves one request per batch, never zero
  sched2 = _bulk_sched(clock, max_batch_seconds=0.5)
  for i in range(4):
    sched2.add(_mmo(12))
  sched2.add(_mmo(24, deadline_s=60.0))
  sched2.next_batch()  # urgent
  _, bulk = sched2.next_batch()
  assert len(bulk) == 1


def test_batch_cap_recency_window_expires():
  """An ongoing deadline stream keeps bulk batches short *between* urgent
  arrivals; once the stream stops (no deadline-tagged submit within the
  lookback), bulk batching returns to full size."""
  clock = FakeClock()
  sched = _bulk_sched(clock, max_batch_seconds=2.0, deadline_lookback_s=1.0)
  sched.add(_mmo(24, deadline_s=60.0))
  sched.next_batch()  # drain the urgent bucket; none queued now
  for i in range(8):
    sched.add(_mmo(12))
  clock.t = 0.5  # within the lookback → still capped
  _, batch = sched.next_batch()
  assert len(batch) == 2
  clock.t = 2.0  # lookback expired → full batches again
  _, batch = sched.next_batch()
  assert len(batch) == 6


def test_batch_cap_survives_bad_predictions():
  """A predictor that answers 0 / inf / None must disable the cap, not
  divide by zero or cap everything to nothing."""
  clock = FakeClock()
  for bad in (lambda k: 0.0, lambda k: float("inf"), None):
    sched = BucketScheduler(policy="deadline", max_batch=4, clock=clock,
                            max_batch_seconds=1.0)
    sched.predict_seconds = bad
    sched.add(_mmo(24, deadline_s=60.0))
    sched.next_batch()
    for i in range(4):
      sched.add(_mmo(12))
    _, batch = sched.next_batch()
    assert len(batch) == 4


def test_preemption_deadline_met_with_cap_missed_without():
  """The ROADMAP scenario, end to end through the engine with an injectable
  clock (no real sleeps): an urgent request arriving mid-bulk-burst meets
  its deadline under service-time batch capping and misses it without.

  The cost table prices one bulk closure request at 1s (0.25s/contraction ×
  lg(16)=4 squarings); execution time is *simulated* by advancing the fake
  clock by the batch's predicted duration after each step.  Uncapped, the
  first bulk batch holds all 8 requests → the urgent arrival (deadline 3.0s
  absolute) next gets a pick at t=8 and expires.  Capped at 2s of predicted
  work, batches hold 2 requests → the urgent arrival is picked at t=2 and
  completes inside its budget."""
  from repro.tuning import CostTable
  table = CostTable(device="test")
  table.record("minplus", (16, 16, 16), "float32", "xla", (512,), 0.25)
  table.record("mma", (16, 16, 16), "float32", "xla", (512,), 0.01)

  def run(max_batch_seconds):
    clock = FakeClock()
    eng = MMOEngine(backend="xla", max_batch=8, policy="deadline",
                    cost_table=table, clock=clock,
                    max_batch_seconds=max_batch_seconds,
                    deadline_lookback_s=60.0)
    # an earlier urgent request establishes the deadline stream (the cap
    # protects the *next* arrival, which is not queued yet by definition)
    first = eng.submit(_mmo(12, deadline_s=10.0, priority=1))
    bulk = [eng.submit(apsp_request(graphs.weighted_digraph(12, 0.3, seed=i),
                                    tenant="bulk")) for i in range(8)]
    assert eng.step() == 1 and first.state == "done"  # urgent bucket first
    # bulk batch begins at t=0; the urgent request arrives mid-execution
    served = eng.step()
    clock.t = 0.5
    urgent = eng.submit(_mmo(12, deadline_s=2.5, priority=1))
    clock.t = float(served) * 1.0  # the batch's simulated service time
    eng.step()  # first pick the urgent arrival can get
    eng.run_until_idle()
    assert all(f.state == "done" for f in bulk)
    return served, urgent

  served, urgent = run(max_batch_seconds=None)
  assert served == 8  # uncapped: the whole burst in one batch
  assert urgent.state == "expired"
  with pytest.raises(DeadlineExceededError):
    urgent.result()

  served, urgent = run(max_batch_seconds=2.0)
  assert served == 2  # capped: ~2s of predicted work per batch
  assert urgent.state == "done"
  assert urgent.result().value.shape == (12, 12)


# ---------------------------------------------------------------------------
# admission control
# ---------------------------------------------------------------------------


def test_admission_max_queue_bounds_depth():
  eng = MMOEngine(backend="xla", max_batch=4, max_queue=4)
  futs = [eng.submit(_mmo(12)) for _ in range(10)]
  rejected = [f for f in futs if f.state == "rejected"]
  assert len(rejected) == 6 and len(eng.scheduler) == 4
  assert eng.admission.queued == 4
  for f in rejected:
    with pytest.raises(RejectedError, match="queue full"):
      f.result()
  assert eng.run_until_idle() == 4
  assert all(f.result().value.shape == (12, 12)
             for f in futs if f.state != "rejected")
  st = eng.stats()
  assert st.rejected == 6 and st.completed == 4
  # queue drained → admission slots free again
  assert eng.submit(_mmo(12)).state == "pending"


def test_admission_tenant_quota_in_flight():
  eng = MMOEngine(backend="xla", max_batch=4, tenant_quota={"noisy": 2})
  f1 = eng.submit(_mmo(12, tenant="noisy"))
  f2 = eng.submit(_mmo(12, tenant="noisy"))
  f3 = eng.submit(_mmo(12, tenant="noisy"))
  quiet = eng.submit(_mmo(12, tenant="quiet"))  # other tenants unaffected
  assert f3.state == "rejected" and quiet.state == "pending"
  with pytest.raises(RejectedError, match="over quota"):
    f3.result()
  eng.run_until_idle()
  assert f1.result().value.shape == (12, 12)
  # completions release the in-flight slots
  assert eng.submit(_mmo(12, tenant="noisy")).state == "pending"
  assert eng.admission.rejections == {"tenant_quota": 1}


def test_admission_predicted_backlog_seconds():
  """Backlog admission is denominated in predicted seconds of work from the
  cost table, not queue length: cheap requests fit where one expensive one
  would not, and closures are charged their worst-case trip count."""
  from repro.tuning import CostTable
  table = CostTable(device="test")
  table.record("mma", (16, 16, 16), "float32", "xla", (512,), 10.0)   # slow
  table.record("minplus", (16, 16, 16), "float32", "xla", (512,), 1e-4)
  eng = MMOEngine(backend="auto", max_batch=4, cost_table=table,
                  max_backlog_s=15.0)
  # per-request charge = measured 10s × 1 contraction → one fits, two do not
  f1 = eng.submit(_mmo(12))
  f2 = eng.submit(_mmo(12))
  assert f1.state == "pending" and f2.state == "rejected"
  with pytest.raises(RejectedError, match="predicted backlog"):
    f2.result()
  # cheap closure: 1e-4 × lg(16)=4 squarings — fits the remaining budget
  cheap = eng.submit(apsp_request(graphs.weighted_digraph(12, 0.3, seed=0)))
  assert cheap.state == "pending"
  assert eng.admission.backlog_s == pytest.approx(10.0 + 4e-4, rel=1e-6)


def test_predict_request_seconds_fixed_backend_reads_table():
  """A fixed-backend engine must still price admission off the table's
  measured row for that backend, not the idealized roofline prior."""
  from repro.tuning import CostTable
  table = CostTable(device="test")
  table.record("mma", (16, 16, 16), "float32", "vector", (128,), 7.0)
  eng = MMOEngine(backend="vector", cost_table=table)
  key = request_bucket(_mmo(12))
  assert eng.predict_request_seconds(key) == pytest.approx(7.0)
  # and a closure bucket multiplies by the solver's worst-case trip count
  table.record("minplus", (16, 16, 16), "float32", "vector", (128,), 2.0)
  ck = request_bucket(apsp_request(graphs.weighted_digraph(12, 0.3, seed=0)))
  assert eng.predict_request_seconds(ck) == pytest.approx(2.0 * 4)  # lg(16)


def test_admission_controller_unbounded_admits_everything():
  adm = AdmissionController()
  assert adm.unbounded
  req = _mmo(12)
  assert adm.try_admit(req) is None
  adm.on_dequeue(req)
  adm.on_done(req)
  assert adm.queued == 0 and dict(adm.inflight) == {}


# ---------------------------------------------------------------------------
# deadline expiry through the engine (synthetic clock)
# ---------------------------------------------------------------------------


def test_engine_expires_queued_request_past_deadline():
  clock = FakeClock()
  eng = MMOEngine(backend="xla", max_batch=4, clock=clock)
  doomed = eng.submit(_mmo(12, deadline_s=1.0))
  ok = eng.submit(_mmo(12))
  clock.t = 5.0  # deadline lapses while queued
  eng.run_until_idle()
  assert doomed.state == "expired"
  with pytest.raises(DeadlineExceededError, match="missed its 1s deadline"):
    doomed.result()
  assert ok.result().value.shape == (12, 12)
  st = eng.stats()
  assert st.expired == 1 and st.completed == 1
  assert eng.pending() == 0 and eng.admission.queued == 0
  assert dict(eng.admission.inflight) == {}
  snap = eng.metrics_snapshot()
  assert snap["counters"]["expired"] == 1
  assert snap["counters"]["completed"] == 1


def test_engine_deadline_policy_fails_fast_infeasible():
  """With the deadline policy, a request whose deadline cannot be met (cost
  table predicts service longer than the remaining budget) fails fast even
  though the deadline has not lapsed yet."""
  from repro.tuning import CostTable
  table = CostTable(device="test")
  table.record("mma", (16, 16, 16), "float32", "xla", (512,), 100.0)
  clock = FakeClock()
  eng = MMOEngine(backend="auto", max_batch=4, policy="deadline",
                  cost_table=table, clock=clock)
  hopeless = eng.submit(_mmo(12, deadline_s=1.0))
  eng.run_until_idle()
  assert hopeless.state == "expired"
  with pytest.raises(DeadlineExceededError):
    hopeless.result()


def test_deadline_met_requests_execute_normally():
  eng = MMOEngine(backend="xla", max_batch=4, policy="deadline")
  fut = eng.submit(_mmo(12, deadline_s=600.0))
  eng.run_until_idle()
  assert fut.state == "done" and fut.result().value.shape == (12, 12)


# ---------------------------------------------------------------------------
# deadline policy beats FIFO under bulk interference (the BENCH_qos claim)
# ---------------------------------------------------------------------------


def _interference_p99(policy):
  """p99 latency of small deadline-tagged traffic submitted *behind* a burst
  of bulk closure work, per policy.  Both engines are prewarmed so compile
  time never pollutes the comparison."""
  eng = MMOEngine(backend="xla", max_batch=4, policy=policy)
  eng.prewarm([apsp_request(graphs.weighted_digraph(40, 0.3, seed=0)),
               _mmo(12)])
  bulk = [eng.submit(apsp_request(
      graphs.weighted_digraph(40 + (i % 3), 0.3, seed=i), tenant="bulk"))
      for i in range(12)]
  urgent = [eng.submit(_mmo(12, deadline_s=60.0, priority=1,
                            tenant="interactive")) for _ in range(8)]
  eng.run_until_idle()
  recs = {r.request_id: r for r in eng._records}
  lat = [recs[f.request.request_id].latency_s for f in urgent]
  assert all(f.state == "done" for f in bulk + urgent)
  return float(np.percentile(lat, 99))


def test_deadline_p99_at_least_2x_better_than_fifo_under_bulk():
  fifo = _interference_p99("fifo")
  deadline = _interference_p99("deadline")
  assert deadline * 2.0 <= fifo, (
      f"deadline-policy p99 {deadline * 1e3:.1f}ms not 2x better than "
      f"FIFO {fifo * 1e3:.1f}ms under bulk interference")


# ---------------------------------------------------------------------------
# live metrics
# ---------------------------------------------------------------------------


def test_rolling_window_percentiles_and_eviction():
  w = RollingWindow(size=4)
  assert w.percentile(50) is None  # empty window: None, never NaN
  for v in (1.0, 2.0, 3.0, 4.0, 100.0):  # 1.0 evicted by 100.0
    w.add(v)
  assert w.count == 5
  assert sorted(w.values()) == [2.0, 3.0, 4.0, 100.0]
  assert w.percentile(0) == 2.0
  assert w.percentile(100) == 100.0
  with pytest.raises(ValueError):
    RollingWindow(size=0)


def test_metrics_snapshot_midrun_under_background_loop():
  """The whole point of metrics.py: a consistent snapshot while the
  background loop is actively serving — no stop, no drain."""
  eng = MMOEngine(backend="xla", max_batch=4)
  eng.prewarm([apsp_request(graphs.weighted_digraph(12, 0.3, seed=0))])
  eng.start()
  try:
    futs = [eng.submit(apsp_request(
        graphs.weighted_digraph(10 + (i % 4), 0.3, seed=i)))
        for i in range(24)]
    mid = eng.metrics_snapshot()  # taken while the loop is mid-drain
    assert mid["counters"]["submitted"] == 24
    assert mid["counters"]["rejected"] == 0
    assert 0 <= mid["queue_depth"] <= 24
    assert mid["admission"]["queued"] == mid["queue_depth"]
    for f in futs:
      f.result(timeout=120)
  finally:
    eng.stop()
  done = eng.metrics_snapshot()
  assert done["counters"]["completed"] == 24 and done["queue_depth"] == 0
  (label,) = [k for k in done["buckets"] if k.startswith("closure/minplus")]
  b = done["buckets"][label]
  assert b["completed"] == 24
  assert b["service_ms"]["p50"] <= b["service_ms"]["p99"]
  assert b["queue_ms"]["p99"] >= 0.0


def test_metrics_snapshot_concurrent_with_serving_is_safe():
  """Hammer snapshot from a second thread while the loop serves: no
  exceptions, monotone counters."""
  eng = MMOEngine(backend="xla", max_batch=4)
  eng.prewarm([_mmo(12)])
  eng.start()
  seen, errs = [], []

  def poll():
    try:
      for _ in range(50):
        seen.append(eng.metrics_snapshot()["counters"]["completed"])
        time.sleep(0.002)
    except Exception as e:  # noqa: BLE001
      errs.append(e)

  t = threading.Thread(target=poll)
  t.start()
  futs = [eng.submit(_mmo(12)) for _ in range(32)]
  for f in futs:
    f.result(timeout=120)
  t.join()
  eng.stop()
  assert not errs
  assert seen == sorted(seen)  # completed counter never goes backwards


# ---------------------------------------------------------------------------
# end-to-end: policies through the engine produce correct results
# ---------------------------------------------------------------------------


@pytest.mark.parametrize("policy", ["fifo", "deadline", "fair"])
def test_engine_results_correct_under_every_policy(policy):
  from repro.apps import solvers
  eng = MMOEngine(backend="xla", max_batch=4, policy=policy)
  ws = {n: graphs.weighted_digraph(n, 0.3, seed=n) for n in (9, 11, 13)}
  futs = {n: eng.submit(apsp_request(w, tenant=f"t{n % 2}", deadline_s=600.0))
          for n, w in ws.items()}
  eng.run_until_idle()
  for n, w in ws.items():
    ref, _ = solvers.apsp(w)
    np.testing.assert_allclose(futs[n].result().value, np.asarray(ref),
                               atol=1e-5)


def test_engine_rejects_unknown_policy():
  with pytest.raises(ValueError, match="unknown policy"):
    MMOEngine(backend="xla", policy="lifo")


def test_request_bucket_ignores_qos_fields():
  """QoS fields must not fragment buckets: a tagged and an untagged request
  of the same shape share one executable."""
  w = graphs.weighted_digraph(12, 0.3, seed=0)
  assert (request_bucket(apsp_request(w))
          == request_bucket(apsp_request(w, tenant="x", priority=3,
                                         deadline_s=1.0)))

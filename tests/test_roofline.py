"""Roofline extraction: the HLO walker must be loop-correct and agree with
XLA on loop-free programs."""
import os
import subprocess
import sys
import textwrap

import pytest

SRC = os.path.join(os.path.dirname(__file__), "..", "src")

_SCRIPT = textwrap.dedent("""
    import os
    os.environ["XLA_FLAGS"] = "--xla_force_host_platform_device_count=8"
    import jax, jax.numpy as jnp
    from jax.sharding import NamedSharding, PartitionSpec as P
    from repro.roofline.hlo_walk import module_cost

    M = 256
    def rolled(ws, x):
        def body(x, w):
            return x @ w, ()
        x, _ = jax.lax.scan(body, x, ws)
        return x
    def unrolled(ws, x):
        for i in range(16):
            x = x @ ws[i]
        return x

    sw = jax.ShapeDtypeStruct((16, M, M), jnp.float32)
    sx = jax.ShapeDtypeStruct((M, M), jnp.float32)
    c_r = module_cost(jax.jit(rolled).lower(sw, sx).compile().as_text())
    co_u = jax.jit(unrolled).lower(sw, sx).compile()
    c_u = module_cost(co_u.as_text())
    expect = 16 * 2 * M ** 3
    assert c_r.flops == expect, (c_r.flops, expect)
    assert c_u.flops == expect
    # agreement with XLA's own counter on the loop-free program
    xla_cost = co_u.cost_analysis()
    if isinstance(xla_cost, (list, tuple)):  # older jax: per-device list
        xla_cost = xla_cost[0]
    assert abs(c_u.flops - xla_cost["flops"]) < 1e-6
    print("FLOPS_OK")

    # collective accounting: K-sharded matmul → one all-reduce of (M,M) f32
    mesh = jax.make_mesh((8,), ("model",))
    def f(a, b):
        return a @ b
    j = jax.jit(f, in_shardings=(NamedSharding(mesh, P(None, "model")),
                                 NamedSharding(mesh, P("model", None))),
                out_shardings=NamedSharding(mesh, P(None, None)))
    co = j.lower(sx, sx).compile()
    c = module_cost(co.as_text())
    ring = 2 * (8 - 1) / 8 * M * M * 4
    assert abs(c.coll_bytes - ring) / ring < 0.05, (c.coll_bytes, ring)
    assert abs(c.flops - 2 * M ** 3 / 8) < 1e-6
    print("COLL_OK")
""")


@pytest.mark.slow
def test_hlo_walker():
  env = dict(os.environ, PYTHONPATH=SRC)
  r = subprocess.run([sys.executable, "-c", _SCRIPT], capture_output=True,
                     text=True, env=env, timeout=600)
  assert r.returncode == 0, r.stderr[-3000:]
  assert "FLOPS_OK" in r.stdout
  assert "COLL_OK" in r.stdout


def test_roofline_terms():
  from repro.roofline.analysis import Roofline
  r = Roofline(arch="x", shape="train_4k", mesh="single", chips=256,
               hlo_flops=256 * 197e12,       # exactly 1s of compute
               hlo_bytes=256 * 819e9 * 0.5,  # 0.5s of memory
               coll_bytes=50e9 * 4 * 0.25,   # 0.25s of collective
               coll_breakdown={}, model_flops=256 * 197e12 * 0.5)
  assert abs(r.t_compute - 1.0) < 1e-9
  assert abs(r.t_memory - 0.5) < 1e-9
  assert abs(r.t_collective - 0.25) < 1e-9
  assert r.bottleneck == "compute"
  assert abs(r.mfu_bound - 0.5) < 1e-9


def test_collective_parser_shapes():
  from repro.roofline.collectives import collective_bytes
  hlo = '''
  %x = bf16[16,128]{1,0} all-gather(%a), replica_groups=[2,8]<=[16], dimensions={0}
  %y = f32[64]{0} all-reduce-start(%b), replica_groups={{0,1,2,3}}
  '''
  out = collective_bytes(hlo)
  ag = (8 - 1) / 8 * 16 * 128 * 2
  ar = 2 * (4 - 1) / 4 * 64 * 4
  assert abs(out["all-gather"] - ag) < 1e-6
  assert abs(out["all-reduce"] - ar) < 1e-6

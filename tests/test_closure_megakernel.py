"""Parity suite for the fused Pallas closure megakernel.

The megakernel's contract is *bit-identity* with the per-iteration
``_batched_fixpoint`` path — outputs AND per-request iteration counts —
for every ring with a ⊗-identity, under ragged ``valid_n``, mixed
convergence speeds, and chunk lengths that do not divide the trip count.
Everything here runs the kernel in interpret mode (CPU CI); on TPU the
same calls compile to the real fused program.
"""
import jax.numpy as jnp
import numpy as np
import pytest

from fixtures import closure_corpus as corpus
from fixtures.closure_corpus import IDENTITY_RINGS, line_graph

from repro.core import closure as cl_mod


def _rand_adj(op, n, r, seed=0):
  return jnp.asarray(corpus.rand_adj(op, n, r, seed=seed))


def _assert_parity(op, algorithm, adj, *, valid_n=None, g=3, max_iters=None):
  """Reference vs megakernel: outputs and iteration counts bit-identical."""
  solver = (cl_mod.batched_leyzorek_closure if algorithm == "leyzorek"
            else cl_mod.batched_bellman_ford_closure)
  ref_out, ref_it = solver(adj, op=op, backend="xla", valid_n=valid_n,
                           max_iters=max_iters)
  mk_out, mk_it = solver(adj, op=op, fixpoint_backend="megakernel",
                         megakernel_g=g, valid_n=valid_n,
                         max_iters=max_iters, interpret=True)
  np.testing.assert_array_equal(np.asarray(mk_out), np.asarray(ref_out))
  np.testing.assert_array_equal(np.asarray(mk_it), np.asarray(ref_it))
  return np.asarray(ref_it)


@pytest.mark.parametrize("algorithm", ("leyzorek", "bellman_ford"))
@pytest.mark.parametrize("op", IDENTITY_RINGS)
def test_parity_all_rings(op, algorithm):
  adj = _rand_adj(op, 12, 2, seed=hash(op) % 1000)
  _assert_parity(op, algorithm, adj, g=3)


@pytest.mark.parametrize("case", corpus.CORPUS, ids=corpus.CASE_IDS)
def test_corpus_parity_megakernel(case):
  """The shared adversarial corpus, megakernel vs reference: every case the
  serving paths are pinned on must hold through the fused kernel too."""
  solver = (cl_mod.batched_leyzorek_closure if case.algorithm == "leyzorek"
            else cl_mod.batched_bellman_ford_closure)
  stack, valid = corpus.stacked(case)
  ref_out, ref_it = corpus.reference(case)
  mk_out, mk_it = solver(stack, op=case.op, fixpoint_backend="megakernel",
                         megakernel_g=3, valid_n=valid,
                         max_iters=case.max_iters, interpret=True)
  np.testing.assert_array_equal(np.asarray(mk_out), ref_out)
  np.testing.assert_array_equal(np.asarray(mk_it), ref_it)


def _line_graph(n, seed=0):
  return line_graph(n, seed=seed)


def test_parity_ragged_valid_n():
  """Mixed true sizes inside one padded bucket: the kernel's scalar-
  prefetched per-request live-n must reproduce the reference's masked-K
  semantics exactly."""
  nb = 16
  sizes = (9, 11, 16)
  prepared = [cl_mod.prepare_adjacency(jnp.asarray(_line_graph(n, seed=n)),
                                       op="minplus") for n in sizes]
  stack = jnp.stack([jnp.asarray(cl_mod.pad_adjacency(p, nb, op="minplus"))
                     for p in prepared])
  valid = jnp.asarray(sizes, jnp.int32)
  for algorithm in ("leyzorek", "bellman_ford"):
    _assert_parity("minplus", algorithm, stack, valid_n=valid, g=4)


def test_parity_converged_slot_freezes():
  """An already-closed request co-batched with a straggler: both paths must
  stop its counter at 1 (the probe iteration that detects no change) while
  the straggler keeps iterating.  Unit edge weights keep every path sum
  exactly representable, so the closure is a bit-stable fixpoint (random
  float weights re-associate by one ulp under a different hop split)."""
  n = 10
  w = np.full((n, n), np.inf, np.float32)
  w[np.arange(n - 1), np.arange(1, n)] = 1.0
  line = cl_mod.prepare_adjacency(jnp.asarray(w), op="minplus")
  closed, _ = cl_mod.batched_bellman_ford_closure(line[None], op="minplus",
                                                  backend="xla")
  stack = jnp.concatenate([closed, line[None]])
  it = _assert_parity("minplus", "bellman_ford", stack, g=4)
  assert it[0] == 1
  assert it[1] > it[0]


@pytest.mark.parametrize("g", (1, 3, 4, 7, 64))
def test_parity_g_not_dividing_trip_count(g):
  """A line graph's Bellman-Ford runs ~n iterations; sweep chunk lengths
  that undershoot, straddle, and overshoot it — the per-chunk live budget
  must keep the max_iters cap and the counters exact."""
  n = 10
  adj = cl_mod.prepare_adjacency(jnp.asarray(_line_graph(n)),
                                 op="minplus")[None]
  it = _assert_parity("minplus", "bellman_ford", adj, g=g)
  # diameter n−1: the last change lands on step n−2, the no-change probe
  # that freezes the request is step n−1 — one short of the max_iters cap
  assert it[0] == n - 1


def test_parity_max_iters_cap():
  """max_iters smaller than the natural trip count: both paths stop at the
  cap, even when G does not divide it."""
  n = 12
  adj = cl_mod.prepare_adjacency(jnp.asarray(_line_graph(n)),
                                 op="minplus")[None]
  it = _assert_parity("minplus", "bellman_ford", adj, g=5, max_iters=7)
  assert it[0] == 7


def test_nan_aware_changed_regression():
  """A NaN edge weight used to spin the fixpoint to max_iters: NaN != NaN
  made ``_changed`` report progress forever.  After the fix, NaN cells
  compare equal to themselves and the request converges normally — and the
  megakernel's in-kernel reduction agrees bit-for-bit."""
  n = 8
  w = _line_graph(n)
  w[0, 1] = np.nan
  adj = cl_mod.prepare_adjacency(jnp.asarray(w), op="minplus")[None]
  ref_out, ref_it = cl_mod.batched_bellman_ford_closure(adj, op="minplus",
                                                        backend="xla")
  assert int(ref_it[0]) < n, "NaN request must converge before the cap"
  assert np.isnan(np.asarray(ref_out)).any()
  _assert_parity("minplus", "bellman_ford", adj, g=3)


def test_backend_alias_routes_to_megakernel():
  """backend='megakernel' (the cost-table spelling) and
  fixpoint_backend='megakernel' are the same arm."""
  adj = _rand_adj("minplus", 8, 2, seed=3)
  a_out, a_it = cl_mod.batched_leyzorek_closure(
      adj, op="minplus", backend="megakernel", interpret=True)
  b_out, b_it = cl_mod.batched_leyzorek_closure(
      adj, op="minplus", fixpoint_backend="megakernel", interpret=True)
  np.testing.assert_array_equal(np.asarray(a_out), np.asarray(b_out))
  np.testing.assert_array_equal(np.asarray(a_it), np.asarray(b_it))


def test_addnorm_refused():
  adj = jnp.zeros((1, 8, 8), jnp.float32)
  with pytest.raises(ValueError, match="⊗-identity"):
    cl_mod.batched_leyzorek_closure(adj, op="addnorm",
                                    fixpoint_backend="megakernel",
                                    interpret=True)


def test_unknown_fixpoint_backend_refused():
  adj = jnp.zeros((1, 8, 8), jnp.float32)
  with pytest.raises(ValueError, match="fixpoint_backend"):
    cl_mod.batched_leyzorek_closure(adj, op="minplus",
                                    fixpoint_backend="nope")


def test_mmo_refuses_megakernel_backend():
  """A single contraction cannot run a fused fixpoint — mmo points callers
  at the closure entry points instead of silently falling back."""
  from repro.core.mmo import mmo
  a = jnp.zeros((8, 8), jnp.float32)
  with pytest.raises(ValueError, match="megakernel"):
    mmo(a, a, op="minplus", backend="megakernel")


# ---------------------------------------------------------------------------
# dispatch containment: the megakernel arm competes only where a closure-
# owning dispatcher opts in
# ---------------------------------------------------------------------------


def test_cost_table_prior_amortizes_bandwidth():
  """For a bandwidth-bound point the fused arm's prior divides the HBM term
  by G, so at G=8 it must undercut the per-iteration pallas prior."""
  from repro.tuning import prior_seconds
  shape = (64, 64, 64)
  pal = prior_seconds("minplus", shape, "float32", "pallas", (128,))
  mk8 = prior_seconds("minplus", shape, "float32", "megakernel", (8,))
  assert mk8 < pal


def test_best_default_order_excludes_megakernel():
  from repro.tuning import CLOSURE_BACKENDS, CostTable
  table = CostTable(device="test")
  shape = (16, 16, 16)
  table.record("minplus", shape, "float32", "xla", (), 1.0)
  table.record("minplus", shape, "float32", "megakernel", (8,), 1e-9)
  d = table.best("minplus", shape, "float32")
  assert d.backend == "xla", "default pool must never surface megakernel"
  d = table.best("minplus", shape, "float32", backends=CLOSURE_BACKENDS)
  assert d.backend == "megakernel" and d.cfg == (8,)


def test_engine_routes_closure_bucket_to_megakernel():
  """End to end: a cost table that says the fused arm wins a closure bucket
  → resolve_backend picks it for closure only → the batch executes through
  the megakernel (interpret mode) and returns the exact reference APSP."""
  from repro.serve_mmo import MMOEngine, apsp_request
  from repro.tuning import CostTable
  table = CostTable(device="test")
  nb = (16, 16, 16)
  table.record("minplus", nb, "float32", "xla", (), 1.0, source="measured")
  table.record("minplus", nb, "float32", "megakernel", (4,), 1e-9,
               source="measured")
  eng = MMOEngine(backend="auto", max_batch=4, cost_table=table)
  w = _line_graph(12, seed=5)
  fut = eng.submit(apsp_request(w))
  eng.run_until_idle()
  key = next(iter(eng._decisions))
  assert eng._decisions[key] == ("megakernel", (4,))
  ref, ref_it = cl_mod.batched_leyzorek_closure(
      cl_mod.prepare_adjacency(jnp.asarray(w), op="minplus")[None],
      op="minplus", backend="xla")
  got = fut.result()
  np.testing.assert_array_equal(got.value, np.asarray(ref[0]))
  assert got.extras["iterations"] == int(ref_it[0])


def test_engine_mmo_bucket_never_sees_megakernel():
  """The same winning row must NOT leak into a plain contraction bucket:
  its pool is the per-contraction backends."""
  from repro.serve_mmo import MMOEngine, mmo_request
  from repro.tuning import CostTable
  table = CostTable(device="test")
  nb = (16, 16, 16)
  table.record("minplus", nb, "float32", "xla", (), 1.0, source="measured")
  table.record("minplus", nb, "float32", "megakernel", (4,), 1e-9,
               source="measured")
  eng = MMOEngine(backend="auto", max_batch=4, cost_table=table)
  rng = np.random.default_rng(0)
  a = rng.standard_normal((12, 12)).astype(np.float32)
  fut = eng.submit(mmo_request(a, a, op="minplus"))
  eng.run_until_idle()
  assert all(b != "megakernel" for b, _ in eng._decisions.values())
  assert fut.done()

"""MMO serving engine: batched semiring execution, scheduler, cache, e2e."""
import time

import numpy as np
import pytest

import jax.numpy as jnp

from repro.apps import graphs, solvers
from repro.core import (batched_bellman_ford_closure, batched_leyzorek_closure,
                        bellman_ford_closure, leyzorek_closure, mmo_batched,
                        mmo_reference, pad_adjacency, prepare_adjacency)
from repro.serve_mmo import (MMOEngine, apsp_request, closure_request,
                             knn_request, mmo_request, reachability_request)
from repro.serve_mmo.scheduler import (FifoBucketScheduler, bucket_dim,
                                       request_bucket)

RNG = np.random.default_rng(0)


# ---------------------------------------------------------------------------
# batched semiring execution: vmapped mmo parity across backends, with C
# ---------------------------------------------------------------------------


@pytest.mark.parametrize("op", ["mma", "minplus", "maxmin", "addnorm",
                                "maxmul", "orand"])
@pytest.mark.parametrize("backend", ["vector", "xla", "pallas"])
def test_mmo_batched_backend_parity(op, backend):
  r, m, k, n = 3, 7, 11, 5
  a = RNG.standard_normal((r, m, k)).astype(np.float32)
  b = RNG.standard_normal((r, k, n)).astype(np.float32)
  c = RNG.standard_normal((r, m, n)).astype(np.float32)
  if op == "orand":
    a, b, c = a > 0.3, b > 0.3, c > 0.8
  kw = {"interpret": True} if backend == "pallas" else {}
  got = mmo_batched(jnp.asarray(a), jnp.asarray(b), jnp.asarray(c), op=op,
                    backend=backend, **kw)
  ref = mmo_reference(jnp.asarray(a), jnp.asarray(b), jnp.asarray(c), op=op)
  np.testing.assert_allclose(np.asarray(got, np.float64),
                             np.asarray(ref, np.float64), atol=1e-4)


def test_mmo_batched_rejects_2d():
  a = jnp.zeros((3, 4))
  with pytest.raises(ValueError):
    mmo_batched(a, a)


def test_mmo_batched_rejects_2d_c():
  a = jnp.zeros((2, 3, 4))
  b = jnp.zeros((2, 4, 5))
  with pytest.raises(ValueError, match=r"\(R, M, N\) for c"):
    mmo_batched(a, b, jnp.zeros((3, 5)))
  with pytest.raises(ValueError, match="request-axis mismatch"):
    mmo_batched(a, b, jnp.zeros((3, 3, 5)))


@pytest.mark.parametrize("op", ["minplus", "maxmin", "orand"])
@pytest.mark.parametrize("algorithm", ["leyzorek", "bellman_ford"])
def test_ragged_masked_k_closure_matches_padded(op, algorithm):
  """valid_n work skipping changes which K-blocks execute, never the result
  (padded lanes are algebraic no-ops, converged requests are frozen)."""
  sizes = [6, 9, 13, 16]
  nb = 16
  if op == "orand":
    ws = [graphs.boolean_digraph(n, 0.15, seed=n) for n in sizes]
  elif op == "maxmin":
    ws = [graphs.capacity_graph(n, 0.3, seed=n) for n in sizes]
  else:
    ws = [graphs.weighted_digraph(n, 0.3, seed=n) for n in sizes]
  prepared = [prepare_adjacency(jnp.asarray(w), op=op) for w in ws]
  stack = jnp.stack([pad_adjacency(p, nb, op=op) for p in prepared])
  solver = (batched_leyzorek_closure if algorithm == "leyzorek"
            else batched_bellman_ford_closure)
  valid = jnp.asarray(sizes, jnp.int32)
  # small block_k so ragged skipping actually partitions the K axis
  def mmo_fn(a, b, c, op_, bk, k_valid=None):
    from repro.core.mmo import mmo
    return mmo(a, b, c, op=op_, backend=bk, block_k=4, k_valid=k_valid)

  padded, it_p = solver(stack, op=op, backend="xla", mmo_fn=mmo_fn)
  ragged, it_r = solver(stack, op=op, backend="xla", mmo_fn=mmo_fn,
                        valid_n=valid)
  np.testing.assert_allclose(np.asarray(ragged, np.float64),
                             np.asarray(padded, np.float64), atol=1e-5)
  np.testing.assert_array_equal(np.asarray(it_r), np.asarray(it_p))


@pytest.mark.parametrize("op", ["minplus", "maxmin", "orand"])
def test_batched_closure_matches_unbatched(op):
  """Padded (R, nb, nb) batched closure == per-request closure, and the
  per-request convergence mask reports sane iteration counts."""
  sizes = [6, 9, 13, 16]
  nb = 16
  if op == "orand":
    ws = [graphs.boolean_digraph(n, 0.15, seed=n) for n in sizes]
  elif op == "maxmin":
    ws = [graphs.capacity_graph(n, 0.3, seed=n) for n in sizes]
  else:
    ws = [graphs.weighted_digraph(n, 0.3, seed=n) for n in sizes]
  prepared = [prepare_adjacency(jnp.asarray(w), op=op) for w in ws]
  stack = jnp.stack([pad_adjacency(p, nb, op=op) for p in prepared])

  out, iters = batched_leyzorek_closure(stack, op=op)
  assert iters.shape == (len(sizes),)
  for i, (n, p) in enumerate(zip(sizes, prepared)):
    ref, ref_it = leyzorek_closure(p, op=op)
    np.testing.assert_allclose(np.asarray(out[i, :n, :n], np.float64),
                               np.asarray(ref, np.float64), atol=1e-5)
    assert int(iters[i]) >= int(ref_it)  # padded run can't converge sooner

  out_bf, _ = batched_bellman_ford_closure(stack, op=op)
  for i, (n, p) in enumerate(zip(sizes, prepared)):
    ref_bf, _ = bellman_ford_closure(p, op=op)
    np.testing.assert_allclose(np.asarray(out_bf[i, :n, :n], np.float64),
                               np.asarray(ref_bf, np.float64), atol=1e-5)


# ---------------------------------------------------------------------------
# scheduler
# ---------------------------------------------------------------------------


def test_bucket_dim():
  assert bucket_dim(1) == 8 and bucket_dim(8) == 8
  assert bucket_dim(9) == 16 and bucket_dim(16) == 16
  assert bucket_dim(100) == 128
  with pytest.raises(ValueError):
    bucket_dim(0)


def test_bucketing_determinism():
  """Equal-spec requests always map to the same bucket; different static
  params or dtypes split buckets."""
  w = graphs.weighted_digraph(11, 0.3, seed=1)
  k1 = request_bucket(apsp_request(w))
  k2 = request_bucket(apsp_request(graphs.weighted_digraph(13, 0.4, seed=9)))
  assert k1 == k2  # 11 and 13 both pad to 16, same ring/kind/dtype
  assert k1 != request_bucket(closure_request(w, op="minplus",
                                              algorithm="bellman_ford"))
  assert k1 != request_bucket(reachability_request(w > 5.0))  # bool / orand
  q, r = graphs.knn_points(20, 6, 4, seed=0)
  assert (request_bucket(knn_request(q[:6], r, k=3))
          != request_bucket(knn_request(q[:6], r, k=4)))  # k is static


def test_scheduler_fifo_within_bucket_and_oldest_bucket_first():
  sched = FifoBucketScheduler(max_batch=2)
  small = [apsp_request(graphs.weighted_digraph(10, 0.3, seed=i))
           for i in range(3)]
  big = apsp_request(graphs.weighted_digraph(40, 0.3, seed=7))
  sched.add(small[0])
  sched.add(small[1])
  sched.add(big)
  sched.add(small[2])
  key1, batch1 = sched.next_batch()
  assert [r is s for r, s in zip(batch1, small[:2])] == [True, True]  # FIFO
  key2, batch2 = sched.next_batch()
  assert batch2 == [big]  # big arrived before small[2] → its bucket goes next
  _, batch3 = sched.next_batch()
  assert batch3 == [small[2]]
  assert sched.next_batch() is None and len(sched) == 0


def test_engine_completion_order_fifo():
  eng = MMOEngine(backend="xla", max_batch=2)
  ws = [graphs.weighted_digraph(12, 0.3, seed=i) for i in range(5)]
  futs = [eng.submit(apsp_request(w)) for w in ws]
  eng.run_until_idle()
  order = [r.request_id for r in eng._records]
  assert order == sorted(order)  # same-bucket completion order == submit order


# ---------------------------------------------------------------------------
# padding correctness through the full engine path (odd shapes, all kinds)
# ---------------------------------------------------------------------------


def test_engine_padding_correctness_mixed():
  eng = MMOEngine(backend="xla", max_batch=4)
  futs = {}

  ws = {n: graphs.weighted_digraph(n, 0.3, seed=n) for n in (9, 11, 13, 17)}
  for n, w in ws.items():
    futs[("apsp", n)] = eng.submit(apsp_request(w))

  adj = graphs.boolean_digraph(10, 0.15, seed=5)
  futs["reach"] = eng.submit(reachability_request(adj))

  ref_pts, qry_pts = graphs.knn_points(21, 7, 5, seed=3)
  futs["knn"] = eng.submit(knn_request(qry_pts, ref_pts, k=4))

  a = RNG.standard_normal((5, 9)).astype(np.float32)
  b = RNG.standard_normal((9, 6)).astype(np.float32)
  c = RNG.standard_normal((5, 6)).astype(np.float32)
  futs["mmo"] = eng.submit(mmo_request(a, b, c, op="maxmin"))

  assert eng.run_until_idle() == len(futs)

  for n, w in ws.items():
    ref, _ = solvers.apsp(w)
    np.testing.assert_allclose(futs[("apsp", n)].result().value,
                               np.asarray(ref), atol=1e-5)
  ref, _ = solvers.gtc(adj)
  np.testing.assert_array_equal(futs["reach"].result().value, np.asarray(ref))
  d2, idx = solvers.knn(ref_pts, qry_pts, k=4)
  res = futs["knn"].result()
  np.testing.assert_allclose(res.value, np.asarray(d2), atol=1e-3)
  np.testing.assert_array_equal(res.extras["indices"], np.asarray(idx))
  ref = mmo_reference(jnp.asarray(a), jnp.asarray(b), jnp.asarray(c),
                      op="maxmin")
  np.testing.assert_allclose(futs["mmo"].result().value, np.asarray(ref),
                             atol=1e-5)


def test_knn_large_coordinates_ignore_padded_rows():
  """Padded corpus rows are masked by the valid-row count, so results stay
  correct for data at any magnitude (no far-away sentinel to collide with)."""
  ref_pts, qry_pts = graphs.knn_points(21, 7, 5, seed=3)
  ref_pts = ref_pts + 1.0e6   # sit right where a magic pad point would
  qry_pts = qry_pts + 1.0e6
  eng = MMOEngine(backend="xla")
  res = eng.submit(knn_request(qry_pts, ref_pts, k=4)).result()
  assert res.extras["indices"].max() < 21  # never a padded row
  _, idx = solvers.knn(ref_pts, qry_pts, k=4)
  np.testing.assert_array_equal(res.extras["indices"], np.asarray(idx))


def test_stop_without_loop_drains_synchronously():
  eng = MMOEngine(backend="xla")
  fut = eng.submit(apsp_request(graphs.weighted_digraph(10, 0.3, seed=0)))
  eng.stop()  # no background loop ever started — must not hang
  assert fut.done() and fut.result().value.shape == (10, 10)


def test_submit_after_stop_raises_cleanly():
  """Pinned decision: stop() is a terminal accepting state — submit raises
  a RuntimeError instead of queueing onto a loop nobody will run; start()
  re-arms the engine."""
  eng = MMOEngine(backend="xla")
  eng.start()
  eng.submit(apsp_request(graphs.weighted_digraph(10, 0.3, seed=0)))
  eng.stop()
  with pytest.raises(RuntimeError, match="stopped engine"):
    eng.submit(apsp_request(graphs.weighted_digraph(10, 0.3, seed=1)))
  eng.start()  # restart re-arms submission
  fut = eng.submit(apsp_request(graphs.weighted_digraph(10, 0.3, seed=2)))
  eng.stop()
  assert fut.result().value.shape == (10, 10)


def test_stats_summary_on_idle_engine_does_not_crash():
  """EngineStats.summary() on an engine that served zero requests must stay
  printable (no division by zero, no empty-percentile blowup) and must
  carry the rejected/expired counters."""
  eng = MMOEngine(backend="xla")
  st = eng.stats()
  s = st.summary()
  assert "completed=0" in s and "p50=n/a" in s
  assert "rejected=0" in s and "expired=0" in s
  assert st.mean_batch == 0.0 and np.isnan(st.percentile(99))


def test_engine_closure_reports_iterations():
  eng = MMOEngine(backend="xla")
  w = graphs.weighted_digraph(12, 0.3, seed=0)
  res = eng.submit(apsp_request(w)).result()
  _, it = solvers.apsp(w)
  assert res.extras["iterations"] >= int(it) >= 1


# ---------------------------------------------------------------------------
# executable cache: steady-state traffic never retraces
# ---------------------------------------------------------------------------


def test_cache_zero_recompiles_on_repeat_traffic():
  eng = MMOEngine(backend="xla", max_batch=4)
  def traffic():
    futs = [eng.submit(apsp_request(graphs.weighted_digraph(n, 0.3, seed=n)))
            for n in (9, 10, 12, 14)]
    futs.append(eng.submit(reachability_request(
        graphs.boolean_digraph(11, 0.15, seed=1))))
    eng.run_until_idle()
    return futs

  traffic()
  misses = eng.cache.misses
  assert misses > 0
  futs = traffic()  # identical shapes → identical buckets → pure cache hits
  assert eng.cache.misses == misses
  assert all(f.done() for f in futs)


def test_mixed_backend_buckets_zero_retraces():
  """Steady-state serving with *per-bucket* backend selection: two buckets
  resolved to different backends replay their executables with zero cache
  misses after warmup — the dispatch decision is part of the cache key."""
  from repro.tuning import CostTable
  table = CostTable(device="test")
  nb = (16, 16, 16)
  table.record("minplus", nb, "float32", "vector", (128,), 1e-6)
  table.record("minplus", nb, "float32", "xla", (512,), 1.0)
  table.record("orand", nb, "bool", "xla", (512,), 1e-6)
  table.record("orand", nb, "bool", "vector", (128,), 1.0)
  eng = MMOEngine(backend="auto", max_batch=4, cost_table=table)

  def traffic():
    futs = [eng.submit(apsp_request(graphs.weighted_digraph(n, 0.3, seed=n)))
            for n in (9, 11, 13)]
    futs.append(eng.submit(reachability_request(
        graphs.boolean_digraph(10, 0.15, seed=1))))
    eng.run_until_idle()
    return futs

  futs = traffic()
  assert {b for b, _ in eng._decisions.values()} == {"vector", "xla"}
  misses = eng.cache.misses
  assert misses > 0
  futs2 = traffic()  # steady state: mixed backends, zero retraces
  assert eng.cache.misses == misses
  assert all(f.done() for f in futs + futs2)
  for fut, n in zip(futs, (9, 11, 13)):
    ref, _ = solvers.apsp(graphs.weighted_digraph(n, 0.3, seed=n))
    np.testing.assert_allclose(fut.result().value, np.asarray(ref), atol=1e-5)
  ref, _ = solvers.gtc(graphs.boolean_digraph(10, 0.15, seed=1))
  np.testing.assert_array_equal(futs[-1].result().value, np.asarray(ref))


def test_prewarm_resolves_like_step():
  """prewarm and step must agree on the (backend, block) part of the cache
  key, or warmed engines would recompile on first real traffic."""
  from repro.tuning import CostTable
  table = CostTable(device="test")
  table.record("minplus", (16, 16, 16), "float32", "vector", (128,), 1e-6)
  eng = MMOEngine(backend="auto", max_batch=2, cost_table=table)
  eng.prewarm([apsp_request(graphs.weighted_digraph(10, 0.3, seed=0))])
  misses = eng.cache.misses
  eng.submit(apsp_request(graphs.weighted_digraph(12, 0.3, seed=1)))
  eng.run_until_idle()
  assert eng.cache.misses == misses


def test_prewarm_covers_batch_variants():
  eng = MMOEngine(backend="xla", max_batch=4)
  sample = [apsp_request(graphs.weighted_digraph(10, 0.3, seed=0))]
  compiled = eng.prewarm(sample)
  assert compiled == 3  # batch buckets 1, 2, 4
  misses = eng.cache.misses
  for i in range(3):  # batch of 3 → rounds up to the prewarmed 4
    eng.submit(apsp_request(graphs.weighted_digraph(12, 0.3, seed=i)))
  eng.run_until_idle()
  assert eng.cache.misses == misses


# ---------------------------------------------------------------------------
# futures / background loop
# ---------------------------------------------------------------------------


def test_future_lazy_result_drives_engine():
  eng = MMOEngine(backend="xla")
  fut = eng.submit(apsp_request(graphs.weighted_digraph(10, 0.3, seed=2)))
  assert not fut.done()
  res = fut.result()  # drives step() internally
  assert fut.done() and res.value.shape == (10, 10)


def test_background_loop_serves():
  eng = MMOEngine(backend="xla", max_batch=4)
  eng.start()
  futs = [eng.submit(apsp_request(graphs.weighted_digraph(10, 0.3, seed=i)))
          for i in range(6)]
  results = [f.result(timeout=120) for f in futs]
  eng.stop()
  assert all(r.value.shape == (10, 10) for r in results)


def test_request_validation():
  with pytest.raises(ValueError):
    mmo_request(np.zeros((3, 4)), np.zeros((5, 6)))  # contraction mismatch
  with pytest.raises(ValueError):
    closure_request(np.zeros((3, 4)), op="minplus")  # non-square
  with pytest.raises(ValueError):
    knn_request(np.zeros((2, 3)), np.zeros((4, 3)), k=9)  # k > corpus
  with pytest.raises(ValueError):
    closure_request(np.zeros((3, 3)), op="nope")  # unknown ring


# ---------------------------------------------------------------------------
# engine concurrency seams (the PR-3 bugfix sweep)
# ---------------------------------------------------------------------------


def test_dropped_request_raises_runtime_error_not_timeout():
  """A request the scheduler loses is an engine bug: result() must say so
  (naming the request), not claim it timed out 'within Nones'."""
  eng = MMOEngine(backend="xla")
  fut = eng.submit(apsp_request(graphs.weighted_digraph(10, 0.3, seed=0)))
  eng.scheduler._buckets.clear()  # simulate the engine losing the request
  with pytest.raises(RuntimeError, match=rf"request {fut.request.request_id}"
                                         r".*dropped"):
    fut.result()


def test_dropped_request_raises_in_background_loop_mode():
  """Same engine bug with the background loop running: result(timeout=None)
  must raise instead of blocking forever on an event nobody will set."""
  eng = MMOEngine(backend="xla")
  fut = eng.submit(apsp_request(graphs.weighted_digraph(10, 0.3, seed=0)))
  eng.scheduler._buckets.clear()  # lose it before the loop can serve it
  eng.start()
  try:
    with pytest.raises(RuntimeError, match=r"dropped"):
      fut.result()
  finally:
    eng.stop(drain=False)


def test_timeout_message_formats_seconds():
  eng = MMOEngine(backend="xla")
  fut = eng.submit(apsp_request(graphs.weighted_digraph(10, 0.3, seed=0)))
  # a zero timeout expires before the first step; the message must carry a
  # readable duration (the old f-string printed 'within Nones' for None)
  with pytest.raises(TimeoutError, match=r"not done within 0s"):
    fut.result(timeout=0.0)
  assert fut.result().value.shape == (10, 10)  # still servable afterwards


def test_resolve_backend_is_threadsafe(monkeypatch):
  """prewarm() on the caller thread races step() on the loop thread into
  resolve_backend; the memoization must be atomic so every caller sees one
  decision even when the cost table's answer changes between calls."""
  import threading as th
  from repro.tuning import Decision
  from repro.tuning import dispatch as dsp

  calls = []

  def slow_resolve(op, m, k, n, dtype, **kw):
    calls.append(None)
    time.sleep(0.005)  # widen the check-then-memoize window
    return Decision(f"backend-{len(calls)}", (), 1.0, "measured")

  monkeypatch.setattr(dsp, "resolve", slow_resolve)
  eng = MMOEngine(backend="auto")
  key = request_bucket(apsp_request(graphs.weighted_digraph(10, 0.3, seed=0)))

  out, barrier = [], th.Barrier(8)

  def hammer():
    barrier.wait()
    for _ in range(10):
      out.append(eng.resolve_backend(key))

  threads = [th.Thread(target=hammer) for _ in range(8)]
  for t in threads:
    t.start()
  for t in threads:
    t.join()
  assert len(set(out)) == 1, f"divergent memoized decisions: {set(out)}"
  assert len(calls) == 1  # resolved exactly once, under the engine lock


def test_stop_drain_wakes_on_empty_pending():
  """stop(drain=True) must return promptly once the loop empties the queue
  (condition-variable wait, not a sleep-poll) and leave everything done."""
  eng = MMOEngine(backend="xla", max_batch=4)
  eng.start()
  futs = [eng.submit(apsp_request(graphs.weighted_digraph(10, 0.3, seed=i)))
          for i in range(8)]
  eng.stop(drain=True)
  assert eng.pending() == 0
  assert all(f.done() for f in futs)
  assert all(f.result().value.shape == (10, 10) for f in futs)


def test_batch_failure_fails_futures_and_keeps_serving(monkeypatch):
  """step()'s except branch: a poisoned batch fails every future in it,
  leaves _inflight/_pending clean, and the engine keeps serving."""
  from repro.serve_mmo import batching as batching_mod

  eng = MMOEngine(backend="xla", max_batch=4)
  futs = [eng.submit(apsp_request(graphs.weighted_digraph(10, 0.3, seed=i)))
          for i in range(3)]

  boom = RuntimeError("poisoned operands")
  real_stack = batching_mod.stack_batch
  monkeypatch.setattr(batching_mod, "stack_batch",
                      lambda *a, **kw: (_ for _ in ()).throw(boom))
  assert eng.step() == 0  # the whole batch fails, step reports 0 completions
  assert eng._inflight == set() and eng.pending() == 0
  for f in futs:
    assert f.done()
    with pytest.raises(RuntimeError, match="poisoned operands"):
      f.result()

  monkeypatch.setattr(batching_mod, "stack_batch", real_stack)
  ok = eng.submit(apsp_request(graphs.weighted_digraph(12, 0.3, seed=9)))
  assert eng.run_until_idle() == 1
  ref, _ = solvers.apsp(graphs.weighted_digraph(12, 0.3, seed=9))
  np.testing.assert_allclose(ok.result().value, np.asarray(ref), atol=1e-5)
  assert eng._inflight == set() and eng.pending() == 0


def test_poisoned_batch_still_records_measured_iterations(monkeypatch):
  """Regression: measured closure convergence counts feed the adaptive
  estimator the moment the fixpoint has run — a batch that fails *after*
  execution (poisoned split_results) must still contribute its iteration
  observations, or serving pathologies would systematically starve the
  estimator exactly when the device is misbehaving.  Failed batches must
  NOT contribute service-seconds observations (no result was produced to
  time)."""
  from repro.serve_mmo import batching as batching_mod

  eng = MMOEngine(backend="xla", max_batch=4)
  key = None
  for i in range(3):
    req = apsp_request(graphs.weighted_digraph(12, 0.3, seed=i))
    key = key or request_bucket(req)
    eng.submit(req)

  real_split = batching_mod.split_results
  monkeypatch.setattr(
      batching_mod, "split_results",
      lambda *a, **kw: (_ for _ in ()).throw(RuntimeError("poisoned split")))
  assert eng.step() == 0  # the fixpoint ran; splitting its results failed
  snap = eng.estimator.snapshot()
  (label,) = snap["iterations"]
  assert label.startswith("closure/minplus")
  it = snap["iterations"][label]
  assert it["observations"] == 1 and 1.0 <= it["iterations"] <= 4.0
  # only the live slots count — a padded 4-batch of 3 requests must not
  # average the 4th (copied) slot's convergence into the estimate
  assert snap["cells"] == {}  # no seconds observation from a failed batch

  # and the estimator keeps accumulating once the engine recovers
  monkeypatch.setattr(batching_mod, "split_results", real_split)
  ok = eng.submit(apsp_request(graphs.weighted_digraph(12, 0.3, seed=9)))
  assert eng.run_until_idle() == 1 and ok.state == "done"
  snap = eng.estimator.snapshot()
  assert snap["iterations"][label]["observations"] == 2
  assert any(lab.startswith("closure/minplus") for lab in snap["cells"])


def test_split_count_mismatch_fails_loudly_not_wedged(monkeypatch):
  """A split_results that returns the wrong number of results must fail the
  batch (every future resolves with an error) rather than silently leaving
  the unzipped tail pending forever — and the engine keeps serving."""
  from repro.serve_mmo import batching as batching_mod

  eng = MMOEngine(backend="xla", max_batch=4, transient_retries=0,
                  bisect=False, retry_backoff_s=0.0)
  futs = [eng.submit(apsp_request(graphs.weighted_digraph(12, 0.3, seed=i)))
          for i in range(3)]

  real_split = batching_mod.split_results
  monkeypatch.setattr(
      batching_mod, "split_results",
      lambda key, reqs, out: real_split(key, reqs, out)[:-1])  # drop one
  assert eng.step() == 0
  assert eng._inflight == set() and eng.pending() == 0
  for f in futs:
    assert f.done()
    with pytest.raises(RuntimeError, match="split_results returned 2"):
      f.result()

  monkeypatch.setattr(batching_mod, "split_results", real_split)
  ok = eng.submit(apsp_request(graphs.weighted_digraph(12, 0.3, seed=9)))
  assert eng.run_until_idle() == 1
  assert ok.result().value.shape == (12, 12)
  assert eng._inflight == set() and eng.pending() == 0


def test_future_callback_error_does_not_kill_serving():
  """A consumer hook that raises out of future fulfillment must not take
  down the batch's siblings or the serving loop: the result is already
  delivered (state set before the hook ran), the error is traced, and the
  request still counts completed."""
  eng = MMOEngine(backend="xla", max_batch=4)
  futs = [eng.submit(apsp_request(graphs.weighted_digraph(12, 0.3, seed=i)))
          for i in range(3)]

  orig = futs[1]._fulfill
  def exploding_fulfill(res):
    orig(res)  # state is set first — then the consumer-side hook blows up
    raise RuntimeError("consumer callback boom")
  futs[1]._fulfill = exploding_fulfill

  assert eng.step() == 3  # the raising callback's request still completes
  assert eng._inflight == set() and eng.pending() == 0
  for f in futs:
    assert f.done() and f.result().value.shape == (12, 12)
  snap = eng.metrics_snapshot()
  assert snap["counters"]["completed"] == 3
  assert snap["counters"]["failed"] == 0
  names = [ev["name"] for ev in eng.export_trace()["traceEvents"]
           if ev.get("ph") == "i"]
  assert "future_callback_error" in names

  ok = eng.submit(apsp_request(graphs.weighted_digraph(12, 0.3, seed=9)))
  assert eng.run_until_idle() == 1 and ok.state == "done"


# --- executable-cache thread-safety (repro.analysis lock-discipline fix) ----


def test_cache_concurrent_get_or_compile_consistent_accounting():
  """N threads hammer a handful of keys with a slow build; accounting must
  balance (hits + compile-losses == calls - executables) and every thread
  must receive a working executable.  Before the cache grew its lock this
  raced: concurrent first-misses corrupted the entry dict and the counters.
  """
  import threading

  from repro.serve_mmo.cache import ExecutableCache

  cache = ExecutableCache()
  keys = [("k", i) for i in range(3)]
  calls_per_thread, n_threads = 8, 6
  args = (np.zeros((4, 4), np.float32),)
  errors = []
  barrier = threading.Barrier(n_threads)

  def make_fn():
    time.sleep(0.01)  # widen the miss→insert window
    return lambda x: x + 1

  def worker(seed):
    rng = np.random.default_rng(seed)
    barrier.wait()
    try:
      for _ in range(calls_per_thread):
        key = keys[rng.integers(len(keys))]
        fn = cache.get_or_compile(key, make_fn, args)
        out = fn(args[0])
        assert out.shape == (4, 4)
        cache.stats()  # concurrent reader on the counters
    except Exception as e:  # noqa: BLE001
      errors.append(e)

  threads = [threading.Thread(target=worker, args=(s,))
             for s in range(n_threads)]
  for t in threads:
    t.start()
  for t in threads:
    t.join()
  assert errors == []
  stats = cache.stats()
  total_calls = calls_per_thread * n_threads
  assert stats["executables"] == len(keys)
  # misses counts compile attempts; a loser of a compile race counts as a
  # miss AND lands on the winner's entry as a hit, so the exact invariant
  # is hits + inserted executables == total calls
  assert stats["misses"] >= stats["executables"]
  assert stats["hits"] + stats["executables"] == total_calls
  assert len(cache) == len(keys)


# ---------------------------------------------------------------------------
# cross-path parity: the batch path against the shared closure corpus
# ---------------------------------------------------------------------------

from fixtures import closure_corpus as corpus  # noqa: E402


@pytest.mark.parametrize("case",
                         [c for c in corpus.CORPUS if c.engine_ok],
                         ids=[c.name for c in corpus.CORPUS if c.engine_ok])
def test_corpus_parity_engine_batch_mode(case):
  """The batched per-iteration path (mode='batch', backend='xla') must be
  bit-identical — outputs AND iteration counts — to the corpus reference.
  test_closure_megakernel.py and test_arena.py assert the same corpus for
  the fused and arena paths, so all three execution paths are pinned to
  one set of numbers (validation off: the NaN-edge case is data here)."""
  ref_out, ref_it = corpus.reference(case)
  eng = MMOEngine(backend="xla", validate_results=False)
  futs = [eng.submit(closure_request(g, op=case.op, algorithm=case.algorithm,
                                     prepared=True))
          for g in case.graphs]
  eng.run_until_idle()
  for i, f in enumerate(futs):
    res = f.result()
    n = case.sizes[i]
    np.testing.assert_array_equal(res.value, ref_out[i, :n, :n])
    assert res.extras["iterations"] == int(ref_it[i])

"""SSD intra-chunk Pallas kernel vs einsum oracle, and consistency with the
full model-side SSD (the intra part of ssm.ssd_chunked)."""
import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.kernels.ssd import ssd_intra_chunk, ssd_intra_chunk_ref

RNG = np.random.default_rng(9)


@pytest.mark.parametrize("shape", [
    # (BZ, H, Q, N, P)
    (2, 4, 32, 16, 8),
    (1, 2, 64, 32, 16),
    (3, 1, 16, 8, 8),
])
@pytest.mark.parametrize("dtype", [jnp.float32, jnp.bfloat16])
def test_ssd_kernel_matches_oracle(shape, dtype):
  bz, h, q, n, p = shape
  c = jnp.asarray(RNG.standard_normal((bz, h, q, n)), dtype)
  b = jnp.asarray(RNG.standard_normal((bz, h, q, n)), dtype)
  x = jnp.asarray(RNG.standard_normal((bz, h, q, p)), dtype)
  dt = jnp.asarray(RNG.uniform(0.01, 0.2, (bz, h, q)), dtype)
  # cum must be non-increasing-ish (decays ≤ 0); use a cumsum of negatives
  da = -RNG.uniform(0.001, 0.1, (bz, h, q))
  cum = jnp.asarray(np.cumsum(da, axis=-1), dtype)
  got = ssd_intra_chunk(c, b, x, dt, cum, interpret=True)
  ref = ssd_intra_chunk_ref(c, b, x, dt, cum)
  tol = 1e-5 if dtype == jnp.float32 else 5e-2
  np.testing.assert_allclose(np.asarray(got), np.asarray(ref), rtol=tol,
                             atol=tol)


def test_ssd_kernel_matches_model_ssd():
  """Kernel y_diag == the intra-chunk part of models/ssm.ssd_chunked (run
  the full SSD with a single chunk: no inter-chunk term, zero init state)."""
  from repro.models.ssm import ssd_chunked
  B, S, H, P, G, N = 2, 32, 4, 8, 1, 16
  xh = RNG.standard_normal((B, S, H, P)).astype(np.float32)
  dt = RNG.uniform(0.01, 0.2, (B, S, H)).astype(np.float32)
  a = -RNG.uniform(0.1, 1.0, (H,)).astype(np.float32)
  bmat = RNG.standard_normal((B, S, G, N)).astype(np.float32)
  cmat = RNG.standard_normal((B, S, G, N)).astype(np.float32)
  y_model, _ = ssd_chunked(jnp.asarray(xh), jnp.asarray(dt), jnp.asarray(a),
                           jnp.asarray(bmat), jnp.asarray(cmat), chunk=S)

  # kernel formulation: BZ=B (one chunk), per-head expanded c/b, dA cumsum
  da = dt * a[None, None, :]
  cum = np.cumsum(da, axis=1)                       # (B,S,H)
  hg = H // G
  ce = np.repeat(cmat, hg, axis=2).transpose(0, 2, 1, 3)   # (B,H,S,N)
  be = np.repeat(bmat, hg, axis=2).transpose(0, 2, 1, 3)
  xe = xh.transpose(0, 2, 1, 3)                             # (B,H,S,P)
  dte = dt.transpose(0, 2, 1)
  cume = cum.transpose(0, 2, 1)
  y_k = ssd_intra_chunk(jnp.asarray(ce), jnp.asarray(be), jnp.asarray(xe),
                        jnp.asarray(dte), jnp.asarray(cume), interpret=True)
  y_k = np.asarray(y_k).transpose(0, 2, 1, 3)               # (B,S,H,P)
  np.testing.assert_allclose(y_k, np.asarray(y_model), rtol=2e-4, atol=2e-4)

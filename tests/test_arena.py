"""Device-resident request arena: parity, lifecycle, and chaos pins.

The arena's contract (serve_mmo/arena.py) is *bit-identity* with the
batched per-iteration path — outputs AND per-request iteration counts —
for every case in the shared parity corpus, regardless of when requests
are admitted or evicted relative to each other.  Plus the structural
guarantees the mode exists for: a mid-flight arrival joins a running
fixpoint with ZERO retraces after prewarm, a NaN-poisoned slot fails alone
without corrupting neighbors, and tick-failure retry/breaker accounting
matches the batch path's.
"""
import numpy as np
import pytest

from fixtures import closure_corpus as corpus

from repro.core import closure as cl_mod
from repro.serve_mmo import (FaultInjector, FaultRule, InjectedFault,
                             MMOEngine, NonFiniteResultError, RequestArena,
                             apsp_request, closure_request)
from repro.serve_mmo.cache import ExecutableCache
from repro.serve_mmo.scheduler import BucketKey, request_bucket

# one cache across the module: arenas with the same (bucket, capacity, g,
# max_iters) replay each other's executables, so the whole file compiles
# each program once
_CACHE = ExecutableCache()


def _requests(case):
  return [closure_request(g, op=case.op, algorithm=case.algorithm,
                          prepared=True) for g in case.graphs]


def _drain(arena, pending):
  """Admit-when-free / tick / sweep until everything evicts."""
  done = {}
  pending = list(pending)
  while pending or arena.live_slots():
    while pending and arena.free_slots():
      arena.admit(pending.pop(0))
    arena.tick()
    for ev in arena.sweep():
      assert id(ev.request) not in done, "request evicted twice"
      done[id(ev.request)] = ev
  return done


# ---------------------------------------------------------------------------
# corpus parity — standalone arena and engine arena mode
# ---------------------------------------------------------------------------


@pytest.mark.parametrize("case", corpus.CORPUS, ids=corpus.CASE_IDS)
def test_corpus_parity_arena(case):
  """Every corpus case through the slot lifecycle, bit-identical to the
  batched reference — with capacity 2, so some requests wait for an
  eviction and enter an arena whose other slots are mid-fixpoint."""
  ref_out, ref_it = corpus.reference(case)
  reqs = _requests(case)
  arena = RequestArena(request_bucket(reqs[0]), capacity=2, g=3,
                       cache=_CACHE, max_iters=case.max_iters,
                       interpret=True)
  done = _drain(arena, reqs)
  for i, r in enumerate(reqs):
    ev = done[id(r)]
    n = case.sizes[i]
    np.testing.assert_array_equal(ev.value, ref_out[i, :n, :n])
    assert ev.iterations == int(ref_it[i])


@pytest.mark.parametrize("case",
                         [c for c in corpus.CORPUS if c.engine_ok],
                         ids=[c.name for c in corpus.CORPUS if c.engine_ok])
def test_corpus_parity_engine_arena_mode(case):
  """The same corpus through the full engine in mode='arena': scheduler →
  admission → slots → futures, still bit-identical (validation off so the
  NaN-edge case flows through as data, matching the reference run)."""
  ref_out, ref_it = corpus.reference(case)
  eng = MMOEngine(backend="xla", mode="arena", arena_capacity=2, arena_g=3,
                  validate_results=False)
  eng.cache = _CACHE
  futs = [eng.submit(r) for r in _requests(case)]
  eng.run_until_idle()
  for i, f in enumerate(futs):
    res = f.result()
    n = case.sizes[i]
    np.testing.assert_array_equal(res.value, ref_out[i, :n, :n])
    assert res.extras["iterations"] == int(ref_it[i])


# ---------------------------------------------------------------------------
# the structural guarantee: mid-flight admission, zero retraces
# ---------------------------------------------------------------------------


def _line(n, seed):
  rng = np.random.default_rng(seed)
  w = np.full((n, n), np.inf, np.float32)
  w[np.arange(n - 1), np.arange(1, n)] = rng.uniform(
      0.5, 1.5, n - 1).astype(np.float32)
  return w


def test_midflight_admission_zero_retraces():
  """After prewarm, a request arriving while the arena is mid-fixpoint is
  admitted into the RUNNING buffer at the next tick boundary — no new
  compilation (the cache miss counter is flat), and its result is still
  bit-identical to the batched reference."""
  eng = MMOEngine(backend="xla", mode="arena", arena_capacity=4, arena_g=2)
  compiled = eng.prewarm([apsp_request(_line(14, 0),
                                       algorithm="bellman_ford")])
  assert compiled == 3  # admit / tick / read
  misses0 = eng.cache.misses

  fa = eng.submit(apsp_request(_line(14, 1), algorithm="bellman_ford"))
  eng.step()  # admit A + first tick: the fixpoint is now running
  arena = next(iter(eng._arenas.values()))
  assert arena.live_slots() == 1 and not fa.done()
  fb = eng.submit(apsp_request(_line(13, 2), algorithm="bellman_ford"))
  eng.run_until_idle()

  assert eng.cache.misses == misses0, "mid-flight admission retraced"
  prepared = cl_mod.prepare_adjacency(np.asarray(_line(13, 2)), op="minplus")
  stack = np.asarray(cl_mod.pad_adjacency(prepared, 16, op="minplus"))[None]
  ref, it = cl_mod.batched_bellman_ford_closure(
      stack, op="minplus", backend="xla",
      valid_n=np.asarray([13], np.int32))
  np.testing.assert_array_equal(fb.result().value,
                                np.asarray(ref[0])[:13, :13])
  assert fb.result().extras["iterations"] == int(it[0])
  assert fa.result().extras["iterations"] > 0


def test_arena_trace_slot_lifecycle():
  """The flight recorder carries the admit → tick×k → evict span: an
  execute slice opening with the slot index, arena_tick X-events, and the
  eviction closing the slice with the measured iteration count."""
  eng = MMOEngine(backend="xla", mode="arena", arena_capacity=2, arena_g=2)
  fut = eng.submit(apsp_request(_line(10, 3), algorithm="bellman_ford"))
  eng.run_until_idle()
  fut.result()
  ev = eng.export_trace()["traceEvents"]
  begins = [e for e in ev if e.get("ph") == "b" and e["name"] == "execute"]
  assert begins and "slot" in begins[0]["args"]
  ticks = [e for e in ev if e.get("name") == "arena_tick"]
  assert len(ticks) >= 2  # bellman_ford on a 10-line at g=2 needs several
  ends = [e for e in ev if e.get("ph") == "e" and e["name"] == "execute"]
  assert ends and ends[-1]["args"]["outcome"] == "done"
  assert ends[-1]["args"]["iterations"] == fut.result().extras["iterations"]


# ---------------------------------------------------------------------------
# chaos pins — fault injection through the arena path
# ---------------------------------------------------------------------------


def test_nan_poisoned_slot_fails_alone():
  """A NaN-poisoned slot is evicted as FAILED without freezing or
  corrupting its live neighbors — the isolation the batch path needs
  bisection for, free here from per-slot state."""
  faults = FaultInjector([FaultRule(point="nonfinite", backend="arena",
                                    request_ids={0})])
  eng = MMOEngine(backend="xla", mode="arena", arena_capacity=4, arena_g=3,
                  faults=faults)
  poisoned = eng.submit(apsp_request(_line(12, 4),
                                     algorithm="bellman_ford"))
  neighbor = eng.submit(apsp_request(_line(12, 5),
                                     algorithm="bellman_ford"))
  eng.run_until_idle()
  with pytest.raises(NonFiniteResultError):
    poisoned.result()
  prepared = cl_mod.prepare_adjacency(np.asarray(_line(12, 5)), op="minplus")
  stack = np.asarray(cl_mod.pad_adjacency(prepared, 16, op="minplus"))[None]
  ref, it = cl_mod.batched_bellman_ford_closure(stack, op="minplus",
                                                backend="xla",
                                                valid_n=np.asarray(
                                                    [12], np.int32))
  np.testing.assert_array_equal(neighbor.result().value,
                                np.asarray(ref[0])[:12, :12])
  assert neighbor.result().extras["iterations"] == int(it[0])
  snap = eng.metrics_snapshot()
  assert snap["counters"]["failed"] == 1
  assert snap["counters"]["completed"] == 1


def test_arena_tick_retry_accounting():
  """A transient execute fault on one tick: the slots stay resident, the
  next step retries the tick whole, everything completes — and the retry
  and breaker accounting from the batch path holds (counted retry, breaker
  failure recorded then cleared by success)."""
  faults = FaultInjector([FaultRule(point="execute", backend="arena",
                                    mode="transient", count=1)])
  eng = MMOEngine(backend="xla", mode="arena", arena_capacity=2, arena_g=4,
                  faults=faults, transient_retries=1, retry_backoff_s=0.0)
  fut = eng.submit(apsp_request(_line(10, 6), algorithm="bellman_ford"))
  eng.run_until_idle()
  assert fut.result().extras["iterations"] > 0
  snap = eng.metrics_snapshot()
  assert snap["counters"]["retries"] >= 1
  assert snap["counters"]["completed"] == 1
  assert snap["counters"]["failed"] == 0


def test_arena_tick_failure_budget_fails_residents():
  """A persistent execute fault exhausts the transient budget: every
  resident request fails together (there is no sibling arm to re-dispatch
  a device-resident buffer to), the arena resets, and the engine is not
  wedged — traffic after the fault clears completes normally."""
  faults = FaultInjector([FaultRule(point="execute", backend="arena")])
  eng = MMOEngine(backend="xla", mode="arena", arena_capacity=2, arena_g=4,
                  faults=faults, transient_retries=1, retry_backoff_s=0.0)
  fut = eng.submit(apsp_request(_line(10, 7), algorithm="bellman_ford"))
  eng.run_until_idle()
  with pytest.raises(InjectedFault):
    fut.result()
  assert next(iter(eng._arenas.values())).live_slots() == 0
  faults.clear("execute")
  ok = eng.submit(apsp_request(_line(10, 8), algorithm="bellman_ford"))
  eng.run_until_idle()
  assert ok.result().extras["iterations"] > 0


# ---------------------------------------------------------------------------
# slot-lifecycle unit pins
# ---------------------------------------------------------------------------


def test_arena_refuses_non_closure_and_bad_params():
  key = BucketKey(kind="mmo", op="minplus", shape=(8, 8, 8),
                  dtypes=("float32",), params=(False,))
  with pytest.raises(ValueError, match="closure"):
    RequestArena(key)
  ckey = request_bucket(apsp_request(_line(8, 0)))
  with pytest.raises(ValueError, match="capacity"):
    RequestArena(ckey, capacity=0)
  with pytest.raises(ValueError, match="g must"):
    RequestArena(ckey, g=0)


def test_arena_full_refuses_and_backfills():
  """Capacity is a hard bound: admit past it raises (the engine bounds
  admissions by free_slots); an eviction frees the slot for reuse."""
  req = apsp_request(_line(8, 1))
  arena = RequestArena(request_bucket(req), capacity=1, g=8, cache=_CACHE,
                       interpret=True)
  slot = arena.admit(req)
  assert arena.free_slots() == 0
  with pytest.raises(RuntimeError, match="arena full"):
    arena.admit(apsp_request(_line(8, 2)))
  arena.tick()
  (ev,) = arena.sweep()
  assert ev.slot == slot and arena.free_slots() == 1
  # backfill reuses the freed slot and reseeds its stale flags
  again = apsp_request(_line(7, 3))
  assert arena.admit(again) == slot
  arena.tick()
  (ev2,) = arena.sweep()
  assert ev2.request is again and ev2.iterations > 0


def test_arena_reset_returns_residents():
  reqs = [apsp_request(_line(8, s)) for s in (4, 5)]
  arena = RequestArena(request_bucket(reqs[0]), capacity=4, g=1,
                       cache=_CACHE, interpret=True)
  for r in reqs:
    arena.admit(r)
  arena.tick()
  victims = arena.reset()
  assert set(map(id, victims)) == set(map(id, reqs))
  assert arena.live_slots() == 0 and arena.free_slots() == 4
  # the arena still serves after a reset
  done = _drain(arena, [apsp_request(_line(8, 6))])
  assert len(done) == 1

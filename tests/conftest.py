"""Shared test helpers."""


class FakeClock:
  """Injectable monotonic clock: tests set ``.t`` to move time."""

  def __init__(self, t=0.0):
    self.t = t

  def __call__(self):
    return self.t

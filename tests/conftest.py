"""Shared test helpers + REPRO_SANITIZE=1 hardened mode.

Setting ``REPRO_SANITIZE=1`` in the environment makes the whole test run
stricter: jax_debug_nans raises at the op that produced a NaN, and the
repro.analysis pre-flight aborts the session before collection if the tree
has new static-analysis findings.  Default off — tier-1 behavior is
unchanged without the variable.
"""


import pytest

_SANITIZE_KEY = pytest.StashKey()


def pytest_configure(config):
  from repro.analysis.sanitize import maybe_enable_sanitize
  if maybe_enable_sanitize():
    config.stash[_SANITIZE_KEY] = True


def pytest_report_header(config):
  if config.stash.get(_SANITIZE_KEY, False):
    return "repro: REPRO_SANITIZE=1 (jax_debug_nans on, analyzer preflight)"
  return None


class FakeClock:
  """Injectable monotonic clock: tests set ``.t`` to move time."""

  def __init__(self, t=0.0):
    self.t = t

  def __call__(self):
    return self.t

"""Training substrate: optimizer semantics, grad accumulation, loss descent,
data determinism."""
import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro import configs
from repro.data import DataConfig, SyntheticLM, make_source
from repro.models import zoo
from repro.train import (AdamWConfig, init_opt_state, make_train_step,
                         xent_loss)


def test_loss_decreases():
  cfg = configs.get_config("tinyllama-1.1b", smoke=True)
  oc = AdamWConfig(lr=5e-3, warmup_steps=5, total_steps=100)
  params = zoo.init(cfg, jax.random.PRNGKey(0))
  state = (params, init_opt_state(params))
  step = jax.jit(make_train_step(cfg, oc))
  data = SyntheticLM(DataConfig(vocab=cfg.vocab, seq_len=64, global_batch=8,
                                seed=3))
  losses = []
  for i in range(60):
    state, m = step(state, data.batch_at(i))
    losses.append(float(m["loss"]))
  assert np.mean(losses[-10:]) < np.mean(losses[:10]) - 0.1, losses[::10]


def test_grad_accum_matches_full_batch():
  """accum=2 must equal accum=1 on the same global batch (up to fp)."""
  cfg = configs.get_config("tinyllama-1.1b", smoke=True)
  oc = AdamWConfig(lr=1e-3, warmup_steps=1, total_steps=10)
  params = zoo.init(cfg, jax.random.PRNGKey(1))
  data = SyntheticLM(DataConfig(vocab=cfg.vocab, seq_len=32, global_batch=8,
                                seed=4))
  batch = data.batch_at(0)
  s1 = jax.jit(make_train_step(cfg, oc, accum=1))((params,
                                                   init_opt_state(params)),
                                                  batch)
  s2 = jax.jit(make_train_step(cfg, oc, accum=2))((params,
                                                   init_opt_state(params)),
                                                  batch)
  np.testing.assert_allclose(float(s1[1]["loss"]), float(s2[1]["loss"]),
                             rtol=1e-5)
  np.testing.assert_allclose(float(s1[1]["grad_norm"]),
                             float(s2[1]["grad_norm"]), rtol=1e-4)
  # post-Adam params: rsqrt(v)+eps amplifies fp-reassociation noise where
  # g≈0 (delta flips sign at magnitude ~lr) — bound by 2·lr instead of fp eps
  la, lb = jax.tree.leaves(s1[0][0]), jax.tree.leaves(s2[0][0])
  for a, b in zip(la, lb):
    np.testing.assert_allclose(np.asarray(a, np.float32),
                               np.asarray(b, np.float32), atol=2e-3)


def test_xent_masks_out_of_vocab():
  logits = jnp.zeros((1, 4, 8))
  labels = jnp.asarray([[1, 2, -1, 9]])  # -1 and 9 masked
  loss = xent_loss(logits, labels, vocab=8)
  np.testing.assert_allclose(float(loss), np.log(8), rtol=1e-6)


def test_lr_schedule():
  from repro.train.optimizer import lr_schedule
  oc = AdamWConfig(lr=1.0, warmup_steps=10, total_steps=110,
                   min_lr_ratio=0.1)
  assert float(lr_schedule(oc, jnp.asarray(5))) == pytest.approx(0.5)
  assert float(lr_schedule(oc, jnp.asarray(10))) == pytest.approx(1.0)
  assert float(lr_schedule(oc, jnp.asarray(110))) == pytest.approx(0.1)


def test_weight_decay_mask():
  from repro.train.optimizer import _decay_mask
  assert _decay_mask("blocks/attn/wq")
  assert not _decay_mask("blocks/ln1_norm_scale")
  assert not _decay_mask("blocks/attn/bq_bias")
  assert not _decay_mask("blocks/ssm/A_log")


def test_data_determinism_and_sharding():
  cfg = DataConfig(vocab=100, seq_len=16, global_batch=8, seed=7)
  a = SyntheticLM(cfg).batch_at(3)
  b = SyntheticLM(cfg).batch_at(3)
  assert np.array_equal(np.asarray(a["tokens"]), np.asarray(b["tokens"]))
  c = SyntheticLM(cfg).batch_at(4)
  assert not np.array_equal(np.asarray(a["tokens"]), np.asarray(c["tokens"]))
  # host sharding: different hosts draw different rows, same host is stable
  h0 = SyntheticLM(cfg, n_hosts=2, host_id=0).batch_at(3)
  h1 = SyntheticLM(cfg, n_hosts=2, host_id=1).batch_at(3)
  assert h0["tokens"].shape == (4, 16)
  assert not np.array_equal(np.asarray(h0["tokens"]),
                            np.asarray(h1["tokens"]))


def test_packed_corpus(tmp_path):
  toks = np.arange(10000, dtype=np.uint16) % 50
  path = tmp_path / "corpus.bin"
  toks.tofile(path)
  cfg = DataConfig(vocab=50, seq_len=32, global_batch=4, seed=1,
                   corpus_path=str(path))
  src = make_source(cfg)
  b1, b2 = src.batch_at(0), src.batch_at(0)
  assert np.array_equal(np.asarray(b1["tokens"]), np.asarray(b2["tokens"]))
  assert b1["tokens"].shape == (4, 32)
  assert int(jnp.max(b1["tokens"])) < 50

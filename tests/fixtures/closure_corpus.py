"""Shared closure parity corpus — one table of adversarial fixpoint inputs.

Three execution paths compute semiring closures: the per-iteration batched
reference (``core.closure._batched_fixpoint`` via ``backend="xla"``), the
fused Pallas megakernel (``fixpoint_backend="megakernel"``), and the
device-resident request arena (``serve_mmo/arena.py``).  Their contract is
*bit-identity* — outputs AND per-request iteration counts.  This module is
the single source of inputs all three parity suites assert against
(``test_closure_megakernel.py``, ``test_serve_mmo.py``, ``test_arena.py``),
so the paths cannot drift apart silently: a new adversarial case added here
is automatically pinned on every path.

Cases cover: every ring with a ⊗-identity × both algorithms, inf/NaN edge
weights, fully isolated vertices, ragged ``valid_n`` inside one bucket,
already-converged seeds co-batched with stragglers, and ``max_iters`` caps
that the chunk length does not divide.
"""
from __future__ import annotations

from typing import NamedTuple, Optional, Tuple

import numpy as np
import jax.numpy as jnp

from repro.core import closure as cl_mod
from repro.core import semiring as sr_mod
from repro.serve_mmo.scheduler import bucket_dim

IDENTITY_RINGS = tuple(op for op in sr_mod.ALL_OPS
                       if sr_mod.get(op).otimes_identity is not None)


def rand_adj(op, n, r, seed=0):
  """Random prepared (R, n, n) adjacency stack in ring ``op``'s conventions."""
  sr = sr_mod.get(op)
  rng = np.random.default_rng(seed)
  missing, _ = cl_mod.closure_pad_values(op)
  if sr.boolean:
    w = rng.random((r, n, n)) > 0.6
  else:
    w = rng.uniform(0.2, 1.5, (r, n, n)).astype(np.float32)
    if op == "mma":
      # strictly upper-triangular (nilpotent): the mma closure terminates
      # exactly instead of growing without bound
      w = np.triu(0.1 * w, k=1).astype(np.float32)
    keep = rng.random((r, n, n)) > 0.5
    w = np.where(keep, w, np.float32(missing)).astype(np.float32)
  return np.array(cl_mod.prepare_adjacency(jnp.asarray(w), op=op))


def line_graph(n, seed=0):
  """Weighted directed line 0→1→…→n−1; every other edge is missing (inf)."""
  rng = np.random.default_rng(seed)
  w = np.full((n, n), np.inf, np.float32)
  w[np.arange(n - 1), np.arange(1, n)] = rng.uniform(
      0.5, 1.5, n - 1).astype(np.float32)
  return w


def _prepared_line(n, seed=0):
  return np.array(cl_mod.prepare_adjacency(
      jnp.asarray(line_graph(n, seed=seed)), op="minplus"))


def _closed_unit_line(n):
  """The minplus closure of a unit-weight line graph, built directly: an
  already-converged seed (the fixpoint detects no change on its first probe
  iteration, so its counter must stop at exactly 1 on every path)."""
  closed = np.full((n, n), np.inf, np.float32)
  i, j = np.meshgrid(np.arange(n), np.arange(n), indexing="ij")
  closed[j >= i] = (j - i)[j >= i].astype(np.float32)
  return closed


class CorpusCase(NamedTuple):
  name: str              # pytest id — stable, grep-able
  op: str                # semiring ring
  algorithm: str         # leyzorek | bellman_ford
  graphs: Tuple[np.ndarray, ...]  # prepared true-size (n, n) adjacencies
  sizes: Tuple[int, ...]          # true n per graph
  nb: int                # shared bucket dim (every graph buckets here)
  max_iters: Optional[int]        # explicit cap, or None for the default
  engine_ok: bool        # servable via the engine path (no explicit cap)


def _case(name, op, algorithm, graphs, *, max_iters=None, engine_ok=True):
  graphs = tuple(np.asarray(g) for g in graphs)
  sizes = tuple(int(g.shape[-1]) for g in graphs)
  nbs = {bucket_dim(n) for n in sizes}
  assert len(nbs) == 1, f"corpus case {name} spans buckets {nbs}"
  return CorpusCase(name=name, op=op, algorithm=algorithm, graphs=graphs,
                    sizes=sizes, nb=nbs.pop(), max_iters=max_iters,
                    engine_ok=engine_ok)


def _build_corpus():
  cases = []
  # every ⊗-identity ring × both algorithms, random adversarial stacks
  # (inf-missing edges, nilpotent mma, boolean rings)
  for op in IDENTITY_RINGS:
    stack = rand_adj(op, 12, 2, seed=hash(op) % 1000)
    for algorithm in ("leyzorek", "bellman_ford"):
      cases.append(_case(f"rand-{op}-{algorithm}", op, algorithm,
                         tuple(stack)))
  # ragged true sizes inside one padded bucket: masked-K semantics
  for algorithm in ("leyzorek", "bellman_ford"):
    cases.append(_case(f"ragged-minplus-{algorithm}", "minplus", algorithm,
                       [_prepared_line(n, seed=n) for n in (9, 11, 16)]))
  # an already-converged seed co-batched with a straggler: the seed's
  # counter must freeze at 1 (the no-change probe) while the line iterates
  cases.append(_case("converged-seed-minplus-bellman_ford", "minplus",
                     "bellman_ford",
                     [_closed_unit_line(10), _prepared_line(10, seed=10)]))
  # NaN edge weight: the NaN-aware convergence compare must not spin
  nan_line = _prepared_line(8, seed=8)
  nan_line[0, 1] = np.nan
  cases.append(_case("nan-edge-minplus-bellman_ford", "minplus",
                     "bellman_ford", [nan_line]))
  # a fully isolated vertex (all edges missing) mid-matrix: indistinguishable
  # from bucket padding, must stay inert on every path
  iso = rand_adj("minplus", 12, 1, seed=77)[0]
  iso[5, :], iso[:, 5] = np.inf, np.inf
  iso[5, 5] = 0.0
  cases.append(_case("isolated-vertex-minplus-leyzorek", "minplus",
                     "leyzorek", [iso]))
  # explicit max_iters below the natural trip count, chosen so chunk
  # lengths (g=3,4) do not divide it — engine/arena defaults never cap, so
  # this case is pinned on the solver paths only
  cases.append(_case("cap-minplus-bellman_ford", "minplus", "bellman_ford",
                     [_prepared_line(12, seed=12)], max_iters=7,
                     engine_ok=False))
  return tuple(cases)


CORPUS = _build_corpus()
CASE_IDS = tuple(c.name for c in CORPUS)


def stacked(case: CorpusCase):
  """Bucket-padded (R, nb, nb) stack + (R,) valid_n — the batched layout the
  serving path produces for these requests."""
  stack = jnp.stack([
      jnp.asarray(cl_mod.pad_adjacency(jnp.asarray(g), case.nb, op=case.op))
      for g in case.graphs])
  return stack, jnp.asarray(case.sizes, jnp.int32)


def reference(case: CorpusCase):
  """Ground truth: the per-iteration batched fixpoint (``backend="xla"``).
  Returns numpy (R, nb, nb) closure + (R,) iteration counts."""
  solver = (cl_mod.batched_leyzorek_closure if case.algorithm == "leyzorek"
            else cl_mod.batched_bellman_ford_closure)
  stack, valid = stacked(case)
  out, iters = solver(stack, op=case.op, backend="xla", valid_n=valid,
                      max_iters=case.max_iters)
  return np.asarray(out), np.asarray(iters)

"""Launch-path guard: one real dry-run cell compiles against the production
mesh in a subprocess (512 placeholder devices), and the cell JSON carries
coherent roofline fields.  Slow (~1–2 min) but protects the entire
specs/sharding/step/lowering chain."""
import json
import os
import subprocess
import sys

import pytest

SRC = os.path.join(os.path.dirname(__file__), "..", "src")


@pytest.mark.slow
def test_dryrun_cell_compiles():
  env = dict(os.environ, PYTHONPATH=SRC)
  r = subprocess.run(
      [sys.executable, "-m", "repro.launch.dryrun", "--arch",
       "tinyllama-1.1b", "--shape", "decode_32k", "--mesh", "single"],
      capture_output=True, text=True, env=env, timeout=1200)
  assert r.returncode == 0, r.stderr[-2000:]
  row = json.loads(r.stdout.strip().splitlines()[-1])
  assert row["status"] == "ok", row
  assert row["chips"] == 256
  assert row["peak_mem_per_dev"] < 16 * 2 ** 30
  for k in ("t_compute_s", "t_memory_s", "t_collective_s"):
    assert row[k] >= 0.0
  assert row["bottleneck"] in ("compute", "memory", "collective")
  assert row["hlo_flops"] > 0


@pytest.mark.slow
def test_dryrun_skip_reason():
  env = dict(os.environ, PYTHONPATH=SRC)
  r = subprocess.run(
      [sys.executable, "-m", "repro.launch.dryrun", "--arch", "granite-8b",
       "--shape", "long_500k", "--mesh", "single"],
      capture_output=True, text=True, env=env, timeout=300)
  assert r.returncode == 0
  row = json.loads(r.stdout.strip().splitlines()[-1])
  assert row["status"] == "skipped"
  assert "full-attention" in row["reason"]

"""Adaptive service-time estimator: EWMA convergence, cold-start fallback,
iteration-count feedback, and thread safety under concurrent observe/predict."""
import threading

import numpy as np
import pytest

from repro.serve_mmo import Estimate, MMOEngine, ServiceEstimator, apsp_request
from repro.serve_mmo.scheduler import request_bucket
from repro.apps import graphs

from conftest import FakeClock

RNG = np.random.default_rng(0)


def _mmo_key(n=12):
  from repro.serve_mmo import mmo_request
  a = RNG.standard_normal((n, n)).astype(np.float32)
  return request_bucket(mmo_request(a, a, op="mma"))


def _closure_key(n=12):
  return request_bucket(apsp_request(graphs.weighted_digraph(n, 0.3, seed=0)))


# ---------------------------------------------------------------------------
# EWMA mechanics
# ---------------------------------------------------------------------------


def test_ewma_pins_exact_update_rule():
  """The decay is per-observation with alpha = 1 − 2^(−1/half_life); pin the
  arithmetic so a silent reformulation (time-based decay, different alpha)
  cannot slip in and shift every admission decision."""
  est = ServiceEstimator(half_life=1.0, min_observations=1)
  key = _mmo_key()
  est.observe_batch(key, "xla", "local", 1, 1.0)
  assert est.predict(key, "xla", "local", 99.0, 1.0).seconds == 1.0
  # half_life=1 → alpha = 0.5: each new reading moves halfway to the target
  est.observe_batch(key, "xla", "local", 1, 3.0)
  assert est.predict(key, "xla", "local", 99.0, 1.0).seconds == \
      pytest.approx(2.0)
  est.observe_batch(key, "xla", "local", 1, 3.0)
  assert est.predict(key, "xla", "local", 99.0, 1.0).seconds == \
      pytest.approx(2.5)


def test_ewma_converges_to_shifted_load_within_half_lives():
  """After a load shift, the estimate crosses within 10% of the new level in
  ~4 half-lives of observations — the property that makes predictions track
  the device instead of the cold-start prior forever."""
  est = ServiceEstimator(half_life=8.0, min_observations=1)
  key = _mmo_key()
  for _ in range(50):
    est.observe_batch(key, "xla", "local", 1, 0.001)  # unloaded device
  for _ in range(32):  # 4 half-lives at the loaded level
    est.observe_batch(key, "xla", "local", 1, 0.1)    # device now loaded
  got = est.predict(key, "xla", "local", 1e-6, 1.0).seconds
  assert got == pytest.approx(0.1, rel=0.10)
  # and the old level no longer dominates
  assert got > 0.05


def test_observations_normalized_per_padded_slot():
  """A batch's seconds are divided by its padded slot count: marginal
  per-request cost, the unit every consumer (admission backlog, deadline
  feasibility, batch cap) is denominated in."""
  est = ServiceEstimator(min_observations=1)
  key = _mmo_key()
  est.observe_batch(key, "xla", "local", 8, 0.8)
  assert est.predict(key, "xla", "local", 9.9, 1.0) == Estimate(0.1, "ewma")


def test_bogus_observations_are_dropped():
  est = ServiceEstimator(min_observations=1)
  key = _mmo_key()
  est.observe_batch(key, "xla", "local", 0, 1.0)           # zero slots
  est.observe_batch(key, "xla", "local", 1, float("nan"))  # NaN seconds
  est.observe_batch(key, "xla", "local", 1, float("inf"))
  assert est.observations(key, "xla", "local") == 0
  assert est.predict(key, "xla", "local", 7.0, 1.0) == Estimate(7.0, "static")


def test_constructor_validation():
  with pytest.raises(ValueError, match="half_life"):
    ServiceEstimator(half_life=0.0)
  with pytest.raises(ValueError, match="min_observations"):
    ServiceEstimator(min_observations=0)


# ---------------------------------------------------------------------------
# cold start + precedence
# ---------------------------------------------------------------------------


def test_cold_start_falls_back_to_static_prior():
  """Below min_observations the static prediction answers verbatim — one
  outlier first batch must not steer admission."""
  est = ServiceEstimator(min_observations=3)
  key = _mmo_key()
  assert est.predict(key, "xla", "local", 2.0, 3.0) == Estimate(6.0, "static")
  est.observe_batch(key, "xla", "local", 1, 100.0)
  est.observe_batch(key, "xla", "local", 1, 100.0)
  assert est.predict(key, "xla", "local", 2.0, 3.0).source == "static"
  est.observe_batch(key, "xla", "local", 1, 100.0)  # third reading → warm
  got = est.predict(key, "xla", "local", 2.0, 3.0)
  assert got.source == "ewma" and got.seconds == pytest.approx(100.0)


def test_cells_keyed_by_backend_and_schedule():
  """A bucket re-routed to another backend must not inherit the old route's
  latency readings; schedules keep separate cells (dp and local latencies
  are never averaged), but a cold *distributed* cell falls back to the
  bucket's measured local cell — per-batch placement can downgrade dp
  batches to 'local' (rb not divisible over the mesh), and measured local
  latency beats the static prior for a bucket that is mostly executing
  locally anyway."""
  est = ServiceEstimator(min_observations=1)
  key = _mmo_key()
  est.observe_batch(key, "pallas", "local", 1, 5.0)
  assert est.predict(key, "pallas", "local", 1.0, 1.0).source == "ewma"
  assert est.predict(key, "xla", "local", 1.0, 1.0).source == "static"
  # cold dp cell → the local cell answers ...
  assert est.predict(key, "pallas", "dp", 1.0, 1.0) == Estimate(5.0, "ewma")
  # ... until the dp cell itself warms, which then takes precedence
  est.observe_batch(key, "pallas", "dp", 1, 2.0)
  assert est.predict(key, "pallas", "dp", 1.0, 1.0) == Estimate(2.0, "ewma")
  # the fallback is one-way: 'local' never reads a distributed cell
  est2 = ServiceEstimator(min_observations=1)
  est2.observe_batch(key, "xla", "dp", 1, 2.0)
  assert est2.predict(key, "xla", "local", 1.0, 1.0).source == "static"


def test_measured_iterations_replace_worst_case_trip_count():
  """Closure cold start: with measured convergence counts but no warm
  seconds cell, the prediction is static per-contraction cost × the
  measured iteration EWMA, clamped to [1, worst_trips]."""
  est = ServiceEstimator(min_observations=3)
  key = _closure_key()
  # worst case for an nb=16 Leyzorek bucket is lg(16) = 4 squarings; the
  # traffic actually converges in 2
  est.observe_iterations(key, [2, 2, 2])
  assert est.iteration_estimate(key, 4.0) == pytest.approx(2.0)
  got = est.predict(key, "xla", "local", 1.0, 4.0)
  assert got.source == "iterations" and got.seconds == pytest.approx(2.0)
  # a noise reading above the worst case clamps to the bound
  est2 = ServiceEstimator()
  est2.observe_iterations(key, [9.0])
  assert est2.iteration_estimate(key, 4.0) == 4.0
  # and below 1 clamps up (a fixpoint runs at least one contraction)
  est3 = ServiceEstimator()
  est3.observe_iterations(key, [0.0])
  assert est3.iteration_estimate(key, 4.0) == 1.0


def test_warm_ewma_beats_iterations_beats_static():
  est = ServiceEstimator(min_observations=1)
  key = _closure_key()
  assert est.predict(key, "xla", "local", 1.0, 4.0).source == "static"
  est.observe_iterations(key, [2])
  assert est.predict(key, "xla", "local", 1.0, 4.0).source == "iterations"
  est.observe_batch(key, "xla", "local", 1, 0.5)
  got = est.predict(key, "xla", "local", 1.0, 4.0)
  assert got == Estimate(0.5, "ewma")


def test_snapshot_is_jsonable_and_labeled():
  import json
  est = ServiceEstimator()
  est.observe_batch(_mmo_key(), "xla", "local", 2, 0.2)
  est.observe_iterations(_closure_key(), [3])
  snap = est.snapshot()
  json.dumps(snap)  # must not raise
  (cell_label,) = snap["cells"]
  assert cell_label.endswith("|xla|local")
  assert snap["cells"][cell_label] == {"seconds": 0.1, "observations": 1}
  (it_label,) = snap["iterations"]
  assert it_label.startswith("closure/minplus")


# ---------------------------------------------------------------------------
# thread safety: observe on the serving loop, predict on submit threads
# ---------------------------------------------------------------------------


def test_concurrent_observe_predict_is_safe():
  """Hammer observe/observe_iterations/predict/snapshot from 8 threads: no
  exceptions, counts exact, and the final estimate sits inside the observed
  value range (no torn float reads)."""
  est = ServiceEstimator(half_life=4.0, min_observations=1)
  keys = [_mmo_key(), _closure_key()]
  errs, n_per_thread = [], 200
  barrier = threading.Barrier(8)

  def writer(i):
    try:
      barrier.wait()
      for j in range(n_per_thread):
        est.observe_batch(keys[0], "xla", "local", 1, 0.01 + 0.01 * (j % 3))
        est.observe_iterations(keys[1], [1 + (j % 4)])
    except Exception as e:  # noqa: BLE001
      errs.append(e)

  def reader(i):
    try:
      barrier.wait()
      for _ in range(n_per_thread):
        got = est.predict(keys[0], "xla", "local", 1.0, 1.0)
        assert got.seconds >= 0.0
        est.snapshot()
        est.iteration_estimate(keys[1], 8.0)
    except Exception as e:  # noqa: BLE001
      errs.append(e)

  threads = [threading.Thread(target=writer, args=(i,)) for i in range(4)]
  threads += [threading.Thread(target=reader, args=(i,)) for i in range(4)]
  for t in threads:
    t.start()
  for t in threads:
    t.join()
  assert not errs
  assert est.observations(keys[0], "xla", "local") == 4 * n_per_thread
  final = est.predict(keys[0], "xla", "local", 1.0, 1.0)
  assert final.source == "ewma" and 0.01 <= final.seconds <= 0.03
  assert 1.0 <= est.iteration_estimate(keys[1], 8.0) <= 4.0


# ---------------------------------------------------------------------------
# engine integration: live feedback corrects static predictions
# ---------------------------------------------------------------------------


def test_adaptive_engine_corrects_wrong_static_prediction():
  """A cost table that is wildly wrong (measured row says 100s for a
  millisecond bucket) poisons static predictions; after serving a few
  batches the adaptive engine's prediction collapses to measured reality.
  The non-adaptive engine keeps trusting the table — the drift this PR
  exists to close."""
  from repro.tuning import CostTable
  table = CostTable(device="test")
  table.record("mma", (16, 16, 16), "float32", "xla", (512,), 100.0)

  def run(adaptive):
    eng = MMOEngine(backend="xla", max_batch=2, cost_table=table,
                    adaptive=adaptive)
    key = None
    for i in range(8):
      a = RNG.standard_normal((12, 12)).astype(np.float32)
      from repro.serve_mmo import mmo_request
      req = mmo_request(a, a, op="mma")
      key = key or request_bucket(req)
      eng.submit(req)
    eng.run_until_idle()
    return eng.predict_request(key)

  static = run(adaptive=False)
  assert static == Estimate(100.0, "static")
  live = run(adaptive=True)
  assert live.source == "ewma"
  assert live.seconds < 1.0  # a 12×12 mma batch is not 100 seconds


def test_estimator_observations_exclude_compile_time():
  """A cache-miss batch must not feed trace+compile latency into the EWMA
  as device service time: compile is orders of magnitude above steady
  service and carries ~84% of the cell's weight when min_observations is
  reached, which would expire feasible deadlines and collapse batch caps
  for the next ~half-life of batches."""
  clock = FakeClock()
  eng = MMOEngine(backend="xla", max_batch=2, clock=clock)
  real = eng.cache.get_or_compile

  def slow_compile(*a, **kw):
    clock.t += 100.0  # a compile hiding inside the first batch
    return real(*a, **kw)

  eng.cache.get_or_compile = slow_compile
  from repro.serve_mmo import mmo_request
  a = RNG.standard_normal((12, 12)).astype(np.float32)
  eng.submit(mmo_request(a, a, op="mma"))
  eng.run_until_idle()
  snap = eng.estimator.snapshot()
  (label,) = snap["cells"]
  # the fake clock only moved during "compilation" — observed service is 0
  assert snap["cells"][label] == {"seconds": 0.0, "observations": 1}


def test_adaptive_engine_uses_measured_closure_iterations_cold():
  """Before the seconds cell warms, a closure bucket's prediction uses the
  measured convergence EWMA instead of the worst-case trip count."""
  from repro.tuning import CostTable
  table = CostTable(device="test")
  table.record("minplus", (16, 16, 16), "float32", "xla", (512,), 2.0)
  eng = MMOEngine(backend="xla", max_batch=4, cost_table=table, adaptive=True,
                  estimator=ServiceEstimator(min_observations=100))
  # dense graph → tiny diameter → converges below the lg(16) worst case
  w = graphs.weighted_digraph(12, 0.9, seed=0)
  key = request_bucket(apsp_request(w))
  assert eng.predict_request(key) == Estimate(8.0, "static")  # 2.0 × lg(16)
  fut = eng.submit(apsp_request(w))
  eng.run_until_idle()
  measured_iters = fut.result().extras["iterations"]
  got = eng.predict_request(key)
  assert got.source == "iterations"
  assert got.seconds == pytest.approx(2.0 * min(max(measured_iters, 1), 4))

"""Serving engine across families: MoE, SSM and enc-dec generate correctly
(greedy engine output == manual full-context rollout where exactness holds)."""
import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro import configs
from repro.launch.serve import Engine
from repro.models import zoo

RNG = np.random.default_rng(5)


@pytest.mark.parametrize("arch", ["mixtral-8x7b", "mamba2-780m",
                                  "qwen2.5-3b"])
def test_engine_matches_full_context(arch):
  cfg = configs.get_config(arch, smoke=True)
  params = zoo.init(cfg, jax.random.PRNGKey(2))
  eng = Engine(cfg, params, max_len=48)
  prompts = RNG.integers(0, cfg.vocab, (2, 12), dtype=np.int32)
  toks = eng.generate(prompts, 6)
  assert toks.shape == (2, 6)

  ctx = jnp.asarray(prompts, jnp.int32)
  # bf16 cache round-trips can flip near-ties; MoE amplifies them (a router
  # near-tie swaps experts, shifting logits by more than the tie gap)
  tol = 0.1 if cfg.n_experts else 2e-2
  for t in range(6):
    logits, _, _ = zoo.forward(params, cfg, {"tokens": ctx}, mode="train")
    nxt = np.asarray(jnp.argmax(logits[:, -1], axis=-1), np.int32)
    for b in range(2):
      if toks[b, t] != nxt[b]:
        lg = np.asarray(logits[b, -1], np.float32)
        assert abs(lg[toks[b, t]] - lg[nxt[b]]) < tol, (arch, t, b)
    ctx = jnp.concatenate(
        [ctx, jnp.asarray(toks[:, t:t + 1], jnp.int32)], axis=1)


def test_engine_encdec():
  cfg = configs.get_config("seamless-m4t-large-v2", smoke=True)
  params = zoo.init(cfg, jax.random.PRNGKey(3))
  eng = Engine(cfg, params, max_len=32)
  prompts = RNG.integers(0, cfg.vocab, (2, 8), dtype=np.int32)
  src = RNG.standard_normal((2, cfg.src_len, cfg.d_model)).astype(np.float32)
  toks = eng.generate(prompts, 5, src_embeds=src)
  assert toks.shape == (2, 5)
  assert int(toks.max()) < cfg.vocab

"""repro.analysis tests: fixture good/bad pairs per rule, suppressions,
baseline round-trip, and the self-run gate (the shipped tree must be clean).
"""
from __future__ import annotations

import json
import textwrap

import pytest

from repro import analysis
from repro.analysis.__main__ import main as cli_main


def _tree(tmp_path, files: dict):
  for rel, src in files.items():
    p = tmp_path / rel
    p.parent.mkdir(parents=True, exist_ok=True)
    p.write_text(textwrap.dedent(src), encoding="utf-8")
  return tmp_path


def _run(root, rules):
  return analysis.run(root, rules=rules)


# --- semiring family --------------------------------------------------------

GOOD_TABLE = """
    _T = {"mma": 1, "minplus": 2, "maxplus": 3, "minmul": 4, "maxmul": 5,
          "minmax": 6, "maxmin": 7, "orand": 8, "addnorm": 9}
"""

BAD_TABLE = """
    _T = {"mma": 1, "minplus": 2, "maxplus": 3, "minmul": 4, "maxmul": 5,
          "minmax": 6, "maxmin": 7, "orand": 8, "addnrm": 9}
"""


def test_table_coverage_good(tmp_path):
  root = _tree(tmp_path, {"mod.py": GOOD_TABLE})
  assert _run(root, "semiring-table-coverage").findings == []


def test_table_coverage_bad(tmp_path):
  root = _tree(tmp_path, {"mod.py": BAD_TABLE})
  found = _run(root, "semiring-table-coverage").findings
  msgs = " ".join(f.message for f in found)
  assert "addnorm" in msgs      # missing registered op
  assert "addnrm" in msgs       # unknown key


def test_pad_consistency_flags_broken_pair(tmp_path):
  # minplus pads must satisfy pa + pb == +inf (the ⊕-identity); (0.0, 0.0)
  # sums to 0.0 and would corrupt padded lanes
  root = _tree(tmp_path, {"mod.py": """
      import numpy as np
      _PADS = {"mma": (0.0, 0.0), "minplus": (0.0, 0.0),
               "maxplus": (0.0, float(-np.inf)),
               "minmul": (float(np.inf), float(np.inf)),
               "maxmul": (float(-np.inf), float(np.inf)),
               "minmax": (float(np.inf), float(np.inf)),
               "maxmin": (float(-np.inf), float(-np.inf)),
               "orand": (0.0, 0.0), "addnorm": (0.0, 0.0)}
  """})
  found = _run(root, "semiring-pad-consistency").findings
  assert any("minplus" in f.message for f in found)
  assert not any("'mma'" in f.message for f in found)


def test_hardcoded_identity_scoped_to_contraction_modules(tmp_path):
  src = """
      import numpy as np
      ACC = float(np.inf)
  """
  flagged = _tree(tmp_path / "a", {"core/closure.py": src})
  unflagged = _tree(tmp_path / "b", {"core/other.py": src})
  assert len(_run(flagged, "semiring-hardcoded-identity").findings) == 1
  assert _run(unflagged, "semiring-hardcoded-identity").findings == []


def test_semiring_laws_pass_on_live_registry(tmp_path):
  # the numeric family runs against the live registry regardless of the
  # scanned tree; an empty tree keeps the AST rules quiet
  root = _tree(tmp_path, {"empty.py": ""})
  rep = _run(root, "semiring-laws,semiring-closure-pads")
  assert rep.findings == []


# --- locks family -----------------------------------------------------------

LOCKED_CACHE = """
    import threading

    class ExecutableCache:
      def __init__(self):
        self._lock = threading.Lock()
        self._entries = {}
        self._misses = 0

      def get(self, k):
        with self._lock:
          return self._entries.get(k)

      def _insert_locked(self, k, v):
        self._entries[k] = v
"""

UNLOCKED_CACHE = """
    import threading

    class ExecutableCache:
      def __init__(self):
        self._lock = threading.Lock()
        self._entries = {}
        self._misses = 0

      def get(self, k):
        return self._entries.get(k)
"""


def test_lock_discipline_good(tmp_path):
  root = _tree(tmp_path, {"serve_mmo/cache.py": LOCKED_CACHE})
  assert _run(root, "lock-discipline").findings == []


def test_lock_discipline_bad(tmp_path):
  root = _tree(tmp_path, {"serve_mmo/cache.py": UNLOCKED_CACHE})
  found = _run(root, "lock-discipline").findings
  assert len(found) == 1
  assert "ExecutableCache.get" in found[0].message
  assert "_entries" in found[0].message


def test_lock_discipline_nested_def_not_protected(tmp_path):
  # a closure built under the lock may run after the lock is released
  root = _tree(tmp_path, {"serve_mmo/cache.py": """
      import threading

      class ExecutableCache:
        def __init__(self):
          self._lock = threading.Lock()
          self._entries = {}
          self._misses = 0

        def get(self, k):
          with self._lock:
            def later():
              return self._entries.get(k)
          return later
  """})
  found = _run(root, "lock-discipline").findings
  assert len(found) == 1


# --- trace family -----------------------------------------------------------

GOOD_JIT = """
    import functools
    import jax
    import jax.numpy as jnp

    @functools.partial(jax.jit, static_argnames=("n",))
    def f(x, n):
      if n > 2:              # static: fine
        x = x * 2
      for i in range(x.shape[0]):  # shape extraction is static
        x = x + i
      return jnp.sum(x)
"""

BAD_JIT = """
    import jax
    import numpy as np

    @jax.jit
    def f(x):
      if x > 0:              # traced branch
        x = x + 1
      y = float(x)           # host coercion
      z = np.sum(x)          # host numpy on traced
      return y + z
"""


def test_trace_safety_good(tmp_path):
  root = _tree(tmp_path, {"mod.py": GOOD_JIT})
  assert _run(root, "trace-safety").findings == []


def test_trace_safety_bad(tmp_path):
  root = _tree(tmp_path, {"mod.py": BAD_JIT})
  msgs = [f.message for f in _run(root, "trace-safety").findings]
  assert any("`if`" in m for m in msgs)
  assert any("float()" in m for m in msgs)
  assert any("np.sum" in m for m in msgs)


def test_trace_safety_propagates_through_helpers(tmp_path):
  root = _tree(tmp_path, {"mod.py": """
      import jax

      def helper(v):
        if v.any():          # only bad because f passes a tracer in
          return v * 2
        return v

      @jax.jit
      def f(a):
        return helper(a)
  """})
  found = _run(root, "trace-safety").findings
  assert any("helper" in f.message for f in found)


def test_cache_key_coverage_flags_unkeyed_knob(tmp_path):
  root = _tree(tmp_path, {"serve_mmo/engine.py": """
      from repro.serve_mmo import batching

      class MMOEngine:
        def __init__(self):
          self.interpret = False
          self.flavor = "x"

        def _exec_key(self, key, rb, backend):
          return (key, rb, backend)

        def go(self, key, rb, backend, block):
          return self.cache.get_or_compile(
              self._exec_key(key, rb, backend),
              lambda: batching.make_batch_fn(
                  key, backend=backend, block=block,
                  interpret=self.interpret, mesh=self.mesh),
              ())
  """})
  msgs = [f.message for f in _run(root, "cache-key-coverage").findings]
  assert any("`block`" in m for m in msgs)       # name not in key tuple
  # mesh/interpret are declared engine constants: not flagged
  assert not any("self.interpret" in m for m in msgs)
  assert not any("self.mesh" in m for m in msgs)


def test_cache_key_coverage_clean_engine_passes(tmp_path):
  root = _tree(tmp_path, {"serve_mmo/engine.py": """
      from repro.serve_mmo import batching

      class MMOEngine:
        def _exec_key(self, key, rb, backend):
          return (key, rb, backend, self._mesh_sig)

        def go(self, key, rb, backend):
          return self.cache.get_or_compile(
              self._exec_key(key, rb, backend),
              lambda: batching.make_batch_fn(key, backend=backend,
                                             interpret=self.interpret),
              ())
  """})
  assert _run(root, "cache-key-coverage").findings == []


# --- suppressions -----------------------------------------------------------


def test_suppression_same_line_and_line_above(tmp_path):
  root = _tree(tmp_path, {"core/closure.py": """
      import numpy as np
      A = float(np.inf)  # repro: ignore[semiring-hardcoded-identity]
      # repro: ignore[semiring-hardcoded-identity]
      B = float(np.inf)
      C = float(np.inf)
  """})
  rep = _run(root, "semiring-hardcoded-identity")
  assert len(rep.findings) == 1          # only C
  assert rep.suppressed == 2


def test_bare_suppression_silences_all_rules(tmp_path):
  root = _tree(tmp_path, {"core/closure.py": """
      import numpy as np
      A = float(np.inf)  # repro: ignore
  """})
  rep = _run(root, "semiring-hardcoded-identity")
  assert rep.findings == [] and rep.suppressed == 1


def test_wrong_rule_suppression_does_not_silence(tmp_path):
  root = _tree(tmp_path, {"core/closure.py": """
      import numpy as np
      A = float(np.inf)  # repro: ignore[lock-discipline]
  """})
  assert len(_run(root, "semiring-hardcoded-identity").findings) == 1


# --- baseline ---------------------------------------------------------------


def test_baseline_round_trip(tmp_path):
  root = _tree(tmp_path, {"serve_mmo/cache.py": UNLOCKED_CACHE})
  first = analysis.run(root, rules="lock-discipline")
  assert len(first.findings) == 1
  bl = tmp_path / "baseline.json"
  analysis.save_baseline(bl, first.findings)
  again = analysis.run(root, rules="lock-discipline",
                       baseline=analysis.load_baseline(bl))
  assert again.findings == [] and len(again.baselined) == 1
  assert again.ok


def test_baseline_survives_line_shifts(tmp_path):
  root = _tree(tmp_path, {"serve_mmo/cache.py": UNLOCKED_CACHE})
  bl = tmp_path / "baseline.json"
  analysis.save_baseline(bl, analysis.run(root,
                                          rules="lock-discipline").findings)
  # unrelated edit above the finding moves its line; fingerprint must hold
  shifted = "# a new comment line\n# another\n" + textwrap.dedent(
      UNLOCKED_CACHE)
  (root / "serve_mmo" / "cache.py").write_text(shifted, encoding="utf-8")
  again = analysis.run(root, rules="lock-discipline",
                       baseline=analysis.load_baseline(bl))
  assert again.findings == [] and len(again.baselined) == 1


def test_baseline_rejects_unknown_version(tmp_path):
  bl = tmp_path / "baseline.json"
  bl.write_text(json.dumps({"version": 99, "findings": []}))
  with pytest.raises(ValueError, match="version"):
    analysis.load_baseline(bl)


# --- CLI + self-run ---------------------------------------------------------


def test_cli_exits_zero_on_shipped_tree(capsys):
  assert cli_main([]) == 0
  out = capsys.readouterr().out
  assert "OK" in out


def test_cli_json_output_is_machine_readable(capsys):
  assert cli_main(["--json"]) == 0
  doc = json.loads(capsys.readouterr().out)
  assert doc["ok"] is True
  assert doc["findings"] == []
  assert set(doc["rules"]) >= {"lock-discipline", "trace-safety",
                               "semiring-laws"}


def test_cli_exits_nonzero_on_bad_tree(tmp_path, capsys):
  root = _tree(tmp_path, {"serve_mmo/cache.py": UNLOCKED_CACHE})
  assert cli_main(["--root", str(root), "--no-baseline"]) == 1
  assert "lock-discipline" in capsys.readouterr().out


def test_cli_update_baseline_then_clean(tmp_path, capsys):
  root = _tree(tmp_path, {"serve_mmo/cache.py": UNLOCKED_CACHE})
  bl = tmp_path / "bl.json"
  assert cli_main(["--root", str(root), "--baseline", str(bl),
                   "--update-baseline"]) == 0
  assert cli_main(["--root", str(root), "--baseline", str(bl)]) == 0
  capsys.readouterr()


def test_cli_rules_selector_rejects_unknown(capsys):
  with pytest.raises(SystemExit):
    cli_main(["--rules", "no-such-rule"])
  capsys.readouterr()


def test_self_run_is_fast_and_clean():
  """The acceptance gate: all three families over src/repro, zero new
  findings, under 10 seconds."""
  from repro.analysis.__main__ import DEFAULT_BASELINE, DEFAULT_ROOT
  report = analysis.run(DEFAULT_ROOT,
                        baseline=analysis.load_baseline(DEFAULT_BASELINE))
  assert report.findings == [], "\n".join(str(f) for f in report.findings)
  assert report.elapsed_s < 10.0
  fams = {analysis.all_rules()[r].family for r in report.rules_run}
  assert fams == set(analysis.FAMILIES)

"""Semiring-aware CSR: seeds validated against the registry, results
identical to the dense contraction, addnorm refused (no ⊗-annihilator).
"""
from __future__ import annotations

import numpy as np
import pytest

from repro.core import semiring as sr_mod
from repro.core import sparse
from repro.core.mmo import mmo

STORABLE = [op for op in sr_mod.ALL_OPS if op != "addnorm"]


def _sample(op, rng, shape):
  sr = sr_mod.get(op)
  if sr.boolean:
    return rng.random(shape) < 0.5
  if op in ("minmul", "maxmul", "maxmin"):
    return rng.uniform(0.25, 2.0, shape)  # positive operating domain
  return rng.uniform(-1.0, 1.0, shape)


@pytest.mark.parametrize("op", STORABLE)
def test_csr_seed_validates(op):
  sparse.validate_csr_seed(op)  # must not raise on the shipped table


@pytest.mark.parametrize("op", STORABLE)
def test_csr_spmm_matches_dense(op):
  rng = np.random.default_rng(7)
  sr = sr_mod.get(op)
  absent = sparse.csr_absent_value(op)
  a = _sample(op, rng, (6, 8))
  b = _sample(op, rng, (8, 5))
  mask = rng.random((6, 8)) < 0.4
  dt = bool if sr.boolean else np.float64
  a = np.asarray(a, dt)
  a[mask] = absent
  a[3, :] = absent  # one fully-absent row
  indptr, indices, data = sparse.to_csr(a, op=op)
  assert len(data) == np.count_nonzero(a != np.asarray(absent, dt))
  got = sparse.csr_spmm(indptr, indices, data, np.asarray(b, dt), op=op)
  want = np.asarray(mmo(np.asarray(a, np.float32 if not sr.boolean else bool),
                        np.asarray(b, np.float32 if not sr.boolean else bool),
                        op=op))
  np.testing.assert_allclose(got.astype(np.float64),
                             want.astype(np.float64), atol=1e-5)


def test_addnorm_csr_refused():
  with pytest.raises(ValueError, match="annihilator"):
    sparse.to_csr(np.zeros((2, 2)), op="addnorm")
  with pytest.raises(ValueError, match="annihilator"):
    sparse.csr_absent_value("addnorm")


def test_bad_seed_rejected(monkeypatch):
  # 1.0 is not absorbed under mma: 1*x contributes x, so dropping it lies
  monkeypatch.setitem(sparse._ABSENT, "mma", 1.0)
  with pytest.raises(ValueError, match="not absorbed"):
    sparse.validate_csr_seed("mma")


def test_mma_default_matches_legacy_path():
  rng = np.random.default_rng(3)
  a = rng.standard_normal((5, 7))
  a[rng.random((5, 7)) < 0.5] = 0.0
  b = rng.standard_normal((7, 4))
  csr = sparse.to_csr(a)          # default op="mma" — historical behavior
  # csr_spmm routes ⊗/⊕ through jnp (f32 on hosts without x64): compare at
  # single precision against the pure-numpy f64 legacy path
  np.testing.assert_allclose(sparse.csr_spmm_np(*csr, b),
                             sparse.csr_spmm(*csr, b, op="mma"), atol=1e-5)

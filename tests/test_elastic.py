"""Elasticity control plane: failure detection, stragglers, re-mesh plan."""
from repro.launch.elastic import Coordinator, plan_remesh

from conftest import FakeClock


def test_failure_detection():
  clk = FakeClock()
  c = Coordinator(["h0", "h1", "h2"], deadline_s=10, clock=clk)
  clk.t = 5
  c.beat("h0")
  c.beat("h1")
  assert c.sweep() == []
  clk.t = 16  # h2 late (11s) → suspect
  c.beat("h0")
  c.beat("h1")
  assert c.sweep() == []
  assert c.hosts["h2"].suspect
  clk.t = 26  # h2 gone (>2×deadline)
  assert c.sweep() == ["h2"]
  assert sorted(c.alive()) == ["h0", "h1"]
  # a returning heartbeat resurrects nothing automatically — dead is dead
  # until re-admission, but suspect clears
  c.beat("h2")
  assert not c.hosts["h2"].suspect


def test_straggler_policy():
  clk = FakeClock()
  c = Coordinator([f"h{i}" for i in range(4)], patience=3, clock=clk,
                  straggler_threshold=1.5)
  for step in range(6):
    clk.t += 1
    for i in range(4):
      ms = 100.0 if i != 3 else 300.0  # h3 is 3× slower
      c.beat(f"h{i}", step_ms=ms)
    out = c.stragglers()
  assert out == ["h3"]


def test_straggler_recovers():
  clk = FakeClock()
  c = Coordinator(["a", "b"], patience=2, clock=clk)
  c.beat("a", 100)
  c.beat("b", 500)
  c.stragglers()
  c.beat("a", 100)
  c.beat("b", 100)  # recovered → streak resets before patience
  for _ in range(5):
    c.beat("a", 100)
    c.beat("b", 105)
    assert c.stragglers() == []


def test_plan_remesh():
  assert plan_remesh(64, 4, model=16) == (16, 16)   # full pod intact
  assert plan_remesh(63, 4, model=16) == (8, 16)    # lost a host → dp 15→8
  assert plan_remesh(4, 4, model=16) == (1, 16)     # minimum viable
  assert plan_remesh(3, 4, model=16) is None        # TP group broken

"""Table-1 extras: matrix inverse (mma ring) and k-means (addnorm)."""
import jax.numpy as jnp
import numpy as np

from repro.apps.extras import kmeans, newton_inverse


def test_newton_inverse():
  rng = np.random.default_rng(11)
  a = rng.standard_normal((24, 24)).astype(np.float32)
  a = a @ a.T + 24 * np.eye(24, dtype=np.float32)  # well-conditioned SPD
  inv, resid = newton_inverse(jnp.asarray(a))
  np.testing.assert_allclose(np.asarray(inv), np.linalg.inv(a),
                             rtol=1e-3, atol=1e-4)
  assert float(resid) < 1e-3


def test_kmeans_recovers_clusters():
  rng = np.random.default_rng(12)
  centers = np.array([[0, 0], [8, 8], [-8, 8]], np.float32)
  pts = np.concatenate([
      c + 0.3 * rng.standard_normal((50, 2)).astype(np.float32)
      for c in centers])
  cents, assign, inertia = kmeans(jnp.asarray(pts), k=3, iters=25)
  # every found centroid is within 0.5 of a true center, each cluster pure
  cents = np.asarray(cents)
  d = np.linalg.norm(cents[:, None] - centers[None], axis=-1).min(axis=1)
  assert (d < 0.5).all(), cents
  assign = np.asarray(assign)
  for g in range(3):
    grp = assign[g * 50:(g + 1) * 50]
    assert (grp == grp[0]).all()
  assert float(inertia) < 0.3 ** 2 * 2 * 150 * 3  # loose noise bound

"""Core semiring/mmo correctness: every op × backend × shape × dtype."""
import jax.numpy as jnp
import numpy as np
import pytest

from repro.core import ALL_OPS, get_semiring, mmo, mmo_reference

RNG = np.random.default_rng(0)
SHAPES = [(8, 16, 8), (13, 7, 5), (32, 64, 24)]


def _operands(op, m, k, n, dtype=np.float32):
  a = RNG.standard_normal((m, k)).astype(dtype)
  b = RNG.standard_normal((k, n)).astype(dtype)
  c = RNG.standard_normal((m, n)).astype(dtype)
  if op == "orand":
    return a > 0.5, b > 0.5, c > 1.0
  return a, b, c


@pytest.mark.parametrize("op", ALL_OPS)
@pytest.mark.parametrize("shape", SHAPES)
@pytest.mark.parametrize("backend", ["vector", "xla"])
def test_mmo_matches_reference(op, shape, backend):
  a, b, c = _operands(op, *shape)
  got = mmo(jnp.asarray(a), jnp.asarray(b), jnp.asarray(c), op=op,
            backend=backend, block_k=5)
  ref = mmo_reference(jnp.asarray(a), jnp.asarray(b), jnp.asarray(c), op=op)
  np.testing.assert_allclose(np.asarray(got, np.float64),
                             np.asarray(ref, np.float64),
                             rtol=1e-5, atol=1e-5)


@pytest.mark.parametrize("op", ["minplus", "maxmin", "mma"])
def test_mmo_no_c_operand(op):
  a, b, _ = _operands(op, 9, 11, 6)
  got = mmo(jnp.asarray(a), jnp.asarray(b), op=op)
  ref = mmo_reference(jnp.asarray(a), jnp.asarray(b), op=op)
  np.testing.assert_allclose(np.asarray(got), np.asarray(ref), rtol=1e-5)


@pytest.mark.parametrize("op", ALL_OPS)
def test_bf16_inputs(op):
  a, b, c = _operands(op, 16, 32, 16)
  if op != "orand":
    a, b, c = (x.astype(jnp.bfloat16) for x in (a, b, c))
  got = mmo(jnp.asarray(a), jnp.asarray(b), jnp.asarray(c), op=op)
  ref = mmo_reference(jnp.asarray(a), jnp.asarray(b), jnp.asarray(c), op=op)
  np.testing.assert_allclose(np.asarray(got, np.float64),
                             np.asarray(ref, np.float64),
                             rtol=5e-2, atol=5e-2)


def test_infinity_sentinels_minplus():
  """+inf sentinels (missing edges) must survive the contraction."""
  a = np.full((4, 4), np.inf, np.float32)
  np.fill_diagonal(a, 0)
  a[0, 1] = 3.0
  out = np.asarray(mmo(jnp.asarray(a), jnp.asarray(a), jnp.asarray(a),
                       op="minplus"))
  assert out[0, 1] == 3.0
  assert np.isinf(out[0, 2])
  assert out[0, 0] == 0.0


def test_semiring_registry():
  for op in ALL_OPS:
    sr = get_semiring(op)
    assert sr.name == op
  with pytest.raises(ValueError):
    get_semiring("nope")


def test_identity_element():
  """x ⊕ identity == x for every ring."""
  for op in ALL_OPS:
    sr = get_semiring(op)
    x = jnp.asarray(RNG.standard_normal((4, 4)).astype(np.float32))
    if sr.boolean:
      x = x > 0
    ident = sr.identity_like(x.shape, x.dtype)
    np.testing.assert_array_equal(np.asarray(sr.oplus(x, ident)),
                                  np.asarray(x))

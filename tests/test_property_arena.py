"""Property-based arena tests: random slot lifecycles vs a pure-Python model.

Hypothesis drives random admit/tick/sweep sequences (with backfill arising
naturally whenever an admit follows a sweep) against both the real
device-resident arena and a trivially-auditable host model.  The model
predicts the ENTIRE observable lifecycle from two numbers per request —
the batched reference's iteration count and the arena's per-tick budget:

    iters_done' = min(ref_iters, min(max_iters, iters_done + g))
    evict exactly when iters_done == ref_iters or iters_done >= max_iters

so the properties pin, for every random schedule:

  * the sweep's evicted slot set equals the model's prediction (no early,
    late, or spurious evictions),
  * every eviction's value and iteration count are bit-equal to the
    batched reference — a converged slot's value cannot drift no matter
    how many extra ticks its neighbors keep it resident for,
  * an admit never lands on a live slot and capacity is never exceeded,
  * every admitted request is evicted exactly once after drain.
"""
import numpy as np
import pytest

pytest.importorskip("hypothesis")
from hypothesis import given, settings, strategies as st  # noqa: E402

from repro.core import closure as cl_mod  # noqa: E402
from repro.serve_mmo import RequestArena, apsp_request  # noqa: E402
from repro.serve_mmo.cache import ExecutableCache  # noqa: E402
from repro.serve_mmo.scheduler import request_bucket  # noqa: E402

_CACHE = ExecutableCache()  # shared: each (capacity, g) combo compiles once
_NB = 8


def _line(n, seed):
  rng = np.random.default_rng(seed)
  w = np.full((n, n), np.inf, np.float32)
  w[np.arange(n - 1), np.arange(1, n)] = rng.uniform(
      0.5, 1.5, n - 1).astype(np.float32)
  return w


def _reference(w, n):
  prepared = cl_mod.prepare_adjacency(np.asarray(w), op="minplus")
  stack = np.asarray(cl_mod.pad_adjacency(prepared, _NB, op="minplus"))[None]
  out, it = cl_mod.batched_bellman_ford_closure(
      stack, op="minplus", backend="xla", valid_n=np.asarray([n], np.int32))
  return np.array(np.asarray(out[0])[:n, :n]), int(it[0])


# small graph pool, references precomputed once: (weights, n, value, iters)
_POOL = []
for _i, _n in enumerate((5, 6, 7, 8, 6, 8)):
  _w = _line(_n, 100 + _i)
  _v, _it = _reference(_w, _n)
  _POOL.append((_w, _n, _v, _it))


class _ModelArena:
  """The host-side prediction of the device arena's observable behavior."""

  def __init__(self, capacity, g, max_iters):
    self.capacity, self.g, self.max_iters = capacity, g, max_iters
    self.slots = {}  # slot -> [pool_idx, iters_done]

  def admit(self, slot, pool_idx):
    assert slot not in self.slots, "admit landed on a live slot"
    assert len(self.slots) < self.capacity, "capacity exceeded"
    self.slots[slot] = [pool_idx, 0]

  def tick(self):
    for state in self.slots.values():
      ref_iters = _POOL[state[0]][3]
      state[1] = min(ref_iters, min(self.max_iters, state[1] + self.g))

  def done_slots(self):
    return {s for s, (pi, it) in self.slots.items()
            if it == _POOL[pi][3] or it >= self.max_iters}


def _check_sweep(arena, model, completions):
  evictions = arena.sweep()
  assert {ev.slot for ev in evictions} == model.done_slots()
  for ev in evictions:
    pool_idx, iters_done = model.slots.pop(ev.slot)
    _, n, ref_value, _ = _POOL[pool_idx]
    assert ev.iterations == iters_done
    np.testing.assert_array_equal(ev.value, ref_value)
    assert id(ev.request) not in completions, "request completed twice"
    completions[id(ev.request)] = pool_idx


@settings(max_examples=25, deadline=None)
@given(capacity=st.integers(1, 3), g=st.integers(1, 3),
       picks=st.lists(st.integers(0, len(_POOL) - 1),
                      min_size=1, max_size=5),
       ops=st.lists(st.sampled_from(["admit", "tick", "sweep"]),
                    min_size=1, max_size=30))
def test_random_lifecycle_matches_model(capacity, g, picks, ops):
  pending = [(apsp_request(_POOL[i][0], algorithm="bellman_ford"), i)
             for i in picks]
  arena = RequestArena(request_bucket(pending[0][0]), capacity=capacity,
                       g=g, cache=_CACHE, interpret=True)
  model = _ModelArena(capacity, g, arena.max_iters)
  completions = {}
  admitted = []

  # the drawn schedule, with a drain appended so every example finishes
  schedule = list(ops) + ["admit", "tick", "sweep"] * (
      len(pending) * (arena.max_iters // g + 2))
  for op in schedule:
    if op == "admit":
      if not pending or arena.free_slots() == 0:
        continue
      req, pool_idx = pending.pop(0)
      slot = arena.admit(req)
      model.admit(slot, pool_idx)
      admitted.append(id(req))
    elif op == "tick":
      ticked = arena.tick()
      assert ticked == bool(model.slots)
      model.tick()
    else:
      _check_sweep(arena, model, completions)

  assert not pending and not model.slots and arena.live_slots() == 0
  # every admitted request evicted exactly once, with the graph it carried
  assert sorted(completions) == sorted(admitted)
  assert sorted(completions.values()) == sorted(picks)
  stats = arena.stats()
  assert stats["admitted"] == stats["evicted"] == len(picks)

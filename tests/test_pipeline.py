"""Pipeline parallelism: GPipe schedule ≡ sequential layer application, for
forward AND gradients (subprocess with 8 host devices)."""
import os
import subprocess
import sys
import textwrap

import pytest

SRC = os.path.join(os.path.dirname(__file__), "..", "src")

_SCRIPT = textwrap.dedent("""
    import os
    os.environ["XLA_FLAGS"] = "--xla_force_host_platform_device_count=8"
    import jax, jax.numpy as jnp, numpy as np
    from jax.sharding import PartitionSpec as P
    from repro.models.pipeline import bubble_fraction, pipeline, split_stages

    mesh = jax.make_mesh((4, 2), ("stage", "data"))
    S, LPS, D, M, MB = 4, 3, 16, 6, 2   # stages, layers/stage, dim, micro, mb
    L = S * LPS
    rng = np.random.default_rng(0)
    W = jnp.asarray(rng.standard_normal((L, D, D)) / np.sqrt(D), jnp.float32)
    X = jnp.asarray(rng.standard_normal((M, MB, D)), jnp.float32)

    def layer(x, w):
        return jnp.tanh(x @ w), None

    def stage_fn(w_stage, x):   # scan this stage's layer slice
        y, _ = jax.lax.scan(layer, x, w_stage)
        return y

    # reference: plain sequential over all layers, microbatches independent
    def ref_fwd(W, X):
        def f(x):
            y, _ = jax.lax.scan(layer, x, W)
            return y
        return jax.vmap(f)(X)

    staged = split_stages(W, S)
    with mesh:
        pl = pipeline(stage_fn, mesh, axis="stage",
                      in_spec=P("stage"), x_spec=P(None, "data"))
        got = pl(staged, X)
    ref = ref_fwd(W, X)
    err = float(jnp.abs(got - ref).max())
    assert err < 1e-5, err
    print("FWD_OK", err)

    # gradients flow through the ppermute schedule
    def loss_pl(Wst, X):
        with mesh:
            return (pipeline(stage_fn, mesh, axis="stage",
                             in_spec=P("stage"),
                             x_spec=P(None, "data"))(Wst, X) ** 2).sum()
    def loss_ref(W, X):
        return (ref_fwd(W, X) ** 2).sum()
    g_pl = jax.grad(loss_pl)(staged, X).reshape(L, D, D)
    g_ref = jax.grad(loss_ref)(W, X)
    gerr = float(jnp.abs(g_pl - g_ref).max())
    assert gerr < 1e-4, gerr
    print("GRAD_OK", gerr)

    assert abs(bubble_fraction(4, 6) - 3/9) < 1e-9
    print("BUBBLE_OK")
""")


@pytest.mark.slow
def test_pipeline_parallelism():
  env = dict(os.environ, PYTHONPATH=SRC)
  r = subprocess.run([sys.executable, "-c", _SCRIPT], capture_output=True,
                     text=True, env=env, timeout=900)
  assert r.returncode == 0, r.stderr[-3000:]
  for m in ("FWD_OK", "GRAD_OK", "BUBBLE_OK"):
    assert m in r.stdout

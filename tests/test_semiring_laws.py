"""Tier-1 numeric semiring-law gate (ISSUE: laws checked in the test path,
not only via the analyzer CLI): every registered ring must satisfy its
algebra over adversarial floats, and the closure pad tables must be
invariant under repeated squaring.
"""
from __future__ import annotations

import pytest

from repro.analysis import laws
from repro.core import closure as cl_mod
from repro.core import semiring as sr_mod


@pytest.mark.parametrize("op", sr_mod.ALL_OPS)
def test_laws_hold(op):
  failures = laws.check_laws(op)
  assert failures == [], "\n".join(failures)


@pytest.mark.parametrize("op", sr_mod.ALL_OPS)
def test_closure_pads_invariant(op):
  failures = laws.check_closure_pads(op)
  assert failures == [], "\n".join(failures)


def test_otimes_identity_registered_for_all_true_semirings():
  for op in sr_mod.ALL_OPS:
    sr = sr_mod.get(op)
    if op == "addnorm":
      assert sr.otimes_identity is None  # (a-b)² has no identity
    else:
      assert sr.otimes_identity is not None, op


def test_addnorm_closure_padding_refused():
  # (x-0)² == x² feeds pad vertices back into the real block after one
  # squaring, so closure padding is undefined for addnorm — the guard in
  # closure_pad_values must refuse rather than silently corrupt
  with pytest.raises(ValueError, match="no ⊗-identity"):
    cl_mod.closure_pad_values("addnorm")

"""Sharded bucket execution: mesh routing, dispatch mesh rows, and the
batched distributed schedules.

Quick tests run in-process on a trivial (1, 1) mesh (a real Mesh over the
single host device — the full sharded code path, no subprocess).  The
multi-device suite runs in a subprocess with 8 fake host devices, like
tests/test_distributed.py, so the main process keeps seeing 1 device.
"""
import os
import subprocess
import sys
import textwrap

import numpy as np
import pytest

import jax

from repro.apps import graphs, solvers
from repro.serve_mmo import MMOEngine, apsp_request, mmo_request
from repro.serve_mmo.scheduler import request_bucket
from repro.tuning import (CostTable, prior_seconds, resolve,
                          sharded_prior_seconds)

SRC = os.path.join(os.path.dirname(__file__), "..", "src")


def _mesh11():
  return jax.make_mesh((1, 1), ("data", "model"))


# ---------------------------------------------------------------------------
# sharded roofline prior + dispatch mesh rows (host-side, no devices needed)
# ---------------------------------------------------------------------------


def test_ring_traffic_bytes_model():
  from repro.roofline.collectives import ring_traffic_bytes
  assert ring_traffic_bytes("all-reduce", 100.0, 4) == pytest.approx(150.0)
  assert ring_traffic_bytes("all-gather", 100.0, 4) == pytest.approx(75.0)
  assert ring_traffic_bytes("collective-permute", 100.0, 4) == 100.0
  with pytest.raises(ValueError):
    ring_traffic_bytes("gossip", 1.0, 2)


@pytest.mark.parametrize("schedule", ["dp", "kspan", "summa", "ring"])
def test_sharded_prior_finite_and_positive(schedule):
  s = sharded_prior_seconds("minplus", (256, 256, 256), "float32", schedule,
                            (2, 4))
  assert 0.0 < s < 1.0
  with pytest.raises(ValueError):
    sharded_prior_seconds("minplus", (256,) * 3, "float32", "nope", (2, 4))


def test_prior_crossover_small_local_big_sharded():
  """The model's whole point: collectives lose on small contractions and win
  on big ones (VPU-bound minplus at 512³ vs 16³ on the v5e constants)."""
  small = resolve("minplus", 16, 16, 16, "float32", table=CostTable(),
                  mesh_shape=(2, 4))
  assert small.backend in ("xla", "vector", "pallas")
  big = resolve("minplus", 512, 512, 512, "float32", table=CostTable(),
                mesh_shape=(2, 4))
  assert big.backend in ("kspan", "summa", "ring")
  assert big.cfg == (2, 4)
  # and the sharded prior really is below the local prior at the big point
  assert (sharded_prior_seconds("minplus", (512,) * 3, "float32", big.backend,
                                (2, 4))
          < prior_seconds("minplus", (512,) * 3, "float32", "xla"))


def test_measured_mesh_row_beats_unmeasured_prior_arm():
  """A measured sharded row must win over a sibling arm's idealized prior,
  and a measured sharded row competes directly with a measured local row."""
  t = CostTable(device="test")
  t.record("minplus", (16, 16, 16), "float32", "xla", (512,), 1.0)
  t.record("minplus", (16, 16, 16), "float32", "kspan", (2, 4), 1e-6)
  d = resolve("minplus", 16, 16, 16, "float32", table=t, mesh_shape=(2, 4))
  assert (d.backend, d.cfg, d.source) == ("kspan", (2, 4), "measured")
  # restricting the schedules hides the kspan row → prior-vs-prior → local
  d2 = resolve("minplus", 16, 16, 16, "float32", table=t, mesh_shape=(2, 4),
               schedules=("summa",))
  assert d2.backend == "xla"
  with pytest.raises(ValueError):
    resolve("minplus", 16, 16, 16, "float32", table=t, mesh_shape=(2, 4),
            schedules=("gossip",))


def test_resolve_without_mesh_unchanged():
  t = CostTable(device="test")
  t.record("minplus", (16, 16, 16), "float32", "vector", (128,), 1e-6)
  assert resolve("minplus", 16, 16, 16, "float32", table=t).backend == "vector"


# ---------------------------------------------------------------------------
# engine routing (trivial (1, 1) mesh — full sharded path on one device)
# ---------------------------------------------------------------------------


def test_schedule_fits_divisibility():
  from repro.core.distributed import schedule_fits
  mesh = _mesh11()
  assert schedule_fits("summa", 16, 16, 16, mesh)
  # dp has no problem-axis constraint (request divisibility is the engine's
  # per-batch check)
  assert schedule_fits("dp", 17, 23, 3, mesh)
  assert not schedule_fits("nope", 16, 16, 16, mesh)


def test_engine_requires_mesh_for_pinned_schedule():
  with pytest.raises(ValueError, match="needs a mesh"):
    MMOEngine(schedule="summa")
  # a typo'd schedule must fail loudly, not silently serve local
  with pytest.raises(ValueError, match="unknown schedule"):
    MMOEngine(schedule="suma")
  with pytest.raises(ValueError, match="unknown schedule"):
    MMOEngine(mesh=_mesh11(), schedule="suma")


def test_router_threshold_and_pinned_schedule():
  mesh = _mesh11()
  # below the cutoff → local even with a pinned schedule
  eng = MMOEngine(backend="xla", mesh=mesh, schedule="summa",
                  shard_flops=1e12)
  key = request_bucket(apsp_request(graphs.weighted_digraph(10, 0.3, seed=0)))
  assert eng.resolve_schedule(key) == "local"
  # above the cutoff → the pinned schedule
  eng2 = MMOEngine(backend="xla", mesh=mesh, schedule="summa", shard_flops=0.0)
  assert eng2.resolve_schedule(key) == "summa"
  # closure buckets never route to kspan/ring (iterate must stay in place)
  eng3 = MMOEngine(backend="xla", mesh=mesh, schedule="ring", shard_flops=0.0)
  assert eng3.resolve_schedule(key) == "local"
  # mmo buckets may
  mkey = request_bucket(mmo_request(np.zeros((12, 12), np.float32),
                                    np.zeros((12, 12), np.float32),
                                    op="minplus"))
  assert eng3.resolve_schedule(mkey) == "ring"
  # dp (independent per-device fixpoints) is allowed for closures
  eng4 = MMOEngine(backend="xla", mesh=mesh, schedule="dp", shard_flops=0.0)
  assert eng4.resolve_schedule(key) == "dp"
  # ... and the placement never falls back on a 1-device mesh (rb % 1 == 0)
  assert eng4.resolve_placement(key, 3)[2] == "dp"


def test_sharded_and_local_executables_never_collide():
  """The (schedule, mesh) placement is part of the executable-cache key."""
  eng = MMOEngine(backend="xla", mesh=_mesh11(), schedule="summa",
                  shard_flops=0.0)
  key = request_bucket(apsp_request(graphs.weighted_digraph(10, 0.3, seed=0)))
  local_key = eng._exec_key(key, 1, "xla", (), "local")
  shard_key = eng._exec_key(key, 1, "xla", (), "summa")
  assert local_key != shard_key
  assert local_key[-1] is None and shard_key[-1] == (("data", 1), ("model", 1))


def test_engine_sharded_path_matches_solver_on_trivial_mesh():
  """End-to-end through stack→compile→execute→split with schedule='summa'
  on a (1, 1) mesh: same results as the direct solvers, zero retraces on
  repeat traffic, and the memoized placement is sharded."""
  eng = MMOEngine(backend="xla", mesh=_mesh11(), schedule="summa",
                  shard_flops=0.0, max_batch=4)

  def traffic():
    futs = [eng.submit(apsp_request(graphs.weighted_digraph(n, 0.3, seed=n)))
            for n in (9, 11, 13)]
    eng.run_until_idle()
    return futs

  futs = traffic()
  assert set(eng._schedules.values()) == {"summa"}
  for fut, n in zip(futs, (9, 11, 13)):
    ref, _ = solvers.apsp(graphs.weighted_digraph(n, 0.3, seed=n))
    np.testing.assert_allclose(fut.result().value, np.asarray(ref), atol=1e-5)
  misses = eng.cache.misses
  assert misses > 0
  futs2 = traffic()  # steady state: sharded executables replay
  assert eng.cache.misses == misses
  assert all(f.done() for f in futs2)


def test_prewarm_sharded_matches_step():
  eng = MMOEngine(backend="xla", mesh=_mesh11(), schedule="summa",
                  shard_flops=0.0, max_batch=2)
  eng.prewarm([apsp_request(graphs.weighted_digraph(10, 0.3, seed=0))])
  misses = eng.cache.misses
  eng.submit(apsp_request(graphs.weighted_digraph(12, 0.3, seed=1)))
  eng.run_until_idle()
  assert eng.cache.misses == misses


# ---------------------------------------------------------------------------
# multi-device suite (subprocess, 8 fake host devices)
# ---------------------------------------------------------------------------

_SCRIPT = textwrap.dedent("""
    import os
    os.environ["XLA_FLAGS"] = "--xla_force_host_platform_device_count=8"
    import numpy as np, jax, jax.numpy as jnp
    from repro.core import semiring as sr_mod
    from repro.core import mmo_batched, mmo_reference
    from repro.core import pad_adjacency, prepare_adjacency
    from repro.core.closure import batched_leyzorek_closure
    from repro.core.distributed import (mmo_kspan_batched, ring_mmo_batched,
                                        sharded_closure_batched,
                                        summa_mmo_batched)

    mesh = jax.make_mesh((2, 4), ("data", "model"))
    rng = np.random.default_rng(0)
    R, M, K, N = 3, 16, 32, 24

    # summa gathers K-panels over BOTH axes — a K that doesn't divide the
    # mesh must be rejected by the fit check, not crash inside shard_map
    from repro.core.distributed import schedule_fits
    assert schedule_fits("summa", 16, 32, 16, mesh)
    assert not schedule_fits("summa", 16, 2, 16, mesh)

    # --- 1. every registered op: batched schedules == local batched path ---
    # min/max/or rings are bit-identical (⊕ is order-independent); the two
    # (+)-reductions see cross-device summation order, so tight allclose.
    for op in sr_mod.ALL_OPS:
        sr = sr_mod.get(op)
        a = rng.standard_normal((R, M, K)).astype(np.float32)
        b = rng.standard_normal((R, K, N)).astype(np.float32)
        c = rng.standard_normal((R, M, N)).astype(np.float32)
        if op in ("minmul", "maxmul"):
            a, b = np.abs(np.tanh(a)), np.abs(np.tanh(b))
        if sr.boolean:
            a, b, c = a > 0.3, b > 0.3, c > 0.8
        kv = np.asarray([K, K - 8, K - 16], np.int32)
        pa, pb = sr_mod.contraction_pads(op)
        if sr.boolean:
            pa = pb = False
        for i, k in enumerate(kv):  # honor the k_valid contract
            a[i, :, k:] = pa
            b[i, k:, :] = pb
        a, b, c = jnp.asarray(a), jnp.asarray(b), jnp.asarray(c)
        kvj = jnp.asarray(kv)
        local = np.asarray(mmo_batched(a, b, c, op=op, backend="xla",
                                       k_valid=kvj))
        for fn in (mmo_kspan_batched, summa_mmo_batched, ring_mmo_batched):
            got = np.asarray(fn(a, b, c, op=op, mesh=mesh, k_valid=kvj))
            if sr.oplus in (jnp.minimum, jnp.maximum, jnp.logical_or):
                assert np.array_equal(got, local), (op, fn.__name__)
            else:
                np.testing.assert_allclose(got, local, atol=1e-4,
                                           err_msg=f"{op} {fn.__name__}")
            nokv = np.asarray(fn(a, b, c, op=op, mesh=mesh))
            np.testing.assert_allclose(nokv, np.asarray(
                mmo_reference(a, b, c, op=op)), atol=1e-4)
    print("SCHEDULES_ALLOPS_OK")

    # --- 1b. dp: request-sharded contraction == local, divisibility check --
    from repro.core.distributed import mmo_dp_batched
    a = rng.standard_normal((8, M, K)).astype(np.float32)
    b = rng.standard_normal((8, K, N)).astype(np.float32)
    kv = np.asarray([K - 8 * (i % 3) for i in range(8)], np.int32)
    pa, pb = sr_mod.contraction_pads("minplus")
    for i, k in enumerate(kv):
        a[i, :, k:] = pa
        b[i, k:, :] = pb
    a, b, kvj = jnp.asarray(a), jnp.asarray(b), jnp.asarray(kv)
    got = np.asarray(mmo_dp_batched(a, b, op="minplus", mesh=mesh,
                                    k_valid=kvj))
    want = np.asarray(mmo_batched(a, b, op="minplus", backend="xla",
                                  k_valid=kvj))
    assert np.array_equal(got, want)
    try:
        mmo_dp_batched(a[:3], b[:3], op="minplus", mesh=mesh)
        raise SystemExit("dp accepted a request axis that does not divide")
    except ValueError:
        pass
    print("DP_MMO_OK")

    # --- 2. sharded batched closure == local batched closure -------------
    sizes = [20, 26, 32]
    nb = 32
    ws = []
    for n in sizes:
        w = rng.uniform(1, 10, (n, n)).astype(np.float32)
        w = np.where(rng.random((n, n)) < 0.6, np.inf, w)
        ws.append(np.asarray(prepare_adjacency(jnp.asarray(w), op="minplus")))
    stack = jnp.stack([pad_adjacency(w, nb, op="minplus") for w in ws])
    valid = jnp.asarray(sizes, jnp.int32)
    loc, it_l = batched_leyzorek_closure(stack, op="minplus", backend="xla",
                                         valid_n=valid)
    sh, it_s = sharded_closure_batched(stack, op="minplus", mesh=mesh,
                                       valid_n=valid)
    assert np.array_equal(np.asarray(sh), np.asarray(loc))
    assert np.array_equal(np.asarray(it_s), np.asarray(it_l))

    # dp closure: one independent fixpoint per device, same results and
    # same per-request iteration counts as the coupled local fixpoint
    sizes8 = [20, 26, 32, 24, 30, 22, 28, 32]
    ws8 = []
    for i, n in enumerate(sizes8):
        w = rng.uniform(1, 10, (n, n)).astype(np.float32)
        w = np.where(rng.random((n, n)) < 0.6, np.inf, w)
        ws8.append(np.asarray(prepare_adjacency(jnp.asarray(w),
                                                op="minplus")))
    stack8 = jnp.stack([pad_adjacency(w, nb, op="minplus") for w in ws8])
    valid8 = jnp.asarray(sizes8, jnp.int32)
    loc8, it_l8 = batched_leyzorek_closure(stack8, op="minplus",
                                           backend="xla", valid_n=valid8)
    dp8, it_d8 = sharded_closure_batched(stack8, op="minplus", mesh=mesh,
                                         schedule="dp", valid_n=valid8)
    assert np.array_equal(np.asarray(dp8), np.asarray(loc8))
    assert np.array_equal(np.asarray(it_d8), np.asarray(it_l8))
    print("SHARDED_CLOSURE_OK")

    # --- 3. engine: threshold splits placement; results match solvers ----
    from repro.apps import graphs, solvers
    from repro.serve_mmo import MMOEngine, apsp_request
    # 16-bucket (2·16³ ≈ 8e3 flops) stays local, 64-bucket (5e5) shards
    eng = MMOEngine(backend="xla", mesh=mesh, schedule="summa",
                    shard_flops=1e5, max_batch=4)
    small = {n: graphs.weighted_digraph(n, 0.3, seed=n) for n in (9, 12)}
    big = {n: graphs.weighted_digraph(n, 0.25, seed=n) for n in (49, 60)}
    futs = {n: eng.submit(apsp_request(w))
            for n, w in {**small, **big}.items()}
    eng.run_until_idle()
    scheds = {k.shape[0]: s for k, s in eng._schedules.items()}
    assert scheds == {16: "local", 64: "summa"}, scheds
    for n, w in {**small, **big}.items():
        ref, _ = solvers.apsp(w)
        np.testing.assert_allclose(futs[n].result().value, np.asarray(ref),
                                   atol=1e-5)
    print("ENGINE_ROUTING_OK")

    # --- 4. prewarm → steady-state sharded traffic: zero retraces --------
    eng2 = MMOEngine(backend="xla", mesh=mesh, schedule="summa",
                     shard_flops=1e5, max_batch=4)
    sample = [apsp_request(graphs.weighted_digraph(n, 0.25, seed=0))
              for n in (50, 10)]
    eng2.prewarm(sample)
    misses = eng2.cache.misses
    for i in range(6):
        eng2.submit(apsp_request(
            graphs.weighted_digraph(45 + i, 0.25, seed=i)))
        eng2.submit(apsp_request(graphs.weighted_digraph(9 + i, 0.3, seed=i)))
    eng2.run_until_idle()
    assert eng2.cache.misses == misses, (eng2.cache.misses, misses)
    print("PREWARM_ZERO_RETRACE_OK")

    # --- 5. dp engine: full batches shard, partial batches fall back ------
    eng3 = MMOEngine(backend="xla", mesh=mesh, schedule="dp",
                     shard_flops=1e5, max_batch=8)
    ws = {n: graphs.weighted_digraph(n, 0.25, seed=n) for n in range(49, 57)}
    futs3 = {n: eng3.submit(apsp_request(w)) for n, w in ws.items()}
    eng3.run_until_idle()
    assert set(eng3._schedules.values()) == {"dp"}
    for n, w in ws.items():
        ref, _ = solvers.apsp(w)
        np.testing.assert_allclose(futs3[n].result().value, np.asarray(ref),
                                   atol=1e-5)
    # 3 requests pad to rb=4, which does not divide the 8 devices: the
    # memoized bucket schedule stays dp but the executed placement is local
    eng4 = MMOEngine(backend="xla", mesh=mesh, schedule="dp",
                     shard_flops=1e5, max_batch=8)
    futs4 = [eng4.submit(apsp_request(
        graphs.weighted_digraph(50 + i, 0.25, seed=i))) for i in range(3)]
    eng4.run_until_idle()
    for i, f in enumerate(futs4):
        ref, _ = solvers.apsp(graphs.weighted_digraph(50 + i, 0.25, seed=i))
        np.testing.assert_allclose(f.result().value, np.asarray(ref),
                                   atol=1e-5)
    (key4,) = eng4._schedules
    assert eng4._schedules[key4] == "dp"
    assert eng4.resolve_placement(key4, 4)[2] == "local"
    assert eng4.resolve_placement(key4, 8)[2] == "dp"
    print("DP_ENGINE_OK")
""")


@pytest.mark.slow
def test_sharded_serving_suite():
  env = dict(os.environ, PYTHONPATH=SRC)
  r = subprocess.run([sys.executable, "-c", _SCRIPT], capture_output=True,
                     text=True, env=env, timeout=900)
  assert r.returncode == 0, r.stderr[-3000:]
  for marker in ("SCHEDULES_ALLOPS_OK", "DP_MMO_OK", "SHARDED_CLOSURE_OK",
                 "ENGINE_ROUTING_OK", "PREWARM_ZERO_RETRACE_OK",
                 "DP_ENGINE_OK"):
    assert marker in r.stdout

"""Per-architecture smoke tests (reduced configs, CPU): one forward/train
step asserting output shapes + no NaNs, plus prefill→decode consistency
against the full forward — for every assigned architecture."""
import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro import configs
from repro.models import zoo
from repro.models.transformer import padded_vocab

ARCHS = configs.list_archs()
RNG = np.random.default_rng(3)
KEY = jax.random.PRNGKey(0)
B, S = 2, 16


def _batch(cfg):
  batch = {"tokens": jnp.asarray(RNG.integers(0, cfg.vocab, (B, S)),
                                 jnp.int32)}
  if cfg.family == "encdec":
    batch["src_embeds"] = jnp.asarray(
        RNG.standard_normal((B, cfg.src_len, cfg.d_model)), jnp.float32)
  return batch


@pytest.mark.parametrize("arch", ARCHS)
def test_smoke_forward(arch):
  cfg = configs.get_config(arch, smoke=True)
  params = zoo.init(cfg, KEY)
  logits, cache, aux = zoo.forward(params, cfg, _batch(cfg), mode="train")
  assert logits.shape == (B, S, padded_vocab(cfg))
  assert bool(jnp.all(jnp.isfinite(logits.astype(jnp.float32))))
  assert bool(jnp.isfinite(aux))


@pytest.mark.parametrize("arch", ARCHS)
def test_smoke_train_step(arch):
  from repro.train import AdamWConfig, init_opt_state, make_train_step
  cfg = configs.get_config(arch, smoke=True)
  params = zoo.init(cfg, KEY)
  opt = init_opt_state(params)
  batch = _batch(cfg)
  batch["labels"] = batch["tokens"]
  step = jax.jit(make_train_step(cfg, AdamWConfig(lr=1e-3, warmup_steps=1,
                                                  total_steps=10)))
  (new_p, new_o), metrics = step((params, opt), batch)
  assert bool(jnp.isfinite(metrics["loss"]))
  assert bool(jnp.isfinite(metrics["grad_norm"]))
  # params actually changed
  moved = any(
      float(jnp.max(jnp.abs(a.astype(jnp.float32) - b.astype(jnp.float32))))
      > 0 for a, b in zip(jax.tree.leaves(params), jax.tree.leaves(new_p)))
  assert moved


@pytest.mark.parametrize("arch", ARCHS)
def test_prefill_decode_consistency(arch):
  """decode(prefill(x[:t]), x[t]) logits == train-forward logits at t."""
  cfg = configs.get_config(arch, smoke=True)
  params = zoo.init(cfg, KEY)
  batch = _batch(cfg)
  toks = batch["tokens"]
  full_logits, _, _ = zoo.forward(params, cfg, batch, mode="train")

  pre = dict(batch)
  pre["tokens"] = toks[:, : S - 2]
  _, cache, _ = zoo.forward(params, cfg, pre, mode="prefill")
  tmpl = zoo.init_cache(cfg, B, S + 2)
  cache = jax.tree.map(
      lambda f, g: g if f.shape == g.shape else jnp.pad(
          g, [(0, fs - gs) for fs, gs in zip(f.shape, g.shape)]).astype(
              f.dtype), tmpl, cache)

  enc_out = None
  if cfg.family == "encdec":
    from repro.models import encdec as encdec_mod
    enc_out = encdec_mod.encode(params, cfg, batch["src_embeds"])

  for t in range(S - 2, S):
    db = {"tokens": toks[:, t:t + 1]}
    if enc_out is not None:
      db["enc_out"] = enc_out
    logits, cache, _ = zoo.forward(params, cfg, db, mode="decode",
                                   cache=cache, enc_out=enc_out)
    np.testing.assert_allclose(
        np.asarray(logits[:, 0], np.float32),
        np.asarray(full_logits[:, t], np.float32), atol=2e-2, rtol=2e-2)


def test_sliding_window_restricts_attention():
  """With window=w, logits at position t must not depend on tokens < t-w."""
  cfg = configs.get_config("h2o-danube-1.8b", smoke=True)  # window=16
  cfg = cfg.replace(window=4)
  toks = RNG.integers(10, cfg.vocab, (1, 12))
  t2 = toks.copy()
  t2[0, 0] = 1  # mutate a token far outside the window of the last position
  # one layer bounds the receptive field exactly (depth grows it by w/layer)
  cfg1 = cfg.replace(n_layers=1)
  params1 = zoo.init(cfg1, KEY)
  l1, _, _ = zoo.forward(params1, cfg1, {"tokens": jnp.asarray(toks)},
                         mode="train")
  l2, _, _ = zoo.forward(params1, cfg1, {"tokens": jnp.asarray(t2)},
                         mode="train")
  np.testing.assert_allclose(np.asarray(l1[0, -1]), np.asarray(l2[0, -1]),
                             atol=1e-5)
  assert not np.allclose(np.asarray(l1[0, 1]), np.asarray(l2[0, 1]))


def test_moe_capacity_and_aux():
  from repro.models import moe as moe_mod
  cfg = configs.get_config("mixtral-8x7b", smoke=True)
  p = moe_mod.moe_params(KEY, cfg)
  x = jnp.asarray(RNG.standard_normal((2, 8, cfg.d_model)), jnp.float32)
  y, aux = moe_mod.moe_block(p, cfg, x)
  assert y.shape == x.shape
  assert float(aux) >= 1.0 - 1e-3  # Switch aux loss is ≥1 at balance


def test_vq_tokenize_addnorm():
  from repro.models.vlm import vq_tokenize
  codebook = RNG.standard_normal((64, 16)).astype(np.float32)
  patches = codebook[RNG.integers(0, 64, (2, 10))] + \
      0.01 * RNG.standard_normal((2, 10, 16)).astype(np.float32)
  ids = vq_tokenize(jnp.asarray(patches), jnp.asarray(codebook))
  expect = np.stack([[np.argmin(((p - codebook) ** 2).sum(-1))
                      for p in row] for row in patches])
  assert np.array_equal(np.asarray(ids), expect)

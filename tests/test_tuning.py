"""Cost-table dispatch: schema, precedence, equivalence, engine integration."""
import json

import numpy as np
import pytest

import jax.numpy as jnp

from repro.core import mmo, mmo_reference
from repro.tuning import (CostTable, SCHEMA_VERSION, prior_seconds, resolve,
                          signature, tune, use_cost_table)
from repro.tuning.cost_table import bucket_shape

RNG = np.random.default_rng(0)


# ---------------------------------------------------------------------------
# table: signatures, JSON round-trip, precedence
# ---------------------------------------------------------------------------


def test_signature_buckets_raw_shapes():
  """Raw shapes that land in the same bucket share one table entry — the
  key is the bucket signature, not the raw shape (DESIGN.md §Dispatch)."""
  s1 = signature("minplus", (9, 11, 13), "float32", "vector", (128,))
  s2 = signature("minplus", (16, 16, 16), "float32", "vector", (128,))
  assert s1 == s2
  assert bucket_shape((9, 11, 13)) == (16, 16, 16)
  assert signature("minplus", (17, 16, 16), "float32", "vector",
                   (128,)) != s1  # 17 buckets to 32


def test_json_round_trip(tmp_path):
  t = CostTable(device="cpu:test")
  t.record("mma", (64, 64, 64), "float32", "xla", (512,), 1.5e-4)
  t.record("minplus", (9, 11, 13), "float32", "vector", (128,), 2.5e-4)
  t.record("orand", (16, 16, 16), "bool", "pallas", (128, 128, 128), 3e-3,
           source="prior")
  path = tmp_path / "table.json"
  t.save(path)
  back = CostTable.load(path)
  assert back.device == t.device and back.version == SCHEMA_VERSION
  assert back.entries == t.entries
  # the on-disk form is versioned, sorted JSON
  doc = json.loads(path.read_text())
  assert doc["schema_version"] == SCHEMA_VERSION
  assert list(doc["entries"]) == sorted(doc["entries"])


def test_from_json_rejects_wrong_schema():
  with pytest.raises(ValueError, match="schema_version"):
    CostTable.from_json(json.dumps({"schema_version": 999, "entries": {}}))
  bad = {"schema_version": SCHEMA_VERSION,
         "entries": {"mma|64x64x64|float32|xla|-":
                     {"seconds": -1.0, "source": "measured"}}}
  with pytest.raises(ValueError, match="seconds"):
    CostTable.from_json(json.dumps(bad))


def test_measured_beats_prior_precedence():
  t = CostTable()
  point = ("minplus", (16, 16, 16), "float32", "vector", (128,))
  assert t.record(*point, 1.0, source="prior")
  assert t.record(*point, 2.0, source="measured")  # measured overwrites prior
  assert t.lookup(*point).seconds == 2.0
  assert not t.record(*point, 0.5, source="prior")  # prior can't claw back
  assert t.lookup(*point).source == "measured"
  assert t.lookup(*point).seconds == 2.0
  assert t.record(*point, 3.0, source="measured")  # re-measure always wins
  assert t.lookup(*point).seconds == 3.0


def test_best_is_argmin_with_deterministic_ties():
  t = CostTable()
  t.record("minplus", (16, 16, 16), "float32", "xla", (512,), 2e-4)
  t.record("minplus", (16, 16, 16), "float32", "vector", (128,), 1e-4)
  t.record("minplus", (16, 16, 16), "float32", "vector", (512,), 3e-4)
  d = t.best("minplus", (10, 12, 14), "float32")  # raw shape → same bucket
  assert (d.backend, d.cfg, d.seconds) == ("vector", (128,), 1e-4)
  # restricting candidates honors the restriction
  d = t.best("minplus", (16, 16, 16), "float32", backends=("xla",))
  assert d.backend == "xla"
  # nothing known for this bucket → None → resolve falls back to 'xla'
  assert t.best("minplus", (64, 64, 64), "float32") is None
  assert resolve("minplus", 64, 64, 64, "float32", table=t).backend == "xla"


def test_prior_prefers_mxu_rewrites():
  """The analytic prior knows which ops ride the MXU per backend."""
  fast = prior_seconds("mma", (256, 256, 256), "float32", "xla")
  slow = prior_seconds("mma", (256, 256, 256), "float32", "vector")
  assert fast < slow  # matmul rewrite vs VPU broadcast-reduce
  assert prior_seconds("minplus", (256, 256, 256), "float32", "xla") == \
      prior_seconds("minplus", (256, 256, 256), "float32", "vector")


# ---------------------------------------------------------------------------
# dry-prior tuner sweep + dispatch equivalence: whatever the table picks,
# the result must match the reference oracle
# ---------------------------------------------------------------------------


@pytest.fixture(scope="module")
def prior_table():
  return tune(dry_prior=True, shapes=((16, 16, 16), (8, 16, 8)))


def test_dry_prior_tune_round_trips(prior_table, tmp_path):
  assert len(prior_table) > 0
  assert prior_table.counts()["measured"] == 0
  path = tmp_path / "prior.json"
  prior_table.save(path)
  assert len(CostTable.load(path)) == len(prior_table)


def test_dry_prior_mesh_sweep_records_schedule_rows(tmp_path):
  """The --mesh sweep fills every distributed-schedule arm with the sharded
  roofline prior (no devices needed), keyed on the mesh shape — the rows
  dispatch.resolve(mesh_shape=…) reads for sharded serving."""
  from repro.tuning import SCHEDULE_ARMS
  from repro.tuning.autotune import tune_mesh
  dims = (2, 4)
  table = tune_mesh(dims=dims, ops=("minplus", "orand"),
                    shapes=((64, 64, 64),), dry_prior=True)
  for op, dtype in (("minplus", "float32"), ("orand", "bool")):
    for sched in SCHEDULE_ARMS:
      entry = table.lookup(op, (64, 64, 64), dtype, sched, dims)
      assert entry is not None and entry.source == "prior", (op, sched)
  # round-trips like any other table, and a measured row later wins
  path = tmp_path / "mesh.json"
  table.save(path)
  loaded = CostTable.load(path)
  assert loaded.record("minplus", (64, 64, 64), "float32", "dp", dims, 1e-9)
  d = resolve("minplus", 64, 64, 64, "float32", table=loaded,
              mesh_shape=dims)
  assert d.backend == "dp" and d.source == "measured"
  with pytest.raises(ValueError, match="unknown schedule"):
    tune_mesh(dims=dims, schedules=("warp",), dry_prior=True)


@pytest.mark.parametrize("op", ["mma", "minplus", "maxmin", "maxmul",
                                "orand", "addnorm"])
@pytest.mark.parametrize("shape", [(7, 11, 5), (16, 16, 16)])
def test_dispatch_equivalence(prior_table, op, shape):
  """For every (op, shape, dtype): the chosen backend's output must match
  mmo_reference — dispatch may change *where* an op runs, never its value."""
  m, k, n = shape
  a = RNG.standard_normal((m, k)).astype(np.float32)
  b = RNG.standard_normal((k, n)).astype(np.float32)
  c = RNG.standard_normal((m, n)).astype(np.float32)
  if op == "orand":
    a, b, c = a > 0.3, b > 0.3, c > 0.8
  d = resolve(op, m, k, n, a.dtype, table=prior_table)
  assert d.source == "prior"
  got = mmo(jnp.asarray(a), jnp.asarray(b), jnp.asarray(c), op=op,
            backend=d.backend, block=d.cfg)
  ref = mmo_reference(jnp.asarray(a), jnp.asarray(b), jnp.asarray(c), op=op)
  np.testing.assert_allclose(np.asarray(got, np.float64),
                             np.asarray(ref, np.float64), atol=1e-4)


def test_env_var_table_round_trip(tmp_path, monkeypatch):
  """$REPRO_COST_TABLE ships a persisted table into dispatch; an explicit
  use_cost_table(None) still really means 'no table' under it."""
  from repro.tuning import dispatch as dp
  t = CostTable(device="env")
  t.record("minplus", (16, 16, 16), "float32", "vector", (128,), 1e-6)
  path = tmp_path / "env_table.json"
  t.save(path)
  monkeypatch.setenv(dp.ENV_VAR, str(path))
  dp.clear_cost_table()  # re-arm the env lookup
  try:
    loaded = dp.get_cost_table()
    assert loaded is not None and len(loaded) == 1
    assert resolve("minplus", 16, 16, 16, "float32").backend == "vector"
    with use_cost_table(None):  # explicit None wins over the env var
      assert dp.get_cost_table() is None
      assert resolve("minplus", 16, 16, 16, "float32").backend == "xla"
  finally:
    monkeypatch.delenv(dp.ENV_VAR)
    dp.clear_cost_table()


def test_auto_backend_follows_global_table():
  """backend='auto' consults the installed table per call signature."""
  t = CostTable()
  # claim vector is the winner for this bucket so auto must take that path
  t.record("minplus", (16, 16, 16), "float32", "vector", (8,), 1e-6)
  t.record("minplus", (16, 16, 16), "float32", "xla", (512,), 1.0)
  a = RNG.standard_normal((13, 14)).astype(np.float32)
  b = RNG.standard_normal((14, 11)).astype(np.float32)
  ref = mmo_reference(jnp.asarray(a), jnp.asarray(b), op="minplus")
  with use_cost_table(t):
    got = mmo(jnp.asarray(a), jnp.asarray(b), op="minplus", backend="auto")
  np.testing.assert_allclose(np.asarray(got), np.asarray(ref), atol=1e-5)
  # without a table, auto falls back to the historical default and still works
  got = mmo(jnp.asarray(a), jnp.asarray(b), op="minplus", backend="auto")
  np.testing.assert_allclose(np.asarray(got), np.asarray(ref), atol=1e-5)

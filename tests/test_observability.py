"""Observability layer: flight-recorder ring, Chrome trace validity across
every request outcome, Prometheus exposition grammar + golden rendering,
thread safety under live serving, and the HTTP endpoint."""
import json
import os
import re
import subprocess
import sys
import threading
import urllib.error
import urllib.request

import numpy as np
import pytest

from repro.apps import graphs
from repro.serve_mmo import (DeadlineExceededError, MMOEngine, RejectedError,
                             apsp_request, mmo_request)
from repro.serve_mmo.exposition import (HISTOGRAM_BOUNDS_S, LogHistogram,
                                        escape_label_value, render_prometheus)
from repro.serve_mmo.httpd import PROMETHEUS_CONTENT_TYPE, ObservabilityServer
from repro.serve_mmo.metrics import RollingWindow, ServeMetrics, bucket_label
from repro.serve_mmo.observability import (MAX_ITERATION_SLICES,
                                           FlightRecorder)
from repro.serve_mmo.scheduler import BucketKey, request_bucket

from conftest import FakeClock

RNG = np.random.default_rng(0)


def _mmo_req(n=12):
  a = RNG.standard_normal((n, n)).astype(np.float32)
  b = RNG.standard_normal((n, n)).astype(np.float32)
  return mmo_request(a, b, op="minplus")


def _apsp_req(n=12, seed=0):
  return apsp_request(graphs.weighted_digraph(n, 0.3, seed=seed))


def _async_request_events(events):
  """The trace's nestable async request events, grouped (id, name) → phs."""
  grouped = {}
  for ev in events:
    if ev.get("cat") == "request" and ev["ph"] in ("b", "e"):
      grouped.setdefault((ev["id"], ev["name"]), []).append(ev)
  return grouped


def _assert_balanced(events):
  """Every async request slice must alternate open/close (b,e,b,e,... in
  ring order), equal counts, each end at or after its begin — the invariant
  Perfetto needs to nest them.  A request that was never retried has
  exactly one pair; the recovery path opens one ``execute`` pair per
  attempt (one ``e`` per ``b``)."""
  for (rid, name), evs in _async_request_events(events).items():
    phs = [ev["ph"] for ev in evs]
    assert phs.count("b") == phs.count("e"), \
        f"request {rid} slice {name!r} unbalanced: {phs}"
    assert phs == ["b", "e"] * (len(phs) // 2), \
        f"request {rid} slice {name!r} does not alternate: {phs}"
    for b, e in zip(evs[::2], evs[1::2]):
      assert b["ts"] <= e["ts"]
    # queued happens once; only execute may re-open (retries/bisection)
    if name == "queued":
      assert phs == ["b", "e"], \
          f"request {rid} queued slice re-opened: {phs}"


# ---------------------------------------------------------------------------
# flight recorder mechanics
# ---------------------------------------------------------------------------


def test_ring_bounds_memory_and_reports_drops():
  rec = FlightRecorder(capacity=10, clock=FakeClock())
  for i in range(25):
    rec.instant(f"ev{i}")
  st = rec.stats()
  assert st["live"] == 10 and st["recorded"] == 25 and st["dropped"] == 15
  # oldest events fell off the back, newest survived
  assert [ev["name"] for ev in rec.events()] == \
      [f"ev{i}" for i in range(15, 25)]
  rec.clear()
  assert rec.stats() == {"enabled": True, "capacity": 10, "recorded": 0,
                         "live": 0, "dropped": 0}


def test_disabled_recorder_records_nothing():
  rec = FlightRecorder(capacity=16, clock=FakeClock(), enabled=False)
  rec.request_begin(1, kind="mmo", op="mma", tenant="t")
  rec.request_picked(1)
  rec.request_end(1, "done", executing=True)
  rec.request_rejected(2, "queue_full", kind="mmo", op="mma", tenant="t")
  rec.batch_complete(label="b", scheduled_s=0.0, stacked_s=0.1,
                     executed_s=0.2, device_s=0.3, completed_s=0.4,
                     backend="xla", schedule="local", batch=1, padded=1,
                     h2d_bytes=0, cache_hit=True, request_ids=[1],
                     arrivals_s=[0.0])
  rec.instant("nope")
  assert rec.stats()["recorded"] == 0 and rec.events() == []


def test_recorder_rejects_nonpositive_capacity():
  with pytest.raises(ValueError):
    FlightRecorder(capacity=0)


def test_lifecycle_timestamps_come_from_injected_clock():
  """Spans stamp the engine clock in microseconds — a synthetic clock gives
  exact, deterministic traces."""
  clock = FakeClock(1.0)
  rec = FlightRecorder(clock=clock)
  rec.request_begin(7, kind="closure", op="minplus", tenant="alpha")
  clock.t = 1.5
  rec.request_picked(7)
  clock.t = 2.25
  rec.request_end(7, "done", executing=True)
  evs = rec.events()
  assert [ev["ts"] for ev in evs] == [1.0e6, 1.5e6, 1.5e6, 2.25e6]
  _assert_balanced(evs)
  begin = evs[0]
  assert begin["args"] == {"kind": "closure", "op": "minplus",
                           "tenant": "alpha"}
  assert evs[-1]["args"]["outcome"] == "done"


def test_batch_complete_emits_phases_requests_and_iteration_slices():
  rec = FlightRecorder(clock=FakeClock())
  rec.request_begin(1, kind="closure", op="minplus", tenant="t", t_s=0.0)
  rec.request_begin(2, kind="closure", op="minplus", tenant="t", t_s=0.1)
  rec.batch_complete(label="closure/minplus/16/float32",
                     scheduled_s=1.0, stacked_s=1.1, executed_s=1.3,
                     device_s=1.7, completed_s=1.8, backend="xla",
                     schedule="local", batch=2, padded=2, h2d_bytes=2048,
                     cache_hit=True, request_ids=[1, 2],
                     arrivals_s=[0.0, 0.1], iterations=[3, 5])
  evs = rec.events()
  _assert_balanced(evs)
  phases = {ev["name"]: ev for ev in evs
            if ev["ph"] == "X" and not ev["name"].startswith("squaring")}
  assert set(phases) == {"pad_and_stack", "resolve_compile",
                         "device_compute", "split_results"}
  assert phases["pad_and_stack"]["ts"] == pytest.approx(1.0e6)
  assert phases["pad_and_stack"]["dur"] == pytest.approx(0.1e6)
  assert phases["resolve_compile"]["args"]["cache"] == "hit"
  assert phases["device_compute"]["dur"] == pytest.approx(0.4e6)
  assert phases["device_compute"]["args"]["iterations"] == [3, 5]
  assert phases["split_results"]["dur"] == pytest.approx(0.1e6)
  # apportioned squaring slices: max measured iterations, tiling exactly the
  # device window, explicitly marked as apportioned
  slices = [ev for ev in evs if ev["name"].startswith("squaring_iter")]
  assert len(slices) == 5
  assert all(ev["args"]["apportioned"] is True for ev in slices)
  assert slices[0]["ts"] == pytest.approx(1.3e6)
  assert sum(ev["dur"] for ev in slices) == pytest.approx(0.4e6)
  # per-request completion args carry the measured latency
  done = [ev for ev in evs if ev.get("cat") == "request"
          and ev["ph"] == "e" and ev["name"] == "execute"]
  assert {ev["id"]: ev["args"]["latency_ms"] for ev in done} == \
      {1: pytest.approx(1800.0), 2: pytest.approx(1700.0)}


def test_iteration_slices_are_capped():
  """A 1024-node Bellman-Ford batch measures ~1023 relaxations; tracing one
  slice per relaxation would evict half the ring per batch."""
  rec = FlightRecorder(clock=FakeClock())
  rec.batch_complete(label="b", scheduled_s=0.0, stacked_s=0.0,
                     executed_s=0.0, device_s=1.0, completed_s=1.0,
                     backend="xla", schedule="local", batch=1, padded=1,
                     h2d_bytes=0, cache_hit=True, request_ids=[],
                     arrivals_s=[], iterations=[1000])
  slices = [ev for ev in rec.events()
            if ev["name"].startswith("squaring_iter")]
  assert len(slices) == MAX_ITERATION_SLICES


def test_export_is_json_serializable_chrome_trace():
  rec = FlightRecorder(clock=FakeClock())
  rec.instant("hello", args={"k": 1})
  doc = json.loads(json.dumps(rec.export()))
  assert doc["displayTimeUnit"] == "ms"
  assert doc["traceEvents"][0] == {
      "ph": "M", "pid": 1, "name": "process_name",
      "args": {"name": "serve_mmo engine"}}
  assert doc["traceEvents"][1]["name"] == "hello"


# ---------------------------------------------------------------------------
# engine integration: one trace per request outcome
# ---------------------------------------------------------------------------


@pytest.fixture(scope="module")
def served_engine():
  """One engine that served a small mixed workload (mmo + closure buckets),
  shared by the trace/exposition assertions below."""
  engine = MMOEngine(backend="xla", max_batch=4)
  futs = [engine.submit(r) for r in
          [_mmo_req(), _mmo_req(), _apsp_req(seed=1), _apsp_req(seed=2)]]
  engine.run_until_idle()
  for f in futs:
    assert f.done()
  return engine


def test_live_trace_is_balanced_and_loads_as_json(served_engine):
  doc = json.loads(json.dumps(served_engine.export_trace()))
  evs = doc["traceEvents"]
  _assert_balanced(evs)
  for ev in evs:
    if ev["ph"] == "X":
      assert ev["dur"] >= 0.0
  names = {ev["name"] for ev in evs}
  assert {"pad_and_stack", "resolve_compile", "device_compute",
          "split_results", "queued", "execute"} <= names
  # the closure batches ran a measured fixpoint → apportioned slices and
  # measured iteration counts on the device span
  closure_devs = [ev for ev in evs if ev["name"] == "device_compute"
                  and "iterations" in ev.get("args", {})]
  assert closure_devs and all(
      min(ev["args"]["iterations"]) >= 1 for ev in closure_devs)
  assert any(ev["name"].startswith("squaring_iter") for ev in evs)
  # every completed request closed its execute slice with outcome=done
  done = [ev for ev in evs if ev.get("cat") == "request"
          and ev["ph"] == "e" and ev["name"] == "execute"]
  assert len(done) == 4
  assert all(ev["args"]["outcome"] == "done" for ev in done)


def test_trace_records_expired_requests():
  clock = FakeClock()
  engine = MMOEngine(backend="xla", clock=clock)
  fut = engine.submit(_mmo_req())
  doomed = _mmo_req()
  doomed.deadline_s = 0.5
  fut2 = engine.submit(doomed)
  clock.t = 2.0  # past the deadline before any batch runs
  engine.run_until_idle()
  assert fut.done()
  with pytest.raises(DeadlineExceededError):
    fut2.result(timeout=5)
  evs = engine.export_trace()["traceEvents"]
  _assert_balanced(evs)
  ends = {ev["id"]: ev["args"]["outcome"] for ev in evs
          if ev.get("cat") == "request" and ev["ph"] == "e"
          and "args" in ev}
  assert "expired" in ends.values() and "done" in ends.values()
  # the expired request never executed: its queued slice closed directly
  expired_id = next(i for i, o in ends.items() if o == "expired")
  assert (expired_id, "execute") not in _async_request_events(evs)


def test_trace_records_failed_batches():
  engine = MMOEngine(backend="xla")

  def boom(*a, **kw):
    raise RuntimeError("poisoned compile")

  engine.cache.get_or_compile = boom
  fut = engine.submit(_mmo_req())
  engine.run_until_idle()
  with pytest.raises(RuntimeError):
    fut.result(timeout=5)
  evs = engine.export_trace()["traceEvents"]
  _assert_balanced(evs)
  fails = [ev for ev in evs if ev.get("cat") == "request"
           and ev["ph"] == "e" and ev["name"] == "execute"]
  # one execute end per attempt: retried attempts close 'retried', the
  # terminal attempt closes 'failed' with the error
  assert fails
  assert all(ev["args"]["outcome"] == "retried" for ev in fails[:-1])
  assert fails[-1]["args"] == {"outcome": "failed", "error": "RuntimeError"}
  assert any(ev["name"] == "batch_fail" for ev in evs)


def test_trace_records_rejections_as_instants():
  engine = MMOEngine(backend="xla", max_queue=1)
  kept = engine.submit(_mmo_req())
  with pytest.raises(RejectedError):
    engine.submit(_mmo_req()).result(timeout=5)
  engine.run_until_idle()
  assert kept.done()
  evs = engine.export_trace()["traceEvents"]
  _assert_balanced(evs)
  rejects = [ev for ev in evs if ev["name"] == "reject"]
  assert len(rejects) == 1
  assert rejects[0]["ph"] == "i"
  assert rejects[0]["args"]["reason"] == "queue_full"


def test_trace_off_engine_records_nothing(served_engine):
  engine = MMOEngine(backend="xla", trace=False)
  fut = engine.submit(_mmo_req())
  engine.run_until_idle()
  assert fut.done()
  assert engine.tracer.stats()["recorded"] == 0
  assert len(engine.export_trace()["traceEvents"]) == 1  # metadata only
  # ...and the exposition still renders, advertising tracing as off
  text = render_prometheus(engine.observability_state())
  assert "serve_trace_enabled 0" in text


# ---------------------------------------------------------------------------
# Prometheus exposition: grammar, histograms, golden rendering
# ---------------------------------------------------------------------------

_METRIC_RE = re.compile(r"^[a-zA-Z_:][a-zA-Z0-9_:]*$")
_SAMPLE_RE = re.compile(
    r"^(?P<name>[a-zA-Z_:][a-zA-Z0-9_:]*)"
    r"(?:\{(?P<labels>[^{}]*)\})?"
    r" (?P<value>[^ ]+)$")
_LABEL_RE = re.compile(r'^[a-zA-Z_][a-zA-Z0-9_]*="(?:[^"\\]|\\["\\n])*"$')


def _parse_exposition(text: str):
  """Validate Prometheus text-format 0.0.4 line by line; returns
  (families, samples) where families maps name → type and samples is a list
  of (name, labels-dict, float-value)."""
  assert text.endswith("\n")
  families, helped, samples = {}, set(), []
  for line in text.splitlines():
    if line.startswith("# HELP "):
      name = line.split(" ", 3)[2]
      assert _METRIC_RE.match(name)
      assert name not in helped, f"duplicate HELP for {name}"
      helped.add(name)
    elif line.startswith("# TYPE "):
      _, _, name, mtype = line.split(" ", 3)
      assert _METRIC_RE.match(name)
      assert mtype in ("counter", "gauge", "histogram", "summary", "untyped")
      assert name not in families, f"duplicate TYPE for {name}"
      assert name in helped, f"TYPE for {name} precedes its HELP"
      families[name] = mtype
    else:
      m = _SAMPLE_RE.match(line)
      assert m, f"malformed sample line: {line!r}"
      labels = {}
      if m.group("labels"):
        for pair in re.split(r",(?=[a-zA-Z_])", m.group("labels")):
          assert _LABEL_RE.match(pair), f"malformed label: {pair!r}"
          k, v = pair.split("=", 1)
          labels[k] = v[1:-1]
      value = m.group("value")
      fval = {"+Inf": float("inf"), "-Inf": float("-inf")}.get(
          value, None)
      samples.append((m.group("name"), labels,
                      fval if fval is not None else float(value)))
  return families, samples


def test_live_exposition_parses_and_histograms_are_cumulative(served_engine):
  text = render_prometheus(served_engine.observability_state())
  families, samples = _parse_exposition(text)
  # every sample belongs to a declared family (histograms contribute
  # _bucket/_sum/_count children of the declared base name)
  for name, _, _ in samples:
    base = re.sub(r"_(bucket|sum|count)$", "", name)
    assert name in families or base in families, f"undeclared sample {name}"
  assert families["serve_submitted_total"] == "counter"
  assert families["serve_queue_depth"] == "gauge"
  assert families["serve_service_seconds"] == "histogram"
  by_name: dict = {}
  for name, labels, value in samples:
    by_name.setdefault(name, []).append((labels, value))
  assert by_name["serve_submitted_total"] == [({}, 4)]
  # per-(histogram, bucket-label) series: counts cumulative in le, and the
  # +Inf bucket equals _count
  hname = "serve_service_seconds"
  series: dict = {}
  for labels, value in by_name[f"{hname}_bucket"]:
    series.setdefault(labels["bucket"], []).append((labels["le"], value))
  counts = {labels["bucket"]: value
            for labels, value in by_name[f"{hname}_count"]}
  assert series and set(series) == set(counts)
  for blabel, buckets in series.items():
    values = [v for _, v in buckets]
    assert values == sorted(values), f"non-cumulative histogram {blabel}"
    assert dict(buckets)["+Inf"] == counts[blabel]
    # fixed fleet-wide boundaries: every series emits the same le labels
    assert len(buckets) == len(HISTOGRAM_BOUNDS_S) + 1


def test_exposition_includes_estimator_drift(served_engine):
  text = render_prometheus(served_engine.observability_state())
  _, samples = _parse_exposition(text)
  drift = [(labels, v) for name, labels, v in samples
           if name == "serve_estimator_drift_ratio"]
  assert drift, "served engine must report estimator drift cells"
  for labels, v in drift:
    assert {"bucket", "backend", "schedule"} <= set(labels)
    assert v > 0.0


def test_golden_exposition_rendering():
  """Pin the full rendered text for one synthetic state: any grammar change
  (family names, label sets, le spellings, ordering) shows up as a golden
  diff, not as a silently reshaped scrape."""
  q1 = [0] * 23
  q1[8], q1[10] = 3, 1
  s1 = [0] * 23
  s1[12] = 4
  q2 = [0] * 23
  q2[5] = 2
  state = {
      "metrics": {
          "uptime_s": 12.5,
          "counters": {"submitted": 9, "completed": 6, "rejected": 1,
                       "expired": 1, "failed": 1, "batches": 3,
                       "h2d_bytes": 4096, "retries": 3},
          "rejected_by_reason": {"queue_full": 1},
          "batch_failures_by_kind": {"execute": 2, "nonfinite": 1},
          "histogram_bounds_s": list(HISTOGRAM_BOUNDS_S),
          "buckets": {
              "closure/minplus/16/float32": {
                  "completed": 4, "expired": 1, "failed": 0,
                  "histograms": {"queue": (q1, 0.0421, 4),
                                 "service": (s1, 0.0631, 4)}},
              "mmo/mma/16x16x16/float32+float16": {
                  "completed": 2, "expired": 0, "failed": 1,
                  "histograms": {"queue": (q2, 0.0015, 2)}},
          },
      },
      "queue_depth": 2,
      "executing": 1,
      "admission": {"queued": 2, "backlog_s": 0.25, "evaluations": 9,
                    "inflight": {"alpha": 2, "beta": 1},
                    "rejections": {"queue_full": 1},
                    "limits": {"max_queue": 64, "tenant_quota": None,
                               "max_backlog_s": None}},
      "cache": {"executables": 5, "hits": 12, "misses": 5,
                "compile_s": 1.5},
      "scheduler": {"picks": 3, "pick_seconds": 0.004},
      "estimator_cells": [
          {"bucket": "closure/minplus/16/float32", "backend": "xla",
           "schedule": "local", "seconds": 0.002, "observations": 4,
           "drift": 1.25}],
      "breakers": [
          {"bucket": "closure/minplus/16/float32", "backend": "xla",
           "schedule": "local", "state": "open",
           "consecutive_failures": 5, "opens": 1, "closes": 0, "probes": 0},
          {"bucket": "closure/minplus/16/float32", "backend": "vector",
           "schedule": "local", "state": "closed",
           "consecutive_failures": 0, "opens": 0, "closes": 0, "probes": 1}],
      "trace": {"enabled": True, "capacity": 65536, "recorded": 120,
                "live": 120, "dropped": 0},
  }
  text = render_prometheus(state)
  _parse_exposition(text)  # golden must itself be grammatical
  golden_path = os.path.join(os.path.dirname(__file__), "data",
                             "golden_metrics.prom")
  with open(golden_path, encoding="utf-8") as f:
    assert text == f.read()


def test_log_histogram_drops_bogus_values():
  h = LogHistogram()
  for bad in (float("nan"), float("inf"), -1.0):
    h.add(bad)
  assert h.count == 0
  h.add(0.0)
  h.add(1e-5)   # at the first boundary → first bucket (le is inclusive)
  h.add(100.0)  # beyond the top bound → overflow slot
  counts, total, n = h.state()
  assert n == 3 and counts[0] == 2 and counts[-1] == 1
  assert total == pytest.approx(100.00001)


def test_escape_label_value():
  assert escape_label_value('a"b\\c\nd') == 'a\\"b\\\\c\\nd'


# ---------------------------------------------------------------------------
# metrics satellites: strict-JSON empty windows, mixed-dtype bucket labels
# ---------------------------------------------------------------------------


def test_empty_window_percentiles_are_null_not_nan():
  """A bucket created by on_expire alone has empty latency windows; its
  snapshot must be strict JSON (None → null), never bareword NaN."""
  assert RollingWindow().percentile(50) is None
  metrics = ServeMetrics()
  metrics.on_expire(request_bucket(_mmo_req()))
  snap = metrics.snapshot(queue_depth=0, executing=0)
  text = json.dumps(snap, allow_nan=False)  # raises on NaN/Inf
  (bucket,) = snap["buckets"].values()
  assert bucket["queue_ms"] == {"p50": None, "p99": None}
  assert json.loads(text)["counters"]["expired"] == 1


def test_bucket_label_spells_out_mixed_dtypes():
  uniform = BucketKey(kind="mmo", op="mma", shape=(16, 16, 16),
                      dtypes=("float32", "float32"), params=())
  mixed_a = BucketKey(kind="mmo", op="mma", shape=(16, 16, 16),
                      dtypes=("float32", "float16"), params=())
  mixed_b = BucketKey(kind="mmo", op="mma", shape=(16, 16, 16),
                      dtypes=("float32", "bfloat16"), params=())
  # historical single-dtype spelling for the uniform majority
  assert bucket_label(uniform) == "mmo/mma/16x16x16/float32"
  # two buckets differing only in a non-leading operand dtype cannot share
  # a label
  assert bucket_label(mixed_a) == "mmo/mma/16x16x16/float32+float16"
  assert bucket_label(mixed_a) != bucket_label(mixed_b)


# ---------------------------------------------------------------------------
# thread safety: snapshots + renders + trace exports against a live engine
# ---------------------------------------------------------------------------


def test_concurrent_observability_reads_during_serving():
  """Hammer every observability read path from 8 threads while the engine
  serves on its background loop: no exceptions, every read parseable, all
  traffic completes."""
  engine = MMOEngine(backend="xla", max_batch=4)
  reqs = [_mmo_req() for _ in range(12)] + \
         [_apsp_req(seed=s) for s in range(4)]
  engine.prewarm(reqs)
  engine.start()
  errs = []
  futures = []
  barrier = threading.Barrier(8)

  def submitter(i):
    try:
      barrier.wait()
      for r in reqs[i::4]:
        futures.append(engine.submit(r))
    except Exception as e:  # noqa: BLE001
      errs.append(e)

  def reader(i):
    try:
      barrier.wait()
      for _ in range(25):
        json.dumps(engine.metrics_snapshot(), default=float,
                   allow_nan=False)
        _parse_exposition(render_prometheus(engine.observability_state()))
        json.dumps(engine.export_trace())
    except Exception as e:  # noqa: BLE001
      errs.append(e)

  threads = [threading.Thread(target=submitter, args=(i,)) for i in range(4)]
  threads += [threading.Thread(target=reader, args=(i,)) for i in range(4)]
  for t in threads:
    t.start()
  for t in threads:
    t.join()
  engine.stop()
  assert not errs
  assert len(futures) == len(reqs) and all(f.done() for f in futures)
  _assert_balanced(engine.export_trace()["traceEvents"])


# ---------------------------------------------------------------------------
# HTTP endpoint
# ---------------------------------------------------------------------------


def test_http_endpoint_serves_all_routes(served_engine):
  with ObservabilityServer(served_engine, port=0) as srv:
    assert srv.port != 0

    def get(path):
      with urllib.request.urlopen(f"{srv.url}{path}", timeout=10) as resp:
        return resp.status, resp.headers.get("Content-Type"), \
            resp.read().decode("utf-8")

    status, ctype, body = get("/metrics")
    assert status == 200 and ctype == PROMETHEUS_CONTENT_TYPE
    families, _ = _parse_exposition(body)
    assert "serve_completed_total" in families

    status, ctype, body = get("/healthz")
    assert status == 200 and ctype == "application/json"
    health = json.loads(body)
    assert health["status"] == "ok" and health["pending"] == 0

    status, _, body = get("/snapshot")
    assert status == 200
    assert json.loads(body)["counters"]["completed"] == 4

    status, _, body = get("/trace")
    assert status == 200
    _assert_balanced(json.loads(body)["traceEvents"])

    with pytest.raises(urllib.error.HTTPError) as err:
      get("/nope")
    assert err.value.code == 404


# ---------------------------------------------------------------------------
# launch driver: the metrics ticker must never write to stdout
# ---------------------------------------------------------------------------


@pytest.mark.slow
def test_launch_metrics_ticker_goes_to_stderr(tmp_path):
  env = dict(os.environ, PYTHONPATH="src")
  proc = subprocess.run(
      [sys.executable, "-m", "repro.launch.serve_mmo", "--rate", "30",
       "--duration", "1.5", "--sizes", "12", "--max-batch", "4",
       "--metrics-every", "0.3", "--trace-out",
       str(tmp_path / "trace.json")],
      capture_output=True, text=True, timeout=600, env=env,
      cwd=os.path.dirname(os.path.dirname(os.path.abspath(__file__))))
  assert proc.returncode == 0, proc.stderr
  assert "[serve_mmo][metrics]" not in proc.stdout
  ticks = [l for l in proc.stderr.splitlines()
           if l.startswith("[serve_mmo][metrics] ")]
  assert ticks, "ticker produced no stderr snapshots"
  for line in ticks:
    snap = json.loads(line.split(" ", 1)[1])
    assert "counters" in snap and "queue_depth" in snap
  trace = json.loads((tmp_path / "trace.json").read_text())
  _assert_balanced(trace["traceEvents"])

"""Fault tolerance: atomic checkpointing, kill-and-restart exact resume."""
import os
import subprocess
import sys

import jax
import numpy as np
import pytest

from repro.train import checkpoint as ckpt

SRC = os.path.join(os.path.dirname(__file__), "..", "src")


def test_atomic_commit(tmp_path):
  d = str(tmp_path)
  state = {"a": np.arange(6, dtype=np.float32).reshape(2, 3)}
  ckpt.save(d, 10, state)
  assert ckpt.latest_step(d) == 10
  ckpt.save(d, 20, {"a": state["a"] * 2})
  assert ckpt.latest_step(d) == 20
  out, step = ckpt.restore(d)
  assert step == 20
  np.testing.assert_array_equal(out["a"], state["a"] * 2)
  # older checkpoint still restorable explicitly
  out10, _ = ckpt.restore(d, step=10)
  np.testing.assert_array_equal(out10["a"], state["a"])


def test_restore_missing_raises(tmp_path):
  with pytest.raises(FileNotFoundError):
    ckpt.restore(str(tmp_path))


def _run_train(args, check=True):
  env = dict(os.environ, PYTHONPATH=SRC)
  return subprocess.run(
      [sys.executable, "-m", "repro.launch.train"] + args,
      capture_output=True, text=True, env=env, check=check, timeout=600)


def test_kill_and_resume_exact(tmp_path):
  """Train 1→30 with a simulated node failure at step 20; resume must
  produce the same final loss as an uninterrupted run (stateless data +
  committed state = exact restart)."""
  common = ["--arch", "tinyllama-1.1b", "--smoke", "--steps", "30",
            "--batch", "4", "--seq", "32", "--lr", "1e-3",
            "--ckpt-every", "10", "--log-every", "30"]
  ref_dir = tmp_path / "ref"
  r = _run_train(common + ["--ckpt-dir", str(ref_dir)])
  ref_loss = [l for l in r.stdout.splitlines() if "loss=" in l][-1]

  crash_dir = tmp_path / "crash"
  r1 = subprocess.run(
      [sys.executable, "-m", "repro.launch.train"] + common +
      ["--ckpt-dir", str(crash_dir), "--fail-at", "20"],
      capture_output=True, text=True,
      env=dict(os.environ, PYTHONPATH=SRC), timeout=600)
  assert r1.returncode == 42  # simulated failure
  assert ckpt.latest_step(str(crash_dir)) == 20
  r2 = _run_train(common + ["--ckpt-dir", str(crash_dir)])
  assert "resumed from step 20" in r2.stdout
  out_loss = [l for l in r2.stdout.splitlines() if "loss=" in l][-1]

  def loss_of(line):
    return float(line.split("loss=")[1].split()[0])
  np.testing.assert_allclose(loss_of(out_loss), loss_of(ref_loss),
                             rtol=1e-5)

"""Pallas kernels vs pure-jnp oracles (interpret mode on CPU).

Per the deliverable: each kernel swept over shapes/dtypes and
assert_allclose'd against ref.py.
"""
import jax.numpy as jnp
import numpy as np
import pytest

from repro.core.semiring import ALL_OPS
from repro.kernels import flash_attention, semiring_mmo
from repro.kernels.ref import attention_ref, semiring_mmo_ref

RNG = np.random.default_rng(1)

MMO_SHAPES = [(128, 128, 128), (64, 200, 96), (13, 7, 5), (256, 384, 128),
              (1, 128, 1)]


@pytest.mark.parametrize("op", ALL_OPS)
@pytest.mark.parametrize("shape", MMO_SHAPES)
def test_semiring_kernel(op, shape):
  m, k, n = shape
  a = RNG.standard_normal((m, k)).astype(np.float32)
  b = RNG.standard_normal((k, n)).astype(np.float32)
  c = RNG.standard_normal((m, n)).astype(np.float32)
  if op == "orand":
    a, b, c = a > 0.8, b > 0.8, c > 1.5
  got = semiring_mmo(jnp.asarray(a), jnp.asarray(b), jnp.asarray(c), op=op,
                     interpret=True)
  ref = semiring_mmo_ref(jnp.asarray(a), jnp.asarray(b), jnp.asarray(c),
                         op=op)
  np.testing.assert_allclose(np.asarray(got, np.float64),
                             np.asarray(ref, np.float64),
                             rtol=1e-4, atol=1e-4)


@pytest.mark.parametrize("op", ["mma", "minplus", "addnorm"])
@pytest.mark.parametrize("dtype", [jnp.bfloat16, jnp.float32])
def test_semiring_kernel_dtypes(op, dtype):
  a = jnp.asarray(RNG.standard_normal((64, 96)), dtype)
  b = jnp.asarray(RNG.standard_normal((96, 32)), dtype)
  got = semiring_mmo(a, b, op=op, interpret=True)
  ref = semiring_mmo_ref(a, b, op=op)
  np.testing.assert_allclose(np.asarray(got, np.float64),
                             np.asarray(ref, np.float64),
                             rtol=3e-2, atol=3e-2)


@pytest.mark.parametrize("op", ["mma", "addnorm"])
def test_faithful_vpu_variant(op):
  """The paper-faithful ⊗-ALU path must agree with the MXU rewrite."""
  a = jnp.asarray(RNG.standard_normal((40, 70)), jnp.float32)
  b = jnp.asarray(RNG.standard_normal((70, 50)), jnp.float32)
  got = semiring_mmo(a, b, op=op, interpret=True, faithful=True)
  ref = semiring_mmo_ref(a, b, op=op)
  np.testing.assert_allclose(np.asarray(got), np.asarray(ref), rtol=1e-4,
                             atol=1e-4)


def test_semiring_kernel_batched():
  a = jnp.asarray(RNG.standard_normal((3, 2, 16, 32)), jnp.float32)
  b = jnp.asarray(RNG.standard_normal((3, 2, 32, 24)), jnp.float32)
  got = semiring_mmo(a, b, op="minplus", interpret=True)
  ref = semiring_mmo_ref(a, b, op="minplus")
  np.testing.assert_allclose(np.asarray(got), np.asarray(ref), atol=1e-5)


@pytest.mark.parametrize("op", ["mma", "minplus", "maxmin", "orand"])
def test_semiring_kernel_masked_k(op):
  """Per-request k_valid skips dead K-blocks without changing the result:
  lanes at/beyond k_valid hold contraction pads (⊗(pa, pb) == ⊕-identity),
  so the skipped blocks were algebraic no-ops by construction."""
  from repro.core.semiring import contraction_pads, get as get_sr
  r, m, k, n = 3, 16, 64, 24
  kv = np.asarray([24, 40, 64], np.int32)
  pa, pb = contraction_pads(op)
  a = RNG.standard_normal((r, m, k)).astype(np.float32)
  b = RNG.standard_normal((r, k, n)).astype(np.float32)
  if get_sr(op).boolean:
    a, b = a > 0.3, b > 0.3
    pa = pb = False
  for i, kvi in enumerate(kv):
    a[i, :, kvi:] = pa
    b[i, kvi:, :] = pb
  got = semiring_mmo(jnp.asarray(a), jnp.asarray(b), op=op, bk=16,
                     interpret=True, k_valid=jnp.asarray(kv))
  ref = semiring_mmo_ref(jnp.asarray(a), jnp.asarray(b), op=op)
  np.testing.assert_allclose(np.asarray(got, np.float64),
                             np.asarray(ref, np.float64), rtol=1e-4,
                             atol=1e-4)
  # scalar k_valid on a single 2-D problem
  got0 = semiring_mmo(jnp.asarray(a[0]), jnp.asarray(b[0]), op=op, bk=16,
                      interpret=True, k_valid=24)
  np.testing.assert_allclose(np.asarray(got0, np.float64),
                             np.asarray(ref, np.float64)[0], rtol=1e-4,
                             atol=1e-4)


FA_CASES = [
    # b, h, hkv, sq, skv, d, causal, window
    (2, 4, 2, 128, 128, 64, True, None),
    (1, 8, 2, 96, 160, 32, True, None),
    (2, 4, 4, 128, 128, 64, False, None),
    (1, 4, 1, 200, 200, 64, True, 96),
    (1, 2, 2, 64, 256, 128, True, None),
    (1, 4, 4, 160, 160, 80, True, None),   # non-128-aligned head dim
]


@pytest.mark.parametrize("case", FA_CASES)
def test_flash_attention(case):
  b, h, hkv, sq, skv, d, causal, window = case
  q = RNG.standard_normal((b, h, sq, d)).astype(np.float32)
  k = RNG.standard_normal((b, hkv, skv, d)).astype(np.float32)
  v = RNG.standard_normal((b, hkv, skv, d)).astype(np.float32)
  kx = np.repeat(k, h // hkv, axis=1)
  vx = np.repeat(v, h // hkv, axis=1)
  ref = attention_ref(jnp.asarray(q), jnp.asarray(kx), jnp.asarray(vx),
                      causal=causal, window=window)
  got = flash_attention(jnp.asarray(q), jnp.asarray(k), jnp.asarray(v),
                        causal=causal, window=window, bq=64, bkv=64,
                        interpret=True)
  np.testing.assert_allclose(np.asarray(got), np.asarray(ref), atol=2e-5)


def test_flash_attention_bf16():
  q = jnp.asarray(RNG.standard_normal((1, 4, 64, 64)), jnp.bfloat16)
  k = jnp.asarray(RNG.standard_normal((1, 4, 64, 64)), jnp.bfloat16)
  v = jnp.asarray(RNG.standard_normal((1, 4, 64, 64)), jnp.bfloat16)
  got = flash_attention(q, k, v, interpret=True)
  ref = attention_ref(q, k, v)
  np.testing.assert_allclose(np.asarray(got, np.float32),
                             np.asarray(ref, np.float32), atol=3e-2)

"""Hypothesis property tests for the cost-table persistence layer.

The dispatch invariant a shipped ``cost_table.json`` rests on: serializing a
table and loading it back must not change a single dispatch decision —
otherwise a warmed table behaves differently in the serving job that loads
it than in the autotune run that wrote it.

``hypothesis`` is an optional test dependency (see pyproject.toml); the
module skips cleanly when it is not installed.
"""
import json
import math
import os
import tempfile

import pytest

pytest.importorskip("hypothesis")
from hypothesis import given, settings, strategies as st  # noqa: E402

from repro.core import ALL_OPS  # noqa: E402
from repro.tuning import (CostTable, DEFAULT_CONFIGS, SCHEDULE_ARMS,  # noqa: E402
                          resolve)

_BACKENDS = tuple(DEFAULT_CONFIGS)
_DTYPES = ("float32", "float16", "bool")
_MESH = (2, 4)

_ops = st.sampled_from(sorted(ALL_OPS))
_dims = st.integers(min_value=1, max_value=300)
_seconds = st.floats(min_value=1e-9, max_value=1e3,
                     allow_nan=False, allow_infinity=False)
_sources = st.sampled_from(("measured", "prior"))


@st.composite
def _entries(draw):
  """One valid table row: a local backend row (with one of its swept block
  configs, or none) or a distributed-schedule mesh row (cfg = mesh shape)."""
  op = draw(_ops)
  shape = (draw(_dims), draw(_dims), draw(_dims))
  dtype = draw(st.sampled_from(_DTYPES))
  if draw(st.booleans()):
    backend = draw(st.sampled_from(_BACKENDS))
    cfg = draw(st.sampled_from(DEFAULT_CONFIGS[backend] + ((),)))
  else:
    backend = draw(st.sampled_from(SCHEDULE_ARMS))
    cfg = _MESH
  return (op, shape, dtype, backend, cfg, draw(_seconds), draw(_sources))


@st.composite
def _tables(draw):
  table = CostTable(device=draw(st.sampled_from(("test", "cpu", "v5e"))))
  for row in draw(st.lists(_entries(), min_size=0, max_size=24)):
    op, shape, dtype, backend, cfg, seconds, source = row
    table.record(op, shape, dtype, backend, cfg, seconds, source=source)
  return table


def _probe_points(table):
  """Every (op, bucketed shape, dtype) the table holds rows for — the only
  points where a round-trip could possibly change a decision — plus one
  point no table ever holds (the both-sides-fall-to-prior case)."""
  points = set()
  for sig in table.entries:
    op, shape_s, dtype, _, _ = sig.split("|")
    m, k, n = (int(d) for d in shape_s.split("x"))
    points.add((op, (m, k, n), dtype))
  points.add(("mma", (8, 8, 8), "float32"))
  return sorted(points, key=repr)


@settings(max_examples=40, deadline=None)
@given(_tables())
def test_round_trip_preserves_entries_exactly(table):
  """to_json → from_json is the identity on the entry dict (float seconds
  survive bit-exact — json repr round-trips IEEE doubles)."""
  loaded = CostTable.from_json(table.to_json())
  assert loaded.device == table.device
  assert loaded.entries.keys() == table.entries.keys()
  for sig, entry in table.entries.items():
    got = loaded.entries[sig]
    assert got.seconds == entry.seconds and got.source == entry.source
  # and serialization is deterministic (sorted keys): stable artifact diffs
  assert loaded.to_json() == table.to_json()


@settings(max_examples=40, deadline=None)
@given(_tables())
def test_round_trip_preserves_resolve_decisions(table):
  """save → load must preserve every dispatch decision: same backend, same
  block config, same seconds, same measured/prior provenance — for the
  local argmin and for the mesh-arm competition."""
  with tempfile.TemporaryDirectory() as d:
    path = os.path.join(d, "cost_table.json")
    table.save(path)
    loaded = CostTable.load(path)
  for op, (m, k, n), dtype in _probe_points(table):
    before = resolve(op, m, k, n, dtype, table=table)
    after = resolve(op, m, k, n, dtype, table=loaded)
    assert after == before, (op, (m, k, n), dtype)
    before_m = resolve(op, m, k, n, dtype, table=table, mesh_shape=_MESH)
    after_m = resolve(op, m, k, n, dtype, table=loaded, mesh_shape=_MESH)
    # prior seconds are recomputed, not stored; compare the decision fields
    assert (after_m.backend, after_m.cfg, after_m.source) == \
        (before_m.backend, before_m.cfg, before_m.source), (op, (m, k, n))
    if math.isfinite(before_m.seconds):
      assert after_m.seconds == pytest.approx(before_m.seconds)


@settings(max_examples=25, deadline=None)
@given(_tables())
def test_round_trip_preserves_best_per_backend(table):
  """The fixed-backend read path (``best(backends=(b,))`` — what a fixed
  ``backend=`` engine prices admission with) survives the round trip too."""
  loaded = CostTable.from_json(table.to_json())
  for op, (m, k, n), dtype in _probe_points(table):
    for backend in _BACKENDS:
      assert (loaded.best(op, (m, k, n), dtype, backends=(backend,))
              == table.best(op, (m, k, n), dtype, backends=(backend,)))


def test_from_json_rejects_corrupt_documents():
  """Non-property guardrails stay pinned alongside (runs without
  hypothesis installed too — importorskip already fired, but these four
  asserts document the validation surface the properties lean on)."""
  t = CostTable(device="test")
  t.record("mma", (16, 16, 16), "float32", "xla", (512,), 1e-3)
  doc = json.loads(t.to_json())
  bad_version = dict(doc, schema_version=999)
  with pytest.raises(ValueError, match="schema_version"):
    CostTable.from_json(json.dumps(bad_version))
  sig = next(iter(doc["entries"]))
  bad_source = json.loads(json.dumps(doc))
  bad_source["entries"][sig]["source"] = "vibes"
  with pytest.raises(ValueError, match="source"):
    CostTable.from_json(json.dumps(bad_source))
  bad_seconds = json.loads(json.dumps(doc))
  bad_seconds["entries"][sig]["seconds"] = -1.0
  with pytest.raises(ValueError, match="seconds"):
    CostTable.from_json(json.dumps(bad_seconds))

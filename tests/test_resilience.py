"""Fault tolerance: injection harness, batch bisection, circuit breakers.

The two ISSUE acceptance pins live here:

  * a persistent single-request poison in a 16-request batch fails exactly
    that one future — the 15 innocents complete (bisection isolation),
  * a persistently-failing backend opens its breaker within a few batches,
    traffic re-dispatches to the fallback arm with bit-identical results,
    and a half-open probe closes the breaker after the fault clears — all
    visible in /metrics, /healthz and the exported trace.
"""
import json
import time
import urllib.error
import urllib.request

import numpy as np
import pytest

from conftest import FakeClock
from repro.apps import graphs, solvers
from repro.serve_mmo import (BatchTimeoutError, FaultInjector, FaultRule,
                             InjectedFault, MMOEngine, NonFiniteResultError,
                             ObservabilityServer, ResilienceManager,
                             apsp_request, parse_fault_spec)
from repro.serve_mmo import batching
from repro.serve_mmo.faults import classify_failure
from repro.serve_mmo.scheduler import request_bucket


def _engine(**kw):
  kw.setdefault("backend", "vector")
  kw.setdefault("retry_backoff_s", 0.0)
  return MMOEngine(**kw)


def _submit_apsp(eng, n_reqs, *, nodes=10, **req_kw):
  return [eng.submit(apsp_request(
      graphs.weighted_digraph(nodes, 0.3, seed=i), **req_kw))
      for i in range(n_reqs)]


def _trace_events(eng):
  return eng.export_trace()["traceEvents"]


def _http_get(url):
  """(status, body) — urllib raises on 503, which is a valid answer here."""
  try:
    with urllib.request.urlopen(url, timeout=10) as resp:
      return resp.status, resp.read().decode("utf-8")
  except urllib.error.HTTPError as e:
    return e.code, e.read().decode("utf-8")


# ---------------------------------------------------------------------------
# fault injector: rules, schedules, determinism, spec grammar
# ---------------------------------------------------------------------------


def test_fault_rule_validation():
  with pytest.raises(ValueError, match="point"):
    FaultRule(point="nope")
  with pytest.raises(ValueError, match="mode"):
    FaultRule(point="execute", mode="sometimes")
  with pytest.raises(ValueError, match="rate"):
    FaultRule(point="execute", mode="rate", rate=1.5)
  with pytest.raises(ValueError, match="count"):
    FaultRule(point="execute", mode="transient", count=0)


def test_transient_rule_exhausts():
  inj = FaultInjector([FaultRule(point="execute", mode="transient", count=2)])
  assert inj.check("execute") is not None
  assert inj.check("execute") is not None
  assert inj.check("execute") is None  # budget spent
  assert inj.stats()["fired"]["execute"] == 2


def test_persistent_rule_fires_until_cleared():
  inj = FaultInjector([FaultRule(point="compile", mode="persistent")])
  for _ in range(5):
    assert inj.check("compile") is not None
  assert inj.check("execute") is None  # other points untouched
  assert inj.clear("execute") == 0     # nothing armed there
  assert inj.clear() == 1              # "the fault cleared"
  assert inj.check("compile") is None


def test_rate_rule_is_deterministic_under_seed():
  def pattern(seed):
    inj = FaultInjector(
        [FaultRule(point="execute", mode="rate", rate=0.3)], seed=seed)
    return [inj.check("execute") is not None for _ in range(200)]

  p = pattern(7)
  assert p == pattern(7)          # same seed → identical chaos, replayable
  assert 0 < sum(p) < 200         # actually probabilistic, not all/none


def test_rule_scoping_filters():
  inj = FaultInjector([
      FaultRule(point="execute", mode="persistent", backend="xla"),
      FaultRule(point="compile", mode="persistent", match="closure"),
      FaultRule(point="nonfinite", mode="persistent",
                request_ids=frozenset({7})),
  ])
  assert inj.check("execute", backend="vector") is None
  assert inj.check("execute", backend="xla") is not None
  assert inj.check("compile", label="mmo/minplus") is None
  assert inj.check("compile", label="closure/minplus/n16") is not None
  assert inj.check("nonfinite", request_ids=[1, 2]) is None
  assert inj.check("nonfinite", request_ids=[2, 7]) is not None


def test_parse_fault_spec_grammar():
  inj = parse_fault_spec(
      "execute:rate:0.02;slow:transient:1:delay=0.2;"
      "execute:persistent:backend=xla;nonfinite:persistent:rid=3,5@closure")
  rules = inj.rules()
  assert [r.point for r in rules] == ["execute", "slow", "execute",
                                      "nonfinite"]
  assert rules[0].mode == "rate" and rules[0].rate == 0.02
  assert rules[1].count == 1 and rules[1].delay_s == 0.2
  assert rules[2].backend == "xla"
  assert rules[3].request_ids == frozenset({3, 5})
  assert rules[3].match == "closure"


def test_parse_fault_spec_rejects_garbage():
  with pytest.raises(ValueError, match="point"):
    parse_fault_spec("frobnicate:persistent")
  with pytest.raises(ValueError, match="unknown fault rule key"):
    parse_fault_spec("execute:persistent:color=red")
  with pytest.raises(ValueError, match="too many positional"):
    parse_fault_spec("execute:transient:1:2")
  with pytest.raises(ValueError, match="no rules"):
    parse_fault_spec(" ; ")


def test_classify_failure_taxonomy():
  assert classify_failure(NonFiniteResultError("b", [0]), "split") == "nonfinite"
  assert classify_failure(BatchTimeoutError("b", 0.1), "execute") == "timeout"
  assert classify_failure(InjectedFault("compile"), "execute") == "compile"
  assert classify_failure(RuntimeError("x"), "stack") == "stack"
  assert classify_failure(RuntimeError("x"), "weird-phase") == "other"


# ---------------------------------------------------------------------------
# result validation primitives
# ---------------------------------------------------------------------------


def test_validate_finite_flags_nan_not_inf():
  key = request_bucket(apsp_request(graphs.weighted_digraph(10, 0.3, seed=0)),
                       8)
  out = np.zeros((4, 16, 16), np.float32)
  out[3] = np.inf          # legitimate tropical output (unreachable pair)
  assert batching.validate_finite(key, out, 4) == []
  out[1, 5, 5] = np.nan
  out[3, 0, 0] = np.nan    # padded-slot NaN beyond live must be ignored too
  assert batching.validate_finite(key, out, 2) == [1]
  assert batching.validate_finite(key, out, 4) == [1, 3]
  # tuple outputs (closure results carry iteration counts) check out[0]
  iters = np.array([2, 2, 2, 2], np.int32)
  assert batching.validate_finite(key, (out, iters), 4) == [1, 3]
  # non-float payloads (boolean reachability) have no NaN to find
  assert batching.validate_finite(key, out.astype(bool), 4) == []


def test_poison_output_corrupts_requested_slots():
  key = request_bucket(apsp_request(graphs.weighted_digraph(10, 0.3, seed=0)),
                       8)
  out = np.zeros((3, 4, 4), np.float32)
  poisoned = batching.poison_output(key, (out, np.arange(3)), [1])
  assert np.isnan(poisoned[0][1]).all()
  assert not np.isnan(poisoned[0][0]).any()
  np.testing.assert_array_equal(poisoned[1], np.arange(3))


# ---------------------------------------------------------------------------
# circuit breaker state machine (unit level, fake clock)
# ---------------------------------------------------------------------------


def test_breaker_opens_probes_and_closes():
  fake_clock = FakeClock()
  mgr = ResilienceManager(threshold=2, probe_after_s=1.0, clock=fake_clock)
  key = request_bucket(apsp_request(graphs.weighted_digraph(10, 0.3, seed=0)),
                       8)
  primary = ("xla", (), "local")
  fallbacks = lambda: (("vector", (), "local"),)

  assert mgr.pick(key, primary, fallbacks) == (primary, False)
  assert mgr.on_failure(key, primary) is None          # 1 of 2
  assert mgr.pick(key, primary, fallbacks) == (primary, False)
  assert mgr.on_failure(key, primary) == "open"        # threshold hit
  # open: picks fall through to the fallback arm
  assert mgr.pick(key, primary, fallbacks) == (("vector", (), "local"), False)
  assert mgr.open_arms()[0]["backend"] == "xla"
  # cooldown elapses on the injected clock → next pick is the probe
  fake_clock.t += 1.5
  arm, probe = mgr.pick(key, primary, fallbacks)
  assert arm == primary and probe
  # probe failure re-opens and restarts the cooldown
  assert mgr.on_failure(key, primary) == "open"
  assert mgr.pick(key, primary, fallbacks)[0] == ("vector", (), "local")
  fake_clock.t += 1.5
  arm, probe = mgr.pick(key, primary, fallbacks)
  assert probe
  assert mgr.on_success(key, primary) == "close"       # probe recovered it
  assert mgr.pick(key, primary, fallbacks) == (primary, False)
  snap = mgr.snapshot()
  assert len(snap) == 1
  cell = snap[0]
  assert (cell["state"], cell["opens"], cell["closes"], cell["probes"]) == (
      "closed", 2, 1, 2)
  assert mgr.open_arms() == []


def test_breaker_success_resets_consecutive_count():
  mgr = ResilienceManager(threshold=3, clock=FakeClock())
  key = request_bucket(apsp_request(graphs.weighted_digraph(10, 0.3, seed=0)),
                       8)
  arm = ("xla", (), "local")
  mgr.on_failure(key, arm)
  mgr.on_failure(key, arm)
  assert mgr.on_success(key, arm) is None   # plain success, not a probe
  mgr.on_failure(key, arm)
  mgr.on_failure(key, arm)
  assert mgr.snapshot()[0]["state"] == "closed"  # never 3 consecutive


def test_breaker_all_arms_open_serves_last():
  mgr = ResilienceManager(threshold=1, probe_after_s=100.0, clock=FakeClock())
  key = request_bucket(apsp_request(graphs.weighted_digraph(10, 0.3, seed=0)),
                       8)
  primary = ("xla", (), "local")
  last = ("vector", (), "local")
  mgr.on_failure(key, primary)
  mgr.on_failure(key, last)
  # both broken, no cooldown elapsed: serve on the terminal arm anyway
  assert mgr.pick(key, primary, lambda: (last,)) == (last, False)


def test_breaker_threshold_none_disables():
  mgr = ResilienceManager(threshold=None)
  key = request_bucket(apsp_request(graphs.weighted_digraph(10, 0.3, seed=0)),
                       8)
  arm = ("xla", (), "local")
  for _ in range(50):
    assert mgr.on_failure(key, arm) is None
  assert mgr.pick(key, arm, lambda: ()) == (arm, False)
  assert mgr.snapshot() == []


def test_breaker_threshold_validation():
  with pytest.raises(ValueError, match="threshold"):
    ResilienceManager(threshold=0)
  with pytest.raises(ValueError, match="transient_retries"):
    MMOEngine(backend="vector", transient_retries=-1)


# ---------------------------------------------------------------------------
# engine fault matrix: every injection point × transient / persistent
# ---------------------------------------------------------------------------

_MATRIX = [
    ("compile", "compile", InjectedFault),
    ("execute", "execute", InjectedFault),
    ("nonfinite", "nonfinite", NonFiniteResultError),
    ("slow", "timeout", BatchTimeoutError),
]


@pytest.mark.parametrize("point,kind,_exc", _MATRIX,
                         ids=[m[0] for m in _MATRIX])
def test_transient_fault_is_ridden_out(point, kind, _exc):
  """A blip at any injection point is absorbed by the retry budget: every
  request completes, the retry counter moves, the failure is classified."""
  inj = FaultInjector([FaultRule(point=point, mode="transient", count=1,
                                 delay_s=0.5)])
  eng = _engine(max_batch=2, faults=inj, transient_retries=2,
                breaker_threshold=None,
                watchdog_s=0.1 if point == "slow" else None)
  futs = _submit_apsp(eng, 2)
  assert eng.run_until_idle() == 2
  for i, fut in enumerate(futs):
    ref, _ = solvers.apsp(graphs.weighted_digraph(10, 0.3, seed=i))
    np.testing.assert_allclose(fut.result().value, np.asarray(ref), atol=1e-5)
  snap = eng.metrics_snapshot()
  assert snap["counters"]["retries"] >= 1
  assert snap["counters"]["failed"] == 0
  assert snap["batch_failures_by_kind"] == {kind: 1}


@pytest.mark.parametrize("point,kind,exc", _MATRIX,
                         ids=[m[0] for m in _MATRIX])
def test_persistent_fault_exhausts_budget_and_fails(point, kind, exc):
  """A persistent fault burns retries and bisection, then fails every
  poisoned request with the *typed* failure — and the loop keeps serving."""
  inj = FaultInjector([FaultRule(point=point, mode="persistent",
                                 delay_s=0.5)])
  eng = _engine(max_batch=2, faults=inj, transient_retries=1,
                breaker_threshold=None,
                watchdog_s=0.1 if point == "slow" else None)
  futs = _submit_apsp(eng, 2)
  assert eng.run_until_idle() == 0
  for fut in futs:
    with pytest.raises(exc):
      fut.result()
  snap = eng.metrics_snapshot()
  assert snap["counters"]["failed"] == 2
  assert snap["counters"]["completed"] == 0
  assert set(snap["batch_failures_by_kind"]) == {kind}
  assert not eng._inflight
  # the fault clearing restores service on the same engine
  inj.clear()
  fut = eng.submit(apsp_request(graphs.weighted_digraph(10, 0.3, seed=9)))
  eng.run_until_idle()
  assert fut.result().value.shape == (10, 10)


# ---------------------------------------------------------------------------
# acceptance pin 1: bisection isolates a single poisoned request
# ---------------------------------------------------------------------------


def test_single_poisoned_request_in_16_batch_fails_alone():
  inj = FaultInjector()
  eng = _engine(max_batch=16, faults=inj, transient_retries=1,
                breaker_threshold=None)
  futs = _submit_apsp(eng, 16, nodes=12)
  poisoned_rid = futs[5].request.request_id
  inj.arm(FaultRule(point="execute", mode="persistent",
                    request_ids=frozenset({poisoned_rid})))
  assert eng.run_until_idle() == 15

  for i, fut in enumerate(futs):
    if i == 5:
      with pytest.raises(InjectedFault):
        fut.result()
    else:
      ref, _ = solvers.apsp(graphs.weighted_digraph(12, 0.3, seed=i))
      np.testing.assert_allclose(fut.result().value, np.asarray(ref),
                                 atol=1e-5)
  snap = eng.metrics_snapshot()
  assert snap["counters"]["completed"] == 15
  assert snap["counters"]["failed"] == 1
  assert snap["counters"]["retries"] > 0
  assert not eng._inflight

  events = _trace_events(eng)
  names = [ev["name"] for ev in events if ev.get("ph") == "i"]
  assert "batch_bisect" in names         # isolation visible in the trace
  assert "batch_fail" in names
  # O(log B) isolation: a 16-wide poison needs ~log2(16)=4 bisections, far
  # fewer than the 15 a linear per-request scan would cost
  assert 4 <= names.count("batch_bisect") <= 8


def test_bisect_disabled_fails_whole_batch():
  inj = FaultInjector()
  eng = _engine(max_batch=4, faults=inj, transient_retries=1, bisect=False,
                breaker_threshold=None)
  futs = _submit_apsp(eng, 4)
  inj.arm(FaultRule(point="execute", mode="persistent",
                    request_ids=frozenset({futs[0].request.request_id})))
  assert eng.run_until_idle() == 0    # historical fail-whole-batch behavior
  for fut in futs:
    with pytest.raises(InjectedFault):
      fut.result()


def test_rate_faults_never_fail_innocents():
  """Chaos mode: a 20% execute fault rate with bisection + fresh per-half
  retry budgets completes every request (nobody is actually poisoned)."""
  inj = FaultInjector([FaultRule(point="execute", mode="rate", rate=0.2)],
                      seed=3)
  eng = _engine(max_batch=8, faults=inj, transient_retries=2,
                breaker_threshold=None)
  futs = _submit_apsp(eng, 16)
  eng.run_until_idle()
  assert all(f.result().value.shape == (10, 10) for f in futs)
  snap = eng.metrics_snapshot()
  assert snap["counters"]["failed"] == 0
  assert snap["counters"]["completed"] == 16


# ---------------------------------------------------------------------------
# retry accounting: once-per-request outcomes, balanced spans, no re-stamp
# ---------------------------------------------------------------------------


def test_retry_does_not_double_count_or_restamp_deadlines():
  inj = FaultInjector([FaultRule(point="execute", mode="transient", count=1)])
  eng = _engine(max_batch=4, faults=inj, transient_retries=1,
                breaker_threshold=None)
  futs = _submit_apsp(eng, 4, deadline_s=30.0)
  deadlines = [f.request.deadline_at for f in futs]
  assert eng.run_until_idle() == 4
  # deadlines are stamped at submit and never re-stamped by the retry path
  assert [f.request.deadline_at for f in futs] == deadlines
  snap = eng.metrics_snapshot()
  assert snap["counters"]["completed"] == 4   # once per request, not per try
  assert snap["counters"]["submitted"] == 4
  assert snap["counters"]["retries"] == 1
  # admission quota drained exactly once per request
  assert eng.admission.snapshot()["inflight"] == {}

  # balanced spans per request: queued is exactly one b/e pair; every
  # execute 'b' (one per attempt) has a matching 'e'
  events = _trace_events(eng)
  for fut in futs:
    rid = fut.request.request_id
    mine = [ev for ev in events
            if ev.get("ph") in ("b", "e") and ev.get("id") == rid]
    queued = [ev["ph"] for ev in mine if ev["name"] == "queued"]
    execute = [ev["ph"] for ev in mine if ev["name"] == "execute"]
    assert queued == ["b", "e"]
    assert len(execute) % 2 == 0
    assert execute == ["b", "e"] * (len(execute) // 2)
  # the retried attempt closed its first execute slice as 'retried'
  outcomes = [ev["args"]["outcome"] for ev in events
              if ev.get("name") == "execute" and ev.get("ph") == "e"
              and "outcome" in ev.get("args", {})]
  assert "retried" in outcomes and "done" in outcomes


def test_service_window_includes_retry_time():
  """queue/service metrics measure what the caller experienced: the service
  window spans from the ORIGINAL batch pick through the final successful
  attempt, retries and backoff included."""
  inj = FaultInjector([FaultRule(point="execute", mode="transient", count=1)])
  eng = MMOEngine(backend="vector", max_batch=2, faults=inj,
                  transient_retries=1, breaker_threshold=None,
                  retry_backoff_s=0.05)
  futs = _submit_apsp(eng, 2)
  eng.run_until_idle()
  assert all(f.done() for f in futs)
  snap = eng.metrics_snapshot()
  svc = snap["buckets"][next(iter(snap["buckets"]))]["service_ms"]
  assert svc["p50"] >= 50.0   # the 50ms backoff is part of service latency


# ---------------------------------------------------------------------------
# watchdog: a hung batch fails instead of wedging the loop
# ---------------------------------------------------------------------------


def test_watchdog_times_out_hung_batch():
  inj = FaultInjector([FaultRule(point="slow", mode="persistent",
                                 delay_s=1.0)])
  eng = _engine(max_batch=2, faults=inj, transient_retries=0, bisect=False,
                breaker_threshold=None, watchdog_s=0.05)
  futs = _submit_apsp(eng, 2)
  t0 = time.perf_counter()
  assert eng.run_until_idle() == 0
  assert time.perf_counter() - t0 < 0.9   # did not serve the full stall
  for fut in futs:
    with pytest.raises(BatchTimeoutError, match="watchdog"):
      fut.result()
  assert eng.metrics_snapshot()["batch_failures_by_kind"] == {"timeout": 1}


def test_watchdog_disabled_runs_inline():
  inj = FaultInjector([FaultRule(point="slow", mode="persistent",
                                 delay_s=0.02)])
  eng = _engine(max_batch=2, faults=inj, breaker_threshold=None)
  futs = _submit_apsp(eng, 2)
  assert eng.run_until_idle() == 2         # slow but correct, no timeout
  assert all(f.result().value.shape == (10, 10) for f in futs)


# ---------------------------------------------------------------------------
# acceptance pin 2: breaker re-dispatch, bit-identical results, probe close
# ---------------------------------------------------------------------------


def test_breaker_cycle_redispatch_probe_and_health():
  inj = parse_fault_spec("execute:persistent:backend=xla")
  eng = MMOEngine(backend="xla", max_batch=4, faults=inj,
                  fallback_backends=("vector",), breaker_threshold=2,
                  transient_retries=1, retry_backoff_s=0.0,
                  breaker_probe_s=0.05)
  futs = _submit_apsp(eng, 8)
  assert eng.run_until_idle() == 8   # breaker opened mid-recovery; innocents
                                     # (all 8) completed on the fallback arm

  # bit-identical to the fallback arm computed standalone (the SIMD²
  # property: sibling arms share the substrate, results are exchangeable)
  ref_eng = MMOEngine(backend="vector", max_batch=4)
  ref_futs = _submit_apsp(ref_eng, 8)
  ref_eng.run_until_idle()
  for fut, ref in zip(futs, ref_futs):
    np.testing.assert_array_equal(fut.result().value, ref.result().value)

  snap = eng.observability_state()
  cells = {(c["backend"], c["state"]) for c in snap["breakers"]}
  assert ("xla", "open") in cells
  assert eng.resilience.open_arms()

  with ObservabilityServer(eng) as srv:
    status, body = _http_get(srv.url + "/healthz")
    assert status == 503
    health = json.loads(body)
    assert health["status"] == "degraded"
    assert health["open_breakers"][0]["backend"] == "xla"
    status, text = _http_get(srv.url + "/metrics")
    assert status == 200
    assert 'serve_breaker_state{' in text and 'backend="xla"' in text
    assert 'serve_batch_failures_total{kind="execute"}' in text
    assert "serve_retries_total" in text

    # the fault clears; after the cooldown the next pick probes the primary
    # arm, the probe succeeds, and the breaker closes
    inj.clear()
    time.sleep(0.06)
    fut = eng.submit(apsp_request(graphs.weighted_digraph(10, 0.3, seed=42)))
    eng.run_until_idle()
    assert fut.result().value.shape == (10, 10)
    cell = [c for c in eng.resilience.snapshot() if c["backend"] == "xla"][0]
    assert cell["state"] == "closed"
    assert cell["closes"] >= 1 and cell["probes"] >= 1
    status, body = _http_get(srv.url + "/healthz")
    assert status == 200
    assert json.loads(body)["status"] == "ok"
    assert json.loads(body)["open_breakers"] == []

  names = [ev["name"] for ev in _trace_events(eng) if ev.get("ph") == "i"]
  assert "breaker_open" in names
  assert "breaker_probe" in names
  assert "breaker_close" in names


def test_fallback_chain_ends_at_reference_backend():
  """Auto-ranked fallbacks (no fallback_backends override) terminate at the
  reference dense backend, and a dead primary still serves through it."""
  inj = parse_fault_spec("execute:persistent:backend=xla;"
                         "execute:persistent:backend=pallas")
  eng = MMOEngine(backend="xla", max_batch=2, faults=inj,
                  breaker_threshold=1, transient_retries=2,
                  retry_backoff_s=0.0, breaker_probe_s=60.0, interpret=True)
  futs = _submit_apsp(eng, 2)
  eng.run_until_idle()
  for i, fut in enumerate(futs):
    ref, _ = solvers.apsp(graphs.weighted_digraph(10, 0.3, seed=i))
    np.testing.assert_allclose(fut.result().value, np.asarray(ref), atol=1e-5)
  key = next(iter(eng._fallback_arms_memo))
  arms = eng._fallback_arms(key)
  assert arms[-1][0] == "vector"   # terminal arm is the reference backend


def test_breaker_disabled_keeps_failing_in_place():
  """threshold=None is the historical behavior: no fallback, the poisoned
  arm's failures land on callers."""
  inj = parse_fault_spec("execute:persistent:backend=vector")
  eng = _engine(max_batch=2, faults=inj, transient_retries=0, bisect=False,
                breaker_threshold=None)
  futs = _submit_apsp(eng, 2)
  assert eng.run_until_idle() == 0
  for fut in futs:
    with pytest.raises(InjectedFault):
      fut.result()
  assert eng.observability_state()["breakers"] == []

"""Sharded serving — one engine, small buckets local, big buckets on a mesh.

    PYTHONPATH=src python examples/serve_sharded.py

Runs on a laptop CPU: XLA_FLAGS is defaulted below to expose 8 fake host
devices before jax initializes.  The engine builds a (data=2, model=4) mesh
and routes each shape bucket by contraction size: APSP requests below the
``shard_flops`` cutoff execute on one device, the big ones run their closure
as a batched SUMMA squaring schedule across all 8 — same request API, same
results, one scheduler.
"""
import os

os.environ.setdefault("XLA_FLAGS", "--xla_force_host_platform_device_count=8")

import numpy as np  # noqa: E402

import jax  # noqa: E402

from repro.apps import graphs, solvers  # noqa: E402
from repro.serve_mmo import MMOEngine, apsp_request  # noqa: E402


def main():
  n_dev = len(jax.devices())
  dims = (2, 4) if n_dev >= 8 else (1, n_dev)
  mesh = jax.make_mesh(dims, ("data", "model"))
  print(f"mesh: data={dims[0]} × model={dims[1]} on {n_dev} "
        f"{jax.default_backend()} devices")

  # 2·16³ ≈ 8e3 flops stays local; 2·64³ ≈ 5e5 crosses the 1e5 cutoff
  eng = MMOEngine(backend="xla", mesh=mesh, schedule="summa",
                  shard_flops=1e5, max_batch=4)

  small = {n: graphs.weighted_digraph(n, 0.3, seed=n) for n in (9, 12, 14)}
  big = {n: graphs.weighted_digraph(n, 0.25, seed=n) for n in (49, 55, 62)}
  futs = {n: eng.submit(apsp_request(w)) for n, w in {**small, **big}.items()}
  eng.run_until_idle()

  placement = {k.shape[0]: s for k, s in eng._schedules.items()}
  for n, w in sorted({**small, **big}.items()):
    res = futs[n].result()
    ref, _ = solvers.apsp(w)
    np.testing.assert_allclose(res.value, np.asarray(ref), atol=1e-5)
    bucket = 1 << (n - 1).bit_length()
    print(f"apsp n={n:>2} → bucket {bucket:>3} [{placement[bucket]:>6}]  "
          f"closed in {res.extras['iterations']} iterations, matches solver")

  # steady state: repeat traffic replays cached executables on both paths
  misses = eng.cache.misses
  futs2 = [eng.submit(apsp_request(w)) for w in {**small, **big}.values()]
  eng.run_until_idle()
  assert all(f.done() for f in futs2)
  print(f"repeat traffic: {eng.cache.misses - misses} new compiles "
        f"(sharded + local executables cached independently)")
  print(eng.stats().summary())


if __name__ == "__main__":
  main()

"""SIMD² inside the LM framework: the two places the paper's ops appear
natively in the assigned architectures (DESIGN.md §4).

  1. chameleon-style VQ image tokenization — nearest-codebook search is the
     ``addnorm`` instruction (+argmin); runs on the MXU-rewrite and on the
     Pallas kernel path, validated against brute force.
  2. embedding retrieval (KNN over model embeddings) — the ``knn`` app as a
     serving-side retrieval primitive.

    PYTHONPATH=src python examples/vq_retrieval.py
"""
import numpy as np
import jax
import jax.numpy as jnp


def main():
  from repro.apps.baselines import knn_np
  from repro.apps.solvers import knn
  from repro.models.vlm import fuse_streams, vq_tokenize

  rng = np.random.default_rng(0)

  # --- 1. VQ tokenization (chameleon frontend stub) -------------------------
  codebook = rng.standard_normal((8192, 256)).astype(np.float32)
  patches = codebook[rng.integers(0, 8192, (2, 1024))] \
      + 0.05 * rng.standard_normal((2, 1024, 256)).astype(np.float32)
  ids_xla = vq_tokenize(jnp.asarray(patches), jnp.asarray(codebook))
  ids_pl = vq_tokenize(jnp.asarray(patches), jnp.asarray(codebook),
                       backend="pallas")
  brute = np.stack([
      [np.argmin(((p - codebook) ** 2).sum(-1)) for p in row]
      for row in patches[:, :8]])
  ok = np.array_equal(np.asarray(ids_xla)[:, :8], brute) and \
      np.array_equal(np.asarray(ids_xla), np.asarray(ids_pl))
  print(f"VQ tokenize: 2×1024 patches → codebook ids; "
        f"xla==pallas==brute: {ok}")

  text = jnp.asarray(rng.integers(0, 32000, (2, 64)), jnp.int32)
  fused = fuse_streams(text, ids_xla, image_token_offset=32768)
  print(f"early fusion: image({ids_xla.shape[1]}) + text({text.shape[1]}) "
        f"→ stream {fused.shape}")

  # --- 2. embedding retrieval ------------------------------------------------
  table = rng.standard_normal((50000, 128)).astype(np.float32)
  queries = rng.standard_normal((32, 128)).astype(np.float32)
  d, i = knn(table, queries, k=8)
  d_ref, i_ref = knn_np(table, queries, 8)
  print(f"kNN retrieval (50k×128): idx match {np.array_equal(np.asarray(i), i_ref)}")


if __name__ == "__main__":
  main()

"""End-to-end driver: train a ~100M-parameter llama-family model for a few
hundred steps on the host, with checkpointing — deliverable (b)'s training
example.  (The same launcher drives the full configs on a real pod.)

    PYTHONPATH=src python examples/train_lm.py [--steps 300]
"""
import argparse
import sys

from repro.launch import train as train_mod
from repro.models.common import ModelConfig


def main(argv=None):
  ap = argparse.ArgumentParser()
  ap.add_argument("--steps", type=int, default=300)
  ap.add_argument("--ckpt-dir", default="/tmp/simd2_train_lm")
  args = ap.parse_args(argv)

  # ~100M-param llama-family config (registered ad hoc — any entry in
  # src/repro/configs works the same way via --arch)
  import repro.configs as configs
  cfg100m = ModelConfig(
      name="llama-100m", family="dense", n_layers=12, d_model=768,
      n_heads=12, n_kv_heads=4, d_ff=2048, vocab=32000, head_dim=64)
  configs._ARCHS["llama-100m"] = "llama_100m"

  import types
  mod = types.ModuleType("repro.configs.llama_100m")
  mod.CONFIG = cfg100m
  mod.smoke_config = lambda: cfg100m.replace(n_layers=2, d_model=128,
                                             d_ff=256, vocab=1024)
  sys.modules["repro.configs.llama_100m"] = mod

  n_params = 12 * (3 * 768 * 2048 + 768 * (12 + 8) * 64 + 768 * 768) \
      + 2 * 32000 * 768
  print(f"llama-100m ≈ {n_params / 1e6:.0f}M params; training "
        f"{args.steps} steps on the host mesh …")
  return train_mod.main([
      "--arch", "llama-100m", "--steps", str(args.steps),
      "--batch", "8", "--seq", "512", "--lr", "3e-4",
      "--ckpt-dir", args.ckpt_dir, "--ckpt-every", "100",
      "--log-every", "20",
  ])


if __name__ == "__main__":
  sys.exit(main())

"""Distributed APSP: the paper's flagship application at (simulated) pod
scale — the min-plus closure runs 2-D-sharded across a device mesh with
SUMMA semiring matmuls (core/distributed.py).

    PYTHONPATH=src python examples/apsp_pod_scale.py          # host devices
    XLA_FLAGS=--xla_force_host_platform_device_count=16 \
        PYTHONPATH=src python examples/apsp_pod_scale.py      # 16-way mesh
"""
import numpy as np


def main():
  import jax
  import jax.numpy as jnp
  from repro.apps import graphs
  from repro.apps.baselines import apsp_np
  from repro.core import prepare_adjacency
  from repro.core.distributed import distributed_leyzorek

  n_dev = len(jax.devices())
  model = 4 if n_dev % 4 == 0 and n_dev >= 4 else 1
  data = max(1, n_dev // model)
  mesh = jax.make_mesh((data, model), ("data", "model"))
  print(f"mesh: data={data} × model={model} ({n_dev} devices)")

  n = 512
  w = graphs.weighted_digraph(n, 0.1, seed=7)
  adj = prepare_adjacency(jnp.asarray(w), op="minplus")
  dist = distributed_leyzorek(adj, op="minplus", mesh=mesh)

  ref = apsp_np(w)
  fin = np.isfinite(ref)
  err = np.abs(np.asarray(dist)[fin] - ref[fin]).max()
  print(f"APSP |V|={n}: sharded closure max err = {err:.2e} "
        f"(validated vs Floyd-Warshall)")
  print("C stays 2-D block-sharded across iterations; each squaring "
        "moves only SUMMA K-panels (all-gather row/col).")


if __name__ == "__main__":
  main()

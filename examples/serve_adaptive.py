"""Adaptive QoS — live-latency feedback correcting static predictions.

    PYTHONPATH=src python examples/serve_adaptive.py

The cost table (or its roofline prior) answers "what should this bucket
cost" for an idealized accelerator; the machine actually serving traffic
answers differently.  This example shows the drift and the fix:

  1. a static engine prices a bulk closure bucket off the roofline prior —
     microseconds — while the measured batch takes milliseconds, so its
     service-time batch cap (``max_batch_seconds``) never binds and urgent
     arrivals wait behind full bulk batches;
  2. an adaptive engine serves the same mix: after a few batches its EWMA
     estimator has learned the real per-request latency (and the measured
     convergence counts of the closure traffic), the cap binds, bulk
     batches stay short, and the urgent slice's latency collapses.
"""
import numpy as np

from repro.apps import graphs
from repro.serve_mmo import MMOEngine, apsp_request, mmo_request
from repro.serve_mmo.scheduler import request_bucket

RNG = np.random.default_rng(0)
BULK_N = 72           # pads to the 128 closure bucket — compute-dominated
CAP_S = 0.025         # ~one measured bulk request of work per batch


def bulk_req(seed):
  return apsp_request(graphs.weighted_digraph(BULK_N, 0.3, seed=seed),
                      tenant="bulk")


def urgent_req():
  a = RNG.standard_normal((12, 12)).astype(np.float32)
  b = RNG.standard_normal((12, 12)).astype(np.float32)
  return mmo_request(a, b, op="minplus", tenant="interactive",
                     deadline_s=30.0, priority=1)


def serve(adaptive: bool) -> None:
  eng = MMOEngine(backend="xla", max_batch=8, policy="deadline",
                  adaptive=adaptive, max_batch_seconds=CAP_S,
                  deadline_lookback_s=60.0)
  eng.prewarm([bulk_req(0), urgent_req()])

  # warm the feedback loop: the estimator needs a few observed batches
  # before it overrides the static prior (min_observations)
  for wave in range(4):
    eng.submit(bulk_req(100 + wave))
    eng.submit(urgent_req())
    eng.run_until_idle()
  eng.reset_stats()

  key = request_bucket(bulk_req(0))
  est = eng.predict_request(key)
  print(f"\n--- adaptive={adaptive} ---")
  print(f"bulk prediction: {est.seconds * 1e3:.3f} ms/request "
        f"(source: {est.source})")

  # a bulk flood with urgent requests interleaved — synchronous stepping so
  # the batch sizes are easy to see
  futs = [eng.submit(bulk_req(i)) for i in range(12)]
  urgent = []
  for _ in range(4):
    eng.step()
    urgent.append(eng.submit(urgent_req()))
  eng.run_until_idle()
  assert all(f.state == "done" for f in futs + urgent)

  recs = {r.request_id: r for r in eng._records}
  bulk_batches = [recs[f.request.request_id].batch_size for f in futs]
  lat = [recs[f.request.request_id].latency_s * 1e3 for f in urgent]
  print(f"bulk batch sizes under the {CAP_S * 1e3:.0f}ms cap: "
        f"mean={np.mean(bulk_batches):.2f}")
  print(f"urgent latency: p50={np.percentile(lat, 50):.1f}ms "
        f"max={max(lat):.1f}ms")
  snap = eng.metrics_snapshot()["estimator"]
  for label, cell in snap["cells"].items():
    print(f"estimator {label}: {cell['seconds'] * 1e3:.3f} ms/request "
          f"({cell['observations']} batches)")
  for label, cell in snap["iterations"].items():
    print(f"measured convergence {label}: {cell['iterations']:.1f} "
          f"iterations (worst case would be charged 7)")


def main():
  serve(adaptive=False)
  serve(adaptive=True)


if __name__ == "__main__":
  main()

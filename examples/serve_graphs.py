"""Serving SIMD² graph workloads — request API quickstart.

    PYTHONPATH=src python examples/serve_graphs.py

Submits a mixed stream of the paper's applications (APSP, KNN, transitive
closure, a raw min-plus mmo) to the MMO serving engine and cross-checks each
result against the direct library solver.  Shows the three ways to consume
results: run_until_idle + future.result(), lazy future-driven execution, and
the background serving loop.
"""
import numpy as np

from repro.apps import graphs, solvers
from repro.serve_mmo import (MMOEngine, apsp_request, knn_request,
                             mmo_request, reachability_request)


def main():
  eng = MMOEngine(backend="xla", max_batch=8)

  # -- 1. batch submit + drain ----------------------------------------------
  weights = [graphs.weighted_digraph(n, 0.3, seed=n) for n in (10, 14, 16, 21)]
  futs = [eng.submit(apsp_request(w)) for w in weights]
  eng.run_until_idle()
  for w, f in zip(weights, futs):
    res = f.result()
    ref, _ = solvers.apsp(w)
    np.testing.assert_allclose(res.value, np.asarray(ref), atol=1e-5)
    print(f"apsp n={w.shape[0]:>2}  closed in {res.extras['iterations']} "
          f"mmo iterations, matches the direct solver")

  # -- 2. lazy execution: result() drives the engine ------------------------
  ref_pts, qry_pts = graphs.knn_points(64, 9, 8, seed=1)
  fut = eng.submit(knn_request(qry_pts, ref_pts, k=5))
  print("knn top-1 indices:", fut.result().extras["indices"][:, 0])

  adj = graphs.boolean_digraph(12, 0.12, seed=2)
  fut = eng.submit(reachability_request(adj))
  reach = fut.result().value
  print(f"reachability: {int(reach.sum())}/{reach.size} pairs connected")

  # -- 3. background serving loop + raw mmo instructions --------------------
  eng.start()
  rng = np.random.default_rng(0)
  a = rng.standard_normal((9, 17)).astype(np.float32)
  b = rng.standard_normal((17, 11)).astype(np.float32)
  fut = eng.submit(mmo_request(a, b, op="minplus"))
  d = fut.result(timeout=60).value
  print(f"raw minplus mmo: {a.shape} ⊗ {b.shape} → {d.shape}")
  eng.stop()

  print(eng.stats().summary())


if __name__ == "__main__":
  main()

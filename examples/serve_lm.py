"""Serving example: batched prefill + decode through launch/serve.Engine
with a reduced config (same code path the decode_* dry-run shapes lower).

    PYTHONPATH=src python examples/serve_lm.py --arch mixtral-8x7b
"""
import sys

from repro.launch import serve as serve_mod


def main(argv=None):
  argv = argv or sys.argv[1:]
  if not any(a.startswith("--arch") for a in argv):
    argv = ["--arch", "tinyllama-1.1b"] + argv
  return serve_mod.main(argv + ["--smoke", "--batch", "4",
                                "--prompt-len", "24", "--gen", "16"])


if __name__ == "__main__":
  sys.exit(main())

"""Quickstart: the SIMD² programming model in five minutes.

    PYTHONPATH=src python examples/quickstart.py

Mirrors the paper's Figures 6–7: generalized matrix ops (`mmo`), a closure
solver composed from them (APSP via Leyzorek's algorithm with convergence
checks), and the same op running on the Pallas TPU kernel path.
"""
import numpy as np
import jax.numpy as jnp

from repro.apps import graphs
from repro.apps.baselines import apsp_np
from repro.core import leyzorek_closure, mmo, prepare_adjacency


def main():
  # 1. D = C ⊕ (A ⊗ B) with the ⊕/⊗ pair selected per op (paper Table 2)
  rng = np.random.default_rng(0)
  a = jnp.asarray(rng.standard_normal((128, 128)), jnp.float32)
  b = jnp.asarray(rng.standard_normal((128, 128)), jnp.float32)
  for op in ("mma", "minplus", "maxmin", "addnorm"):
    d = mmo(a, b, op=op)
    print(f"mmo[{op:8s}] -> {d.shape} {d.dtype}, d[0,0]={float(d[0, 0]):.3f}")

  # 2. the same op on the Pallas SIMD²-unit kernel (interpret mode on CPU)
  d_kernel = mmo(a, b, op="minplus", backend="pallas", interpret=True)
  d_xla = mmo(a, b, op="minplus", backend="xla")
  print("pallas == xla:", bool(jnp.allclose(d_kernel, d_xla, atol=1e-4)))

  # 3. a whole application: APSP = min-plus closure (Fig 7, Leyzorek form)
  w = graphs.weighted_digraph(256, 0.2, seed=1)
  adj = prepare_adjacency(jnp.asarray(w), op="minplus")
  dist, iters = leyzorek_closure(adj, op="minplus")
  ref = apsp_np(w)
  fin = np.isfinite(ref)
  err = np.abs(np.asarray(dist)[fin] - ref[fin]).max()
  print(f"APSP closure: {int(iters)} squarings (lg|V|={int(np.ceil(np.log2(256)))} worst case), "
        f"max err vs Floyd-Warshall = {err:.2e}")


if __name__ == "__main__":
  main()

"""QoS serving — deadlines, priorities, tenants, admission, live metrics.

    PYTHONPATH=src python examples/serve_qos.py

A bulk tenant floods the engine with big closure problems while an
interactive tenant submits small deadline-tagged lookups.  The deadline
policy serves the interactive slice first (earliest feasible deadline,
priority tiers), admission bounds what the bulk tenant may queue, and a
mid-run metrics snapshot shows rolling p50/p99 without stopping the loop.
"""
import json

import numpy as np

from repro.apps import graphs
from repro.serve_mmo import (MMOEngine, RejectedError, apsp_request,
                             mmo_request)


def main():
  eng = MMOEngine(backend="xla", max_batch=4, policy="deadline",
                  max_queue=64, tenant_quota={"bulk": 12})
  eng.prewarm([apsp_request(graphs.weighted_digraph(40, 0.3, seed=0)),
               mmo_request(np.zeros((12, 12), np.float32),
                           np.zeros((12, 12), np.float32), op="minplus")])

  # -- bulk tenant: 20 offered, quota admits 12 ------------------------------
  bulk = [eng.submit(apsp_request(graphs.weighted_digraph(40, 0.3, seed=i),
                                  tenant="bulk"))
          for i in range(20)]
  over_quota = [f for f in bulk if f.state == "rejected"]
  print(f"bulk: offered {len(bulk)}, admitted {len(bulk) - len(over_quota)}, "
        f"{len(over_quota)} rejected by the tenant quota")

  # -- interactive tenant: deadline-tagged, jumps the bulk queue -------------
  rng = np.random.default_rng(0)
  urgent = [eng.submit(mmo_request(
      rng.standard_normal((12, 12)).astype(np.float32),
      rng.standard_normal((12, 12)).astype(np.float32),
      op="minplus", tenant="interactive", deadline_s=30.0, priority=1))
      for _ in range(6)]

  eng.start()
  for f in urgent:  # resolve while bulk work is still queued behind them
    f.result(timeout=120)
  snap = eng.metrics_snapshot()  # live: the loop is still serving bulk
  print(f"mid-run metrics: queue_depth={snap['queue_depth']} "
        f"counters={snap['counters']}")
  eng.stop()

  for f in over_quota:
    try:
      f.result()
    except RejectedError as e:
      print(f"rejected future raises at result(): {e}")
      break

  recs = {r.request_id: r for r in eng._records}
  lat = [recs[f.request.request_id].latency_s * 1e3 for f in urgent]
  print(f"interactive latency under bulk flood: "
        f"p50={np.percentile(lat, 50):.1f}ms max={max(lat):.1f}ms")
  print(eng.stats().summary())
  print(json.dumps(eng.metrics_snapshot()["buckets"], indent=2,
                   default=float))


if __name__ == "__main__":
  main()

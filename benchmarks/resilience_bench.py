"""Resilience benchmark: goodput under injected faults + disabled-hook cost.

    PYTHONPATH=src python benchmarks/resilience_bench.py [--smoke]

Two experiments, recorded in BENCH_resilience.json:

**Goodput under transient execute faults.**  The same deadline-tagged APSP
stream (25% urgent at priority 1) is served twice against a seeded
transient execute fault schedule — one guaranteed blip
(``execute:transient:1``, so the comparison never degenerates to
fault-free) plus rate-mode chaos (``execute:rate:R`` — each batch dispatch
fails with probability R, replayable under the seed):

  baseline — fail-whole-batch: ``transient_retries=0, bisect=False``, the
             pre-recovery behavior.  Every fault costs the whole batch: all
             co-batched requests fail, goodput drops by batch-sized bites.
  recovery — the engine's recovery driver (bounded retries + bisection).
             A transient fault is ridden out by a retry; goodput stays 1.0
             and the cost is a few extra launches, not failed requests.

Reported per arm: goodput (completed / offered), overall and urgent-slice
p99 latency, retries, and batch failures by kind.  Both arms run with
breakers disabled (``breaker_threshold=None``): the injected fault is
backend-agnostic, so arm re-dispatch could not help and would only blur the
comparison.

**Disabled-hook steady-state overhead.**  The fault-tolerance machinery is
designed to be left on in production, so its *disabled/steady* cost must
be negligible.  In the no-fault steady state the recovery path adds
exactly three things to a batch: the ``faults is not None`` hook checks,
the breaker fast path (``pick`` + ``on_success`` against an empty breaker
registry), and NaN result validation (one NaN-propagating ``min``
reduction over the live output).  Those calls cost single-digit
microseconds against a millisecond-scale batch — an effect an end-to-end
A/B wall cannot resolve on a contended CI box (paired 0.3s walls here
swing ±10% run to run, and even an A/A test of two identical engines
reads ±6%).  So the bench prices the overhead *directly*: it times the
exact added calls against the stream's real bucket output, times the real
per-batch serve cycle on a warm default engine, and reports the ratio.
Asserted < 2%.
"""
from __future__ import annotations

import argparse
import json
import statistics
import time

import numpy as np

from repro.apps import graphs
from repro.serve_mmo import MMOEngine, apsp_request, parse_fault_spec

OVERHEAD_BUDGET = 0.02  # max disabled-hook steady-state slowdown
URGENT_FRAC = 0.25


def make_stream(n_requests: int, seed: int = 0):
  """Same-bucket APSP stream (deterministic batching), 25% urgent."""
  rng = np.random.default_rng(seed)
  reqs = []
  for i in range(n_requests):
    urgent = rng.random() < URGENT_FRAC
    qos = {"priority": 1, "deadline_s": 60.0} if urgent else {}
    reqs.append(apsp_request(
        graphs.weighted_digraph(12, 0.3, seed=int(rng.integers(0, 2 ** 31))),
        **qos))
  return reqs


def _p99_ms(lat):
  return float(np.percentile(np.asarray(lat, np.float64), 99)) * 1e3 \
      if lat else None


def run_arm(label: str, *, n_requests: int, stream_seed: int,
            fault_rate: float, fault_seed: int, retries: int, bisect: bool,
            backend: str, max_batch: int) -> dict:
  """Serve one fresh copy of the stream under one recovery configuration."""
  injector = (parse_fault_spec(
      f"execute:transient:1;execute:rate:{fault_rate}", seed=fault_seed)
      if fault_rate > 0.0 else None)
  eng = MMOEngine(backend=backend, max_batch=max_batch, policy="deadline",
                  faults=injector, transient_retries=retries, bisect=bisect,
                  breaker_threshold=None, retry_backoff_s=0.0005)
  reqs = make_stream(n_requests, seed=stream_seed)
  eng.prewarm(reqs[:1])   # compiles every pow2 batch variant of the bucket,
                          # so bisection launches never pay a compile
  t0 = time.perf_counter()
  futs = [eng.submit(r) for r in reqs]
  eng.run_until_idle()
  wall = time.perf_counter() - t0

  urgent_rids = {f.request.request_id for f in futs
                 if f.request.priority == 1}
  lat, urgent_lat = [], []
  for rec in eng._records:
    lat.append(rec.completed_s - t0)
    if rec.request_id in urgent_rids:
      urgent_lat.append(rec.completed_s - t0)
  snap = eng.metrics_snapshot()
  done = sum(1 for f in futs if f.state == "done")
  out = {
      "label": label,
      "offered": len(futs),
      "completed": done,
      "goodput": done / len(futs),
      "wall_s": wall,
      "p99_ms": _p99_ms(lat),
      "urgent_p99_ms": _p99_ms(urgent_lat),
      "retries": snap["counters"]["retries"],
      "batch_failures": snap["batch_failures_by_kind"],
      "faults_fired": injector.stats()["fired_total"] if injector else 0,
  }
  print(f"[resilience_bench] {label:9s}: goodput={out['goodput']:.3f} "
        f"({done}/{len(futs)})  p99={out['p99_ms']:.1f}ms  "
        f"urgent_p99={out['urgent_p99_ms']:.1f}ms  "
        f"retries={out['retries']}  faults={out['faults_fired']}  "
        f"failures={out['batch_failures']}")
  return out


def run_disabled_overhead(*, n_requests: int, stream_seed: int, backend: str,
                          max_batch: int, repeats: int) -> dict:
  """Price the steady-state hook calls directly against the real per-batch
  serve cycle (see module docstring for why not an end-to-end A/B wall)."""
  from repro.serve_mmo import batching
  from repro.serve_mmo.resilience import ResilienceManager
  from repro.serve_mmo.scheduler import request_bucket

  rng = np.random.default_rng(stream_seed)
  ws = [graphs.weighted_digraph(12, 0.3, seed=int(rng.integers(0, 2 ** 31)))
        for _ in range(n_requests)]

  # per-batch serve cycle on a warm default engine (hooks armed) — min over
  # several replays so contention bursts don't inflate the denominator
  eng = MMOEngine(backend=backend, max_batch=max_batch)
  eng.prewarm([apsp_request(ws[0])])
  for w in ws:
    eng.submit(apsp_request(w))
  eng.run_until_idle()    # warmup replay outside the measurement
  batch_walls = []
  for _ in range(repeats):
    eng.reset_stats()
    t0 = time.perf_counter()
    for w in ws:
      eng.submit(apsp_request(w))
    eng.run_until_idle()
    batches = eng.stats().batches
    batch_walls.append((time.perf_counter() - t0) / max(batches, 1))
  batch_s = min(batch_walls)

  # the exact calls the recovery path adds to a no-fault batch, against the
  # stream's real bucket output shape
  key = request_bucket(apsp_request(ws[0]))
  (nb,) = key.shape
  out = (np.random.default_rng(0).random(
      (max_batch, nb, nb)).astype(np.float32),
         np.full(max_batch, 3, np.int32))
  mgr = ResilienceManager(threshold=5)
  arm = (backend, (), "local")
  hook_walls = []
  loops = 5000
  for _ in range(3):
    t0 = time.perf_counter()
    for _ in range(loops):
      batching.validate_finite(key, out, max_batch)
      mgr.pick(key, arm, lambda: ())
      mgr.on_success(key, arm)
    hook_walls.append((time.perf_counter() - t0) / loops)
  hook_s = min(hook_walls)

  return {
      "batch_cycle_s": batch_s,
      "hook_s": hook_s,
      "overhead_frac": hook_s / batch_s,
      "budget_frac": OVERHEAD_BUDGET,
      "pairs": repeats,
  }


def main(argv=None):
  ap = argparse.ArgumentParser()
  ap.add_argument("--requests", type=int, default=192)
  ap.add_argument("--backend", default="xla")
  ap.add_argument("--max-batch", type=int, default=8)
  ap.add_argument("--fault-rate", type=float, default=0.01,
                  help="per-dispatch transient execute fault probability")
  ap.add_argument("--seed", type=int, default=0)
  ap.add_argument("--fault-seed", type=int, default=2,
                  help="injector seed (default chosen so the default "
                       "config actually draws >= 1 fault)")
  ap.add_argument("--repeats", type=int, default=15,
                  help="replays for the per-batch serve-cycle timing")
  ap.add_argument("--retries", type=int, default=2,
                  help="recovery arm's transient retry budget")
  ap.add_argument("--smoke", action="store_true",
                  help="CI sizing: fewer requests/pairs, higher fault rate "
                       "so the fault path is exercised deterministically")
  ap.add_argument("--out", default="BENCH_resilience.json", metavar="PATH",
                  help="write all arms' numbers to PATH as JSON "
                       "('' disables)")
  args = ap.parse_args(argv)
  if args.smoke:
    args.requests = min(args.requests, 96)
    args.fault_rate = max(args.fault_rate, 0.05)
    args.repeats = min(args.repeats, 7)

  common = dict(n_requests=args.requests, stream_seed=args.seed,
                fault_rate=args.fault_rate, fault_seed=args.fault_seed,
                backend=args.backend, max_batch=args.max_batch)
  baseline = run_arm("baseline", retries=0, bisect=False, **common)
  recovery = run_arm("recovery", retries=args.retries, bisect=True, **common)

  obs = run_disabled_overhead(
      n_requests=args.requests, stream_seed=args.seed,
      backend=args.backend, max_batch=args.max_batch, repeats=args.repeats)
  print(f"[resilience_bench] disabled hooks: {obs['hook_s'] * 1e6:.1f}us "
        f"per batch vs {obs['batch_cycle_s'] * 1e6:.0f}us batch cycle → "
        f"{obs['overhead_frac'] * 100:+.2f}% "
        f"(budget {OVERHEAD_BUDGET * 100:.0f}%)")

  if args.out:
    doc = {
        "requests": args.requests,
        "backend": args.backend,
        "max_batch": args.max_batch,
        "fault_rate": args.fault_rate,
        "fault_seed": args.fault_seed,
        "baseline": baseline,
        "recovery": recovery,
        "disabled_hook_overhead": obs,
    }
    with open(args.out, "w", encoding="utf-8") as f:
      json.dump(doc, f, indent=2)
    print(f"[resilience_bench] wrote {args.out}")

  assert recovery["goodput"] == 1.0, (
      f"recovery arm dropped requests under transient faults: "
      f"{recovery['goodput']:.3f} goodput — isolation failed")
  if baseline["faults_fired"]:
    assert baseline["goodput"] < 1.0, (
        "baseline arm absorbed a fault without retries — injector inert?")
    assert recovery["goodput"] > baseline["goodput"], (
        f"recovery ({recovery['goodput']:.3f}) must beat fail-whole-batch "
        f"({baseline['goodput']:.3f}) under the same fault schedule")
  if recovery["faults_fired"]:
    assert recovery["retries"] > 0, "faults fired but nothing retried"
  assert obs["overhead_frac"] < OVERHEAD_BUDGET, (
      f"disabled fault-tolerance hooks cost "
      f"{obs['overhead_frac'] * 100:.2f}% steady-state — exceeds the "
      f"{OVERHEAD_BUDGET * 100:.0f}% budget; the machinery must be free "
      f"when idle")
  return 0


if __name__ == "__main__":
  raise SystemExit(main())

"""Fig 11: the 8 applications × {small, medium, large} — three arms, same
structure as the paper's figure:

  baseline      — classic algorithm (numpy FW / Kruskal / BFS / brute force);
  simd2 w/o units — the SIMD²-ized solver measured on this host's vector
                  ALUs.  Min/max-family apps come out SLOWER than baseline
                  (0.1–0.3×) — reproducing the paper's own observation that
                  "these applications can never take advantage of
                  matrix-based algorithms … when SIMD² units are absent";
                  mma/orand/addnorm apps (GTC, KNN) win even without units
                  via the MXU rewrites, as in the paper.
  simd2 w/ units — modeled: measured time scaled by the v5e roofline gain of
                  the app's ⊕⊗ op (benchmarks/common.modeled_speedup).

Sizes follow configs/simd2_apps.py BENCH_SIZES (paper Table 4 ratios scaled
to the CPU host; APP_SIZES holds the paper's originals).
"""
from __future__ import annotations

import numpy as np
import jax

from benchmarks.common import csv_row, gmean, timeit
from repro.apps import baselines as bl
from repro.apps import graphs
from repro.apps import solvers as sv
from repro.configs.simd2_apps import BENCH_SIZES


def _inputs(app, n, seed=0):
  if app in ("apsp",):
    return (graphs.weighted_digraph(n, 0.25, seed=seed),)
  if app == "aplp":
    return (graphs.dag(n, 0.25, seed=seed),)
  if app == "mcp":
    return (graphs.capacity_graph(n, 0.25, seed=seed),)
  if app == "maxrp":
    return (graphs.reliability_graph(n, 0.25, seed=seed, maximize=True),)
  if app == "minrp":
    return (graphs.reliability_graph(n, 0.25, seed=seed, maximize=False),)
  if app == "mst":
    return (graphs.undirected_weighted(n, 0.3, seed=seed),)
  if app == "gtc":
    return (graphs.boolean_digraph(n, 0.03, seed=seed),)
  if app == "knn":
    ref, qry = graphs.knn_points(n, max(32, n // 8), 64, seed=seed)
    return (ref, qry)
  raise KeyError(app)


_BASE = {"apsp": bl.apsp_np, "aplp": bl.aplp_np, "mcp": bl.maxcp_np,
         "maxrp": bl.maxrp_np, "minrp": bl.minrp_np,
         "mst": lambda w: bl.minimax_paths_np(w),
         "gtc": bl.gtc_np, "knn": lambda r, q: bl.knn_np(r, q, 8)}


def _simd2_fn(app):
  if app == "knn":
    return lambda r, q: sv.knn(r, q, k=8)
  solver = sv.ALL_APPS[app]
  return lambda *xs: solver(*xs)[0]


_APP_OP = {"apsp": "minplus", "aplp": "maxplus", "mcp": "maxmin",
           "maxrp": "maxmul", "minrp": "minmul", "mst": "minmax",
           "gtc": "orand", "knn": "addnorm"}


def run(sizes=("small", "medium", "large"), iters=2):
  from benchmarks.common import modeled_speedup
  rows = []
  import time
  for size in sizes:
    sp_no_unit, sp_unit = [], []
    for app, ns in BENCH_SIZES.items():
      n = ns[size]
      inp = _inputs(app, n)
      t0 = time.perf_counter()
      _BASE[app](*inp)
      t_base = time.perf_counter() - t0
      fn = _simd2_fn(app)
      t_simd2 = timeit(lambda: fn(*inp), iters=iters)
      s = t_base / t_simd2
      # with-units arm: the op's ⊕⊗ contraction speeds up by the unit gain
      unit_gain = modeled_speedup(_APP_OP[app], n, n, n)
      s_unit = t_base / (t_simd2 / unit_gain)
      sp_no_unit.append(s)
      sp_unit.append(s_unit)
      rows.append(csv_row(
          f"fig11/{app}/{size}(n={n})", t_simd2 * 1e6,
          f"no_units_x{s:.2f};with_units_modeled_x{s_unit:.2f}"))
    rows.append(csv_row(
        f"fig11/gmean/{size}", 0.0,
        f"no_units_x{gmean(sp_no_unit):.2f};"
        f"with_units_modeled_x{gmean(sp_unit):.2f}"))
  return rows


def main():
  for r in run():
    print(r)


if __name__ == "__main__":
  main()

"""Benchmark utilities: wall-clock timing (CPU host) + TPU roofline model.

Two speedup columns appear throughout, mirroring the paper's method under
our hardware substitution (DESIGN.md §2):
  * measured — CPU wall time of the two program arms (both XLA-compiled);
  * modeled  — v5e roofline ratio of the 'vector-unit' arm vs the
    'SIMD²-unit' arm, using the MXU:VPU throughput gap (×16) and the
    paper's observed structural-hazard factor for min/max / or-and pairs
    (two same-port VPU ops per element → ×2).
"""
from __future__ import annotations

import time

import jax
import numpy as np

from repro.roofline import hw


def timeit(fn, *args, warmup: int = 1, iters: int = 3) -> float:
  """Best-of wall time in seconds (fn must return jax arrays)."""
  for _ in range(warmup):
    jax.block_until_ready(fn(*args))
  best = float("inf")
  for _ in range(iters):
    t0 = time.perf_counter()
    jax.block_until_ready(fn(*args))
    best = min(best, time.perf_counter() - t0)
  return best


def modeled_speedup(op: str, m: int, k: int, n: int,
                    dtype_bytes: int = 2) -> float:
  """v5e model: SIMD²-unit arm runs the ⊕⊗-contraction at MXU-class
  throughput; the vector arm runs it on the VPU (peak/16) with a structural
  port hazard for fused min/max / or/and pairs (hw.vpu_hazard — shared with
  the dispatch cost prior).  Both arms pay the same HBM traffic, so the
  ratio is evaluated at the roofline knee."""
  flops = 2.0 * m * k * n
  bytes_ = dtype_bytes * (m * k + k * n + 4 * m * n)
  t_mem = bytes_ / hw.HBM_BW
  t_unit = max(flops / hw.PEAK_FLOPS_BF16, t_mem)
  t_vpu = max(flops * hw.vpu_hazard(op) / (hw.PEAK_FLOPS_BF16 * hw.VPU_RATIO),
              t_mem)
  return t_vpu / t_unit


def gmean(xs) -> float:
  xs = np.asarray(list(xs), dtype=np.float64)
  return float(np.exp(np.mean(np.log(xs))))


def csv_row(name: str, us: float, derived: str = "") -> str:
  return f"{name},{us:.1f},{derived}"

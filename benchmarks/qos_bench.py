"""QoS benchmark: deadline traffic under bulk interference, FIFO vs deadline
policy, adaptive vs static predictions under the service-time batch cap,
plus admission bounding and the scheduler pick microbench.

    PYTHONPATH=src python benchmarks/qos_bench.py [--out BENCH_qos.json]
    PYTHONPATH=src python benchmarks/qos_bench.py --smoke   # CI-sized

Four experiments land in one JSON perf-trajectory artifact:

  interference — a burst of bulk closure requests is submitted ahead of a
      trickle of small deadline-tagged problems (the latency-sensitive
      slice).  Both engines are prewarmed (no compile time in the numbers).
      Under FIFO the deadline slice waits behind every older bulk batch;
      under the deadline policy it is served first.  The artifact records
      p50/p99 per class per policy and asserts the headline claim: deadline
      policy p99 for deadline traffic >= 2x better than FIFO.

  adaptive — the live-feedback claim: two identically configured engines
      (deadline policy, service-time batch cap ``max_batch_seconds``) serve
      an urgent deadline-tagged trickle against a sustained bulk closure
      stream on the background loop.  The *static* engine prices the cap
      with cost-table/roofline predictions — on CPU those are orders of
      magnitude optimistic, so the cap never binds and each urgent arrival
      waits behind a full max_batch bulk batch.  The *adaptive* engine's
      EWMA estimator has learned real batch latency, the cap binds, bulk
      batches stay short, and urgent p99 drops.  Asserted: adaptive p99 >=
      1.5x better, with zero steady-state retraces in the measured window.

  admission — the same bulk burst thrown at an engine with ``max_queue``:
      queue depth stays at the cap, the overflow is rejected at submit (not
      queued forever), and everything admitted completes.  Asserted.

  pick_bench — scheduler bucket-pick cost vs bucket diversity: the lazy-heap
      picker (serve_mmo/policy.py) against the O(buckets) linear scan it
      replaced, at 16 / 256 / 1024 distinct buckets.  The heap's per-pick
      cost stays flat while the scan grows with diversity.
"""
from __future__ import annotations

import argparse
import json
import os
import sys
import time

import numpy as np

# script-mode friendliness: `python benchmarks/qos_bench.py` puts only
# benchmarks/ on sys.path — add the repo root so repro.* resolves via src
_ROOT = os.path.dirname(os.path.dirname(os.path.abspath(__file__)))
for _p in (_ROOT, os.path.join(_ROOT, "src")):
  if _p not in sys.path:
    sys.path.insert(0, _p)

RNG = np.random.default_rng(0)


def _mmo_req(n, **qos):
  from repro.serve_mmo import mmo_request
  a = RNG.standard_normal((n, n)).astype(np.float32)
  b = RNG.standard_normal((n, n)).astype(np.float32)
  return mmo_request(a, b, op="mma", **qos)


def _bulk_req(n, seed, **qos):
  from repro.apps import graphs
  from repro.serve_mmo import apsp_request
  return apsp_request(graphs.weighted_digraph(n, 0.3, seed=seed),
                      tenant="bulk", **qos)


def interference(policy: str, *, bulk_n: int, bulk_count: int,
                 urgent_count: int, max_batch: int = 4) -> dict:
  """Latency percentiles per traffic class for one policy."""
  from repro.serve_mmo import MMOEngine
  eng = MMOEngine(backend="xla", max_batch=max_batch, policy=policy)
  eng.prewarm([_bulk_req(bulk_n, seed=0), _mmo_req(12)])
  t0 = time.perf_counter()
  bulk = [eng.submit(_bulk_req(bulk_n - (i % 3), seed=i))
          for i in range(bulk_count)]
  urgent = [eng.submit(_mmo_req(12, deadline_s=120.0, priority=1,
                                tenant="interactive"))
            for _ in range(urgent_count)]
  eng.run_until_idle()
  wall = time.perf_counter() - t0
  assert all(f.state == "done" for f in bulk + urgent), "a request failed"
  recs = {r.request_id: r for r in eng._records}

  def pcts(futs):
    lat = [recs[f.request.request_id].latency_s for f in futs]
    return {"p50_ms": float(np.percentile(lat, 50)) * 1e3,
            "p99_ms": float(np.percentile(lat, 99)) * 1e3}

  return {"policy": policy, "wall_s": wall,
          "deadline_traffic": pcts(urgent), "bulk_traffic": pcts(bulk)}


def adaptive_interference(*, bulk_n: int, bulk_count: int, urgent_count: int,
                          max_batch: int = 8) -> dict:
  """Urgent p99 under a sustained bulk stream: static vs adaptive
  predictions feeding the same service-time batch cap."""
  from repro.serve_mmo import MMOEngine
  from repro.serve_mmo.scheduler import request_bucket

  def build(adaptive, cap):
    eng = MMOEngine(backend="xla", max_batch=max_batch, policy="deadline",
                    adaptive=adaptive, max_batch_seconds=cap,
                    deadline_lookback_s=60.0)
    eng.prewarm([_bulk_req(bulk_n, seed=0), _mmo_req(12)])
    # feedback warmup: mixed waves so the estimator's (bucket, backend,
    # schedule) cells pass min_observations and the first *execution* of
    # every batch size the measured window will replay (bulk rb=1 under
    # the cap, rb=2, urgent rb=1) is out of the measured numbers
    for wave in range(4):
      for j in range(1 + wave % 2):
        eng.submit(_bulk_req(bulk_n, seed=100 + 4 * wave + j))
      eng.submit(_mmo_req(12, deadline_s=60.0, priority=1,
                          tenant="interactive"))
      eng.run_until_idle()
    eng.reset_stats()
    return eng

  # calibrate the cap from measured reality so the experiment is
  # machine-independent: ~1.6x one bulk request's measured service time,
  # i.e. the cap wants single-request bulk batches while urgents flow.
  # The estimator *records* on static engines too — only predictions
  # differ — so the calibration engine can be the static build.
  cal = build(adaptive=False, cap=None)
  bulk_key = request_bucket(_bulk_req(bulk_n, seed=0))
  backend, _ = cal.resolve_backend(bulk_key)
  per_req = cal.estimator.predict(bulk_key, backend, "local", 0.0, 1.0)
  assert per_req.source == "ewma", "calibration estimator never warmed"
  cap = 1.6 * per_req.seconds

  def run(adaptive):
    eng = build(adaptive, cap)
    static_pred = eng.predict_request_seconds(bulk_key)
    misses_before = eng.cache.misses
    bulk = [eng.submit(_bulk_req(bulk_n - (i % 3), seed=i))
            for i in range(bulk_count)]
    eng.start()
    urgent = []
    for i in range(urgent_count):
      # pace urgents so each lands mid-bulk-batch, and replenish the bulk
      # stream so backlog pressure is sustained across the whole window
      time.sleep(3.0 * per_req.seconds)
      urgent.append(eng.submit(_mmo_req(12, deadline_s=30.0, priority=1,
                                        tenant="interactive")))
      bulk.append(eng.submit(_bulk_req(bulk_n, seed=1000 + i)))
      bulk.append(eng.submit(_bulk_req(bulk_n - 1, seed=2000 + i)))
    for f in urgent:
      f.result(timeout=300)
    eng.stop()  # drains the remaining bulk
    assert all(f.state == "done" for f in bulk + urgent), "a request failed"
    recompiles = eng.cache.misses - misses_before
    recs = {r.request_id: r for r in eng._records}
    lat = [recs[f.request.request_id].latency_s for f in urgent]
    bulk_batches = [recs[f.request.request_id].batch_size for f in bulk]
    return {
        "adaptive": adaptive,
        "max_batch_seconds": cap,
        "bulk_pred_ms_per_request": static_pred * 1e3,
        "urgent_p50_ms": float(np.percentile(lat, 50)) * 1e3,
        "urgent_p99_ms": float(np.percentile(lat, 99)) * 1e3,
        "mean_bulk_batch": float(np.mean(bulk_batches)),
        "recompiles_measured_window": recompiles,
        "estimator": eng.estimator.snapshot(),
    }

  rows = {"static": run(adaptive=False), "adaptive": run(adaptive=True)}
  rows["p99_speedup_adaptive_vs_static"] = (
      rows["static"]["urgent_p99_ms"] / rows["adaptive"]["urgent_p99_ms"])
  rows["measured_bulk_ms_per_request"] = per_req.seconds * 1e3
  return rows


def admission(*, bulk_n: int, offered: int, max_queue: int) -> dict:
  """Queue depth stays at the cap; overflow rejects instead of queueing."""
  from repro.serve_mmo import MMOEngine
  eng = MMOEngine(backend="xla", max_batch=4, max_queue=max_queue)
  eng.prewarm([_bulk_req(bulk_n, seed=0)])
  futs = [eng.submit(_bulk_req(bulk_n, seed=i)) for i in range(offered)]
  depth_at_burst = len(eng.scheduler)
  eng.run_until_idle()
  st = eng.stats()
  row = {"offered": offered, "max_queue": max_queue,
         "depth_at_burst": depth_at_burst,
         "admitted": sum(f.state != "rejected" for f in futs),
         "rejected": st.rejected, "completed": st.completed}
  assert depth_at_burst <= max_queue, row
  assert st.rejected == offered - max_queue, row
  assert st.completed == max_queue, row
  return row


def pick_bench(bucket_counts=(16, 256, 1024), picks: int = 2000) -> list:
  """ns/pick for the lazy-heap picker vs the linear scan it replaced.

  Pure scheduler work — requests are tiny and never execute.  Each bucket
  holds enough entries that picks never exhaust the queue mid-measurement.
  """
  from repro.serve_mmo import ProblemRequest
  from repro.serve_mmo.scheduler import FifoBucketScheduler

  def fill(sched, n_buckets, per_bucket):
    a = np.zeros((4, 4), np.float32)
    for i in range(n_buckets):
      for _ in range(per_bucket):
        sched.add(ProblemRequest(kind="mmo", op="mma",
                                 arrays={"a": a, "b": a}, shape=(4, 4, 4),
                                 params=(False, "pickbench", i)))

  def linear_next(sched):  # the pre-heap implementation, kept for comparison
    best_key, best_seq = None, None
    for key, q in sched._buckets.items():
      if q and (best_seq is None or q[0].seq < best_seq):
        best_key, best_seq = key, q[0].seq
    return best_key

  rows = []
  for n_buckets in bucket_counts:
    per_bucket = max(2, picks // n_buckets + 2)
    sched = FifoBucketScheduler(max_batch=1)
    fill(sched, n_buckets, per_bucket)
    t0 = time.perf_counter()
    for _ in range(picks):
      sched.next_batch()
    heap_ns = (time.perf_counter() - t0) / picks * 1e9

    sched = FifoBucketScheduler(max_batch=1)
    fill(sched, n_buckets, per_bucket)
    t0 = time.perf_counter()
    for _ in range(picks):
      linear_next(sched)
    linear_ns = (time.perf_counter() - t0) / picks * 1e9

    rows.append({"buckets": n_buckets, "heap_ns_per_pick": heap_ns,
                 "linear_scan_ns_per_pick": linear_ns})
  return rows


def main(argv=None):
  ap = argparse.ArgumentParser()
  ap.add_argument("--out", default="BENCH_qos.json")
  ap.add_argument("--smoke", action="store_true",
                  help="CI-sized: small bulk problems, few requests")
  ap.add_argument("--bulk-n", type=int, default=None,
                  help="bulk closure problem size (default 48; smoke 24)")
  ap.add_argument("--bulk-count", type=int, default=None)
  ap.add_argument("--urgent-count", type=int, default=None)
  args = ap.parse_args(argv)

  bulk_n = args.bulk_n or (24 if args.smoke else 48)
  bulk_count = args.bulk_count or (8 if args.smoke else 16)
  urgent_count = args.urgent_count or (6 if args.smoke else 12)

  rows = {p: interference(p, bulk_n=bulk_n, bulk_count=bulk_count,
                          urgent_count=urgent_count)
          for p in ("fifo", "deadline")}
  for p, row in rows.items():
    d, b = row["deadline_traffic"], row["bulk_traffic"]
    print(f"[qos_bench] policy={p:9s} deadline-traffic "
          f"p50={d['p50_ms']:8.1f}ms p99={d['p99_ms']:8.1f}ms | bulk "
          f"p50={b['p50_ms']:8.1f}ms p99={b['p99_ms']:8.1f}ms")
  fifo_p99 = rows["fifo"]["deadline_traffic"]["p99_ms"]
  ddl_p99 = rows["deadline"]["deadline_traffic"]["p99_ms"]
  speedup = fifo_p99 / ddl_p99
  print(f"[qos_bench] deadline-policy p99 {speedup:.1f}x better than FIFO "
        f"for deadline traffic under bulk interference")

  # the adaptive experiment needs bulk batches whose cost scales ~linearly
  # with occupancy (compute-dominated bucket), so it sizes independently of
  # --bulk-n: n=72 pads to the 128 closure bucket
  ada = adaptive_interference(bulk_n=72,
                              bulk_count=12 if args.smoke else 16,
                              urgent_count=10 if args.smoke else 16)
  for name in ("static", "adaptive"):
    row = ada[name]
    print(f"[qos_bench] predictions={name:8s} urgent "
          f"p50={row['urgent_p50_ms']:7.1f}ms p99={row['urgent_p99_ms']:7.1f}ms"
          f" | mean bulk batch={row['mean_bulk_batch']:.2f} "
          f"pred={row['bulk_pred_ms_per_request']:.4f}ms/req "
          f"recompiles={row['recompiles_measured_window']}")
  ada_speedup = ada["p99_speedup_adaptive_vs_static"]
  print(f"[qos_bench] adaptive predictions p99 {ada_speedup:.1f}x better than "
        f"static under the same max_batch_seconds="
        f"{ada['static']['max_batch_seconds'] * 1e3:.1f}ms cap "
        f"(measured bulk {ada['measured_bulk_ms_per_request']:.1f}ms/req)")

  adm = admission(bulk_n=bulk_n, offered=bulk_count + 8,
                  max_queue=bulk_count // 2)
  print(f"[qos_bench] admission: offered={adm['offered']} "
        f"cap={adm['max_queue']} depth_at_burst={adm['depth_at_burst']} "
        f"rejected={adm['rejected']} completed={adm['completed']}")

  picks = pick_bench(bucket_counts=(16, 64) if args.smoke
                     else (16, 256, 1024))
  for r in picks:
    print(f"[qos_bench] pick: buckets={r['buckets']:5d} "
          f"heap={r['heap_ns_per_pick']:8.0f}ns "
          f"linear={r['linear_scan_ns_per_pick']:8.0f}ns")

  doc = {
      "schema": 1,
      "smoke": bool(args.smoke),
      "bulk_n": bulk_n,
      "bulk_count": bulk_count,
      "urgent_count": urgent_count,
      "interference": rows,
      "deadline_p99_speedup_vs_fifo": speedup,
      "adaptive": ada,
      "admission": adm,
      "pick_bench": picks,
  }
  with open(args.out, "w") as f:
    json.dump(doc, f, indent=2)
  print(f"[qos_bench] wrote {args.out}")

  assert speedup >= 2.0, (
      f"deadline policy p99 only {speedup:.2f}x better than FIFO "
      f"({ddl_p99:.1f}ms vs {fifo_p99:.1f}ms) — expected >= 2x")
  assert ada_speedup >= 1.5, (
      f"adaptive predictions p99 only {ada_speedup:.2f}x better than static "
      f"({ada['adaptive']['urgent_p99_ms']:.1f}ms vs "
      f"{ada['static']['urgent_p99_ms']:.1f}ms) under the batch cap — "
      f"expected >= 1.5x")
  for name in ("static", "adaptive"):
    assert ada[name]["recompiles_measured_window"] == 0, (
        f"{name} run recompiled mid-measurement: "
        f"{ada[name]['recompiles_measured_window']} misses")
  return 0


if __name__ == "__main__":
  raise SystemExit(main())

"""Arena benchmark: slot-based continuous batching vs bucket-cycle batching.

    PYTHONPATH=src python benchmarks/arena_bench.py [--requests 150]

One closure-only request stream (ragged APSP instances in a single shape
bucket) is replayed OPEN-LOOP — arrivals follow a Poisson process whose
rate does not react to the server, the regime where batching policy shows
up in tail latency — against two engines serving in the background:

  batch  — mode="batch": the per-iteration bucket-cycle path.  A request
           arriving just after a batch launches waits out the ENTIRE
           remaining fixpoint of the running cohort, then joins the next
           stack; every distinct cohort size replays a different pow2
           executable.
  arena  — mode="arena": requests are admitted into free slots of the
           device-resident buffer at the next tick boundary (≤ g fused
           iterations away) and evicted individually at convergence.

Reported per arm: completed/s and p50/p99 end-to-end latency (arrival →
future completion), plus the steady-state retrace count after a prewarmed
warmup pass — asserted ZERO for the arena (its three slot programs take
traced slot/n scalars, so no admission mix can force a recompile) while
the batch arm is allowed its pow2 cohort ladder.  Results land in
BENCH_arena.json; README's "Continuous batching" section quotes them.
"""
from __future__ import annotations

import argparse
import json
import time

import numpy as np

from repro.apps import graphs
from repro.serve_mmo import MMOEngine, apsp_request


def make_stream(n_requests: int, *, nmin: int, nmax: int, seed: int = 0):
  """Ragged single-bucket APSP stream (bellman_ford: the long fixpoint,
  where mid-flight admission has the most tail latency to win back)."""
  rng = np.random.default_rng(seed)
  reqs = []
  for _ in range(n_requests):
    n = int(rng.integers(nmin, nmax + 1))
    w = graphs.weighted_digraph(n, 0.3, seed=int(rng.integers(0, 2 ** 31)))
    reqs.append(apsp_request(w, algorithm="bellman_ford"))
  return reqs


def poisson_offsets(n: int, rate_hz: float, seed: int = 1):
  rng = np.random.default_rng(seed)
  return np.cumsum(rng.exponential(1.0 / rate_hz, n))


def _percentiles(lat):
  lat = np.asarray(lat, dtype=np.float64)
  return (float(np.percentile(lat, 50)) * 1e3,
          float(np.percentile(lat, 99)) * 1e3)


def run_open_loop(engine: MMOEngine, stream, offsets):
  """Submit each request at its Poisson arrival time against the running
  background loop; latency is arrival → completion (queue + service), read
  from the engine's per-request records — both stamps on the engine clock,
  so the measurement doesn't depend on when this thread polls futures."""
  engine.start()
  try:
    t0 = time.perf_counter()
    futs = []
    for req, dt in zip(stream, offsets):
      now = time.perf_counter() - t0
      if dt > now:
        time.sleep(dt - now)
      futs.append(engine.submit(req))
    for fut in futs:
      fut.result()
    wall = time.perf_counter() - t0
  finally:
    engine.stop(drain=True)
  lat = [r.completed_s - r.arrival_s for r in engine._records[-len(stream):]]
  return wall, lat


def bench_arm(label, stream, offsets, *, make_engine, verbose=True):
  # warmup pass (closed-loop is fine: it populates the executable cache the
  # same way) so the measured pass prices steady state, not compiles
  engine = make_engine()
  engine.prewarm(stream)
  for f in [engine.submit(r) for r in stream[:8]]:
    f.result()
  engine.run_until_idle()
  engine.reset_stats()
  misses0 = engine.cache.misses

  wall, lat = run_open_loop(engine, stream, offsets)
  retraces = engine.cache.misses - misses0
  p50, p99 = _percentiles(lat)
  if verbose:
    print(f"[arena_bench] {label:6s}: {len(lat) / wall:7.1f} completed/s  "
          f"p50={p50:7.1f}ms  p99={p99:7.1f}ms  wall={wall:.2f}s  "
          f"(steady-state retraces: {retraces})")
  return {"wall_s": wall, "completed": len(lat), "p50_ms": p50,
          "p99_ms": p99, "retraces": retraces}


def main(argv=None):
  ap = argparse.ArgumentParser()
  ap.add_argument("--requests", type=int, default=150)
  ap.add_argument("--rate", type=float, default=500.0,
                  help="open-loop Poisson arrival rate (req/s)")
  ap.add_argument("--nmin", type=int, default=33)
  ap.add_argument("--nmax", type=int, default=48)
  ap.add_argument("--capacity", type=int, default=8)
  ap.add_argument("--g", type=int, default=4)
  ap.add_argument("--max-batch", type=int, default=8)
  ap.add_argument("--backend", default="xla")
  ap.add_argument("--seed", type=int, default=0)
  ap.add_argument("--out", default="BENCH_arena.json", metavar="PATH",
                  help="write both arms' numbers to PATH as JSON "
                       "('' disables)")
  args = ap.parse_args(argv)

  stream = make_stream(args.requests, nmin=args.nmin, nmax=args.nmax,
                       seed=args.seed)
  offsets = poisson_offsets(len(stream), args.rate, seed=args.seed + 1)

  batch = bench_arm(
      "batch", stream, offsets,
      make_engine=lambda: MMOEngine(backend=args.backend,
                                    max_batch=args.max_batch))
  arena = bench_arm(
      "arena", stream, offsets,
      make_engine=lambda: MMOEngine(backend=args.backend, mode="arena",
                                    arena_capacity=args.capacity,
                                    arena_g=args.g))

  print(f"[arena_bench] p99 ratio batch/arena: "
        f"{batch['p99_ms'] / max(arena['p99_ms'], 1e-9):.2f}x  "
        f"retraces: batch={batch['retraces']} arena={arena['retraces']}")

  if args.out:
    doc = {
        "requests": len(stream), "rate_hz": args.rate,
        "bucket_n": [args.nmin, args.nmax],
        "arena_capacity": args.capacity, "arena_g": args.g,
        "max_batch": args.max_batch, "backend": args.backend,
        "batch": batch, "arena": arena,
        "p99_ratio_batch_over_arena": batch["p99_ms"] / max(arena["p99_ms"],
                                                            1e-9),
    }
    with open(args.out, "w", encoding="utf-8") as f:
      json.dump(doc, f, indent=2)
    print(f"[arena_bench] wrote {args.out}")

  assert arena["retraces"] == 0, (
      f"arena steady state retraced {arena['retraces']}x — the slot "
      f"programs must absorb any admission mix after prewarm")
  assert arena["completed"] == len(stream)
  assert batch["completed"] == len(stream)
  return 0


if __name__ == "__main__":
  raise SystemExit(main())

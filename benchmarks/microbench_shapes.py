"""Fig 10: microbenchmark, non-square shapes (tall/wide/deep contractions)."""
from __future__ import annotations

import numpy as np
import jax.numpy as jnp

from benchmarks.common import csv_row, gmean, modeled_speedup, timeit
from repro.core.mmo import mmo

SHAPES = (
    (2048, 256, 2048),   # shallow K
    (256, 4096, 256),    # deep K
    (4096, 512, 128),    # tall
    (128, 512, 4096),    # wide
)
OPS = ("mma", "minplus", "maxmin", "orand", "addnorm")


def run(shapes=SHAPES, ops=OPS, iters=3):
  rng = np.random.default_rng(1)
  rows = []
  for (m, k, n) in shapes:
    models = []
    for op in ops:
      a = rng.standard_normal((m, k)).astype(np.float32)
      b = rng.standard_normal((k, n)).astype(np.float32)
      if op == "orand":
        a, b = a > 1.2, b > 1.2
      aj, bj = jnp.asarray(a), jnp.asarray(b)
      t_vec = timeit(lambda: mmo(aj, bj, op=op, backend="vector"),
                     iters=iters)
      t_xla = timeit(lambda: mmo(aj, bj, op=op, backend="xla"), iters=iters)
      model = modeled_speedup(op, m, k, n)
      models.append(model)
      rows.append(csv_row(f"fig10/{op}/{m}x{k}x{n}", t_xla * 1e6,
                          f"measured_x{t_vec / t_xla:.2f};modeled_x{model:.2f}"))
    rows.append(csv_row(f"fig10/gmean/{m}x{k}x{n}", 0.0,
                        f"modeled_gmean_x{gmean(models):.2f}"))
  return rows


def main():
  for r in run():
    print(r)


if __name__ == "__main__":
  main()

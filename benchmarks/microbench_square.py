"""Fig 9: microbenchmark, square matrices — 9 SIMD² ops × sizes.

Arms: 'vector' backend (SIMD²-w/-CUDA-cores analogue) vs 'xla' backend
(SIMD²-unit analogue: MXU rewrites + blocked contraction).  Reports measured
CPU speedup and the v5e-modeled speedup (see benchmarks/common.py).
Paper reference: gain saturating ≈10× at ≥4096², up to 15.8× for
min-max/max-min/or-and, ≈3.1× for mma/addnorm.
"""
from __future__ import annotations

import numpy as np
import jax.numpy as jnp

from benchmarks.common import csv_row, gmean, modeled_speedup, timeit
from repro.core import ALL_OPS
from repro.core.mmo import mmo

SIZES = (256, 512, 1024)


def run(sizes=SIZES, ops=ALL_OPS, iters=3):
  rng = np.random.default_rng(0)
  rows = []
  for n in sizes:
    speedups = []
    for op in ops:
      a = rng.standard_normal((n, n)).astype(np.float32)
      b = rng.standard_normal((n, n)).astype(np.float32)
      if op == "orand":
        a, b = a > 1.2, b > 1.2
      aj, bj = jnp.asarray(a), jnp.asarray(b)
      t_vec = timeit(lambda: mmo(aj, bj, op=op, backend="vector"),
                     iters=iters)
      t_xla = timeit(lambda: mmo(aj, bj, op=op, backend="xla"), iters=iters)
      meas = t_vec / t_xla
      model = modeled_speedup(op, n, n, n)
      speedups.append(model)
      rows.append(csv_row(f"fig9/{op}/{n}", t_xla * 1e6,
                          f"measured_x{meas:.2f};modeled_x{model:.2f}"))
    rows.append(csv_row(f"fig9/gmean/{n}", 0.0,
                        f"modeled_gmean_x{gmean(speedups):.2f}"))
  return rows


def main():
  for r in run():
    print(r)


if __name__ == "__main__":
  main()

"""Fig 12: algorithmic-optimization ablation — Leyzorek ± convergence vs
all-pairs Bellman-Ford, on APSP/APLP/MCP (paper: Leyzorek lg|V| beats AP-BF
|V|; convergence checks are input-sensitive but win on real diameters)."""
from __future__ import annotations

import jax
import numpy as np

from benchmarks.common import csv_row, timeit
from repro.apps import graphs
from repro.apps import solvers as sv

N = 512
APPS = {
    "apsp": lambda: graphs.weighted_digraph(N, 0.15, seed=3),
    "aplp": lambda: graphs.dag(N, 0.15, seed=4),
    "mcp": lambda: graphs.capacity_graph(N, 0.15, seed=5),
}


def run(iters=2):
  rows = []
  for app, gen in APPS.items():
    w = gen()
    solver = sv.ALL_APPS[app]
    arms = {
        "leyzorek+conv": dict(algorithm="leyzorek", convergence=True),
        "leyzorek": dict(algorithm="leyzorek", convergence=False),
        "apbf+conv": dict(algorithm="bellman_ford", convergence=True),
        "apbf": dict(algorithm="bellman_ford", convergence=False,
                     max_iters=min(N, 64)),  # |V| iters clipped for wallclock
    }
    for name, kw in arms.items():
      out, it = solver(w, **kw)
      t = timeit(lambda: solver(w, **kw)[0], iters=iters)
      rows.append(csv_row(f"fig12/{app}/{name}", t * 1e6,
                          f"iters={int(it)}"))
  return rows


def main():
  for r in run():
    print(r)


if __name__ == "__main__":
  main()

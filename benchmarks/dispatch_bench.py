"""Dispatch benchmark: measured cost-table auto-dispatch vs fixed backends.

    PYTHONPATH=src python benchmarks/dispatch_bench.py [--out BENCH_dispatch.json]

Two experiments, results persisted to a JSON perf-trajectory artifact:

  dispatch — per op family, wall time of a small shape sweep under each
             *fixed* backend vs ``backend="auto"`` driven by a cost table
             measured on this very device moments earlier.  Auto must hold a
             ≥1.2× geomean over the worst fixed backend: that is the whole
             point of dispatch — no single backend is safe to pin across op
             families (the MXU rewrites crush 'vector' on mma/addnorm/orand;
             the min/max rings don't care).
  ragged   — one mixed-size closure bucket (line graphs iterate ~n times,
             the big dense graph converges almost immediately), padded vs
             ragged masked-K execution (per-request ``valid_n`` + converged
             requests dropping to k_valid=0).  Ragged must beat padded:
             after the big request converges, every surviving iteration
             contracts ~ceil(n_straggler/bk) K-blocks instead of the full
             padded bucket.
  fixpoint — per-iteration dispatch vs the fused Pallas megakernel on mixed
             leyzorek closure buckets, for chunk lengths G ∈ {2, 4, 8}.
             Fusing keeps the iterate in VMEM across G squarings: HBM sees
             each request once per chunk instead of once per iteration, and
             the host issues one program per chunk instead of one per
             squaring.  Outputs and iteration counts are asserted
             bit-identical to the reference before anything is timed.  The
             ≥1.3× win is asserted on TPU only — CPU runs the kernel in
             interpret mode, which emulates the grid step-by-step in Python
             and cannot exhibit the dispatch/bandwidth saving being
             measured (the JSON carries a ``platform_note`` saying so).
"""
from __future__ import annotations

import argparse
import json
import time

import numpy as np

import jax
import jax.numpy as jnp

from benchmarks.common import gmean, timeit
from repro.core.closure import (batched_bellman_ford_closure,
                                batched_leyzorek_closure,
                                pad_adjacency, prepare_adjacency)
from repro.core.mmo import mmo
from repro.tuning import tune, use_cost_table

# One representative shape pair per op family: big enough that backend choice
# matters, small enough for a CPU host.
FAMILY_SHAPES = ((128, 128, 128), (64, 256, 64))
FAMILIES = ("mma", "addnorm", "orand", "minplus", "maxmin")


def _operands(op, shape, seed=0):
  from repro.tuning.autotune import _operands as _tune_operands
  a, b = _tune_operands(op, shape, "float32", seed=seed)
  return jnp.asarray(a), jnp.asarray(b)


def bench_dispatch(backends, *, iters=3):
  """{family: {fixed: {backend: s}, auto: s, worst_fixed: s, speedups}}."""
  table = tune(ops=FAMILIES, shapes=FAMILY_SHAPES, backends=backends,
               iters=iters)
  out = {}
  for op in FAMILIES:
    arms = {}
    for backend in backends:
      arms[backend] = sum(
          timeit(lambda a=a, b=b, bk=backend: mmo(a, b, op=op, backend=bk),
                 iters=iters)
          for a, b in (_operands(op, s) for s in FAMILY_SHAPES))
    with use_cost_table(table):
      auto = sum(
          timeit(lambda a=a, b=b: mmo(a, b, op=op, backend="auto"),
                 iters=iters)
          for a, b in (_operands(op, s) for s in FAMILY_SHAPES))
    worst = max(arms.values())
    out[op] = {
        "fixed_s": arms,
        "auto_s": auto,
        "worst_fixed_s": worst,
        "speedup_vs_worst_fixed": worst / auto,
        "speedup_vs_best_fixed": min(arms.values()) / auto,
    }
  return out


def _line_graph(n, seed=0):
  """Path graph i→i+1: diameter n−1, so Bellman-Ford iterates ~n times —
  the straggler that keeps a mixed bucket alive."""
  rng = np.random.default_rng(seed)
  w = np.full((n, n), np.inf, np.float32)
  w[np.arange(n - 1), np.arange(1, n)] = rng.uniform(
      0.5, 1.5, n - 1).astype(np.float32)
  return w


def _dense_graph(n, seed=0):
  """Dense random digraph: tiny diameter, converges in a few iterations."""
  rng = np.random.default_rng(seed)
  w = rng.uniform(0.5, 1.5, (n, n)).astype(np.float32)
  w[rng.random((n, n)) > 0.5] = np.inf
  return w


def bench_ragged(*, nb=128, stragglers=(65, 66, 68, 70, 72, 74, 76),
                 iters=3):
  """Padded vs ragged masked-K on one mixed-size closure bucket."""
  sizes = list(stragglers) + [nb]
  ws = [_line_graph(n, seed=n) for n in stragglers] + [_dense_graph(nb)]
  prepared = [prepare_adjacency(jnp.asarray(w), op="minplus") for w in ws]
  stack = jnp.stack([pad_adjacency(p, nb, op="minplus") for p in prepared])
  valid = jnp.asarray(sizes, jnp.int32)

  padded_s = timeit(
      lambda: batched_bellman_ford_closure(stack, op="minplus",
                                           backend="xla")[0], iters=iters)
  ragged_s = timeit(
      lambda: batched_bellman_ford_closure(stack, op="minplus", backend="xla",
                                           valid_n=valid)[0], iters=iters)
  # parity: skipping dead K-blocks must not move the fixpoint
  out_p, it_p = batched_bellman_ford_closure(stack, op="minplus",
                                             backend="xla")
  out_r, it_r = batched_bellman_ford_closure(stack, op="minplus",
                                             backend="xla", valid_n=valid)
  np.testing.assert_allclose(np.asarray(out_r), np.asarray(out_p), atol=1e-5)
  return {
      "bucket": nb,
      "sizes": sizes,
      "iterations": np.asarray(it_r).tolist(),
      "padded_s": padded_s,
      "ragged_s": ragged_s,
      "speedup": padded_s / ragged_s,
  }


def bench_fixpoint(*, buckets=(32, 64), gs=(2, 4, 8), iters=3):
  """Per-iteration dispatch vs the fused on-chip fixpoint, per bucket size.

  Each bucket mixes line-graph stragglers with a dense fast-converger (the
  serving-realistic shape: ragged sizes, ragged convergence) and runs the
  leyzorek squaring closure.  Every megakernel arm is parity-checked
  bit-for-bit — outputs AND per-request iteration counts — against the
  per-iteration dispatch reference before its wall time counts."""
  platform = jax.default_backend()
  out = {}
  for nb in buckets:
    stragglers = (nb // 2 + 1, nb // 2 + 3)
    sizes = list(stragglers) + [nb]
    ws = [_line_graph(n, seed=n) for n in stragglers] + [_dense_graph(nb)]
    prepared = [prepare_adjacency(jnp.asarray(w), op="minplus") for w in ws]
    stack = jnp.stack([pad_adjacency(p, nb, op="minplus") for p in prepared])
    valid = jnp.asarray(sizes, jnp.int32)

    ref_out, ref_it = batched_leyzorek_closure(stack, op="minplus",
                                               backend="xla", valid_n=valid)
    dispatch_s = timeit(
        lambda: batched_leyzorek_closure(stack, op="minplus", backend="xla",
                                         valid_n=valid)[0], iters=iters)
    arms = {}
    for g in gs:
      mk_out, mk_it = batched_leyzorek_closure(
          stack, op="minplus", fixpoint_backend="megakernel", megakernel_g=g,
          valid_n=valid)
      np.testing.assert_array_equal(np.asarray(mk_out), np.asarray(ref_out))
      np.testing.assert_array_equal(np.asarray(mk_it), np.asarray(ref_it))
      arms[str(g)] = timeit(
          lambda g=g: batched_leyzorek_closure(
              stack, op="minplus", fixpoint_backend="megakernel",
              megakernel_g=g, valid_n=valid)[0], iters=iters)
    best_g, best_s = min(arms.items(), key=lambda kv: kv[1])
    out[str(nb)] = {
        "sizes": sizes,
        "iterations": np.asarray(ref_it).tolist(),
        "dispatch_s": dispatch_s,
        "megakernel_s": arms,
        "best_g": int(best_g),
        "speedup": dispatch_s / best_s,
    }
  doc = {"platform": platform, "buckets": out}
  if platform != "tpu":
    doc["platform_note"] = (
        "megakernel ran in Pallas interpret mode: the grid is emulated "
        "step-by-step in Python, so the fused arm cannot show the "
        "dispatch/HBM-traffic win it exists for.  Parity (bit-identical "
        "outputs and iteration counts) is still verified here; the >=1.3x "
        "speedup gate applies on TPU only.")
  return doc


def main(argv=None):
  ap = argparse.ArgumentParser()
  ap.add_argument("--out", default="BENCH_dispatch.json")
  ap.add_argument("--iters", type=int, default=3)
  ap.add_argument("--backends", default=None,
                  help="comma-separated fixed arms (default: xla,vector "
                       "plus pallas on TPU)")
  args = ap.parse_args(argv)

  if args.backends:
    backends = tuple(args.backends.split(","))
  else:
    # pallas-interpret on CPU is an emulation arm, not a serving option —
    # only sweep fixed backends this host can actually serve with
    from repro.tuning.autotune import default_backends
    backends = default_backends()
  # the dispatch experiment sweeps *contraction* arms; the fused fixpoint
  # arm is a closure program (mmo refuses it) and gets its own experiment
  backends = tuple(b for b in backends if b != "megakernel")

  dispatch = bench_dispatch(backends, iters=args.iters)
  for op, row in dispatch.items():
    fixed = "  ".join(f"{b}={s * 1e3:7.2f}ms" for b, s in
                      row["fixed_s"].items())
    print(f"[dispatch_bench] {op:8s} {fixed}  auto={row['auto_s'] * 1e3:7.2f}ms"
          f"  vs_worst={row['speedup_vs_worst_fixed']:5.2f}x"
          f"  vs_best={row['speedup_vs_best_fixed']:5.2f}x")
  geo_worst = gmean(r["speedup_vs_worst_fixed"] for r in dispatch.values())
  geo_best = gmean(r["speedup_vs_best_fixed"] for r in dispatch.values())
  print(f"[dispatch_bench] auto-dispatch geomean: {geo_worst:.2f}x vs worst "
        f"fixed backend, {geo_best:.2f}x vs best fixed backend")

  ragged = bench_ragged(iters=args.iters)
  print(f"[dispatch_bench] ragged closure bucket={ragged['bucket']} "
        f"sizes={ragged['sizes']}: padded={ragged['padded_s'] * 1e3:.1f}ms "
        f"ragged={ragged['ragged_s'] * 1e3:.1f}ms "
        f"({ragged['speedup']:.2f}x)")

  fixpoint = bench_fixpoint(iters=args.iters)
  for nb, row in fixpoint["buckets"].items():
    arms = "  ".join(f"G={g}:{s * 1e3:7.2f}ms"
                     for g, s in row["megakernel_s"].items())
    print(f"[dispatch_bench] fixpoint bucket={nb:>3s} "
          f"dispatch={row['dispatch_s'] * 1e3:7.2f}ms  {arms}  "
          f"best G={row['best_g']} ({row['speedup']:.2f}x)")
  if "platform_note" in fixpoint:
    print(f"[dispatch_bench] note: {fixpoint['platform_note']}")

  doc = {
      "schema": 2,
      "device": f"{jax.default_backend()}",
      "backends": list(backends),
      "dispatch": dispatch,
      "geomean_speedup_vs_worst_fixed": geo_worst,
      "geomean_speedup_vs_best_fixed": geo_best,
      "ragged": ragged,
      "fixpoint": fixpoint,
  }
  with open(args.out, "w") as f:
    json.dump(doc, f, indent=2)
  print(f"[dispatch_bench] wrote {args.out}")

  assert geo_worst >= 1.2, (
      f"auto-dispatch must hold >=1.2x geomean over the worst fixed backend, "
      f"got {geo_worst:.2f}x")
  assert ragged["speedup"] > 1.0, (
      f"ragged masked-K must beat padded on a mixed-size bucket, got "
      f"{ragged['speedup']:.2f}x")
  if fixpoint["platform"] == "tpu":
    best = max(r["speedup"] for r in fixpoint["buckets"].values())
    assert best >= 1.3, (
        f"fused fixpoint must beat per-iteration dispatch >=1.3x on at "
        f"least one bucket on TPU, got {best:.2f}x")
  return 0


if __name__ == "__main__":
  raise SystemExit(main())

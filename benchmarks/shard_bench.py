"""Sharded-serving benchmark: local vs distributed schedules on big buckets.

    XLA_FLAGS=--xla_force_host_platform_device_count=8 \
        PYTHONPATH=src python benchmarks/shard_bench.py [--out BENCH_shard.json]

(The driver re-execs itself with that flag when the host exposes fewer
devices than ``--devices``, so a bare ``python benchmarks/shard_bench.py``
works on a laptop CPU.)

One experiment per closure bucket size: a batch of ragged min-plus closure
requests (the serving engine's heaviest bucket shape) executed five ways —
the single-device batched path, and the four batched mesh schedules from
core/distributed.py (dp / kspan / SUMMA / ring) on a (dp, mp) host-device
mesh.  The batch mixes one high-diameter line graph (the straggler that
needs all lg(n) squarings) with fast-converging dense graphs — the
convergence mix real closure buckets have, and the one where dp's
independent per-device fixpoints decouple the straggler from everyone else.
All arms run the identical padded stack with the identical per-request
``valid_n`` ragged masks, and every arm's output is asserted equal to the
local arm before timing counts.

Results land in a JSON perf-trajectory artifact; mesh rows for the winning
(and losing) schedules can be recorded into a dispatch cost table with
``--cost-table``, which is how measured mesh rows reach ``backend="auto"``
serving (launch/serve_mmo.py --mesh … --cost-table …).
"""
from __future__ import annotations

import argparse
import json
import os
import subprocess
import sys

import numpy as np

# script-mode friendliness: `python benchmarks/shard_bench.py` puts only
# benchmarks/ on sys.path — add the repo root so benchmarks.common resolves
_ROOT = os.path.dirname(os.path.dirname(os.path.abspath(__file__)))
if _ROOT not in sys.path:
  sys.path.insert(0, _ROOT)


def _line_graph(n, seed=0):
  """Path graph i→i+1: diameter n−1 — the straggler that keeps Leyzorek
  iterating lg(n) rounds."""
  rng = np.random.default_rng(seed)
  w = np.full((n, n), np.inf, np.float32)
  w[np.arange(n - 1), np.arange(1, n)] = rng.uniform(
      0.5, 1.5, n - 1).astype(np.float32)
  return w


def _dense_graph(n, seed=0):
  rng = np.random.default_rng(seed)
  w = rng.uniform(0.5, 1.5, (n, n)).astype(np.float32)
  w[rng.random((n, n)) > 0.5] = np.inf
  return w


def bench_bucket(nb: int, mesh, *, requests: int = 8, iters: int = 3,
                 backend: str = "xla"):
  """{arm: seconds} + parity for one (R, nb, nb) min-plus closure bucket.

  Also times a single batched squaring per arm (``step_seconds``, normalized
  per request) — the measurement whose units match the cost table's
  one-(m, k, n)-contraction signature; whole-fixpoint wall times do not.
  """
  import jax
  import jax.numpy as jnp

  from benchmarks.common import timeit
  from repro.core import mmo_batched, pad_adjacency, prepare_adjacency
  from repro.core.closure import batched_leyzorek_closure
  from repro.core.distributed import (SCHEDULES, mmo_sharded_batched,
                                      sharded_closure_batched)

  rng = np.random.default_rng(nb)
  sizes = [int(rng.integers(nb // 2 + 1, nb + 1)) for _ in range(requests - 1)]
  sizes.append(nb)
  ws = [_line_graph(n, seed=n) for n in sizes[:1]] + [
      _dense_graph(n, seed=n) for n in sizes[1:]]
  prepared = [prepare_adjacency(jnp.asarray(w), op="minplus") for w in ws]
  stack = jnp.stack([pad_adjacency(p, nb, op="minplus") for p in prepared])
  valid = jnp.asarray(sizes, jnp.int32)

  arms = {}
  local_fn = lambda: batched_leyzorek_closure(  # noqa: E731
      stack, op="minplus", backend=backend, valid_n=valid)[0]
  local_out = np.asarray(local_fn())
  arms["local"] = timeit(local_fn, iters=iters)
  for sched in SCHEDULES:
    fn = lambda s=sched: sharded_closure_batched(  # noqa: E731
        stack, op="minplus", mesh=mesh, schedule=s, backend=backend,
        valid_n=valid)[0]
    out = np.asarray(fn())
    assert np.array_equal(out, local_out), f"{sched} diverged from local"
    arms[sched] = timeit(fn, iters=iters)
  step_fns = {"local": jax.jit(lambda x: mmo_batched(
      x, x, op="minplus", backend=backend, k_valid=valid))}
  for sched in SCHEDULES:
    step_fns[sched] = jax.jit(lambda x, s=sched: mmo_sharded_batched(
        x, x, op="minplus", schedule=s, mesh=mesh, backend=backend,
        k_valid=valid))
  steps = {}
  for name, f in step_fns.items():  # timeit's warmup call absorbs compile
    steps[name] = timeit(lambda: f(stack), iters=iters) / requests

  best_sched = min((s for s in arms if s != "local"), key=arms.get)
  return {
      "bucket": nb,
      "requests": requests,
      "sizes": sizes,
      "seconds": arms,
      "step_seconds": steps,
      "best_schedule": best_sched,
      "speedup_best_vs_local": arms["local"] / arms[best_sched],
  }


def main(argv=None):
  ap = argparse.ArgumentParser()
  ap.add_argument("--out", default="BENCH_shard.json")
  ap.add_argument("--buckets", default="64,128,256",
                  help="comma-separated closure bucket sizes")
  ap.add_argument("--requests", type=int, default=8,
                  help="requests per bucket batch (divisible by the device "
                       "count so the dp arm can shard the request axis)")
  ap.add_argument("--iters", type=int, default=3)
  ap.add_argument("--devices", type=int, default=8,
                  help="fake host devices to request when the host has fewer")
  ap.add_argument("--mesh", default="2,4", metavar="DP,MP")
  ap.add_argument("--cost-table", default=None, metavar="PATH",
                  help="record the measured mesh rows (and local row) into "
                       "this dispatch cost table (created if missing)")
  args = ap.parse_args(argv)

  import jax
  if len(jax.devices()) < args.devices and jax.default_backend() == "cpu":
    # must be set before jax initializes — re-exec with the flag appended
    env = dict(os.environ)
    env["XLA_FLAGS"] = (env.get("XLA_FLAGS", "") +
                        f" --xla_force_host_platform_device_count="
                        f"{args.devices}").strip()
    return subprocess.call([sys.executable, os.path.abspath(__file__),
                            *(argv or sys.argv[1:])], env=env)

  dims = tuple(int(x) for x in args.mesh.split(","))
  mesh = jax.make_mesh(dims, ("data", "model"))
  buckets = tuple(int(b) for b in args.buckets.split(","))

  rows = []
  for nb in buckets:
    row = bench_bucket(nb, mesh, requests=args.requests, iters=args.iters)
    rows.append(row)
    secs = "  ".join(f"{a}={s * 1e3:8.1f}ms" for a, s in
                     row["seconds"].items())
    print(f"[shard_bench] bucket={nb:4d} R={args.requests}  {secs}  "
          f"best={row['best_schedule']} "
          f"({row['speedup_best_vs_local']:.2f}x vs local)")

  if args.cost_table:
    from repro.core.distributed import SCHEDULES
    from repro.tuning import CostTable
    table = (CostTable.load(args.cost_table)
             if os.path.exists(args.cost_table) else CostTable(
                 device=f"{jax.default_backend()}-mesh{args.mesh}"))
    # record the per-request single-squaring timings: the table's signature
    # is one (m, k, n) contraction, so whole-fixpoint wall times would be
    # off by R × iterations and poison every later backend="auto" resolve
    for row in rows:
      shape = (row["bucket"],) * 3
      table.record("minplus", shape, "float32", "xla", (512,),
                   row["step_seconds"]["local"])
      for sched in SCHEDULES:
        table.record("minplus", shape, "float32", sched, dims,
                     row["step_seconds"][sched])
    table.save(args.cost_table)
    print(f"[shard_bench] recorded {(1 + len(SCHEDULES)) * len(rows)} "
          f"measured rows → {args.cost_table}")

  doc = {
      "schema": 1,
      "device": jax.default_backend(),
      "n_devices": len(jax.devices()),
      "mesh": list(dims),
      "buckets": rows,
  }
  with open(args.out, "w") as f:
    json.dump(doc, f, indent=2)
  print(f"[shard_bench] wrote {args.out}")

  biggest = rows[-1]
  assert biggest["speedup_best_vs_local"] > 1.0, (
      f"no distributed schedule beat the local path on the largest closure "
      f"bucket ({biggest['bucket']}): {biggest['seconds']}")
  return 0


if __name__ == "__main__":
  raise SystemExit(main())

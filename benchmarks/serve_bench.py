"""Serving benchmark: bucketed continuous batching vs one-request-at-a-time.

    PYTHONPATH=src python benchmarks/serve_bench.py [--requests 120]

Two arms serve the same mixed APSP + KNN + reachability request stream with
ragged problem sizes (the serving-realistic case: every request is a
different graph):

  naive   — sequential loop over the direct solvers (solvers.apsp / knn /
            gtc).  Every *novel* shape pays an XLA trace+compile; repeats
            hit jax's jit cache.
  engine  — MMOEngine: shape-bucketed batching, one AOT executable per
            (bucket, batch); ~a dozen compiles total regardless of how many
            distinct shapes arrive.

Reported per arm: problems/s and p50/p99 latency (arrival = stream start).
A second pass replays the same traffic against the warm engine and asserts
**zero recompiles** (executable-cache steady state) — the property that
makes p99 flat under sustained load.

A third experiment prices the observability layer: the same warm traffic
with the request-lifecycle flight recorder enabled vs disabled
(``MMOEngine(trace=...)``), measured as a median of paired on/off ratios
(see ``run_overhead`` for why).  The enabled arm must stay within the
overhead budget (< 5% steady-state throughput regression — tracing is
designed to be left on in production), asserted here and recorded in
BENCH_serve.json with the other arms.
"""
from __future__ import annotations

import argparse
import json
import statistics
import time

import numpy as np

from repro.apps import graphs, solvers
from repro.serve_mmo import (MMOEngine, apsp_request, knn_request,
                             reachability_request)


def make_stream(n_requests: int, seed: int = 0):
  """Mixed ragged-shape stream: (request, naive-solver thunk) pairs."""
  rng = np.random.default_rng(seed)
  stream = []
  for _ in range(n_requests):
    kind = rng.choice(("apsp", "knn", "reach"))
    n = int(rng.integers(9, 49))
    s = int(rng.integers(0, 2 ** 31))
    if kind == "apsp":
      w = graphs.weighted_digraph(n, 0.3, seed=s)
      stream.append((apsp_request(w), lambda w=w: solvers.apsp(w)[0]))
    elif kind == "reach":
      adj = graphs.boolean_digraph(n, 0.1, seed=s)
      stream.append((reachability_request(adj),
                     lambda adj=adj: solvers.gtc(adj)[0]))
    else:
      ref, qry = graphs.knn_points(4 * n, n, 16, seed=s)
      k = min(8, 4 * n)
      stream.append((knn_request(qry, ref, k=k),
                     lambda ref=ref, qry=qry, k=k: solvers.knn(ref, qry, k=k)))
  return stream


def _percentiles(lat):
  lat = np.asarray(lat, dtype=np.float64)
  return (float(np.percentile(lat, 50)) * 1e3,
          float(np.percentile(lat, 99)) * 1e3)


def run_naive(stream):
  import jax
  t0 = time.perf_counter()
  lat = []
  for _, thunk in stream:
    jax.block_until_ready(thunk())
    lat.append(time.perf_counter() - t0)
  wall = time.perf_counter() - t0
  return wall, lat


def run_engine(stream, engine: MMOEngine):
  t0 = time.perf_counter()
  futs = [engine.submit(req) for req, _ in stream]
  engine.run_until_idle()
  wall = time.perf_counter() - t0
  lat = [r.completed_s - t0 for r in engine._records[-len(stream):]]
  for f in futs:
    assert f.done()
  return wall, lat


OVERHEAD_BUDGET = 0.05  # max allowed steady-state slowdown with tracing on


def run_overhead(stream, *, backend: str, max_batch: int, repeats: int = 15):
  """Warm steady-state wall time with the flight recorder on vs off.

  Measurement discipline: the effect being measured (~1-3%) is far below
  this environment's noise floor — a single ~50ms warm replay jitters ±5%
  run-to-run, and contention streaks last whole seconds, so sequential A/B
  walls (or even interleaved best-of-N mins) swing the apparent overhead
  ±10% either direction.  The estimator that survives that noise is the
  MEDIAN OF PAIRED RATIOS: both engines are built + prewarmed up front,
  each of ``repeats`` trials measures the two arms back to back (each wall
  covering a few replays so per-replay jitter amortizes; arm order
  alternates between trials so within-pair ordering cancels too) and
  yields one on/off ratio — drift is common to the pair, so it divides
  out — and the median across trials discards the outlier pairs a
  contention streak produces.  Returns the per-arm median walls + the
  overhead fraction (median ratio − 1)."""
  inner = 3  # replays per measured wall
  engines = {}
  for label, trace in (("disabled", False), ("enabled", True)):
    engine = MMOEngine(backend=backend, max_batch=max_batch, trace=trace)
    engine.prewarm([req for req, _ in stream])
    run_engine(stream, engine)  # first-run warmup, outside the measurement
    engines[label] = engine

  def wall(engine):
    engine.reset_stats()
    t0 = time.perf_counter()
    for _ in range(inner):
      run_engine(stream, engine)
    return time.perf_counter() - t0

  ratios, on_walls, off_walls = [], [], []
  for i in range(repeats):
    if i % 2 == 0:
      off = wall(engines["disabled"])
      on = wall(engines["enabled"])
    else:
      on = wall(engines["enabled"])
      off = wall(engines["disabled"])
    ratios.append(on / off)
    on_walls.append(on)
    off_walls.append(off)
  overhead = statistics.median(ratios) - 1.0
  return {
      "disabled_wall_s": statistics.median(off_walls),
      "enabled_wall_s": statistics.median(on_walls),
      "overhead_frac": overhead,
      "budget_frac": OVERHEAD_BUDGET,
      "pairs": repeats,
      "trace_events_recorded": engines["enabled"].tracer.stats()["recorded"],
  }


def main(argv=None):
  ap = argparse.ArgumentParser()
  ap.add_argument("--requests", type=int, default=120)
  ap.add_argument("--backend", default="xla")
  ap.add_argument("--max-batch", type=int, default=8)
  ap.add_argument("--seed", type=int, default=0)
  ap.add_argument("--repeats", type=int, default=15,
                  help="paired on/off trials for the observability "
                       "overhead measurement")
  ap.add_argument("--out", default="BENCH_serve.json", metavar="PATH",
                  help="write all arms' numbers to PATH as JSON "
                       "('' disables)")
  args = ap.parse_args(argv)

  stream = make_stream(args.requests, seed=args.seed)
  n = len(stream)

  # -- naive sequential arm --------------------------------------------------
  naive_wall, naive_lat = run_naive(stream)
  np50, np99 = _percentiles(naive_lat)
  print(f"[serve_bench] naive   : {n / naive_wall:7.1f} problems/s  "
        f"p50={np50:8.1f}ms  p99={np99:8.1f}ms  wall={naive_wall:.2f}s")

  # -- bucketed engine, cold (compiles included) -----------------------------
  engine = MMOEngine(backend=args.backend, max_batch=args.max_batch)
  cold_wall, cold_lat = run_engine(stream, engine)
  cp50, cp99 = _percentiles(cold_lat)
  cold_compiles = engine.cache.misses
  print(f"[serve_bench] engine  : {n / cold_wall:7.1f} problems/s  "
        f"p50={cp50:8.1f}ms  p99={cp99:8.1f}ms  wall={cold_wall:.2f}s  "
        f"(cold: {cold_compiles} compiles)")

  # -- repeated traffic: executable-cache steady state -----------------------
  engine.reset_stats()
  misses_before = engine.cache.misses
  warm_wall, warm_lat = run_engine(stream, engine)
  recompiles = engine.cache.misses - misses_before
  wp50, wp99 = _percentiles(warm_lat)
  print(f"[serve_bench] engine#2: {n / warm_wall:7.1f} problems/s  "
        f"p50={wp50:8.1f}ms  p99={wp99:8.1f}ms  wall={warm_wall:.2f}s  "
        f"(warm: {recompiles} recompiles)")

  speedup = naive_wall / cold_wall
  print(f"[serve_bench] speedup: {speedup:.2f}x cold, "
        f"{naive_wall / warm_wall:.2f}x warm; "
        f"executables={len(engine.cache)} "
        f"mean_batch={engine.stats().mean_batch:.2f}")

  # -- observability overhead: tracing on vs off, warm steady state ----------
  obs = run_overhead(stream, backend=args.backend, max_batch=args.max_batch,
                     repeats=args.repeats)
  print(f"[serve_bench] observability: trace-off {obs['disabled_wall_s']:.3f}s"
        f" vs trace-on {obs['enabled_wall_s']:.3f}s → "
        f"{obs['overhead_frac'] * 100:+.2f}% median of {obs['pairs']} pairs "
        f"(budget {OVERHEAD_BUDGET * 100:.0f}%, "
        f"{obs['trace_events_recorded']} events)")

  if args.out:
    doc = {
        "requests": n,
        "backend": args.backend,
        "max_batch": args.max_batch,
        "naive": {"wall_s": naive_wall, "p50_ms": np50, "p99_ms": np99},
        "engine_cold": {"wall_s": cold_wall, "p50_ms": cp50, "p99_ms": cp99,
                        "compiles": cold_compiles},
        "engine_warm": {"wall_s": warm_wall, "p50_ms": wp50, "p99_ms": wp99,
                        "recompiles": recompiles},
        "speedup_cold": speedup,
        "speedup_warm": naive_wall / warm_wall,
        "observability": obs,
    }
    with open(args.out, "w", encoding="utf-8") as f:
      json.dump(doc, f, indent=2)
    print(f"[serve_bench] wrote {args.out}")

  assert recompiles == 0, f"steady-state traffic recompiled {recompiles}x"
  assert speedup > 1.0, (
      f"bucketed engine must beat the naive loop, got {speedup:.2f}x")
  assert obs["overhead_frac"] < OVERHEAD_BUDGET, (
      f"observability overhead {obs['overhead_frac'] * 100:.2f}% exceeds the "
      f"{OVERHEAD_BUDGET * 100:.0f}% budget — tracing must stay cheap enough "
      f"to leave on")
  return 0


if __name__ == "__main__":
  raise SystemExit(main())

"""Benchmark driver — one function per paper table/figure.

Prints ``name,us_per_call,derived`` CSV rows.  Figure map:
  Fig 9   microbench_square     Fig 12  algo_opts
  Fig 10  microbench_shapes     Fig 13/14  sparse_bench
  Fig 11  apps_bench            Table 5 area_table
  §Roofline  roofline_table (from dry-run artifacts, if present)
  §Dispatch  dispatch_bench (auto vs fixed backends, ragged masked-K, and
             the fused fixpoint megakernel vs per-iteration dispatch →
             BENCH_dispatch.json)
  §Sharding  shard_bench (local vs distributed schedules → BENCH_shard.json;
             re-execs itself with 8 fake host devices on CPU)
  §QoS       qos_bench (deadline vs FIFO under bulk interference, admission
             bounding, scheduler pick cost → BENCH_qos.json)
  §Serving   serve_bench (bucketed engine vs naive loop, zero-recompile
             steady state, observability overhead < 5% → BENCH_serve.json)
  §Faults    resilience_bench (goodput + urgent p99 under injected execute
             faults vs fail-whole-batch, disabled-hook overhead < 2% →
             BENCH_resilience.json)
  §Arena     arena_bench (slot-based continuous batching vs bucket-cycle
             under open-loop Poisson arrivals, zero steady-state retraces →
             BENCH_arena.json)
"""
from __future__ import annotations

import sys
import traceback


def main() -> None:
  from repro.analysis.sanitize import maybe_enable_sanitize
  maybe_enable_sanitize()  # REPRO_SANITIZE=1: debug_nans + analyzer preflight
  from benchmarks import (algo_opts, apps_bench, area_table, arena_bench,
                          dispatch_bench, microbench_shapes,
                          microbench_square, qos_bench, resilience_bench,
                          roofline_table, serve_bench, shard_bench,
                          sparse_bench)
  print("name,us_per_call,derived")
  suites = (
      ("fig9", microbench_square.main),
      ("fig10", microbench_shapes.main),
      ("fig11", apps_bench.main),
      ("fig12", algo_opts.main),
      ("fig13_14", sparse_bench.main),
      ("table5", area_table.main),
      ("roofline", roofline_table.main),
      ("dispatch", dispatch_bench.main),
      ("shard", shard_bench.main),
      ("qos", qos_bench.main),
      ("serve", serve_bench.main),
      ("resilience", resilience_bench.main),
      ("arena", arena_bench.main),
  )
  failed = []
  for name, fn in suites:
    try:
      fn()
    except Exception:  # noqa: BLE001
      failed.append(name)
      print(f"{name}/SUITE_FAILED,0.0,", file=sys.stderr)
      traceback.print_exc()
  if failed:
    raise SystemExit(f"failed suites: {failed}")


if __name__ == "__main__":
  main()

"""Render markdown roofline tables from dry-run JSON dirs (EXPERIMENTS.md)."""
import glob, json, os, sys

ORDER = {"train_4k": 0, "prefill_32k": 1, "decode_32k": 2, "long_500k": 3}

def rows(tag):
    rs = [json.load(open(f)) for f in glob.glob(f"results/{tag}/*.json")]
    return sorted(rs, key=lambda r: (ORDER.get(r["shape"], 9), r["arch"]))

def md(tag):
    out = [f"### {tag}", "",
           "| arch | shape | t_compute | t_memory | t_coll | bottleneck | mem/dev | useful | MFU-bound |",
           "|---|---|---|---|---|---|---|---|---|"]
    for r in rows(tag):
        if r["status"] == "skipped":
            out.append(f"| {r['arch']} | {r['shape']} | — | — | — | *skip: {r['reason'][:48]}…* | — | — | — |")
            continue
        if r["status"] != "ok":
            out.append(f"| {r['arch']} | {r['shape']} | FAILED | | | | | | |")
            continue
        mem = (r.get("peak_mem_per_dev") or 0) / 2**30
        out.append(
            f"| {r['arch']} | {r['shape']} | {r['t_compute_s']:.3g} s | "
            f"{r['t_memory_s']:.3g} s | {r['t_collective_s']:.3g} s | "
            f"**{r['bottleneck']}** | {mem:.1f} GiB | {r['useful_ratio']:.2f} | "
            f"{r['mfu_bound']*100:.2f}% |")
    return "\n".join(out)

if __name__ == "__main__":
    for tag in sys.argv[1:] or ["final_single", "final_multi"]:
        print(md(tag)); print()

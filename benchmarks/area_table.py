"""Table 5: area/power of the SIMD² unit — analytical model (SIMULATED RTL;
see core/area_model.py).  Prints model-vs-paper for all 27 published numbers
plus the power and full-chip overhead derivations."""
from __future__ import annotations

from benchmarks.common import csv_row
from repro.core import area_model as am


def run():
  rows = []
  for tbl_name, tbl in (("5a", am.table5a()), ("5b", am.table5b()),
                        ("5c", am.table5c())):
    for k, (model, paper) in tbl.items():
      rows.append(csv_row(f"table{tbl_name}/{k.replace(' ', '_')}", 0.0,
                          f"model={model};paper={paper}"))
  fid = am.fidelity()
  rows.append(csv_row("table5/fidelity", 0.0,
                      f"mean_rel_err={fid['mean_rel_err']:.3f};"
                      f"max_rel_err={fid['max_rel_err']:.3f};"
                      f"n={fid['n_targets']}"))
  rows.append(csv_row("table5/power_all_ops_W", 0.0,
                      f"model={am.power_w(am.ALL_OPS):.2f};paper=4.53"))
  rows.append(csv_row("table5/chip_overhead_pct", 0.0,
                      f"model={am.chip_overhead_fraction() * 100:.1f};paper=5"))
  rows.append(csv_row("table5/grid8x8_scale", 0.0,
                      f"model={am.grid_scaling(8):.2f};paper=7.5"))
  return rows


def main():
  for r in run():
    print(r)


if __name__ == "__main__":
  main()

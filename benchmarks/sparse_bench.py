"""Fig 13/14: sparse SIMD².

Fig 13 arm — 2:4 structured sparsity: SIMD² ops on pruned inputs; measured
compacted-contraction time + the modeled 2× sparse-unit throughput applied
to the dense roofline (paper: 1.67–1.9× over dense SIMD²).
Fig 14 arm — density crossover: dense MMO vs CSR SpMM (numpy stand-in for
cuSparse) across sparsity levels (paper: crossover ≈99% at 4096²)."""
from __future__ import annotations

import time

import jax.numpy as jnp
import numpy as np

from benchmarks.common import csv_row, timeit
from repro.core.mmo import mmo
from repro.core.sparse import csr_spmm_np, mmo_sparse24, prune_24, to_csr


def run_24(n: int = 512, iters=2):
  rng = np.random.default_rng(2)
  rows = []
  for op in ("mma", "minplus", "maxmin"):
    a = rng.standard_normal((n, n)).astype(np.float32)
    b = rng.standard_normal((n, n)).astype(np.float32)
    aj, bj = jnp.asarray(a), jnp.asarray(b)
    vals, idx = prune_24(aj)
    t_dense = timeit(lambda: mmo(aj, bj, op=op), iters=iters)
    t_24 = timeit(lambda: mmo_sparse24(vals, idx, bj, op=op), iters=iters)
    # sparse-unit model: ⊗ throughput doubles, memory term unchanged
    rows.append(csv_row(
        f"fig13/{op}/{n}", t_24 * 1e6,
        f"measured_x{t_dense / t_24:.2f};modeled_sparse_unit_x2.0"))
  return rows


def run_crossover(n: int = 512, densities=(0.5, 0.1, 0.02, 0.01, 0.005),
                  iters=1):
  rng = np.random.default_rng(3)
  rows = []
  b = rng.standard_normal((n, n)).astype(np.float32)
  bj = jnp.asarray(b)
  for d in densities:
    a = rng.standard_normal((n, n)).astype(np.float32)
    a[rng.random((n, n)) >= d] = 0.0
    aj = jnp.asarray(a)
    t_dense = timeit(lambda: mmo(aj, bj, op="mma"), iters=iters)
    indptr, indices, data = to_csr(a)
    t0 = time.perf_counter()
    csr_spmm_np(indptr, indices, data, b)
    t_csr = time.perf_counter() - t0
    rows.append(csv_row(
        f"fig14/sparsity{1 - d:.3f}/{n}", t_dense * 1e6,
        f"csr_over_dense_x{t_dense / t_csr:.3f};dense_wins={t_dense < t_csr}"))
  return rows


def main():
  for r in run_24() + run_crossover():
    print(r)


if __name__ == "__main__":
  main()

"""§Roofline artifact: render the per-(arch × shape × mesh) three-term
roofline table from the dry-run JSON results (results/<tag>/*.json).

    PYTHONPATH=src python -m benchmarks.roofline_table [tag ...]
"""
from __future__ import annotations

import glob
import json
import os
import sys

from benchmarks.common import csv_row

_SHAPE_ORDER = {"train_4k": 0, "prefill_32k": 1, "decode_32k": 2,
                "long_500k": 3}


def load(tag: str):
  rows = []
  for f in sorted(glob.glob(os.path.join("results", tag, "*.json"))):
    rows.append(json.load(open(f)))
  return sorted(rows, key=lambda r: (_SHAPE_ORDER.get(r["shape"], 9),
                                     r["arch"]))


def render(tag: str):
  out = []
  rows = load(tag)
  if not rows:
    out.append(csv_row(f"roofline/{tag}/MISSING", 0.0,
                       "run repro.launch.dryrun --all first"))
    return out
  for r in rows:
    cell = f"roofline/{tag}/{r['arch']}/{r['shape']}"
    if r["status"] == "skipped":
      out.append(csv_row(cell, 0.0, f"SKIP:{r['reason'][:60]}"))
      continue
    if r["status"] != "ok":
      out.append(csv_row(cell, 0.0, f"FAILED:{r.get('error', '')[:80]}"))
      continue
    mem_g = (r.get("peak_mem_per_dev") or 0) / 2 ** 30
    out.append(csv_row(
        cell, r["t_bound_s"] * 1e6 if "t_bound_s" in r else
        max(r["t_compute_s"], r["t_memory_s"], r["t_collective_s"]) * 1e6,
        f"tC={r['t_compute_s']:.3g};tM={r['t_memory_s']:.3g};"
        f"tX={r['t_collective_s']:.3g};bneck={r['bottleneck']};"
        f"useful={r['useful_ratio']:.2f};mfu_bound={r['mfu_bound']:.3f};"
        f"mem={mem_g:.1f}GiB"))
  return out


def main(tags=None):
  tags = tags or ["final_single", "final_multi"]
  for t in tags:
    for r in render(t):
      print(r)


if __name__ == "__main__":
  main(sys.argv[1:] or None)
